//! Quickstart: fit an L1-SVM with first-order-initialized column
//! generation on synthetic data, and (when `make artifacts` has run)
//! demonstrate the JAX/Pallas AOT path by evaluating the fused
//! smoothed-hinge gradient through PJRT.
//!
//!     cargo run --release --example quickstart

use cutgen::backend::NativeBackend;
use cutgen::coordinator::l1svm::column_generation;
use cutgen::coordinator::GenParams;
use cutgen::data::synthetic::{generate_l1, SyntheticSpec};
use cutgen::fom::screening::correlation_screen;
use cutgen::rng::Xoshiro256;
use cutgen::runtime::{FusedHingeGrad, PjrtRuntime};

fn main() -> anyhow::Result<()> {
    // 1. data: the paper's §5.1.1 generator (100 samples, 2000 features,
    //    10 informative).
    let spec = SyntheticSpec::paper_default(100, 2000);
    let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(7));
    let lambda = 0.01 * ds.lambda_max_l1();
    println!("L1-SVM quickstart: n={}, p={}, λ = 0.01·λ_max = {lambda:.4}", ds.n(), ds.p());

    // 2. column generation, seeded by correlation screening.
    let backend = NativeBackend::new(&ds.x);
    let init = correlation_screen(&ds.x, &ds.y, 50);
    let t0 = std::time::Instant::now();
    let sol = column_generation(&ds, &backend, lambda, &init, &GenParams::default());
    println!(
        "solved in {:.3}s: objective {:.4}, {} nonzeros, working set {} of {} columns",
        t0.elapsed().as_secs_f64(),
        sol.objective,
        sol.support_size(),
        sol.cols.len(),
        ds.p()
    );
    let k0_hits = (0..10).filter(|&j| sol.beta[j].abs() > 1e-8).count();
    println!("recovered {k0_hits}/10 informative features");

    // 3. training accuracy.
    let mut correct = 0;
    for i in 0..ds.n() {
        let xi: Vec<f64> = (0..ds.p()).map(|j| ds.x.get(i, j)).collect();
        if sol.predict(&xi) == ds.y[i] {
            correct += 1;
        }
    }
    println!("training accuracy {}/{}", correct, ds.n());

    // 4. the AOT three-layer path: JAX/Pallas → HLO text → PJRT.
    if PjrtRuntime::artifacts_available() {
        let rt = PjrtRuntime::load(PjrtRuntime::default_dir())?;
        println!("\nPJRT path (platform {}):", rt.platform());
        let fused = FusedHingeGrad::new(&rt, &ds.x, &ds.y)?;
        let (val, grad, g0) = fused.value_grad(&sol.beta, sol.beta0, 0.2)?;
        println!("  fused Pallas hinge-grad at the CG solution:");
        println!("    F^tau = {val:.4}   |∇β|∞ = {:.4}   ∇β₀ = {g0:.4}",
            grad.iter().fold(0.0f64, |m, v| m.max(v.abs())));
        println!("  (value ≈ hinge loss of the LP solution — the smoothed gap is ≤ τ/2·n)");
    } else {
        println!("\n(artifacts not built; run `make artifacts` to see the PJRT path)");
    }
    Ok(())
}
