//! Slope-SVM (Problem 4) with distinct BH-style weights — the case where
//! generic solvers crash (the epigraph needs p! cuts) but Algorithm 7
//! needs only a handful.
//!
//!     cargo run --release --example slope_svm

use cutgen::backend::NativeBackend;
use cutgen::coordinator::slope::slope_column_constraint_generation;
use cutgen::coordinator::GenParams;
use cutgen::data::synthetic::{generate_l1, SyntheticSpec};
use cutgen::fom::objective::bh_slope_weights;
use cutgen::rng::Xoshiro256;

fn main() {
    let ds = generate_l1(
        &SyntheticSpec::paper_default(100, 20_000),
        &mut Xoshiro256::seed_from_u64(31),
    );
    let lambda_tilde = 0.01 * ds.lambda_max_l1();
    let lambda = bh_slope_weights(ds.p(), lambda_tilde);
    println!(
        "Slope-SVM: n={}, p={}, λ_j = sqrt(log(2p/j))·{lambda_tilde:.4} (all distinct)",
        ds.n(),
        ds.p()
    );
    println!("(the A.2 LP reformulation of this problem needs {} rows — hopeless;",
        ds.n() + ds.p() * ds.p());
    println!(" the epigraph has p! ≈ 10^77k permutation cuts)");

    let backend = NativeBackend::new(&ds.x);
    let (init, t_init) = cutgen::exps::common::fo_slope_init(&ds, &lambda, 100);
    let t0 = std::time::Instant::now();
    let sol = slope_column_constraint_generation(
        &ds,
        &backend,
        &lambda,
        &init,
        &GenParams { eps: 1e-2, max_cols_per_round: 10, ..Default::default() },
    );
    println!(
        "solved in {:.2}s (+{t_init:.2}s FO init): objective {:.4}",
        t0.elapsed().as_secs_f64(),
        sol.objective
    );
    println!(
        "  {} nonzeros, working set {} columns, {} permutation cuts, {} rounds",
        sol.support_size(),
        sol.cols.len(),
        sol.stats.rows_added,
        sol.stats.rounds
    );
}
