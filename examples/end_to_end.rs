//! End-to-end driver: proves all three layers compose on realistic
//! workloads and reproduces the paper's headline effect. Results are
//! recorded in EXPERIMENTS.md.
//!
//! Workloads (one per regime of the paper):
//!   1. p ≫ n  (n=100, p=50 000 dense)  — column generation, priced by
//!      the AOT JAX/Pallas `xtv` kernel through PJRT (Layers 1+2+3);
//!      full-LP baseline for the headline speedup.
//!   2. n and p large (n=2000, p=20 000) — the hybrid SFO+CL-CNG
//!      (Algorithm 4) where neither pure method is viable.
//!   3. sparse rcv1-like — the Table 3 regime.
//!
//!     cargo run --release --example end_to_end

use cutgen::backend::{Backend, NativeBackend};
use cutgen::coordinator::l1svm::column_generation;
use cutgen::coordinator::GenParams;
use cutgen::data::synthetic::{generate_l1, generate_sparse_text, SparseTextSpec, SyntheticSpec};
use cutgen::exps::common::{fo_clg, sfo_cl_cng};
use cutgen::exps::time_it;
use cutgen::rng::Xoshiro256;
use cutgen::runtime::{PjrtBackend, PjrtRuntime};

fn main() -> anyhow::Result<()> {
    println!("=== cutgen end-to-end driver ===\n");

    // ---------------- workload 1: p >> n (CG territory) ----------------
    let (n1, p1) = (100, 50_000);
    let ds1 = generate_l1(&SyntheticSpec::paper_default(n1, p1), &mut Xoshiro256::seed_from_u64(1));
    let lam1 = 0.01 * ds1.lambda_max_l1();
    println!("[workload 1] dense p>>n: n={n1}, p={p1}, λ=0.01·λ_max");

    let native1 = NativeBackend::new(&ds1.x);
    let rt = if PjrtRuntime::artifacts_available() {
        Some(PjrtRuntime::load(PjrtRuntime::default_dir())?)
    } else {
        println!("  !! artifacts missing — run `make artifacts`; PJRT path skipped");
        None
    };
    if let Some(rt) = &rt {
        let (pjrt, t_up) = time_it(|| PjrtBackend::new(rt, &ds1.x));
        let pjrt = pjrt?;
        println!(
            "  PJRT: uploaded as {}x{} f32 tiles in {t_up:.2}s (platform {})",
            rt.meta.tn,
            rt.meta.tp,
            rt.platform()
        );
        // Layer 1/2 vs native parity on the pricing kernel.
        let mut rng = Xoshiro256::seed_from_u64(2);
        let v: Vec<f64> = (0..n1).map(|_| rng.uniform()).collect();
        let mut q_native = vec![0.0; p1];
        let mut q_pjrt = vec![0.0; p1];
        let (_, t_nat) = time_it(|| native1.xtv(&v, &mut q_native));
        let (_, t_pj) = time_it(|| pjrt.xtv(&v, &mut q_pjrt));
        let max_err =
            q_native.iter().zip(&q_pjrt).fold(0.0f64, |m, (a, b)| m.max((a - b).abs()));
        println!(
            "  pricing parity: max |Δq| = {max_err:.2e} (native {:.1}ms, pjrt {:.1}ms)",
            t_nat * 1e3,
            t_pj * 1e3
        );
        assert!(max_err < 1e-3, "backend mismatch");

        // Layer 3 on the PJRT backend.
        let init = cutgen::coordinator::path::initial_columns(&ds1, 50);
        let (sol, t) =
            time_it(|| column_generation(&ds1, &pjrt, lam1, &init, &GenParams::default()));
        println!(
            "  CLG priced by Pallas/PJRT: {:.2}s, objective {:.4}, support {}",
            t,
            sol.objective,
            sol.support_size()
        );
    }

    // the paper's headline on this workload: FO+CLG vs full LP
    let (sol_cg, split) = fo_clg(&ds1, lam1, 1e-2, 100);
    println!(
        "  FO+CLG      : {:.2}s (init {:.2}s + cut {:.2}s), objective {:.4}, support {}",
        split.total(),
        split.init,
        split.cut,
        sol_cg.objective,
        sol_cg.support_size()
    );
    let (lp, t_lp) = time_it(|| cutgen::baselines::full_lp::solve_full_l1(&ds1, lam1));
    println!("  full LP     : {:.2}s, objective {:.4}", t_lp, lp.objective);
    let speedup = t_lp / split.total();
    let gap = (sol_cg.objective - lp.objective).abs() / lp.objective;
    println!("  >>> headline: FO+CLG is {speedup:.0}x faster than the full LP (gap {gap:.2e})");

    // ---------------- workload 2: n and p both large --------------------
    let (n2, p2) = (2000, 20_000);
    let ds2 = generate_l1(&SyntheticSpec::paper_default(n2, p2), &mut Xoshiro256::seed_from_u64(3));
    let lam2 = 0.01 * ds2.lambda_max_l1();
    println!("\n[workload 2] dense n,p large: n={n2}, p={p2} ({:.0} MB)", (n2 * p2 * 8) as f64 / 1e6);
    let (sol_cc, split_cc) = sfo_cl_cng(&ds2, lam2, 1e-2, 200, 3);
    println!(
        "  SFO+CL-CNG  : {:.2}s (init {:.2}s + cut {:.2}s), objective {:.4}",
        split_cc.total(),
        split_cc.init,
        split_cc.cut,
        sol_cc.objective
    );
    println!(
        "  restricted model: |I| = {} of {}, |J| = {} of {} — the full LP never gets built",
        sol_cc.rows.len(),
        n2,
        sol_cc.cols.len(),
        p2
    );

    // ---------------- workload 3: sparse rcv1-like ----------------------
    println!("\n[workload 3] sparse rcv1-like");
    let spec = SparseTextSpec::rcv1_like(0.15);
    let sds = generate_sparse_text(&spec, &mut Xoshiro256::seed_from_u64(4));
    let slam = 0.05 * sds.lambda_max_l1();
    println!(
        "  n={}, p={}, nnz={} (density {:.4})",
        sds.n(),
        sds.p(),
        sds.x.nnz(),
        sds.x.nnz() as f64 / (sds.n() * sds.p()) as f64
    );
    let (ssol, ssplit) = sfo_cl_cng(&sds, slam, 1e-2, 200, 5);
    println!(
        "  SFO+CL-CNG  : {:.2}s, objective {:.4}, support {}, |I|={} of {}, |J|={} of {}",
        ssplit.total(),
        ssol.objective,
        ssol.support_size(),
        ssol.rows.len(),
        sds.n(),
        ssol.cols.len(),
        sds.p()
    );

    println!("\n=== end-to-end complete: all layers verified ===");
    Ok(())
}
