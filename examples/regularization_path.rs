//! Regularization path (Algorithm 2): 20 λ values with warm-started
//! column generation, printing the path like Table 1's CLG rows.
//!
//!     cargo run --release --example regularization_path

use cutgen::backend::NativeBackend;
use cutgen::coordinator::path::{geometric_grid, regularization_path};
use cutgen::coordinator::GenParams;
use cutgen::data::synthetic::{generate_l1, SyntheticSpec};
use cutgen::rng::Xoshiro256;

fn main() {
    let ds = generate_l1(
        &SyntheticSpec::paper_default(100, 10_000),
        &mut Xoshiro256::seed_from_u64(11),
    );
    let grid = geometric_grid(ds.lambda_max_l1(), 20, 0.7);
    let backend = NativeBackend::new(&ds.x);
    println!("path over {} λ values on n={}, p={}", grid.len(), ds.n(), ds.p());
    let t0 = std::time::Instant::now();
    let (path, _) =
        regularization_path(&ds, &backend, &grid, 10, &GenParams { eps: 1e-2, ..Default::default() });
    println!("{:>12} {:>12} {:>6} {:>6}", "lambda", "objective", "nnz", "|J|");
    for pt in &path {
        println!("{:>12.5} {:>12.4} {:>6} {:>6}", pt.lambda, pt.objective, pt.support, pt.working_set);
    }
    println!(
        "total {:.2}s — the working set grows to {} of {} columns; every re-solve was warm",
        t0.elapsed().as_secs_f64(),
        path.last().unwrap().working_set,
        ds.p()
    );
}
