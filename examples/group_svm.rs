//! Group-SVM (Problem 3): column generation on groups with a block-CD
//! first-order initializer — the Figure 4 winning method in miniature.
//!
//!     cargo run --release --example group_svm

use cutgen::backend::NativeBackend;
use cutgen::coordinator::group::{group_column_generation, initial_groups};
use cutgen::coordinator::GenParams;
use cutgen::data::synthetic::{generate_group, GroupSpec};
use cutgen::fom::block_cd::{block_cd, BlockCdParams};
use cutgen::rng::Xoshiro256;

fn main() {
    let spec = GroupSpec {
        n: 100,
        n_groups: 500,
        group_size: 10,
        k0_groups: 3,
        rho: 0.1,
        standardize: true,
    };
    let gd = generate_group(&spec, &mut Xoshiro256::seed_from_u64(23));
    let ds = &gd.data;
    let lambda = 0.1 * ds.lambda_max_group(&gd.groups);
    println!(
        "Group-SVM: n={}, p={} ({} groups of 10), λ = 0.1·λ_max",
        ds.n(),
        ds.p(),
        gd.groups.len()
    );

    // block-CD warm start → which groups look active?
    let t0 = std::time::Instant::now();
    let cd = block_cd(&ds.x, &ds.y, &gd.groups, lambda, &BlockCdParams::default(), None);
    let active: Vec<usize> = (0..gd.groups.len())
        .filter(|&g| gd.groups[g].iter().any(|&j| cd.beta[j].abs() > 1e-6))
        .collect();
    println!("block CD: {} sweeps, {} candidate groups, {:.3}s", cd.sweeps, active.len(),
        t0.elapsed().as_secs_f64());

    let init = if active.is_empty() { initial_groups(ds, &gd.groups, 5) } else { active };
    let backend = NativeBackend::new(&ds.x);
    let t1 = std::time::Instant::now();
    let sol = group_column_generation(ds, &backend, &gd.groups, lambda, &init, &GenParams::default());
    println!(
        "column generation: objective {:.4}, {} active groups of {}, {:.3}s",
        sol.objective,
        sol.cols.len(),
        gd.groups.len(),
        t1.elapsed().as_secs_f64()
    );
    let informative_found = sol
        .cols
        .iter()
        .filter(|&&g| g < 3)
        .count();
    println!("informative groups recovered: {informative_found}/3");
}
