"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps tile shapes (multiples of the block sizes) and data
distributions; every property asserts allclose against ``kernels.ref``.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hinge_terms, xb, xtv
from compile.kernels import ref
from compile.kernels.matvec import BLOCK_N, BLOCK_P

RNG = np.random.default_rng


def make_tile(seed, tn, tp, scale=1.0, dtype=np.float32):
    r = RNG(seed)
    x = (r.standard_normal((tn, tp)) * scale).astype(dtype)
    return x


# --- fixed-shape smoke tests ------------------------------------------------


def test_xtv_matches_ref_basic():
    x = make_tile(0, BLOCK_N, BLOCK_P)
    v = RNG(1).standard_normal(BLOCK_N).astype(np.float32)
    got = np.asarray(xtv(x, v))
    want = np.asarray(ref.xtv_ref(x, v))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_xb_matches_ref_basic():
    x = make_tile(2, BLOCK_N, BLOCK_P)
    b = RNG(3).standard_normal(BLOCK_P).astype(np.float32)
    got = np.asarray(xb(x, b))
    want = np.asarray(ref.xb_ref(x, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_hinge_terms_matches_ref_basic():
    r = RNG(4)
    z = r.standard_normal(BLOCK_N).astype(np.float32) * 2
    y = np.where(r.standard_normal(BLOCK_N) > 0, 1.0, -1.0).astype(np.float32)
    tau = np.array([0.2], np.float32)
    v, f = hinge_terms(z, y, tau)
    vr, fr = ref.hinge_terms_ref(z, y, 0.2)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(f), np.asarray(fr), rtol=1e-6, atol=1e-6)


# --- hypothesis sweeps over shapes / dtypes / scales ------------------------


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(1, 3),
    p_blocks=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_xtv_shape_sweep(n_blocks, p_blocks, seed, scale):
    tn, tp = n_blocks * BLOCK_N, p_blocks * BLOCK_P
    x = make_tile(seed, tn, tp, scale)
    v = RNG(seed + 1).standard_normal(tn).astype(np.float32)
    got = np.asarray(xtv(x, v))
    want = np.asarray(ref.xtv_ref(x, v))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale)


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(1, 3),
    p_blocks=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_xb_shape_sweep(n_blocks, p_blocks, seed, scale):
    tn, tp = n_blocks * BLOCK_N, p_blocks * BLOCK_P
    x = make_tile(seed, tn, tp, scale)
    b = RNG(seed + 2).standard_normal(tp).astype(np.float32)
    got = np.asarray(xb(x, b))
    want = np.asarray(ref.xb_ref(x, b))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale)


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
    tau=st.sampled_from([0.05, 0.2, 1.0, 5.0]),
)
def test_hinge_terms_sweep(n_blocks, seed, tau):
    tn = n_blocks * BLOCK_N
    r = RNG(seed)
    z = (r.standard_normal(tn) * 3).astype(np.float32)
    y = np.where(r.standard_normal(tn) > 0, 1.0, -1.0).astype(np.float32)
    v, f = hinge_terms(z, y, np.array([tau], np.float32))
    vr, fr = ref.hinge_terms_ref(z, y, tau)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(f), np.asarray(fr), rtol=1e-5, atol=1e-6)


# --- dtype robustness --------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_xtv_accepts_float_inputs(dtype):
    # jax will cast f64 -> f32 under default x64-disabled config; the
    # kernel must still match the f32 oracle.
    x = make_tile(7, BLOCK_N, BLOCK_P, dtype=np.float32).astype(dtype)
    v = RNG(8).standard_normal(BLOCK_N).astype(dtype)
    got = np.asarray(xtv(x.astype(np.float32), v.astype(np.float32)))
    want = np.asarray(ref.xtv_ref(x, v))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# --- special values -----------------------------------------------------------


def test_hinge_terms_saturation_edges():
    # exactly at the clip boundaries z = ±2τ
    tau = 0.25
    z = np.array([2 * tau, -2 * tau, 0.0, 4 * tau, -4 * tau] + [0.0] * (BLOCK_N - 5),
                 np.float32)
    y = np.ones(BLOCK_N, np.float32)
    v, f = hinge_terms(z, y, np.array([tau], np.float32))
    vr, fr = ref.hinge_terms_ref(z, y, tau)
    np.testing.assert_allclose(np.asarray(v), np.asarray(vr), atol=1e-7)
    np.testing.assert_allclose(np.asarray(f), np.asarray(fr), atol=1e-7)


def test_xtv_zero_and_sparse_vectors():
    x = make_tile(9, BLOCK_N, BLOCK_P)
    v = np.zeros(BLOCK_N, np.float32)
    np.testing.assert_allclose(np.asarray(xtv(x, v)), 0.0)
    v[3] = 2.5  # single support vector
    got = np.asarray(xtv(x, v))
    np.testing.assert_allclose(got, 2.5 * x[3], rtol=1e-6)
