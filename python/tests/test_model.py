"""Layer-2 model tests: the fused hinge_value_grad graph vs oracle and
finite differences, plus padding semantics the Rust runtime relies on."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.matvec import BLOCK_N, BLOCK_P

RNG = np.random.default_rng
TN, TP = 2 * BLOCK_N, BLOCK_P


def setup(seed, tn=TN, tp=TP, live_n=None):
    r = RNG(seed)
    x = (r.standard_normal((tn, tp)) * 0.3).astype(np.float32)
    y = np.where(r.standard_normal(tn) > 0, 1.0, -1.0).astype(np.float32)
    if live_n is not None:
        # zero-pad rows beyond live_n (the runtime's padding contract)
        x[live_n:] = 0.0
        y[live_n:] = 0.0
    beta = (r.standard_normal(tp) * 0.1).astype(np.float32)
    beta0 = np.array([0.3], np.float32)
    tau = np.array([0.2], np.float32)
    return x, y, beta, beta0, tau


def test_fused_grad_matches_oracle():
    x, y, beta, beta0, tau = setup(0)
    val, gb, g0 = model.hinge_value_grad(x, y, beta, beta0, tau)
    vr, gbr, g0r = ref.smoothed_hinge_value_grad_ref(x, y, beta, beta0[0], tau[0])
    np.testing.assert_allclose(float(val), float(vr), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gbr), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(g0), float(g0r), rtol=1e-4, atol=1e-5)


def test_fused_grad_finite_differences():
    x, y, beta, beta0, tau = setup(1)
    val, gb, g0 = model.hinge_value_grad(x, y, beta, beta0, tau)
    h = 1e-3  # f32: use a relatively large step
    for j in [0, 7, TP - 1]:
        bp = beta.copy()
        bp[j] += h
        vp, _, _ = model.hinge_value_grad(x, y, bp, beta0, tau)
        fd = (float(vp) - float(val)) / h
        assert abs(fd - float(gb[j])) < 5e-2, (j, fd, float(gb[j]))
    b0p = beta0 + h
    vp, _, _ = model.hinge_value_grad(x, y, beta, b0p, tau)
    fd0 = (float(vp) - float(val)) / h
    assert abs(fd0 - float(g0)) < 5e-2


@settings(max_examples=10, deadline=None)
@given(live_n=st.integers(1, TN), seed=st.integers(0, 10_000))
def test_padded_rows_contribute_nothing(live_n, seed):
    """The Rust runtime pads n up to the tile height with x = 0, y = 0;
    value and gradients must equal the unpadded computation."""
    x, y, beta, beta0, tau = setup(seed, live_n=live_n)
    val, gb, g0 = model.hinge_value_grad(x, y, beta, beta0, tau)
    # oracle on the live slice only
    vr, gbr, g0r = ref.smoothed_hinge_value_grad_ref(
        x[:live_n], y[:live_n], beta, beta0[0], tau[0]
    )
    np.testing.assert_allclose(float(val), float(vr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gbr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(g0), float(g0r), rtol=1e-4, atol=1e-4)


def test_pricing_is_xt_y_pi():
    x, y, _, _, _ = setup(3)
    pi = RNG(4).uniform(0, 1, TN).astype(np.float32)
    q = model.pricing(x, y, pi)
    want = ref.xtv_ref(x, y * pi)
    np.testing.assert_allclose(np.asarray(q), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_margins_offset():
    x, _, beta, beta0, _ = setup(5)
    m = model.margins(x, beta, beta0)
    want = ref.xb_ref(x, beta) + beta0[0]
    np.testing.assert_allclose(np.asarray(m), np.asarray(want), rtol=1e-5, atol=1e-5)
