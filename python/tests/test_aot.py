"""AOT lowering tests: artifacts are valid HLO text with the expected
parameter shapes, and lowering is deterministic."""

import json

from compile import aot


def test_lower_all_produces_hlo_text():
    arts = aot.lower_all(tn=256, tp=256)
    assert set(arts) == {"xtv", "xb", "hinge_terms", "hinge_grad"}
    for name, text in arts.items():
        assert "HloModule" in text, name
        assert "ENTRY" in text, name
        # interpret-mode pallas must lower to plain HLO: no Mosaic
        # custom-calls the CPU PJRT client can't run.
        assert "mosaic" not in text.lower(), name


def test_artifact_shapes_in_text():
    arts = aot.lower_all(tn=256, tp=256)
    assert "f32[256,256]" in arts["xtv"]
    assert "f32[256]" in arts["xtv"]
    assert "f32[256,256]" in arts["hinge_grad"]


def test_lowering_deterministic():
    a = aot.lower_all(tn=128, tp=256)
    b = aot.lower_all(tn=128, tp=256)
    assert a.keys() == b.keys()
    for k in a:
        assert a[k] == b[k], f"{k} not deterministic"


def test_main_writes_manifest(tmp_path):
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--tn", "128", "--tp", "256"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    meta = json.loads((tmp_path / "meta.json").read_text())
    assert meta["tn"] == 128
    assert meta["tp"] == 256
    for fname in meta["artifacts"].values():
        text = (tmp_path / fname).read_text()
        assert "HloModule" in text
