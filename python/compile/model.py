"""Layer-2 JAX model: the Nesterov-smoothed hinge compute graph.

This is the paper's §4.1 objective written as a jax function that calls
the Layer-1 Pallas kernels, so that a single ``jax.jit(...).lower(...)``
produces one HLO module containing the whole gradient evaluation. The
Rust coordinator loads the lowered artifacts and never imports Python.

Two granularities are exported by ``aot.py``:

* the three *tile* kernels (``xtv``/``xb``/``hinge_terms``) at a fixed
  tile shape — the Rust runtime pads and loops tiles, so one artifact
  serves every (n, p);
* the *fused* ``hinge_value_grad`` at a fixed model shape — one
  round-trip computes value + full gradient (used by the quickstart
  demo and the runtime integration test).
"""

import jax
import jax.numpy as jnp

from .kernels import hinge_terms, xb, xtv


def hinge_value_grad(x, y, beta, beta0, tau):
    """Smoothed-hinge value and gradient, all Pallas-kernel powered.

    Args:
      x: (N, P) f32 design tile (padded rows/cols must be zero).
      y: (N,) f32 labels in {-1, +1} (0 on padded rows).
      beta: (P,) f32 coefficients.
      beta0: (1,) f32 intercept.
      tau: (1,) f32 smoothing parameter.

    Returns:
      (value ()), grad_beta (P,), grad_beta0 ()) — note padded rows
      contribute 0 to every output because y = 0 there makes z = 1,
      w = clip(1/2tau) ... NOT zero; padding correctness is instead
      guaranteed by masking below.
    """
    margins = xb(x, beta)
    z = 1.0 - y * (margins + beta0[0])
    v, f = hinge_terms(z, y, tau)
    # mask out padded rows (y == 0): their v and f must not contribute.
    live = (y != 0.0).astype(jnp.float32)
    v = v * live
    f = f * live
    value = jnp.sum(f)
    grad_beta = -xtv(x, v)
    grad_beta0 = -jnp.sum(v)
    return value, grad_beta, grad_beta0


def pricing(x, y, pi):
    """Column pricing q = X^T (y ∘ π) for one tile (eq. 14's hot product)."""
    return xtv(x, y * pi)


def margins(x, beta, beta0):
    """Margins Xβ + β₀ for one tile."""
    return xb(x, beta) + beta0[0]
