"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Everything here is deliberately the most obvious possible implementation;
pytest (with hypothesis sweeps) asserts the Pallas kernels match these to
float32 tolerance, and the Rust runtime's integration tests compare the
PJRT execution of the lowered HLO against the same values.
"""

import jax.numpy as jnp


def xtv_ref(x, v):
    """X^T v."""
    return jnp.asarray(x, jnp.float32).T @ jnp.asarray(v, jnp.float32)


def xb_ref(x, beta):
    """X beta."""
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(beta, jnp.float32)


def hinge_terms_ref(z, y, tau):
    """Smoothed-hinge weights and per-sample values (see paper §4.1)."""
    z = jnp.asarray(z, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    tau = jnp.float32(tau)
    w = jnp.clip(z / (2.0 * tau), -1.0, 1.0)
    v = 0.5 * y * (1.0 + w)
    f = 0.5 * z * (1.0 + w) - 0.5 * tau * w * w
    return v, f


def smoothed_hinge_value_grad_ref(x, y, beta, beta0, tau):
    """Full smoothed-hinge objective value and gradient (L2 oracle)."""
    z = 1.0 - y * (xb_ref(x, beta) + beta0)
    v, f = hinge_terms_ref(z, y, tau)
    value = f.sum()
    grad_beta = -xtv_ref(x, v)
    grad_beta0 = -v.sum()
    return value, grad_beta, grad_beta0


def hinge_loss_ref(x, y, beta, beta0):
    """Exact (non-smoothed) hinge loss."""
    z = 1.0 - y * (x @ beta + beta0)
    return jnp.maximum(z, 0.0).sum()
