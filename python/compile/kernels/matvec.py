"""Pallas tile kernels for the cutting-plane hot paths.

TPU-style structure even though correctness runs under ``interpret=True``
on CPU: block shapes are multiples of 128 (MXU/VPU lanes), each grid step
streams one X block HBM->VMEM and reduces it against a resident vector.
The default artifact tile is ``(TN, TP) = (512, 2048)`` f32 = 4 MiB, well
inside a TPU core's ~16 MiB VMEM with room for double buffering.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes inside one artifact tile (lane-aligned).
BLOCK_P = 256
BLOCK_N = 128


def _xtv_kernel(v_ref, x_ref, o_ref):
    """One output block of q = X^T v: o[bp] = v . X[:, block]."""
    o_ref[...] = jnp.dot(
        v_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


def xtv(x: jax.Array, v: jax.Array) -> jax.Array:
    """``X^T v`` for one resident tile ``x`` of shape (TN, TP).

    Grid over column blocks: each program loads an (TN, BLOCK_P) slab of X
    into VMEM and contracts it against the resident v (TN,).
    """
    tn, tp = x.shape
    assert v.shape == (tn,)
    assert tp % BLOCK_P == 0, f"tile width {tp} must be a multiple of {BLOCK_P}"
    grid = (tp // BLOCK_P,)
    return pl.pallas_call(
        _xtv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn,), lambda j: (0,)),
            pl.BlockSpec((tn, BLOCK_P), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_P,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((tp,), jnp.float32),
        interpret=True,
    )(v, x)


def _xb_kernel(b_ref, x_ref, o_ref):
    """One output block of m = X b: o[bn] = X[block, :] . b."""
    o_ref[...] = jnp.dot(
        x_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def xb(x: jax.Array, beta: jax.Array) -> jax.Array:
    """``X beta`` for one resident tile ``x`` of shape (TN, TP)."""
    tn, tp = x.shape
    assert beta.shape == (tp,)
    assert tn % BLOCK_N == 0, f"tile height {tn} must be a multiple of {BLOCK_N}"
    grid = (tn // BLOCK_N,)
    return pl.pallas_call(
        _xb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tp,), lambda i: (0,)),
            pl.BlockSpec((BLOCK_N, tp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((tn,), jnp.float32),
        interpret=True,
    )(beta, x)


def _hinge_kernel(z_ref, y_ref, tau_ref, v_ref, f_ref):
    """Fused smoothed-hinge elementwise pass.

    Given margins z = 1 - y(x^T beta + beta0):
      w  = clip(z / 2tau, -1, 1)
      v  = y (1 + w) / 2                (the X^T v gradient weights)
      f  = z (1 + w)/2 - tau w^2 / 2    (per-sample smoothed loss)
    """
    z = z_ref[...]
    y = y_ref[...]
    tau = tau_ref[0]
    w = jnp.clip(z / (2.0 * tau), -1.0, 1.0)
    v_ref[...] = 0.5 * y * (1.0 + w)
    f_ref[...] = 0.5 * z * (1.0 + w) - 0.5 * tau * w * w


def hinge_terms(z: jax.Array, y: jax.Array, tau: jax.Array):
    """Smoothed-hinge weights and per-sample values for one tile.

    ``tau`` is a shape-(1,) f32 array so the same artifact serves every
    smoothing level. Returns ``(v, f)`` with the caller summing ``f`` and
    feeding ``v`` into :func:`xtv`.
    """
    (tn,) = z.shape
    assert y.shape == (tn,)
    assert tn % BLOCK_N == 0
    grid = (tn // BLOCK_N,)
    return pl.pallas_call(
        _hinge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tn,), jnp.float32),
            jax.ShapeDtypeStruct((tn,), jnp.float32),
        ],
        interpret=True,
    )(z, y, tau)


@partial(jax.jit, static_argnames=())
def pricing_tile(x, yv):
    """Convenience jit: q-tile = X^T (y*pi) for one tile (used by tests)."""
    return xtv(x, yv)
