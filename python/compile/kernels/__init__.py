"""Layer-1 Pallas kernels (build-time only; AOT-lowered to HLO text).

The cutting-plane hot spots are two matvecs against the design matrix and
one fused elementwise pass for the Nesterov-smoothed hinge:

* ``xtv``   — Xᵀv  (pricing / reduced costs, gradient accumulation)
* ``xb``    — Xβ   (margins)
* ``hinge_terms`` — smoothed-hinge weights + per-sample values

All kernels run in ``interpret=True`` mode so the lowered HLO executes on
the CPU PJRT client that the Rust runtime drives (real-TPU Mosaic
custom-calls are not loadable there; see DESIGN.md §Hardware-Adaptation).
"""

from .matvec import xb, xtv, hinge_terms  # noqa: F401
from . import ref  # noqa: F401
