"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits (tile shape TN x TP, f32):

    xtv.hlo.txt          (x[TN,TP], v[TN])            -> (q[TP],)
    xb.hlo.txt           (x[TN,TP], beta[TP])         -> (m[TN],)
    hinge_terms.hlo.txt  (z[TN], y[TN], tau[1])       -> (v[TN], f[TN])
    hinge_grad.hlo.txt   (x, y, beta, beta0[1], tau[1])
                         -> (value[], grad_beta[TP], grad_b0[])
    meta.json            tile shape + artifact manifest
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import hinge_terms, xb, xtv

# Default artifact tile: 512 x 2048 f32 = 4 MiB resident slab.
TN = 512
TP = 2048


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_all(tn: int = TN, tp: int = TP):
    """Lower every artifact; returns {name: hlo_text}."""
    out = {}
    out["xtv"] = to_hlo_text(
        jax.jit(lambda x, v: (xtv(x, v),)).lower(_spec((tn, tp)), _spec((tn,)))
    )
    out["xb"] = to_hlo_text(
        jax.jit(lambda x, b: (xb(x, b),)).lower(_spec((tn, tp)), _spec((tp,)))
    )
    out["hinge_terms"] = to_hlo_text(
        jax.jit(lambda z, y, tau: hinge_terms(z, y, tau)).lower(
            _spec((tn,)), _spec((tn,)), _spec((1,))
        )
    )
    out["hinge_grad"] = to_hlo_text(
        jax.jit(model.hinge_value_grad).lower(
            _spec((tn, tp)), _spec((tn,)), _spec((tp,)), _spec((1,)), _spec((1,))
        )
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tn", type=int, default=TN)
    ap.add_argument("--tp", type=int, default=TP)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    arts = lower_all(args.tn, args.tp)
    manifest = {"tn": args.tn, "tp": args.tp, "artifacts": {}}
    for name, text in arts.items():
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = fname
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'meta.json')}")


if __name__ == "__main__":
    main()
