//! Bench target regenerating the paper's fig1 (see rust/src/exps/fig1.rs).
//! Usage: cargo bench --bench fig1_fixed_lambda [-- smoke|default|paper]
use cutgen::exps::{run_experiment, Scale};

fn main() {
    let scale = std::env::args()
        .skip(1)
        .find_map(|a| Scale::parse(&a))
        .unwrap_or(Scale::Default);
    println!("=== fig1 (scale {scale:?}) ===");
    run_experiment("fig1", scale).expect("known experiment id");
}
