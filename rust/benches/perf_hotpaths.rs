//! Micro-benchmarks of the hot paths (hand-rolled harness; the offline
//! image carries no criterion). Reports ns/op and effective GFLOP/s —
//! these numbers feed EXPERIMENTS.md §Perf, and the serial-vs-parallel
//! pricing section tracks the engine's threaded `Xᵀv` chunking.
//!
//! Usage: cargo bench --bench perf_hotpaths [-- smoke] [-- json]
//!
//! With `json`, results are also written to `BENCH_hotpaths.json` in the
//! working directory, so the perf trajectory is machine-readable across
//! PRs.

use std::hint::black_box;
use std::time::Instant;

use cutgen::backend::{Backend, NativeBackend};
use cutgen::data::synthetic::{generate_l1, generate_sparse_text, SparseTextSpec, SyntheticSpec};
use cutgen::data::Design;
use cutgen::engine::{BackendPricer, Pricer};
use cutgen::fom::prox::prox_slope;
use cutgen::linalg::{dot, Lu, Matrix};
use cutgen::rng::Xoshiro256;

/// Pre-tiling scalar reference `out = Aᵀv` — what `Matrix::tmatvec` was
/// before the register-tiled row-blocked sweep; kept here so the bench
/// can report "dense xtv tiled" against "dense xtv scalar" on the same
/// matrix.
fn scalar_tmatvec(m: &Matrix, v: &[f64], out: &mut [f64]) {
    out.fill(0.0);
    for i in 0..m.rows() {
        let vi = v[i];
        if vi == 0.0 {
            continue;
        }
        for (o, x) in out.iter_mut().zip(m.row(i)) {
            *o += vi * *x;
        }
    }
}

/// One measured result.
struct Record {
    name: String,
    us_per_op: f64,
    gflops: f64,
}

/// Time `f` adaptively: warm up, then run enough iterations for ≥0.2 s.
fn bench(records: &mut Vec<Record>, name: &str, flops_per_op: f64, mut f: impl FnMut()) {
    // warmup
    for _ in 0..3 {
        f();
    }
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        if dt > 0.2 || iters > 1 << 22 {
            let per_op = dt / iters as f64;
            let gflops = flops_per_op / per_op / 1e9;
            println!(
                "{name:<42} {:>12.2} us/op {:>9.2} GFLOP/s",
                per_op * 1e6,
                gflops
            );
            records.push(Record {
                name: name.to_string(),
                us_per_op: per_op * 1e6,
                gflops,
            });
            return;
        }
        iters = ((0.25 / dt.max(1e-9)) as u64).max(iters * 2);
    }
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn write_json(records: &[Record], mode: &str, note: &str) {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"bench\": \"perf_hotpaths\",\n  \"mode\": \"{mode}\",\n"));
    out.push_str(&format!("  \"note\": \"{}\",\n", json_escape(note)));
    out.push_str("  \"results\": [\n");
    for (k, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"us_per_op\": {:.3}, \"gflops\": {:.4}}}{}\n",
            json_escape(&r.name),
            r.us_per_op,
            r.gflops,
            if k + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write("BENCH_hotpaths.json", &out) {
        Ok(()) => println!("wrote BENCH_hotpaths.json ({} results)", records.len()),
        Err(e) => eprintln!("could not write BENCH_hotpaths.json: {e}"),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "smoke");
    let json = std::env::args().any(|a| a == "json");
    let mut rng = Xoshiro256::seed_from_u64(42);
    let mut recs: Vec<Record> = Vec::new();
    println!("--- perf_hotpaths ({}) ---", if smoke { "smoke" } else { "default" });

    // 1. dot product
    let n = if smoke { 4096 } else { 65536 };
    let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    bench(&mut recs, &format!("dot f64 n={n}"), 2.0 * n as f64, || {
        black_box(dot(black_box(&a), black_box(&b)));
    });

    // 2. dense Xᵀv / Xβ (the pricing hot path)
    let (dn, dp) = if smoke { (200, 2000) } else { (1000, 20_000) };
    let ds = generate_l1(&SyntheticSpec::paper_default(dn, dp), &mut rng);
    let backend = NativeBackend::new(&ds.x);
    let v: Vec<f64> = (0..dn).map(|_| rng.uniform()).collect();
    let mut q = vec![0.0; dp];
    bench(&mut recs, &format!("dense xtv {dn}x{dp} (pricing)"), 2.0 * (dn * dp) as f64, || {
        backend.xtv(black_box(&v), black_box(&mut q));
    });
    let beta: Vec<f64> = (0..dp).map(|_| rng.normal() * 0.01).collect();
    let mut m = vec![0.0; dn];
    bench(&mut recs, &format!("dense xb {dn}x{dp} (margins)"), 2.0 * (dn * dp) as f64, || {
        backend.xb(black_box(&beta), black_box(&mut m));
    });

    // 2a. register-tiled vs scalar dense Xᵀv on the same matrix — the
    // tile is what `Matrix::tmatvec` now runs; the scalar loop is the
    // pre-tiling baseline kept above for comparison.
    if let Design::Dense(dm) = &ds.x {
        bench(&mut recs, &format!("dense xtv tiled {dn}x{dp}"), 2.0 * (dn * dp) as f64, || {
            dm.tmatvec(black_box(&v), black_box(&mut q));
        });
        bench(&mut recs, &format!("dense xtv scalar {dn}x{dp}"), 2.0 * (dn * dp) as f64, || {
            scalar_tmatvec(black_box(dm), black_box(&v), black_box(&mut q));
        });
    }

    // 2b. serial vs parallel pricing through the engine's BackendPricer —
    // n·p = 4M (smoke: 0.4M) and 20M, the sizes the engine refactor targets.
    for (pn, pp) in if smoke { vec![(200, 2000)] } else { vec![(200, 20_000), (1000, 20_000)] } {
        let pds = generate_l1(&SyntheticSpec::paper_default(pn, pp), &mut rng);
        let pbackend = NativeBackend::new(&pds.x);
        let pv: Vec<f64> = (0..pn).map(|_| rng.uniform()).collect();
        let mut pq = vec![0.0; pp];
        for threads in [1usize, 2, 4] {
            let pricer = BackendPricer::new(&pbackend, threads);
            bench(
                &mut recs,
                &format!("pricing xtv {pn}x{pp} threads={threads}"),
                2.0 * (pn * pp) as f64,
                || {
                    pricer.score(black_box(&pv), black_box(&mut pq));
                },
            );
        }
    }

    // 2c. §4 initialization strategies + FOM-vs-screening cold solves —
    // the engine Initializer layer: seed cost alone, then the end-to-end
    // cold solve it unlocks (seed + column generation).
    {
        use cutgen::coordinator::l1svm::column_generation;
        use cutgen::coordinator::GenParams;
        use cutgen::engine::{InitStrategy, Initializer};

        let (inn, inp) = if smoke { (80, 800) } else { (200, 4000) };
        let ids = generate_l1(&SyntheticSpec::paper_default(inn, inp), &mut rng);
        let ibackend = NativeBackend::new(&ids.x);
        let ilam = 0.05 * ids.lambda_max_l1();
        for strat in [InitStrategy::Screening, InitStrategy::Fista] {
            let ini = Initializer::new(strat, 10);
            bench(
                &mut recs,
                &format!("init {} n={inn} p={inp}", strat.as_str()),
                0.0,
                || {
                    black_box(ini.seed_l1(&ids, &ibackend, ilam).ws.len());
                },
            );
        }
        // subsample-and-average on a large-n draw (§4.4.2)
        let (sn2, sp2) = if smoke { (2000, 20) } else { (12_000, 40) };
        let sds2 = generate_l1(&SyntheticSpec::paper_default(sn2, sp2), &mut rng);
        let sbackend2 = NativeBackend::new(&sds2.x);
        let slam2 = 0.02 * sds2.lambda_max_l1();
        let sub_ini = Initializer::new(InitStrategy::Subsample, 10);
        bench(&mut recs, &format!("init subsample n={sn2} p={sp2}"), 0.0, || {
            black_box(sub_ini.seed_l1(&sds2, &sbackend2, slam2).ws.len());
        });
        // cold solve: screening seed vs FOM seed, end to end
        for strat in [InitStrategy::Screening, InitStrategy::Fista] {
            let ini = Initializer::new(strat, 10);
            bench(
                &mut recs,
                &format!("cold solve {} n={inn} p={inp}", strat.as_str()),
                0.0,
                || {
                    let seed = ini.seed_l1(&ids, &ibackend, ilam);
                    let sol = column_generation(
                        &ids,
                        &ibackend,
                        ilam,
                        &seed.ws.cols,
                        &GenParams::default(),
                    );
                    black_box(sol.objective);
                },
            );
        }
    }

    // 3. sparse pricing on power-law text data. The design really is
    // CSR+CSC (generate_sparse_text builds Design::Sparse); the threaded
    // rows are the engine's nnz-balanced chunked pricing — per-column
    // reduction order is fixed, so any thread count is bit-identical.
    let spec = SparseTextSpec {
        n: if smoke { 2000 } else { 20_000 },
        p: if smoke { 5000 } else { 40_000 },
        density: 0.002,
        k0: 50,
        zipf: 1.1,
    };
    let sds = generate_sparse_text(&spec, &mut rng);
    assert!(sds.x.is_sparse(), "sparse bench section must run on a CSC/CSR design");
    let sbackend = NativeBackend::new(&sds.x);
    let sv: Vec<f64> = (0..sds.n()).map(|_| rng.uniform()).collect();
    let mut sq = vec![0.0; sds.p()];
    bench(
        &mut recs,
        &format!("sparse xtv {}x{} nnz={}", sds.n(), sds.p(), sds.x.nnz()),
        2.0 * sds.x.nnz() as f64,
        || {
            sbackend.xtv(black_box(&sv), black_box(&mut sq));
        },
    );
    for threads in [1usize, 4] {
        let pricer = BackendPricer::new(&sbackend, threads);
        bench(
            &mut recs,
            &format!("sparse xtv nnz-balanced threads={threads} nnz={}", sds.x.nnz()),
            2.0 * sds.x.nnz() as f64,
            || {
                pricer.score(black_box(&sv), black_box(&mut sq));
            },
        );
    }

    // 3a. dense vs sparse at the same shape — the layout-speedup claim.
    // A smaller draw so the dense twin stays reasonable (to_dense is
    // n·p·8 bytes), and an explicit agreement check: the two layouts
    // reduce in different orders, so they agree to ~1e-12, not bitwise.
    let agree_note: String = {
        let tspec = SparseTextSpec {
            n: if smoke { 400 } else { 2000 },
            p: if smoke { 2000 } else { 10_000 },
            density: 0.005,
            k0: 20,
            zipf: 1.1,
        };
        let tds = generate_sparse_text(&tspec, &mut rng);
        let (tn, tp) = (tds.n(), tds.p());
        let dense_twin = match &tds.x {
            Design::Sparse { csr, .. } => Design::Dense(csr.to_dense()),
            Design::Dense(_) => unreachable!("generate_sparse_text builds a sparse design"),
        };
        let sb = NativeBackend::new(&tds.x);
        let db = NativeBackend::new(&dense_twin);
        let tv: Vec<f64> = (0..tn).map(|_| rng.uniform()).collect();
        let mut qs = vec![0.0; tp];
        let mut qd = vec![0.0; tp];
        sb.xtv(&tv, &mut qs);
        db.xtv(&tv, &mut qd);
        let max_delta = qs
            .iter()
            .zip(&qd)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(
            max_delta <= 1e-12,
            "dense and sparse xtv disagree: max |delta| = {max_delta:e}"
        );
        let before = recs.len();
        bench(
            &mut recs,
            &format!("sparse xtv same-shape {tn}x{tp} nnz={}", tds.x.nnz()),
            2.0 * tds.x.nnz() as f64,
            || {
                sb.xtv(black_box(&tv), black_box(&mut qs));
            },
        );
        bench(
            &mut recs,
            &format!("dense xtv same-shape {tn}x{tp}"),
            2.0 * (tn * tp) as f64,
            || {
                db.xtv(black_box(&tv), black_box(&mut qd));
            },
        );
        let speedup = recs[before + 1].us_per_op / recs[before].us_per_op;
        println!(
            "    sparse is {speedup:.1}x faster than dense at {tn}x{tp} \
             (density {:.4}, max |delta| {max_delta:.3e})",
            tds.x.nnz() as f64 / (tn * tp) as f64
        );
        format!(
            "dense and sparse xtv agree to <= 1e-12 at {tn}x{tp} \
             (measured max |delta| = {max_delta:.3e}); sparse/dense \
             same-shape speedup {speedup:.1}x"
        )
    };

    // 4. LU factorize + solves (the simplex basis kernel)
    for mdim in if smoke { vec![100] } else { vec![100, 400, 1000] } {
        let mut am = vec![0.0; mdim * mdim];
        for i in 0..mdim {
            for j in 0..mdim {
                am[i * mdim + j] = rng.normal() * 0.1;
            }
            am[i * mdim + i] += mdim as f64;
        }
        bench(
            &mut recs,
            &format!("LU factorize m={mdim}"),
            2.0 / 3.0 * (mdim as f64).powi(3),
            || {
                black_box(Lu::factorize_flat(mdim, black_box(&am)));
            },
        );
        let lu = Lu::factorize_flat(mdim, &am);
        let rhs: Vec<f64> = (0..mdim).map(|_| rng.normal()).collect();
        bench(&mut recs, &format!("FTRAN m={mdim}"), 2.0 * (mdim as f64).powi(2), || {
            let mut x = rhs.clone();
            lu.solve(&mut x);
            black_box(x);
        });
        bench(&mut recs, &format!("BTRAN m={mdim}"), 2.0 * (mdim as f64).powi(2), || {
            let mut x = rhs.clone();
            lu.solve_transposed(&mut x);
            black_box(x);
        });
    }

    // 5. Slope prox (PAVA) — the FOM inner loop for Table 6
    let pp = if smoke { 2000 } else { 50_000 };
    let eta: Vec<f64> = (0..pp).map(|_| rng.normal()).collect();
    let lams = cutgen::fom::objective::bh_slope_weights(pp, 0.1);
    bench(&mut recs, &format!("prox_slope (PAVA) p={pp}"), (pp as f64) * 20.0, || {
        black_box(prox_slope(black_box(&eta), &lams, 1.0));
    });

    // 6. workload pricing — the two estimators added on the GenEngine.
    // Dantzig: both channels are one chunked Xᵀv through BackendPricer
    // (rows: Xᵀ(y − Xβ); cols: XᵀXμ̄ via w = Σ μ_i x_i). RankSVM: the
    // row channel compared across pair representations — the enumerated
    // O(|P|) list scan vs the implicit O(n log n) sorted-order sweep, at
    // the ISSUE 5 acceptance sizes n = 2·10³ and n = 2·10⁴ (the
    // enumerated 2·10⁴ point materializes ~2·10⁸ pairs, ≈1.6 GB — the
    // regime the implicit representation exists to retire).
    {
        use cutgen::data::synthetic::{generate_dantzig, generate_ranksvm, DantzigSpec, RankSpec};
        use cutgen::engine::PairMode;
        use cutgen::workloads::dantzig::{initial_features, lambda_max_dantzig, RestrictedDantzig};
        use cutgen::workloads::pairset::PairSet;
        use cutgen::workloads::ranksvm::{initial_rank_features, lambda_max_rank, RestrictedRank};

        let (wn, wp) = if smoke { (100, 1000) } else { (400, 8000) };
        let dspec =
            DantzigSpec { n: wn, p: wp, k0: 10, rho: 0.1, sigma: 0.5, standardize: true };
        let dds = generate_dantzig(&dspec, &mut rng);
        let dbackend = NativeBackend::new(&dds.x);
        let dlam = 0.3 * lambda_max_dantzig(&dds);
        let mut rd = RestrictedDantzig::new(&dds, dlam, &initial_features(&dds, 10));
        rd.solve();
        for threads in [1usize, 4] {
            let pricer = BackendPricer::new(&dbackend, threads);
            bench(
                &mut recs,
                &format!("dantzig row pricing {wn}x{wp} threads={threads}"),
                2.0 * (wn * wp) as f64,
                || {
                    black_box(rd.price_constraints(&dds, &pricer, 1e-2));
                },
            );
        }

        let sizes: Vec<usize> = if smoke { vec![400] } else { vec![2000, 20_000] };
        for rn in sizes {
            let rp = 200;
            let rspec =
                RankSpec { n: rn, p: rp, k0: 10, rho: 0.1, noise: 0.3, standardize: true };
            let rds = generate_ranksvm(&rspec, &mut rng);
            for mode in [PairMode::Enumerate, PairMode::Implicit] {
                let pairs = PairSet::build(&rds.y, mode);
                let rlam = 0.05 * lambda_max_rank(&rds, &pairs);
                let mut rr = RestrictedRank::new(
                    &rds,
                    &pairs,
                    rlam,
                    &pairs.spread(10),
                    &initial_rank_features(&rds, &pairs, 10),
                );
                rr.solve();
                let flops = if pairs.is_enumerated() { 2.0 * pairs.len() as f64 } else { 0.0 };
                bench(
                    &mut recs,
                    &format!("ranksvm pair-scan {} n={rn} |P|={}", pairs.mode(), pairs.len()),
                    flops,
                    || {
                        black_box(rr.price_pairs(&rds, 1e-2));
                    },
                );
            }
        }

        // weighted pair channel: the bucketed O(n·L) sweep vs the
        // enumerated O(|P|) list walk, on a level-structured instance
        // (L = 8 relevance levels — the ranking-practice regime the
        // bucketed sweep exists for; see docs/ranksvm-scaling.md)
        {
            use cutgen::workloads::pairset::PairCosts;
            let wsizes: Vec<usize> = if smoke { vec![400] } else { vec![2000, 20_000] };
            for rn in wsizes {
                let wy: Vec<f64> = (0..rn).map(|i| ((i * 7 + 3) % 8) as f64).collect();
                let m: Vec<f64> = (0..rn).map(|_| rng.normal()).collect();
                for (mode, label) in
                    [(PairMode::Enumerate, "enumerated"), (PairMode::Implicit, "bucketed")]
                {
                    let pairs = PairSet::build(&wy, mode);
                    let costs = PairCosts::bucketed_by(&pairs, |a, b| {
                        (1.0 + 0.25 * (a - b) as f64, 1.5)
                    });
                    let flops =
                        if pairs.is_enumerated() { 3.0 * pairs.len() as f64 } else { 0.0 };
                    bench(
                        &mut recs,
                        &format!(
                            "ranksvm weighted pair-scan {label} n={rn} |P|={}",
                            pairs.len()
                        ),
                        flops,
                        || {
                            black_box(pairs.price_weighted(&m, 1e-2, &[], 256, 1, &costs));
                        },
                    );
                }
            }
        }
    }

    // 7. end-to-end column generation (small, fixed)
    let ds2 =
        generate_l1(&SyntheticSpec::paper_default(100, if smoke { 1000 } else { 5000 }), &mut rng);
    let lam = 0.01 * ds2.lambda_max_l1();
    let be2 = NativeBackend::new(&ds2.x);
    bench(&mut recs, "column_generation n=100 (end-to-end)", 0.0, || {
        let sol = cutgen::coordinator::l1svm::column_generation(
            &ds2,
            &be2,
            lam,
            &[0, 1],
            &cutgen::coordinator::GenParams::default(),
        );
        black_box(sol.objective);
    });

    // 8. end-to-end workload generation (small, fixed)
    {
        use cutgen::data::synthetic::{generate_dantzig, generate_ranksvm, DantzigSpec, RankSpec};
        use cutgen::engine::PairMode;
        use cutgen::workloads::dantzig::{dantzig_generation, lambda_max_dantzig};
        use cutgen::workloads::pairset::PairSet;
        use cutgen::workloads::ranksvm::{lambda_max_rank, ranksvm_generation};

        let dp = if smoke { 200 } else { 800 };
        let dspec = DantzigSpec { n: 60, p: dp, k0: 8, rho: 0.1, sigma: 0.5, standardize: true };
        let dds = generate_dantzig(&dspec, &mut rng);
        let dbe = NativeBackend::new(&dds.x);
        let dlam = 0.3 * lambda_max_dantzig(&dds);
        bench(&mut recs, &format!("dantzig ccg n=60 p={dp} (end-to-end)"), 0.0, || {
            let sol = dantzig_generation(
                &dds,
                &dbe,
                dlam,
                &[],
                &cutgen::coordinator::GenParams::default(),
            );
            black_box(sol.objective);
        });

        let rn = if smoke { 40 } else { 80 };
        let rspec = RankSpec { n: rn, p: 200, k0: 8, rho: 0.1, noise: 0.3, standardize: true };
        let rds = generate_ranksvm(&rspec, &mut rng);
        let rbe = NativeBackend::new(&rds.x);
        let pairs = PairSet::build(&rds.y, PairMode::Auto);
        let rlam = 0.05 * lambda_max_rank(&rds, &pairs);
        bench(
            &mut recs,
            &format!("ranksvm ccg n={rn} |P|={} (end-to-end)", pairs.len()),
            0.0,
            || {
                let sol = ranksvm_generation(
                    &rds,
                    &rbe,
                    &pairs,
                    rlam,
                    &[],
                    &[],
                    &cutgen::coordinator::GenParams::default(),
                );
                black_box(sol.objective);
            },
        );
    }

    // 9. serve: requests through the protocol handler — cold (cache off)
    // vs warm (cache primed, snapshot-seeded restricted model), then a
    // fixed 8-request batch drained by 1 vs 4 worker threads.
    {
        use cutgen::serve::ServeState;
        let state = ServeState::new(64);
        let (sn, sp) = if smoke { (40, 200) } else { (100, 2000) };
        let reg = format!(
            "{{\"op\":\"register\",\"name\":\"b\",\"synthetic\":\
             {{\"kind\":\"l1\",\"n\":{sn},\"p\":{sp},\"seed\":1}}}}"
        );
        assert!(state.handle_line(&reg).contains("\"ok\":true"), "bench dataset registration");
        let cold_req =
            r#"{"op":"solve","dataset":"b","workload":"l1svm","lambda_frac":0.05,"cache":false}"#;
        bench(&mut recs, &format!("serve solve cold n={sn} p={sp}"), 0.0, || {
            black_box(state.handle_line(cold_req));
        });
        let warm_req = r#"{"op":"solve","dataset":"b","workload":"l1svm","lambda_frac":0.05}"#;
        let primed = state.handle_line(warm_req); // prime the cache
        assert!(primed.contains("\"ok\":true"));
        bench(&mut recs, &format!("serve solve warm n={sn} p={sp}"), 0.0, || {
            black_box(state.handle_line(warm_req));
        });
        for workers in [1usize, 4] {
            bench(&mut recs, &format!("serve batch8 warm workers={workers}"), 0.0, || {
                std::thread::scope(|scope| {
                    for _ in 0..workers {
                        scope.spawn(|| {
                            for _ in 0..(8 / workers) {
                                black_box(state.handle_line(warm_req));
                            }
                        });
                    }
                });
            });
        }
    }

    // 10. instrumentation overhead — the engine always reads its span
    // clocks; the only optional cost is fanning each round event into a
    // TraceSink (what `"trace": true` turns on in serve, via a bounded
    // RingSink). sink=off is the default path for every solve, so the
    // pair brackets the full price of round tracing end to end.
    {
        use cutgen::coordinator::GenParams;
        use cutgen::obs::RingSink;
        use std::sync::Arc;

        let off = GenParams::default();
        let on = GenParams { sink: Some(Arc::new(RingSink::new(512))), ..GenParams::default() };
        let before = recs.len();
        for (tag, params) in [("off", &off), ("ring", &on)] {
            bench(&mut recs, &format!("engine solve sink={tag} n=100"), 0.0, || {
                let sol = cutgen::coordinator::l1svm::column_generation(
                    &ds2,
                    &be2,
                    lam,
                    &[0, 1],
                    params,
                );
                black_box(sol.objective);
            });
        }
        let base = recs[before].us_per_op;
        let traced = recs[before + 1].us_per_op;
        let overhead = (traced - base) / base * 100.0;
        println!(
            "    ring-sink tracing overhead {overhead:+.2}% \
             ({base:.1} -> {traced:.1} us/op)"
        );
        // emission is one struct copy per round: anything past 2% is a
        // regression. The absolute floor keeps smoke-mode timer noise on
        // a sub-millisecond solve from flaking the run.
        assert!(
            overhead <= 2.0 || traced - base <= 150.0,
            "ring-sink tracing costs {overhead:.2}% (> 2%) on the end-to-end solve"
        );
    }

    // 11. path exact vs warm-grid (l1svm, 50 points) — the parametric
    // ride prices the implicit column space only at basis-change
    // breakpoints, so over the same λ range a dense 50-point grid pays
    // for ≥ 50 pricing rounds where the exact path pays one per
    // breakpoint (plus expansions). Both drivers are run end to end on
    // the same draw; the printed round counts are the claim.
    {
        use cutgen::coordinator::path::{geometric_grid, regularization_path};
        use cutgen::coordinator::path_exact::l1svm_path_exact;
        use cutgen::coordinator::GenParams;

        let (xn, xp) = if smoke { (40, 200) } else { (100, 1000) };
        let xds = generate_l1(&SyntheticSpec::paper_default(xn, xp), &mut rng);
        let xbe = NativeBackend::new(&xds.x);
        let xlmax = xds.lambda_max_l1();
        let xparams = GenParams { eps: 1e-6, ..Default::default() };
        let ratio = 0.5f64.powf(1.0 / 49.0);
        let grid = geometric_grid(xlmax, 50, ratio);
        bench(&mut recs, &format!("path warm-grid (l1svm, 50 pts) n={xn} p={xp}"), 0.0, || {
            let (pts, _) = regularization_path(&xds, &xbe, &grid, &xparams);
            black_box(pts.len());
        });
        bench(&mut recs, &format!("path exact (l1svm, 50 pts range) n={xn} p={xp}"), 0.0, || {
            let path = l1svm_path_exact(&xds, &xbe, xlmax, 0.5 * xlmax, &xparams);
            black_box(path.points.len());
        });
        let (pts, _) = regularization_path(&xds, &xbe, &grid, &xparams);
        let grid_rounds = pts.last().map_or(0, |p| p.stats.rounds);
        let path = l1svm_path_exact(&xds, &xbe, xlmax, 0.5 * xlmax, &xparams);
        println!(
            "    path exact: {} breakpoints, {} pricing rounds vs warm-grid {} rounds \
             over 50 λ's (same range)",
            path.stats.breakpoints, path.stats.pricing_rounds, grid_rounds
        );
    }

    if json {
        write_json(&recs, if smoke { "smoke" } else { "default" }, &agree_note);
    }
    println!("--- done ---");
}
