//! Bench target regenerating the paper's fig4 (see rust/src/exps/fig4.rs).
//! Usage: cargo bench --bench fig4_group_svm [-- smoke|default|paper]
use cutgen::exps::{run_experiment, Scale};

fn main() {
    let scale = std::env::args()
        .skip(1)
        .find_map(|a| Scale::parse(&a))
        .unwrap_or(Scale::Default);
    println!("=== fig4 (scale {scale:?}) ===");
    run_experiment("fig4", scale).expect("known experiment id");
}
