//! Bench target regenerating the paper's table4 (see rust/src/exps/table4.rs).
//! Usage: cargo bench --bench table4_psm [-- smoke|default|paper]
use cutgen::exps::{run_experiment, Scale};

fn main() {
    let scale = std::env::args()
        .skip(1)
        .find_map(|a| Scale::parse(&a))
        .unwrap_or(Scale::Default);
    println!("=== table4 (scale {scale:?}) ===");
    run_experiment("table4", scale).expect("known experiment id");
}
