//! Bench target regenerating the paper's fig2 (see rust/src/exps/fig2.rs).
//! Usage: cargo bench --bench fig2_constraint_gen [-- smoke|default|paper]
use cutgen::exps::{run_experiment, Scale};

fn main() {
    let scale = std::env::args()
        .skip(1)
        .find_map(|a| Scale::parse(&a))
        .unwrap_or(Scale::Default);
    println!("=== fig2 (scale {scale:?}) ===");
    run_experiment("fig2", scale).expect("known experiment id");
}
