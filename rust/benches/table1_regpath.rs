//! Bench target regenerating the paper's table1 (see rust/src/exps/table1.rs).
//! Usage: cargo bench --bench table1_regpath [-- smoke|default|paper]
use cutgen::exps::{run_experiment, Scale};

fn main() {
    let scale = std::env::args()
        .skip(1)
        .find_map(|a| Scale::parse(&a))
        .unwrap_or(Scale::Default);
    println!("=== table1 (scale {scale:?}) ===");
    run_experiment("table1", scale).expect("known experiment id");
}
