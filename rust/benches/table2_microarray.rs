//! Bench target regenerating the paper's table2 (see rust/src/exps/table2.rs).
//! Usage: cargo bench --bench table2_microarray [-- smoke|default|paper]
use cutgen::exps::{run_experiment, Scale};

fn main() {
    let scale = std::env::args()
        .skip(1)
        .find_map(|a| Scale::parse(&a))
        .unwrap_or(Scale::Default);
    println!("=== table2 (scale {scale:?}) ===");
    run_experiment("table2", scale).expect("known experiment id");
}
