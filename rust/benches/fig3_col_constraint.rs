//! Bench target regenerating the paper's fig3 (see rust/src/exps/fig3.rs).
//! Usage: cargo bench --bench fig3_col_constraint [-- smoke|default|paper]
use cutgen::exps::{run_experiment, Scale};

fn main() {
    let scale = std::env::args()
        .skip(1)
        .find_map(|a| Scale::parse(&a))
        .unwrap_or(Scale::Default);
    println!("=== fig3 (scale {scale:?}) ===");
    run_experiment("fig3", scale).expect("known experiment id");
}
