//! Bench target regenerating the paper's table3 (see rust/src/exps/table3.rs).
//! Usage: cargo bench --bench table3_sparse_real [-- smoke|default|paper]
use cutgen::exps::{run_experiment, Scale};

fn main() {
    let scale = std::env::args()
        .skip(1)
        .find_map(|a| Scale::parse(&a))
        .unwrap_or(Scale::Default);
    println!("=== table3 (scale {scale:?}) ===");
    run_experiment("table3", scale).expect("known experiment id");
}
