//! Bench target regenerating the paper's table6 (see rust/src/exps/table6.rs).
//! Usage: cargo bench --bench table6_slope_distinct [-- smoke|default|paper]
use cutgen::exps::{run_experiment, Scale};

fn main() {
    let scale = std::env::args()
        .skip(1)
        .find_map(|a| Scale::parse(&a))
        .unwrap_or(Scale::Default);
    println!("=== table6 (scale {scale:?}) ===");
    run_experiment("table6", scale).expect("known experiment id");
}
