//! Bench target regenerating the paper's table5 (see rust/src/exps/table5.rs).
//! Usage: cargo bench --bench table5_slope_equal [-- smoke|default|paper]
use cutgen::exps::{run_experiment, Scale};

fn main() {
    let scale = std::env::args()
        .skip(1)
        .find_map(|a| Scale::parse(&a))
        .unwrap_or(Scale::Default);
    println!("=== table5 (scale {scale:?}) ===");
    run_experiment("table5", scale).expect("known experiment id");
}
