//! The unified cut-generation engine.
//!
//! The paper's three coordinators (L1-SVM Algorithms 1/3/4, Group-SVM
//! §2.4, Slope-SVM Algorithms 5–7) and the warm-started regularization
//! path (Algorithm 2) all instantiate one pattern: solve a *restricted*
//! LP, price the left-out columns/constraints through an O(np) matvec,
//! expand the working sets, repeat until no violation exceeds ε. This
//! module owns that pattern once:
//!
//! * [`RestrictedProblem`] — what the engine needs from a restricted LP:
//!   warm-started re-solve, objective/iteration introspection, pricing of
//!   left-out columns and rows, and working-set expansion;
//! * [`Pricer`] — scores all candidate columns from the restricted LP's
//!   duals (`q = Xᵀv`); [`BackendPricer`] is the standard implementation,
//!   chunking the matvec over `std::thread::scope` workers when
//!   [`GenParams::threads`] > 1;
//! * [`GenEngine`] — the solve → price → expand driver, with per-round
//!   instrumentation ([`GenParams::trace`]), a round cap, and stall
//!   detection ([`GenParams::stall_rounds`]);
//! * [`init`] — the §4 first-order initialization layer: an
//!   [`Initializer`] maps `(dataset, workload, λ, budget)` to a seed
//!   [`WorkingSet`] (plus an optional primal guess) via screening,
//!   smoothed-hinge FISTA, block CD, or subsample-and-average, selected
//!   by [`GenParams::init`].
//!
//! New LP workloads plug in by implementing [`RestrictedProblem`] —
//! roughly 200 lines of model bookkeeping instead of a forked generation
//! loop; `crate::workloads::{ranksvm, dantzig}` are worked examples, and
//! `docs/adding-a-workload.md` is the step-by-step guide.

#![warn(missing_docs)]

pub mod init;

pub use init::{InitStrategy, Initializer, Seed, DEFAULT_SEED_BUDGET};

use std::sync::Arc;

use crate::backend::{par_xtv, Backend};
use crate::bail;
use crate::error::Result;
use crate::obs::{RoundEvent, Span, StderrSink, TraceSink};
use crate::simplex::Status;

/// How RankSVM's comparison-pair channel represents its O(n²) implicit
/// candidate set (see `crate::workloads::pairset::PairSet`).
///
/// Pricing must be sublinear in the implicit constraint set for
/// generation to scale (the pair *scan*, not the restricted LP, is the
/// large-n bottleneck), so the pair channel has two interchangeable
/// representations sharing one canonical index space: a materialized
/// list for small candidate sets and exactness cross-checks, and a
/// sorted-order implicit form whose pricing sweep is O(n log n).
/// Workloads without a pair channel ignore this knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairMode {
    /// Enumerate while the candidate set stays below
    /// `crate::workloads::pairset::ENUM_PAIR_CAP` pairs; implicit beyond.
    Auto,
    /// Always materialize the pair list (tiny n, cross-checks).
    Enumerate,
    /// Always the implicit sorted-order representation (never allocates
    /// the O(n²) list; pricing is O(n log n) per round).
    Implicit,
}

impl PairMode {
    /// Parse a knob value (`auto|enumerate|implicit`).
    pub fn parse(name: &str) -> Result<PairMode> {
        Ok(match name {
            "auto" => PairMode::Auto,
            "enumerate" => PairMode::Enumerate,
            "implicit" => PairMode::Implicit,
            other => bail!("unknown pair mode {other:?} (auto|enumerate|implicit)"),
        })
    }

    /// Knob spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            PairMode::Auto => "auto",
            PairMode::Enumerate => "enumerate",
            PairMode::Implicit => "implicit",
        }
    }
}

/// Shared knobs for the generation loops.
#[derive(Clone, Debug)]
pub struct GenParams {
    /// Reduced-cost tolerance ε (paper: 1e-2).
    pub eps: f64,
    /// Maximum generation rounds (solve/price cycles).
    pub max_rounds: usize,
    /// Cap on columns added per round (0 = unlimited; Slope uses 10).
    pub max_cols_per_round: usize,
    /// Cap on constraints added per round (0 = unlimited).
    pub max_rows_per_round: usize,
    /// Worker threads for pricing matvecs (1 = serial). Results are
    /// identical for any thread count; see [`BackendPricer`].
    pub threads: usize,
    /// Abort after this many consecutive expanding rounds with an exactly
    /// unchanged restricted objective (0 = never). Protects against
    /// numerically stuck generation loops re-pricing the same cuts.
    pub stall_rounds: usize,
    /// How a cold solve seeds its initial working sets (§4): the drivers
    /// resolve [`InitStrategy::Auto`] to their per-workload default — a
    /// first-order method for fixed-λ solves, closed-form screening for
    /// the λ_max-anchored path drivers. See [`Initializer`].
    pub init: InitStrategy,
    /// Seed-size budget `k` for initial working sets — screening keeps
    /// the top-k reduced costs, FOM seeds keep the k largest surviving
    /// coefficients (default [`DEFAULT_SEED_BUDGET`]).
    pub seed_budget: usize,
    /// Representation of RankSVM's comparison-pair channel (CLI
    /// `--pair-mode`, serve `"pair_mode"`); other workloads ignore it.
    pub pair_mode: PairMode,
    /// Print one line per round to stderr.
    pub trace: bool,
    /// Optional structured sink receiving one typed [`RoundEvent`] per
    /// round plus terminal messages (stall/stop), independent of
    /// [`GenParams::trace`]'s stderr lines: a [`crate::obs::RingSink`]
    /// for serve's `"trace": true` responses, a
    /// [`crate::obs::JsonlSink`] for `--trace-json`.
    pub sink: Option<Arc<dyn TraceSink>>,
}

impl Default for GenParams {
    fn default() -> Self {
        Self {
            eps: 1e-2,
            max_rounds: 200,
            max_cols_per_round: 0,
            max_rows_per_round: 0,
            threads: 1,
            stall_rounds: 60,
            init: InitStrategy::Auto,
            seed_budget: DEFAULT_SEED_BUDGET,
            pair_mode: PairMode::Auto,
            trace: false,
            sink: None,
        }
    }
}

/// Target for the dynamic-λ controller (rank2plan's "dynamic
/// regularisation"): instead of a fixed λ, the caller names the ratio
/// `hinge(β) / ‖β‖₁` — total (weighted) slack over the L1 norm — it
/// wants the solution to sit at, and
/// `crate::coordinator::controller::resolve_lambda_for_ratio` bisects
/// λ in log-space until the achieved ratio lands within `tol` of it.
/// The ratio is monotone increasing in λ (more regularization shrinks
/// ‖β‖₁ and grows the slack), which is what makes bisection sound.
#[derive(Clone, Copy, Debug)]
pub struct RatioTarget {
    /// Desired `hinge / ‖β‖₁` ratio (must be finite and > 0).
    pub ratio: f64,
    /// Relative tolerance on the achieved ratio (default 0.1: accept
    /// within ±10% of the target).
    pub tol: f64,
    /// Cap on controller solves, bracket endpoints included (default
    /// 24 ≈ 22 bisection steps: λ resolved to ~1e-6 relative).
    pub max_solves: usize,
    /// Lower bracket endpoint as a fraction of λ_max (default 1e-4).
    /// The upper endpoint is λ_max itself, where β = 0 and the ratio
    /// is +∞.
    pub lo_frac: f64,
}

impl Default for RatioTarget {
    fn default() -> Self {
        Self { ratio: 1.0, tol: 0.1, max_solves: 24, lo_frac: 1e-4 }
    }
}

/// Progress counters common to all coordinators.
#[derive(Clone, Copy, Debug, Default)]
pub struct GenStats {
    /// Solve/price rounds executed.
    pub rounds: usize,
    /// Columns brought into the model.
    pub cols_added: usize,
    /// Constraints (rows or cuts) brought into the model.
    pub rows_added: usize,
    /// Total simplex iterations across re-solves.
    pub simplex_iters: usize,
    /// Terminated with no violation above ε (as opposed to hitting the
    /// round cap or stalling).
    pub converged: bool,
    /// Aborted by stall detection (see [`GenParams::stall_rounds`]).
    pub stalled: bool,
    /// Aborted by the caller's stop callback (see
    /// [`GenEngine::with_should_stop`]) — e.g. a serve-layer deadline or
    /// shutdown. The restricted solution of the last completed round is
    /// still feasible and its objective bounds the converged one.
    pub timed_out: bool,
    /// Wall-clock nanoseconds in restricted re-solves (the simplex
    /// share of the paper's time-breakdown tables).
    pub solve_ns: u64,
    /// Wall-clock nanoseconds pricing left-out rows and columns.
    pub pricing_ns: u64,
    /// Wall-clock nanoseconds in the [`Initializer`] seed phase —
    /// filled by the drivers that own seeding (coordinators, serve),
    /// not by [`GenEngine::run`] itself.
    pub seed_ns: u64,
    /// Which pair-scan strategy priced RankSVM's comparison channel
    /// (`"uniform"`, `"bucketed"`, `"enumerated-list"`,
    /// `"enumerated-per-pair"`; see
    /// `crate::workloads::pairset::PairScan`). Filled by the RankSVM
    /// drivers so callers can see *why* a weighted solve fell back to
    /// enumeration; `None` for workloads without a pair channel.
    pub pair_scan: Option<&'static str>,
}

/// A serializable snapshot of a restricted problem's working sets.
///
/// This is the unit the serve layer's warm-start cache stores: the
/// indices of every column and row currently in a restricted model,
/// cheap to export after a solve and restorable into a *fresh*
/// [`RestrictedProblem`] (via [`Snapshot::import_working_set`] or by
/// seeding the workload's constructor), so a solve at a nearby λ
/// resumes generation from a converged working set instead of starting
/// cold.
///
/// Index spaces are the workload's own: features for L1/Slope columns,
/// groups for Group-SVM, comparison-pair indices for RankSVM rows,
/// correlation-row features for the Dantzig selector. Slope's epigraph
/// cuts are *not* index-addressable (they are weight vectors generated
/// from incumbents), so its snapshot carries columns only and the cuts
/// regenerate in a few engine rounds.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WorkingSet {
    /// Column-channel indices, insertion order.
    pub cols: Vec<usize>,
    /// Row-channel indices, insertion order.
    pub rows: Vec<usize>,
}

impl WorkingSet {
    /// Whether both channels are empty.
    pub fn is_empty(&self) -> bool {
        self.cols.is_empty() && self.rows.is_empty()
    }

    /// Total number of indices across both channels.
    pub fn len(&self) -> usize {
        self.cols.len() + self.rows.len()
    }
}

/// Uniform working-set export/import for restricted problems.
///
/// Every workload adapter implements this next to [`RestrictedProblem`]:
/// `export_working_set` reads the current sets out of the model,
/// `import_working_set` unions a previously exported snapshot into the
/// model (indices already present are skipped — every workload's `add_*`
/// dedupes). Importing preserves whatever invariants the workload's own
/// expansion path maintains (e.g. the Dantzig `I ⊆ J` feasibility
/// invariant, because import routes through the same `add_*` methods the
/// engine uses).
pub trait Snapshot {
    /// Export the current working sets.
    fn export_working_set(&self) -> WorkingSet;
    /// Union a snapshot's working sets into this problem.
    fn import_working_set(&mut self, ws: &WorkingSet);
}

/// What the engine needs from a restricted LP.
///
/// `price_*` return `(index, violation)` pairs for every candidate whose
/// violation exceeds ε; the engine keeps the most-violated subset (per the
/// round caps) and hands the surviving indices back to `add_*`. The index
/// space is the implementation's own (features, samples, groups, or cuts).
pub trait RestrictedProblem {
    /// Re-solve the restricted LP (warm-started).
    fn solve(&mut self) -> Status;
    /// Objective of the last solve.
    fn objective(&self) -> f64;
    /// Cumulative simplex iterations (primal + dual) so far.
    fn simplex_iters(&self) -> usize;
    /// Price left-out rows/constraints/cuts.
    fn price_rows(&mut self, eps: f64) -> Vec<(usize, f64)>;
    /// Price left-out columns.
    fn price_cols(&mut self, eps: f64) -> Vec<(usize, f64)>;
    /// Bring the selected rows into the model.
    fn add_rows(&mut self, idx: &[usize]);
    /// Bring the selected columns into the model.
    fn add_cols(&mut self, idx: &[usize]);
    /// Current working-set size (columns + rows in the restricted
    /// model), reported in [`RoundEvent`]s. Defaults to 0 for adapters
    /// that don't track it.
    fn working_set_size(&self) -> usize {
        0
    }
    /// Move the problem to a new regularization value `λ` without
    /// discarding the basis, so the next [`RestrictedProblem::solve`]
    /// warm-resumes from the current vertex. The exact-path drivers in
    /// `crate::coordinator::path_exact` call this at every basis
    /// breakpoint before re-running the engine; workloads without a
    /// parametric cost/rhs structure keep the no-op default (the engine
    /// itself never calls it).
    fn reprice_at(&mut self, _lambda: f64) {}
}

/// Scores candidate columns from a dual-derived vector: `q = Xᵀv`.
///
/// Kept as a trait so workloads can swap in structured pricers (e.g. a
/// group-collapsed or screened scorer) without touching the coordinators.
pub trait Pricer {
    /// Number of candidate columns (length of `q`).
    fn cols(&self) -> usize;
    /// `q = Xᵀ v` over all candidates.
    fn score(&self, v: &[f64], q: &mut [f64]);
    /// Human-readable name for logs/benches.
    fn name(&self) -> &'static str {
        "pricer"
    }
}

/// The standard pricer: `Xᵀv` through a [`Backend`], chunked over column
/// ranges across `threads` scoped workers.
///
/// Determinism: every column's dot product accumulates over samples in
/// ascending row order regardless of the chunking, so the scores — and
/// therefore the selected working sets — are identical for any thread
/// count.
pub struct BackendPricer<'a> {
    backend: &'a dyn Backend,
    threads: usize,
}

impl<'a> BackendPricer<'a> {
    /// Wrap a backend with a worker count (clamped to ≥ 1).
    pub fn new(backend: &'a dyn Backend, threads: usize) -> Self {
        Self { backend, threads: threads.max(1) }
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }
}

impl Pricer for BackendPricer<'_> {
    fn cols(&self) -> usize {
        self.backend.cols()
    }

    fn score(&self, v: &[f64], q: &mut [f64]) {
        // the shared chunked kernel — also drives the FOM gradients, so
        // initialization and pricing stay on one hot path
        par_xtv(self.backend, self.threads, v, q);
    }

    fn name(&self) -> &'static str {
        "backend"
    }
}

/// The pricer for problems whose column channel is disabled (pure
/// constraint generation): zero candidates, never called.
pub struct NullPricer;

impl Pricer for NullPricer {
    fn cols(&self) -> usize {
        0
    }
    fn score(&self, _v: &[f64], _q: &mut [f64]) {}
    fn name(&self) -> &'static str {
        "null"
    }
}

/// Keep the `cap` most-violated entries (0 = unlimited) and return their
/// indices.
pub fn select_violators(mut priced: Vec<(usize, f64)>, cap: usize) -> Vec<usize> {
    if cap > 0 && priced.len() > cap {
        priced.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        priced.truncate(cap);
    }
    priced.into_iter().map(|(idx, _)| idx).collect()
}

/// The generic solve → price → expand driver.
///
/// # Example
///
/// Any [`RestrictedProblem`] can be driven to ε-optimality. The toy
/// problem below claims one violated column per round until three are in
/// the model, then reports clean pricing — the engine detects
/// convergence and counts the expansions:
///
/// ```
/// use cutgen::engine::{GenEngine, GenParams, RestrictedProblem};
/// use cutgen::simplex::Status;
///
/// struct Toy {
///     cols_in: usize,
/// }
///
/// impl RestrictedProblem for Toy {
///     fn solve(&mut self) -> Status {
///         Status::Optimal
///     }
///     fn objective(&self) -> f64 {
///         -(self.cols_in as f64)
///     }
///     fn simplex_iters(&self) -> usize {
///         self.cols_in
///     }
///     fn price_rows(&mut self, _eps: f64) -> Vec<(usize, f64)> {
///         Vec::new()
///     }
///     fn price_cols(&mut self, _eps: f64) -> Vec<(usize, f64)> {
///         if self.cols_in < 3 {
///             vec![(self.cols_in, 1.0)] // one violation left
///         } else {
///             Vec::new() // priced out: optimal
///         }
///     }
///     fn add_rows(&mut self, _idx: &[usize]) {}
///     fn add_cols(&mut self, idx: &[usize]) {
///         self.cols_in += idx.len();
///     }
/// }
///
/// let params = GenParams::default();
/// let mut prob = Toy { cols_in: 0 };
/// let stats = GenEngine::new(&params).run(&mut prob);
/// assert!(stats.converged);
/// assert_eq!(stats.cols_added, 3);
/// assert_eq!(stats.rounds, 4); // three expanding rounds + the clean one
/// ```
pub struct GenEngine<'p> {
    params: &'p GenParams,
    should_stop: Option<&'p dyn Fn() -> bool>,
}

impl<'p> GenEngine<'p> {
    /// Bind the engine to a parameter set.
    pub fn new(params: &'p GenParams) -> Self {
        Self { params, should_stop: None }
    }

    /// Install a cooperative stop callback, polled once per generation
    /// round *after* the restricted re-solve and *before* pricing. When it
    /// returns `true` the loop exits with [`GenStats::timed_out`] set and
    /// the problem left at the last completed round's optimal restricted
    /// solution — always primal-feasible for the full problem's restricted
    /// relaxation, with objective ≥ the fully converged one. At least one
    /// restricted solve always completes, so a caller with an
    /// already-expired deadline still gets a valid (seed-quality) answer.
    pub fn with_should_stop(mut self, f: &'p dyn Fn() -> bool) -> Self {
        self.should_stop = Some(f);
        self
    }

    /// Run the generation loop to ε-optimality (or the round cap / stall
    /// guard) and return the counters. `simplex_iters` in the result is
    /// the *delta* accumulated by this run, so callers can sum stats
    /// across several runs on one warm model (the regularization path).
    pub fn run(&self, prob: &mut dyn RestrictedProblem) -> GenStats {
        let p = self.params;
        // `--trace` keeps its historical stderr lines via the stderr
        // sink; a structured sink (ring, JSONL) rides along
        // independently. Both receive identical events.
        let stderr_sink = if p.trace { Some(StderrSink) } else { None };
        let emit_round = |ev: &RoundEvent| {
            if let Some(s) = &stderr_sink {
                s.round(ev);
            }
            if let Some(s) = &p.sink {
                s.round(ev);
            }
        };
        let emit_message = |text: &str| {
            if let Some(s) = &stderr_sink {
                s.message(text);
            }
            if let Some(s) = &p.sink {
                s.message(text);
            }
        };
        let iters0 = prob.simplex_iters();
        let mut stats = GenStats::default();
        let mut last_obj = f64::NAN;
        let mut stall = 0usize;
        for round in 0..p.max_rounds {
            stats.rounds += 1;
            let span = Span::start();
            let st = prob.solve();
            let solve_ns = span.elapsed_ns();
            stats.solve_ns += solve_ns;
            debug_assert_eq!(st, Status::Optimal, "restricted LP not optimal: {st:?}");
            let obj = prob.objective();
            // Deadline/cancellation: checked after the re-solve so the
            // model always holds a consistent optimal restricted solution
            // when we bail, and before pricing so an expired caller never
            // pays another O(np) scan.
            if let Some(stop) = self.should_stop {
                if stop() {
                    stats.timed_out = true;
                    emit_message(&format!("stopped by caller after round {}", round + 1));
                    break;
                }
            }
            let span = Span::start();
            let viol_rows = prob.price_rows(p.eps);
            let viol_cols = prob.price_cols(p.eps);
            let pricing_ns = span.elapsed_ns();
            stats.pricing_ns += pricing_ns;
            let mut ev = RoundEvent {
                round: round + 1,
                objective: obj,
                viol_rows: viol_rows.len(),
                viol_cols: viol_cols.len(),
                working_set: prob.working_set_size(),
                simplex_iters: prob.simplex_iters() - iters0,
                solve_ns,
                pricing_ns,
                ..RoundEvent::default()
            };
            if viol_rows.is_empty() && viol_cols.is_empty() {
                stats.converged = true;
                emit_round(&ev);
                break;
            }
            let add_rows = select_violators(viol_rows, p.max_rows_per_round);
            let add_cols = select_violators(viol_cols, p.max_cols_per_round);
            stats.rows_added += add_rows.len();
            stats.cols_added += add_cols.len();
            let span = Span::start();
            prob.add_rows(&add_rows);
            prob.add_cols(&add_cols);
            ev.expand_ns = span.elapsed_ns();
            ev.rows_added = add_rows.len();
            ev.cols_added = add_cols.len();
            ev.working_set = prob.working_set_size();
            emit_round(&ev);
            // Stall guard: the restricted objective is monotone under
            // expansion; many consecutive rounds with an exactly unchanged
            // objective while still generating means the loop is stuck.
            if obj == last_obj {
                stall += 1;
                if p.stall_rounds > 0 && stall >= p.stall_rounds {
                    stats.stalled = true;
                    emit_message(&format!("stalled after {stall} flat rounds"));
                    break;
                }
            } else {
                stall = 0;
            }
            last_obj = obj;
        }
        stats.simplex_iters = prob.simplex_iters() - iters0;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::synthetic::{generate_l1, generate_sparse_text, SparseTextSpec, SyntheticSpec};
    use crate::rng::Xoshiro256;

    #[test]
    fn parallel_pricing_matches_serial_bitwise() {
        let mut rng = Xoshiro256::seed_from_u64(311);
        let dense_spec = SyntheticSpec { n: 57, p: 203, k0: 5, rho: 0.2, standardize: true };
        let dense = generate_l1(&dense_spec, &mut rng);
        let sparse = generate_sparse_text(
            &SparseTextSpec { n: 120, p: 331, density: 0.05, k0: 10, zipf: 1.1 },
            &mut rng,
        );
        for ds in [&dense, &sparse] {
            let backend = NativeBackend::new(&ds.x);
            let v: Vec<f64> = (0..ds.n()).map(|_| rng.normal()).collect();
            let mut q1 = vec![0.0; ds.p()];
            BackendPricer::new(&backend, 1).score(&v, &mut q1);
            for t in [2, 3, 4, 7] {
                let mut qt = vec![0.0; ds.p()];
                let pricer = BackendPricer::new(&backend, t);
                assert_eq!(pricer.cols(), ds.p());
                pricer.score(&v, &mut qt);
                for j in 0..ds.p() {
                    assert_eq!(q1[j], qt[j], "q[{j}] differs at {t} threads");
                }
            }
        }
    }

    #[test]
    fn pricer_handles_more_threads_than_columns() {
        let mut rng = Xoshiro256::seed_from_u64(312);
        let spec = SyntheticSpec { n: 10, p: 3, k0: 2, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let backend = NativeBackend::new(&ds.x);
        let v: Vec<f64> = (0..ds.n()).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; 3];
        let mut b = vec![0.0; 3];
        BackendPricer::new(&backend, 1).score(&v, &mut a);
        BackendPricer::new(&backend, 16).score(&v, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn select_violators_keeps_most_violated() {
        let priced = vec![(0, 0.1), (1, 0.9), (2, 0.5), (3, 0.7)];
        let top2 = select_violators(priced.clone(), 2);
        assert_eq!(top2, vec![1, 3]);
        let all = select_violators(priced, 0);
        assert_eq!(all.len(), 4);
    }

    /// A tiny synthetic RestrictedProblem that stops improving: the stall
    /// guard must cut the loop short of the round cap.
    struct Flat {
        solves: usize,
    }
    impl RestrictedProblem for Flat {
        fn solve(&mut self) -> Status {
            self.solves += 1;
            Status::Optimal
        }
        fn objective(&self) -> f64 {
            1.0
        }
        fn simplex_iters(&self) -> usize {
            self.solves
        }
        fn price_rows(&mut self, _eps: f64) -> Vec<(usize, f64)> {
            Vec::new()
        }
        fn price_cols(&mut self, _eps: f64) -> Vec<(usize, f64)> {
            vec![(0, 1.0)] // always claims a violation
        }
        fn add_rows(&mut self, _idx: &[usize]) {}
        fn add_cols(&mut self, _idx: &[usize]) {}
    }

    #[test]
    fn stall_guard_breaks_flat_loops() {
        let params = GenParams { stall_rounds: 5, max_rounds: 1000, ..Default::default() };
        let mut prob = Flat { solves: 0 };
        let stats = GenEngine::new(&params).run(&mut prob);
        assert!(stats.stalled);
        assert!(!stats.converged);
        assert!(stats.rounds <= 7, "ran {} rounds", stats.rounds);
    }

    #[test]
    fn round_cap_is_respected() {
        let params = GenParams { stall_rounds: 0, max_rounds: 13, ..Default::default() };
        let mut prob = Flat { solves: 0 };
        let stats = GenEngine::new(&params).run(&mut prob);
        assert_eq!(stats.rounds, 13);
        assert!(!stats.converged);
        assert!(!stats.stalled);
    }

    /// A toy that converges after bringing three columns in, mirroring the
    /// module doctest — used to pin the stop-callback semantics.
    struct Grow {
        cols_in: usize,
    }
    impl RestrictedProblem for Grow {
        fn solve(&mut self) -> Status {
            Status::Optimal
        }
        fn objective(&self) -> f64 {
            -(self.cols_in as f64)
        }
        fn simplex_iters(&self) -> usize {
            self.cols_in
        }
        fn price_rows(&mut self, _eps: f64) -> Vec<(usize, f64)> {
            Vec::new()
        }
        fn price_cols(&mut self, _eps: f64) -> Vec<(usize, f64)> {
            if self.cols_in < 3 {
                vec![(self.cols_in, 1.0)]
            } else {
                Vec::new()
            }
        }
        fn add_rows(&mut self, _idx: &[usize]) {}
        fn add_cols(&mut self, idx: &[usize]) {
            self.cols_in += idx.len();
        }
    }

    #[test]
    fn expired_stop_callback_still_completes_one_solve() {
        let params = GenParams::default();
        let stop = || true; // deadline already expired at entry
        let mut prob = Grow { cols_in: 0 };
        let stats = GenEngine::new(&params).with_should_stop(&stop).run(&mut prob);
        assert!(stats.timed_out);
        assert!(!stats.converged);
        assert!(!stats.stalled);
        assert_eq!(stats.rounds, 1, "exactly one restricted solve must run");
        assert_eq!(stats.cols_added, 0, "stop fires before any expansion");
        // The restricted objective never undercuts the converged one
        // (column generation only decreases the objective as columns
        // enter): here 0.0 (no columns) vs -3.0 converged.
        let converged = GenEngine::new(&params).run(&mut Grow { cols_in: 0 });
        assert!(converged.converged);
        assert!(prob.objective() >= -3.0);
        assert!(!converged.timed_out);
    }

    #[test]
    fn generous_stop_callback_is_identical_to_none() {
        let params = GenParams::default();
        let stop = || false; // never fires
        let mut with_cb = Grow { cols_in: 0 };
        let s1 = GenEngine::new(&params).with_should_stop(&stop).run(&mut with_cb);
        let mut without = Grow { cols_in: 0 };
        let s2 = GenEngine::new(&params).run(&mut without);
        assert!(s1.converged && s2.converged);
        assert!(!s1.timed_out && !s2.timed_out);
        assert_eq!(s1.rounds, s2.rounds);
        assert_eq!(s1.cols_added, s2.cols_added);
        assert_eq!(with_cb.cols_in, without.cols_in);
    }

    #[test]
    fn ring_sink_events_agree_with_stats() {
        use crate::obs::RingSink;
        let ring = Arc::new(RingSink::new(64));
        let sink: Arc<dyn TraceSink> = ring.clone();
        let params = GenParams { sink: Some(sink), ..Default::default() };
        let mut prob = Grow { cols_in: 0 };
        let stats = GenEngine::new(&params).run(&mut prob);
        assert!(stats.converged);
        let events = ring.events();
        assert_eq!(events.len(), stats.rounds, "one event per round");
        assert_eq!(events.iter().map(|e| e.cols_added).sum::<usize>(), stats.cols_added);
        assert_eq!(events.iter().map(|e| e.rows_added).sum::<usize>(), stats.rows_added);
        assert_eq!(events.last().unwrap().simplex_iters, stats.simplex_iters);
        // per-round spans sum exactly to the cumulative GenStats spans
        assert_eq!(events.iter().map(|e| e.solve_ns).sum::<u64>(), stats.solve_ns);
        assert_eq!(events.iter().map(|e| e.pricing_ns).sum::<u64>(), stats.pricing_ns);
        for (i, e) in events.iter().enumerate() {
            assert_eq!(e.round, i + 1, "rounds are 1-based and consecutive");
        }
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn mid_run_stop_keeps_partial_expansion() {
        let params = GenParams::default();
        let calls = std::cell::Cell::new(0usize);
        // fire on the second poll: one expanding round completes first
        let stop = move || {
            calls.set(calls.get() + 1);
            calls.get() >= 2
        };
        let mut prob = Grow { cols_in: 0 };
        let stats = GenEngine::new(&params).with_should_stop(&stop).run(&mut prob);
        assert!(stats.timed_out);
        assert_eq!(stats.rounds, 2);
        assert_eq!(stats.cols_added, 1);
        assert_eq!(prob.cols_in, 1);
    }
}
