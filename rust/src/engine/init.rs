//! The §4 first-order initialization layer.
//!
//! The paper's headline result is the *combination* of column/constraint
//! generation with first-order methods: a FOM runs cheaply to a
//! low-accuracy solution whose support seeds the restricted LP, which
//! then converges in a handful of rounds (§2.2.1, §4). This module owns
//! that combination once, instead of each driver wiring its own FISTA:
//!
//! * [`InitStrategy`] — the knob ([`crate::engine::GenParams::init`],
//!   CLI `--init`, serve-protocol `"init"`) selecting how a cold solve
//!   seeds its working sets;
//! * [`Initializer`] — maps `(dataset, workload, λ, budget)` to a
//!   [`Seed`]: a [`WorkingSet`] plus an optional primal guess.
//!
//! Strategies and the workloads they cover:
//!
//! | strategy    | L1-SVM | Group | Slope | RankSVM | Dantzig |
//! |-------------|--------|-------|-------|---------|---------|
//! | `screening` | closed-form λ_max reduced costs, top-k everywhere |||||
//! | `fista`     | smoothed hinge + soft-threshold | group-L∞ prox | Slope prox (PAVA) | pairwise-difference view, no intercept | least-squares correlation residual |
//! | `blockcd`   | — | proximal block CD (§4.3) | — | — | — |
//! | `subsample` | subsample-and-average (§4.4.2–4.4.3) | — | — | — | — |
//!
//! `Auto` resolves per workload: FISTA for L1 (subsample-and-average
//! once n crosses [`SUBSAMPLE_AUTO_N`] in the n ≥ 10p regime), block CD
//! for Group, FISTA for Slope/RankSVM/Dantzig. A strategy that does not apply to a workload
//! falls back to the nearest one that does (documented on each
//! `seed_*`). Every FOM gradient rides the shared chunked
//! [`crate::backend::par_xtv`] kernel, so seeds are bit-identical for
//! any thread count and deterministic given [`Initializer::seed`].

use crate::backend::{par_xtv, sigma_max_sq, Backend, NativeBackend};
use crate::bail;
use crate::data::Dataset;
use crate::engine::WorkingSet;
use crate::error::Result;
use crate::fom::block_cd::{block_cd, BlockCdParams};
use crate::fom::fista::{fista, FistaParams, FistaResult, Penalty};
use crate::fom::prox::soft_threshold;
use crate::fom::screening::{correlation_screen_backend, group_screen_backend, top_k_by_abs};
use crate::fom::subsample::{subsample_average, violated_samples_capped, SubsampleParams};
use crate::workloads::pairset::{PairCosts, PairSet};

/// Default seed-size budget `k` (the paper seeds with ~10 columns).
pub const DEFAULT_SEED_BUDGET: usize = 10;

/// Above this sample count — AND when n ≥ 10p, the §4.4.2 regime where a
/// size-10p subsample is a genuine subsample — `Auto` on L1-SVM switches
/// from one FISTA run to the subsample-and-average heuristic: the
/// full-data FOM is gradient-bound at large n, while subsample solves
/// parallelize. Without the n ≥ 10p guard the "subsamples" would be the
/// whole dataset and the heuristic would just run FISTA twice.
pub const SUBSAMPLE_AUTO_N: usize = 4096;

/// Cap on FOM-flagged constraint rows handed to the restricted LP: a
/// noisy first-order estimate can flag thousands of samples/pairs, and
/// seeding all of them inflates the LP basis for no benefit — the
/// generation rounds bring in whatever the initializer missed.
pub const SEED_ROW_CAP: usize = 1500;

/// How a cold solve seeds its initial working sets (§4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitStrategy {
    /// Per-workload default: a first-order method for fixed-λ solves
    /// (block CD for Group, subsample-and-average for large-n L1),
    /// closed-form screening for the λ_max-anchored path drivers.
    Auto,
    /// Closed-form λ_max reduced-cost screening, top-k (§2.2.2, eq. 10).
    Screening,
    /// Nesterov-smoothed hinge FISTA with the workload's prox (§4.3);
    /// RankSVM via the pairwise-difference view, the Dantzig selector
    /// via its least-squares correlation residual.
    Fista,
    /// Proximal block coordinate descent on groups (§4.3; Group only —
    /// other workloads fall back to [`InitStrategy::Fista`]).
    BlockCd,
    /// Subsample-and-average (§4.4.2–4.4.3; L1 only — other workloads
    /// fall back to their FOM).
    Subsample,
}

impl InitStrategy {
    /// Parse a knob value (`auto|screening|fista|blockcd|subsample`).
    pub fn parse(name: &str) -> Result<InitStrategy> {
        Ok(match name {
            "auto" => InitStrategy::Auto,
            "screening" => InitStrategy::Screening,
            "fista" => InitStrategy::Fista,
            "blockcd" => InitStrategy::BlockCd,
            "subsample" => InitStrategy::Subsample,
            other => {
                bail!("unknown init strategy {other:?} (auto|screening|fista|blockcd|subsample)")
            }
        })
    }

    /// Knob spelling.
    pub fn as_str(&self) -> &'static str {
        match self {
            InitStrategy::Auto => "auto",
            InitStrategy::Screening => "screening",
            InitStrategy::Fista => "fista",
            InitStrategy::BlockCd => "blockcd",
            InitStrategy::Subsample => "subsample",
        }
    }
}

/// A computed seed: the initial working sets plus (for FOM strategies)
/// the low-accuracy primal the sets were read off.
#[derive(Clone, Debug)]
pub struct Seed {
    /// Column/row indices to seed the restricted model with. Index
    /// spaces are the workload's own (features, groups, pairs — see
    /// [`WorkingSet`]).
    pub ws: WorkingSet,
    /// The FOM's `(β, β₀)` (None for pure screening). Beyond selecting
    /// the working set, the L1 driver feeds this into
    /// `RestrictedL1::crossover_from`, which seats the guessed support
    /// as the starting basis — a FISTA-quality guess typically lands a
    /// few pivots from the optimal vertex, vs. a full dual-simplex pass
    /// from the all-logical crash basis.
    pub primal: Option<(Vec<f64>, f64)>,
    /// The strategy that actually ran (`Auto` resolved).
    pub strategy: InitStrategy,
}

/// The shared §4 initializer: one configuration, one `seed_*` method per
/// workload. Construct via [`Initializer::new`] or
/// [`Initializer::from_params`], then override the FOM knobs with the
/// builder methods where an experiment needs specific settings.
#[derive(Clone, Debug)]
pub struct Initializer {
    /// Strategy (resolved per workload when `Auto`).
    pub strategy: InitStrategy,
    /// Seed-size budget `k` (clamped to ≥ 1).
    pub budget: usize,
    /// Worker threads for the FOM gradients and subsample solves.
    pub threads: usize,
    /// RNG seed for the subsampling heuristic (fixed ⇒ deterministic).
    pub seed: u64,
    /// FISTA settings for the smoothed-hinge seeds.
    pub fista: FistaParams,
    /// Block-CD settings for the Group seed (low accuracy by design).
    pub block_cd: BlockCdParams,
    /// Subsample settings; `None` derives them from `(n, p)` per §4.4.2.
    pub subsample: Option<SubsampleParams>,
}

impl Initializer {
    /// An initializer with the given strategy and budget (serial, seed 0,
    /// default FOM settings).
    pub fn new(strategy: InitStrategy, budget: usize) -> Self {
        Self {
            strategy,
            budget: budget.max(1),
            threads: 1,
            seed: 0,
            fista: FistaParams::default(),
            block_cd: BlockCdParams { max_sweeps: 60, tol: 1e-3, ..Default::default() },
            subsample: None,
        }
    }

    /// Read strategy, budget and threads off a
    /// [`crate::engine::GenParams`].
    pub fn from_params(params: &crate::engine::GenParams) -> Self {
        let mut me = Self::new(params.init, params.seed_budget);
        me.threads = params.threads.max(1);
        me.fista.threads = me.threads;
        me.block_cd.threads = me.threads;
        me
    }

    /// Like [`Initializer::from_params`] but resolving `Auto` to
    /// `Screening` — the λ-path drivers anchor at λ_max, where the
    /// closed-form reduced costs are exact and a FOM would only find the
    /// all-zero solution (Algorithm 2's own choice).
    pub fn for_path(params: &crate::engine::GenParams) -> Self {
        let mut me = Self::from_params(params);
        if me.strategy == InitStrategy::Auto {
            me.strategy = InitStrategy::Screening;
        }
        me
    }

    /// Override the subsampling RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Override the FISTA settings.
    pub fn with_fom(mut self, fista: FistaParams) -> Self {
        self.fista = fista;
        self
    }

    /// Override the block-CD settings.
    pub fn with_block_cd(mut self, params: BlockCdParams) -> Self {
        self.block_cd = params;
        self
    }

    /// Override the subsample settings.
    pub fn with_subsample(mut self, params: SubsampleParams) -> Self {
        self.subsample = Some(params);
        self
    }

    /// Seed the L1-SVM working sets at `lambda`. `Auto` → FISTA, or
    /// subsample-and-average when `n ≥` [`SUBSAMPLE_AUTO_N`] and
    /// `n ≥ 10p`; `BlockCd` falls back to FISTA (no group structure).
    /// FOM seeds carry both channels: the top-budget surviving
    /// coefficients as columns and the most violated margins (capped at
    /// [`SEED_ROW_CAP`]) as rows. Callers that only consume the column
    /// channel (Algorithm 1) should use [`Initializer::seed_l1_cols`],
    /// which skips the O(np) margin scan.
    pub fn seed_l1(&self, ds: &Dataset, backend: &dyn Backend, lambda: f64) -> Seed {
        self.seed_l1_impl(ds, backend, lambda, true)
    }

    /// [`Initializer::seed_l1`] without the violated-margin row scan —
    /// for pure column generation, where the rows would be discarded and
    /// the scan's full-design matvec is pure overhead.
    pub fn seed_l1_cols(&self, ds: &Dataset, backend: &dyn Backend, lambda: f64) -> Seed {
        self.seed_l1_impl(ds, backend, lambda, false)
    }

    fn seed_l1_impl(
        &self,
        ds: &Dataset,
        backend: &dyn Backend,
        lambda: f64,
        want_rows: bool,
    ) -> Seed {
        let strat = match self.strategy {
            InitStrategy::Auto => {
                if ds.n() >= SUBSAMPLE_AUTO_N && ds.n() >= 10 * ds.p() {
                    InitStrategy::Subsample
                } else {
                    InitStrategy::Fista
                }
            }
            InitStrategy::BlockCd => InitStrategy::Fista,
            s => s,
        };
        match strat {
            InitStrategy::Screening => self.screening_l1(ds),
            InitStrategy::Subsample => {
                let params = self
                    .subsample
                    .clone()
                    .unwrap_or_else(|| self.derived_subsample_params(ds));
                let avg = subsample_average(ds, lambda, &params, self.seed);
                self.l1_seed_from_primal(
                    ds,
                    backend,
                    avg.beta,
                    avg.beta0,
                    InitStrategy::Subsample,
                    want_rows,
                )
            }
            _ => {
                // screened FISTA on the smoothed hinge (§4.4.1 + §4.3);
                // scoring rides the shared chunked Xᵀy kernel
                let screen = correlation_screen_backend(
                    backend,
                    &ds.y,
                    (10 * ds.n()).min(ds.p()),
                    self.fista.threads,
                );
                let xx = ds.x.subset_cols(&screen);
                let sub_backend = NativeBackend::new(&xx);
                let res = fista(&sub_backend, &ds.y, &Penalty::L1(lambda), &self.fista, None);
                let mut beta = vec![0.0; ds.p()];
                for (k, &j) in screen.iter().enumerate() {
                    beta[j] = res.beta[k];
                }
                self.l1_seed_from_primal(
                    ds,
                    backend,
                    beta,
                    res.beta0,
                    InitStrategy::Fista,
                    want_rows,
                )
            }
        }
    }

    /// Seed the Group-SVM working set (group indices in
    /// [`WorkingSet::cols`]) at `lambda`. `Auto`/`Subsample` → block CD;
    /// `Fista` uses the group-L∞ prox. Both FOMs run on the top-n
    /// screened groups (§4.4.1) and keep the budget's worth of groups by
    /// coefficient mass, falling back to screening when every group
    /// thresholds to zero.
    pub fn seed_group(&self, ds: &Dataset, groups: &[Vec<usize>], lambda: f64) -> Seed {
        let strat = match self.strategy {
            InitStrategy::Auto | InitStrategy::Subsample => InitStrategy::BlockCd,
            s => s,
        };
        if strat == InitStrategy::Screening {
            return Seed {
                ws: WorkingSet {
                    cols: crate::coordinator::group::initial_groups(ds, groups, self.budget),
                    rows: Vec::new(),
                },
                primal: None,
                strategy: InitStrategy::Screening,
            };
        }
        // screen groups, materialize their columns, solve locally
        let keep = ds.n().max(self.budget).min(groups.len());
        let screened = group_screen_backend(
            &NativeBackend::new(&ds.x),
            &ds.y,
            groups,
            keep,
            self.fista.threads,
        );
        let cols_flat: Vec<usize> =
            screened.iter().flat_map(|&g| groups[g].iter().copied()).collect();
        let xx = ds.x.subset_cols(&cols_flat);
        let sub_backend = NativeBackend::new(&xx);
        let mut local: Vec<Vec<usize>> = Vec::with_capacity(screened.len());
        let mut off = 0;
        for &g in &screened {
            local.push((off..off + groups[g].len()).collect());
            off += groups[g].len();
        }
        let (beta_local, beta0) = if strat == InitStrategy::BlockCd {
            let res = block_cd(&sub_backend, &ds.y, &local, lambda, &self.block_cd, None);
            (res.beta, res.beta0)
        } else {
            let res = fista(
                &sub_backend,
                &ds.y,
                &Penalty::GroupLinf { lambda, groups: local.clone() },
                &self.fista,
                None,
            );
            (res.beta, res.beta0)
        };
        // rank screened groups by coefficient mass, keep nonzero ones
        let mass: Vec<f64> = local
            .iter()
            .map(|g| g.iter().map(|&j| beta_local[j].abs()).sum())
            .collect();
        let cols: Vec<usize> = top_k_by_abs(&mass, self.budget)
            .into_iter()
            .filter(|&k| mass[k] > 1e-8)
            .map(|k| screened[k])
            .collect();
        let (cols, strat) = if cols.is_empty() {
            (
                crate::coordinator::group::initial_groups(ds, groups, self.budget),
                InitStrategy::Screening,
            )
        } else {
            (cols, strat)
        };
        let mut beta = vec![0.0; ds.p()];
        for (k, &j) in cols_flat.iter().enumerate() {
            beta[j] = beta_local[k];
        }
        Seed {
            ws: WorkingSet { cols, rows: Vec::new() },
            primal: Some((beta, beta0)),
            strategy: strat,
        }
    }

    /// Seed the Slope-SVM column working set for the (sorted,
    /// nonincreasing) weight vector. `Auto`/`BlockCd`/`Subsample` →
    /// FISTA with the Slope prox (PAVA) on the screened columns; the row
    /// channel stays empty — epigraph cuts regenerate from incumbents.
    pub fn seed_slope(&self, ds: &Dataset, weights: &[f64]) -> Seed {
        if matches!(self.strategy, InitStrategy::Screening) {
            return Seed {
                ws: WorkingSet {
                    cols: crate::coordinator::path::initial_columns(ds, self.budget),
                    rows: Vec::new(),
                },
                primal: None,
                strategy: InitStrategy::Screening,
            };
        }
        let screen = correlation_screen_backend(
            &NativeBackend::new(&ds.x),
            &ds.y,
            (10 * ds.n()).min(ds.p()),
            self.fista.threads,
        );
        let xx = ds.x.subset_cols(&screen);
        let sub_backend = NativeBackend::new(&xx);
        let sub_lams: Vec<f64> = weights[..screen.len()].to_vec();
        let res = fista(&sub_backend, &ds.y, &Penalty::Slope(sub_lams), &self.fista, None);
        let mut beta = vec![0.0; ds.p()];
        for (k, &j) in screen.iter().enumerate() {
            beta[j] = res.beta[k];
        }
        let cols = support_top_k(&beta, self.budget);
        let (cols, strategy) = if cols.is_empty() {
            (
                crate::coordinator::path::initial_columns(ds, self.budget),
                InitStrategy::Screening,
            )
        } else {
            (cols, InitStrategy::Fista)
        };
        Seed {
            ws: WorkingSet { cols, rows: Vec::new() },
            primal: Some((beta, res.beta0)),
            strategy,
        }
    }

    /// Seed the RankSVM working sets (pair indices in rows, features in
    /// cols) at `lambda` — [`Initializer::seed_ranksvm_costed`] with
    /// uniform costs (`g = w = 1`), bitwise the original unweighted
    /// seed.
    pub fn seed_ranksvm(
        &self,
        ds: &Dataset,
        backend: &dyn Backend,
        pairs: &PairSet,
        lambda: f64,
    ) -> Seed {
        self.seed_ranksvm_costed(ds, backend, pairs, &PairCosts::UNIFORM, lambda)
    }

    /// Seed the weighted/gapped RankSVM working sets at `lambda`. The
    /// FOM route depends on the cost structure and the candidate-set
    /// size:
    ///
    /// * **uniform costs, ≤ [`crate::workloads::pairset::ENUM_PAIR_CAP`]
    ///   pairs** — FISTA on the **pairwise-difference view**: the
    ///   implicit design `D` with one row `x_i − x_k` per pair,
    ///   all-ones targets and no intercept ([`PairDiffBackend`]
    ///   streams the pairs in canonical order; the O(n²) list is never
    ///   materialized). The FISTA *iterates* are Θ(|P|)-length, which
    ///   is what caps this route;
    /// * **uniform or bucketed costs beyond the cap (and bucketed at
    ///   any size)** — the **level-aggregated O(n)-state smoothed-hinge
    ///   FOM** (after arXiv:1808.07100): the pairwise smoothed-hinge
    ///   gradient collapses to per-sample coefficients computable from
    ///   per-level sorted margins (O(n log n) per iteration for uniform
    ///   costs via a Fenwick sweep, O(n·L·log n) for L-level bucketed
    ///   costs) — FISTA seeds at any n without a Θ(|P|) iterate;
    /// * **per-pair costs** — no aggregation structure to exploit: the
    ///   closed-form weighted screening pick seeds
    ///   ([`crate::workloads::ranksvm::initial_rank_features_weighted`]),
    ///   and the generation rounds do the rest.
    pub fn seed_ranksvm_costed(
        &self,
        ds: &Dataset,
        backend: &dyn Backend,
        pairs: &PairSet,
        costs: &PairCosts,
        lambda: f64,
    ) -> Seed {
        use crate::workloads::ranksvm::initial_rank_features_weighted;
        let strat = match self.strategy {
            InitStrategy::Screening => InitStrategy::Screening,
            _ => InitStrategy::Fista,
        };
        let screening = |primal: Option<(Vec<f64>, f64)>| Seed {
            ws: WorkingSet {
                cols: initial_rank_features_weighted(ds, pairs, costs, self.budget),
                rows: pairs.spread(self.budget),
            },
            primal,
            strategy: InitStrategy::Screening,
        };
        if strat == InitStrategy::Screening
            || pairs.is_empty()
            || matches!(costs, PairCosts::PerPair { .. })
        {
            return screening(None);
        }
        if !costs.is_uniform() || pairs.len() > crate::workloads::pairset::ENUM_PAIR_CAP {
            return self.aggregated_rank_fista(ds, backend, pairs, costs, lambda);
        }
        let pd = PairDiffBackend::new(backend, pairs, self.fista.threads.max(1));
        let ones = vec![1.0; pairs.len()];
        let params = FistaParams { fit_intercept: false, ..self.fista.clone() };
        let res = fista(&pd, &ones, &Penalty::L1(lambda), &params, None);
        let cols = support_top_k(&res.beta, self.budget);
        if cols.is_empty() {
            // λ ≥ λ_max: the FOM found nothing — the screening pick seeds
            return screening(Some((res.beta, 0.0)));
        }
        // most violated pairs at the FOM point, capped
        let rows = violated_samples_capped(&pd, &ones, &res.beta, 0.0, 0.0, SEED_ROW_CAP);
        let rows = if rows.is_empty() { pairs.spread(self.budget) } else { rows };
        Seed {
            ws: WorkingSet { cols, rows },
            primal: Some((res.beta, 0.0)),
            strategy: InitStrategy::Fista,
        }
    }

    /// The level-aggregated smoothed-hinge FISTA (arXiv:1808.07100):
    /// minimize `Σ_t w_t·φ_μ(g_t − d_t) + λ‖β‖₁` over the **implicit**
    /// pair set, where `d_t = m_i − m_k` and `φ_μ` is the Nesterov-
    /// smoothed hinge, without ever allocating a Θ(|P|) vector. The
    /// gradient is `Xᵀc` with per-sample coefficients `c` computed by
    /// [`aggregated_grad_coeffs`] from per-level sorted margins; the
    /// Lipschitz constant is `σ_max²(X)·2·r_max/μ` with `r_max` the
    /// largest total pair weight any one sample participates in (each
    /// pair's rank-one term `(x_i−x_k)(x_i−x_k)ᵀ ⪯ 2(x_ix_iᵀ+x_kx_kᵀ)`).
    /// The momentum schedule, prox step, and `‖Δβ‖ ≤ eta` stop mirror
    /// [`crate::fom::fista::fista`] deliberately — keep them in sync.
    fn aggregated_rank_fista(
        &self,
        ds: &Dataset,
        backend: &dyn Backend,
        pairs: &PairSet,
        costs: &PairCosts,
        lambda: f64,
    ) -> Seed {
        use crate::workloads::ranksvm::initial_rank_features_weighted;
        let n = ds.n();
        let p = ds.p();
        let params = &self.fista;
        // smoothing width: matches the per-sample smoothed hinge, whose
        // Lipschitz constant σ²/(4τ) corresponds to μ = 4τ
        let mu = (4.0 * params.tau).max(1e-9);
        let rmax = max_row_weight(pairs, costs);
        let l =
            (sigma_max_sq(backend, params.power_iters) * (2.0 * rmax / mu)).max(1e-12) * 1.05;
        let inv_l = 1.0 / l;
        let mut beta = vec![0.0; p];
        let mut beta_prev = beta.clone();
        let mut q = 1.0f64;
        let mut m = vec![0.0; n];
        let mut coef = vec![0.0; n];
        let mut grad = vec![0.0; p];
        for _ in 0..params.max_iters {
            let q_next = 0.5 * (1.0 + (1.0 + 4.0 * q * q).sqrt());
            let mom = (q - 1.0) / q_next;
            let mut alpha: Vec<f64> =
                beta.iter().zip(&beta_prev).map(|(b, bp)| b + mom * (b - bp)).collect();
            q = q_next;
            backend.xb(&alpha, &mut m);
            coef.iter_mut().for_each(|v| *v = 0.0);
            aggregated_grad_coeffs(pairs, costs, &m, mu, &mut coef);
            par_xtv(backend, params.threads, &coef, &mut grad);
            for (a, g) in alpha.iter_mut().zip(&grad) {
                *a -= inv_l * g;
            }
            soft_threshold(&mut alpha, lambda * inv_l);
            let mut delta = 0.0;
            for (a, b) in alpha.iter().zip(&beta) {
                delta += (a - b) * (a - b);
            }
            beta_prev = std::mem::replace(&mut beta, alpha);
            if delta.sqrt() <= params.eta {
                break;
            }
        }
        let cols = support_top_k(&beta, self.budget);
        if cols.is_empty() {
            // λ ≥ λ_max: nothing survived — the screening pick seeds
            return Seed {
                ws: WorkingSet {
                    cols: initial_rank_features_weighted(ds, pairs, costs, self.budget),
                    rows: pairs.spread(self.budget),
                },
                primal: Some((beta, 0.0)),
                strategy: InitStrategy::Screening,
            };
        }
        // most violated pairs at the FOM point: the winner-best weighted
        // sweep, capped — never a Θ(|P|) pass
        backend.xb(&beta, &mut m);
        let (viol, _scan) =
            pairs.price_weighted(&m, 0.0, &[], SEED_ROW_CAP, self.threads.max(1), costs);
        let rows: Vec<usize> = viol.into_iter().map(|(t, _)| t).collect();
        let rows = if rows.is_empty() { pairs.spread(self.budget) } else { rows };
        Seed {
            ws: WorkingSet { cols, rows },
            primal: Some((beta, 0.0)),
            strategy: InitStrategy::Fista,
        }
    }

    /// Seed the Dantzig-selector row working set (feature indices; the
    /// restricted model pulls each row's coefficient pair in itself,
    /// preserving `I ⊆ J`). The FOM is FISTA on the least-squares lasso
    /// surrogate `½‖Xβ − y‖² + λ‖β‖₁` — its KKT conditions bound the
    /// **correlation residual** `‖Xᵀ(y − Xβ)‖∞ ≤ λ`, i.e. a lasso
    /// solution at the same λ is Dantzig-feasible and its support marks
    /// the rows that bind.
    pub fn seed_dantzig(&self, ds: &Dataset, backend: &dyn Backend, lambda: f64) -> Seed {
        use crate::workloads::dantzig::initial_features;
        let strat = match self.strategy {
            InitStrategy::Screening => InitStrategy::Screening,
            _ => InitStrategy::Fista,
        };
        if strat == InitStrategy::Screening {
            return Seed {
                ws: WorkingSet { cols: Vec::new(), rows: initial_features(ds, self.budget) },
                primal: None,
                strategy: InitStrategy::Screening,
            };
        }
        let res = lasso_fista(backend, &ds.y, lambda, &self.fista);
        let rows = support_top_k(&res.beta, self.budget);
        let (rows, strategy) = if rows.is_empty() {
            (initial_features(ds, self.budget), InitStrategy::Screening)
        } else {
            (rows, InitStrategy::Fista)
        };
        Seed {
            ws: WorkingSet { cols: Vec::new(), rows },
            primal: Some((res.beta, 0.0)),
            strategy,
        }
    }

    // -- internals --------------------------------------------------------

    fn screening_l1(&self, ds: &Dataset) -> Seed {
        Seed {
            ws: WorkingSet {
                cols: crate::coordinator::path::initial_columns(ds, self.budget),
                rows: Vec::new(),
            },
            primal: None,
            strategy: InitStrategy::Screening,
        }
    }

    /// §4.4.2 defaults: n₀ = 10p (clamped into [100, n]), Q_max = n/n₀
    /// (clamped into [2, 12]), with correlation screening inside each
    /// subsample once p is large (§4.4.3). The inner FISTA runs serial —
    /// the subsample solves themselves occupy the workers.
    fn derived_subsample_params(&self, ds: &Dataset) -> SubsampleParams {
        let n = ds.n();
        let p = ds.p();
        SubsampleParams {
            // clamp low end to n as well so tiny datasets can't invert
            // the clamp bounds
            n0: (10 * p).clamp(100.min(n), n),
            mu_tol: 1e-1,
            q_max: (n / (10 * p).max(1)).clamp(2, 12),
            threads: self.threads.max(1),
            screen_k: if p > 2000 { 1000 } else { 0 },
            fista: FistaParams { threads: 1, ..self.fista.clone() },
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn l1_seed_from_primal(
        &self,
        ds: &Dataset,
        backend: &dyn Backend,
        beta: Vec<f64>,
        beta0: f64,
        strategy: InitStrategy,
        want_rows: bool,
    ) -> Seed {
        let cols = support_top_k(&beta, self.budget);
        // `strategy` reports what actually seeded the columns: an empty
        // FOM support (λ ≥ λ_max) falls back to the screening pick
        let (cols, strategy) = if cols.is_empty() {
            (
                crate::coordinator::path::initial_columns(ds, self.budget),
                InitStrategy::Screening,
            )
        } else {
            (cols, strategy)
        };
        let rows = if want_rows {
            violated_samples_capped(backend, &ds.y, &beta, beta0, 0.0, SEED_ROW_CAP)
        } else {
            Vec::new()
        };
        Seed { ws: WorkingSet { cols, rows }, primal: Some((beta, beta0)), strategy }
    }
}

/// Indices of the (at most) `k` largest nonzero entries of `beta` by
/// absolute value — the FOM support a seed keeps.
fn support_top_k(beta: &[f64], k: usize) -> Vec<usize> {
    top_k_by_abs(beta, k.min(beta.len()))
        .into_iter()
        .filter(|&j| beta[j] != 0.0)
        .collect()
}

/// A 1-indexed Fenwick tree over coordinate-compressed margin values,
/// carrying `(count, sum)` per node — the range count/sum queries behind
/// the uniform-cost aggregated gradient sweep.
struct CountSumFenwick {
    cnt: Vec<f64>,
    sum: Vec<f64>,
}

impl CountSumFenwick {
    fn new(len: usize) -> Self {
        Self { cnt: vec![0.0; len + 1], sum: vec![0.0; len + 1] }
    }

    /// Insert one value `v` at compressed rank `i` (0-based).
    fn add(&mut self, i: usize, v: f64) {
        let mut j = i + 1;
        while j < self.cnt.len() {
            self.cnt[j] += 1.0;
            self.sum[j] += v;
            j += j & j.wrapping_neg();
        }
    }

    /// `(count, sum)` of the inserted values with compressed rank `< i`.
    fn prefix(&self, i: usize) -> (f64, f64) {
        let (mut c, mut s) = (0.0, 0.0);
        let mut j = i;
        while j > 0 {
            c += self.cnt[j];
            s += self.sum[j];
            j &= j - 1;
        }
        (c, s)
    }
}

/// Per-sample gradient coefficients of the weighted smoothed pairwise
/// hinge `Σ_t w_t·φ_μ(g_t − (m_i − m_k))` at margins `m`, accumulated
/// into `c` (length n): with `d = m_i − m_k`, the chain rule scatters
/// `c[i] += w·φ′(d)` on the winner and `c[k] −= w·φ′(d)` on the loser,
/// where `φ′(d) = 0` for `d ≥ g`, `(d − g)/μ` for `g − μ < d < g`, and
/// `−1` for `d ≤ g − μ` — so the full gradient w.r.t. β is `Xᵀc`.
///
/// The point is to do this **without enumerating pairs** when costs are
/// constant per level pair: a sample's sum over one opposing level needs
/// only that level's margin count and margin sum inside the quadratic
/// window `(m ± g − μ, m ± g)` plus the count beyond it. Bucketed costs
/// use per-level sorted margins + prefix sums + two binary searches per
/// (sample, level) — O(n·L·log n); uniform costs collapse further to one
/// merged Fenwick sweep over all lower (resp. higher) levels at once —
/// O(n log n). Per-pair costs have no structure to exploit and fall back
/// to O(|P|) enumeration (also the brute-force oracle the aggregated
/// paths are tested against).
fn aggregated_grad_coeffs(pairs: &PairSet, costs: &PairCosts, m: &[f64], mu: f64, c: &mut [f64]) {
    let order = pairs.sorted_order();
    if order.is_empty() {
        return;
    }
    let bounds = pairs.level_bounds();
    let nl = pairs.n_levels();
    let mm: Vec<f64> = order.iter().map(|&i| m[i as usize]).collect();
    match costs {
        PairCosts::Uniform => {
            let mut uniq = mm.clone();
            uniq.sort_unstable_by(f64::total_cmp);
            uniq.dedup();
            let rank_le = |v: f64| uniq.partition_point(|&u| u <= v);
            let rank_lt = |v: f64| uniq.partition_point(|&u| u < v);
            // winner pass: levels ascending, the tree holds every lower level
            let mut fw = CountSumFenwick::new(uniq.len());
            let mut inserted = 0.0;
            for a in 0..nl {
                for pos in bounds[a]..bounds[a + 1] {
                    let mi = mm[pos];
                    let (c_lo, s_lo) = fw.prefix(rank_le(mi - 1.0));
                    let (c_hi, s_hi) = fw.prefix(rank_lt(mi - 1.0 + mu));
                    let (cq, sq) = (c_hi - c_lo, s_hi - s_lo);
                    c[order[pos] as usize] += ((mi - 1.0) * cq - sq) / mu - (inserted - c_hi);
                }
                for pos in bounds[a]..bounds[a + 1] {
                    fw.add(rank_lt(mm[pos]), mm[pos]);
                    inserted += 1.0;
                }
            }
            // loser pass: levels descending, the tree holds every higher level
            let mut fl = CountSumFenwick::new(uniq.len());
            for b in (0..nl).rev() {
                for pos in bounds[b]..bounds[b + 1] {
                    let mk = mm[pos];
                    let (c_lo, s_lo) = fl.prefix(rank_le(mk + 1.0 - mu));
                    let (c_hi, s_hi) = fl.prefix(rank_lt(mk + 1.0));
                    let (cq, sq) = (c_hi - c_lo, s_hi - s_lo);
                    c[order[pos] as usize] += ((mk + 1.0) * cq - sq) / mu + c_lo;
                }
                for pos in bounds[b]..bounds[b + 1] {
                    fl.add(rank_lt(mm[pos]), mm[pos]);
                }
            }
        }
        PairCosts::Bucketed { levels, gaps, weights } => {
            let lv = *levels;
            // per-level margins sorted ascending, with prefix sums
            let mut ms: Vec<Vec<f64>> = Vec::with_capacity(nl);
            let mut pre: Vec<Vec<f64>> = Vec::with_capacity(nl);
            for l in 0..nl {
                let mut v = mm[bounds[l]..bounds[l + 1]].to_vec();
                v.sort_unstable_by(f64::total_cmp);
                let mut pr = Vec::with_capacity(v.len() + 1);
                pr.push(0.0);
                for &x in &v {
                    pr.push(pr.last().unwrap() + x);
                }
                ms.push(v);
                pre.push(pr);
            }
            for a in 0..nl {
                for pos in bounds[a]..bounds[a + 1] {
                    let mi = mm[pos];
                    let mut acc = 0.0;
                    // as a winner, against every lower level
                    for b in 0..a {
                        let (g, w) = (gaps[a * lv + b], weights[a * lv + b]);
                        let v = &ms[b];
                        let lo = v.partition_point(|&x| x <= mi - g);
                        let hi = v.partition_point(|&x| x < mi - g + mu);
                        let (cq, sq) = ((hi - lo) as f64, pre[b][hi] - pre[b][lo]);
                        acc += w * (((mi - g) * cq - sq) / mu - (v.len() - hi) as f64);
                    }
                    // as a loser, against every higher level
                    for hl in a + 1..nl {
                        let (g, w) = (gaps[hl * lv + a], weights[hl * lv + a]);
                        let v = &ms[hl];
                        let lo = v.partition_point(|&x| x <= mi + g - mu);
                        let hi = v.partition_point(|&x| x < mi + g);
                        let (cq, sq) = ((hi - lo) as f64, pre[hl][hi] - pre[hl][lo]);
                        acc += w * (((mi + g) * cq - sq) / mu + lo as f64);
                    }
                    c[order[pos] as usize] += acc;
                }
            }
        }
        PairCosts::PerPair { gaps, weights } => {
            pairs.for_each(|t, i, k| {
                let (g, w) = (gaps[t], weights[t]);
                let d = m[i] - m[k];
                let phi = if d >= g {
                    0.0
                } else if d > g - mu {
                    (d - g) / mu
                } else {
                    -1.0
                };
                c[i] += w * phi;
                c[k] -= w * phi;
            });
        }
    }
}

/// The largest total pair weight any one sample participates in
/// (`r_max = max_i Σ_{t ∋ i} w_t`) — the factor in the aggregated FOM's
/// Lipschitz bound `‖∇²‖ ≤ 2·r_max·σ_max²(X)/μ`, since each pair's
/// rank-one Hessian term `(x_i−x_k)(x_i−x_k)ᵀ ⪯ 2(x_ix_iᵀ + x_kx_kᵀ)`.
/// Uniform/bucketed costs need only per-level counts; per-pair costs
/// scatter exactly in O(|P|).
fn max_row_weight(pairs: &PairSet, costs: &PairCosts) -> f64 {
    let bounds = pairs.level_bounds();
    let nl = pairs.n_levels();
    let cnt: Vec<f64> = (0..nl).map(|l| (bounds[l + 1] - bounds[l]) as f64).collect();
    let mut rmax = 0.0f64;
    match costs {
        PairCosts::Uniform => {
            let total: f64 = cnt.iter().sum();
            for l in 0..nl {
                rmax = rmax.max(total - cnt[l]);
            }
        }
        PairCosts::Bucketed { levels, weights, .. } => {
            let lv = *levels;
            for a in 0..nl {
                let mut r = 0.0;
                for b in 0..a {
                    r += weights[a * lv + b] * cnt[b];
                }
                for hl in a + 1..nl {
                    r += weights[hl * lv + a] * cnt[hl];
                }
                rmax = rmax.max(r);
            }
        }
        PairCosts::PerPair { weights, .. } => {
            let mut r: Vec<f64> = Vec::new();
            pairs.for_each(|t, i, k| {
                let need = i.max(k) + 1;
                if r.len() < need {
                    r.resize(need, 0.0);
                }
                r[i] += weights[t];
                r[k] += weights[t];
            });
            rmax = r.iter().cloned().fold(0.0, f64::max);
        }
    }
    rmax
}

/// Run a first-order method to the given accuracy on the **full** design
/// (no screening, no truncation) — the experiment harness's "FO-only"
/// baselines ride the same shared wiring as the seeds.
pub fn fom_full(
    backend: &dyn Backend,
    y: &[f64],
    penalty: &Penalty,
    params: &FistaParams,
) -> FistaResult {
    fista(backend, y, penalty, params, None)
}

/// The pairwise-difference design `D`: one row `x_i − x_k` per comparison
/// pair `(i, k)`, never materialized — pairs stream through the
/// [`PairSet`] canonical order (the sorted representation), so even the
/// 16-bytes-per-pair index list is never allocated. `Dβ` is one base
/// matvec plus an O(|P|) gather; `Dᵀv` scatters the pair weights onto
/// the samples (+winner/−loser) **once** and then runs the base `Xᵀ·`
/// through the chunked [`par_xtv`] kernel with the configured thread
/// count — the same dual-scatter identity RankSVM pricing uses, so the
/// FOM and the pricer agree on cost and on bits.
/// `supports_range_pricing` is `false` on purpose: |P| is O(n²), so
/// re-scattering per column chunk would dominate; parallelism lives
/// *inside* `xtv` instead, behind the single scatter.
pub struct PairDiffBackend<'a> {
    base: &'a dyn Backend,
    pairs: &'a PairSet,
    threads: usize,
}

impl<'a> PairDiffBackend<'a> {
    /// View `base` through the comparison pairs; `threads` chunks the
    /// base matvec behind the one-time pair scatter.
    pub fn new(base: &'a dyn Backend, pairs: &'a PairSet, threads: usize) -> Self {
        Self { base, pairs, threads: threads.max(1) }
    }

    fn scatter(&self, v: &[f64]) -> Vec<f64> {
        let mut s = vec![0.0; self.base.rows()];
        self.pairs.for_each(|t, i, k| {
            let vt = v[t];
            if vt != 0.0 {
                s[i] += vt;
                s[k] -= vt;
            }
        });
        s
    }
}

impl Backend for PairDiffBackend<'_> {
    fn rows(&self) -> usize {
        self.pairs.len()
    }
    fn cols(&self) -> usize {
        self.base.cols()
    }
    fn xb(&self, beta: &[f64], out: &mut [f64]) {
        let mut m = vec![0.0; self.base.rows()];
        self.base.xb(beta, &mut m);
        self.pairs.for_each(|t, i, k| out[t] = m[i] - m[k]);
    }
    fn xtv(&self, v: &[f64], out: &mut [f64]) {
        // one O(|P|) scatter, then the (possibly chunked) base matvec
        par_xtv(self.base, self.threads, &self.scatter(v), out);
    }
    fn xtv_range(&self, v: &[f64], j0: usize, out: &mut [f64]) {
        self.base.xtv_range(&self.scatter(v), j0, out);
    }
    fn supports_range_pricing(&self) -> bool {
        false
    }
    fn name(&self) -> &'static str {
        "pairdiff"
    }
}

/// FISTA on the least-squares lasso `½‖Xβ − y‖² + λ‖β‖₁` (no intercept)
/// — the Dantzig selector's first-order surrogate. Gradients ride the
/// shared chunked [`par_xtv`] kernel; the Lipschitz constant reuses the
/// augmented-design power iteration (an upper bound on `σ_max(XᵀX)`).
///
/// The momentum schedule, prox step, and `‖Δβ‖ ≤ eta` stop mirror
/// [`crate::fom::fista::fista`] deliberately — keep the two in sync if
/// either acceleration loop changes (only the loss gradient and the
/// absent intercept differ).
pub fn lasso_fista(
    backend: &dyn Backend,
    y: &[f64],
    lambda: f64,
    params: &FistaParams,
) -> FistaResult {
    let n = backend.rows();
    let p = backend.cols();
    let l = sigma_max_sq(backend, params.power_iters).max(1e-12) * 1.05;
    let inv_l = 1.0 / l;
    let mut beta = vec![0.0; p];
    let mut beta_prev = beta.clone();
    let mut q = 1.0f64;
    let mut resid = vec![0.0; n];
    let mut grad = vec![0.0; p];
    let mut iters = 0;
    for t in 0..params.max_iters {
        iters = t + 1;
        let q_next = 0.5 * (1.0 + (1.0 + 4.0 * q * q).sqrt());
        let mom = (q - 1.0) / q_next;
        let mut alpha: Vec<f64> =
            beta.iter().zip(&beta_prev).map(|(b, bp)| b + mom * (b - bp)).collect();
        q = q_next;
        // ∇ = Xᵀ(Xα − y)
        backend.xb(&alpha, &mut resid);
        for (r, yi) in resid.iter_mut().zip(y) {
            *r -= yi;
        }
        par_xtv(backend, params.threads, &resid, &mut grad);
        for (a, g) in alpha.iter_mut().zip(&grad) {
            *a -= inv_l * g;
        }
        soft_threshold(&mut alpha, lambda * inv_l);
        let mut delta = 0.0;
        for (a, b) in alpha.iter().zip(&beta) {
            delta += (a - b) * (a - b);
        }
        beta_prev = std::mem::replace(&mut beta, alpha);
        if delta.sqrt() <= params.eta {
            break;
        }
    }
    // objective for introspection
    backend.xb(&beta, &mut resid);
    let mut obj = 0.0;
    for (r, yi) in resid.iter().zip(y) {
        obj += 0.5 * (r - yi) * (r - yi);
    }
    obj += lambda * beta.iter().map(|v| v.abs()).sum::<f64>();
    FistaResult { beta, beta0: 0.0, iters, objective: obj }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{
        generate_dantzig, generate_group, generate_l1, generate_ranksvm, DantzigSpec, GroupSpec,
        RankSpec, SyntheticSpec,
    };
    use crate::engine::PairMode;
    use crate::rng::Xoshiro256;
    use crate::workloads::ranksvm::ranking_pairs;

    fn l1_ds(n: usize, p: usize, seed: u64) -> Dataset {
        let spec = SyntheticSpec { n, p, k0: 5.min(p), rho: 0.1, standardize: true };
        generate_l1(&spec, &mut Xoshiro256::seed_from_u64(seed))
    }

    #[test]
    fn strategy_names_roundtrip() {
        for s in [
            InitStrategy::Auto,
            InitStrategy::Screening,
            InitStrategy::Fista,
            InitStrategy::BlockCd,
            InitStrategy::Subsample,
        ] {
            assert_eq!(InitStrategy::parse(s.as_str()).unwrap(), s);
        }
        assert!(InitStrategy::parse("fomish").is_err());
    }

    #[test]
    fn l1_fista_seed_finds_informative_columns() {
        let ds = l1_ds(80, 160, 21);
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.05 * ds.lambda_max_l1();
        let seed = Initializer::new(InitStrategy::Fista, 10).seed_l1(&ds, &backend, lambda);
        assert_eq!(seed.strategy, InitStrategy::Fista);
        assert!(!seed.ws.cols.is_empty() && seed.ws.cols.len() <= 10);
        let hits = seed.ws.cols.iter().filter(|&&j| j < 5).count();
        assert!(hits >= 3, "seed {:?} misses the informative features", seed.ws.cols);
        assert!(seed.primal.is_some());
    }

    #[test]
    fn l1_seed_above_lambda_max_falls_back_to_screening_columns() {
        let ds = l1_ds(30, 40, 22);
        let backend = NativeBackend::new(&ds.x);
        let lambda = 1.5 * ds.lambda_max_l1(); // FOM thresholds everything to 0
        let seed = Initializer::new(InitStrategy::Fista, 6).seed_l1(&ds, &backend, lambda);
        assert_eq!(seed.ws.cols.len(), 6, "screening fallback must fill the budget");
        assert_eq!(
            seed.strategy,
            InitStrategy::Screening,
            "the seed must report what actually seeded the columns"
        );
        // the column-only variant skips the margin scan entirely
        let cols_only =
            Initializer::new(InitStrategy::Fista, 6).seed_l1_cols(&ds, &backend, lambda);
        assert_eq!(cols_only.ws.cols, seed.ws.cols);
        assert!(cols_only.ws.rows.is_empty());
    }

    #[test]
    fn auto_resolves_subsample_for_large_n() {
        let spec = SyntheticSpec {
            n: SUBSAMPLE_AUTO_N + 200,
            p: 12,
            k0: 4,
            rho: 0.1,
            standardize: true,
        };
        let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(23));
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.02 * ds.lambda_max_l1();
        let ini = Initializer::new(InitStrategy::Auto, 8).with_fom(FistaParams {
            max_iters: 60,
            ..Default::default()
        });
        let seed = ini.seed_l1(&ds, &backend, lambda);
        assert_eq!(seed.strategy, InitStrategy::Subsample);
        assert!(!seed.ws.rows.is_empty(), "subsample seed must flag violated margins");
        assert!(seed.ws.rows.len() <= SEED_ROW_CAP);
    }

    #[test]
    fn group_seed_prefers_informative_groups() {
        let spec = GroupSpec {
            n: 60,
            n_groups: 12,
            group_size: 5,
            k0_groups: 3,
            rho: 0.2,
            standardize: true,
        };
        let gd = generate_group(&spec, &mut Xoshiro256::seed_from_u64(24));
        let lambda = 0.1 * gd.data.lambda_max_group(&gd.groups);
        for strat in [InitStrategy::BlockCd, InitStrategy::Fista, InitStrategy::Auto] {
            let seed = Initializer::new(strat, 5).seed_group(&gd.data, &gd.groups, lambda);
            let hits = seed.ws.cols.iter().filter(|&&g| g < 3).count();
            assert!(hits >= 2, "{strat:?}: seed {:?}", seed.ws.cols);
            assert!(seed.ws.rows.is_empty());
        }
    }

    #[test]
    fn ranksvm_pairdiff_backend_matches_explicit_differences() {
        let spec = RankSpec { n: 12, p: 8, k0: 4, rho: 0.1, noise: 0.3, standardize: true };
        let ds = generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(25));
        let ps = PairSet::build(&ds.y, PairMode::Implicit);
        let pairs = ranking_pairs(&ds.y);
        assert_eq!(ps.materialize(), pairs, "streaming order matches the reference");
        let base = NativeBackend::new(&ds.x);
        let pd = PairDiffBackend::new(&base, &ps, 1);
        assert_eq!(pd.rows(), pairs.len());
        assert_eq!(pd.cols(), ds.p());
        let beta: Vec<f64> = (0..ds.p()).map(|j| (j as f64 * 0.3).sin()).collect();
        let mut z = vec![0.0; pairs.len()];
        pd.xb(&beta, &mut z);
        for (t, &(i, k)) in pairs.iter().enumerate() {
            let direct: f64 =
                (0..ds.p()).map(|j| (ds.x.get(i, j) - ds.x.get(k, j)) * beta[j]).sum();
            assert!((z[t] - direct).abs() < 1e-12, "pair {t}");
        }
        // Dᵀv against brute force, serial and chunked
        let v: Vec<f64> = (0..pairs.len()).map(|t| ((t % 5) as f64) - 2.0).collect();
        let mut q = vec![0.0; ds.p()];
        pd.xtv(&v, &mut q);
        for j in 0..ds.p() {
            let direct: f64 = pairs
                .iter()
                .zip(&v)
                .map(|(&(i, k), vt)| vt * (ds.x.get(i, j) - ds.x.get(k, j)))
                .sum();
            assert!((q[j] - direct).abs() < 1e-10, "col {j}");
        }
        // chunked variant: threads live INSIDE xtv (one scatter, base
        // matvec chunked) — must be bit-identical to the serial view
        let pd3 = PairDiffBackend::new(&base, &ps, 3);
        assert!(!pd3.supports_range_pricing());
        let mut qp = vec![0.0; ds.p()];
        pd3.xtv(&v, &mut qp);
        assert_eq!(q, qp, "chunked pairdiff pricing must be bit-identical");
        // and routing through the outer par_xtv degrades to one xtv call
        let mut qo = vec![0.0; ds.p()];
        par_xtv(&pd3, 4, &v, &mut qo);
        assert_eq!(q, qo);
    }

    #[test]
    fn ranksvm_fista_seed_has_no_intercept_shortcut() {
        let spec = RankSpec { n: 20, p: 25, k0: 5, rho: 0.1, noise: 0.3, standardize: true };
        let ds = generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(26));
        let pairs = PairSet::build(&ds.y, PairMode::Auto);
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.05 * crate::workloads::ranksvm::lambda_max_rank(&ds, &pairs);
        let seed = Initializer::new(InitStrategy::Fista, 8)
            .seed_ranksvm(&ds, &backend, &pairs, lambda);
        assert!(!seed.ws.cols.is_empty());
        assert!(!seed.ws.rows.is_empty());
        let (beta, beta0) = seed.primal.clone().unwrap();
        assert_eq!(beta0, 0.0, "the pairwise view fits no intercept");
        assert!(beta.iter().any(|v| *v != 0.0), "FOM must learn a ranking direction");
        let hits = seed.ws.cols.iter().filter(|&&j| j < 5).count();
        assert!(hits >= 2, "seed {:?}", seed.ws.cols);
        // the seed must not depend on the pair-channel representation:
        // the FOM streams the same canonical order either way
        let implicit = PairSet::build(&ds.y, PairMode::Implicit);
        let seed2 = Initializer::new(InitStrategy::Fista, 8)
            .seed_ranksvm(&ds, &backend, &implicit, lambda);
        assert_eq!(seed.ws, seed2.ws, "seed working sets must be representation-independent");
        assert_eq!(seed.primal.unwrap().0, seed2.primal.unwrap().0);
    }

    #[test]
    fn dantzig_lasso_residual_is_feasible_and_seeds_support() {
        let spec = DantzigSpec { n: 40, p: 30, k0: 5, rho: 0.1, sigma: 0.4, standardize: true };
        let ds = generate_dantzig(&spec, &mut Xoshiro256::seed_from_u64(27));
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.3 * crate::workloads::dantzig::lambda_max_dantzig(&ds);
        let params = FistaParams { max_iters: 2000, eta: 1e-10, ..Default::default() };
        let res = lasso_fista(&backend, &ds.y, lambda, &params);
        // KKT: the correlation residual obeys the Dantzig constraint
        let mut xb = vec![0.0; ds.n()];
        backend.xb(&res.beta, &mut xb);
        let u: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, m)| y - m).collect();
        let mut r = vec![0.0; ds.p()];
        backend.xtv(&u, &mut r);
        let linf = r.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        // FISTA is iterative, so allow a small KKT slack over the exact
        // ‖Xᵀ(y − Xβ*)‖∞ ≤ λ bound
        assert!(linf <= lambda * (1.0 + 1e-3), "residual ‖·‖∞ {linf} exceeds λ {lambda}");
        let seed = Initializer::new(InitStrategy::Fista, 8).seed_dantzig(&ds, &backend, lambda);
        assert!(!seed.ws.rows.is_empty());
        let hits = seed.ws.rows.iter().filter(|&&j| j < 5).count();
        assert!(hits >= 2, "seed {:?}", seed.ws.rows);
    }

    #[test]
    fn seeds_are_deterministic_and_thread_independent() {
        let ds = l1_ds(80, 120, 28);
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.05 * ds.lambda_max_l1();
        let a = Initializer::new(InitStrategy::Fista, 10).seed_l1(&ds, &backend, lambda);
        let mut par = Initializer::new(InitStrategy::Fista, 10);
        par.threads = 4;
        par.fista.threads = 4;
        let b = par.seed_l1(&ds, &backend, lambda);
        assert_eq!(a.ws, b.ws, "seeds must not depend on the thread count");
        assert_eq!(a.primal.unwrap().0, b.primal.unwrap().0);
    }

    #[test]
    fn aggregated_grad_coeffs_match_pairwise_enumeration() {
        // ties, a NaN (unranked sample), and a smoothing width sized so
        // margins land in all three smoothed-hinge zones
        let y = vec![2.0, 0.0, 1.0, f64::NAN, 1.0, 2.0, 0.0, 3.0, 1.0];
        let ps = PairSet::build(&y, PairMode::Auto);
        let n = y.len();
        let mut rng = Xoshiro256::seed_from_u64(99);
        let m: Vec<f64> = (0..n).map(|_| rng.uniform_in(-1.5, 1.5)).collect();
        let mu = 0.37;
        let brute = |costs: &PairCosts| {
            let mut c = vec![0.0; n];
            for (i, k, g, w) in crate::workloads::ranksvm::ranking_pairs_costed(&y, costs) {
                let d = m[i] - m[k];
                let phi = if d >= g {
                    0.0
                } else if d > g - mu {
                    (d - g) / mu
                } else {
                    -1.0
                };
                c[i] += w * phi;
                c[k] -= w * phi;
            }
            c
        };
        let check = |costs: &PairCosts| {
            let mut c = vec![0.0; n];
            aggregated_grad_coeffs(&ps, costs, &m, mu, &mut c);
            let want = brute(costs);
            for i in 0..n {
                assert!(
                    (c[i] - want[i]).abs() < 1e-9,
                    "sample {i}: aggregated {} vs enumerated {} under {costs:?}",
                    c[i],
                    want[i]
                );
            }
        };
        check(&PairCosts::UNIFORM);
        // non-uniform per-level-pair gaps and weights
        let bucketed = PairCosts::bucketed_by(&ps, |a, b| {
            (0.5 + 0.25 * (a - b) as f64, 1.0 + 0.5 * b as f64)
        });
        check(&bucketed);
        // the same table expanded per pair rides the O(|P|) oracle path
        let costed = crate::workloads::ranksvm::ranking_pairs_costed(&y, &bucketed);
        let per = PairCosts::PerPair {
            gaps: costed.iter().map(|c| c.2).collect(),
            weights: costed.iter().map(|c| c.3).collect(),
        };
        check(&per);
        // the NaN sample pairs with nothing: zero coefficient everywhere
        let mut c = vec![0.0; n];
        aggregated_grad_coeffs(&ps, &bucketed, &m, mu, &mut c);
        assert_eq!(c[3], 0.0, "unranked samples take no gradient");
    }

    #[test]
    fn bucketed_costs_seed_via_aggregated_fom() {
        let spec = RankSpec { n: 24, p: 25, k0: 5, rho: 0.1, noise: 0.3, standardize: true };
        let ds = generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(31));
        let pairs = PairSet::build(&ds.y, PairMode::Auto);
        let costs = PairCosts::bucketed_by(&pairs, |a, b| (1.0 + 0.5 * (a - b) as f64, 2.0));
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.05
            * crate::workloads::ranksvm::lambda_max_rank_weighted(&ds, &pairs, &costs);
        let seed = Initializer::new(InitStrategy::Fista, 8)
            .seed_ranksvm_costed(&ds, &backend, &pairs, &costs, lambda);
        assert_eq!(seed.strategy, InitStrategy::Fista, "bucketed costs must not fall to screening");
        assert!(!seed.ws.cols.is_empty());
        assert!(!seed.ws.rows.is_empty());
        assert!(seed.ws.rows.iter().all(|&t| t < pairs.len()), "rows are pair indices");
        let hits = seed.ws.cols.iter().filter(|&&j| j < 5).count();
        assert!(hits >= 2, "aggregated FOM misses informative features: {:?}", seed.ws.cols);
        let (beta, beta0) = seed.primal.unwrap();
        assert_eq!(beta0, 0.0);
        assert!(beta.iter().any(|v| *v != 0.0));
        // per-pair costs have no aggregation structure: screening seeds
        let costed = crate::workloads::ranksvm::ranking_pairs_costed(&ds.y, &costs);
        let per = PairCosts::PerPair {
            gaps: costed.iter().map(|c| c.2).collect(),
            weights: costed.iter().map(|c| c.3).collect(),
        };
        let sper = Initializer::new(InitStrategy::Fista, 8)
            .seed_ranksvm_costed(&ds, &backend, &pairs, &per, lambda);
        assert_eq!(sper.strategy, InitStrategy::Screening);
    }

    #[test]
    fn uniform_seed_beyond_pair_cap_no_longer_screens() {
        // distinct relevance scores ⇒ |P| = n(n−1)/2 > ENUM_PAIR_CAP for
        // n = 2100 — pre-aggregation this forced the screening fallback
        let spec = RankSpec { n: 2100, p: 12, k0: 4, rho: 0.1, noise: 0.3, standardize: true };
        let ds = generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(32));
        let pairs = PairSet::build(&ds.y, PairMode::Auto);
        assert!(
            pairs.len() > crate::workloads::pairset::ENUM_PAIR_CAP,
            "fixture must exceed the enumeration cap, got {}",
            pairs.len()
        );
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.05 * crate::workloads::ranksvm::lambda_max_rank(&ds, &pairs);
        let seed =
            Initializer::new(InitStrategy::Fista, 8).seed_ranksvm(&ds, &backend, &pairs, lambda);
        assert_eq!(seed.strategy, InitStrategy::Fista, "aggregated FOM must take over past the cap");
        assert!(!seed.ws.cols.is_empty());
        assert!(!seed.ws.rows.is_empty() && seed.ws.rows.len() <= SEED_ROW_CAP);
        let hits = seed.ws.cols.iter().filter(|&&j| j < 4).count();
        assert!(hits >= 2, "seed {:?}", seed.ws.cols);
    }
}
