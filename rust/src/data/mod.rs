//! Datasets: design-matrix abstraction, synthetic generators, and the
//! libsvm text format.
//!
//! * [`Design`] — a dense **or** sparse feature matrix behind one set of
//!   operations; every coordinator and first-order method is written
//!   against it, so Table 3's sparse runs share all code with the dense
//!   experiments.
//! * [`synthetic`] — the paper's generators (§5.1.1 equicorrelated
//!   Gaussian two-class model; §5.2 group version; sparse text-like data
//!   standing in for rcv1 / real-sim).
//! * [`libsvm`] — reader/writer for the standard `label idx:val ...`
//!   format.

pub mod libsvm;
pub mod synthetic;

use crate::linalg::{fmadd, Matrix};
use crate::sparse::{Csc, Csr};

/// A binary-classification dataset: features `x` (n × p) and labels
/// `y ∈ {−1, +1}ⁿ`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Design,
    pub y: Vec<f64>,
}

impl Dataset {
    /// Number of samples.
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Number of features.
    pub fn p(&self) -> usize {
        self.x.cols()
    }

    /// L2-standardize every feature column in place (paper's preprocessing).
    pub fn standardize(&mut self) {
        self.x.standardize_columns();
    }

    /// λ_max for the L1-SVM problem: `max_j Σ_i |x_ij|` (§2.2.2).
    ///
    /// For λ ≥ λ_max the all-zero coefficient vector is optimal.
    pub fn lambda_max_l1(&self) -> f64 {
        let mut colsums = vec![0.0; self.p()];
        self.x.abs_col_sums(&mut colsums);
        colsums.iter().fold(0.0f64, |m, &v| m.max(v))
    }

    /// λ_max for the Group-SVM problem: `max_g Σ_{j∈g} Σ_i |x_ij|` (eq. 18).
    pub fn lambda_max_group(&self, groups: &[Vec<usize>]) -> f64 {
        let mut colsums = vec![0.0; self.p()];
        self.x.abs_col_sums(&mut colsums);
        groups
            .iter()
            .map(|g| g.iter().map(|&j| colsums[j]).sum::<f64>())
            .fold(0.0f64, f64::max)
    }

    /// Counts of the two classes `(N₊, N₋)`.
    pub fn class_counts(&self) -> (usize, usize) {
        let pos = self.y.iter().filter(|&&v| v > 0.0).count();
        (pos, self.y.len() - pos)
    }
}

/// Dense or sparse design matrix with a unified operation set.
#[derive(Clone, Debug)]
pub enum Design {
    /// Row-major dense storage.
    Dense(Matrix),
    /// Dual-layout sparse storage (CSR for row ops, CSC for column ops).
    Sparse { csr: Csr, csc: Csc },
}

impl Design {
    /// Wrap a dense matrix.
    pub fn dense(m: Matrix) -> Self {
        Design::Dense(m)
    }

    /// Wrap a CSR matrix (builds the CSC twin).
    pub fn sparse(csr: Csr) -> Self {
        let csc = csr.to_csc();
        Design::Sparse { csr, csc }
    }

    /// Number of rows (samples).
    pub fn rows(&self) -> usize {
        match self {
            Design::Dense(m) => m.rows(),
            Design::Sparse { csr, .. } => csr.rows,
        }
    }

    /// Number of columns (features).
    pub fn cols(&self) -> usize {
        match self {
            Design::Dense(m) => m.cols(),
            Design::Sparse { csr, .. } => csr.cols,
        }
    }

    /// Whether the matrix is stored sparse.
    pub fn is_sparse(&self) -> bool {
        matches!(self, Design::Sparse { .. })
    }

    /// Stored nonzeros (= n·p for dense).
    pub fn nnz(&self) -> usize {
        match self {
            Design::Dense(m) => m.rows() * m.cols(),
            Design::Sparse { csr, .. } => csr.nnz(),
        }
    }

    /// Single entry (O(1) dense, O(log nnz_col) sparse).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        match self {
            Design::Dense(m) => m.get(i, j),
            Design::Sparse { csc, .. } => {
                let (idx, val) = csc.col(j);
                match idx.binary_search(&i) {
                    Ok(k) => val[k],
                    Err(_) => 0.0,
                }
            }
        }
    }

    /// `out = X v` (margins).
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        match self {
            Design::Dense(m) => m.matvec(v, out),
            Design::Sparse { csr, .. } => csr.matvec(v, out),
        }
    }

    /// `out = Xᵀ v` (pricing / gradients).
    pub fn tmatvec(&self, v: &[f64], out: &mut [f64]) {
        match self {
            Design::Dense(m) => m.tmatvec(v, out),
            Design::Sparse { csr, .. } => csr.tmatvec(v, out),
        }
    }

    /// Column-range slice of `Xᵀ v`: `out[k] = (Xᵀv)[j0 + k]`.
    ///
    /// This is the worker kernel of parallel pricing: each thread owns a
    /// contiguous feature range. Every output accumulates over samples in
    /// ascending row order (dense: register-tiled row-blocked sweep;
    /// sparse: CSC column dot), so results are independent of how the
    /// range is chunked.
    pub fn tmatvec_range(&self, v: &[f64], j0: usize, out: &mut [f64]) {
        assert_eq!(v.len(), self.rows());
        assert!(j0 + out.len() <= self.cols());
        match self {
            Design::Dense(m) => m.tmatvec_range(v, j0, out),
            Design::Sparse { csc, .. } => {
                for (k, o) in out.iter_mut().enumerate() {
                    *o = csc.col_dot(j0 + k, v);
                }
            }
        }
    }

    /// `out = Xᵀ v` over a row subset (`rows[k]` weighted by `v[k]`).
    pub fn tmatvec_rows(&self, rows: &[usize], v: &[f64], out: &mut [f64]) {
        match self {
            Design::Dense(m) => m.tmatvec_rows(rows, v, out),
            Design::Sparse { csr, .. } => csr.tmatvec_rows(rows, v, out),
        }
    }

    /// `out = Σ_k β[k] · X[:, cols[k]]` — margins when β is supported on a
    /// column subset (column generation's working set J).
    pub fn matvec_cols(&self, cols: &[usize], beta: &[f64], out: &mut [f64]) {
        assert_eq!(cols.len(), beta.len());
        assert_eq!(out.len(), self.rows());
        out.fill(0.0);
        match self {
            Design::Dense(m) => {
                for i in 0..m.rows() {
                    out[i] = m.row_dot_cols(i, cols, beta);
                }
            }
            Design::Sparse { csc, .. } => {
                for (k, &j) in cols.iter().enumerate() {
                    if beta[k] != 0.0 {
                        csc.col_axpy(j, beta[k], out);
                    }
                }
            }
        }
    }

    /// `out += alpha · X[:, j]` (incremental margin updates in block CD).
    pub fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        match self {
            Design::Dense(m) => {
                for i in 0..m.rows() {
                    out[i] += alpha * m.get(i, j);
                }
            }
            Design::Sparse { csc, .. } => csc.col_axpy(j, alpha, out),
        }
    }

    /// Column `j` as `(row, value)` pairs (dense: all rows).
    pub fn col_entries(&self, j: usize) -> Vec<(usize, f64)> {
        match self {
            Design::Dense(m) => (0..m.rows()).map(|i| (i, m.get(i, j))).collect(),
            Design::Sparse { csc, .. } => {
                let (idx, val) = csc.col(j);
                idx.iter().copied().zip(val.iter().copied()).collect()
            }
        }
    }

    /// Dot of column `j` with a dense vector over all rows.
    pub fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        match self {
            Design::Dense(m) => {
                // strided gather — four independent accumulators split
                // the FP dependency chain the stride otherwise serializes
                let n = m.rows();
                let chunks = n / 4;
                let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
                for k in 0..chunks {
                    let i = 4 * k;
                    s0 = fmadd(m.get(i, j), v[i], s0);
                    s1 = fmadd(m.get(i + 1, j), v[i + 1], s1);
                    s2 = fmadd(m.get(i + 2, j), v[i + 2], s2);
                    s3 = fmadd(m.get(i + 3, j), v[i + 3], s3);
                }
                let mut s = (s0 + s1) + (s2 + s3);
                for i in 4 * chunks..n {
                    s = fmadd(m.get(i, j), v[i], s);
                }
                s
            }
            Design::Sparse { csc, .. } => csc.col_dot(j, v),
        }
    }

    /// Stored entries in column `j` (= rows for dense).
    pub fn col_nnz(&self, j: usize) -> usize {
        match self {
            Design::Dense(m) => m.rows(),
            Design::Sparse { csc, .. } => csc.indptr[j + 1] - csc.indptr[j],
        }
    }

    /// Monotone cumulative stored-entry count of columns `[0, j)` —
    /// `work_prefix(0) = 0`, `work_prefix(cols()) = nnz()`. The parallel
    /// kernels binary-search this prefix for nnz-balanced column splits
    /// (for sparse designs it is just the CSC `indptr`).
    pub fn work_prefix(&self, j: usize) -> usize {
        match self {
            Design::Dense(m) => j * m.rows(),
            Design::Sparse { csc, .. } => csc.indptr[j],
        }
    }

    /// Estimated resident bytes of the stored matrix: `8·n·p` dense;
    /// values + row indices for both CSR and CSC layouts plus the two
    /// index pointers when sparse.
    pub fn resident_bytes(&self) -> usize {
        match self {
            Design::Dense(m) => 8 * m.rows() * m.cols(),
            Design::Sparse { csr, csc } => {
                16 * (csr.nnz() + csc.nnz()) + 8 * (csr.indptr.len() + csc.indptr.len())
            }
        }
    }

    /// Per-column sums of absolute values (λ_max computations).
    pub fn abs_col_sums(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.cols());
        out.fill(0.0);
        match self {
            Design::Dense(m) => {
                for i in 0..m.rows() {
                    for (j, v) in m.row(i).iter().enumerate() {
                        out[j] += v.abs();
                    }
                }
            }
            Design::Sparse { csr, .. } => {
                for (j, v) in csr.indices.iter().zip(&csr.data) {
                    out[*j] += v.abs();
                }
            }
        }
    }

    /// L2-standardize columns in place.
    pub fn standardize_columns(&mut self) {
        match self {
            Design::Dense(m) => {
                m.standardize_columns();
            }
            Design::Sparse { csr, csc } => {
                let norms = csr.col_norms();
                let scale: Vec<f64> =
                    norms.iter().map(|&n| if n > 0.0 { 1.0 / n } else { 1.0 }).collect();
                csr.scale_columns(&scale);
                *csc = csr.to_csc();
            }
        }
    }

    /// Restrict to a subset of rows (used by the subsampling heuristics).
    pub fn subset_rows(&self, rows: &[usize]) -> Design {
        match self {
            Design::Dense(m) => {
                let mut out = Matrix::zeros(rows.len(), m.cols());
                for (k, &i) in rows.iter().enumerate() {
                    out.row_mut(k).copy_from_slice(m.row(i));
                }
                Design::Dense(out)
            }
            Design::Sparse { csr, .. } => {
                let mut coo = crate::sparse::Coo::new(rows.len(), csr.cols);
                for (k, &i) in rows.iter().enumerate() {
                    let (idx, val) = csr.row(i);
                    for (j, v) in idx.iter().zip(val) {
                        coo.push(k, *j, *v);
                    }
                }
                Design::sparse(coo.to_csr())
            }
        }
    }

    /// Restrict to a subset of columns (correlation screening).
    pub fn subset_cols(&self, cols: &[usize]) -> Design {
        match self {
            Design::Dense(m) => {
                let mut out = Matrix::zeros(m.rows(), cols.len());
                for i in 0..m.rows() {
                    let src = m.row(i);
                    let dst = out.row_mut(i);
                    for (k, &j) in cols.iter().enumerate() {
                        dst[k] = src[j];
                    }
                }
                Design::Dense(out)
            }
            Design::Sparse { csc, .. } => {
                let mut coo = crate::sparse::Coo::new(csc.rows, cols.len());
                for (k, &j) in cols.iter().enumerate() {
                    let (idx, val) = csc.col(j);
                    for (i, v) in idx.iter().zip(val) {
                        coo.push(*i, k, *v);
                    }
                }
                Design::sparse(coo.to_csr())
            }
        }
    }

    /// Stack selected rows of `self` on top of selected rows of `other`.
    /// Column counts must match. Dense × dense stays dense; any sparse
    /// operand yields a sparse result. The serve layer's incremental
    /// `update` op uses this to derive a dataset from a registered
    /// parent (retired samples dropped, appended samples drawn from
    /// another registered dataset) in a single pass.
    pub fn stack_rows(&self, rows: &[usize], other: &Design, other_rows: &[usize]) -> Design {
        assert_eq!(self.cols(), other.cols(), "stack_rows: column counts differ");
        let total = rows.len() + other_rows.len();
        if let (Design::Dense(a), Design::Dense(b)) = (self, other) {
            let mut out = Matrix::zeros(total, a.cols());
            for (k, &i) in rows.iter().enumerate() {
                out.row_mut(k).copy_from_slice(a.row(i));
            }
            for (k, &i) in other_rows.iter().enumerate() {
                out.row_mut(rows.len() + k).copy_from_slice(b.row(i));
            }
            return Design::Dense(out);
        }
        fn push_rows(coo: &mut crate::sparse::Coo, d: &Design, src: &[usize], base: usize) {
            for (k, &i) in src.iter().enumerate() {
                match d {
                    Design::Dense(m) => {
                        for (j, &v) in m.row(i).iter().enumerate() {
                            if v != 0.0 {
                                coo.push(base + k, j, v);
                            }
                        }
                    }
                    Design::Sparse { csr, .. } => {
                        let (idx, val) = csr.row(i);
                        for (j, v) in idx.iter().zip(val) {
                            coo.push(base + k, *j, *v);
                        }
                    }
                }
            }
        }
        let mut coo = crate::sparse::Coo::new(total, self.cols());
        push_rows(&mut coo, self, rows, 0);
        push_rows(&mut coo, other, other_rows, rows.len());
        Design::sparse(coo.to_csr())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn dense_ds() -> Dataset {
        let m = Matrix::from_vec(3, 2, vec![1.0, -2.0, 0.0, 3.0, -1.0, 1.0]);
        Dataset { x: Design::dense(m), y: vec![1.0, -1.0, 1.0] }
    }

    fn sparse_ds() -> Dataset {
        let mut coo = Coo::new(3, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, -2.0);
        coo.push(1, 1, 3.0);
        coo.push(2, 0, -1.0);
        coo.push(2, 1, 1.0);
        Dataset { x: Design::sparse(coo.to_csr()), y: vec![1.0, -1.0, 1.0] }
    }

    #[test]
    fn dense_sparse_op_parity() {
        let d = dense_ds();
        let s = sparse_ds();
        let v = [0.5, -1.5];
        let mut od = vec![0.0; 3];
        let mut os = vec![0.0; 3];
        d.x.matvec(&v, &mut od);
        s.x.matvec(&v, &mut os);
        assert_eq!(od, os);

        let w = [1.0, 2.0, 3.0];
        let mut td = vec![0.0; 2];
        let mut ts = vec![0.0; 2];
        d.x.tmatvec(&w, &mut td);
        s.x.tmatvec(&w, &mut ts);
        assert_eq!(td, ts);

        let mut rd = vec![0.0; 2];
        let mut rs = vec![0.0; 2];
        d.x.tmatvec_rows(&[2, 0], &[1.0, -1.0], &mut rd);
        s.x.tmatvec_rows(&[2, 0], &[1.0, -1.0], &mut rs);
        assert_eq!(rd, rs);

        let mut md = vec![0.0; 3];
        let mut ms = vec![0.0; 3];
        d.x.matvec_cols(&[1], &[2.0], &mut md);
        s.x.matvec_cols(&[1], &[2.0], &mut ms);
        assert_eq!(md, ms);

        assert_eq!(d.x.col_dot(0, &w), s.x.col_dot(0, &w));
        assert_eq!(d.x.get(1, 1), s.x.get(1, 1));
        assert_eq!(d.x.get(1, 0), s.x.get(1, 0));
    }

    #[test]
    fn tmatvec_range_matches_full() {
        for ds in [dense_ds(), sparse_ds()] {
            let v = [1.0, 2.0, -0.5];
            let mut full = vec![0.0; 2];
            ds.x.tmatvec(&v, &mut full);
            // single-column ranges
            for j0 in 0..2 {
                let mut one = vec![0.0; 1];
                ds.x.tmatvec_range(&v, j0, &mut one);
                assert_eq!(one[0], full[j0]);
            }
            // whole range in one chunk
            let mut all = vec![0.0; 2];
            ds.x.tmatvec_range(&v, 0, &mut all);
            assert_eq!(all, full);
            // empty range is a no-op
            let mut none: Vec<f64> = Vec::new();
            ds.x.tmatvec_range(&v, 2, &mut none);
        }
    }

    #[test]
    fn nnz_accounting_dense_and_sparse() {
        let d = dense_ds();
        let s = sparse_ds();
        assert_eq!(d.x.col_nnz(0), 3);
        assert_eq!(s.x.col_nnz(0), 2);
        assert_eq!(s.x.col_nnz(1), 3);
        for x in [&d.x, &s.x] {
            assert_eq!(x.work_prefix(0), 0);
            assert_eq!(x.work_prefix(x.cols()), x.nnz());
            for j in 0..x.cols() {
                assert_eq!(x.work_prefix(j + 1) - x.work_prefix(j), x.col_nnz(j));
            }
        }
        assert_eq!(d.x.resident_bytes(), 8 * 3 * 2);
        assert_eq!(s.x.resident_bytes(), 16 * 2 * 5 + 8 * (4 + 3));
    }

    #[test]
    fn lambda_max_matches_definition() {
        let d = dense_ds();
        // |col0| sums: 1+0+1 = 2 ; |col1|: 2+3+1 = 6
        assert!((d.lambda_max_l1() - 6.0).abs() < 1e-12);
        let lg = d.lambda_max_group(&[vec![0], vec![1]]);
        assert!((lg - 6.0).abs() < 1e-12);
        let lg_all = d.lambda_max_group(&[vec![0, 1]]);
        assert!((lg_all - 8.0).abs() < 1e-12);
    }

    #[test]
    fn stack_rows_dense_sparse_combinations() {
        let d = dense_ds();
        let s = sparse_ds();
        // Dense × dense stays dense and preserves row order.
        let dd = d.x.stack_rows(&[0, 2], &d.x, &[1]);
        assert!(matches!(dd, Design::Dense(_)));
        assert_eq!(dd.rows(), 3);
        assert_eq!(dd.get(0, 1), d.x.get(0, 1));
        assert_eq!(dd.get(1, 0), d.x.get(2, 0));
        assert_eq!(dd.get(2, 1), d.x.get(1, 1));
        // A sparse operand (either side) yields sparse with the same values.
        for (a, b) in [(&d.x, &s.x), (&s.x, &d.x), (&s.x, &s.x)] {
            let m = a.stack_rows(&[2, 1], b, &[0, 2]);
            assert!(matches!(m, Design::Sparse { .. }));
            assert_eq!(m.rows(), 4);
            for j in 0..2 {
                assert_eq!(m.get(0, j), a.get(2, j));
                assert_eq!(m.get(1, j), a.get(1, j));
                assert_eq!(m.get(2, j), b.get(0, j));
                assert_eq!(m.get(3, j), b.get(2, j));
            }
        }
        // Empty selections are fine.
        let empty = d.x.stack_rows(&[], &s.x, &[1]);
        assert_eq!(empty.rows(), 1);
        assert_eq!(empty.get(0, 1), s.x.get(1, 1));
    }

    #[test]
    fn subsetting_rows_and_cols() {
        for ds in [dense_ds(), sparse_ds()] {
            let r = ds.x.subset_rows(&[2, 0]);
            assert_eq!(r.rows(), 2);
            assert_eq!(r.get(0, 1), ds.x.get(2, 1));
            let c = ds.x.subset_cols(&[1]);
            assert_eq!(c.cols(), 1);
            assert_eq!(c.get(1, 0), ds.x.get(1, 1));
        }
    }

    #[test]
    fn standardize_both_layouts() {
        for mut ds in [dense_ds(), sparse_ds()] {
            ds.standardize();
            let mut sums = vec![0.0; 2];
            // column norms must be 1
            for j in 0..2 {
                let col: Vec<f64> = (0..3).map(|i| ds.x.get(i, j)).collect();
                sums[j] = col.iter().map(|v| v * v).sum::<f64>().sqrt();
            }
            assert!((sums[0] - 1.0).abs() < 1e-12);
            assert!((sums[1] - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn class_counts() {
        assert_eq!(dense_ds().class_counts(), (2, 1));
    }
}
