//! Reader / writer for the libsvm text format (`label idx:val idx:val …`,
//! 1-based feature indices), the lingua franca for the sparse datasets the
//! paper's Table 3 uses (rcv1.binary, real-sim).

use crate::data::{Dataset, Design};
use crate::sparse::Coo;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Parse errors for the libsvm format.
#[derive(Debug)]
pub enum LibsvmError {
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// Unparseable label token.
    BadLabel { line: usize, token: String },
    /// Unparseable `idx:val` token.
    BadFeature { line: usize, token: String },
    /// Feature indices are 1-based in the format.
    ZeroIndex { line: usize },
}

impl std::fmt::Display for LibsvmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LibsvmError::Io(e) => write!(f, "io error: {e}"),
            LibsvmError::BadLabel { line, token } => {
                write!(f, "line {line}: bad label {token:?}")
            }
            LibsvmError::BadFeature { line, token } => {
                write!(f, "line {line}: bad feature token {token:?}")
            }
            LibsvmError::ZeroIndex { line } => {
                write!(f, "line {line}: feature index must be >= 1")
            }
        }
    }
}

impl std::error::Error for LibsvmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LibsvmError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for LibsvmError {
    fn from(e: std::io::Error) -> Self {
        LibsvmError::Io(e)
    }
}

/// Parse a libsvm-format reader into a sparse [`Dataset`]. Labels are
/// mapped to ±1 (any value > 0 → +1). `min_cols` lets callers force the
/// feature-space width when a split file doesn't mention trailing features.
pub fn read<R: BufRead>(reader: R, min_cols: usize) -> Result<Dataset, LibsvmError> {
    read_impl(reader, min_cols, true)
}

/// Like [`read`], but labels are kept as-is — the loading path for
/// regression-style responses (RankSVM relevance scores, Dantzig-selector
/// targets), where coercing `y` to ±1 would destroy the problem.
pub fn read_raw<R: BufRead>(reader: R, min_cols: usize) -> Result<Dataset, LibsvmError> {
    read_impl(reader, min_cols, false)
}

fn read_impl<R: BufRead>(
    reader: R,
    min_cols: usize,
    map_labels: bool,
) -> Result<Dataset, LibsvmError> {
    let mut rows: Vec<(f64, Vec<(usize, f64)>)> = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut toks = line.split_whitespace();
        let label_tok = toks.next().unwrap();
        let label: f64 = label_tok
            .parse()
            .map_err(|_| LibsvmError::BadLabel { line: lineno + 1, token: label_tok.into() })?;
        let label = if map_labels {
            if label > 0.0 {
                1.0
            } else {
                -1.0
            }
        } else {
            label
        };
        let mut feats = Vec::new();
        for t in toks {
            if t.starts_with('#') {
                break;
            }
            let (idx_s, val_s) = t
                .split_once(':')
                .ok_or_else(|| LibsvmError::BadFeature { line: lineno + 1, token: t.into() })?;
            let idx: usize = idx_s
                .parse()
                .map_err(|_| LibsvmError::BadFeature { line: lineno + 1, token: t.into() })?;
            if idx == 0 {
                return Err(LibsvmError::ZeroIndex { line: lineno + 1 });
            }
            let val: f64 = val_s
                .parse()
                .map_err(|_| LibsvmError::BadFeature { line: lineno + 1, token: t.into() })?;
            max_col = max_col.max(idx);
            feats.push((idx - 1, val));
        }
        rows.push((label, feats));
    }
    let p = max_col.max(min_cols);
    let mut coo = Coo::new(rows.len(), p);
    let mut y = Vec::with_capacity(rows.len());
    for (i, (label, feats)) in rows.into_iter().enumerate() {
        y.push(label);
        for (j, v) in feats {
            coo.push(i, j, v);
        }
    }
    Ok(Dataset { x: Design::sparse(coo.to_csr()), y })
}

/// Read a libsvm file from disk (labels mapped to ±1).
pub fn read_file<P: AsRef<Path>>(path: P, min_cols: usize) -> Result<Dataset, LibsvmError> {
    let f = std::fs::File::open(path)?;
    read(std::io::BufReader::new(f), min_cols)
}

/// Read a libsvm file from disk keeping raw labels (see [`read_raw`]).
pub fn read_file_raw<P: AsRef<Path>>(path: P, min_cols: usize) -> Result<Dataset, LibsvmError> {
    let f = std::fs::File::open(path)?;
    read_raw(std::io::BufReader::new(f), min_cols)
}

/// Write a (sparse or dense) dataset in libsvm format.
pub fn write_file<P: AsRef<Path>>(ds: &Dataset, path: P) -> Result<(), LibsvmError> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.n() {
        // ±1 labels keep the conventional tokens; anything else (RankSVM
        // relevances, regression targets) round-trips verbatim
        if ds.y[i] == 1.0 {
            write!(w, "+1")?;
        } else if ds.y[i] == -1.0 {
            write!(w, "-1")?;
        } else {
            write!(w, "{}", ds.y[i])?;
        }
        match &ds.x {
            Design::Dense(m) => {
                for (j, v) in m.row(i).iter().enumerate() {
                    if *v != 0.0 {
                        write!(w, " {}:{}", j + 1, v)?;
                    }
                }
            }
            Design::Sparse { csr, .. } => {
                let (idx, val) = csr.row(i);
                for (j, v) in idx.iter().zip(val) {
                    write!(w, " {}:{}", j + 1, v)?;
                }
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic() {
        let text = "+1 1:0.5 3:-2\n-1 2:1.0\n";
        let ds = read(Cursor::new(text), 0).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.p(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.x.get(0, 0), 0.5);
        assert_eq!(ds.x.get(0, 2), -2.0);
        assert_eq!(ds.x.get(1, 1), 1.0);
    }

    #[test]
    fn parse_skips_comments_and_blank_lines() {
        let text = "# header\n\n+1 1:1\n";
        let ds = read(Cursor::new(text), 0).unwrap();
        assert_eq!(ds.n(), 1);
    }

    #[test]
    fn parse_respects_min_cols() {
        let ds = read(Cursor::new("+1 1:1\n"), 10).unwrap();
        assert_eq!(ds.p(), 10);
    }

    #[test]
    fn labels_mapped_to_pm1() {
        let ds = read(Cursor::new("3 1:1\n0 1:1\n-2 1:1\n"), 0).unwrap();
        assert_eq!(ds.y, vec![1.0, -1.0, -1.0]);
    }

    #[test]
    fn raw_labels_survive_read_and_roundtrip() {
        let ds = read_raw(Cursor::new("3.5 1:1\n0 1:1\n-2 2:0.5\n"), 0).unwrap();
        assert_eq!(ds.y, vec![3.5, 0.0, -2.0]);
        // raw responses round-trip through the writer
        let path = std::env::temp_dir().join("cutgen_libsvm_raw_roundtrip.txt");
        write_file(&ds, &path).unwrap();
        let back = read_file_raw(&path, ds.p()).unwrap();
        assert_eq!(back.y, ds.y);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn errors_are_reported() {
        assert!(matches!(
            read(Cursor::new("abc 1:1\n"), 0),
            Err(LibsvmError::BadLabel { line: 1, .. })
        ));
        assert!(matches!(
            read(Cursor::new("+1 nonsense\n"), 0),
            Err(LibsvmError::BadFeature { line: 1, .. })
        ));
        assert!(matches!(
            read(Cursor::new("+1 0:2\n"), 0),
            Err(LibsvmError::ZeroIndex { line: 1 })
        ));
    }

    #[test]
    fn roundtrip_through_disk() {
        use crate::rng::Xoshiro256;
        let mut rng = Xoshiro256::seed_from_u64(6);
        let spec = crate::data::synthetic::SparseTextSpec {
            n: 20,
            p: 50,
            density: 0.1,
            k0: 5,
            zipf: 1.0,
        };
        let ds = crate::data::synthetic::generate_sparse_text(&spec, &mut rng);
        let path = std::env::temp_dir().join("cutgen_libsvm_roundtrip.txt");
        write_file(&ds, &path).unwrap();
        let back = read_file(&path, ds.p()).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.y, ds.y);
        for i in 0..ds.n() {
            for j in 0..ds.p() {
                assert!((back.x.get(i, j) - ds.x.get(i, j)).abs() < 1e-12);
            }
        }
        std::fs::remove_file(path).ok();
    }
}
