//! Synthetic dataset generators reproducing the paper's experimental
//! setups (§5.1.1, §5.2) plus sparse text-like data standing in for the
//! rcv1 / real-sim corpora of Table 3 (see DESIGN.md §Substitutions).

use crate::data::{Dataset, Design};
use crate::linalg::Matrix;
use crate::rng::Xoshiro256;
use crate::sparse::Coo;

/// Parameters of the §5.1.1 generator: equicorrelated Gaussian features,
/// two classes with opposite means on the first `k0` coordinates.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    /// Number of samples (half per class; `n` odd puts the extra in +1).
    pub n: usize,
    /// Number of features.
    pub p: usize,
    /// Number of informative features (paper uses 10).
    pub k0: usize,
    /// Pairwise feature correlation ρ (paper uses 0.1).
    pub rho: f64,
    /// Standardize columns to unit L2 norm (paper default: yes).
    pub standardize: bool,
}

impl SyntheticSpec {
    /// The paper's default configuration at a given size.
    pub fn paper_default(n: usize, p: usize) -> Self {
        Self { n, p, k0: 10, rho: 0.1, standardize: true }
    }
}

/// Draw a dataset from the §5.1.1 model.
///
/// Features: `x_i ~ N(±μ, Σ)` with `Σ_jj = 1`, `Σ_jk = ρ (j≠k)`;
/// `μ = (1_{k0}, 0_{p−k0})`, sign by class. The equicorrelated Gaussian is
/// sampled as `√ρ·z + √(1−ρ)·ε_j` with a shared `z` per sample — exact and
/// O(np) instead of a p×p Cholesky.
pub fn generate_l1(spec: &SyntheticSpec, rng: &mut Xoshiro256) -> Dataset {
    let SyntheticSpec { n, p, k0, rho, standardize } = *spec;
    assert!(k0 <= p);
    let sr = rho.max(0.0).sqrt();
    let se = (1.0 - rho.max(0.0)).sqrt();
    let mut m = Matrix::zeros(n, p);
    let mut y = vec![0.0; n];
    let n_pos = n - n / 2;
    for i in 0..n {
        let label = if i < n_pos { 1.0 } else { -1.0 };
        y[i] = label;
        let shared = rng.normal();
        let row = m.row_mut(i);
        for j in 0..p {
            let mean = if j < k0 { label } else { 0.0 };
            row[j] = mean + sr * shared + se * rng.normal();
        }
    }
    let mut ds = Dataset { x: Design::dense(m), y };
    if standardize {
        ds.standardize();
    }
    ds
}

/// Group-structured generator (§5.2): `G` disjoint groups of size `p_g`;
/// within-group correlation ρ, independence across groups; the first
/// `k0_groups` groups are informative (mean ±1 on every coordinate).
#[derive(Clone, Debug)]
pub struct GroupSpec {
    pub n: usize,
    /// Number of groups.
    pub n_groups: usize,
    /// Size of each group.
    pub group_size: usize,
    /// Number of informative groups.
    pub k0_groups: usize,
    /// Within-group correlation.
    pub rho: f64,
    pub standardize: bool,
}

/// Generated group dataset: the data plus the group index sets.
pub struct GroupDataset {
    pub data: Dataset,
    /// `groups[g]` = column indices of group `g` (disjoint, covering `[p]`).
    pub groups: Vec<Vec<usize>>,
}

/// Draw from the group model.
pub fn generate_group(spec: &GroupSpec, rng: &mut Xoshiro256) -> GroupDataset {
    let GroupSpec { n, n_groups, group_size, k0_groups, rho, standardize } = *spec;
    let p = n_groups * group_size;
    let sr = rho.max(0.0).sqrt();
    let se = (1.0 - rho.max(0.0)).sqrt();
    let mut m = Matrix::zeros(n, p);
    let mut y = vec![0.0; n];
    let n_pos = n - n / 2;
    for i in 0..n {
        let label = if i < n_pos { 1.0 } else { -1.0 };
        y[i] = label;
        let row = m.row_mut(i);
        for g in 0..n_groups {
            let shared = rng.normal(); // one latent factor per group
            let mean = if g < k0_groups { label } else { 0.0 };
            for k in 0..group_size {
                row[g * group_size + k] = mean + sr * shared + se * rng.normal();
            }
        }
    }
    let groups: Vec<Vec<usize>> = (0..n_groups)
        .map(|g| ((g * group_size)..((g + 1) * group_size)).collect())
        .collect();
    let mut data = Dataset { x: Design::dense(m), y };
    if standardize {
        data.standardize();
    }
    GroupDataset { data, groups }
}

/// Sparse text-classification-like generator standing in for rcv1 /
/// real-sim (Table 3). Feature document-frequencies follow a power law
/// (Zipf-like, as in bag-of-words data); a small informative subset
/// carries class signal; entries are positive tf-idf-like weights.
#[derive(Clone, Debug)]
pub struct SparseTextSpec {
    pub n: usize,
    pub p: usize,
    /// Expected fraction of nonzero entries (rcv1 ≈ 0.0016).
    pub density: f64,
    /// Number of informative features.
    pub k0: usize,
    /// Zipf exponent for feature popularity.
    pub zipf: f64,
}

impl SparseTextSpec {
    /// rcv1.binary-like dimensions, scaled by `scale` (1.0 = full size).
    pub fn rcv1_like(scale: f64) -> Self {
        Self {
            n: (20_242.0 * scale) as usize,
            p: (47_236.0 * scale) as usize,
            density: 0.0016,
            k0: 50,
            zipf: 1.1,
        }
    }

    /// real-sim-like dimensions.
    pub fn real_sim_like(scale: f64) -> Self {
        Self {
            n: (72_309.0 * scale) as usize,
            p: (20_958.0 * scale) as usize,
            density: 0.0025,
            k0: 50,
            zipf: 1.1,
        }
    }
}

/// Draw a sparse dataset. Each document draws `~density·p` features from a
/// Zipf popularity distribution; informative features are over-sampled in
/// one class and carry a signed weight bump.
pub fn generate_sparse_text(spec: &SparseTextSpec, rng: &mut Xoshiro256) -> Dataset {
    let SparseTextSpec { n, p, density, k0, zipf } = *spec;
    // Precompute a Zipf sampler via inverse-CDF on cumulative weights.
    let mut cum = Vec::with_capacity(p);
    let mut total = 0.0;
    for j in 0..p {
        total += 1.0 / ((j + 1) as f64).powf(zipf);
        cum.push(total);
    }
    let nnz_per_row = ((density * p as f64).round() as usize).max(2);
    let mut coo = Coo::new(n, p);
    let mut y = vec![0.0; n];
    let n_pos = n - n / 2;
    for i in 0..n {
        let label = if i < n_pos { 1.0 } else { -1.0 };
        y[i] = label;
        let mut seen = std::collections::HashSet::new();
        for _ in 0..nnz_per_row {
            let u = rng.uniform() * total;
            let j = match cum.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
                Ok(k) => k,
                Err(k) => k.min(p - 1),
            };
            if seen.insert(j) {
                // tf-idf-like positive weight
                let w = (1.0 + rng.uniform() * 3.0).ln() + 0.1;
                let signal = if j < k0 { 0.5 * label } else { 0.0 };
                coo.push(i, j, w + signal);
            }
        }
        // Guarantee some informative mass in each document.
        let j_sig = rng.below(k0.max(1));
        if seen.insert(j_sig) {
            coo.push(i, j_sig, 0.75 * label + 1.0);
        }
    }
    Dataset { x: Design::sparse(coo.to_csr()), y }
}

/// Parameters of the RankSVM generator: equicorrelated Gaussian features
/// and a *real-valued* relevance score `y_i = Σ_{j<k0} x_ij + noise·ε_i`
/// — `y` is an ordering signal, not a ±1 class label.
#[derive(Clone, Debug)]
pub struct RankSpec {
    /// Number of samples.
    pub n: usize,
    /// Number of features.
    pub p: usize,
    /// Number of informative features (relevance drivers).
    pub k0: usize,
    /// Pairwise feature correlation ρ.
    pub rho: f64,
    /// Standard deviation of the additive relevance noise.
    pub noise: f64,
    /// Standardize columns to unit L2 norm.
    pub standardize: bool,
}

/// Draw a ranking dataset: features as in §5.1.1 (equicorrelated
/// Gaussian, zero mean), relevance `y` from a sparse linear model. The
/// relevance is computed on the raw features *before* standardization —
/// only the ordering of `y` matters to RankSVM.
pub fn generate_ranksvm(spec: &RankSpec, rng: &mut Xoshiro256) -> Dataset {
    let RankSpec { n, p, k0, rho, noise, standardize } = *spec;
    assert!(k0 <= p);
    let sr = rho.max(0.0).sqrt();
    let se = (1.0 - rho.max(0.0)).sqrt();
    let mut m = Matrix::zeros(n, p);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let shared = rng.normal();
        let row = m.row_mut(i);
        for j in 0..p {
            row[j] = sr * shared + se * rng.normal();
        }
        let signal: f64 = row[..k0].iter().sum();
        y[i] = signal + noise * rng.normal();
    }
    let mut ds = Dataset { x: Design::dense(m), y };
    if standardize {
        ds.standardize();
    }
    ds
}

/// Parameters of the Dantzig-selector generator: a sparse linear
/// regression `y = Xβ* + σ·ε` with `β*_j = (−1)^j` on the first `k0`
/// coordinates — the setting of Mazumder, Wright & Zheng
/// (arXiv:1908.06515). `y` is a real-valued response.
#[derive(Clone, Debug)]
pub struct DantzigSpec {
    /// Number of samples.
    pub n: usize,
    /// Number of features.
    pub p: usize,
    /// Support size of β*.
    pub k0: usize,
    /// Pairwise feature correlation ρ.
    pub rho: f64,
    /// Noise standard deviation σ.
    pub sigma: f64,
    /// Standardize columns to unit L2 norm.
    pub standardize: bool,
}

/// Draw a regression dataset from the Dantzig-selector model. The
/// response is computed on the raw features before standardization (the
/// estimator never needs the true β* back on the standardized scale).
pub fn generate_dantzig(spec: &DantzigSpec, rng: &mut Xoshiro256) -> Dataset {
    let DantzigSpec { n, p, k0, rho, sigma, standardize } = *spec;
    assert!(k0 <= p);
    let sr = rho.max(0.0).sqrt();
    let se = (1.0 - rho.max(0.0)).sqrt();
    let mut m = Matrix::zeros(n, p);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let shared = rng.normal();
        let row = m.row_mut(i);
        for j in 0..p {
            row[j] = sr * shared + se * rng.normal();
        }
        let mut signal = 0.0;
        for j in 0..k0 {
            signal += if j % 2 == 0 { row[j] } else { -row[j] };
        }
        y[i] = signal + sigma * rng.normal();
    }
    let mut ds = Dataset { x: Design::dense(m), y };
    if standardize {
        ds.standardize();
    }
    ds
}

/// Microarray-like dense generator used as the Table 2 stand-in
/// (leukemia / lung / ovarian / radsens): tiny n, large p, a handful of
/// differentially-expressed genes, heavier correlation than §5.1.1.
pub fn generate_microarray_like(n: usize, p: usize, rng: &mut Xoshiro256) -> Dataset {
    let spec = SyntheticSpec { n, p, k0: 20, rho: 0.3, standardize: true };
    generate_l1(&spec, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l1_generator_shapes_and_labels() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let ds = generate_l1(&SyntheticSpec::paper_default(50, 200), &mut rng);
        assert_eq!(ds.n(), 50);
        assert_eq!(ds.p(), 200);
        let (pos, neg) = ds.class_counts();
        assert_eq!(pos, 25);
        assert_eq!(neg, 25);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn l1_generator_standardized() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let ds = generate_l1(&SyntheticSpec::paper_default(40, 30), &mut rng);
        for j in 0..ds.p() {
            let norm: f64 =
                (0..ds.n()).map(|i| ds.x.get(i, j).powi(2)).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn informative_features_correlate_with_labels() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let spec = SyntheticSpec { n: 200, p: 50, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        // <x_j, y> should be much larger for informative features.
        let mut cors = vec![0.0; ds.p()];
        ds.x.tmatvec(&ds.y, &mut cors);
        let info: f64 = cors[..5].iter().map(|v| v.abs()).sum::<f64>() / 5.0;
        let noise: f64 = cors[5..].iter().map(|v| v.abs()).sum::<f64>() / 45.0;
        assert!(info > 3.0 * noise, "info {info} noise {noise}");
    }

    #[test]
    fn group_generator_structure() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let spec = GroupSpec {
            n: 30,
            n_groups: 8,
            group_size: 5,
            k0_groups: 2,
            rho: 0.2,
            standardize: true,
        };
        let gd = generate_group(&spec, &mut rng);
        assert_eq!(gd.data.p(), 40);
        assert_eq!(gd.groups.len(), 8);
        let all: Vec<usize> = gd.groups.iter().flatten().copied().collect();
        let set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(set.len(), 40, "groups must partition [p]");
    }

    #[test]
    fn sparse_text_density_and_signal() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let spec = SparseTextSpec { n: 400, p: 2000, density: 0.005, k0: 20, zipf: 1.1 };
        let ds = generate_sparse_text(&spec, &mut rng);
        assert!(ds.x.is_sparse());
        let frac = ds.x.nnz() as f64 / (400.0 * 2000.0);
        assert!(frac > 0.001 && frac < 0.02, "density {frac}");
        // informative block carries signal
        let mut cors = vec![0.0; ds.p()];
        ds.x.tmatvec(&ds.y, &mut cors);
        let info: f64 = cors[..20].iter().map(|v| v.abs()).sum::<f64>() / 20.0;
        let noise: f64 = cors[20..].iter().map(|v| v.abs()).sum::<f64>() / 1980.0;
        assert!(info > 3.0 * noise, "info {info} noise {noise}");
    }

    #[test]
    fn ranksvm_generator_relevance_signal() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        // ρ = 0 here: with all-positive relevance weights, the shared
        // equicorrelation factor leaks signal into every feature, which
        // would blur the informative/noise contrast this test checks.
        let spec = RankSpec { n: 150, p: 40, k0: 5, rho: 0.0, noise: 0.2, standardize: true };
        let ds = generate_ranksvm(&spec, &mut rng);
        assert_eq!(ds.n(), 150);
        assert_eq!(ds.p(), 40);
        // y is real-valued (not ±1) and correlates with informative features
        assert!(ds.y.iter().any(|&v| v != 1.0 && v != -1.0));
        let mut cors = vec![0.0; ds.p()];
        ds.x.tmatvec(&ds.y, &mut cors);
        let info: f64 = cors[..5].iter().map(|v| v.abs()).sum::<f64>() / 5.0;
        let noise: f64 = cors[5..].iter().map(|v| v.abs()).sum::<f64>() / 35.0;
        assert!(info > 3.0 * noise, "info {info} noise {noise}");
    }

    #[test]
    fn dantzig_generator_signed_support() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let spec = DantzigSpec { n: 200, p: 50, k0: 6, rho: 0.1, sigma: 0.3, standardize: true };
        let ds = generate_dantzig(&spec, &mut rng);
        let mut cors = vec![0.0; ds.p()];
        ds.x.tmatvec(&ds.y, &mut cors);
        // alternating-sign support: correlations of the first k0 features
        // carry the sign pattern of β* = (+,−,+,−,…)
        for j in 0..6 {
            let expect = if j % 2 == 0 { 1.0 } else { -1.0 };
            assert!(cors[j] * expect > 0.0, "cors[{j}] = {} sign mismatch", cors[j]);
        }
        let info: f64 = cors[..6].iter().map(|v| v.abs()).sum::<f64>() / 6.0;
        let noise: f64 = cors[6..].iter().map(|v| v.abs()).sum::<f64>() / 44.0;
        assert!(info > 3.0 * noise, "info {info} noise {noise}");
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SyntheticSpec::paper_default(20, 15);
        let a = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(9));
        let b = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(9));
        for i in 0..20 {
            for j in 0..15 {
                assert_eq!(a.x.get(i, j), b.x.get(i, j));
            }
        }
    }
}
