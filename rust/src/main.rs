//! `cutgen` binary — leader entry point for the cutting-plane SVM stack.

fn main() {
    let args = match cutgen::cli::parse_args(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            cutgen::obs::stderr_line(&format!("error: {e}"));
            std::process::exit(2);
        }
    };
    if let Err(e) = cutgen::cli::main_with(args) {
        cutgen::obs::stderr_line(&format!("error: {e:#}"));
        std::process::exit(1);
    }
}
