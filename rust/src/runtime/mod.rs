//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! Python never runs at serve time: `make artifacts` lowers the JAX/Pallas
//! kernels once to `artifacts/*.hlo.txt`; this module compiles them on the
//! PJRT CPU client (`xla` crate) and exposes:
//!
//! * [`PjrtRuntime`] — compiled executables (one per artifact);
//! * [`PjrtBackend`] — a [`crate::backend::Backend`] implementation that keeps the design
//!   matrix as device-resident f32 tiles and runs `Xβ` / `Xᵀv` through
//!   the Pallas `xb` / `xtv` executables, padding and looping tiles so a
//!   single fixed-shape artifact serves every (n, p);
//! * [`FusedHingeGrad`] — the fused Layer-2 gradient artifact (value +
//!   ∇β + ∇β₀ in one round-trip) for problems that fit one tile.
//!
//! The whole XLA-touching surface is gated behind the **`pjrt` cargo
//! feature** (the offline image carries no `xla` crate). Without it, an
//! API-compatible stub is compiled instead: `artifacts_available()`
//! reports `false`, constructors return a descriptive error, and every
//! caller — `cutgen doctor`, `--backend pjrt`, the parity tests — degrades
//! gracefully. The artifact-manifest parser below is always built (and
//! unit-tested) regardless of the feature.

use std::path::PathBuf;

use crate::error::{Context, Result};

/// Artifact manifest (parsed from `meta.json`).
#[derive(Clone, Copy, Debug)]
pub struct Meta {
    /// Tile height (samples).
    pub tn: usize,
    /// Tile width (features).
    pub tp: usize,
}

/// Minimal extraction of `"key": <int>` from the machine-generated
/// manifest; avoids dragging a JSON crate into the image. Strict about
/// shape: the value must be a bare unsigned integer terminated by a JSON
/// delimiter (`,`, `}`, `]`) or whitespace/EOF — `512abc`, `"512"`, or a
/// missing number are errors, not silent truncations.
pub(crate) fn json_usize(text: &str, key: &str) -> Result<usize> {
    let pat = format!("\"{key}\"");
    let at = text.find(&pat).with_context(|| format!("meta.json: missing key {key}"))?;
    let rest = text[at + pat.len()..].trim_start();
    let rest = rest
        .strip_prefix(':')
        .with_context(|| format!("meta.json: expected ':' after key {key}"))?
        .trim_start();
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    if end == 0 {
        crate::bail!("meta.json: no integer value for key {key}");
    }
    let (digits, tail) = rest.split_at(end);
    if let Some(c) = tail.chars().next() {
        if !matches!(c, ',' | '}' | ']') && !c.is_ascii_whitespace() {
            crate::bail!("meta.json: trailing garbage {c:?} after value of key {key}");
        }
    }
    digits.parse().with_context(|| format!("meta.json: bad integer for key {key}"))
}

/// Parse the tile-shape manifest written by `make artifacts`.
pub fn parse_meta(text: &str) -> Result<Meta> {
    Ok(Meta { tn: json_usize(text, "tn")?, tp: json_usize(text, "tp")? })
}

/// Default artifact location: `$CUTGEN_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("CUTGEN_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::path::{Path, PathBuf};

    use super::Meta;
    use crate::backend::Backend;
    use crate::data::Design;
    use crate::err;
    use crate::error::{Context, Result};

    /// Compiled PJRT executables for all artifacts.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        /// Tile shape the artifacts were lowered for.
        pub meta: Meta,
        xtv: xla::PjRtLoadedExecutable,
        xb: xla::PjRtLoadedExecutable,
        hinge_grad: xla::PjRtLoadedExecutable,
    }

    impl PjrtRuntime {
        /// Load and compile every artifact in `dir` (written by `make
        /// artifacts`).
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref();
            let meta_text = std::fs::read_to_string(dir.join("meta.json")).with_context(
                || format!("reading {}/meta.json — run `make artifacts`", dir.display()),
            )?;
            let meta = super::parse_meta(&meta_text)?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| err!("creating PJRT CPU client: {e:?}"))?;
            let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
                let path = dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| err!("parsing {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client.compile(&comp).map_err(|e| err!("compiling {name}: {e:?}"))
            };
            Ok(Self {
                xtv: compile("xtv")?,
                xb: compile("xb")?,
                hinge_grad: compile("hinge_grad")?,
                client,
                meta,
            })
        }

        /// Default artifact location: `$CUTGEN_ARTIFACTS` or `<crate>/artifacts`.
        pub fn default_dir() -> PathBuf {
            super::default_artifact_dir()
        }

        /// Whether artifacts exist at the default location.
        pub fn artifacts_available() -> bool {
            Self::default_dir().join("meta.json").exists()
        }

        /// PJRT platform name (for logs).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn buffer_1d(&self, data: &[f32]) -> Result<xla::PjRtBuffer> {
            self.client
                .buffer_from_host_buffer(data, &[data.len()], None)
                .map_err(|e| err!("host→device transfer: {e:?}"))
        }

        fn buffer_2d(&self, data: &[f32], rows: usize, cols: usize) -> Result<xla::PjRtBuffer> {
            self.client
                .buffer_from_host_buffer(data, &[rows, cols], None)
                .map_err(|e| err!("host→device transfer: {e:?}"))
        }
    }

    fn tuple_outputs(mut outs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<xla::Literal>> {
        let buf = outs
            .pop()
            .and_then(|mut v| if v.is_empty() { None } else { Some(v.remove(0)) })
            .ok_or_else(|| err!("executable produced no output"))?;
        let lit = buf.to_literal_sync().map_err(|e| err!("device→host: {e:?}"))?;
        lit.to_tuple().map_err(|e| err!("untupling output: {e:?}"))
    }

    fn literal_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| err!("literal to_vec: {e:?}"))
    }

    /// A [`Backend`] that runs the matvec hot paths through the AOT
    /// executables, with the design matrix resident on the (CPU) device as
    /// f32 tiles of shape `(tn, tp)`.
    pub struct PjrtBackend<'r> {
        rt: &'r PjrtRuntime,
        /// `tiles[ti][tj]` — device buffer for row-block ti, col-block tj.
        tiles: Vec<Vec<xla::PjRtBuffer>>,
        n: usize,
        p: usize,
        nt_rows: usize,
        nt_cols: usize,
    }

    impl<'r> PjrtBackend<'r> {
        /// Tile, pad (with zeros) and upload a design matrix.
        pub fn new(rt: &'r PjrtRuntime, design: &Design) -> Result<Self> {
            let (tn, tp) = (rt.meta.tn, rt.meta.tp);
            let n = design.rows();
            let p = design.cols();
            let nt_rows = n.div_ceil(tn);
            let nt_cols = p.div_ceil(tp);
            let mut tiles = Vec::with_capacity(nt_rows);
            let mut scratch = vec![0f32; tn * tp];
            for ti in 0..nt_rows {
                let mut row = Vec::with_capacity(nt_cols);
                for tj in 0..nt_cols {
                    scratch.fill(0.0);
                    let i_hi = ((ti + 1) * tn).min(n);
                    let j_hi = ((tj + 1) * tp).min(p);
                    for i in ti * tn..i_hi {
                        let local_i = i - ti * tn;
                        for j in tj * tp..j_hi {
                            let v = design.get(i, j);
                            if v != 0.0 {
                                scratch[local_i * tp + (j - tj * tp)] = v as f32;
                            }
                        }
                    }
                    row.push(rt.buffer_2d(&scratch, tn, tp)?);
                }
                tiles.push(row);
            }
            Ok(Self { rt, tiles, n, p, nt_rows, nt_cols })
        }

        fn xb_impl(&self, beta: &[f64], out: &mut [f64]) -> Result<()> {
            let (tn, tp) = (self.rt.meta.tn, self.rt.meta.tp);
            out.fill(0.0);
            let mut beta_tile = vec![0f32; tp];
            for tj in 0..self.nt_cols {
                // skip all-zero β tiles (cheap sparsity win on CG iterates)
                let j_lo = tj * tp;
                let j_hi = ((tj + 1) * tp).min(self.p);
                beta_tile.fill(0.0);
                let mut any = false;
                for j in j_lo..j_hi {
                    let b = beta[j];
                    if b != 0.0 {
                        beta_tile[j - j_lo] = b as f32;
                        any = true;
                    }
                }
                if !any {
                    continue;
                }
                let beta_buf = self.rt.buffer_1d(&beta_tile)?;
                for ti in 0..self.nt_rows {
                    let outs = self
                        .rt
                        .xb
                        .execute_b(&[&self.tiles[ti][tj], &beta_buf])
                        .map_err(|e| err!("xb execute: {e:?}"))?;
                    let parts = tuple_outputs(outs)?;
                    let m = literal_f32(&parts[0])?;
                    let i_lo = ti * tn;
                    let i_hi = ((ti + 1) * tn).min(self.n);
                    for i in i_lo..i_hi {
                        out[i] += m[i - i_lo] as f64;
                    }
                }
            }
            Ok(())
        }

        fn xtv_impl(&self, v: &[f64], out: &mut [f64]) -> Result<()> {
            let (tn, tp) = (self.rt.meta.tn, self.rt.meta.tp);
            out.fill(0.0);
            let mut v_tile = vec![0f32; tn];
            for ti in 0..self.nt_rows {
                let i_lo = ti * tn;
                let i_hi = ((ti + 1) * tn).min(self.n);
                v_tile.fill(0.0);
                let mut any = false;
                for i in i_lo..i_hi {
                    if v[i] != 0.0 {
                        v_tile[i - i_lo] = v[i] as f32;
                        any = true;
                    }
                }
                if !any {
                    continue; // dual vectors are sparse: whole sample blocks skip
                }
                let v_buf = self.rt.buffer_1d(&v_tile)?;
                for tj in 0..self.nt_cols {
                    let outs = self
                        .rt
                        .xtv
                        .execute_b(&[&self.tiles[ti][tj], &v_buf])
                        .map_err(|e| err!("xtv execute: {e:?}"))?;
                    let parts = tuple_outputs(outs)?;
                    let q = literal_f32(&parts[0])?;
                    let j_lo = tj * tp;
                    let j_hi = ((tj + 1) * tp).min(self.p);
                    for j in j_lo..j_hi {
                        out[j] += q[j - j_lo] as f64;
                    }
                }
            }
            Ok(())
        }
    }

    // NOTE: `Backend: Sync` is a supertrait (for parallel pricing), so
    // this impl only compiles if the vendored `xla` bindings mark the
    // buffer/executable types `Sync`. If they do not, re-enabling the
    // `pjrt` feature requires either an `unsafe impl Sync` here (justified
    // by PJRT's thread-compatible execution contract) or dropping this
    // Backend impl in favor of a dedicated single-threaded path. The
    // pricer itself never chunks this backend across threads anyway:
    // `supports_range_pricing()` is false (the default), so pricing
    // degrades to one serial `xtv` call.
    impl Backend for PjrtBackend<'_> {
        fn rows(&self) -> usize {
            self.n
        }
        fn cols(&self) -> usize {
            self.p
        }
        fn xb(&self, beta: &[f64], out: &mut [f64]) {
            self.xb_impl(beta, out).expect("PJRT xb failed");
        }
        fn xtv(&self, v: &[f64], out: &mut [f64]) {
            self.xtv_impl(v, out).expect("PJRT xtv failed");
        }
        fn name(&self) -> &'static str {
            "pjrt"
        }
    }

    /// The fused Layer-2 artifact: smoothed-hinge value + gradient for a
    /// problem that fits a single tile (n ≤ tn, p ≤ tp).
    pub struct FusedHingeGrad<'r> {
        rt: &'r PjrtRuntime,
        x_buf: xla::PjRtBuffer,
        y_buf: xla::PjRtBuffer,
        n: usize,
        p: usize,
    }

    impl<'r> FusedHingeGrad<'r> {
        /// Upload (padded) data once.
        pub fn new(rt: &'r PjrtRuntime, design: &Design, y: &[f64]) -> Result<Self> {
            let (tn, tp) = (rt.meta.tn, rt.meta.tp);
            let n = design.rows();
            let p = design.cols();
            if n > tn || p > tp {
                return Err(err!("problem ({n}×{p}) exceeds the fused tile ({tn}×{tp})"));
            }
            let mut x = vec![0f32; tn * tp];
            for i in 0..n {
                for j in 0..p {
                    x[i * tp + j] = design.get(i, j) as f32;
                }
            }
            let mut yy = vec![0f32; tn];
            for i in 0..n {
                yy[i] = y[i] as f32;
            }
            Ok(Self { x_buf: rt.buffer_2d(&x, tn, tp)?, y_buf: rt.buffer_1d(&yy)?, rt, n, p })
        }

        /// One fused evaluation: `(F^τ, ∇β, ∇β₀)`.
        pub fn value_grad(
            &self,
            beta: &[f64],
            beta0: f64,
            tau: f64,
        ) -> Result<(f64, Vec<f64>, f64)> {
            let tp = self.rt.meta.tp;
            let mut b = vec![0f32; tp];
            for j in 0..self.p {
                b[j] = beta[j] as f32;
            }
            let b_buf = self.rt.buffer_1d(&b)?;
            let b0_buf = self.rt.buffer_1d(&[beta0 as f32])?;
            let tau_buf = self.rt.buffer_1d(&[tau as f32])?;
            let outs = self
                .rt
                .hinge_grad
                .execute_b(&[&self.x_buf, &self.y_buf, &b_buf, &b0_buf, &tau_buf])
                .map_err(|e| err!("hinge_grad execute: {e:?}"))?;
            let parts = tuple_outputs(outs)?;
            if parts.len() != 3 {
                return Err(err!("expected 3 outputs, got {}", parts.len()));
            }
            let value = literal_f32(&parts[0])?[0] as f64;
            let grad_full = literal_f32(&parts[1])?;
            let grad_beta: Vec<f64> = grad_full[..self.p].iter().map(|&v| v as f64).collect();
            let grad_b0 = literal_f32(&parts[2])?[0] as f64;
            Ok((value, grad_beta, grad_b0))
        }

        /// Number of live samples.
        pub fn n(&self) -> usize {
            self.n
        }
    }

    /// Smoke helper used by the CLI `doctor` command.
    pub fn smoke() -> Result<String> {
        let client = xla::PjRtClient::cpu().map_err(|e| err!("{e:?}"))?;
        Ok(client.platform_name())
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{smoke, FusedHingeGrad, PjrtBackend, PjrtRuntime};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::{Path, PathBuf};

    use super::Meta;
    use crate::backend::Backend;
    use crate::data::Design;
    use crate::error::Result;

    const MSG: &str = "cutgen was built without the `pjrt` feature; rebuild with \
                       `--features pjrt` (requires the vendored `xla` crate)";

    /// Stub runtime: same API surface, always unavailable.
    pub struct PjrtRuntime {
        /// Tile shape placeholder (never populated — `load` always fails).
        pub meta: Meta,
    }

    impl PjrtRuntime {
        /// Always fails: the build carries no PJRT client.
        pub fn load(_dir: impl AsRef<Path>) -> Result<Self> {
            Err(crate::err!("{MSG}"))
        }

        /// Default artifact location: `$CUTGEN_ARTIFACTS` or `<crate>/artifacts`.
        pub fn default_dir() -> PathBuf {
            super::default_artifact_dir()
        }

        /// Artifacts are never usable without the runtime.
        pub fn artifacts_available() -> bool {
            false
        }

        /// PJRT platform name (for logs).
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }
    }

    /// Stub backend: cannot be constructed.
    pub struct PjrtBackend<'r> {
        _rt: &'r PjrtRuntime,
    }

    impl<'r> PjrtBackend<'r> {
        /// Always fails: the build carries no PJRT client.
        pub fn new(_rt: &'r PjrtRuntime, _design: &Design) -> Result<Self> {
            Err(crate::err!("{MSG}"))
        }
    }

    impl Backend for PjrtBackend<'_> {
        fn rows(&self) -> usize {
            unreachable!("stub PjrtBackend cannot be constructed")
        }
        fn cols(&self) -> usize {
            unreachable!("stub PjrtBackend cannot be constructed")
        }
        fn xb(&self, _beta: &[f64], _out: &mut [f64]) {
            unreachable!("stub PjrtBackend cannot be constructed")
        }
        fn xtv(&self, _v: &[f64], _out: &mut [f64]) {
            unreachable!("stub PjrtBackend cannot be constructed")
        }
        fn name(&self) -> &'static str {
            "pjrt"
        }
    }

    /// Stub fused-gradient artifact: cannot be constructed.
    pub struct FusedHingeGrad<'r> {
        _rt: &'r PjrtRuntime,
    }

    impl<'r> FusedHingeGrad<'r> {
        /// Always fails: the build carries no PJRT client.
        pub fn new(_rt: &'r PjrtRuntime, _design: &Design, _y: &[f64]) -> Result<Self> {
            Err(crate::err!("{MSG}"))
        }

        /// Unreachable on the stub.
        pub fn value_grad(
            &self,
            _beta: &[f64],
            _beta0: f64,
            _tau: f64,
        ) -> Result<(f64, Vec<f64>, f64)> {
            unreachable!("stub FusedHingeGrad cannot be constructed")
        }

        /// Unreachable on the stub.
        pub fn n(&self) -> usize {
            unreachable!("stub FusedHingeGrad cannot be constructed")
        }
    }

    /// Smoke helper used by the CLI `doctor` command.
    pub fn smoke() -> Result<String> {
        Err(crate::err!("{MSG}"))
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{smoke, FusedHingeGrad, PjrtBackend, PjrtRuntime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_usize_extracts() {
        let t = r#"{"tn": 512, "tp":2048, "artifacts": {}}"#;
        assert_eq!(json_usize(t, "tn").unwrap(), 512);
        assert_eq!(json_usize(t, "tp").unwrap(), 2048);
        assert!(json_usize(t, "zz").is_err());
    }

    #[test]
    fn json_usize_accepts_whitespace_and_terminators() {
        assert_eq!(json_usize("{\"tn\"  :  7 }", "tn").unwrap(), 7);
        assert_eq!(json_usize("{\"tn\":7}", "tn").unwrap(), 7);
        assert_eq!(json_usize("{\"tn\":7,\"tp\":9}", "tn").unwrap(), 7);
        assert_eq!(json_usize("{\"a\":[1],\"tn\":3]", "tn").unwrap(), 3);
        assert_eq!(json_usize("\"tn\": 42", "tn").unwrap(), 42);
    }

    #[test]
    fn json_usize_rejects_missing_digits() {
        let e = json_usize(r#"{"tn": , "tp": 4}"#, "tn").unwrap_err();
        assert!(e.to_string().contains("no integer"), "{e}");
        let e = json_usize(r#"{"tn": "512"}"#, "tn").unwrap_err();
        assert!(e.to_string().contains("no integer"), "{e}");
        let e = json_usize(r#"{"tn": null}"#, "tn").unwrap_err();
        assert!(e.to_string().contains("no integer"), "{e}");
        let e = json_usize(r#"{"tn": -5}"#, "tn").unwrap_err();
        assert!(e.to_string().contains("no integer"), "{e}");
    }

    #[test]
    fn json_usize_rejects_trailing_garbage() {
        let e = json_usize(r#"{"tn": 512abc}"#, "tn").unwrap_err();
        assert!(e.to_string().contains("trailing garbage"), "{e}");
        let e = json_usize(r#"{"tn": 3.5}"#, "tn").unwrap_err();
        assert!(e.to_string().contains("trailing garbage"), "{e}");
    }

    #[test]
    fn json_usize_rejects_missing_colon() {
        let e = json_usize(r#"{"tn" 512}"#, "tn").unwrap_err();
        assert!(e.to_string().contains("expected ':'"), "{e}");
    }

    #[test]
    fn parse_meta_roundtrip() {
        let m = parse_meta(r#"{"tn": 512, "tp": 2048}"#).unwrap();
        assert_eq!(m.tn, 512);
        assert_eq!(m.tp, 2048);
        assert!(parse_meta(r#"{"tn": 512}"#).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_reports_unavailable() {
        assert!(!PjrtRuntime::artifacts_available());
        assert!(PjrtRuntime::load(PjrtRuntime::default_dir()).is_err());
        assert!(smoke().is_err());
        let msg = smoke().unwrap_err().to_string();
        assert!(msg.contains("pjrt"), "{msg}");
    }
}

/// Numeric-parity tests for the real PJRT runtime (f32 tiling/padding of
/// `xb`/`xtv`, the fused hinge gradient, and FISTA end-to-end). Compiled
/// only with the `pjrt` feature; they skip at runtime when `make
/// artifacts` has not produced the HLO files.
#[cfg(all(test, feature = "pjrt"))]
mod pjrt_tests {
    use super::*;
    use crate::backend::{Backend, NativeBackend};
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::fom::smoothing::{HingeWorkspace, SmoothedHinge};
    use crate::rng::Xoshiro256;

    fn runtime() -> Option<PjrtRuntime> {
        if !PjrtRuntime::artifacts_available() {
            let msg = "skipping PJRT test: artifacts not built (run `make artifacts`)";
            crate::obs::stderr_line(msg);
            return None;
        }
        Some(PjrtRuntime::load(PjrtRuntime::default_dir()).expect("load artifacts"))
    }

    #[test]
    fn pjrt_backend_matches_native() {
        let Some(rt) = runtime() else { return };
        let mut rng = Xoshiro256::seed_from_u64(181);
        // deliberately NOT tile-aligned: exercises padding
        let spec = SyntheticSpec { n: 300, p: 700, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let pjrt = PjrtBackend::new(&rt, &ds.x).expect("tile upload");
        let native = NativeBackend::new(&ds.x);

        let beta: Vec<f64> = (0..ds.p()).map(|_| rng.normal() * 0.1).collect();
        let mut out_p = vec![0.0; ds.n()];
        let mut out_n = vec![0.0; ds.n()];
        pjrt.xb(&beta, &mut out_p);
        native.xb(&beta, &mut out_n);
        for i in 0..ds.n() {
            assert!(
                (out_p[i] - out_n[i]).abs() < 1e-3,
                "xb[{i}]: pjrt {} native {}",
                out_p[i],
                out_n[i]
            );
        }

        let v: Vec<f64> = (0..ds.n()).map(|_| rng.uniform()).collect();
        let mut q_p = vec![0.0; ds.p()];
        let mut q_n = vec![0.0; ds.p()];
        pjrt.xtv(&v, &mut q_p);
        native.xtv(&v, &mut q_n);
        for j in 0..ds.p() {
            assert!(
                (q_p[j] - q_n[j]).abs() < 1e-3,
                "xtv[{j}]: pjrt {} native {}",
                q_p[j],
                q_n[j]
            );
        }
    }

    #[test]
    fn fused_hinge_grad_matches_native() {
        let Some(rt) = runtime() else { return };
        let mut rng = Xoshiro256::seed_from_u64(182);
        let spec = SyntheticSpec { n: 120, p: 300, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let fused = FusedHingeGrad::new(&rt, &ds.x, &ds.y).expect("upload");
        let beta: Vec<f64> = (0..ds.p()).map(|_| rng.normal() * 0.05).collect();
        let (val, grad, g0) = fused.value_grad(&beta, 0.1, 0.2).expect("exec");

        let native = NativeBackend::new(&ds.x);
        let sh = SmoothedHinge { tau: 0.2 };
        let mut ws = HingeWorkspace::new(ds.n());
        let mut grad_n = vec![0.0; ds.p()];
        let (val_n, g0_n) = sh.value_grad(&native, &ds.y, &beta, 0.1, &mut ws, &mut grad_n);
        assert!((val - val_n).abs() / val_n.abs().max(1.0) < 1e-3, "val {val} vs {val_n}");
        assert!((g0 - g0_n).abs() < 1e-3, "g0 {g0} vs {g0_n}");
        for j in 0..ds.p() {
            assert!((grad[j] - grad_n[j]).abs() < 1e-3, "grad[{j}]");
        }
    }

    #[test]
    fn pjrt_backend_drives_fista_to_same_objective() {
        let Some(rt) = runtime() else { return };
        let mut rng = Xoshiro256::seed_from_u64(183);
        let spec = SyntheticSpec { n: 100, p: 400, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let lambda = 0.05 * ds.lambda_max_l1();
        let params = crate::fom::FistaParams { max_iters: 60, eta: 1e-9, ..Default::default() };

        let native = NativeBackend::new(&ds.x);
        let res_native =
            crate::fom::fista(&native, &ds.y, &crate::fom::Penalty::L1(lambda), &params, None);

        let pjrt = PjrtBackend::new(&rt, &ds.x).expect("upload");
        let res_pjrt =
            crate::fom::fista(&pjrt, &ds.y, &crate::fom::Penalty::L1(lambda), &params, None);

        let rel = (res_pjrt.objective - res_native.objective).abs()
            / res_native.objective.max(1e-9);
        assert!(
            rel < 5e-3,
            "objectives diverge: pjrt {} native {}",
            res_pjrt.objective,
            res_native.objective
        );
    }
}
