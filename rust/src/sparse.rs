//! Compressed sparse matrices (CSR + CSC).
//!
//! Table 3 of the paper runs on large sparse text-classification data
//! (rcv1, real-sim); the LP model coefficient matrices and the pricing
//! matvecs must exploit that sparsity. We keep *both* layouts around:
//! CSR for row-oriented kernels (`Xβ`, sample subsetting) and CSC for
//! column-oriented ones (building LP columns, per-column reduced costs).

/// Triplet (COO) builder — accumulate entries in any order, then convert.
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub entries: Vec<(usize, usize, f64)>,
}

impl Coo {
    /// New empty builder with fixed dimensions.
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols, entries: Vec::new() }
    }

    /// Record `A[i, j] = v` (duplicates are summed on conversion).
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        if v != 0.0 {
            self.entries.push((i, j, v));
        }
    }

    /// Convert to CSR (sorts by row, then column; sums duplicates).
    pub fn to_csr(&self) -> Csr {
        let mut ent = self.entries.clone();
        ent.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(ent.len());
        let mut data: Vec<f64> = Vec::with_capacity(ent.len());
        for &(i, j, v) in &ent {
            indices.push(j);
            data.push(v);
            indptr[i + 1] = indices.len();
        }
        // Empty rows inherit the previous offset.
        for i in 0..self.rows {
            indptr[i + 1] = indptr[i + 1].max(indptr[i]);
        }
        let mut csr = Csr { rows: self.rows, cols: self.cols, indptr, indices, data };
        csr.merge_duplicates();
        csr
    }
}

/// Compressed sparse row matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub data: Vec<f64>,
}

impl Csr {
    /// Merge adjacent duplicate column indices within each row (assumes
    /// indices sorted within rows).
    fn merge_duplicates(&mut self) {
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(self.indices.len());
        let mut data = Vec::with_capacity(self.data.len());
        for i in 0..self.rows {
            let (s, e) = (self.indptr[i], self.indptr[i + 1]);
            let mut k = s;
            while k < e {
                let j = self.indices[k];
                let mut v = self.data[k];
                let mut k2 = k + 1;
                while k2 < e && self.indices[k2] == j {
                    v += self.data[k2];
                    k2 += 1;
                }
                if v != 0.0 {
                    indices.push(j);
                    data.push(v);
                }
                k = k2;
            }
            indptr[i + 1] = indices.len();
        }
        self.indptr = indptr;
        self.indices = indices;
        self.data = data;
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Row `i` as (column indices, values).
    #[inline]
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.data[s..e])
    }

    /// `out = A v`.
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            let mut s = 0.0;
            for (j, a) in idx.iter().zip(val) {
                s += a * v[*j];
            }
            out[i] = s;
        }
    }

    /// `out = Aᵀ v`.
    pub fn tmatvec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for i in 0..self.rows {
            let vi = v[i];
            if vi == 0.0 {
                continue;
            }
            let (idx, val) = self.row(i);
            for (j, a) in idx.iter().zip(val) {
                out[*j] += a * vi;
            }
        }
    }

    /// `out = Aᵀ v` over a row subset: rows[k] weighted by v[k].
    pub fn tmatvec_rows(&self, rows: &[usize], v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), rows.len());
        assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for (k, &i) in rows.iter().enumerate() {
            let vi = v[k];
            if vi == 0.0 {
                continue;
            }
            let (idx, val) = self.row(i);
            for (j, a) in idx.iter().zip(val) {
                out[*j] += a * vi;
            }
        }
    }

    /// Transpose into CSC layout (same matrix, column-compressed).
    pub fn to_csc(&self) -> Csc {
        let mut counts = vec![0usize; self.cols];
        for &j in &self.indices {
            counts[j] += 1;
        }
        let mut indptr = vec![0usize; self.cols + 1];
        for j in 0..self.cols {
            indptr[j + 1] = indptr[j] + counts[j];
        }
        let mut indices = vec![0usize; self.nnz()];
        let mut data = vec![0.0; self.nnz()];
        let mut next = indptr.clone();
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for (j, a) in idx.iter().zip(val) {
                let pos = next[*j];
                indices[pos] = i;
                data[pos] = *a;
                next[*j] += 1;
            }
        }
        Csc { rows: self.rows, cols: self.cols, indptr, indices, data }
    }

    /// Per-column L2 norms.
    pub fn col_norms(&self) -> Vec<f64> {
        let mut s = vec![0.0; self.cols];
        for (j, v) in self.indices.iter().zip(&self.data) {
            s[*j] += v * v;
        }
        s.iter().map(|x| x.sqrt()).collect()
    }

    /// Scale column `j` by `scale[j]` in place (feature standardization).
    pub fn scale_columns(&mut self, scale: &[f64]) {
        assert_eq!(scale.len(), self.cols);
        for (j, v) in self.indices.iter().zip(self.data.iter_mut()) {
            *v *= scale[*j];
        }
    }

    /// Dense row-major copy (tests / small problems only).
    pub fn to_dense(&self) -> crate::linalg::Matrix {
        let mut m = crate::linalg::Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for (j, a) in idx.iter().zip(val) {
                m.set(i, *j, *a);
            }
        }
        m
    }
}

/// Compressed sparse column matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub data: Vec<f64>,
}

impl Csc {
    /// Column `j` as (row indices, values).
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let (s, e) = (self.indptr[j], self.indptr[j + 1]);
        (&self.indices[s..e], &self.data[s..e])
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Dot of column `j` with a dense vector of length `rows`.
    ///
    /// Deliberately a single-accumulator ascending-row loop, and it must
    /// stay one: the serial sparse `Xᵀv` is the CSR scatter
    /// ([`Csr::tmatvec`]), which also feeds each output column its
    /// contributions one at a time in ascending row order. Keeping both
    /// reduction orders identical is what makes chunked parallel pricing
    /// (CSC range dots) bit-identical to the serial product at any
    /// thread count — a multi-accumulator tile here would trade that
    /// contract for a few percent on a gather-bound loop. See
    /// docs/kernels.md.
    pub fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        let (idx, val) = self.col(j);
        let mut s = 0.0;
        for (i, a) in idx.iter().zip(val) {
            s += a * v[*i];
        }
        s
    }

    /// `out += alpha * A[:, j]` scattered into a dense vector.
    pub fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        let (idx, val) = self.col(j);
        for (i, a) in idx.iter().zip(val) {
            out[*i] += alpha * a;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_csr() -> Csr {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(0, 2, 2.0);
        coo.push(2, 0, 3.0);
        coo.push(2, 1, 4.0);
        coo.to_csr()
    }

    #[test]
    fn coo_roundtrip_with_empty_row() {
        let a = sample_csr();
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.row(1), (&[][..], &[][..]));
        assert_eq!(a.row(2), (&[0usize, 1][..], &[3.0, 4.0][..]));
    }

    #[test]
    fn coo_sums_duplicates() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 1, 1.0);
        coo.push(0, 1, 2.5);
        let a = coo.to_csr();
        assert_eq!(a.nnz(), 1);
        assert_eq!(a.row(0), (&[1usize][..], &[3.5][..]));
    }

    #[test]
    fn matvec_matches_dense() {
        let a = sample_csr();
        let d = a.to_dense();
        let v = [1.0, -2.0, 0.5];
        let mut out_s = vec![0.0; 3];
        let mut out_d = vec![0.0; 3];
        a.matvec(&v, &mut out_s);
        d.matvec(&v, &mut out_d);
        assert_eq!(out_s, out_d);
    }

    #[test]
    fn tmatvec_matches_dense() {
        let a = sample_csr();
        let d = a.to_dense();
        let v = [1.0, 5.0, -1.0];
        let mut out_s = vec![0.0; 3];
        let mut out_d = vec![0.0; 3];
        a.tmatvec(&v, &mut out_s);
        d.tmatvec(&v, &mut out_d);
        assert_eq!(out_s, out_d);
    }

    #[test]
    fn tmatvec_rows_subset() {
        let a = sample_csr();
        let mut out = vec![0.0; 3];
        a.tmatvec_rows(&[2, 0], &[1.0, 10.0], &mut out);
        assert_eq!(out, vec![13.0, 4.0, 20.0]);
    }

    #[test]
    fn csc_roundtrip_and_col_ops() {
        let a = sample_csr();
        let c = a.to_csc();
        assert_eq!(c.nnz(), a.nnz());
        assert_eq!(c.col(0), (&[0usize, 2][..], &[1.0, 3.0][..]));
        assert_eq!(c.col_dot(0, &[1.0, 1.0, 2.0]), 7.0);
        let mut out = vec![0.0; 3];
        c.col_axpy(1, 2.0, &mut out);
        assert_eq!(out, vec![0.0, 0.0, 8.0]);
    }

    #[test]
    fn col_norms_and_scaling() {
        let mut a = sample_csr();
        let norms = a.col_norms();
        assert!((norms[0] - (10.0f64).sqrt()).abs() < 1e-12);
        let scale: Vec<f64> = norms.iter().map(|&n| if n > 0.0 { 1.0 / n } else { 1.0 }).collect();
        a.scale_columns(&scale);
        let after = a.col_norms();
        assert!((after[0] - 1.0).abs() < 1e-12);
        assert!((after[1] - 1.0).abs() < 1e-12);
        assert!((after[2] - 1.0).abs() < 1e-12);
    }
}
