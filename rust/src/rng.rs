//! Deterministic pseudo-random number generation.
//!
//! The offline build image carries no `rand` crate, so we implement the
//! small amount of randomness the library needs ourselves:
//!
//! * [`SplitMix64`] — seeding / stream splitting (Steele et al., 2014).
//! * [`Xoshiro256`] — xoshiro256++ main generator (Blackman & Vigna, 2019).
//! * Gaussian sampling via the polar Box–Muller transform.
//! * [`Fnv1a`] — the shared FNV-1a content-fingerprint primitive (serve
//!   registry, `PairSet` index space).
//!
//! Every experiment in the repository is seeded, so runs are reproducible
//! bit-for-bit across invocations.

/// SplitMix64: a tiny, high-quality 64-bit generator mainly used to expand
/// a user seed into the 256-bit state of [`Xoshiro256`].
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse generator.
///
/// Period 2^256 − 1, passes BigCrush; more than adequate for generating
/// synthetic benchmark datasets.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 expansion (the construction recommended by the
    /// xoshiro authors: never seed with all zeros).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (used to hand one RNG per thread).
    pub fn split(&mut self) -> Self {
        Self::seed_from_u64(self.next_u64())
    }

    /// Next 64 uniformly random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection-free-ish method;
    /// the bias for n ≪ 2^64 is negligible, but we keep the widening
    /// multiply form for speed).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via the polar (Marsaglia) Box–Muller method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Fill a slice with i.i.d. standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Incremental FNV-1a 64-bit hash — the shared content-fingerprint
/// primitive behind the serve registry's dataset fingerprints and the
/// `PairSet` index-space fingerprint. Deterministic and
/// platform-independent (byte-oriented), like everything else here.
#[derive(Clone, Debug)]
pub struct Fnv1a(u64);

impl Fnv1a {
    /// Fresh hasher at the FNV offset basis.
    pub fn new() -> Self {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    /// Fold bytes into the hash.
    pub fn eat(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    /// The current hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_reference_values() {
        // Public reference vectors for 64-bit FNV-1a.
        assert_eq!(Fnv1a::new().finish(), 0xcbf2_9ce4_8422_2325, "offset basis");
        let mut h = Fnv1a::new();
        h.eat(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h2 = Fnv1a::default();
        h2.eat(b"foo");
        h2.eat(b"bar");
        let mut h3 = Fnv1a::new();
        h3.eat(b"foobar");
        assert_eq!(h2.finish(), h3.finish(), "chunking must not matter");
        assert_eq!(h3.finish(), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn splitmix_reference_values() {
        // Reference values for seed 1234567 from the public-domain C code.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = a.split();
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_variance() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let n = 100_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            sum += u;
            sq += u * u;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 200_000;
        let (mut m1, mut m2, mut m4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m1 += x;
            m2 += x * x;
            m4 += x * x * x * x;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        m4 /= n as f64;
        assert!(m1.abs() < 0.01, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var {m2}");
        assert!((m4 - 3.0).abs() < 0.15, "kurtosis {m4}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn sample_indices_distinct_and_complete() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
        // k == n returns a permutation
        let all = r.sample_indices(15, 15);
        let set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(set.len(), 15);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
