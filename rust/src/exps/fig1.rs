//! **Figure 1** — L1-SVM at fixed λ = 0.01·λ_max, n = 100, varying p:
//! methods (a) RP-CLG, (b) FO+CLG (and CLG wo FO), (c) correlation-
//! screening init, (d) random init, (e) full LP solver.

use crate::baselines::full_lp::solve_full_l1;
use crate::data::synthetic::{generate_l1, SyntheticSpec};
use crate::exps::common::{fo_clg, init_clg, rp_clg};
use crate::exps::{ara_percent, fmt_time, mean_std, time_it, Scale, Table};
use crate::rng::Xoshiro256;

fn sizes(scale: Scale) -> (Vec<usize>, usize, usize, usize) {
    // (ps, n, reps, lp_cap)
    match scale {
        Scale::Smoke => (vec![300], 40, 1, 300),
        Scale::Default => (vec![1000, 5000, 20_000], 100, 2, 20_000),
        Scale::Paper => (vec![2000, 10_000, 50_000, 100_000], 100, 5, 100_000),
    }
}

/// Run Figure 1 (as a table: one row per (p, method)).
pub fn run(scale: Scale) -> String {
    let (ps, n, reps, lp_cap) = sizes(scale);
    let mut table = Table::new(
        "Figure 1 — L1-SVM fixed λ = 0.01·λ_max, n = 100, varying p",
        &["p", "method", "time (s)", "ARA (%)"],
    );
    let eps = 1e-2;

    for &p in &ps {
        let labels =
            ["(a) RP CLG", "(b) FO+CLG", "(b') CLG wo FO", "(c) Cor. screening", "(d) Random init", "(e) LP solver"];
        let mut times: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
        let mut objs: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();

        for rep in 0..reps {
            let spec = SyntheticSpec::paper_default(n, p);
            let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(2000 + rep as u64));
            let lambda = 0.01 * ds.lambda_max_l1();

            let (sol, t) = rp_clg(&ds, lambda, eps, 7);
            times.entry(labels[0]).or_default().push(t);
            objs.entry(labels[0]).or_default().push(sol.objective);

            let (sol, split) = fo_clg(&ds, lambda, eps, 100);
            times.entry(labels[1]).or_default().push(split.total());
            times.entry(labels[2]).or_default().push(split.cut);
            objs.entry(labels[1]).or_default().push(sol.objective);
            objs.entry(labels[2]).or_default().push(sol.objective);

            let (sol, t) = init_clg(&ds, lambda, eps, 50, false, 7 + rep as u64);
            times.entry(labels[3]).or_default().push(t);
            objs.entry(labels[3]).or_default().push(sol.objective);

            let (sol, t) = init_clg(&ds, lambda, eps, 50, true, 77 + rep as u64);
            times.entry(labels[4]).or_default().push(t);
            objs.entry(labels[4]).or_default().push(sol.objective);

            if p <= lp_cap {
                let (sol, t) = time_it(|| solve_full_l1(&ds, lambda));
                times.entry(labels[5]).or_default().push(t);
                objs.entry(labels[5]).or_default().push(sol.objective);
            }
        }

        let n_points = reps;
        let mut best = vec![f64::INFINITY; n_points];
        for v in objs.values() {
            if v.len() == n_points {
                for (b, o) in best.iter_mut().zip(v) {
                    *b = b.min(*o);
                }
            }
        }
        for label in labels {
            match times.get(label) {
                Some(ts) => {
                    let (m, s) = mean_std(ts);
                    let ara = ara_percent(&objs[label], &best);
                    table.row(vec![
                        p.to_string(),
                        label.to_string(),
                        fmt_time(m, s),
                        format!("{ara:.2}"),
                    ]);
                }
                None => table.row(vec![
                    p.to_string(),
                    label.to_string(),
                    "— (> cap)".into(),
                    "—".into(),
                ]),
            }
        }
    }
    let out = table.render();
    println!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_smoke() {
        let out = run(Scale::Smoke);
        assert!(out.contains("(b) FO+CLG"));
        assert!(out.contains("(e) LP solver"));
    }
}
