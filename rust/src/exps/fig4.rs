//! **Figure 4** — Group-SVM at fixed λ = 0.1·λ_max, n = 100, group size
//! 10, varying p: (i) RP CLG, (ii) FO+CLG (accelerated gradient),
//! (iii) FO BCD+CLG (block coordinate descent), (iv) full LP.
//!
//! The full Group-SVM LP carries n + p rows (margins + box rows), so the
//! dense basis caps it early — mirroring the paper where it is two to
//! three orders of magnitude slower than the CG methods.

use crate::backend::NativeBackend;
use crate::coordinator::group::{
    group_column_generation, initial_groups, GroupProblem, RestrictedGroup,
};
use crate::coordinator::GenParams;
use crate::data::synthetic::{generate_group, GroupSpec};
use crate::engine::{BackendPricer, GenEngine, InitStrategy, Initializer};
use crate::exps::{ara_percent, fmt_time, mean_std, time_it, Scale, Table};
use crate::fom::block_cd::BlockCdParams;
use crate::fom::fista::FistaParams;
use crate::rng::Xoshiro256;

fn sizes(scale: Scale) -> (usize, Vec<usize>, usize, usize) {
    // (n, ps, reps, lp_cap)
    match scale {
        Scale::Smoke => (40, vec![200], 1, 200),
        Scale::Default => (100, vec![2000, 10_000], 1, 2000),
        Scale::Paper => (100, vec![2000, 10_000, 50_000, 100_000], 3, 2000),
    }
}

const PG: usize = 10; // group size (paper)

/// FO (FISTA or BCD) init for group CG via the shared engine
/// initializer: screened groups, a low-accuracy local solve, top groups
/// by coefficient mass.
fn fo_group_init(
    gd: &crate::data::synthetic::GroupDataset,
    lambda: f64,
    use_bcd: bool,
) -> Vec<usize> {
    let strat = if use_bcd { InitStrategy::BlockCd } else { InitStrategy::Fista };
    Initializer::new(strat, 30)
        .with_fom(FistaParams { max_iters: 200, eta: 1e-3, ..Default::default() })
        .with_block_cd(BlockCdParams { max_sweeps: 60, tol: 1e-3, ..Default::default() })
        .seed_group(&gd.data, &gd.groups, lambda)
        .ws
        .cols
}

/// Run Figure 4.
pub fn run(scale: Scale) -> String {
    let (n, ps, reps, lp_cap) = sizes(scale);
    let mut table = Table::new(
        &format!("Figure 4 — Group-SVM fixed λ = 0.1·λ_max, n = {n}, group size {PG}"),
        &["p", "method", "time (s)", "ARA (%)"],
    );
    let eps = 1e-2;
    for &p in &ps {
        let n_groups = p / PG;
        let mut times: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
        let mut objs: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
        for rep in 0..reps {
            let spec = GroupSpec {
                n,
                n_groups,
                group_size: PG,
                k0_groups: 1,
                rho: 0.1,
                standardize: true,
            };
            let gd = generate_group(&spec, &mut Xoshiro256::seed_from_u64(8000 + rep as u64));
            let ds = &gd.data;
            let lambda = 0.1 * ds.lambda_max_group(&gd.groups);
            let backend = NativeBackend::new(&ds.x);
            let params = GenParams { eps, ..Default::default() };

            // (i) RP CLG: 6 equispaced λ values in [λ_max/2, λ]
            {
                let lmax = ds.lambda_max_group(&gd.groups);
                let grid: Vec<f64> = (0..6)
                    .map(|k| lmax / 2.0 - (lmax / 2.0 - lambda) * k as f64 / 5.0)
                    .collect();
                let (obj, t) = time_it(|| {
                    let pricer = BackendPricer::new(&backend, params.threads);
                    let rg = RestrictedGroup::new(
                        ds,
                        &gd.groups,
                        grid[0],
                        &initial_groups(ds, &gd.groups, 5),
                    );
                    let mut prob = GroupProblem::new(rg, ds, &pricer);
                    let engine = GenEngine::new(&params);
                    let mut last_obj = f64::NAN;
                    for &lam in &grid {
                        prob.set_lambda(lam);
                        engine.run(&mut prob);
                        last_obj = prob.inner().objective();
                    }
                    last_obj
                });
                times.entry("(i) RP CLG").or_default().push(t);
                objs.entry("(i) RP CLG").or_default().push(obj);
            }
            // (ii) FO+CLG (accelerated gradient init)
            {
                let ((sol, t_cut), t_all) = time_it(|| {
                    let init = fo_group_init(&gd, lambda, false);
                    time_it(|| {
                        group_column_generation(ds, &backend, &gd.groups, lambda, &init, &params)
                    })
                });
                times.entry("(ii) FO+CLG").or_default().push(t_all);
                times.entry("CLG wo FO").or_default().push(t_cut);
                objs.entry("(ii) FO+CLG").or_default().push(sol.objective);
                objs.entry("CLG wo FO").or_default().push(sol.objective);
            }
            // (iii) FO BCD+CLG
            {
                let ((sol, t_cut), t_all) = time_it(|| {
                    let init = fo_group_init(&gd, lambda, true);
                    time_it(|| {
                        group_column_generation(ds, &backend, &gd.groups, lambda, &init, &params)
                    })
                });
                times.entry("(iii) FO BCD+CLG").or_default().push(t_all);
                times.entry("CLG wo FO BCD").or_default().push(t_cut);
                objs.entry("(iii) FO BCD+CLG").or_default().push(sol.objective);
                objs.entry("CLG wo FO BCD").or_default().push(sol.objective);
            }
            // (iv) full LP (all groups)
            if p <= lp_cap {
                let (sol, t) = time_it(|| {
                    crate::baselines::full_lp::solve_full_group(ds, &gd.groups, lambda)
                });
                times.entry("(iv) LP solver").or_default().push(t);
                objs.entry("(iv) LP solver").or_default().push(sol.objective);
            }
        }
        let mut best = vec![f64::INFINITY; reps];
        for v in objs.values() {
            if v.len() == reps {
                for (b, o) in best.iter_mut().zip(v) {
                    *b = b.min(*o);
                }
            }
        }
        for label in
            ["(i) RP CLG", "(ii) FO+CLG", "CLG wo FO", "(iii) FO BCD+CLG", "CLG wo FO BCD", "(iv) LP solver"]
        {
            match times.get(label) {
                Some(ts) => {
                    let (m, s) = mean_std(ts);
                    let ara = ara_percent(&objs[label], &best);
                    table.row(vec![
                        p.to_string(),
                        label.to_string(),
                        fmt_time(m, s),
                        format!("{ara:.2}"),
                    ]);
                }
                None => table.row(vec![
                    p.to_string(),
                    label.to_string(),
                    "— (> cap)".into(),
                    "—".into(),
                ]),
            }
        }
    }
    let out = table.render();
    println!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_smoke() {
        let out = run(Scale::Smoke);
        assert!(out.contains("FO BCD+CLG"));
        assert!(out.contains("(iv) LP solver"));
    }
}
