//! **Figure 3** — L1-SVM with *both* n and p large, fixed λ =
//! 0.001·λ_max: the hybrid SFO+CL-CNG (Algorithm 4 + subsampling init)
//! vs the pure column-generation methods (a) RP-CLG and (b) FO+CLG.

use crate::data::synthetic::{generate_l1, SyntheticSpec};
use crate::exps::common::{fo_clg, rp_clg, sfo_cl_cng};
use crate::exps::{ara_percent, fmt_time, mean_std, Scale, Table};
use crate::rng::Xoshiro256;

fn sizes(scale: Scale) -> (usize, Vec<usize>, usize, usize) {
    // (n, ps, reps, rp_cap: skip RP-CLG beyond this p — it "explodes")
    match scale {
        Scale::Smoke => (300, vec![500], 1, 500),
        Scale::Default => (1000, vec![5000, 20_000], 1, 5000),
        Scale::Paper => (5000, vec![20_000, 50_000, 100_000], 3, 20_000),
    }
}

/// Run Figure 3.
pub fn run(scale: Scale) -> String {
    let (n, ps, reps, rp_cap) = sizes(scale);
    let mut table = Table::new(
        &format!("Figure 3 — L1-SVM fixed λ = 0.001·λ_max, n = {n}, varying p"),
        &["p", "method", "time (s)", "ARA (%)"],
    );
    for &p in &ps {
        let mut times: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
        let mut objs: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
        for rep in 0..reps {
            let spec = SyntheticSpec::paper_default(n, p);
            let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(5000 + rep as u64));
            let lambda = 0.001 * ds.lambda_max_l1();

            if p <= rp_cap {
                let (sol, t) = rp_clg(&ds, lambda, 1e-2, 7);
                times.entry("(a) RP CLG").or_default().push(t);
                objs.entry("(a) RP CLG").or_default().push(sol.objective);
            }
            let (sol, split) = fo_clg(&ds, lambda, 1e-2, 200);
            times.entry("(b) FO+CLG").or_default().push(split.total());
            objs.entry("(b) FO+CLG").or_default().push(sol.objective);

            let (sol, split) = sfo_cl_cng(&ds, lambda, 1e-2, 200, 13 + rep as u64);
            times.entry("(g) SFO+CL-CNG").or_default().push(split.total());
            times.entry("CL-CNG wo SFO").or_default().push(split.cut);
            objs.entry("(g) SFO+CL-CNG").or_default().push(sol.objective);
            objs.entry("CL-CNG wo SFO").or_default().push(sol.objective);
        }
        let mut best = vec![f64::INFINITY; reps];
        for v in objs.values() {
            if v.len() == reps {
                for (b, o) in best.iter_mut().zip(v) {
                    *b = b.min(*o);
                }
            }
        }
        for label in ["(a) RP CLG", "(b) FO+CLG", "(g) SFO+CL-CNG", "CL-CNG wo SFO"] {
            match times.get(label) {
                Some(ts) => {
                    let (m, s) = mean_std(ts);
                    let ara = ara_percent(&objs[label], &best);
                    table.row(vec![
                        p.to_string(),
                        label.to_string(),
                        fmt_time(m, s),
                        format!("{ara:.2}"),
                    ]);
                }
                None => table.row(vec![
                    p.to_string(),
                    label.to_string(),
                    "— (explodes)".into(),
                    "—".into(),
                ]),
            }
        }
    }
    let out = table.render();
    println!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_smoke() {
        let out = run(Scale::Smoke);
        assert!(out.contains("SFO+CL-CNG"));
    }
}
