//! **Table 6** — Slope-SVM with *distinct* BH-style weights
//! `λ_j = √(log(2p/j))·λ̃`: FO+CL-CNG vs a pure first-order method asked
//! for a high-accuracy solution (the CVXPY route crashes outright for
//! distinct weights — our A.2 model exceeds its row budget at p ≈ 80+).

use crate::backend::NativeBackend;
use crate::baselines::slope_full::solve_slope_full;
use crate::coordinator::slope::slope_column_constraint_generation;
use crate::coordinator::GenParams;
use crate::data::synthetic::{generate_l1, SyntheticSpec};
use crate::engine::init::fom_full;
use crate::exps::common::fo_slope_init;
use crate::exps::{ara_percent, fmt_time, mean_std, time_it, Scale, Table};
use crate::fom::fista::{FistaParams, Penalty};
use crate::fom::objective::{bh_slope_weights, slope_objective};
use crate::rng::Xoshiro256;

fn sizes(scale: Scale) -> (usize, Vec<usize>, usize) {
    match scale {
        Scale::Smoke => (30, vec![150], 1),
        Scale::Default => (100, vec![1000, 5000, 10_000], 2),
        Scale::Paper => (100, vec![10_000, 20_000, 50_000], 3),
    }
}

/// Run Table 6.
pub fn run(scale: Scale) -> String {
    let (n, ps, reps) = sizes(scale);
    let mut table = Table::new(
        "Table 6 — Slope-SVM, distinct BH weights λ_j = √(log(2p/j))·λ̃",
        &["p", "FO+CL-CNG (s)", "ARA (%)", "CL-CNG wo FO (s)", "FO-only (s)", "FO-only ARA (%)", "CVXPY-like"],
    );
    for &p in &ps {
        let mut t_cg = Vec::new();
        let mut t_cut = Vec::new();
        let mut t_fo = Vec::new();
        let mut o_cg = Vec::new();
        let mut o_fo = Vec::new();
        let mut cvxpy_ok = false;
        for rep in 0..reps {
            let spec = SyntheticSpec { n, p, k0: 10.min(p / 2), rho: 0.1, standardize: true };
            let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(9500 + rep as u64));
            let lambda_tilde = 0.01 * ds.lambda_max_l1();
            let lambda = bh_slope_weights(p, lambda_tilde);
            let backend = NativeBackend::new(&ds.x);

            let (init, t_init) = fo_slope_init(&ds, &lambda, 100);
            let (sol, t) = time_it(|| {
                slope_column_constraint_generation(
                    &ds,
                    &backend,
                    &lambda,
                    &init,
                    &GenParams { eps: 1e-2, max_cols_per_round: 10, ..Default::default() },
                )
            });
            t_cg.push(t + t_init);
            t_cut.push(t);
            o_cg.push(sol.objective);

            // first-order method pushed for accuracy (full p, many iters)
            let (fo_obj, t) = time_it(|| {
                let res = fom_full(
                    &backend,
                    &ds.y,
                    &Penalty::Slope(lambda.clone()),
                    &FistaParams {
                        tau: 0.2,
                        eta: 1e-8,
                        max_iters: 1500,
                        power_iters: 25,
                        ..Default::default()
                    },
                );
                slope_objective(&backend, &ds.y, &res.beta, res.beta0, &lambda)
            });
            t_fo.push(t);
            o_fo.push(fo_obj);

            if rep == 0 {
                cvxpy_ok = solve_slope_full(&ds, &lambda).is_some();
            }
        }
        let best: Vec<f64> = o_cg.iter().zip(&o_fo).map(|(a, b)| a.min(*b)).collect();
        let (mc, sc) = mean_std(&t_cg);
        let (mk, sk) = mean_std(&t_cut);
        let (mf, sf) = mean_std(&t_fo);
        table.row(vec![
            p.to_string(),
            fmt_time(mc, sc),
            format!("{:.2}", ara_percent(&o_cg, &best)),
            fmt_time(mk, sk),
            fmt_time(mf, sf),
            format!("{:.2}", ara_percent(&o_fo, &best)),
            if cvxpy_ok { "ok".into() } else { "— (crashed/row budget)".to_string() },
        ]);
    }
    let out = table.render();
    println!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_smoke() {
        let out = run(Scale::Smoke);
        assert!(out.contains("Table 6"));
    }
}
