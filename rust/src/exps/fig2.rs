//! **Figure 2** — L1-SVM at fixed λ for n ≫ p (p = 100): SFO+CNG
//! (subsampled first-order init + constraint generation) vs the full LP.
//!
//! The full LP holds all n margin rows, so its basis factorization is
//! O(n³) — beyond `lp_cap` we report “— (> cap)”, mirroring the paper's
//! time-outs for the full model.

use crate::baselines::full_lp::solve_full_l1;
use crate::data::synthetic::{generate_l1, SyntheticSpec};
use crate::exps::common::sfo_cng;
use crate::exps::{ara_percent, fmt_time, mean_std, time_it, Scale, Table};
use crate::rng::Xoshiro256;

fn sizes(scale: Scale) -> (Vec<usize>, usize, usize, usize) {
    // (ns, p, reps, lp_cap)
    match scale {
        Scale::Smoke => (vec![600], 20, 1, 600),
        Scale::Default => (vec![1000, 5000, 10_000], 100, 1, 2000),
        Scale::Paper => (vec![1000, 5000, 20_000, 50_000], 100, 3, 3000),
    }
}

/// Run Figure 2.
pub fn run(scale: Scale) -> String {
    let (ns, p, reps, lp_cap) = sizes(scale);
    let mut table = Table::new(
        "Figure 2 — L1-SVM fixed λ = 0.01·λ_max, p = 100, varying n",
        &["n", "method", "time (s)", "ARA (%)"],
    );
    for &n in &ns {
        let mut t_cng = Vec::new();
        let mut t_cng_only = Vec::new();
        let mut t_lp = Vec::new();
        let mut o_cng = Vec::new();
        let mut o_lp = Vec::new();
        for rep in 0..reps {
            let spec = SyntheticSpec::paper_default(n, p);
            let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(4000 + rep as u64));
            let lambda = 0.01 * ds.lambda_max_l1();
            let (sol, split) = sfo_cng(&ds, lambda, 1e-2, 5 + rep as u64);
            t_cng.push(split.total());
            t_cng_only.push(split.cut);
            o_cng.push(sol.objective);
            if n <= lp_cap {
                let (lp, t) = time_it(|| solve_full_l1(&ds, lambda));
                t_lp.push(t);
                o_lp.push(lp.objective);
            }
        }
        let best: Vec<f64> = (0..reps)
            .map(|r| {
                let mut b = o_cng[r];
                if r < o_lp.len() {
                    b = b.min(o_lp[r]);
                }
                b
            })
            .collect();
        let (m, s) = mean_std(&t_cng);
        table.row(vec![
            n.to_string(),
            "(f) SFO+CNG".into(),
            fmt_time(m, s),
            format!("{:.2}", ara_percent(&o_cng, &best)),
        ]);
        let (m, s) = mean_std(&t_cng_only);
        table.row(vec![n.to_string(), "CNG wo SFO".into(), fmt_time(m, s), "—".into()]);
        if o_lp.len() == reps {
            let (m, s) = mean_std(&t_lp);
            table.row(vec![
                n.to_string(),
                "(e) LP solver".into(),
                fmt_time(m, s),
                format!("{:.2}", ara_percent(&o_lp, &best)),
            ]);
        } else {
            table.row(vec![n.to_string(), "(e) LP solver".into(), "— (> cap)".into(), "—".into()]);
        }
    }
    let out = table.render();
    println!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_smoke() {
        let out = run(Scale::Smoke);
        assert!(out.contains("SFO+CNG"));
        assert!(out.contains("LP solver"));
    }
}
