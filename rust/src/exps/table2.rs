//! **Table 2** — L1-SVM at fixed λ on microarray-style real datasets
//! (p ≫ n): FO+CLG vs the full LP solver.
//!
//! The paper's four datasets (leukemia, lung cancer, ovarian, radsens)
//! are not redistributable in this image; matched-size synthetic
//! microarray-like data stands in (see DESIGN.md §Substitutions).

use crate::baselines::full_lp::solve_full_l1;
use crate::data::synthetic::generate_microarray_like;
use crate::exps::common::fo_clg;
use crate::exps::{ara_percent, fmt_time, mean_std, time_it, Scale, Table};
use crate::rng::Xoshiro256;

fn datasets(scale: Scale) -> Vec<(&'static str, usize, usize)> {
    match scale {
        Scale::Smoke => vec![("leukemia-like", 36, 700)],
        Scale::Default => vec![
            ("leukemia-like", 72, 7129),
            ("lung-cancer-like", 181, 12_533),
            ("ovarian-like", 253, 15_155),
            ("radsens-like", 58, 12_625),
        ],
        Scale::Paper => vec![
            ("leukemia-like", 72, 7129),
            ("lung-cancer-like", 181, 12_533),
            ("ovarian-like", 253, 15_155),
            ("radsens-like", 58, 12_625),
        ],
    }
}

/// Run Table 2.
pub fn run(scale: Scale) -> String {
    let reps = if scale == Scale::Smoke { 1 } else { 3 };
    let mut table = Table::new(
        "Table 2 — L1-SVM at λ = 0.01·λ_max on microarray-like data (p ≫ n)",
        &["dataset", "n", "p", "FO+CLG time (s)", "FO+CLG ARA (%)", "LP solver time (s)"],
    );
    for (name, n, p) in datasets(scale) {
        let mut t_fo = Vec::new();
        let mut t_lp = Vec::new();
        let mut o_fo = Vec::new();
        let mut o_lp = Vec::new();
        for rep in 0..reps {
            let ds =
                generate_microarray_like(n, p, &mut Xoshiro256::seed_from_u64(3000 + rep as u64));
            let lambda = 0.01 * ds.lambda_max_l1();
            let (sol, split) = fo_clg(&ds, lambda, 1e-2, 100);
            t_fo.push(split.total());
            o_fo.push(sol.objective);
            let (lp, t) = time_it(|| solve_full_l1(&ds, lambda));
            t_lp.push(t);
            o_lp.push(lp.objective);
        }
        let best: Vec<f64> = o_fo.iter().zip(&o_lp).map(|(a, b)| a.min(*b)).collect();
        let (mf, sf) = mean_std(&t_fo);
        let (ml, sl) = mean_std(&t_lp);
        table.row(vec![
            name.to_string(),
            n.to_string(),
            p.to_string(),
            fmt_time(mf, sf),
            format!("{:.2e}", ara_percent(&o_fo, &best)),
            fmt_time(ml, sl),
        ]);
    }
    let out = table.render();
    println!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_smoke() {
        let out = run(Scale::Smoke);
        assert!(out.contains("leukemia-like"));
    }
}
