//! Shared method runners for the experiment harness — each corresponds to
//! a labelled method in §5 ("FO+CLG", "SFO+CNG", "RP CLG", …).
//!
//! All first-order initialization routes through the shared engine layer
//! (`engine::Initializer`); this module only configures the strategies
//! with the paper's §5 hyperparameters and times the two stages.

use crate::backend::NativeBackend;
use crate::coordinator::l1svm::{
    column_constraint_generation, column_generation, constraint_generation,
};
use crate::coordinator::path::{geometric_grid, regularization_path};
use crate::coordinator::{GenParams, SvmSolution};
use crate::data::Dataset;
use crate::engine::{InitStrategy, Initializer};
use crate::exps::time_it;
use crate::fom::fista::FistaParams;
use crate::fom::screening::correlation_screen_backend;
use crate::fom::subsample::SubsampleParams;
use crate::rng::Xoshiro256;

/// Timing split of a two-stage method (initializer + cutting planes).
#[derive(Clone, Copy, Debug, Default)]
pub struct SplitTime {
    /// First-order / screening initialization seconds.
    pub init: f64,
    /// Cutting-plane seconds.
    pub cut: f64,
}

impl SplitTime {
    /// Total seconds.
    pub fn total(&self) -> f64 {
        self.init + self.cut
    }
}

/// Pricing thread count used by the shared method runners: the
/// `CUTGEN_THREADS` env var (set by `cutgen train --threads T`), else 1.
/// Thread count never changes results — see `engine::BackendPricer`.
pub fn pricing_threads() -> usize {
    std::env::var("CUTGEN_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(1)
}

/// The §5.1.1 FISTA settings (screened init, low accuracy by design).
fn fo_fista_params() -> FistaParams {
    FistaParams {
        tau: 0.2,
        eta: 1e-3,
        max_iters: 200,
        power_iters: 20,
        threads: pricing_threads(),
        fit_intercept: true,
    }
}

/// Method (b) "FO+CLG": correlation-screened FISTA init, then column
/// generation (§5.1.1). Returns the solution and the timing split.
pub fn fo_clg(
    ds: &Dataset,
    lambda: f64,
    eps: f64,
    keep_top: usize,
) -> (SvmSolution, SplitTime) {
    let backend = NativeBackend::new(&ds.x);
    let ini = Initializer::new(InitStrategy::Fista, keep_top).with_fom(fo_fista_params());
    // column-only: Algorithm 1 keeps every margin row in the model.
    // (The FOM support is kept as-is — up to keep_top surviving
    // coefficients — rather than zero-padded to exactly keep_top as the
    // pre-refactor harness did; padding columns carried no information.)
    let (seed, t_init) = time_it(|| ini.seed_l1_cols(ds, &backend, lambda));
    let (sol, t_cut) = time_it(|| {
        column_generation(
            ds,
            &backend,
            lambda,
            &seed.ws.cols,
            &GenParams { eps, threads: pricing_threads(), ..Default::default() },
        )
    });
    (sol, SplitTime { init: t_init, cut: t_cut })
}

/// Method (a) "RP CLG": regularization-path continuation down to λ
/// (7 grid points in [λ_max/2, λ], §5.1.1).
pub fn rp_clg(ds: &Dataset, lambda: f64, eps: f64, grid_points: usize) -> (SvmSolution, f64) {
    let backend = NativeBackend::new(&ds.x);
    let lmax = ds.lambda_max_l1();
    let hi = lmax / 2.0;
    let ratio = (lambda / hi).powf(1.0 / (grid_points.max(2) - 1) as f64);
    let grid: Vec<f64> = (0..grid_points).map(|k| hi * ratio.powi(k as i32)).collect();
    let ((_, sol), t) = time_it(|| {
        let params = GenParams { eps, threads: pricing_threads(), ..Default::default() };
        regularization_path(ds, &backend, &grid, &params)
    });
    (sol, t)
}

/// Method (c)/(d): column generation from a screening or random init.
pub fn init_clg(
    ds: &Dataset,
    lambda: f64,
    eps: f64,
    init_size: usize,
    random: bool,
    seed: u64,
) -> (SvmSolution, f64) {
    let backend = NativeBackend::new(&ds.x);
    let init: Vec<usize> = if random {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        rng.sample_indices(ds.p(), init_size.min(ds.p()))
    } else {
        correlation_screen_backend(&backend, &ds.y, init_size.min(ds.p()), pricing_threads())
    };
    time_it(|| {
        let params = GenParams { eps, threads: pricing_threads(), ..Default::default() };
        column_generation(ds, &backend, lambda, &init, &params)
    })
}

/// Method (f) "SFO+CNG": subsampled first-order init, then constraint
/// generation (§5.1.3).
pub fn sfo_cng(ds: &Dataset, lambda: f64, eps: f64, seed: u64) -> (SvmSolution, SplitTime) {
    let backend = NativeBackend::new(&ds.x);
    let subsample = SubsampleParams {
        n0: (10 * ds.p()).clamp(100, ds.n()),
        mu_tol: 1e-1,
        q_max: (ds.n() / (10 * ds.p()).max(1)).clamp(2, 12),
        threads: 4,
        screen_k: 0,
        fista: FistaParams {
            tau: 0.2,
            eta: 1e-3,
            max_iters: 150,
            power_iters: 15,
            ..Default::default()
        },
    };
    let ini = Initializer::new(InitStrategy::Subsample, 10)
        .with_subsample(subsample)
        .with_seed(seed);
    let (seed_ws, t_init) = time_it(|| ini.seed_l1(ds, &backend, lambda).ws);
    let (sol, t_cut) = time_it(|| {
        constraint_generation(
            ds,
            lambda,
            &seed_ws.rows,
            &GenParams {
                eps,
                max_rows_per_round: 1000,
                threads: pricing_threads(),
                ..Default::default()
            },
        )
    });
    (sol, SplitTime { init: t_init, cut: t_cut })
}

/// Method (g) "SFO+CL-CNG": subsampled + screened first-order init, then
/// combined column-and-constraint generation (§5.1.4).
pub fn sfo_cl_cng(
    ds: &Dataset,
    lambda: f64,
    eps: f64,
    keep_cols: usize,
    seed: u64,
) -> (SvmSolution, SplitTime) {
    let backend = NativeBackend::new(&ds.x);
    let subsample = SubsampleParams {
        n0: 1000.min(ds.n()),
        mu_tol: 0.5,
        q_max: 8,
        threads: 4,
        screen_k: (10 * 100).min(ds.p()),
        fista: FistaParams {
            tau: 0.2,
            eta: 1e-3,
            max_iters: 150,
            power_iters: 15,
            ..Default::default()
        },
    };
    let ini = Initializer::new(InitStrategy::Subsample, keep_cols)
        .with_subsample(subsample)
        .with_seed(seed);
    let (seed_ws, t_init) = time_it(|| ini.seed_l1(ds, &backend, lambda).ws);
    let (sol, t_cut) = time_it(|| {
        column_constraint_generation(
            ds,
            &backend,
            lambda,
            &seed_ws.rows,
            &seed_ws.cols,
            &GenParams {
                eps,
                max_rows_per_round: 1000,
                threads: pricing_threads(),
                ..Default::default()
            },
        )
    });
    (sol, SplitTime { init: t_init, cut: t_cut })
}

/// First-order initializer for Slope: screened FISTA with the Slope prox
/// (through the shared `engine::Initializer`).
pub fn fo_slope_init(ds: &Dataset, lambda: &[f64], keep_top: usize) -> (Vec<usize>, f64) {
    let ini = Initializer::new(InitStrategy::Fista, keep_top).with_fom(fo_fista_params());
    time_it(|| ini.seed_slope(ds, lambda).ws.cols)
}

/// Paper-standard λ grid for Table 1: 20 values, geometric ratio 0.7.
pub fn table1_grid(lambda_max: f64, n_values: usize) -> Vec<f64> {
    geometric_grid(lambda_max, n_values, 0.7)
}
