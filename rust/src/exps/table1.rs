//! **Table 1** — regularization-path training times for L1-SVM, p ≫ n:
//! Gurobi-style full LP (with and without warm starts) vs column
//! generation (CLG) at three tolerance levels, with ARA.

use crate::baselines::full_lp::FullL1Lp;
use crate::coordinator::path::regularization_path;
use crate::coordinator::GenParams;
use crate::backend::NativeBackend;
use crate::data::synthetic::{generate_l1, SyntheticSpec};
use crate::exps::common::table1_grid;
use crate::exps::{ara_percent, fmt_time, mean_std, time_it, Scale, Table};
use crate::rng::Xoshiro256;

struct Sizes {
    ps: Vec<usize>,
    n: usize,
    n_lambda: usize,
    reps: usize,
    /// p cap for the no-warm-start full LP (it is brutally slow).
    lp_cold_cap: usize,
}

fn sizes(scale: Scale) -> Sizes {
    match scale {
        Scale::Smoke => Sizes { ps: vec![200], n: 40, n_lambda: 6, reps: 1, lp_cold_cap: 200 },
        Scale::Default => {
            Sizes { ps: vec![1000, 5000, 10_000], n: 100, n_lambda: 20, reps: 2, lp_cold_cap: 1000 }
        }
        Scale::Paper => Sizes {
            ps: vec![1000, 10_000, 100_000],
            n: 100,
            n_lambda: 20,
            reps: 5,
            lp_cold_cap: 10_000,
        },
    }
}

/// Run Table 1 and render it.
pub fn run(scale: Scale) -> String {
    let sz = sizes(scale);
    let mut table = Table::new(
        "Table 1 — L1-SVM regularization path (20 λ values, ratio 0.7)",
        &["p", "method", "time (s)", "ARA (%)"],
    );

    for &p in &sz.ps {
        // per (rep, λ) objective bookkeeping for ARA
        let mut times: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
        let mut objs: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();

        for rep in 0..sz.reps {
            let spec = SyntheticSpec::paper_default(sz.n, p);
            let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(1000 + rep as u64));
            let grid = table1_grid(ds.lambda_max_l1(), sz.n_lambda);
            let backend = NativeBackend::new(&ds.x);

            // LP without warm start: rebuild + cold solve per λ.
            if p <= sz.lp_cold_cap {
                let (objs_run, t) = time_it(|| {
                    grid.iter()
                        .map(|&lam| FullL1Lp::new(&ds, lam).solve(lam).objective)
                        .collect::<Vec<f64>>()
                });
                times.entry("LP wo warm-start").or_default().push(t);
                objs.entry("LP wo warm-start").or_default().extend(objs_run);
            }
            // LP with warm start: one model, λ continuation.
            {
                let (objs_run, t) = time_it(|| {
                    let mut lp = FullL1Lp::new(&ds, grid[0]);
                    grid.iter()
                        .map(|&lam| {
                            lp.set_lambda(lam);
                            lp.solve(lam).objective
                        })
                        .collect::<Vec<f64>>()
                });
                times.entry("LP warm-start").or_default().push(t);
                objs.entry("LP warm-start").or_default().extend(objs_run);
            }
            // CLG at three tolerances.
            for (label, eps) in
                [("CLG, eps=0.5", 0.5), ("CLG, eps=0.1", 0.1), ("CLG, eps=0.01", 0.01)]
            {
                let (path, t) = time_it(|| {
                    let params = GenParams { eps, ..Default::default() };
                    regularization_path(&ds, &backend, &grid, &params).0
                });
                times.entry(label).or_default().push(t);
                objs.entry(label).or_default().extend(path.iter().map(|pt| pt.objective));
            }
        }

        // per-(rep,λ) best across methods for the ARA denominator
        let n_points = objs.values().map(|v| v.len()).max().unwrap_or(0);
        let mut best = vec![f64::INFINITY; n_points];
        for v in objs.values() {
            if v.len() == n_points {
                for (b, o) in best.iter_mut().zip(v) {
                    *b = b.min(*o);
                }
            }
        }
        for (label, ts) in &times {
            let (m, s) = mean_std(ts);
            let ara = objs
                .get(label)
                .filter(|v| v.len() == n_points)
                .map(|v| ara_percent(v, &best))
                .unwrap_or(f64::NAN);
            table.row(vec![
                p.to_string(),
                label.to_string(),
                fmt_time(m, s),
                if ara.is_nan() { "—".into() } else { format!("{ara:.2}") },
            ]);
        }
        if p > sz.lp_cold_cap {
            table.row(vec![
                p.to_string(),
                "LP wo warm-start".into(),
                "— (> cap)".into(),
                "—".into(),
            ]);
        }
    }
    let out = table.render();
    println!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_smoke() {
        let out = run(Scale::Smoke);
        assert!(out.contains("CLG, eps=0.01"));
        assert!(out.contains("LP warm-start"));
    }
}
