//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§5) on this machine's substrate.
//!
//! Each submodule owns one table/figure and exposes `run(scale) -> String`
//! printing the same rows/series the paper reports. Absolute times differ
//! from the paper (different LP engine, different machine); the
//! reproduction target is the *shape*: who wins, by what factor, and how
//! it scales (see EXPERIMENTS.md for paper-vs-measured).
//!
//! Sizes are controlled by [`Scale`]: `Smoke` for CI, `Default` for
//! `cargo bench`, `Paper` for the closest-feasible-to-paper sizes.

pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;

use std::time::Instant;

/// Experiment size knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Seconds-fast (CI smoke).
    Smoke,
    /// Minutes (default for `cargo bench`).
    Default,
    /// Closest feasible to the paper's sizes (tens of minutes).
    Paper,
}

impl Scale {
    /// Parse from CLI text.
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "smoke" => Some(Scale::Smoke),
            "default" => Some(Scale::Default),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }
}

/// Wall-clock one closure.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Mean and (population) standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    (m, v.sqrt())
}

/// Averaged relative accuracy (§5.1.1): mean over replications of
/// `(f_alg − f_best)/f_best`, in percent.
pub fn ara_percent(objs: &[f64], bests: &[f64]) -> f64 {
    debug_assert_eq!(objs.len(), bests.len());
    let mut s = 0.0;
    for (o, b) in objs.iter().zip(bests) {
        s += (o - b) / b.max(1e-12);
    }
    100.0 * s / objs.len().max(1) as f64
}

/// Format seconds as `x.xx` or `x.xxe-k` compactly.
pub fn fmt_time(mean: f64, std: f64) -> String {
    if mean.is_nan() {
        return "—".to_string();
    }
    if mean >= 100.0 {
        format!("{mean:.0}({std:.0})")
    } else if mean >= 1.0 {
        format!("{mean:.2}({std:.2})")
    } else {
        format!("{mean:.3}({std:.3})")
    }
}

/// Simple fixed-width markdown-ish table renderer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (k, c) in row.iter().enumerate() {
                widths[k] = widths[k].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Run an experiment by id (used by the CLI and the bench binaries).
pub fn run_experiment(id: &str, scale: Scale) -> Option<String> {
    let out = match id {
        "table1" => table1::run(scale),
        "table2" => table2::run(scale),
        "table3" => table3::run(scale),
        "table4" => table4::run(scale),
        "table5" => table5::run(scale),
        "table6" => table6::run(scale),
        "fig1" => fig1::run(scale),
        "fig2" => fig2::run(scale),
        "fig3" => fig3::run(scale),
        "fig4" => fig4::run(scale),
        _ => return None,
    };
    Some(out)
}

/// All experiment ids.
pub const ALL_EXPERIMENTS: &[&str] = &[
    "table1", "fig1", "table2", "fig2", "fig3", "table3", "table4", "fig4", "table5", "table6",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn ara_zero_for_best() {
        assert_eq!(ara_percent(&[2.0, 4.0], &[2.0, 4.0]), 0.0);
        assert!((ara_percent(&[2.2, 4.0], &[2.0, 4.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "method"]);
        t.row(vec!["1".into(), "x".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("| a"));
    }

    #[test]
    fn scale_parses() {
        assert_eq!(Scale::parse("smoke"), Some(Scale::Smoke));
        assert_eq!(Scale::parse("paper"), Some(Scale::Paper));
        assert_eq!(Scale::parse("zzz"), None);
    }
}
