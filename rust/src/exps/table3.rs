//! **Table 3** — L1-SVM on large *sparse* data (rcv1 / real-sim stand-ins)
//! at λ = 0.05·λ_max: SFO+CL-CNG vs the full LP solver.
//!
//! As in the paper — where Gurobi takes “> 3 hrs” — the full model is
//! reported as out of budget: all n margin rows make the basis
//! factorization intractable, while the hybrid coordinator's restricted
//! LP stays tiny.

use crate::data::synthetic::{generate_sparse_text, SparseTextSpec};
use crate::exps::common::sfo_cl_cng;
use crate::exps::{fmt_time, mean_std, Scale, Table};
use crate::rng::Xoshiro256;

fn datasets(scale: Scale) -> Vec<(&'static str, SparseTextSpec)> {
    match scale {
        Scale::Smoke => vec![(
            "rcv1-like (tiny)",
            SparseTextSpec { n: 400, p: 900, density: 0.01, k0: 20, zipf: 1.1 },
        )],
        Scale::Default => vec![
            ("rcv1-like", SparseTextSpec::rcv1_like(0.15)),
            ("real-sim-like", SparseTextSpec::real_sim_like(0.08)),
        ],
        Scale::Paper => vec![
            ("rcv1-like", SparseTextSpec::rcv1_like(0.5)),
            ("real-sim-like", SparseTextSpec::real_sim_like(0.25)),
        ],
    }
}

/// Run Table 3.
pub fn run(scale: Scale) -> String {
    let reps = if scale == Scale::Smoke { 1 } else { 2 };
    let mut table = Table::new(
        "Table 3 — L1-SVM on sparse data at λ = 0.05·λ_max (n, p both large)",
        &["dataset", "n", "p", "nnz", "SFO+CL-CNG (s)", "CL-CNG wo SFO (s)", "LP solver"],
    );
    for (name, spec) in datasets(scale) {
        let mut t_tot = Vec::new();
        let mut t_cut = Vec::new();
        let mut dims = (0usize, 0usize, 0usize);
        for rep in 0..reps {
            let ds = generate_sparse_text(&spec, &mut Xoshiro256::seed_from_u64(6000 + rep as u64));
            dims = (ds.n(), ds.p(), ds.x.nnz());
            let lambda = 0.05 * ds.lambda_max_l1();
            let (sol, split) = sfo_cl_cng(&ds, lambda, 1e-2, 200, 21 + rep as u64);
            let _ = sol;
            t_tot.push(split.total());
            t_cut.push(split.cut);
        }
        let (mt, st) = mean_std(&t_tot);
        let (mc, sc) = mean_std(&t_cut);
        table.row(vec![
            name.to_string(),
            dims.0.to_string(),
            dims.1.to_string(),
            dims.2.to_string(),
            fmt_time(mt, st),
            fmt_time(mc, sc),
            "— (> budget, cf. paper's >3 hrs)".into(),
        ]);
    }
    let out = table.render();
    println!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_smoke() {
        let out = run(Scale::Smoke);
        assert!(out.contains("rcv1-like"));
    }
}
