//! **Table 5** — Slope-SVM with two-level weights (λᵢ = 2λ̃ for i ≤ k₀,
//! λ̃ after; λ̃ = 0.01·λ_max): FO+CL-CNG vs the O(p²)/A.2 LP (the CVXPY
//! substitute). A “—” means the canonicalized model blew past the row
//! budget, as CVXPY-Ecos did in the paper.

use crate::backend::NativeBackend;
use crate::baselines::slope_full::solve_slope_full;
use crate::coordinator::slope::slope_column_constraint_generation;
use crate::coordinator::GenParams;
use crate::data::synthetic::{generate_l1, SyntheticSpec};
use crate::exps::common::fo_slope_init;
use crate::exps::{ara_percent, fmt_time, mean_std, time_it, Scale, Table};
use crate::fom::objective::two_level_slope_weights;
use crate::rng::Xoshiro256;

fn sizes(scale: Scale) -> (usize, Vec<usize>, usize) {
    match scale {
        Scale::Smoke => (30, vec![200], 1),
        Scale::Default => (100, vec![1000, 5000, 20_000], 1),
        Scale::Paper => (100, vec![10_000, 20_000, 50_000, 100_000], 3),
    }
}

const K0: usize = 10;

/// Run Table 5.
pub fn run(scale: Scale) -> String {
    let (n, ps, reps) = sizes(scale);
    let mut table = Table::new(
        "Table 5 — Slope-SVM, two-level weights (λ_i/λ_j = 2), vs CVXPY-style full LP",
        &["p", "FO+CL-CNG (s)", "ARA (%)", "CL-CNG wo FO (s)", "full-LP (CVXPY-like) (s)", "full-LP ARA (%)"],
    );
    for &p in &ps {
        let mut t_cg = Vec::new();
        let mut t_cut = Vec::new();
        let mut t_full = Vec::new();
        let mut o_cg = Vec::new();
        let mut o_full = Vec::new();
        for rep in 0..reps {
            let spec = SyntheticSpec { n, p, k0: K0.min(p / 2), rho: 0.1, standardize: true };
            let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(9000 + rep as u64));
            let lambda_tilde = 0.01 * ds.lambda_max_l1();
            let lambda = two_level_slope_weights(p, K0.min(p / 2), lambda_tilde);
            let backend = NativeBackend::new(&ds.x);

            let (init, t_init) = fo_slope_init(&ds, &lambda, 100);
            let (sol, t) = time_it(|| {
                slope_column_constraint_generation(
                    &ds,
                    &backend,
                    &lambda,
                    &init,
                    &GenParams { eps: 1e-2, max_cols_per_round: 10, ..Default::default() },
                )
            });
            t_cg.push(t + t_init);
            t_cut.push(t);
            o_cg.push(sol.objective);

            let (full, t) = time_it(|| solve_slope_full(&ds, &lambda));
            if let Some(full) = full {
                t_full.push(t);
                o_full.push(full.objective);
            }
        }
        let best: Vec<f64> = (0..reps)
            .map(|r| {
                let mut b = o_cg[r];
                if r < o_full.len() {
                    b = b.min(o_full[r]);
                }
                b
            })
            .collect();
        let (mc, sc) = mean_std(&t_cg);
        let (mk, sk) = mean_std(&t_cut);
        let full_cells = if o_full.len() == reps {
            let (mf, sf) = mean_std(&t_full);
            (fmt_time(mf, sf), format!("{:.2}", ara_percent(&o_full, &best)))
        } else {
            ("—".to_string(), "—".to_string())
        };
        table.row(vec![
            p.to_string(),
            fmt_time(mc, sc),
            format!("{:.2e}", ara_percent(&o_cg, &best)),
            fmt_time(mk, sk),
            full_cells.0,
            full_cells.1,
        ]);
    }
    let out = table.render();
    println!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_smoke() {
        let out = run(Scale::Smoke);
        assert!(out.contains("Table 5"));
    }
}
