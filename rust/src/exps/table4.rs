//! **Table 4** — the best cutting-plane method vs PSM (parametric simplex
//! of Pang et al. 2017) on p ≫ n and n ≫ p instances.

use crate::baselines::psm::psm_l1svm;
use crate::data::synthetic::{generate_l1, SyntheticSpec};
use crate::exps::common::{fo_clg, sfo_cng};
use crate::exps::{ara_percent, fmt_time, mean_std, time_it, Scale, Table};
use crate::rng::Xoshiro256;

struct Case {
    n: usize,
    p: usize,
    method: &'static str,
}

fn cases(scale: Scale) -> (Vec<Case>, usize) {
    match scale {
        Scale::Smoke => (vec![Case { n: 40, p: 300, method: "FO+CLG" }], 1),
        Scale::Default => (
            vec![
                Case { n: 100, p: 5000, method: "FO+CLG" },
                Case { n: 100, p: 10_000, method: "FO+CLG" },
                Case { n: 500, p: 100, method: "SFO+CNG" },
                Case { n: 1000, p: 100, method: "SFO+CNG" },
            ],
            2,
        ),
        Scale::Paper => (
            vec![
                Case { n: 100, p: 10_000, method: "FO+CLG" },
                Case { n: 100, p: 20_000, method: "FO+CLG" },
                Case { n: 1000, p: 100, method: "SFO+CNG" },
                Case { n: 2000, p: 100, method: "SFO+CNG" },
            ],
            3,
        ),
    }
}

/// Run Table 4.
pub fn run(scale: Scale) -> String {
    let (cases, reps) = cases(scale);
    let mut table = Table::new(
        "Table 4 — best cutting-plane method vs PSM at λ = 0.01·λ_max",
        &["n", "p", "method", "time (s)", "ARA (%)", "PSM time (s)", "PSM ARA (%)"],
    );
    for case in cases {
        let mut t_cp = Vec::new();
        let mut t_psm = Vec::new();
        let mut o_cp = Vec::new();
        let mut o_psm = Vec::new();
        for rep in 0..reps {
            let spec = SyntheticSpec::paper_default(case.n, case.p);
            let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(7000 + rep as u64));
            let lambda = 0.01 * ds.lambda_max_l1();

            match case.method {
                "FO+CLG" => {
                    let (sol, split) = fo_clg(&ds, lambda, 1e-2, 100);
                    t_cp.push(split.total());
                    o_cp.push(sol.objective);
                }
                _ => {
                    let (sol, split) = sfo_cng(&ds, lambda, 1e-2, 31 + rep as u64);
                    t_cp.push(split.total());
                    o_cp.push(sol.objective);
                }
            }
            let (res, t) = time_it(|| psm_l1svm(&ds, lambda));
            t_psm.push(t);
            o_psm.push(res.solution.objective);
        }
        let best: Vec<f64> = o_cp.iter().zip(&o_psm).map(|(a, b)| a.min(*b)).collect();
        let (mc, sc) = mean_std(&t_cp);
        let (mp, sp) = mean_std(&t_psm);
        table.row(vec![
            case.n.to_string(),
            case.p.to_string(),
            case.method.to_string(),
            fmt_time(mc, sc),
            format!("{:.2}", ara_percent(&o_cp, &best)),
            fmt_time(mp, sp),
            format!("{:.2}", ara_percent(&o_psm, &best)),
        ]);
    }
    let out = table.render();
    println!("{out}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_smoke() {
        let out = run(Scale::Smoke);
        assert!(out.contains("FO+CLG"));
        assert!(out.contains("PSM"));
    }
}
