//! cutgen: column & constraint generation for L1-regularized SVMs and cousins.
pub mod backend;
pub mod baselines;
pub mod coordinator;
pub mod cli;
pub mod data;
pub mod engine;
pub mod error;
pub mod exps;
pub mod fom;
pub mod linalg;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod simplex;
pub mod sparse;
pub mod workloads;
