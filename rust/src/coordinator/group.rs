//! Column generation on *groups* for the Group-SVM LP (§2.4).
//!
//! The restricted model brings in whole groups: each included group `g`
//! contributes its L∞-bound variable `v_g` (cost λ), the coefficient
//! halves `β⁺_j, β⁻_j` for `j ∈ I_g` (cost 0), and the box rows
//! `v_g − β⁺_j − β⁻_j ≥ 0`. Pricing a left-out group uses eq. (17):
//! `r̄_g = λ − Σ_{j∈I_g} |q_j|` with `q = Xᵀ(y∘π)` — the same pricing
//! hot path as L1-SVM, driven by the shared [`crate::engine::GenEngine`]
//! through [`GroupProblem`].

use crate::backend::Backend;
use crate::coordinator::{GenParams, GenStats, SvmSolution};
use crate::data::Dataset;
use crate::engine::{BackendPricer, GenEngine, Pricer, RestrictedProblem, Snapshot, WorkingSet};
use crate::simplex::{LpModel, SimplexSolver, Status, VarId};

/// Restricted-groups Group-SVM LP.
pub struct RestrictedGroup<'g> {
    solver: SimplexSolver,
    lambda: f64,
    groups: &'g [Vec<usize>],
    /// group g → whether included.
    in_g: Vec<bool>,
    /// included groups in insertion order.
    g_list: Vec<usize>,
    /// per included feature j: (β⁺ id, β⁻ id).
    beta_vars: Vec<Option<(VarId, VarId)>>,
    /// v_g variable per included group (aligned with `g_list`).
    vg_vars: Vec<VarId>,
    b0: VarId,
    /// margin row per sample (built for all n once).
    n: usize,
}

impl<'g> RestrictedGroup<'g> {
    /// Build with margin rows for all samples and the given initial groups.
    pub fn new(ds: &Dataset, groups: &'g [Vec<usize>], lambda: f64, g_init: &[usize]) -> Self {
        let n = ds.n();
        let mut model = LpModel::new();
        let b0 = model.add_col_free(0.0, &[]);
        let mut xi = Vec::with_capacity(n);
        for _ in 0..n {
            xi.push(model.add_col(1.0, 0.0, f64::INFINITY, &[]));
        }
        for i in 0..n {
            model.add_row(1.0, f64::INFINITY, &[(xi[i], 1.0), (b0, ds.y[i])]);
        }
        let mut me = Self {
            solver: SimplexSolver::new(model),
            lambda,
            groups,
            in_g: vec![false; groups.len()],
            g_list: Vec::new(),
            vg_vars: Vec::new(),
            beta_vars: vec![None; ds.p()],
            b0,
            n,
        };
        me.add_groups(ds, g_init);
        me
    }

    /// Included groups (insertion order).
    pub fn g_set(&self) -> &[usize] {
        &self.g_list
    }

    /// Bring groups into the model.
    pub fn add_groups(&mut self, ds: &Dataset, gs: &[usize]) {
        for &g in gs {
            if self.in_g[g] {
                continue;
            }
            self.in_g[g] = true;
            self.g_list.push(g);
            let vg = self.solver.add_col(self.lambda, 0.0, f64::INFINITY, &[]);
            self.vg_vars.push(vg);
            for &j in &self.groups[g] {
                // margin-row coefficients of β⁺_j / β⁻_j
                let mut pos = Vec::new();
                let mut neg = Vec::new();
                for (i, v) in ds.x.col_entries(j) {
                    if v != 0.0 {
                        pos.push((i, ds.y[i] * v));
                        neg.push((i, -ds.y[i] * v));
                    }
                }
                let bp = self.solver.add_col(0.0, 0.0, f64::INFINITY, &pos);
                let bm = self.solver.add_col(0.0, 0.0, f64::INFINITY, &neg);
                // box row: v_g − β⁺_j − β⁻_j ≥ 0
                self.solver
                    .add_row(0.0, f64::INFINITY, &[(vg, 1.0), (bp, -1.0), (bm, -1.0)]);
                self.beta_vars[j] = Some((bp, bm));
            }
        }
    }

    /// Change λ in place (costs of the v_g variables); keeps the basis
    /// for primal warm starts along a regularization path.
    pub fn set_lambda(&mut self, lambda: f64) {
        self.lambda = lambda;
        for &vg in &self.vg_vars {
            self.solver.set_col_cost(vg, lambda);
        }
    }

    /// Worker threads for the dense dual-simplex pricing row (see
    /// [`crate::simplex::SimplexSolver::set_threads`]).
    pub fn set_threads(&mut self, threads: usize) {
        self.solver.set_threads(threads);
    }

    /// Solve (warm-started).
    pub fn solve(&mut self) -> Status {
        self.solver.solve()
    }

    /// Restricted objective.
    pub fn objective(&self) -> f64 {
        self.solver.objective()
    }

    /// Cumulative simplex iterations.
    pub fn simplex_iters(&self) -> usize {
        self.solver.stats.primal_iters + self.solver.stats.dual_iters
    }

    /// Coefficients on included groups plus intercept.
    pub fn beta_support(&self) -> (Vec<(usize, f64)>, f64) {
        let mut out = Vec::new();
        for &g in &self.g_list {
            for &j in &self.groups[g] {
                if let Some((bp, bm)) = self.beta_vars[j] {
                    let b = self.solver.col_value(bp) - self.solver.col_value(bm);
                    if b != 0.0 {
                        out.push((j, b));
                    }
                }
            }
        }
        (out, self.solver.col_value(self.b0))
    }

    /// Margin duals π (rows 0..n are the margin rows).
    pub fn margin_duals(&self) -> Vec<f64> {
        (0..self.n).map(|r| self.solver.row_dual(r)).collect()
    }

    /// Price left-out groups (eq. 17): returns `(g, violation)` with
    /// violation `= Σ_{j∈I_g} |q_j| − λ > ε`.
    pub fn price_groups(&self, ds: &Dataset, pricer: &dyn Pricer, eps: f64) -> Vec<(usize, f64)> {
        let pi = self.margin_duals();
        let v: Vec<f64> = pi.iter().zip(&ds.y).map(|(p, y)| p * y).collect();
        let mut q = vec![0.0; ds.p()];
        pricer.score(&v, &mut q);
        let mut out = Vec::new();
        for (g, members) in self.groups.iter().enumerate() {
            if !self.in_g[g] {
                let score: f64 = members.iter().map(|&j| q[j].abs()).sum();
                let viol = score - self.lambda;
                if viol > eps {
                    out.push((g, viol));
                }
            }
        }
        out
    }
}

/// [`RestrictedGroup`] adapted to the generic engine: pure column (group)
/// generation — the constraint channel is empty.
pub struct GroupProblem<'a, 'g> {
    rg: RestrictedGroup<'g>,
    ds: &'a Dataset,
    pricer: &'a dyn Pricer,
}

impl<'a, 'g> GroupProblem<'a, 'g> {
    /// Wrap a restricted group model.
    pub fn new(rg: RestrictedGroup<'g>, ds: &'a Dataset, pricer: &'a dyn Pricer) -> Self {
        Self { rg, ds, pricer }
    }

    /// The wrapped restricted model.
    pub fn inner(&self) -> &RestrictedGroup<'g> {
        &self.rg
    }

    /// Change λ in place (warm-start preserving) — for path-style drivers
    /// that re-run the engine across a λ grid on one model.
    pub fn set_lambda(&mut self, lambda: f64) {
        self.rg.set_lambda(lambda);
    }
}

impl Snapshot for GroupProblem<'_, '_> {
    fn export_working_set(&self) -> WorkingSet {
        // column channel carries *group* indices; there is no row channel
        WorkingSet { cols: self.rg.g_set().to_vec(), rows: Vec::new() }
    }
    fn import_working_set(&mut self, ws: &WorkingSet) {
        self.rg.add_groups(self.ds, &ws.cols);
    }
}

impl RestrictedProblem for GroupProblem<'_, '_> {
    fn solve(&mut self) -> Status {
        self.rg.solve()
    }
    fn objective(&self) -> f64 {
        self.rg.objective()
    }
    fn simplex_iters(&self) -> usize {
        self.rg.simplex_iters()
    }
    fn price_rows(&mut self, _eps: f64) -> Vec<(usize, f64)> {
        Vec::new()
    }
    fn price_cols(&mut self, eps: f64) -> Vec<(usize, f64)> {
        self.rg.price_groups(self.ds, self.pricer, eps)
    }
    fn add_rows(&mut self, _idx: &[usize]) {}
    fn add_cols(&mut self, idx: &[usize]) {
        self.rg.add_groups(self.ds, idx);
    }
    fn working_set_size(&self) -> usize {
        self.rg.g_set().len()
    }
}

/// Initial groups at λ_max via eq. (19).
pub fn initial_groups(ds: &Dataset, groups: &[Vec<usize>], g0: usize) -> Vec<usize> {
    let q = crate::coordinator::path::lambda_max_scores(ds);
    let scores: Vec<f64> = groups.iter().map(|g| g.iter().map(|&j| q[j].abs()).sum()).collect();
    let mut idx: Vec<usize> = (0..groups.len()).collect();
    idx.sort_unstable_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    idx.truncate(g0.min(groups.len()));
    idx
}

/// Column generation for Group-SVM (the CG loop of §2.4).
pub fn group_column_generation(
    ds: &Dataset,
    backend: &dyn Backend,
    groups: &[Vec<usize>],
    lambda: f64,
    g_init: &[usize],
    params: &GenParams,
) -> SvmSolution {
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rg = RestrictedGroup::new(ds, groups, lambda, g_init);
    rg.set_threads(params.threads);
    let mut prob = GroupProblem::new(rg, ds, &pricer);
    let mut stats: GenStats = GenEngine::new(params).run(&mut prob);
    stats.cols_added += g_init.len();
    let rg = prob.inner();

    let (support, beta0) = rg.beta_support();
    let report = crate::coordinator::report::group_report(ds, groups, &support, beta0, lambda);
    let mut cols = rg.g_set().to_vec();
    cols.sort_unstable();
    SvmSolution {
        beta: report.beta,
        beta0,
        objective: report.objective,
        stats,
        cols, // group indices here
        rows: (0..ds.n()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::synthetic::{generate_group, GroupSpec};
    use crate::rng::Xoshiro256;

    fn setup(seed: u64) -> (crate::data::synthetic::GroupDataset, f64) {
        let spec = GroupSpec {
            n: 40,
            n_groups: 15,
            group_size: 4,
            k0_groups: 3,
            rho: 0.2,
            standardize: true,
        };
        let gd = generate_group(&spec, &mut Xoshiro256::seed_from_u64(seed));
        let lam = 0.1 * gd.data.lambda_max_group(&gd.groups);
        (gd, lam)
    }

    fn full_objective(gd: &crate::data::synthetic::GroupDataset, lam: f64) -> f64 {
        let all: Vec<usize> = (0..gd.groups.len()).collect();
        let mut rg = RestrictedGroup::new(&gd.data, &gd.groups, lam, &all);
        assert_eq!(rg.solve(), Status::Optimal);
        rg.objective()
    }

    #[test]
    fn group_cg_matches_full_lp() {
        let (gd, lam) = setup(121);
        let backend = NativeBackend::new(&gd.data.x);
        let full = full_objective(&gd, lam);
        let params = GenParams { eps: 1e-6, ..Default::default() };
        let sol =
            group_column_generation(&gd.data, &backend, &gd.groups, lam, &[0], &params);
        assert!(
            (sol.objective - full).abs() / full.max(1e-9) < 1e-5,
            "cg {} full {}",
            sol.objective,
            full
        );
        assert!(sol.cols.len() <= gd.groups.len());
    }

    #[test]
    fn group_structure_in_solution() {
        let (gd, lam) = setup(122);
        let backend = NativeBackend::new(&gd.data.x);
        let sol = group_column_generation(
            &gd.data,
            &backend,
            &gd.groups,
            lam,
            &initial_groups(&gd.data, &gd.groups, 3),
            &GenParams { eps: 1e-6, ..Default::default() },
        );
        // groups are either fully zero or have at least one active member;
        // informative groups should hold most mass
        let mass = |g: &Vec<usize>| g.iter().map(|&j| sol.beta[j].abs()).sum::<f64>();
        let info: f64 = gd.groups[..3].iter().map(mass).sum();
        let noise: f64 = gd.groups[3..].iter().map(mass).sum();
        assert!(info > noise, "info {info} noise {noise}");
    }

    #[test]
    fn lambda_above_group_max_gives_zero() {
        let (gd, _) = setup(123);
        let lam = 1.01 * gd.data.lambda_max_group(&gd.groups);
        let backend = NativeBackend::new(&gd.data.x);
        let sol = group_column_generation(
            &gd.data,
            &backend,
            &gd.groups,
            lam,
            &[0, 1],
            &GenParams::default(),
        );
        assert_eq!(sol.support_size(), 0);
    }

    #[test]
    fn initial_groups_prefer_informative() {
        let (gd, _) = setup(124);
        let init = initial_groups(&gd.data, &gd.groups, 4);
        let hits = init.iter().filter(|&&g| g < 3).count();
        assert!(hits >= 2, "init {init:?}");
    }
}
