//! **Algorithm 2** — regularization path for L1-SVM with warm-started
//! column generation (§2.2.2).
//!
//! The path starts at `λ_max = max_j Σ_i |x_ij|` where `β* = 0`. The
//! initial working set is picked by the closed-form reduced costs at
//! `λ_max` (eq. 10, using the analytic dual `π(λ_max)`), and each step
//! down the grid reuses the previous step's restricted model, basis and
//! working set — only the β-costs change, so every re-solve is a primal
//! warm start. Each grid point is one [`crate::engine::GenEngine`] run
//! on the same [`L1Problem`].

use crate::backend::Backend;
use crate::coordinator::group::{GroupProblem, RestrictedGroup};
use crate::coordinator::l1svm::{L1Problem, RestrictedL1};
use crate::coordinator::report::{dantzig_report, group_report, l1_report, ranksvm_report};
use crate::coordinator::{GenParams, GenStats, SvmSolution};
use crate::data::Dataset;
use crate::engine::{BackendPricer, GenEngine, Initializer, Snapshot, WorkingSet};
use crate::fom::screening::top_k_by_abs;
use crate::obs::Span;
use crate::workloads::dantzig::{DantzigProblem, RestrictedDantzig};
use crate::workloads::pairset::PairSet;
use crate::workloads::ranksvm::{pair_rows_cap, RankProblem, RestrictedRank};

/// Analytic reduced-cost scores at λ_max (the rhs of eq. 10, second
/// term): features with the largest |·| are the first to activate.
pub fn lambda_max_scores(ds: &Dataset) -> Vec<f64> {
    let (npos, nneg) = ds.class_counts();
    // dual at λ_max: π_i = N−/N+ on the majority class (+1 if N+ ≥ N−),
    // 1 on the other (§2.2.2).
    let (w_pos, w_neg) = if npos >= nneg {
        (nneg as f64 / npos as f64, 1.0)
    } else {
        (1.0, npos as f64 / nneg as f64)
    };
    let v: Vec<f64> = ds
        .y
        .iter()
        .map(|&yi| if yi > 0.0 { yi * w_pos } else { yi * w_neg })
        .collect();
    let mut q = vec![0.0; ds.p()];
    ds.x.tmatvec(&v, &mut q);
    q
}

/// Initial working set at λ slightly below λ_max: the `j0` features
/// minimizing the reduced cost (10) = maximizing |q_j|.
pub fn initial_columns(ds: &Dataset, j0: usize) -> Vec<usize> {
    let q = lambda_max_scores(ds);
    top_k_by_abs(&q, j0.min(ds.p()))
}

/// One solved point on the path.
#[derive(Clone, Debug)]
pub struct PathSolution {
    /// λ value.
    pub lambda: f64,
    /// Full-problem objective at this λ.
    pub objective: f64,
    /// Support size of β*(λ).
    pub support: usize,
    /// Size of the working set J after this step.
    pub working_set: usize,
    /// Cumulative generation stats up to and including this step.
    pub stats: GenStats,
    /// This step's own engine-run delta (the first point also carries
    /// the seed phase's `seed_ns`): per-λ rounds, simplex iterations,
    /// span timings, and whether *this* point was cut short by the
    /// caller's deadline — what the serve `grid` op reports per point.
    pub step: GenStats,
    /// Snapshot of the working sets after this step — lets callers (the
    /// serve `grid` endpoint) seed a warm-start cache at **every**
    /// visited λ, not just the last. For the L1 path the row channel is
    /// left empty (Algorithm 2 keeps every margin row in the model).
    pub ws: WorkingSet,
}

/// A geometric λ grid from λ_max down to `lambda_min` with the given
/// ratio (paper: 20 values, ratio 0.7).
pub fn geometric_grid(lambda_max: f64, n_values: usize, ratio: f64) -> Vec<f64> {
    (0..n_values).map(|k| lambda_max * ratio.powi(k as i32)).collect()
}

/// Run Algorithm 2 over a decreasing λ grid. Returns one entry per grid
/// point plus the final solution object at the last λ.
///
/// The initial working set comes from the shared engine initializer
/// ([`Initializer::for_path`]): the closed-form λ_max screening with
/// [`GenParams::seed_budget`] columns by default, or the configured
/// first-order method when [`GenParams::init`] names one explicitly.
pub fn regularization_path(
    ds: &Dataset,
    backend: &dyn Backend,
    lambdas: &[f64],
    params: &GenParams,
) -> (Vec<PathSolution>, SvmSolution) {
    regularization_path_with_stop(ds, backend, lambdas, params, None)
}

/// [`regularization_path`] with a cooperative stop callback threaded
/// into every engine run (the serve layer's grid deadline). When a
/// step is cut short the path stops at that point — later λ values
/// would only re-poll the expired deadline — so the returned vector
/// may be shorter than `lambdas`; the last entry has
/// [`GenStats::timed_out`] set in its `step`.
pub fn regularization_path_with_stop(
    ds: &Dataset,
    backend: &dyn Backend,
    lambdas: &[f64],
    params: &GenParams,
    should_stop: Option<&dyn Fn() -> bool>,
) -> (Vec<PathSolution>, SvmSolution) {
    assert!(!lambdas.is_empty());
    debug_assert!(lambdas.windows(2).all(|w| w[0] >= w[1]), "grid must decrease");
    let all_i: Vec<usize> = (0..ds.n()).collect();
    let seed_span = Span::start();
    let init = Initializer::for_path(params).seed_l1_cols(ds, backend, lambdas[0]).ws.cols;
    let seed_ns = seed_span.elapsed_ns();
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rl1 = RestrictedL1::new(ds, lambdas[0], &all_i, &init);
    rl1.set_threads(params.threads);
    let mut prob = L1Problem::new(rl1, ds, &pricer, false, true);
    let mut engine = GenEngine::new(params);
    if let Some(f) = should_stop {
        engine = engine.with_should_stop(f);
    }
    let mut stats = GenStats { cols_added: init.len(), ..Default::default() };
    let mut out = Vec::with_capacity(lambdas.len());

    for (k, &lambda) in lambdas.iter().enumerate() {
        prob.set_lambda(lambda);
        // column generation at this λ (warm-started from previous λ)
        let mut step = engine.run(&mut prob);
        if k == 0 {
            step.seed_ns = seed_ns; // the seed phase belongs to the first point
        }
        accumulate(&mut stats, step);
        let (support, b0) = prob.inner().beta_support();
        let report = l1_report(ds, &support, b0, lambda);
        let mut ws = prob.export_working_set();
        ws.rows.clear(); // Algorithm 2 keeps every margin row in the model
        out.push(PathSolution {
            lambda,
            objective: report.objective,
            support: report.support,
            working_set: prob.inner().j_set().len(),
            stats,
            step,
            ws,
        });
        if step.timed_out {
            break;
        }
    }

    // materialize the final solution
    let (support, beta0) = prob.inner().beta_support();
    let mut beta = vec![0.0; ds.p()];
    for &(j, v) in &support {
        beta[j] = v;
    }
    let mut cols = prob.inner().j_set().to_vec();
    cols.sort_unstable();
    let last = out.last().unwrap();
    let final_sol = SvmSolution {
        beta,
        beta0,
        objective: last.objective,
        stats,
        cols,
        rows: (0..ds.n()).collect(),
    };
    (out, final_sol)
}

/// Fold one engine run's counters into the path-cumulative stats
/// (`converged`/`stalled` reflect the last step; `timed_out` sticks once
/// any step is cut short). Shared with the serve layer's chained Slope
/// grid, which cannot reuse one restricted model down the path.
pub(crate) fn accumulate(stats: &mut GenStats, step: GenStats) {
    stats.rounds += step.rounds;
    stats.cols_added += step.cols_added;
    stats.rows_added += step.rows_added;
    stats.simplex_iters += step.simplex_iters;
    stats.solve_ns += step.solve_ns;
    stats.pricing_ns += step.pricing_ns;
    stats.seed_ns += step.seed_ns;
    stats.converged = step.converged;
    stats.stalled = step.stalled;
    stats.timed_out |= step.timed_out;
    stats.pair_scan = step.pair_scan.or(stats.pair_scan);
}

/// Warm-started λ-path for the **Group-SVM** over a decreasing grid
/// (§2.4 down a grid). λ only appears in the per-group costs `λ·v_g`, so
/// each step rewrites the costs in place
/// ([`GroupProblem::set_lambda`]) and re-solves from the previous basis
/// and group working set — a primal-simplex warm start at every grid
/// point, exactly Algorithm 2's mechanics with groups as the column
/// channel.
pub fn group_path(
    ds: &Dataset,
    backend: &dyn Backend,
    groups: &[Vec<usize>],
    lambdas: &[f64],
    params: &GenParams,
) -> Vec<PathSolution> {
    group_path_with_stop(ds, backend, groups, lambdas, params, None)
}

/// [`group_path`] with a cooperative stop callback; same early-exit
/// contract as [`regularization_path_with_stop`].
pub fn group_path_with_stop(
    ds: &Dataset,
    backend: &dyn Backend,
    groups: &[Vec<usize>],
    lambdas: &[f64],
    params: &GenParams,
    should_stop: Option<&dyn Fn() -> bool>,
) -> Vec<PathSolution> {
    assert!(!lambdas.is_empty());
    debug_assert!(lambdas.windows(2).all(|w| w[0] >= w[1]), "grid must decrease");
    let seed_span = Span::start();
    let seed = Initializer::for_path(params).seed_group(ds, groups, lambdas[0]).ws.cols;
    let seed_ns = seed_span.elapsed_ns();
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rg = RestrictedGroup::new(ds, groups, lambdas[0], &seed);
    rg.set_threads(params.threads);
    let mut prob = GroupProblem::new(rg, ds, &pricer);
    let mut engine = GenEngine::new(params);
    if let Some(f) = should_stop {
        engine = engine.with_should_stop(f);
    }
    let mut stats = GenStats { cols_added: seed.len(), ..Default::default() };
    let mut out = Vec::with_capacity(lambdas.len());
    for (k, &lambda) in lambdas.iter().enumerate() {
        prob.set_lambda(lambda);
        let mut step = engine.run(&mut prob);
        if k == 0 {
            step.seed_ns = seed_ns;
        }
        accumulate(&mut stats, step);
        let (support, b0) = prob.inner().beta_support();
        let report = group_report(ds, groups, &support, b0, lambda);
        out.push(PathSolution {
            lambda,
            objective: report.objective,
            support: report.support,
            working_set: prob.inner().g_set().len(),
            stats,
            step,
            ws: prob.export_working_set(),
        });
        if step.timed_out {
            break;
        }
    }
    out
}

/// Warm-started λ-path for the **Dantzig selector** over a decreasing
/// grid. One restricted model is reused down the whole path: moving λ
/// rewrites every correlation row's range in place
/// ([`crate::simplex::SimplexSolver::set_row_bounds`]), which keeps the
/// basis and duals — a dual-simplex warm start at every grid point —
/// while the working sets only ever grow.
pub fn dantzig_path(
    ds: &Dataset,
    backend: &dyn Backend,
    lambdas: &[f64],
    params: &GenParams,
) -> Vec<PathSolution> {
    dantzig_path_with_stop(ds, backend, lambdas, params, None)
}

/// [`dantzig_path`] with a cooperative stop callback; same early-exit
/// contract as [`regularization_path_with_stop`].
pub fn dantzig_path_with_stop(
    ds: &Dataset,
    backend: &dyn Backend,
    lambdas: &[f64],
    params: &GenParams,
    should_stop: Option<&dyn Fn() -> bool>,
) -> Vec<PathSolution> {
    assert!(!lambdas.is_empty());
    debug_assert!(lambdas.windows(2).all(|w| w[0] >= w[1]), "grid must decrease");
    let seed_span = Span::start();
    let seed = Initializer::for_path(params).seed_dantzig(ds, backend, lambdas[0]).ws.rows;
    let seed_ns = seed_span.elapsed_ns();
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rd = RestrictedDantzig::new(ds, lambdas[0], &seed);
    rd.set_threads(params.threads);
    let mut prob = DantzigProblem::new(rd, ds, &pricer);
    let mut engine = GenEngine::new(params);
    if let Some(f) = should_stop {
        engine = engine.with_should_stop(f);
    }
    let mut stats =
        GenStats { cols_added: seed.len(), rows_added: seed.len(), ..Default::default() };
    let mut out = Vec::with_capacity(lambdas.len());
    for (k, &lambda) in lambdas.iter().enumerate() {
        prob.set_lambda(lambda);
        let mut step = engine.run(&mut prob);
        if k == 0 {
            step.seed_ns = seed_ns;
        }
        accumulate(&mut stats, step);
        let report = dantzig_report(ds.p(), &prob.inner().beta_support());
        out.push(PathSolution {
            lambda,
            // the restricted LP objective Σ(β⁺+β⁻) — identical to ‖β‖₁
            // at a non-degenerate vertex, and what `dantzig_generation`
            // reports
            objective: prob.inner().objective(),
            support: report.support,
            working_set: prob.inner().j_set().len(),
            stats,
            step,
            ws: prob.export_working_set(),
        });
        if step.timed_out {
            break;
        }
    }
    out
}

/// Warm-started λ-path for **RankSVM** over a decreasing grid. λ only
/// appears in the β-costs, so each step is a primal-simplex warm start on
/// the same restricted model (exactly Algorithm 2's mechanics, with
/// comparison pairs in place of samples).
pub fn ranksvm_path(
    ds: &Dataset,
    backend: &dyn Backend,
    pairs: &PairSet,
    lambdas: &[f64],
    params: &GenParams,
) -> Vec<PathSolution> {
    ranksvm_path_with_stop(ds, backend, pairs, lambdas, params, None)
}

/// [`ranksvm_path`] with a cooperative stop callback; same early-exit
/// contract as [`regularization_path_with_stop`].
pub fn ranksvm_path_with_stop(
    ds: &Dataset,
    backend: &dyn Backend,
    pairs: &PairSet,
    lambdas: &[f64],
    params: &GenParams,
    should_stop: Option<&dyn Fn() -> bool>,
) -> Vec<PathSolution> {
    assert!(!lambdas.is_empty());
    debug_assert!(lambdas.windows(2).all(|w| w[0] >= w[1]), "grid must decrease");
    let seed_span = Span::start();
    let seed = Initializer::for_path(params).seed_ranksvm(ds, backend, pairs, lambdas[0]).ws;
    let seed_ns = seed_span.elapsed_ns();
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rr = RestrictedRank::new(ds, pairs, lambdas[0], &seed.rows, &seed.cols);
    rr.set_threads(params.threads);
    rr.set_pair_cap(pair_rows_cap(params));
    let mut prob = RankProblem::new(rr, ds, &pricer);
    let mut engine = GenEngine::new(params);
    if let Some(f) = should_stop {
        engine = engine.with_should_stop(f);
    }
    let mut stats = GenStats {
        cols_added: seed.cols.len(),
        rows_added: seed.rows.len(),
        ..Default::default()
    };
    let mut out = Vec::with_capacity(lambdas.len());
    for (k, &lambda) in lambdas.iter().enumerate() {
        prob.set_lambda(lambda);
        let mut step = engine.run(&mut prob);
        if k == 0 {
            step.seed_ns = seed_ns;
        }
        step.pair_scan = Some(prob.inner().pair_scan());
        accumulate(&mut stats, step);
        let report = ranksvm_report(ds, pairs, &prob.inner().beta_support(), lambda);
        out.push(PathSolution {
            lambda,
            objective: report.objective,
            support: report.support,
            working_set: prob.inner().j_set().len(),
            stats,
            step,
            ws: prob.export_working_set(),
        });
        if step.timed_out {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::coordinator::l1svm::column_generation;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::rng::Xoshiro256;

    fn ds() -> Dataset {
        let spec = SyntheticSpec { n: 40, p: 80, k0: 5, rho: 0.1, standardize: true };
        generate_l1(&spec, &mut Xoshiro256::seed_from_u64(111))
    }

    #[test]
    fn grid_is_geometric() {
        let g = geometric_grid(10.0, 4, 0.5);
        assert_eq!(g, vec![10.0, 5.0, 2.5, 1.25]);
    }

    #[test]
    fn initial_columns_match_analytic_scores() {
        let d = ds();
        let cols = initial_columns(&d, 10);
        assert_eq!(cols.len(), 10);
        // informative features (0..5) should be heavily represented
        let hits = cols.iter().filter(|&&j| j < 5).count();
        assert!(hits >= 4, "only {hits}/5 informative in init set");
    }

    #[test]
    fn path_objectives_match_independent_solves() {
        let d = ds();
        let backend = NativeBackend::new(&d.x);
        let lmax = d.lambda_max_l1();
        let grid = geometric_grid(lmax, 6, 0.6);
        let params = GenParams { eps: 1e-6, seed_budget: 5, ..Default::default() };
        let (path, final_sol) = regularization_path(&d, &backend, &grid, &params);
        assert_eq!(path.len(), 6);
        // first point: λ = λ_max → zero solution, objective = n·hinge(0) = n
        assert_eq!(path[0].support, 0);
        assert!((path[0].objective - d.n() as f64).abs() < 1e-6);
        // each point must match a from-scratch column generation solve
        for pt in &path[1..] {
            let direct = column_generation(&d, &backend, pt.lambda, &[0, 1], &params);
            assert!(
                (pt.objective - direct.objective).abs() / direct.objective.max(1e-9) < 1e-5,
                "λ={}: path {} direct {}",
                pt.lambda,
                pt.objective,
                direct.objective
            );
        }
        // objective decreases along the path (λ decreasing)
        for w in path.windows(2) {
            assert!(w[1].objective <= w[0].objective + 1e-6);
        }
        assert_eq!(final_sol.objective, path.last().unwrap().objective);
    }

    #[test]
    fn working_set_grows_monotonically() {
        let d = ds();
        let backend = NativeBackend::new(&d.x);
        let grid = geometric_grid(d.lambda_max_l1(), 5, 0.5);
        let params = GenParams { seed_budget: 5, ..Default::default() };
        let (path, _) = regularization_path(&d, &backend, &grid, &params);
        for w in path.windows(2) {
            assert!(w[1].working_set >= w[0].working_set);
        }
        // every point carries a cacheable snapshot of its working set
        for pt in &path {
            assert_eq!(pt.ws.cols.len(), pt.working_set);
            assert!(pt.ws.rows.is_empty(), "L1 path snapshots carry columns only");
        }
    }

    #[test]
    fn path_stop_callback_truncates_and_marks_steps() {
        let d = ds();
        let backend = NativeBackend::new(&d.x);
        let grid = geometric_grid(d.lambda_max_l1(), 5, 0.5);
        let params = GenParams { seed_budget: 5, ..Default::default() };
        let stop = || true; // deadline already expired at entry
        let (path, _) = regularization_path_with_stop(&d, &backend, &grid, &params, Some(&stop));
        assert_eq!(path.len(), 1, "expired deadline stops after the first point");
        assert!(path[0].step.timed_out);
        assert!(path[0].stats.timed_out);
        // without a callback: full path, per-point deltas sum to the
        // cumulative stats, and the seed span lands on the first point
        let (full, _) = regularization_path(&d, &backend, &grid, &params);
        assert_eq!(full.len(), 5);
        let sum_rounds: usize = full.iter().map(|p| p.step.rounds).sum();
        assert_eq!(sum_rounds, full.last().unwrap().stats.rounds);
        let sum_solve: u64 = full.iter().map(|p| p.step.solve_ns).sum();
        assert_eq!(sum_solve, full.last().unwrap().stats.solve_ns);
        assert!(full.iter().all(|p| !p.step.timed_out));
        assert_eq!(full[0].step.seed_ns, full.last().unwrap().stats.seed_ns);
    }

    #[test]
    fn dantzig_path_matches_independent_solves() {
        use crate::data::synthetic::{generate_dantzig, DantzigSpec};
        use crate::workloads::dantzig::{dantzig_generation, lambda_max_dantzig};
        let spec =
            DantzigSpec { n: 30, p: 20, k0: 4, rho: 0.1, sigma: 0.4, standardize: true };
        let d = generate_dantzig(&spec, &mut Xoshiro256::seed_from_u64(112));
        let backend = NativeBackend::new(&d.x);
        let grid = geometric_grid(lambda_max_dantzig(&d), 5, 0.6);
        let params = GenParams { eps: 1e-9, seed_budget: 5, ..Default::default() };
        let path = dantzig_path(&d, &backend, &grid, &params);
        assert_eq!(path.len(), 5);
        // first point: λ = λ_max → β = 0, objective 0
        assert_eq!(path[0].support, 0);
        assert!(path[0].objective.abs() < 1e-9);
        // ‖β‖₁ grows as λ shrinks; every point matches a fresh solve
        for w in path.windows(2) {
            assert!(w[1].objective >= w[0].objective - 1e-9);
        }
        for pt in &path[1..] {
            let direct = dantzig_generation(&d, &backend, pt.lambda, &[], &params);
            assert!(
                (pt.objective - direct.objective).abs() / direct.objective.max(1e-9) < 1e-6,
                "λ={}: path {} direct {}",
                pt.lambda,
                pt.objective,
                direct.objective
            );
        }
    }

    #[test]
    fn group_path_matches_independent_solves() {
        use crate::coordinator::group::group_column_generation;
        use crate::data::synthetic::{generate_group, GroupSpec};
        let spec = GroupSpec {
            n: 30,
            n_groups: 8,
            group_size: 4,
            k0_groups: 2,
            rho: 0.2,
            standardize: true,
        };
        let gd = generate_group(&spec, &mut Xoshiro256::seed_from_u64(114));
        let backend = NativeBackend::new(&gd.data.x);
        let grid = geometric_grid(gd.data.lambda_max_group(&gd.groups), 5, 0.6);
        let params = GenParams { eps: 1e-7, seed_budget: 3, ..Default::default() };
        let path = group_path(&gd.data, &backend, &gd.groups, &grid, &params);
        assert_eq!(path.len(), 5);
        assert_eq!(path[0].support, 0, "β must be zero at λ_max");
        for w in path.windows(2) {
            assert!(w[1].objective <= w[0].objective + 1e-6, "objective decreases with λ");
            assert!(w[1].working_set >= w[0].working_set, "group working set only grows");
        }
        for pt in &path[1..] {
            let direct =
                group_column_generation(&gd.data, &backend, &gd.groups, pt.lambda, &[0], &params);
            assert!(
                (pt.objective - direct.objective).abs() / direct.objective.max(1e-9) < 1e-5,
                "λ={}: path {} direct {}",
                pt.lambda,
                pt.objective,
                direct.objective
            );
        }
        // every point carries a cacheable snapshot of its group set
        for pt in &path {
            assert_eq!(pt.ws.cols.len(), pt.working_set);
            assert!(pt.ws.rows.is_empty(), "group snapshots carry group indices only");
        }
    }

    #[test]
    fn ranksvm_path_matches_independent_solves() {
        use crate::data::synthetic::{generate_ranksvm, RankSpec};
        use crate::engine::PairMode;
        use crate::workloads::ranksvm::{lambda_max_rank, ranksvm_generation};
        let spec = RankSpec { n: 16, p: 14, k0: 4, rho: 0.1, noise: 0.3, standardize: true };
        let d = generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(113));
        let pairs = PairSet::build(&d.y, PairMode::Auto);
        let backend = NativeBackend::new(&d.x);
        let grid = geometric_grid(lambda_max_rank(&d, &pairs), 5, 0.5);
        let params = GenParams { eps: 1e-9, seed_budget: 8, ..Default::default() };
        let path = ranksvm_path(&d, &backend, &pairs, &grid, &params);
        assert_eq!(path.len(), 5);
        assert_eq!(path[0].support, 0, "β must be zero at λ_max");
        for pt in &path[1..] {
            let direct = ranksvm_generation(&d, &backend, &pairs, pt.lambda, &[], &[], &params);
            assert!(
                (pt.objective - direct.objective).abs() / direct.objective.max(1e-9) < 1e-5,
                "λ={}: path {} direct {}",
                pt.lambda,
                pt.objective,
                direct.objective
            );
        }
    }
}
