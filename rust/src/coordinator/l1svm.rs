//! Column and constraint generation for the L1-SVM LP (§2.2–2.3).
//!
//! [`RestrictedL1`] owns the restricted model `M_{ℓ1}(I, J)` (Problem 13)
//! on top of the warm-started simplex; [`L1Problem`] adapts it to the
//! shared [`crate::engine::GenEngine`], and the three driver functions
//! implement the paper's Algorithms 1, 3 and 4 as engine configurations.
//! Pricing of left-out columns runs through a [`Pricer`]
//! (`q = Xᵀ(y∘π)`, eq. 14 — the O(np) hot path, parallel when
//! `GenParams::threads > 1`), pricing of left-out constraints uses the
//! working-set margin kernel (`Xβ` restricted to J).

use crate::backend::Backend;
use crate::coordinator::{GenParams, GenStats, SvmSolution};
use crate::data::Dataset;
use crate::engine::{
    BackendPricer, GenEngine, NullPricer, Pricer, RestrictedProblem, Snapshot, WorkingSet,
};
use crate::fom::screening::top_k_by_abs;
use crate::simplex::{LpModel, SimplexSolver, Status, VarId};

/// The restricted-columns-and-constraints L1-SVM LP `M_{ℓ1}(I, J)`.
pub struct RestrictedL1 {
    solver: SimplexSolver,
    lambda: f64,
    /// Sample index handled by LP row position k.
    rows_i: Vec<usize>,
    /// sample i → LP row position (None when i ∉ I).
    row_pos: Vec<Option<usize>>,
    /// Row positions currently retired (see [`RestrictedL1::retire_samples`]).
    retired: Vec<bool>,
    /// Feature index handled by column-pair position t.
    cols_j: Vec<usize>,
    /// feature j → column-pair position.
    pos_j: Vec<Option<usize>>,
    /// Hinge slack variables ξ (one per LP row position).
    xi: Vec<VarId>,
    /// β⁺ / β⁻ variable ids per column-pair position.
    bp: Vec<VarId>,
    bm: Vec<VarId>,
    /// Intercept variable.
    b0: VarId,
    /// Cost decomposition `cost_v(λ) = cfix[v] + λ·cvar[v]` over all
    /// structural variables, maintained alongside every `add_*` — the
    /// exact-path driver's breakpoint scan reads it.
    cfix: Vec<f64>,
    cvar: Vec<f64>,
}

impl RestrictedL1 {
    /// Build `M_{ℓ1}(I, J)` for the given working sets.
    pub fn new(ds: &Dataset, lambda: f64, i_set: &[usize], j_set: &[usize]) -> Self {
        let n = ds.n();
        let p = ds.p();
        let mut model = LpModel::new();
        let b0 = model.add_col_free(0.0, &[]);
        let mut me = Self {
            solver: SimplexSolver::new(model),
            lambda,
            rows_i: Vec::new(),
            row_pos: vec![None; n],
            retired: Vec::new(),
            cols_j: Vec::new(),
            pos_j: vec![None; p],
            xi: Vec::new(),
            bp: Vec::new(),
            bm: Vec::new(),
            b0,
            cfix: vec![0.0],
            cvar: vec![0.0],
        };
        me.add_samples(ds, i_set);
        me.add_features(ds, j_set);
        me
    }

    /// Current working set I (sample indices, insertion order).
    pub fn i_set(&self) -> &[usize] {
        &self.rows_i
    }

    /// Current working set J (feature indices, insertion order).
    pub fn j_set(&self) -> &[usize] {
        &self.cols_j
    }

    /// Bring samples into I: appends the margin rows
    /// `ξ_i + Σ_{j∈J} y_i x_ij (β⁺_j − β⁻_j) + y_i β₀ ≥ 1`. A previously
    /// [retired](RestrictedL1::retire_samples) sample is re-armed in
    /// place: its row bounds and ξ cost are restored, and the next solve
    /// warm-resumes dual-feasibly (bound tightening never disturbs the
    /// reduced costs).
    pub fn add_samples(&mut self, ds: &Dataset, samples: &[usize]) {
        for &i in samples {
            if let Some(r) = self.row_pos[i] {
                if self.retired[r] {
                    self.solver.set_row_bounds(r, 1.0, f64::INFINITY);
                    self.solver.set_col_cost(self.xi[r], 1.0);
                    self.cfix[self.xi[r]] = 1.0;
                    self.retired[r] = false;
                }
                continue;
            }
            self.row_pos[i] = Some(self.rows_i.len());
            let yi = ds.y[i];
            let xi = self.solver.add_col(1.0, 0.0, f64::INFINITY, &[]);
            let mut coefs: Vec<(VarId, f64)> = Vec::with_capacity(2 + 2 * self.cols_j.len());
            coefs.push((xi, 1.0));
            coefs.push((self.b0, yi));
            for (t, &j) in self.cols_j.iter().enumerate() {
                let v = ds.x.get(i, j);
                if v != 0.0 {
                    coefs.push((self.bp[t], yi * v));
                    coefs.push((self.bm[t], -yi * v));
                }
            }
            self.solver.add_row(1.0, f64::INFINITY, &coefs);
            self.rows_i.push(i);
            self.retired.push(false);
            self.xi.push(xi);
            self.cfix.push(1.0);
            self.cvar.push(0.0);
        }
    }

    /// Retire samples from the model without rebuilding it: the margin
    /// row is relaxed to `(−∞, ∞)` and the ξ cost zeroed, so the sample
    /// contributes neither a constraint nor hinge loss. The basis
    /// survives — relaxing bounds leaves every reduced cost unchanged,
    /// so the next solve is a short primal cleanup rather than a cold
    /// start. [`RestrictedL1::add_samples`] re-arms retired samples.
    pub fn retire_samples(&mut self, samples: &[usize]) {
        for &i in samples {
            if let Some(r) = self.row_pos[i] {
                if !self.retired[r] {
                    self.solver.set_row_bounds(r, f64::NEG_INFINITY, f64::INFINITY);
                    self.solver.set_col_cost(self.xi[r], 0.0);
                    self.cfix[self.xi[r]] = 0.0;
                    self.retired[r] = true;
                }
            }
        }
    }

    /// Number of samples currently active (in I and not retired).
    pub fn active_samples(&self) -> usize {
        self.retired.iter().filter(|&&t| !t).count()
    }

    /// Bring features into J: appends the β⁺/β⁻ column pair with
    /// coefficients `±y_i x_ij` on the existing margin rows.
    pub fn add_features(&mut self, ds: &Dataset, features: &[usize]) {
        for &j in features {
            if self.pos_j[j].is_some() {
                continue;
            }
            let entries = ds.x.col_entries(j);
            let mut pos_coefs = Vec::new();
            let mut neg_coefs = Vec::new();
            for (i, v) in entries {
                if v == 0.0 {
                    continue;
                }
                if let Some(r) = self.row_pos[i] {
                    let yi = ds.y[i];
                    pos_coefs.push((r, yi * v));
                    neg_coefs.push((r, -yi * v));
                }
            }
            let bp = self.solver.add_col(self.lambda, 0.0, f64::INFINITY, &pos_coefs);
            let bm = self.solver.add_col(self.lambda, 0.0, f64::INFINITY, &neg_coefs);
            self.pos_j[j] = Some(self.cols_j.len());
            self.cols_j.push(j);
            self.bp.push(bp);
            self.bm.push(bm);
            self.cfix.extend_from_slice(&[0.0, 0.0]);
            self.cvar.extend_from_slice(&[1.0, 1.0]);
        }
    }

    /// Change λ in place (costs of all β halves); keeps the basis, so the
    /// next solve warm-starts primal — used by the path driver.
    pub fn set_lambda(&mut self, lambda: f64) {
        self.lambda = lambda;
        for t in 0..self.cols_j.len() {
            self.solver.set_col_cost(self.bp[t], lambda);
            self.solver.set_col_cost(self.bm[t], lambda);
        }
    }

    /// Worker threads for the dense dual-simplex pricing row (see
    /// [`crate::simplex::SimplexSolver::set_threads`]).
    pub fn set_threads(&mut self, threads: usize) {
        self.solver.set_threads(threads);
    }

    /// Largest λ' in `[lambda_lo, lambda)` where the current basis stops
    /// being cost-optimal for the *restricted* model — the exact-path
    /// driver's breakpoint scan (two BTRANs + one nonbasic pass).
    pub(crate) fn next_breakpoint(&mut self, lambda: f64, lambda_lo: f64) -> Option<f64> {
        crate::simplex::next_cost_breakpoint(
            &mut self.solver,
            &self.cfix,
            &self.cvar,
            lambda,
            lambda_lo,
        )
    }

    /// Seat a primal guess `(β, β₀)` as the starting basis. The guessed
    /// support (intercept first, then working-set features by |β_j|,
    /// then the slacks of guess-violated margins by violation size) is
    /// matched greedily to rows and crossed over to a vertex; a
    /// FISTA-quality guess lands a few pivots from the optimum, vs. a
    /// full dual-simplex pass from the all-logical crash basis. Returns
    /// whether the crossover succeeded — on `false` the solver is left
    /// on its cold-start path and the next [`RestrictedL1::solve`] is
    /// simply a cold solve.
    pub fn crossover_from(&mut self, ds: &Dataset, beta: &[f64], beta0: f64) -> bool {
        let mut support: Vec<(usize, f64)> = self
            .cols_j
            .iter()
            .enumerate()
            .filter_map(|(t, &j)| {
                let b = beta.get(j).copied().unwrap_or(0.0);
                if b != 0.0 {
                    Some((t, b))
                } else {
                    None
                }
            })
            .collect();
        support.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
        let mut preferred: Vec<VarId> = Vec::with_capacity(1 + support.len() + self.rows_i.len());
        preferred.push(self.b0);
        for &(t, b) in &support {
            preferred.push(if b > 0.0 { self.bp[t] } else { self.bm[t] });
        }
        // margins of the FULL guess (not just the working set) pick the
        // slacks likely basic at the optimum
        let cols: Vec<usize> =
            (0..beta.len()).filter(|&j| beta[j] != 0.0).collect();
        let vals: Vec<f64> = cols.iter().map(|&j| beta[j]).collect();
        let mut xb = vec![0.0; ds.n()];
        ds.x.matvec_cols(&cols, &vals, &mut xb);
        let mut violated: Vec<(usize, f64)> = Vec::new();
        for (r, &i) in self.rows_i.iter().enumerate() {
            if self.retired[r] {
                continue;
            }
            let slack = 1.0 - ds.y[i] * (xb[i] + beta0);
            if slack > 0.0 {
                violated.push((r, slack));
            }
        }
        violated.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for &(r, _) in &violated {
            preferred.push(self.xi[r]);
        }
        self.solver.crossover_from_guess(&preferred)
    }

    /// Solve the restricted LP (warm-started).
    pub fn solve(&mut self) -> Status {
        self.solver.solve()
    }

    /// Restricted-LP objective.
    pub fn objective(&self) -> f64 {
        self.solver.objective()
    }

    /// Simplex iterations so far (primal + dual, cumulative).
    pub fn simplex_iters(&self) -> usize {
        self.solver.stats.primal_iters + self.solver.stats.dual_iters
    }

    /// Coefficients on the working set: `(j, β_j)` pairs plus intercept.
    pub fn beta_support(&self) -> (Vec<(usize, f64)>, f64) {
        let mut out = Vec::with_capacity(self.cols_j.len());
        for (t, &j) in self.cols_j.iter().enumerate() {
            let b = self.solver.col_value(self.bp[t]) - self.solver.col_value(self.bm[t]);
            if b != 0.0 {
                out.push((j, b));
            }
        }
        (out, self.solver.col_value(self.b0))
    }

    /// Dual vector π scattered over all n samples (zero off I).
    pub fn duals_full(&self, n: usize) -> Vec<f64> {
        let mut pi = vec![0.0; n];
        for (r, &i) in self.rows_i.iter().enumerate() {
            pi[i] = self.solver.row_dual(r);
        }
        pi
    }

    /// Price left-out columns (eq. 14): returns `(j, |q_j| − λ)` for every
    /// `j ∉ J` violating by more than ε, i.e. reduced cost < −ε.
    pub fn price_columns(
        &self,
        ds: &Dataset,
        pricer: &dyn Pricer,
        eps: f64,
    ) -> Vec<(usize, f64)> {
        let n = ds.n();
        let pi = self.duals_full(n);
        // v = y ∘ π
        let v: Vec<f64> = pi.iter().zip(&ds.y).map(|(p, y)| p * y).collect();
        let mut q = vec![0.0; ds.p()];
        pricer.score(&v, &mut q);
        let mut out = Vec::new();
        for (j, &qj) in q.iter().enumerate() {
            if self.pos_j[j].is_none() {
                let viol = qj.abs() - self.lambda;
                if viol > eps {
                    out.push((j, viol));
                }
            }
        }
        out
    }

    /// Price left-out constraints: `π̄_i = 1 − y_i(x_iᵀβ + β₀)`; returns
    /// `(i, π̄_i)` for every `i ∉ I` with `π̄_i > ε`.
    pub fn price_rows(&self, ds: &Dataset, eps: f64) -> Vec<(usize, f64)> {
        let (support, b0) = self.beta_support();
        let cols: Vec<usize> = support.iter().map(|&(j, _)| j).collect();
        let vals: Vec<f64> = support.iter().map(|&(_, v)| v).collect();
        let mut xb = vec![0.0; ds.n()];
        ds.x.matvec_cols(&cols, &vals, &mut xb);
        let mut out = Vec::new();
        for i in 0..ds.n() {
            if self.row_pos[i].is_none() {
                let rc = 1.0 - ds.y[i] * (xb[i] + b0);
                if rc > eps {
                    out.push((i, rc));
                }
            }
        }
        out
    }
}

/// [`RestrictedL1`] adapted to the generic engine: which of the two
/// pricing channels are live distinguishes Algorithms 1, 3 and 4.
pub struct L1Problem<'a> {
    rl1: RestrictedL1,
    ds: &'a Dataset,
    pricer: &'a dyn Pricer,
    gen_rows: bool,
    gen_cols: bool,
}

impl<'a> L1Problem<'a> {
    /// Wrap a restricted model; `gen_rows`/`gen_cols` enable constraint
    /// and column generation respectively.
    pub fn new(
        rl1: RestrictedL1,
        ds: &'a Dataset,
        pricer: &'a dyn Pricer,
        gen_rows: bool,
        gen_cols: bool,
    ) -> Self {
        Self { rl1, ds, pricer, gen_rows, gen_cols }
    }

    /// The wrapped restricted model.
    pub fn inner(&self) -> &RestrictedL1 {
        &self.rl1
    }

    /// Mutable access to the wrapped restricted model (the exact-path
    /// driver's breakpoint scan and the incremental re-solve edits).
    pub fn inner_mut(&mut self) -> &mut RestrictedL1 {
        &mut self.rl1
    }

    /// Change λ in place (warm-start preserving) — the path driver's hook.
    pub fn set_lambda(&mut self, lambda: f64) {
        self.rl1.set_lambda(lambda);
    }
}

impl Snapshot for L1Problem<'_> {
    fn export_working_set(&self) -> WorkingSet {
        WorkingSet { cols: self.rl1.j_set().to_vec(), rows: self.rl1.i_set().to_vec() }
    }
    fn import_working_set(&mut self, ws: &WorkingSet) {
        self.rl1.add_samples(self.ds, &ws.rows);
        self.rl1.add_features(self.ds, &ws.cols);
    }
}

impl RestrictedProblem for L1Problem<'_> {
    fn solve(&mut self) -> Status {
        self.rl1.solve()
    }
    fn objective(&self) -> f64 {
        self.rl1.objective()
    }
    fn simplex_iters(&self) -> usize {
        self.rl1.simplex_iters()
    }
    fn price_rows(&mut self, eps: f64) -> Vec<(usize, f64)> {
        if self.gen_rows {
            self.rl1.price_rows(self.ds, eps)
        } else {
            Vec::new()
        }
    }
    fn price_cols(&mut self, eps: f64) -> Vec<(usize, f64)> {
        if self.gen_cols {
            self.rl1.price_columns(self.ds, self.pricer, eps)
        } else {
            Vec::new()
        }
    }
    fn add_rows(&mut self, idx: &[usize]) {
        self.rl1.add_samples(self.ds, idx);
    }
    fn add_cols(&mut self, idx: &[usize]) {
        self.rl1.add_features(self.ds, idx);
    }
    fn working_set_size(&self) -> usize {
        self.rl1.j_set().len() + self.rl1.i_set().len()
    }
    fn reprice_at(&mut self, lambda: f64) {
        self.rl1.set_lambda(lambda);
    }
}

fn finish(
    ds: &Dataset,
    rl1: &RestrictedL1,
    lambda: f64,
    stats: GenStats,
) -> SvmSolution {
    let (support, beta0) = rl1.beta_support();
    // true full-problem objective (hinge over ALL samples)
    let report = crate::coordinator::report::l1_report(ds, &support, beta0, lambda);
    let mut cols = rl1.j_set().to_vec();
    cols.sort_unstable();
    let mut rows = rl1.i_set().to_vec();
    rows.sort_unstable();
    SvmSolution {
        beta: report.beta,
        beta0,
        objective: report.objective,
        stats,
        cols,
        rows,
    }
}

/// **Algorithm 1** — column generation for L1-SVM (all n constraints, J
/// grows from `j_init`; empty ⇒ the top-[`GenParams::seed_budget`]
/// closed-form reduced costs at λ_max).
pub fn column_generation(
    ds: &Dataset,
    backend: &dyn Backend,
    lambda: f64,
    j_init: &[usize],
    params: &GenParams,
) -> SvmSolution {
    let all_i: Vec<usize> = (0..ds.n()).collect();
    let seed_j: Vec<usize> = if j_init.is_empty() {
        crate::coordinator::path::initial_columns(ds, params.seed_budget)
    } else {
        j_init.to_vec()
    };
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rl1 = RestrictedL1::new(ds, lambda, &all_i, &seed_j);
    rl1.set_threads(params.threads);
    let mut prob = L1Problem::new(rl1, ds, &pricer, false, true);
    let mut stats = GenEngine::new(params).run(&mut prob);
    stats.cols_added += seed_j.len();
    finish(ds, prob.inner(), lambda, stats)
}

/// [`column_generation`] seeded by a full [`crate::engine::Seed`]: the
/// working set comes from `seed.ws.cols` (screening fallback when
/// empty) and, when the seed carries a FOM primal, the guess is crossed
/// over into the starting basis ([`RestrictedL1::crossover_from`]) so
/// the first restricted solve starts pivots — not a dual-simplex pass —
/// from the optimum.
pub fn column_generation_seeded(
    ds: &Dataset,
    backend: &dyn Backend,
    lambda: f64,
    seed: &crate::engine::Seed,
    params: &GenParams,
) -> SvmSolution {
    let all_i: Vec<usize> = (0..ds.n()).collect();
    let seed_j: Vec<usize> = if seed.ws.cols.is_empty() {
        crate::coordinator::path::initial_columns(ds, params.seed_budget)
    } else {
        seed.ws.cols.clone()
    };
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rl1 = RestrictedL1::new(ds, lambda, &all_i, &seed_j);
    rl1.set_threads(params.threads);
    if let Some((beta, beta0)) = &seed.primal {
        // a failed crossover leaves the cold-start path intact
        let _ = rl1.crossover_from(ds, beta, *beta0);
    }
    let mut prob = L1Problem::new(rl1, ds, &pricer, false, true);
    let mut stats = GenEngine::new(params).run(&mut prob);
    stats.cols_added += seed_j.len();
    finish(ds, prob.inner(), lambda, stats)
}

/// **Algorithm 3** — constraint generation for L1-SVM (all p columns, I
/// grows from `i_init`; empty ⇒ the first [`GenParams::seed_budget`]
/// samples).
pub fn constraint_generation(
    ds: &Dataset,
    lambda: f64,
    i_init: &[usize],
    params: &GenParams,
) -> SvmSolution {
    let all_j: Vec<usize> = (0..ds.p()).collect();
    let seed: Vec<usize> = if i_init.is_empty() {
        (0..ds.n().min(params.seed_budget.max(1))).collect()
    } else {
        i_init.to_vec()
    };
    // column channel disabled: every column is already in the model
    let pricer = NullPricer;
    let mut rl1 = RestrictedL1::new(ds, lambda, &seed, &all_j);
    rl1.set_threads(params.threads);
    let mut prob = L1Problem::new(rl1, ds, &pricer, true, false);
    let mut stats = GenEngine::new(params).run(&mut prob);
    stats.rows_added += seed.len();
    finish(ds, prob.inner(), lambda, stats)
}

/// **Algorithm 4** — combined column-and-constraint generation (both I
/// and J grow; empty seeds fall back to [`GenParams::seed_budget`]-sized
/// sample/correlation picks).
pub fn column_constraint_generation(
    ds: &Dataset,
    backend: &dyn Backend,
    lambda: f64,
    i_init: &[usize],
    j_init: &[usize],
    params: &GenParams,
) -> SvmSolution {
    let seed_i: Vec<usize> = if i_init.is_empty() {
        (0..ds.n().min(params.seed_budget.max(1))).collect()
    } else {
        i_init.to_vec()
    };
    let seed_j: Vec<usize> = if j_init.is_empty() {
        // correlation fallback: top-budget |x_jᵀy|
        let mut q = vec![0.0; ds.p()];
        ds.x.tmatvec(&ds.y, &mut q);
        top_k_by_abs(&q, params.seed_budget.min(ds.p()))
    } else {
        j_init.to_vec()
    };
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rl1 = RestrictedL1::new(ds, lambda, &seed_i, &seed_j);
    rl1.set_threads(params.threads);
    let mut prob = L1Problem::new(rl1, ds, &pricer, true, true);
    let mut stats = GenEngine::new(params).run(&mut prob);
    stats.rows_added += seed_i.len();
    stats.cols_added += seed_j.len();
    finish(ds, prob.inner(), lambda, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::rng::Xoshiro256;

    fn small_ds(n: usize, p: usize, seed: u64) -> Dataset {
        let spec = SyntheticSpec { n, p, k0: 5.min(p), rho: 0.1, standardize: true };
        generate_l1(&spec, &mut Xoshiro256::seed_from_u64(seed))
    }

    /// Reference: solve the FULL L1-SVM LP directly.
    fn full_lp_objective(ds: &Dataset, lambda: f64) -> f64 {
        let all_i: Vec<usize> = (0..ds.n()).collect();
        let all_j: Vec<usize> = (0..ds.p()).collect();
        let mut rl1 = RestrictedL1::new(ds, lambda, &all_i, &all_j);
        assert_eq!(rl1.solve(), Status::Optimal);
        rl1.objective()
    }

    #[test]
    fn column_generation_matches_full_lp() {
        let ds = small_ds(30, 60, 91);
        let lambda = 0.05 * ds.lambda_max_l1();
        let backend = NativeBackend::new(&ds.x);
        let full = full_lp_objective(&ds, lambda);
        let params = GenParams { eps: 1e-6, ..Default::default() };
        let sol = column_generation(&ds, &backend, lambda, &[0, 1], &params);
        assert!(
            (sol.objective - full).abs() / full.max(1e-9) < 1e-5,
            "cg {} full {}",
            sol.objective,
            full
        );
        // only a fraction of columns should have been touched
        assert!(sol.cols.len() < ds.p(), "working set {} of {}", sol.cols.len(), ds.p());
        assert!(sol.stats.converged, "engine must report ε-optimality");
    }

    #[test]
    fn constraint_generation_matches_full_lp() {
        let ds = small_ds(80, 10, 92);
        let lambda = 0.05 * ds.lambda_max_l1();
        let full = full_lp_objective(&ds, lambda);
        let params = GenParams { eps: 1e-6, ..Default::default() };
        let sol = constraint_generation(&ds, lambda, &[0, 1, 2, 3], &params);
        assert!(
            (sol.objective - full).abs() / full.max(1e-9) < 1e-5,
            "cng {} full {}",
            sol.objective,
            full
        );
        assert!(sol.rows.len() < ds.n(), "used {} of {} samples", sol.rows.len(), ds.n());
    }

    #[test]
    fn combined_generation_matches_full_lp() {
        let ds = small_ds(60, 40, 93);
        let lambda = 0.03 * ds.lambda_max_l1();
        let backend = NativeBackend::new(&ds.x);
        let full = full_lp_objective(&ds, lambda);
        let params = GenParams { eps: 1e-6, ..Default::default() };
        let sol = column_constraint_generation(&ds, &backend, lambda, &[], &[], &params);
        assert!(
            (sol.objective - full).abs() / full.max(1e-9) < 1e-5,
            "clcng {} full {}",
            sol.objective,
            full
        );
    }

    #[test]
    fn looser_eps_gives_larger_gap_but_fewer_rounds() {
        let ds = small_ds(40, 80, 94);
        let lambda = 0.05 * ds.lambda_max_l1();
        let backend = NativeBackend::new(&ds.x);
        let tight = column_generation(
            &ds,
            &backend,
            lambda,
            &[0],
            &GenParams { eps: 1e-8, ..Default::default() },
        );
        let loose = column_generation(
            &ds,
            &backend,
            lambda,
            &[0],
            &GenParams { eps: 0.5, ..Default::default() },
        );
        assert!(loose.objective >= tight.objective - 1e-9);
        assert!(loose.stats.cols_added <= tight.stats.cols_added);
    }

    #[test]
    fn lambda_above_max_gives_zero_solution() {
        let ds = small_ds(25, 15, 95);
        let lambda = ds.lambda_max_l1() * 1.01;
        let backend = NativeBackend::new(&ds.x);
        let sol = column_generation(&ds, &backend, lambda, &[0, 1], &GenParams::default());
        assert_eq!(sol.support_size(), 0, "beta must be zero above lambda_max");
    }

    // threads=1 vs threads=4 equivalence is covered end-to-end (dense and
    // sparse) by tests/integration.rs::parallel_pricing_produces_identical_working_sets.

    #[test]
    fn fom_crossover_starts_with_fewer_iters_than_support_only() {
        use crate::engine::{InitStrategy, Initializer};
        let ds = small_ds(80, 60, 98);
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.2 * ds.lambda_max_l1();
        let seed = Initializer::new(InitStrategy::Fista, 10).seed_l1_cols(&ds, &backend, lambda);
        let (beta, beta0) = seed.primal.clone().expect("FISTA seed carries a primal");
        let all_i: Vec<usize> = (0..ds.n()).collect();
        // arm A: the support alone seeds the working set (pre-crossover
        // behavior) — the cold solve is a full dual-simplex pass
        let mut cold = RestrictedL1::new(&ds, lambda, &all_i, &seed.ws.cols);
        assert_eq!(cold.solve(), Status::Optimal);
        let iters_cold = cold.simplex_iters();
        // arm B: same working set, FOM primal crossed over into the basis
        let mut warm = RestrictedL1::new(&ds, lambda, &all_i, &seed.ws.cols);
        warm.crossover_from(&ds, &beta, beta0);
        assert_eq!(warm.solve(), Status::Optimal);
        let iters_warm = warm.simplex_iters();
        assert!(
            (cold.objective() - warm.objective()).abs() < 1e-7,
            "cold {} warm {}",
            cold.objective(),
            warm.objective()
        );
        assert!(
            iters_warm < iters_cold,
            "crossover must start closer: warm {iters_warm} vs cold {iters_cold}"
        );
        // the seeded driver wires the same crossover end to end
        let sol = column_generation_seeded(
            &ds,
            &backend,
            lambda,
            &seed,
            &GenParams { eps: 1e-6, ..Default::default() },
        );
        let full = full_lp_objective(&ds, lambda);
        assert!((sol.objective - full).abs() / full.max(1e-9) < 1e-5);
    }

    #[test]
    fn retire_and_rearm_samples_matches_cold_reduced_solve() {
        let ds = small_ds(50, 20, 99);
        let lambda = 0.1 * ds.lambda_max_l1();
        let all_i: Vec<usize> = (0..ds.n()).collect();
        let all_j: Vec<usize> = (0..ds.p()).collect();
        let mut warm = RestrictedL1::new(&ds, lambda, &all_i, &all_j);
        assert_eq!(warm.solve(), Status::Optimal);
        let obj_full = warm.objective();
        // retire the last 10 samples; warm re-solve must match a cold
        // build on the reduced index set
        let gone: Vec<usize> = (40..50).collect();
        warm.retire_samples(&gone);
        assert_eq!(warm.active_samples(), 40);
        assert_eq!(warm.solve(), Status::Optimal);
        let kept: Vec<usize> = (0..40).collect();
        let mut cold = RestrictedL1::new(&ds, lambda, &kept, &all_j);
        assert_eq!(cold.solve(), Status::Optimal);
        assert!(
            (warm.objective() - cold.objective()).abs() < 1e-7,
            "warm {} cold {}",
            warm.objective(),
            cold.objective()
        );
        // re-arm: bounds restore dual-feasibly, recovering the original
        warm.add_samples(&ds, &gone);
        assert_eq!(warm.active_samples(), 50);
        assert_eq!(warm.solve(), Status::Optimal);
        assert!(
            (warm.objective() - obj_full).abs() < 1e-7,
            "re-armed {} original {}",
            warm.objective(),
            obj_full
        );
    }

    #[test]
    fn restricted_lp_duals_in_unit_box() {
        let ds = small_ds(30, 20, 96);
        let lambda = 0.1 * ds.lambda_max_l1();
        let all_i: Vec<usize> = (0..ds.n()).collect();
        let mut rl1 = RestrictedL1::new(&ds, lambda, &all_i, &[0, 1, 2]);
        assert_eq!(rl1.solve(), Status::Optimal);
        let pi = rl1.duals_full(ds.n());
        for (i, &v) in pi.iter().enumerate() {
            assert!(v >= -1e-7 && v <= 1.0 + 1e-7, "π[{i}] = {v} outside [0,1]");
        }
        // complementary slackness structure: Σ y_i π_i = 0 (from the free β₀)
        let s: f64 = pi.iter().zip(&ds.y).map(|(p, y)| p * y).sum();
        assert!(s.abs() < 1e-6, "y·π = {s}");
    }

    #[test]
    fn support_vectors_have_positive_duals() {
        let ds = small_ds(40, 12, 97);
        let lambda = 0.05 * ds.lambda_max_l1();
        let all_i: Vec<usize> = (0..ds.n()).collect();
        let all_j: Vec<usize> = (0..ds.p()).collect();
        let mut rl1 = RestrictedL1::new(&ds, lambda, &all_i, &all_j);
        assert_eq!(rl1.solve(), Status::Optimal);
        let (support, b0) = rl1.beta_support();
        let cols: Vec<usize> = support.iter().map(|&(j, _)| j).collect();
        let vals: Vec<f64> = support.iter().map(|&(_, v)| v).collect();
        let mut xb = vec![0.0; ds.n()];
        ds.x.matvec_cols(&cols, &vals, &mut xb);
        let pi = rl1.duals_full(ds.n());
        for i in 0..ds.n() {
            let margin = ds.y[i] * (xb[i] + b0);
            if margin > 1.0 + 1e-6 {
                // strictly satisfied ⇒ π_i = 0 (complementary slackness)
                assert!(pi[i].abs() < 1e-6, "i={i} margin {margin} π {}", pi[i]);
            }
            if margin < 1.0 - 1e-6 {
                // violated margin ⇒ ξ_i > 0 ⇒ π_i = 1
                assert!((pi[i] - 1.0).abs() < 1e-6, "i={i} margin {margin} π {}", pi[i]);
            }
        }
    }
}
