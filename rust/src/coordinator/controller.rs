//! The dynamic-λ controller (rank2plan's "dynamic regularisation"):
//! resolve λ so the solution sits at a caller-named **slack/‖β‖₁
//! ratio** instead of at a caller-named λ.
//!
//! The control variable is `r(λ) = hinge_w(β*(λ)) / ‖β*(λ)‖₁` — the
//! full-problem weighted pairwise hinge over the L1 norm. It is
//! monotone increasing in λ: more regularization shrinks `‖β‖₁` toward
//! 0 while the slack grows toward `hinge_w(0) = Σ_t w_t·g_t`, so
//! `r → +∞` as `λ → λ_max` and `r` is smallest at the bottom of the
//! bracket. That monotonicity makes the target a **bisection in
//! log-λ** over `[lo_frac·λ_max, λ_max]`
//! ([`RatioTarget`]): each probe is one warm-started
//! column-and-constraint generation solve
//! ([`crate::workloads::ranksvm::ranksvm_generation_costed`]
//! mechanics), reusing the previous probe's working set so later
//! probes converge in a handful of rounds.
//!
//! Exhaustion is a **typed error**, not a silent clamp: when the
//! target ratio lies below `r(lo_frac·λ_max)` (bracket too high) or
//! the solve budget runs out before the achieved ratio lands within
//! `tol`, the caller gets [`ControllerError::BracketExhausted`] with
//! the best bracket seen — CLI and serve surface it verbatim.

use crate::backend::Backend;
use crate::coordinator::{GenParams, GenStats, SvmSolution};
use crate::data::Dataset;
use crate::engine::{
    BackendPricer, GenEngine, Initializer, RatioTarget, Snapshot, WorkingSet,
};
use crate::obs::Span;
use crate::workloads::pairset::{PairCosts, PairSet};
use crate::workloads::ranksvm::{
    lambda_max_rank_weighted, pair_rows_cap, RankProblem, RestrictedRank,
};

/// Why the controller could not land on the target ratio.
#[derive(Clone, Debug, PartialEq)]
pub enum ControllerError {
    /// The target itself is unusable (non-finite or non-positive
    /// ratio, empty pair set, degenerate λ_max).
    BadTarget(String),
    /// The bisection bracket ran dry: either every λ in
    /// `[lo_frac·λ_max, λ_max]` sits on one side of the target, or the
    /// solve budget ran out before the achieved ratio landed within
    /// tolerance. Carries the last bracket and the closest probe.
    BracketExhausted {
        /// Target ratio that was asked for.
        target: f64,
        /// Ratio achieved by the closest probe.
        achieved: f64,
        /// λ of the closest probe.
        lambda: f64,
        /// Probes spent.
        solves: usize,
    },
}

impl std::fmt::Display for ControllerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ControllerError::BadTarget(msg) => write!(f, "bad ratio target: {msg}"),
            ControllerError::BracketExhausted { target, achieved, lambda, solves } => write!(
                f,
                "bracket exhausted after {solves} solves: target ratio {target} \
                 unreachable, closest {achieved} at lambda {lambda}"
            ),
        }
    }
}

impl std::error::Error for ControllerError {}

/// A resolved dynamic-λ solve.
#[derive(Clone, Debug)]
pub struct ControllerOutcome {
    /// The λ the bisection settled on.
    pub lambda: f64,
    /// λ_max of the (weighted) problem — the bracket's upper end.
    pub lambda_max: f64,
    /// Achieved `hinge_w/‖β‖₁` at [`Self::lambda`].
    pub achieved_ratio: f64,
    /// Solves spent (bracket endpoint + bisection probes).
    pub solves: usize,
    /// The solution at the resolved λ (its `stats` are the *last*
    /// probe's engine counters; [`Self::total`] accumulates all).
    pub solution: SvmSolution,
    /// Engine counters summed over every probe.
    pub total: GenStats,
    /// Working-set snapshot at the resolved λ — what serve's warm
    /// cache stores under the resolved λ's bucket.
    pub ws: WorkingSet,
}

/// Full-problem ratio `hinge_w/‖β‖₁` of a support, `+∞` at `β = 0`.
fn ratio_of(ds: &Dataset, pairs: &PairSet, costs: &PairCosts, support: &[(usize, f64)]) -> f64 {
    let l1: f64 = support.iter().map(|&(_, v)| v.abs()).sum();
    if l1 <= 0.0 {
        return f64::INFINITY;
    }
    let (cols, vals) = crate::coordinator::report::split_support(support);
    crate::workloads::ranksvm::pairwise_hinge_support_weighted(ds, pairs, costs, &cols, &vals)
        / l1
}

/// Bisect λ toward `target.ratio` (see the module docs). `should_stop`
/// is threaded into every probe's engine run — a fired deadline
/// surfaces as `timed_out` in [`ControllerOutcome::total`] and ends
/// the bisection at the best probe so far (within-tolerance or
/// [`ControllerError::BracketExhausted`], same as budget exhaustion).
pub fn resolve_lambda_for_ratio(
    ds: &Dataset,
    backend: &dyn Backend,
    pairs: &PairSet,
    costs: &PairCosts,
    target: &RatioTarget,
    params: &GenParams,
    should_stop: Option<&dyn Fn() -> bool>,
) -> Result<ControllerOutcome, ControllerError> {
    if !target.ratio.is_finite() || target.ratio <= 0.0 {
        return Err(ControllerError::BadTarget(format!(
            "target ratio must be finite and > 0, got {}",
            target.ratio
        )));
    }
    if !(target.tol.is_finite() && target.tol > 0.0) {
        return Err(ControllerError::BadTarget(format!(
            "tolerance must be finite and > 0, got {}",
            target.tol
        )));
    }
    if !(target.lo_frac > 0.0 && target.lo_frac < 1.0) {
        return Err(ControllerError::BadTarget(format!(
            "lo_frac must lie in (0, 1), got {}",
            target.lo_frac
        )));
    }
    if target.max_solves < 2 {
        return Err(ControllerError::BadTarget("max_solves must be at least 2".into()));
    }
    if pairs.is_empty() {
        return Err(ControllerError::BadTarget("candidate pair set is empty".into()));
    }
    let lambda_max = lambda_max_rank_weighted(ds, pairs, costs);
    if !(lambda_max.is_finite() && lambda_max > 0.0) {
        return Err(ControllerError::BadTarget(format!(
            "degenerate lambda_max {lambda_max}"
        )));
    }

    let within = |r: f64| (r - target.ratio).abs() <= target.tol * target.ratio;
    let seed_span = Span::start();
    let seed =
        Initializer::from_params(params).seed_ranksvm_costed(ds, backend, pairs, costs, lambda_max);
    let seed_ns = seed_span.elapsed_ns();

    let pricer = BackendPricer::new(backend, params.threads);
    let mut engine = GenEngine::new(params);
    if let Some(f) = should_stop {
        engine = engine.with_should_stop(f);
    }
    let mut total = GenStats {
        cols_added: seed.ws.cols.len(),
        rows_added: seed.ws.rows.len(),
        seed_ns,
        ..Default::default()
    };
    total.pair_scan = Some(costs.scan(pairs).as_str());

    // One probe: a fresh restricted model at λ, seeded from the warm
    // working set, driven to ε-optimality (or the deadline).
    let mut warm = seed.ws;
    let mut best: Option<(f64, f64, SvmSolution, WorkingSet)> = None; // (λ, ratio, sol, ws)
    let mut solves = 0usize;
    let probe = |lambda: f64,
                     warm: &WorkingSet,
                     total: &mut GenStats,
                     solves: &mut usize|
     -> (f64, SvmSolution, WorkingSet) {
        let mut rr =
            RestrictedRank::new_weighted(ds, pairs, costs, lambda, &warm.rows, &warm.cols);
        rr.set_threads(params.threads);
        rr.set_pair_cap(pair_rows_cap(params));
        let mut prob = RankProblem::new(rr, ds, &pricer);
        let step = engine.run(&mut prob);
        crate::coordinator::path::accumulate(total, step);
        *solves += 1;
        let support = prob.inner().beta_support();
        let r = ratio_of(ds, pairs, costs, &support);
        let report = crate::coordinator::report::ranksvm_report_weighted(
            ds,
            pairs,
            costs,
            &support,
            lambda,
        );
        let ws = prob.export_working_set();
        let mut cols = ws.cols.clone();
        cols.sort_unstable();
        let mut rows = ws.rows.clone();
        rows.sort_unstable();
        let sol = SvmSolution {
            beta: report.beta,
            beta0: 0.0,
            objective: report.objective,
            stats: step,
            cols,
            rows,
        };
        (r, sol, ws)
    };

    // Bracket: r(λ) is increasing, r(λ_max) = +∞ ≥ target always, so
    // only the low end can exclude the target. Probe it first.
    let mut lo = target.lo_frac * lambda_max;
    let mut hi = lambda_max;
    let (r_lo, sol_lo, ws_lo) = probe(lo, &warm, &mut total, &mut solves);
    warm = ws_lo.clone();
    if within(r_lo) {
        return Ok(ControllerOutcome {
            lambda: lo,
            lambda_max,
            achieved_ratio: r_lo,
            solves,
            solution: sol_lo,
            total,
            ws: ws_lo,
        });
    }
    if r_lo > target.ratio {
        // even the least-regularized λ in the bracket overshoots: the
        // whole bracket sits above the target
        return Err(ControllerError::BracketExhausted {
            target: target.ratio,
            achieved: r_lo,
            lambda: lo,
            solves,
        });
    }
    best = Some((lo, r_lo, sol_lo, ws_lo));

    while solves < target.max_solves {
        if total.timed_out {
            break;
        }
        let mid = (lo * hi).sqrt();
        let (r, sol, ws) = probe(mid, &warm, &mut total, &mut solves);
        warm = ws.clone();
        let better = match &best {
            Some((_, rb, ..)) => {
                (r.ln() - target.ratio.ln()).abs() < (rb.ln() - target.ratio.ln()).abs()
            }
            None => true,
        };
        if better || within(r) {
            best = Some((mid, r, sol, ws));
        }
        if within(r) {
            let (lambda, achieved_ratio, solution, ws) = best.unwrap();
            return Ok(ControllerOutcome {
                lambda,
                lambda_max,
                achieved_ratio,
                solves,
                solution,
                total,
                ws,
            });
        }
        if r > target.ratio {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    let (lambda, achieved) = best.as_ref().map(|b| (b.0, b.1)).expect("at least one probe ran");
    Err(ControllerError::BracketExhausted { target: target.ratio, achieved, lambda, solves })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::synthetic::{generate_ranksvm, RankSpec};
    use crate::engine::PairMode;
    use crate::rng::Xoshiro256;

    fn fixture() -> Dataset {
        let spec = RankSpec { n: 20, p: 16, k0: 4, rho: 0.1, noise: 0.3, standardize: true };
        generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(771))
    }

    #[test]
    fn bad_targets_are_typed_errors() {
        let ds = fixture();
        let backend = NativeBackend::new(&ds.x);
        let pairs = PairSet::build(&ds.y, PairMode::Auto);
        let params = GenParams::default();
        for bad in [
            RatioTarget { ratio: 0.0, ..Default::default() },
            RatioTarget { ratio: f64::NAN, ..Default::default() },
            RatioTarget { tol: 0.0, ..Default::default() },
            RatioTarget { lo_frac: 1.5, ..Default::default() },
            RatioTarget { max_solves: 1, ..Default::default() },
        ] {
            let r = resolve_lambda_for_ratio(
                &ds,
                &backend,
                &pairs,
                &PairCosts::UNIFORM,
                &bad,
                &params,
                None,
            );
            assert!(matches!(r, Err(ControllerError::BadTarget(_))), "{bad:?} -> {r:?}");
        }
    }

    #[test]
    fn achieved_ratio_lands_within_tolerance() {
        let ds = fixture();
        let backend = NativeBackend::new(&ds.x);
        let pairs = PairSet::build(&ds.y, PairMode::Auto);
        let params = GenParams { eps: 1e-8, ..Default::default() };
        let target = RatioTarget { ratio: 2.0, tol: 0.1, ..Default::default() };
        let out = resolve_lambda_for_ratio(
            &ds,
            &backend,
            &pairs,
            &PairCosts::UNIFORM,
            &target,
            &params,
            None,
        )
        .expect("ratio 2.0 must be reachable");
        assert!(
            (out.achieved_ratio - 2.0).abs() <= 0.1 * 2.0 + 1e-12,
            "achieved {} for target 2.0",
            out.achieved_ratio
        );
        assert!(out.lambda > 0.0 && out.lambda <= out.lambda_max);
        assert!(out.solves <= target.max_solves);
        assert_eq!(out.total.pair_scan, Some("uniform"));
        // the solution really is the solve at the resolved λ
        let direct = crate::workloads::ranksvm::ranksvm_generation(
            &ds,
            &backend,
            &pairs,
            out.lambda,
            &[],
            &[],
            &params,
        );
        assert!(
            (out.solution.objective - direct.objective).abs()
                / direct.objective.abs().max(1e-9)
                < 1e-5,
            "controller {} direct {}",
            out.solution.objective,
            direct.objective
        );
    }

    #[test]
    fn unreachably_low_target_exhausts_the_bracket() {
        let ds = fixture();
        let backend = NativeBackend::new(&ds.x);
        let pairs = PairSet::build(&ds.y, PairMode::Auto);
        let params = GenParams::default();
        // lo_frac close to 1 pins the whole bracket near λ_max where the
        // ratio is huge; a tiny target is then unreachable
        let target =
            RatioTarget { ratio: 1e-6, tol: 0.05, lo_frac: 0.9, ..Default::default() };
        let err = resolve_lambda_for_ratio(
            &ds,
            &backend,
            &pairs,
            &PairCosts::UNIFORM,
            &target,
            &params,
            None,
        )
        .expect_err("target far below the bracket must be typed as exhaustion");
        match err {
            ControllerError::BracketExhausted { target: t, achieved, .. } => {
                assert_eq!(t, 1e-6);
                assert!(achieved > t, "achieved {achieved} should overshoot");
            }
            other => panic!("expected BracketExhausted, got {other:?}"),
        }
        assert!(format!("{err}").contains("bracket exhausted"));
    }

    #[test]
    fn resolved_lambda_is_monotone_in_the_target_ratio() {
        let ds = fixture();
        let backend = NativeBackend::new(&ds.x);
        let pairs = PairSet::build(&ds.y, PairMode::Auto);
        let params = GenParams { eps: 1e-8, ..Default::default() };
        let mut prev = 0.0;
        for ratio in [0.5, 2.0, 8.0] {
            let target = RatioTarget { ratio, tol: 0.1, ..Default::default() };
            let out = resolve_lambda_for_ratio(
                &ds,
                &backend,
                &pairs,
                &PairCosts::UNIFORM,
                &target,
                &params,
                None,
            )
            .unwrap_or_else(|e| panic!("ratio {ratio}: {e}"));
            assert!(
                out.lambda >= prev,
                "λ({ratio}) = {} dropped below the previous target's {prev}",
                out.lambda
            );
            prev = out.lambda;
        }
    }
}
