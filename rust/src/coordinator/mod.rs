//! The paper's contribution: cutting-plane coordinators.
//!
//! Each coordinator describes a *restricted* LP (a subset of columns
//! and/or constraints of the full SVM linear program) as an
//! implementation of [`crate::engine::RestrictedProblem`]; the shared
//! [`crate::engine::GenEngine`] drives the solve → price → expand loop,
//! pricing left-out columns/constraints through a
//! [`crate::engine::Pricer`] (the O(np) hot path) until optimality
//! within ε:
//!
//! * [`l1svm`] — Algorithms 1 (column generation), 3 (constraint
//!   generation), 4 (combined) for the L1-SVM LP (Problems 5/8/11/13);
//! * [`path`] — Algorithm 2, the warm-started regularization path;
//! * [`path_exact`] — the exact parametric λ-path: ride the restricted
//!   LP's basis-change breakpoints and price the implicit space only
//!   there, instead of re-solving on a fixed grid;
//! * [`group`] — column generation on groups for Group-SVM (§2.4);
//! * [`slope`] — Algorithms 5–7 for Slope-SVM: permutation cuts for the
//!   exponential epigraph (§3.1) paired with column generation using the
//!   O(|J|) pricing rule (eq. 34);
//! * [`report`] — shared per-workload full-problem objective/support
//!   reports, consumed by the drivers here and by the serve handlers;
//! * [`controller`] — the dynamic-λ controller: bisect λ toward a
//!   target slack/‖β‖₁ ratio for (weighted) RankSVM instead of taking
//!   λ as an input.
//!
//! [`GenParams`] and [`GenStats`] live in [`crate::engine`] and are
//! re-exported here for compatibility.

pub mod controller;
pub mod group;
pub mod l1svm;
pub mod path;
pub mod path_exact;
pub mod report;
pub mod slope;

pub use crate::engine::{GenParams, GenStats};

/// A fitted SVM-type model from any coordinator.
#[derive(Clone, Debug)]
pub struct SvmSolution {
    /// Dense coefficient vector (length p; zeros off the working set).
    pub beta: Vec<f64>,
    /// Intercept.
    pub beta0: f64,
    /// Objective value of the *restricted* LP (equals the full problem's
    /// objective at termination, up to ε pricing slack).
    pub objective: f64,
    /// Counters.
    pub stats: GenStats,
    /// Final working set of columns J (sorted).
    pub cols: Vec<usize>,
    /// Final working set of constraints I (sorted; empty ⇒ all of [n]).
    pub rows: Vec<usize>,
}

impl SvmSolution {
    /// Number of nonzero coefficients.
    pub fn support_size(&self) -> usize {
        self.beta.iter().filter(|v| v.abs() > 1e-9).count()
    }

    /// Classify a sample.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let s: f64 = self.beta.iter().zip(x).map(|(b, v)| b * v).sum();
        if s + self.beta0 >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }
}
