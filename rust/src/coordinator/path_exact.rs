//! **Exact parametric λ-path** with interleaved cut generation.
//!
//! Where [`crate::coordinator::path`] (Algorithm 2) solves the problem
//! at a *fixed grid* of λ values, this driver rides the cost-parametric
//! basis path of the **restricted** LP from λ_hi down to λ_lo and only
//! stops where something actually changes:
//!
//! 1. converge the restricted problem at the current λ with the usual
//!    solve → price → expand loop ([`crate::engine::GenEngine::run`]);
//! 2. ask the restricted model for the next basis-change breakpoint
//!    below λ (two BTRANs and one nonbasic scan — no pivots, no
//!    pricing);
//! 3. hop just past that crossing, re-cost the model in place
//!    ([`crate::engine::RestrictedProblem::reprice_at`] — the basis is
//!    kept, so the re-solve is a warm start a pivot or two from
//!    optimal), and go to 1.
//!
//! The full implicit column/constraint space is priced **only at
//! breakpoints** — O(#breakpoints) pricing sweeps instead of O(#grid).
//! Between consecutive breakpoints the emitted [`ExactSegment`]
//! interpolates the full-problem objective *exactly* (up to the 1e-9
//! nudge used to step past each crossing):
//!
//! * **L1-SVM** (pure column generation): the full objective f*(λ) is
//!   concave in λ and bounded above by the restricted objective r*(λ),
//!   which is affine on a segment with no basis change and equal to
//!   f* at both endpoints — a chord sandwich, so f* equals the chord.
//! * **RankSVM** (cost-parametric with row cuts): the primal vertex is
//!   constant on a segment, so the set of violated pair rows is
//!   constant; endpoint feasibility certifies the interior.
//! * **Dantzig selector** (RHS-parametric): the basis and duals are
//!   constant on a segment, so column pricing is constant and each
//!   row violation |correlation| − λ is convex in λ — clean endpoints
//!   certify the interior.
//!
//! Group-SVM and Slope-SVM have no such certificate — the group ∞-norm
//! and the epigraph permutation cuts are not cost-parametric in a form
//! the simplex ratio scan covers — so they deliberately keep the
//! warm-started grid drivers in [`crate::coordinator::path`]; the serve
//! layer returns a typed error pointing there. See
//! `docs/path-exact.md` for the full argument.

use std::sync::Arc;

use crate::backend::Backend;
use crate::coordinator::l1svm::{L1Problem, RestrictedL1};
use crate::coordinator::path::accumulate;
use crate::coordinator::report::{dantzig_report, l1_report, ranksvm_report};
use crate::coordinator::{GenParams, GenStats};
use crate::data::Dataset;
use crate::engine::{
    BackendPricer, GenEngine, Initializer, RestrictedProblem, Snapshot, WorkingSet,
};
use crate::obs::{Span, TraceSink};
use crate::workloads::dantzig::{DantzigProblem, RestrictedDantzig};
use crate::workloads::pairset::PairSet;
use crate::workloads::ranksvm::{pair_rows_cap, RankProblem, RestrictedRank};

/// Step taken past each crossing so the re-solve lands strictly on the
/// far side of the degenerate point. Contributes O(1e-9) to the
/// interpolation error — far below the 1e-6 exactness contract.
const NUDGE: f64 = 1e-9;

/// Hard cap on emitted breakpoints: a runaway guard for adversarial
/// inputs (the path of an n×p instance has finitely many vertices, but
/// degenerate ties can revisit). Hitting it sets [`ExactPath::truncated`].
const MAX_BREAKPOINTS: usize = 4096;

/// One examined λ on the exact path: a basis-change breakpoint of the
/// restricted LP (or one of the two interval endpoints).
#[derive(Clone, Debug)]
pub struct ExactBreakpoint {
    /// λ value (just below the actual crossing, see [`NUDGE`]).
    pub lambda: f64,
    /// Full-problem objective at this λ.
    pub objective: f64,
    /// Support size of β*(λ).
    pub support: usize,
    /// Size of the column working set J after this step.
    pub working_set: usize,
    /// Whether pricing at this breakpoint expanded the working set
    /// (columns or rows entered the restricted model).
    pub expanded: bool,
    /// Snapshot of the working sets — lets the serve `path_exact` op
    /// seed the warm cache at **every** breakpoint.
    pub ws: WorkingSet,
}

/// A λ-interval between two consecutive breakpoints on which the
/// full-problem objective is affine (see the module docs for why).
#[derive(Clone, Copy, Debug)]
pub struct ExactSegment {
    /// Upper λ endpoint (the earlier breakpoint; the path rides down).
    pub lambda_hi: f64,
    /// Lower λ endpoint.
    pub lambda_lo: f64,
    /// Full-problem objective at `lambda_hi`.
    pub obj_hi: f64,
    /// Full-problem objective at `lambda_lo`.
    pub obj_lo: f64,
}

impl ExactSegment {
    /// Interpolate the full-problem objective at `lambda ∈ [lo, hi]`.
    pub fn objective_at(&self, lambda: f64) -> f64 {
        let width = self.lambda_hi - self.lambda_lo;
        if width <= f64::EPSILON * self.lambda_hi.abs().max(1.0) {
            return self.obj_lo;
        }
        let t = (lambda - self.lambda_lo) / width;
        self.obj_lo + t * (self.obj_hi - self.obj_lo)
    }
}

/// Counters for one exact-path run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExactPathStats {
    /// Breakpoints examined (= points emitted).
    pub breakpoints: usize,
    /// Full pricing sweeps performed (engine solve/price rounds summed
    /// over all breakpoints) — the number the grid driver pays per grid
    /// point and this driver pays per breakpoint.
    pub pricing_rounds: usize,
    /// Breakpoints at which pricing actually grew the working set.
    pub expansions: usize,
    /// Simplex iterations summed over all re-solves.
    pub simplex_iters: usize,
    /// Cumulative engine counters (same shape the grid path reports).
    pub gen: GenStats,
}

/// The exact λ-path: breakpoints, interpolable segments, counters.
#[derive(Clone, Debug, Default)]
pub struct ExactPath {
    /// Examined points, λ decreasing; first is λ_hi, last is λ_lo
    /// unless the run was cut short.
    pub points: Vec<ExactBreakpoint>,
    /// One segment per consecutive pair of points.
    pub segments: Vec<ExactSegment>,
    /// Counters.
    pub stats: ExactPathStats,
    /// A deadline/stop callback cut the ride short; `points` covers
    /// only [last λ, λ_hi].
    pub timed_out: bool,
    /// The [`MAX_BREAKPOINTS`] guard fired before reaching λ_lo.
    pub truncated: bool,
}

impl ExactPath {
    /// Full-problem objective at any λ covered by the path, by exact
    /// linear interpolation on the containing segment. `None` outside
    /// [last λ, first λ].
    pub fn objective_at(&self, lambda: f64) -> Option<f64> {
        let first = self.points.first()?;
        let slack = 1e-12 * first.lambda.abs().max(1.0);
        for seg in &self.segments {
            if lambda >= seg.lambda_lo - slack && lambda <= seg.lambda_hi + slack {
                return Some(seg.objective_at(lambda));
            }
        }
        if (lambda - first.lambda).abs() <= slack {
            return Some(first.objective);
        }
        None
    }
}

/// Shared bookkeeping: fold an engine run into the counters, append the
/// point (and the segment from the previous one), emit the trace event.
#[allow(clippy::too_many_arguments)]
fn push_point(
    path: &mut ExactPath,
    sink: &Option<Arc<dyn TraceSink>>,
    step: GenStats,
    lambda: f64,
    objective: f64,
    support: usize,
    working_set: usize,
    ws: WorkingSet,
) {
    accumulate(&mut path.stats.gen, step);
    path.stats.pricing_rounds += step.rounds;
    path.stats.simplex_iters += step.simplex_iters;
    let expanded = step.cols_added + step.rows_added > 0;
    path.stats.expansions += expanded as usize;
    path.stats.breakpoints += 1;
    if let Some(prev) = path.points.last() {
        path.segments.push(ExactSegment {
            lambda_hi: prev.lambda,
            lambda_lo: lambda,
            obj_hi: prev.objective,
            obj_lo: objective,
        });
    }
    if let Some(s) = sink {
        s.breakpoint(lambda, objective, expanded);
    }
    path.points.push(ExactBreakpoint { lambda, objective, support, working_set, expanded, ws });
    if step.timed_out {
        path.timed_out = true;
    }
}

/// Decide where to hop next: just past the restricted model's next
/// basis-change crossing, or straight to λ_lo when the basis holds all
/// the way down.
fn next_lambda(crossing: Option<f64>, lambda: f64, lambda_lo: f64) -> f64 {
    let next = crossing.map(|c| (c - NUDGE).max(lambda_lo)).unwrap_or(lambda_lo);
    // The scan only reports crossings strictly below λ; keep the ride
    // downward even if a degenerate tie slips through.
    if next >= lambda {
        lambda_lo
    } else {
        next
    }
}

/// Exact λ-path for the **L1-SVM** (column generation on the same
/// restricted model the grid driver uses; every margin row stays in).
pub fn l1svm_path_exact(
    ds: &Dataset,
    backend: &dyn Backend,
    lambda_hi: f64,
    lambda_lo: f64,
    params: &GenParams,
) -> ExactPath {
    l1svm_path_exact_with_stop(ds, backend, lambda_hi, lambda_lo, params, None)
}

/// [`l1svm_path_exact`] with a cooperative stop callback (the serve
/// layer's deadline); when a step is cut short the path stops there and
/// [`ExactPath::timed_out`] is set.
pub fn l1svm_path_exact_with_stop(
    ds: &Dataset,
    backend: &dyn Backend,
    lambda_hi: f64,
    lambda_lo: f64,
    params: &GenParams,
    should_stop: Option<&dyn Fn() -> bool>,
) -> ExactPath {
    assert!(lambda_hi >= lambda_lo, "exact path rides downward: need lambda_hi >= lambda_lo");
    assert!(lambda_lo >= 0.0, "negative regularization");
    let all_i: Vec<usize> = (0..ds.n()).collect();
    let seed_span = Span::start();
    let init = Initializer::for_path(params).seed_l1_cols(ds, backend, lambda_hi).ws.cols;
    let seed_ns = seed_span.elapsed_ns();
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rl1 = RestrictedL1::new(ds, lambda_hi, &all_i, &init);
    rl1.set_threads(params.threads);
    let mut prob = L1Problem::new(rl1, ds, &pricer, false, true);
    let mut engine = GenEngine::new(params);
    if let Some(f) = should_stop {
        engine = engine.with_should_stop(f);
    }
    let mut path = ExactPath {
        stats: ExactPathStats {
            gen: GenStats { cols_added: init.len(), ..Default::default() },
            ..Default::default()
        },
        ..Default::default()
    };

    let mut lambda = lambda_hi;
    let mut step = engine.run(&mut prob);
    step.seed_ns = seed_ns;
    let (support, b0) = prob.inner().beta_support();
    let report = l1_report(ds, &support, b0, lambda);
    let mut ws = prob.export_working_set();
    ws.rows.clear(); // like Algorithm 2: every margin row stays in the model
    let j = prob.inner().j_set().len();
    push_point(&mut path, &params.sink, step, lambda, report.objective, report.support, j, ws);

    while lambda > lambda_lo && !path.timed_out {
        if path.points.len() >= MAX_BREAKPOINTS {
            path.truncated = true;
            break;
        }
        let crossing = prob.inner_mut().next_breakpoint(lambda, lambda_lo);
        let next = next_lambda(crossing, lambda, lambda_lo);
        prob.reprice_at(next);
        let step = engine.run(&mut prob);
        let (support, b0) = prob.inner().beta_support();
        let report = l1_report(ds, &support, b0, next);
        let mut ws = prob.export_working_set();
        ws.rows.clear();
        let j = prob.inner().j_set().len();
        push_point(&mut path, &params.sink, step, next, report.objective, report.support, j, ws);
        lambda = next;
    }
    path
}

/// Exact λ-path for **RankSVM** (columns and pair-row cuts both priced
/// at every breakpoint).
pub fn ranksvm_path_exact(
    ds: &Dataset,
    backend: &dyn Backend,
    pairs: &PairSet,
    lambda_hi: f64,
    lambda_lo: f64,
    params: &GenParams,
) -> ExactPath {
    ranksvm_path_exact_with_stop(ds, backend, pairs, lambda_hi, lambda_lo, params, None)
}

/// [`ranksvm_path_exact`] with a cooperative stop callback; same
/// early-exit contract as [`l1svm_path_exact_with_stop`].
pub fn ranksvm_path_exact_with_stop(
    ds: &Dataset,
    backend: &dyn Backend,
    pairs: &PairSet,
    lambda_hi: f64,
    lambda_lo: f64,
    params: &GenParams,
    should_stop: Option<&dyn Fn() -> bool>,
) -> ExactPath {
    assert!(lambda_hi >= lambda_lo, "exact path rides downward: need lambda_hi >= lambda_lo");
    assert!(lambda_lo >= 0.0, "negative regularization");
    let seed_span = Span::start();
    let seed = Initializer::for_path(params).seed_ranksvm(ds, backend, pairs, lambda_hi).ws;
    let seed_ns = seed_span.elapsed_ns();
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rr = RestrictedRank::new(ds, pairs, lambda_hi, &seed.rows, &seed.cols);
    rr.set_threads(params.threads);
    rr.set_pair_cap(pair_rows_cap(params));
    let mut prob = RankProblem::new(rr, ds, &pricer);
    let mut engine = GenEngine::new(params);
    if let Some(f) = should_stop {
        engine = engine.with_should_stop(f);
    }
    let mut path = ExactPath {
        stats: ExactPathStats {
            gen: GenStats {
                cols_added: seed.cols.len(),
                rows_added: seed.rows.len(),
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };

    let mut lambda = lambda_hi;
    let mut step = engine.run(&mut prob);
    step.seed_ns = seed_ns;
    let report = ranksvm_report(ds, pairs, &prob.inner().beta_support(), lambda);
    let ws = prob.export_working_set();
    let j = prob.inner().j_set().len();
    push_point(&mut path, &params.sink, step, lambda, report.objective, report.support, j, ws);

    while lambda > lambda_lo && !path.timed_out {
        if path.points.len() >= MAX_BREAKPOINTS {
            path.truncated = true;
            break;
        }
        let crossing = prob.inner_mut().next_breakpoint(lambda, lambda_lo);
        let next = next_lambda(crossing, lambda, lambda_lo);
        prob.reprice_at(next);
        let step = engine.run(&mut prob);
        let report = ranksvm_report(ds, pairs, &prob.inner().beta_support(), next);
        let ws = prob.export_working_set();
        let j = prob.inner().j_set().len();
        push_point(&mut path, &params.sink, step, next, report.objective, report.support, j, ws);
        lambda = next;
    }
    path
}

/// Exact λ-path for the **Dantzig selector**. λ enters through the
/// correlation-row *ranges* rather than the costs, so the breakpoint
/// scan is the RHS-parametric ratio test and each hop is a dual-simplex
/// warm start; the objective reported is the restricted `Σ(β⁺+β⁻)`,
/// exactly as [`crate::coordinator::path::dantzig_path`] reports it.
pub fn dantzig_path_exact(
    ds: &Dataset,
    backend: &dyn Backend,
    lambda_hi: f64,
    lambda_lo: f64,
    params: &GenParams,
) -> ExactPath {
    dantzig_path_exact_with_stop(ds, backend, lambda_hi, lambda_lo, params, None)
}

/// [`dantzig_path_exact`] with a cooperative stop callback; same
/// early-exit contract as [`l1svm_path_exact_with_stop`].
pub fn dantzig_path_exact_with_stop(
    ds: &Dataset,
    backend: &dyn Backend,
    lambda_hi: f64,
    lambda_lo: f64,
    params: &GenParams,
    should_stop: Option<&dyn Fn() -> bool>,
) -> ExactPath {
    assert!(lambda_hi >= lambda_lo, "exact path rides downward: need lambda_hi >= lambda_lo");
    assert!(lambda_lo >= 0.0, "negative regularization");
    let seed_span = Span::start();
    let seed = Initializer::for_path(params).seed_dantzig(ds, backend, lambda_hi).ws.rows;
    let seed_ns = seed_span.elapsed_ns();
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rd = RestrictedDantzig::new(ds, lambda_hi, &seed);
    rd.set_threads(params.threads);
    let mut prob = DantzigProblem::new(rd, ds, &pricer);
    let mut engine = GenEngine::new(params);
    if let Some(f) = should_stop {
        engine = engine.with_should_stop(f);
    }
    let mut path = ExactPath {
        stats: ExactPathStats {
            gen: GenStats {
                cols_added: seed.len(),
                rows_added: seed.len(),
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };

    let mut lambda = lambda_hi;
    let mut step = engine.run(&mut prob);
    step.seed_ns = seed_ns;
    let report = dantzig_report(ds.p(), &prob.inner().beta_support());
    let obj = prob.inner().objective();
    let ws = prob.export_working_set();
    let j = prob.inner().j_set().len();
    push_point(&mut path, &params.sink, step, lambda, obj, report.support, j, ws);

    while lambda > lambda_lo && !path.timed_out {
        if path.points.len() >= MAX_BREAKPOINTS {
            path.truncated = true;
            break;
        }
        let crossing = prob.inner_mut().next_breakpoint(lambda, lambda_lo);
        let next = next_lambda(crossing, lambda, lambda_lo);
        prob.reprice_at(next);
        let step = engine.run(&mut prob);
        let report = dantzig_report(ds.p(), &prob.inner().beta_support());
        let obj = prob.inner().objective();
        let ws = prob.export_working_set();
        let j = prob.inner().j_set().len();
        push_point(&mut path, &params.sink, step, next, obj, report.support, j, ws);
        lambda = next;
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::coordinator::l1svm::column_generation;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::rng::Xoshiro256;

    fn ds() -> Dataset {
        let spec = SyntheticSpec { n: 40, p: 80, k0: 5, rho: 0.1, standardize: true };
        generate_l1(&spec, &mut Xoshiro256::seed_from_u64(111))
    }

    #[test]
    fn segment_interpolation_is_linear() {
        let seg = ExactSegment { lambda_hi: 2.0, lambda_lo: 1.0, obj_hi: 10.0, obj_lo: 4.0 };
        assert!((seg.objective_at(2.0) - 10.0).abs() < 1e-12);
        assert!((seg.objective_at(1.0) - 4.0).abs() < 1e-12);
        assert!((seg.objective_at(1.5) - 7.0).abs() < 1e-12);
        // degenerate (zero-width) segments answer with the low endpoint
        let flat = ExactSegment { lambda_hi: 1.0, lambda_lo: 1.0, obj_hi: 3.0, obj_lo: 3.0 };
        assert_eq!(flat.objective_at(1.0), 3.0);
    }

    #[test]
    fn exact_path_rides_down_and_matches_direct_solves() {
        let d = ds();
        let backend = NativeBackend::new(&d.x);
        let lmax = d.lambda_max_l1();
        let llo = 0.2 * lmax;
        let params = GenParams { eps: 1e-8, seed_budget: 5, ..Default::default() };
        let path = l1svm_path_exact(&d, &backend, lmax, llo, &params);
        assert!(!path.timed_out && !path.truncated);
        assert!(path.points.len() >= 2, "a fifth of λ_max must cross at least one breakpoint");
        assert_eq!(path.segments.len(), path.points.len() - 1);
        // endpoints: λ_max carries the zero solution, λ_lo reaches it
        assert_eq!(path.points[0].support, 0);
        assert!((path.points[0].objective - d.n() as f64).abs() < 1e-6);
        assert!((path.points.last().unwrap().lambda - llo).abs() < 1e-9);
        // λ decreasing, objective non-increasing, segments contiguous
        for (k, w) in path.points.windows(2).enumerate() {
            assert!(w[1].lambda < w[0].lambda);
            assert!(w[1].objective <= w[0].objective + 1e-7);
            assert_eq!(path.segments[k].lambda_hi, w[0].lambda);
            assert_eq!(path.segments[k].lambda_lo, w[1].lambda);
        }
        // interpolated objective at an interior λ matches a fresh solve
        let seg = path
            .segments
            .iter()
            .max_by(|a, b| {
                let wa = a.lambda_hi - a.lambda_lo;
                let wb = b.lambda_hi - b.lambda_lo;
                wa.partial_cmp(&wb).unwrap()
            })
            .unwrap();
        let mid = 0.5 * (seg.lambda_hi + seg.lambda_lo);
        let interp = path.objective_at(mid).expect("mid lies on the path");
        let direct = column_generation(&d, &backend, mid, &[0, 1], &params);
        let rel = (interp - direct.objective).abs() / direct.objective.max(1e-9);
        assert!(rel < 1e-6, "interp {interp} direct {} rel {rel}", direct.objective);
        // outside the covered interval there is no answer
        assert!(path.objective_at(lmax * 1.5).is_none());
        assert!(path.objective_at(llo * 0.5).is_none());
    }

    #[test]
    fn stop_callback_cuts_the_ride_short() {
        let d = ds();
        let backend = NativeBackend::new(&d.x);
        let lmax = d.lambda_max_l1();
        let params = GenParams { seed_budget: 5, ..Default::default() };
        let stop = || true; // deadline already expired at entry
        let path =
            l1svm_path_exact_with_stop(&d, &backend, lmax, 0.1 * lmax, &params, Some(&stop));
        assert!(path.timed_out);
        assert_eq!(path.points.len(), 1, "expired deadline stops at the first point");
    }
}
