//! Shared per-workload solution reports.
//!
//! Every driver that hands a solution to a human or a protocol — the
//! coordinators' `finish` steps, the λ-path drivers, the serve handlers
//! — needs the same three things off a restricted model's support: the
//! dense coefficient vector, the **full-problem** objective (loss over
//! ALL samples/pairs, not just the working set), and the support size.
//! This module computes them once per workload so the serve layer and
//! `coordinator::path` stop duplicating the arithmetic.

use crate::data::Dataset;
use crate::fom::objective::{hinge_loss_support, slope_norm};
use crate::workloads::pairset::{PairCosts, PairSet};
use crate::workloads::ranksvm::pairwise_hinge_support_weighted;

/// A solution scored against the full problem.
#[derive(Clone, Debug)]
pub struct Report {
    /// Full-problem objective.
    pub objective: f64,
    /// Number of nonzero coefficients (|β_j| > 1e-9).
    pub support: usize,
    /// Dense coefficient vector (length p; zeros off the support).
    pub beta: Vec<f64>,
    /// Intercept (0 for workloads without one).
    pub beta0: f64,
}

/// Split `(j, β_j)` support pairs into parallel index/value vectors.
pub fn split_support(support: &[(usize, f64)]) -> (Vec<usize>, Vec<f64>) {
    (
        support.iter().map(|&(j, _)| j).collect(),
        support.iter().map(|&(_, v)| v).collect(),
    )
}

fn densify(p: usize, support: &[(usize, f64)]) -> Vec<f64> {
    let mut beta = vec![0.0; p];
    for &(j, v) in support {
        beta[j] = v;
    }
    beta
}

fn nnz(vals: &[f64]) -> usize {
    vals.iter().filter(|v| v.abs() > 1e-9).count()
}

/// L1-SVM: hinge over all samples plus `λ‖β‖₁`.
pub fn l1_report(ds: &Dataset, support: &[(usize, f64)], beta0: f64, lambda: f64) -> Report {
    let (cols, vals) = split_support(support);
    let hinge = hinge_loss_support(&ds.x, &ds.y, &cols, &vals, beta0);
    let l1: f64 = vals.iter().map(|v| v.abs()).sum();
    Report {
        objective: hinge + lambda * l1,
        support: nnz(&vals),
        beta: densify(ds.p(), support),
        beta0,
    }
}

/// Group-SVM: hinge over all samples plus `λ Σ_g ‖β_g‖∞`.
pub fn group_report(
    ds: &Dataset,
    groups: &[Vec<usize>],
    support: &[(usize, f64)],
    beta0: f64,
    lambda: f64,
) -> Report {
    let (cols, vals) = split_support(support);
    let hinge = hinge_loss_support(&ds.x, &ds.y, &cols, &vals, beta0);
    let beta = densify(ds.p(), support);
    let pen: f64 = groups
        .iter()
        .map(|g| g.iter().fold(0.0f64, |m, &j| m.max(beta[j].abs())))
        .sum();
    Report { objective: hinge + lambda * pen, support: nnz(&vals), beta, beta0 }
}

/// Slope-SVM: hinge over all samples plus the sorted-weight Slope norm.
pub fn slope_report(
    ds: &Dataset,
    weights: &[f64],
    support: &[(usize, f64)],
    beta0: f64,
) -> Report {
    let (cols, vals) = split_support(support);
    let hinge = hinge_loss_support(&ds.x, &ds.y, &cols, &vals, beta0);
    let beta = densify(ds.p(), support);
    Report {
        objective: hinge + slope_norm(&beta, weights),
        support: nnz(&vals),
        beta,
        beta0,
    }
}

/// RankSVM: pairwise hinge over ALL candidate pairs plus `λ‖β‖₁` (no
/// intercept). O(n log n) with an implicit [`PairSet`], never O(|P|)
/// beyond the enumeration threshold.
pub fn ranksvm_report(
    ds: &Dataset,
    pairs: &PairSet,
    support: &[(usize, f64)],
    lambda: f64,
) -> Report {
    ranksvm_report_weighted(ds, pairs, &PairCosts::UNIFORM, support, lambda)
}

/// Weighted RankSVM: `Σ_t w_t·max(0, g_t − (m_i − m_k))` over ALL
/// candidate pairs plus `λ‖β‖₁`. Uniform costs reproduce
/// [`ranksvm_report`] bitwise.
pub fn ranksvm_report_weighted(
    ds: &Dataset,
    pairs: &PairSet,
    costs: &PairCosts,
    support: &[(usize, f64)],
    lambda: f64,
) -> Report {
    let (cols, vals) = split_support(support);
    let hinge = pairwise_hinge_support_weighted(ds, pairs, costs, &cols, &vals);
    let l1: f64 = vals.iter().map(|v| v.abs()).sum();
    Report {
        objective: hinge + lambda * l1,
        support: nnz(&vals),
        beta: densify(ds.p(), support),
        beta0: 0.0,
    }
}

/// Dantzig selector: the objective IS `‖β‖₁` (feasibility is the
/// restricted model's invariant, not a loss).
pub fn dantzig_report(p: usize, support: &[(usize, f64)]) -> Report {
    let (_, vals) = split_support(support);
    Report {
        objective: vals.iter().map(|v| v.abs()).sum(),
        support: nnz(&vals),
        beta: densify(p, support),
        beta0: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::rng::Xoshiro256;

    #[test]
    fn l1_report_matches_manual_objective() {
        let spec = SyntheticSpec { n: 20, p: 10, k0: 3, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(301));
        let support = vec![(2usize, 0.7), (5, -0.3)];
        let r = l1_report(&ds, &support, 0.1, 0.5);
        assert_eq!(r.support, 2);
        assert_eq!(r.beta[2], 0.7);
        assert_eq!(r.beta[5], -0.3);
        let mut manual = 0.5 * (0.7 + 0.3);
        for i in 0..ds.n() {
            let m = ds.x.get(i, 2) * 0.7 + ds.x.get(i, 5) * (-0.3) + 0.1;
            manual += (1.0 - ds.y[i] * m).max(0.0);
        }
        assert!((r.objective - manual).abs() < 1e-10, "{} vs {manual}", r.objective);
    }

    #[test]
    fn dantzig_report_is_the_l1_norm() {
        let r = dantzig_report(6, &[(0, 1.5), (4, -2.0)]);
        assert_eq!(r.objective, 3.5);
        assert_eq!(r.support, 2);
        assert_eq!(r.beta0, 0.0);
    }
}
