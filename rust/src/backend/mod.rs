//! Compute backends for the O(np) matvec hot paths.
//!
//! Both the first-order initialization (smoothed-hinge gradients) and the
//! cutting-plane pricing step (reduced costs `λ − |Xᵀ(y∘π)|`) are a pair
//! of matvecs against the design matrix. Everything above them is written
//! against the [`Backend`] trait so the same coordinator code runs on:
//!
//! * [`NativeBackend`] — plain Rust kernels (dense or sparse), always
//!   available, used for correctness cross-checks and sparse data;
//! * `runtime::PjrtBackend` — the AOT-compiled JAX/Pallas tile kernels
//!   executed through the PJRT CPU client (see `rust/src/runtime`).

use crate::data::Design;

/// Matrix–vector products against a fixed design matrix.
///
/// `Sync` is a supertrait so a `&dyn Backend` can be shared across the
/// scoped worker threads of the parallel pricer
/// (`engine::BackendPricer`); every backend is immutable after
/// construction, so this costs nothing.
pub trait Backend: Sync {
    /// Number of samples (rows of X).
    fn rows(&self) -> usize;
    /// Number of features (columns of X).
    fn cols(&self) -> usize;
    /// `out = X β` (length n).
    fn xb(&self, beta: &[f64], out: &mut [f64]);
    /// `out = Xᵀ v` (length p).
    fn xtv(&self, v: &[f64], out: &mut [f64]);
    /// Column-range slice of `Xᵀ v`: `out[k] = (Xᵀv)[j0 + k]`.
    ///
    /// The parallel pricer partitions the feature axis into ranges, one
    /// per worker. The default implementation computes the full product
    /// and copies the slice — correct for any backend, but O(np) per
    /// call; backends that override it with a real range kernel must also
    /// return `true` from [`Backend::supports_range_pricing`] so the
    /// pricer knows chunking is worthwhile.
    fn xtv_range(&self, v: &[f64], j0: usize, out: &mut [f64]) {
        let mut full = vec![0.0; self.cols()];
        self.xtv(v, &mut full);
        out.copy_from_slice(&full[j0..j0 + out.len()]);
    }
    /// Whether [`Backend::xtv_range`] is a genuine column-range kernel
    /// (cost proportional to the range). When `false`, the parallel
    /// pricer degrades to a single serial `xtv` instead of multiplying
    /// the full matvec across workers.
    fn supports_range_pricing(&self) -> bool {
        false
    }
    /// Human-readable backend name (for logs/benches).
    fn name(&self) -> &'static str {
        "unknown"
    }
}

/// Pure-Rust backend delegating to the [`Design`] kernels.
pub struct NativeBackend<'a> {
    design: &'a Design,
}

impl<'a> NativeBackend<'a> {
    /// Wrap a design matrix.
    pub fn new(design: &'a Design) -> Self {
        Self { design }
    }
}

impl Backend for NativeBackend<'_> {
    fn rows(&self) -> usize {
        self.design.rows()
    }
    fn cols(&self) -> usize {
        self.design.cols()
    }
    fn xb(&self, beta: &[f64], out: &mut [f64]) {
        self.design.matvec(beta, out);
    }
    fn xtv(&self, v: &[f64], out: &mut [f64]) {
        self.design.tmatvec(v, out);
    }
    fn xtv_range(&self, v: &[f64], j0: usize, out: &mut [f64]) {
        self.design.tmatvec_range(v, j0, out);
    }
    fn supports_range_pricing(&self) -> bool {
        true
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Largest singular value (squared) of the augmented matrix `X̃ = [X, 1]`
/// via power iteration — the Lipschitz constant of the smoothed-hinge
/// gradient is `σ_max(X̃ᵀX̃)/(4τ)` (§4.1 of the paper).
pub fn sigma_max_sq(backend: &dyn Backend, iters: usize) -> f64 {
    let n = backend.rows();
    let p = backend.cols();
    // power iteration on (p+1)-vector v = (β, β₀)
    let mut v = vec![1.0 / ((p + 1) as f64).sqrt(); p + 1];
    let mut xv = vec![0.0; n];
    let mut xtxv = vec![0.0; p];
    let mut lam = 0.0;
    for _ in 0..iters.max(2) {
        // w = X̃ v = X β + β₀·1
        backend.xb(&v[..p], &mut xv);
        let b0 = v[p];
        for w in xv.iter_mut() {
            *w += b0;
        }
        // v' = X̃ᵀ w = (Xᵀ w, Σ w)
        backend.xtv(&xv, &mut xtxv);
        let last: f64 = xv.iter().sum();
        let mut norm = last * last;
        for t in &xtxv {
            norm += t * t;
        }
        let norm = norm.sqrt().max(1e-30);
        lam = norm;
        for (vi, t) in v[..p].iter_mut().zip(&xtxv) {
            *vi = t / norm;
        }
        v[p] = last / norm;
    }
    lam
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Design;
    use crate::linalg::Matrix;

    #[test]
    fn native_backend_delegates() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 0.0, 2.0, -1.0, 1.0, 0.0]);
        let d = Design::dense(m);
        let b = NativeBackend::new(&d);
        assert_eq!(b.rows(), 2);
        assert_eq!(b.cols(), 3);
        let mut out = vec![0.0; 2];
        b.xb(&[1.0, 1.0, 1.0], &mut out);
        assert_eq!(out, vec![3.0, 0.0]);
        let mut t = vec![0.0; 3];
        b.xtv(&[1.0, 2.0], &mut t);
        assert_eq!(t, vec![-1.0, 2.0, 2.0]);
    }

    #[test]
    fn power_iteration_estimates_sigma_max() {
        // X̃ = [X, 1] with X = diag(3, 1): eigenvalues of X̃ᵀX̃ computable.
        let m = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 1.0]);
        let d = Design::dense(m);
        let b = NativeBackend::new(&d);
        let est = sigma_max_sq(&b, 200);
        // X̃ = [[3,0,1],[0,1,1]]; X̃ᵀX̃ has σ_max ≈ 10.266 (checked
        // against the characteristic polynomial numerically).
        let a = [[9.0, 0.0, 3.0], [0.0, 1.0, 1.0], [3.0, 1.0, 2.0]];
        // brute-force power iteration on the 3x3 for reference
        let mut v = [1.0f64, 1.0, 1.0];
        let mut lam = 0.0;
        for _ in 0..500 {
            let w = [
                a[0][0] * v[0] + a[0][1] * v[1] + a[0][2] * v[2],
                a[1][0] * v[0] + a[1][1] * v[1] + a[1][2] * v[2],
                a[2][0] * v[0] + a[2][1] * v[1] + a[2][2] * v[2],
            ];
            lam = (w[0] * w[0] + w[1] * w[1] + w[2] * w[2]).sqrt();
            v = [w[0] / lam, w[1] / lam, w[2] / lam];
        }
        assert!((est - lam).abs() < 1e-6 * lam, "est {est} ref {lam}");
    }
}
