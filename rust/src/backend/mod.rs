//! Compute backends for the O(np) matvec hot paths.
//!
//! Both the first-order initialization (smoothed-hinge gradients) and the
//! cutting-plane pricing step (reduced costs `λ − |Xᵀ(y∘π)|`) are a pair
//! of matvecs against the design matrix. Everything above them is written
//! against the [`Backend`] trait so the same coordinator code runs on:
//!
//! * [`NativeBackend`] — plain Rust kernels (dense or sparse), always
//!   available, used for correctness cross-checks and sparse data;
//! * `runtime::PjrtBackend` — the AOT-compiled JAX/Pallas tile kernels
//!   executed through the PJRT CPU client (see `rust/src/runtime`).

use crate::data::Design;

/// Matrix–vector products against a fixed design matrix.
///
/// `Sync` is a supertrait so a `&dyn Backend` can be shared across the
/// scoped worker threads of the parallel pricer
/// (`engine::BackendPricer`); every backend is immutable after
/// construction, so this costs nothing.
pub trait Backend: Sync {
    /// Number of samples (rows of X).
    fn rows(&self) -> usize;
    /// Number of features (columns of X).
    fn cols(&self) -> usize;
    /// `out = X β` (length n).
    fn xb(&self, beta: &[f64], out: &mut [f64]);
    /// `out = Xᵀ v` (length p).
    fn xtv(&self, v: &[f64], out: &mut [f64]);
    /// Column-range slice of `Xᵀ v`: `out[k] = (Xᵀv)[j0 + k]`.
    ///
    /// The parallel pricer partitions the feature axis into ranges, one
    /// per worker. The default implementation computes the full product
    /// and copies the slice — correct for any backend, but O(np) per
    /// call; backends that override it with a real range kernel must also
    /// return `true` from [`Backend::supports_range_pricing`] so the
    /// pricer knows chunking is worthwhile.
    fn xtv_range(&self, v: &[f64], j0: usize, out: &mut [f64]) {
        let mut full = vec![0.0; self.cols()];
        self.xtv(v, &mut full);
        out.copy_from_slice(&full[j0..j0 + out.len()]);
    }
    /// Whether [`Backend::xtv_range`] is a genuine column-range kernel
    /// (cost proportional to the range). When `false`, the parallel
    /// pricer degrades to a single serial `xtv` instead of multiplying
    /// the full matvec across workers.
    fn supports_range_pricing(&self) -> bool {
        false
    }
    /// Dot of column `j` with a dense vector: `(Xᵀv)[j]`.
    ///
    /// The default routes through [`Backend::xtv_range`] with a
    /// single-column range, so backends with a real range kernel get this
    /// at column cost for free.
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        let mut out = [0.0];
        self.xtv_range(v, j, &mut out);
        out[0]
    }
    /// `out += alpha · X[:, j]` (incremental margin maintenance in block
    /// coordinate descent).
    ///
    /// The default multiplies a basis vector through [`Backend::xb`] —
    /// correct for any backend but O(np); backends with column access
    /// should override it.
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        let mut e = vec![0.0; self.cols()];
        e[j] = alpha;
        let mut tmp = vec![0.0; self.rows()];
        self.xb(&e, &mut tmp);
        for (o, t) in out.iter_mut().zip(&tmp) {
            *o += t;
        }
    }
    /// Estimated work of a full `xtv` pass: stored nonzeros of the
    /// design. The default assumes a dense matrix (`rows × cols`);
    /// sparse-aware backends override so the spawn gate in [`par_xtv`] /
    /// [`par_col_dots`] reflects actual flops, not the dense envelope.
    fn work_total(&self) -> usize {
        self.rows().saturating_mul(self.cols())
    }
    /// Monotone cumulative work of columns `[0, j)`, the prefix the
    /// nnz-balanced column splits binary-search. Invariants:
    /// `work_prefix(0) == 0`, `work_prefix(cols()) == work_total()`,
    /// nondecreasing in `j`. Defaults to `j × rows` (every dense column
    /// costs the same); sparse backends return the CSC `indptr`.
    fn work_prefix(&self, j: usize) -> usize {
        j.saturating_mul(self.rows())
    }
    /// Work (stored nonzeros) of column `j` alone.
    fn col_work(&self, _j: usize) -> usize {
        self.rows()
    }
    /// Human-readable backend name (for logs/benches).
    fn name(&self) -> &'static str {
        "unknown"
    }
}

/// Minimum estimated work (stored nonzeros touched, a flop proxy) before
/// the parallel kernels spawn workers: below this, thread spawn/join
/// overhead dominates the matvec itself (a FISTA iteration on a small
/// screened subproblem, or block CD's ~10-column groups). The estimate
/// comes from [`Backend::work_total`] / [`Backend::col_work`], so a
/// wide-but-nearly-empty sparse design no longer spawns threads for a
/// few thousand flops the way the old `rows × cols` proxy did.
const PAR_MIN_WORK: usize = 1 << 15;

/// Column split points `b_0 = 0 ≤ … ≤ b_t = p` with approximately equal
/// work per chunk, found by binary-searching the backend's monotone
/// [`Backend::work_prefix`]. On power-law text data equal *column*
/// counts leave one worker holding most of the nonzeros; equal *nnz*
/// keeps thread scaling flat. Splits only move chunk boundaries — each
/// column is still priced by exactly one worker with the serial
/// accumulation order, so outputs stay bit-identical at any `t`.
fn balanced_bounds(backend: &dyn Backend, p: usize, t: usize) -> Vec<usize> {
    let total = backend.work_prefix(p);
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0usize);
    for c in 1..t {
        let target = ((total as u128 * c as u128) / t as u128) as usize;
        let (mut lo, mut hi) = (*bounds.last().expect("nonempty"), p);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if backend.work_prefix(mid) < target {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        bounds.push(lo);
    }
    bounds.push(p);
    bounds
}

/// `out = Xᵀv` chunked over `threads` scoped workers — the shared kernel
/// behind cutting-plane pricing (`engine::BackendPricer`) **and** the
/// first-order gradients (`fom::fista`, `fom::block_cd`), so both hot
/// paths ride the same `xtv_range` chunking. Chunk boundaries are
/// nnz-balanced (see [`balanced_bounds`]), not equal column counts.
///
/// Determinism: every column's dot product accumulates over samples in
/// ascending row order regardless of the chunking, so the output — and
/// therefore anything seeded from it — is bit-identical for any thread
/// count. Falls back to a single serial `xtv` when `threads <= 1`, when
/// the backend has no genuine range kernel (see
/// [`Backend::supports_range_pricing`]), or when the problem is too
/// small for worker spawn/join to pay for itself ([`PAR_MIN_WORK`],
/// measured in stored nonzeros via [`Backend::work_total`]).
pub fn par_xtv(backend: &dyn Backend, threads: usize, v: &[f64], out: &mut [f64]) {
    let p = out.len();
    if p == 0 {
        return;
    }
    let t = threads.max(1).min(p);
    if t <= 1 || !backend.supports_range_pricing() || backend.work_total() < PAR_MIN_WORK {
        backend.xtv(v, out);
        return;
    }
    let bounds = balanced_bounds(backend, p, t);
    std::thread::scope(|scope| {
        let mut rest = out;
        for c in 0..t {
            let (j0, j1) = (bounds[c], bounds[c + 1]);
            let (slice, tail) = rest.split_at_mut(j1 - j0);
            rest = tail;
            if slice.is_empty() {
                continue;
            }
            scope.spawn(move || backend.xtv_range(v, j0, slice));
        }
    });
}

/// `(Xᵀv)[j]` for an arbitrary column subset, chunked over `threads`
/// scoped workers (block CD's per-group gradient, where the group's
/// columns need not be contiguous). Chunks are balanced by the subset's
/// per-column work ([`Backend::col_work`]) and the spawn gate uses the
/// subset's actual nonzero count. Each output slot is one independent
/// [`Backend::col_dot`], so the result is bit-identical for any thread
/// count — including across the serial small-work fast path.
pub fn par_col_dots(backend: &dyn Backend, threads: usize, cols: &[usize], v: &[f64]) -> Vec<f64> {
    let k = cols.len();
    let mut out = vec![0.0; k];
    let t = threads.max(1).min(k.max(1));
    if t <= 1 {
        for (o, &j) in out.iter_mut().zip(cols) {
            *o = backend.col_dot(j, v);
        }
        return out;
    }
    // prefix[i] = work of cols[..i]; prefix[k] both gates the spawn and
    // is the domain of the balanced splits
    let mut prefix = Vec::with_capacity(k + 1);
    let mut acc = 0usize;
    prefix.push(0usize);
    for &j in cols {
        acc = acc.saturating_add(backend.col_work(j));
        prefix.push(acc);
    }
    if acc < PAR_MIN_WORK {
        for (o, &j) in out.iter_mut().zip(cols) {
            *o = backend.col_dot(j, v);
        }
        return out;
    }
    let mut bounds = Vec::with_capacity(t + 1);
    bounds.push(0usize);
    for c in 1..t {
        let target = ((acc as u128 * c as u128) / t as u128) as usize;
        bounds.push(prefix.partition_point(|&w| w < target).min(k));
    }
    bounds.push(k);
    std::thread::scope(|scope| {
        let mut rest_c = cols;
        let mut rest_o = &mut out[..];
        for c in 0..t {
            let len = bounds[c + 1] - bounds[c];
            let (slice_c, tail_c) = rest_c.split_at(len);
            let (slice_o, tail_o) = rest_o.split_at_mut(len);
            rest_c = tail_c;
            rest_o = tail_o;
            if len == 0 {
                continue;
            }
            scope.spawn(move || {
                for (o, &j) in slice_o.iter_mut().zip(slice_c) {
                    *o = backend.col_dot(j, v);
                }
            });
        }
    });
    out
}

/// Pure-Rust backend delegating to the [`Design`] kernels.
pub struct NativeBackend<'a> {
    design: &'a Design,
}

impl<'a> NativeBackend<'a> {
    /// Wrap a design matrix.
    pub fn new(design: &'a Design) -> Self {
        Self { design }
    }
}

impl Backend for NativeBackend<'_> {
    fn rows(&self) -> usize {
        self.design.rows()
    }
    fn cols(&self) -> usize {
        self.design.cols()
    }
    fn xb(&self, beta: &[f64], out: &mut [f64]) {
        self.design.matvec(beta, out);
    }
    fn xtv(&self, v: &[f64], out: &mut [f64]) {
        self.design.tmatvec(v, out);
    }
    fn xtv_range(&self, v: &[f64], j0: usize, out: &mut [f64]) {
        self.design.tmatvec_range(v, j0, out);
    }
    fn supports_range_pricing(&self) -> bool {
        true
    }
    fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        self.design.col_dot(j, v)
    }
    fn col_axpy(&self, j: usize, alpha: f64, out: &mut [f64]) {
        self.design.col_axpy(j, alpha, out);
    }
    fn work_total(&self) -> usize {
        self.design.nnz()
    }
    fn work_prefix(&self, j: usize) -> usize {
        self.design.work_prefix(j)
    }
    fn col_work(&self, j: usize) -> usize {
        self.design.col_nnz(j)
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Largest singular value (squared) of the augmented matrix `X̃ = [X, 1]`
/// via power iteration — the Lipschitz constant of the smoothed-hinge
/// gradient is `σ_max(X̃ᵀX̃)/(4τ)` (§4.1 of the paper).
pub fn sigma_max_sq(backend: &dyn Backend, iters: usize) -> f64 {
    let n = backend.rows();
    let p = backend.cols();
    // power iteration on (p+1)-vector v = (β, β₀)
    let mut v = vec![1.0 / ((p + 1) as f64).sqrt(); p + 1];
    let mut xv = vec![0.0; n];
    let mut xtxv = vec![0.0; p];
    let mut lam = 0.0;
    for _ in 0..iters.max(2) {
        // w = X̃ v = X β + β₀·1
        backend.xb(&v[..p], &mut xv);
        let b0 = v[p];
        for w in xv.iter_mut() {
            *w += b0;
        }
        // v' = X̃ᵀ w = (Xᵀ w, Σ w)
        backend.xtv(&xv, &mut xtxv);
        let last: f64 = xv.iter().sum();
        let mut norm = last * last;
        for t in &xtxv {
            norm += t * t;
        }
        let norm = norm.sqrt().max(1e-30);
        lam = norm;
        for (vi, t) in v[..p].iter_mut().zip(&xtxv) {
            *vi = t / norm;
        }
        v[p] = last / norm;
    }
    lam
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Design;
    use crate::linalg::Matrix;

    #[test]
    fn native_backend_delegates() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 0.0, 2.0, -1.0, 1.0, 0.0]);
        let d = Design::dense(m);
        let b = NativeBackend::new(&d);
        assert_eq!(b.rows(), 2);
        assert_eq!(b.cols(), 3);
        let mut out = vec![0.0; 2];
        b.xb(&[1.0, 1.0, 1.0], &mut out);
        assert_eq!(out, vec![3.0, 0.0]);
        let mut t = vec![0.0; 3];
        b.xtv(&[1.0, 2.0], &mut t);
        assert_eq!(t, vec![-1.0, 2.0, 2.0]);
    }

    #[test]
    fn par_kernels_match_serial_bitwise() {
        let m = Matrix::from_vec(3, 5, vec![
            1.0, -2.0, 0.5, 0.0, 3.0, //
            0.0, 1.0, -1.5, 2.0, 0.0, //
            4.0, 0.0, 1.0, -0.5, 2.5,
        ]);
        let d = Design::dense(m);
        let b = NativeBackend::new(&d);
        let v = [0.3, -1.2, 0.7];
        let mut serial = vec![0.0; 5];
        b.xtv(&v, &mut serial);
        for t in [1usize, 2, 3, 8] {
            let mut par = vec![0.0; 5];
            par_xtv(&b, t, &v, &mut par);
            assert_eq!(serial, par, "par_xtv diverged at {t} threads");
        }
        let cols = [4usize, 0, 2];
        let want: Vec<f64> = cols.iter().map(|&j| serial[j]).collect();
        for t in [1usize, 2, 7] {
            assert_eq!(par_col_dots(&b, t, &cols, &v), want, "par_col_dots at {t} threads");
        }
        assert!(par_col_dots(&b, 4, &[], &v).is_empty());
        // default col ops (through the trait's fallbacks) agree with the
        // overridden native ones
        struct Wrap<'a>(&'a NativeBackend<'a>);
        impl Backend for Wrap<'_> {
            fn rows(&self) -> usize {
                self.0.rows()
            }
            fn cols(&self) -> usize {
                self.0.cols()
            }
            fn xb(&self, beta: &[f64], out: &mut [f64]) {
                self.0.xb(beta, out)
            }
            fn xtv(&self, v: &[f64], out: &mut [f64]) {
                self.0.xtv(v, out)
            }
        }
        let w = Wrap(&b);
        assert_eq!(w.col_dot(2, &v), b.col_dot(2, &v));
        let mut a1 = vec![1.0; 3];
        let mut a2 = vec![1.0; 3];
        w.col_axpy(1, 0.5, &mut a1);
        b.col_axpy(1, 0.5, &mut a2);
        assert_eq!(a1, a2);
    }

    #[test]
    fn par_col_dots_chunked_path_matches_serial() {
        // big enough to clear PAR_MIN_WORK so workers actually spawn
        let n = 256;
        let p = 200;
        let mut vals = Vec::with_capacity(n * p);
        let mut state = 0x9E37_79B9u64;
        for _ in 0..n * p {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            vals.push(((state >> 33) as f64 / (1u64 << 31) as f64) - 0.5);
        }
        let d = Design::dense(Matrix::from_vec(n, p, vals));
        let b = NativeBackend::new(&d);
        let v: Vec<f64> = (0..n).map(|i| ((i * 7 % 13) as f64) - 6.0).collect();
        let cols: Vec<usize> = (0..p).rev().collect(); // non-contiguous order
        assert!(cols.len() * n >= PAR_MIN_WORK);
        let serial = par_col_dots(&b, 1, &cols, &v);
        for t in [2usize, 4, 7] {
            assert_eq!(par_col_dots(&b, t, &cols, &v), serial, "{t} threads");
        }
    }

    #[test]
    fn balanced_bounds_follow_nnz_skew() {
        // one dominant column (900 of 970 nonzeros): nnz-balancing must
        // give it a chunk of its own instead of splitting columns evenly
        let mut coo = crate::sparse::Coo::new(900, 8);
        for i in 0..900 {
            coo.push(i, 0, 1.0 + i as f64);
        }
        for j in 1..8 {
            for k in 0..10 {
                coo.push(k * 37 + j, j, -(j as f64));
            }
        }
        let d = Design::sparse(coo.to_csr());
        let b = NativeBackend::new(&d);
        assert_eq!(b.work_total(), 970);
        assert_eq!(b.work_prefix(8), b.work_total());
        let bounds = balanced_bounds(&b, 8, 2);
        assert_eq!(bounds, vec![0, 1, 8], "heavy column isolated: {bounds:?}");
        // dense default prefix still splits columns evenly
        let m = Matrix::zeros(900, 8);
        let dd = Design::dense(m);
        let db = NativeBackend::new(&dd);
        assert_eq!(balanced_bounds(&db, 8, 2), vec![0, 4, 8]);
        // degenerate t=1 covers the whole range
        assert_eq!(balanced_bounds(&b, 8, 1), vec![0, 8]);
    }

    #[test]
    fn sparse_par_kernels_bitwise_at_any_thread_count() {
        use crate::data::synthetic::{generate_sparse_text, SparseTextSpec};
        use crate::rng::Xoshiro256;
        // power-law sparse design big enough to clear the nnz spawn gate
        let spec = SparseTextSpec { n: 2000, p: 2000, density: 0.02, k0: 20, zipf: 1.1 };
        let ds = generate_sparse_text(&spec, &mut Xoshiro256::seed_from_u64(9));
        assert!(ds.x.is_sparse());
        assert!(ds.x.nnz() >= PAR_MIN_WORK, "nnz {} below spawn gate", ds.x.nnz());
        let b = NativeBackend::new(&ds.x);
        let v: Vec<f64> = (0..ds.n()).map(|i| ((i * 13 % 31) as f64 - 15.0) / 7.0).collect();
        let mut serial = vec![0.0; ds.p()];
        b.xtv(&v, &mut serial);
        for t in [1usize, 2, 4, 8] {
            let mut par = vec![0.0; ds.p()];
            par_xtv(&b, t, &v, &mut par);
            assert_eq!(serial, par, "sparse par_xtv diverged at {t} threads");
        }
        // arbitrary (non-contiguous) subset through the balanced col-dot path
        let cols: Vec<usize> = (0..ds.p()).rev().step_by(3).collect();
        let one = par_col_dots(&b, 1, &cols, &v);
        for t in [2usize, 4, 7] {
            assert_eq!(par_col_dots(&b, t, &cols, &v), one, "{t} threads");
        }
    }

    #[test]
    fn tiny_nnz_wide_design_stays_under_spawn_gate() {
        // p·rows far exceeds PAR_MIN_WORK but only 64 entries are stored:
        // the nnz-based gate keeps this serial, and the result matches
        let mut coo = crate::sparse::Coo::new(1024, 4096);
        for k in 0..64 {
            coo.push((k * 17) % 1024, (k * 131) % 4096, 1.0 + k as f64);
        }
        let d = Design::sparse(coo.to_csr());
        let b = NativeBackend::new(&d);
        assert!(b.rows() * b.cols() >= PAR_MIN_WORK);
        assert!(b.work_total() < PAR_MIN_WORK);
        let v: Vec<f64> = (0..1024).map(|i| (i % 5) as f64 - 2.0).collect();
        let mut serial = vec![0.0; 4096];
        b.xtv(&v, &mut serial);
        let mut par = vec![0.0; 4096];
        par_xtv(&b, 4, &v, &mut par);
        assert_eq!(serial, par);
        let cols: Vec<usize> = (0..4096).step_by(7).collect();
        assert_eq!(par_col_dots(&b, 4, &cols, &v), par_col_dots(&b, 1, &cols, &v));
    }

    #[test]
    fn power_iteration_estimates_sigma_max() {
        // X̃ = [X, 1] with X = diag(3, 1): eigenvalues of X̃ᵀX̃ computable.
        let m = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 1.0]);
        let d = Design::dense(m);
        let b = NativeBackend::new(&d);
        let est = sigma_max_sq(&b, 200);
        // X̃ = [[3,0,1],[0,1,1]]; X̃ᵀX̃ has σ_max ≈ 10.266 (checked
        // against the characteristic polynomial numerically).
        let a = [[9.0, 0.0, 3.0], [0.0, 1.0, 1.0], [3.0, 1.0, 2.0]];
        // brute-force power iteration on the 3x3 for reference
        let mut v = [1.0f64, 1.0, 1.0];
        let mut lam = 0.0;
        for _ in 0..500 {
            let w = [
                a[0][0] * v[0] + a[0][1] * v[1] + a[0][2] * v[2],
                a[1][0] * v[0] + a[1][1] * v[1] + a[1][2] * v[2],
                a[2][0] * v[0] + a[2][1] * v[1] + a[2][2] * v[2],
            ];
            lam = (w[0] * w[0] + w[1] * w[1] + w[2] * w[2]).sqrt();
            v = [w[0] / lam, w[1] / lam, w[2] / lam];
        }
        assert!((est - lam).abs() < 1e-6 * lam, "est {est} ref {lam}");
    }
}
