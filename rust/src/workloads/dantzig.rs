//! Column-and-constraint generation for the **Dantzig selector** LP.
//!
//! The estimator (Candès & Tao 2007; CCG treatment in Mazumder, Wright &
//! Zheng, arXiv:1908.06515) is
//!
//! ```text
//! min ‖β‖₁   s.t.   ‖Xᵀ(y − Xβ)‖∞ ≤ λ
//! ```
//!
//! Splitting `β = β⁺ − β⁻` gives an LP with `2p` columns and `p` ranged
//! rows: writing `c = Xᵀy` and `A = XᵀX` (the Gram matrix, never formed
//! explicitly),
//!
//! ```text
//! min Σ_j (β⁺_j + β⁻_j)   s.t.   c_i − λ ≤ Σ_j A_ij (β⁺_j − β⁻_j) ≤ c_i + λ.
//! ```
//!
//! Both the row and the column index sets range over the *features*, so
//! the working sets I (rows) and J (columns) live in the same index
//! space. [`RestrictedDantzig`] maintains the invariant **I ⊆ J**: every
//! correlation row in the model has its coefficient pair present. That
//! guarantees the restricted LP is always feasible — pick `β_J` with
//! `X_J β_J = proj_{col(X_J)} y`; then the residual is orthogonal to every
//! `x_i` with `i ∈ I ⊆ J`, so all restricted rows hold with activity
//! exactly `c_i`.
//!
//! Both pricing channels are one [`Pricer`] pass (the chunked parallel
//! `Xᵀv` of [`crate::engine::BackendPricer`]):
//!
//! * **rows** — the full residual correlation `r = Xᵀ(y − Xβ)` prices
//!   every left-out constraint: `i ∉ I` is violated by `|r_i| − λ`;
//! * **columns** — with row duals μ, the reduced cost of `β⁺_j/β⁻_j` is
//!   `1 ∓ (XᵀXμ̄)_j` where `μ̄` scatters μ over the features in I, so
//!   `s = Xᵀw` with `w = Σ_{i∈I} μ_i x_i` prices every `j ∉ J` by
//!   `|s_j| − 1`.

use crate::backend::Backend;
use crate::coordinator::{GenParams, GenStats, SvmSolution};
use crate::data::Dataset;
use crate::engine::{BackendPricer, GenEngine, Pricer, RestrictedProblem, Snapshot, WorkingSet};
use crate::fom::screening::top_k_by_abs;
use crate::simplex::{LpModel, SimplexSolver, Status, VarId};

/// λ above which `β = 0` is optimal: `‖Xᵀy‖∞`.
pub fn lambda_max_dantzig(ds: &Dataset) -> f64 {
    let mut c = vec![0.0; ds.p()];
    ds.x.tmatvec(&ds.y, &mut c);
    c.iter().fold(0.0f64, |m, v| m.max(v.abs()))
}

/// Initial working set: the `k` features with the largest `|c_i| = |x_iᵀy|`
/// (the constraints that bind first as λ drops below λ_max).
pub fn initial_features(ds: &Dataset, k: usize) -> Vec<usize> {
    let mut c = vec![0.0; ds.p()];
    ds.x.tmatvec(&ds.y, &mut c);
    top_k_by_abs(&c, k.min(ds.p()))
}

/// The restricted Dantzig-selector LP over working sets `I ⊆ J` of
/// features.
pub struct RestrictedDantzig {
    solver: SimplexSolver,
    lambda: f64,
    /// `c = Xᵀy` over all p features (row right-hand sides).
    c: Vec<f64>,
    /// Feature whose correlation row sits at LP row position r.
    rows_i: Vec<usize>,
    /// feature i → LP row position (None when i ∉ I).
    row_pos: Vec<Option<usize>>,
    /// Feature handled by column-pair position t.
    cols_j: Vec<usize>,
    /// feature j → column-pair position.
    pos_j: Vec<Option<usize>>,
    /// β⁺ / β⁻ variable ids per column-pair position.
    bp: Vec<VarId>,
    bm: Vec<VarId>,
}

impl RestrictedDantzig {
    /// Build the restricted model seeded with the given features (used as
    /// both rows and columns, preserving `I ⊆ J`).
    pub fn new(ds: &Dataset, lambda: f64, seed: &[usize]) -> Self {
        let p = ds.p();
        let mut c = vec![0.0; p];
        ds.x.tmatvec(&ds.y, &mut c);
        let mut me = Self {
            solver: SimplexSolver::new(LpModel::new()),
            lambda,
            c,
            rows_i: Vec::new(),
            row_pos: vec![None; p],
            cols_j: Vec::new(),
            pos_j: vec![None; p],
            bp: Vec::new(),
            bm: Vec::new(),
        };
        me.add_constraint_rows(ds, seed);
        me
    }

    /// Current row working set I (feature indices, insertion order).
    pub fn i_set(&self) -> &[usize] {
        &self.rows_i
    }

    /// Current column working set J (feature indices, insertion order).
    pub fn j_set(&self) -> &[usize] {
        &self.cols_j
    }

    /// Bring features into the column set J: appends the `β⁺_j/β⁻_j` pair
    /// (cost 1 each) with coefficients `±A_ij = ±x_iᵀx_j` on the existing
    /// correlation rows.
    pub fn add_coef_cols(&mut self, ds: &Dataset, features: &[usize]) {
        for &j in features {
            if self.pos_j[j].is_some() {
                continue;
            }
            // densify column j once, then one Gram dot per existing row
            let mut xj = vec![0.0; ds.n()];
            for (i, v) in ds.x.col_entries(j) {
                xj[i] = v;
            }
            let mut pos_coefs = Vec::with_capacity(self.rows_i.len());
            let mut neg_coefs = Vec::with_capacity(self.rows_i.len());
            for (r, &i) in self.rows_i.iter().enumerate() {
                let a = ds.x.col_dot(i, &xj);
                if a != 0.0 {
                    pos_coefs.push((r, a));
                    neg_coefs.push((r, -a));
                }
            }
            let bp = self.solver.add_col(1.0, 0.0, f64::INFINITY, &pos_coefs);
            let bm = self.solver.add_col(1.0, 0.0, f64::INFINITY, &neg_coefs);
            self.pos_j[j] = Some(self.cols_j.len());
            self.cols_j.push(j);
            self.bp.push(bp);
            self.bm.push(bm);
        }
    }

    /// Bring features into the row set I: appends the ranged row
    /// `c_i − λ ≤ Σ_{j∈J} A_ij (β⁺_j − β⁻_j) ≤ c_i + λ`. Each new row's
    /// own coefficient pair is added first, preserving `I ⊆ J` (the
    /// feasibility invariant — see the module docs).
    pub fn add_constraint_rows(&mut self, ds: &Dataset, features: &[usize]) {
        for &i in features {
            if self.row_pos[i].is_some() {
                continue;
            }
            self.add_coef_cols(ds, &[i]);
            let mut xi = vec![0.0; ds.n()];
            for (r, v) in ds.x.col_entries(i) {
                xi[r] = v;
            }
            let mut coefs: Vec<(VarId, f64)> = Vec::with_capacity(2 * self.cols_j.len());
            for (t, &j) in self.cols_j.iter().enumerate() {
                let a = ds.x.col_dot(j, &xi);
                if a != 0.0 {
                    coefs.push((self.bp[t], a));
                    coefs.push((self.bm[t], -a));
                }
            }
            self.solver.add_row(self.c[i] - self.lambda, self.c[i] + self.lambda, &coefs);
            self.row_pos[i] = Some(self.rows_i.len());
            self.rows_i.push(i);
        }
    }

    /// Largest λ' in `[lambda_lo, lambda)` where the current basis stops
    /// being optimal for the *restricted* model. Dantzig is
    /// RHS-parametric — λ moves the row ranges `[c_i − λ, c_i + λ]`, not
    /// the costs — so the scan rides the basic solution along the bound
    /// shrink direction (one FTRAN) and reports the first basic variable
    /// to hit a bound; see
    /// `crate::simplex::SimplexSolver::next_rhs_breakpoint`.
    pub(crate) fn next_breakpoint(&mut self, lambda: f64, lambda_lo: f64) -> Option<f64> {
        let centers: Vec<f64> = self.rows_i.iter().map(|&i| self.c[i]).collect();
        self.solver.next_rhs_breakpoint(&centers, lambda, lambda_lo)
    }

    /// Change λ in place: every row's range becomes `[c_i − λ, c_i + λ]`.
    /// The basis and duals are untouched (dual warm start; the next solve
    /// repairs primal feasibility with the dual simplex) — the λ-path
    /// driver's hook.
    pub fn set_lambda(&mut self, lambda: f64) {
        self.lambda = lambda;
        for (r, &i) in self.rows_i.iter().enumerate() {
            self.solver.set_row_bounds(r, self.c[i] - lambda, self.c[i] + lambda);
        }
    }

    /// Worker threads for the dense dual-simplex pricing row (see
    /// [`crate::simplex::SimplexSolver::set_threads`]).
    pub fn set_threads(&mut self, threads: usize) {
        self.solver.set_threads(threads);
    }

    /// Solve the restricted LP (warm-started).
    pub fn solve(&mut self) -> Status {
        self.solver.solve()
    }

    /// Restricted-LP objective (= `‖β‖₁` of the restricted solution).
    pub fn objective(&self) -> f64 {
        self.solver.objective()
    }

    /// Simplex iterations so far (primal + dual, cumulative).
    pub fn simplex_iters(&self) -> usize {
        self.solver.stats.primal_iters + self.solver.stats.dual_iters
    }

    /// Coefficients on the working set: `(j, β_j)` pairs.
    pub fn beta_support(&self) -> Vec<(usize, f64)> {
        let mut out = Vec::with_capacity(self.cols_j.len());
        for (t, &j) in self.cols_j.iter().enumerate() {
            let b = self.solver.col_value(self.bp[t]) - self.solver.col_value(self.bm[t]);
            if b != 0.0 {
                out.push((j, b));
            }
        }
        out
    }

    /// Price left-out constraint rows: `r = Xᵀ(y − Xβ)` through the
    /// pricer; returns `(i, |r_i| − λ)` for every `i ∉ I` violating by
    /// more than ε.
    pub fn price_constraints(
        &self,
        ds: &Dataset,
        pricer: &dyn Pricer,
        eps: f64,
    ) -> Vec<(usize, f64)> {
        let support = self.beta_support();
        let cols: Vec<usize> = support.iter().map(|&(j, _)| j).collect();
        let vals: Vec<f64> = support.iter().map(|&(_, v)| v).collect();
        let mut xb = vec![0.0; ds.n()];
        ds.x.matvec_cols(&cols, &vals, &mut xb);
        let u: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, m)| y - m).collect();
        let mut r = vec![0.0; ds.p()];
        pricer.score(&u, &mut r);
        let mut out = Vec::new();
        for (i, &ri) in r.iter().enumerate() {
            if self.row_pos[i].is_none() {
                let viol = ri.abs() - self.lambda;
                if viol > eps {
                    out.push((i, viol));
                }
            }
        }
        out
    }

    /// Price left-out coefficient columns: with row duals μ, the reduced
    /// cost of the cheaper β half of `j` is `1 − |(XᵀXμ̄)_j|`, computed as
    /// `s = Xᵀw`, `w = Σ_{i∈I} μ_i x_i`. Returns `(j, |s_j| − 1)` for
    /// every `j ∉ J` violating by more than ε.
    pub fn price_coef_cols(
        &self,
        ds: &Dataset,
        pricer: &dyn Pricer,
        eps: f64,
    ) -> Vec<(usize, f64)> {
        let mu: Vec<f64> = (0..self.rows_i.len()).map(|r| self.solver.row_dual(r)).collect();
        let mut w = vec![0.0; ds.n()];
        ds.x.matvec_cols(&self.rows_i, &mu, &mut w);
        let mut s = vec![0.0; ds.p()];
        pricer.score(&w, &mut s);
        let mut out = Vec::new();
        for (j, &sj) in s.iter().enumerate() {
            if self.pos_j[j].is_none() {
                let viol = sj.abs() - 1.0;
                if viol > eps {
                    out.push((j, viol));
                }
            }
        }
        out
    }
}

/// [`RestrictedDantzig`] adapted to the generic engine: both channels
/// live (column-and-constraint generation).
pub struct DantzigProblem<'a> {
    rd: RestrictedDantzig,
    ds: &'a Dataset,
    pricer: &'a dyn Pricer,
}

impl<'a> DantzigProblem<'a> {
    /// Wrap a restricted model.
    pub fn new(rd: RestrictedDantzig, ds: &'a Dataset, pricer: &'a dyn Pricer) -> Self {
        Self { rd, ds, pricer }
    }

    /// The wrapped restricted model.
    pub fn inner(&self) -> &RestrictedDantzig {
        &self.rd
    }

    /// Mutable access to the wrapped restricted model (the exact-path
    /// driver's breakpoint scan).
    pub fn inner_mut(&mut self) -> &mut RestrictedDantzig {
        &mut self.rd
    }

    /// Change λ in place (warm-start preserving) — the path driver's hook.
    pub fn set_lambda(&mut self, lambda: f64) {
        self.rd.set_lambda(lambda);
    }
}

impl Snapshot for DantzigProblem<'_> {
    fn export_working_set(&self) -> WorkingSet {
        WorkingSet { cols: self.rd.j_set().to_vec(), rows: self.rd.i_set().to_vec() }
    }
    fn import_working_set(&mut self, ws: &WorkingSet) {
        // rows first: each constraint row pulls in its own coefficient
        // pair, preserving the I ⊆ J feasibility invariant; the remaining
        // snapshot columns are then unioned in
        self.rd.add_constraint_rows(self.ds, &ws.rows);
        self.rd.add_coef_cols(self.ds, &ws.cols);
    }
}

impl RestrictedProblem for DantzigProblem<'_> {
    fn solve(&mut self) -> Status {
        self.rd.solve()
    }
    fn objective(&self) -> f64 {
        self.rd.objective()
    }
    fn simplex_iters(&self) -> usize {
        self.rd.simplex_iters()
    }
    fn price_rows(&mut self, eps: f64) -> Vec<(usize, f64)> {
        self.rd.price_constraints(self.ds, self.pricer, eps)
    }
    fn price_cols(&mut self, eps: f64) -> Vec<(usize, f64)> {
        self.rd.price_coef_cols(self.ds, self.pricer, eps)
    }
    fn add_rows(&mut self, idx: &[usize]) {
        self.rd.add_constraint_rows(self.ds, idx);
    }
    fn add_cols(&mut self, idx: &[usize]) {
        self.rd.add_coef_cols(self.ds, idx);
    }
    fn working_set_size(&self) -> usize {
        self.rd.j_set().len() + self.rd.i_set().len()
    }
    fn reprice_at(&mut self, lambda: f64) {
        self.rd.set_lambda(lambda);
    }
}

/// Package the restricted solution as an [`SvmSolution`] (`beta0` is 0 —
/// the Dantzig selector has no intercept; `objective` is `‖β‖₁`).
fn finish(ds: &Dataset, rd: &RestrictedDantzig, stats: GenStats) -> SvmSolution {
    let support = rd.beta_support();
    let mut beta = vec![0.0; ds.p()];
    for &(j, v) in &support {
        beta[j] = v;
    }
    let mut cols = rd.j_set().to_vec();
    cols.sort_unstable();
    let mut rows = rd.i_set().to_vec();
    rows.sort_unstable();
    SvmSolution { beta, beta0: 0.0, objective: rd.objective(), stats, cols, rows }
}

/// Column-and-constraint generation for the Dantzig selector. `seed` is
/// the initial feature working set (empty ⇒ the top
/// [`GenParams::seed_budget`] `|x_iᵀy|` scores; callers wanting a
/// first-order seed go through
/// [`crate::engine::Initializer::seed_dantzig`]).
pub fn dantzig_generation(
    ds: &Dataset,
    backend: &dyn Backend,
    lambda: f64,
    seed: &[usize],
    params: &GenParams,
) -> SvmSolution {
    let mut rd = RestrictedDantzig::new(ds, lambda, &[]);
    // default seed from the c = Xᵀy the model just computed (no second
    // O(np) pass): the top-|c| features bind first below λ_max
    let seed: Vec<usize> = if seed.is_empty() {
        top_k_by_abs(&rd.c, params.seed_budget.min(ds.p()))
    } else {
        seed.to_vec()
    };
    rd.add_constraint_rows(ds, &seed);
    rd.set_threads(params.threads);
    let pricer = BackendPricer::new(backend, params.threads);
    let mut prob = DantzigProblem::new(rd, ds, &pricer);
    let mut stats = GenEngine::new(params).run(&mut prob);
    stats.rows_added += seed.len();
    stats.cols_added += seed.len();
    finish(ds, prob.inner(), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::baselines::dantzig_full::solve_full_dantzig;
    use crate::data::synthetic::{generate_dantzig, DantzigSpec};
    use crate::rng::Xoshiro256;

    fn small_ds(n: usize, p: usize, seed: u64) -> Dataset {
        let spec = DantzigSpec { n, p, k0: 5.min(p), rho: 0.1, sigma: 0.5, standardize: true };
        generate_dantzig(&spec, &mut Xoshiro256::seed_from_u64(seed))
    }

    #[test]
    fn ccg_matches_full_lp() {
        let ds = small_ds(40, 25, 501);
        let lambda = 0.3 * lambda_max_dantzig(&ds);
        let backend = NativeBackend::new(&ds.x);
        let full = solve_full_dantzig(&ds, lambda);
        let params = GenParams { eps: 1e-9, ..Default::default() };
        let sol = dantzig_generation(&ds, &backend, lambda, &[], &params);
        assert!(sol.stats.converged, "engine must report ε-optimality");
        assert!(
            (sol.objective - full.objective).abs() / full.objective.max(1e-9) < 1e-6,
            "ccg {} full {}",
            sol.objective,
            full.objective
        );
    }

    #[test]
    fn ccg_matches_full_lp_high_dimensional() {
        // p > n: the Gram matrix is singular; the working sets stay small
        let ds = small_ds(25, 60, 502);
        let lambda = 0.4 * lambda_max_dantzig(&ds);
        let backend = NativeBackend::new(&ds.x);
        let full = solve_full_dantzig(&ds, lambda);
        let params = GenParams { eps: 1e-9, ..Default::default() };
        let sol = dantzig_generation(&ds, &backend, lambda, &[], &params);
        assert!(
            (sol.objective - full.objective).abs() / full.objective.max(1e-9) < 1e-6,
            "ccg {} full {}",
            sol.objective,
            full.objective
        );
        assert!(sol.cols.len() < ds.p(), "working set {} of {}", sol.cols.len(), ds.p());
    }

    #[test]
    fn lambda_above_max_gives_zero_solution() {
        let ds = small_ds(30, 20, 503);
        let lambda = 1.01 * lambda_max_dantzig(&ds);
        let backend = NativeBackend::new(&ds.x);
        let sol = dantzig_generation(&ds, &backend, lambda, &[], &GenParams::default());
        assert_eq!(sol.support_size(), 0, "beta must be zero above lambda_max");
        assert!(sol.objective.abs() < 1e-9);
    }

    /// The pricer-based column pricing must agree with a brute-force O(p)
    /// reduced-cost scan that forms each Gram entry explicitly.
    #[test]
    fn column_pricing_matches_brute_force_scan() {
        let ds = small_ds(30, 40, 504);
        let lambda = 0.35 * lambda_max_dantzig(&ds);
        let seed = initial_features(&ds, 6);
        let mut rd = RestrictedDantzig::new(&ds, lambda, &seed);
        assert_eq!(rd.solve(), Status::Optimal);

        let backend = NativeBackend::new(&ds.x);
        let pricer = BackendPricer::new(&backend, 1);
        let fast = rd.price_coef_cols(&ds, &pricer, 1e-9);

        // brute force: s_j = Σ_{i∈I} μ_i <x_i, x_j> entry by entry
        let mu: Vec<f64> =
            (0..rd.i_set().len()).map(|r| rd.solver.row_dual(r)).collect();
        let mut slow = Vec::new();
        for j in 0..ds.p() {
            if rd.pos_j[j].is_some() {
                continue;
            }
            let mut sj = 0.0;
            for (r, &i) in rd.i_set().iter().enumerate() {
                let mut a = 0.0;
                for row in 0..ds.n() {
                    a += ds.x.get(row, i) * ds.x.get(row, j);
                }
                sj += mu[r] * a;
            }
            let viol = sj.abs() - 1.0;
            if viol > 1e-9 {
                slow.push((j, viol));
            }
        }
        assert_eq!(fast.len(), slow.len(), "fast {fast:?} slow {slow:?}");
        for (&(jf, vf), &(js, vs)) in fast.iter().zip(&slow) {
            assert_eq!(jf, js);
            assert!((vf - vs).abs() < 1e-8, "j={jf}: fast {vf} slow {vs}");
        }
    }

    /// Row pricing likewise: r_i = <x_i, y − Xβ> entry by entry.
    #[test]
    fn row_pricing_matches_brute_force_scan() {
        let ds = small_ds(25, 35, 505);
        let lambda = 0.5 * lambda_max_dantzig(&ds);
        let seed = initial_features(&ds, 5);
        let mut rd = RestrictedDantzig::new(&ds, lambda, &seed);
        assert_eq!(rd.solve(), Status::Optimal);

        let backend = NativeBackend::new(&ds.x);
        let pricer = BackendPricer::new(&backend, 1);
        let fast = rd.price_constraints(&ds, &pricer, 1e-9);

        let support = rd.beta_support();
        let mut slow = Vec::new();
        for i in 0..ds.p() {
            if rd.row_pos[i].is_some() {
                continue;
            }
            let mut ri = 0.0;
            for row in 0..ds.n() {
                let mut xb = 0.0;
                for &(j, b) in &support {
                    xb += ds.x.get(row, j) * b;
                }
                ri += ds.x.get(row, i) * (ds.y[row] - xb);
            }
            let viol = ri.abs() - lambda;
            if viol > 1e-9 {
                slow.push((i, viol));
            }
        }
        assert_eq!(fast.len(), slow.len(), "fast {fast:?} slow {slow:?}");
        for (&(ifa, vf), &(isl, vs)) in fast.iter().zip(&slow) {
            assert_eq!(ifa, isl);
            assert!((vf - vs).abs() < 1e-8, "i={ifa}: fast {vf} slow {vs}");
        }
    }

    #[test]
    fn restricted_model_is_always_feasible() {
        // I ⊆ J invariant: even a tiny λ keeps every restricted solve optimal
        let ds = small_ds(20, 30, 506);
        let lambda = 1e-3 * lambda_max_dantzig(&ds);
        let mut rd = RestrictedDantzig::new(&ds, lambda, &initial_features(&ds, 4));
        assert_eq!(rd.solve(), Status::Optimal);
        rd.add_constraint_rows(&ds, &[0, 1, 2]);
        assert_eq!(rd.solve(), Status::Optimal);
        for &i in rd.i_set() {
            assert!(rd.pos_j[i].is_some(), "row {i} lacks its column pair");
        }
    }

    #[test]
    fn warm_lambda_path_matches_fresh_solves() {
        let ds = small_ds(30, 20, 507);
        let lmax = lambda_max_dantzig(&ds);
        let backend = NativeBackend::new(&ds.x);
        let params = GenParams { eps: 1e-9, ..Default::default() };
        let pricer = BackendPricer::new(&backend, 1);
        let seed = initial_features(&ds, 5);
        let mut prob =
            DantzigProblem::new(RestrictedDantzig::new(&ds, 0.6 * lmax, &seed), &ds, &pricer);
        let engine = GenEngine::new(&params);
        for frac in [0.6, 0.4, 0.25] {
            let lambda = frac * lmax;
            prob.set_lambda(lambda);
            engine.run(&mut prob);
            let warm = prob.inner().objective();
            let fresh = dantzig_generation(&ds, &backend, lambda, &[], &params).objective;
            assert!(
                (warm - fresh).abs() / fresh.max(1e-9) < 1e-6,
                "λ={lambda}: warm {warm} fresh {fresh}"
            );
        }
    }
}
