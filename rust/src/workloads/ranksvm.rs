//! Constraint generation for **RankSVM** — the pairwise-hinge L1 LP.
//!
//! Given samples with real-valued relevance scores `y`, RankSVM learns a
//! linear scoring function `x ↦ xᵀβ` that orders the samples like `y`
//! does, with an L1 penalty for feature selection:
//!
//! ```text
//! min Σ_{(i,k)∈P} max(0, 1 − (x_i − x_k)ᵀβ) + λ‖β‖₁,
//! P = {(i,k) : y_i > y_k}.
//! ```
//!
//! The LP form mirrors L1-SVM with the samples replaced by the **O(n²)
//! comparison pairs** — one hinge slack `ξ_ik` and one margin row
//! `ξ_ik + (x_i − x_k)ᵀ(β⁺ − β⁻) ≥ 1` per pair — which is exactly the
//! regime where constraint generation shines: the restricted model only
//! ever materializes the pairs that bind. There is no intercept (it
//! cancels in score differences).
//!
//! The candidate pair set lives behind
//! [`crate::workloads::pairset::PairSet`]: an enumerated list for small
//! instances and cross-checks, an implicit sorted-order representation
//! beyond (selected by [`crate::engine::GenParams::pair_mode`]). Both
//! share one canonical pair-index space, so working-set snapshots are
//! valid under either representation.
//!
//! Pricing:
//!
//! * **rows (pairs)** — one margin matvec `m = Xβ` over the support,
//!   then [`PairSet::price`]: for every winner, the most violated pair
//!   `argmax_k 1 − (m_i − m_k)` via a prefix-max sweep over margins in
//!   sorted-relevance order (O(n log n) implicit; O(|P|) enumerated),
//!   keeping the cap's worth of most-violated winner-best pairs;
//! * **columns (features)** — with pair duals `π ∈ [0,1]`, the reduced
//!   cost of `β⁺_j/β⁻_j` is `λ ∓ q_j` with `q = Xᵀv` and
//!   `v_i = Σ_{(i,·)} π − Σ_{(·,i)} π` (duals scattered +winner/−loser),
//!   so one [`Pricer`] pass — the chunked parallel `Xᵀv` of
//!   [`crate::engine::BackendPricer`] — prices all left-out features.
//!
//! **Weighted, gapped pairs** (rank2plan parity): with a
//! [`PairCosts`] the hinge generalizes to
//! `Σ_t w_t·max(0, g_t − (x_i − x_k)ᵀβ)` — the slack column costs `w_t`
//! and the margin row's lower bound becomes `g_t`, so the LP shape (and
//! the exact-path cost decomposition — gaps enter the RHS, not the
//! cost) is unchanged. Uniform costs (`g = w = 1`) take the original
//! code paths bitwise; bucketed per-relevance-level costs keep the
//! implicit pricing sweep sublinear (O(n·L)); arbitrary per-pair costs
//! fall back to enumeration, surfaced as
//! [`crate::engine::GenStats::pair_scan`].
//!
//! See `docs/ranksvm-scaling.md` for the scaling story.

use std::collections::HashMap;

use crate::backend::Backend;
use crate::coordinator::{GenParams, GenStats, SvmSolution};
use crate::data::Dataset;
use crate::engine::{BackendPricer, GenEngine, Pricer, RestrictedProblem, Snapshot, WorkingSet};
use crate::fom::screening::top_k_by_abs;
use crate::simplex::{LpModel, SimplexSolver, Status, VarId};
use crate::workloads::pairset::{PairCosts, PairSet, DEFAULT_PAIR_ROWS_PER_ROUND};

/// The reference enumeration of all comparison pairs `(i, k)` with
/// `y_i > y_k`, in **canonical order**: winners ascending by sample
/// index, each winner's losers ascending by `(y, index)` — the index
/// space [`PairSet`] exposes in both representations. NaN responses
/// participate in no pair (`y_i > y_k` is false for NaN on either
/// side). O(n²); the implicit representation exists so large-n callers
/// never build this.
pub fn ranking_pairs(y: &[f64]) -> Vec<(usize, usize)> {
    let n = y.len();
    let mut order: Vec<usize> = (0..n).filter(|&i| !y[i].is_nan()).collect();
    order.sort_by(|&a, &b| y[a].total_cmp(&y[b]).then(a.cmp(&b)));
    let mut out = Vec::new();
    for i in 0..n {
        for &k in order.iter().take_while(|&&k| y[k] < y[i]) {
            out.push((i, k));
        }
    }
    out
}

/// The weighted/gapped reference enumeration: [`ranking_pairs`] with
/// each pair's `(gap, weight)` attached, resolved from `costs` **without
/// touching [`PairSet`]** — levels are re-derived here as the rank of
/// `y_i` among the distinct finite responses, and per-pair tables are
/// read at the pair's position in this (canonical-order) enumeration.
/// The independence is the point: oracle tests compare [`PairSet`]'s
/// cost resolution against this one. O(n²).
pub fn ranking_pairs_costed(y: &[f64], costs: &PairCosts) -> Vec<(usize, usize, f64, f64)> {
    let mut distinct: Vec<f64> = y.iter().copied().filter(|v| !v.is_nan()).collect();
    distinct.sort_by(f64::total_cmp);
    distinct.dedup_by(|a, b| a == b);
    let level = |v: f64| distinct.partition_point(|&d| d < v);
    ranking_pairs(y)
        .into_iter()
        .enumerate()
        .map(|(t, (i, k))| {
            let (g, w) = match costs {
                PairCosts::Uniform => (1.0, 1.0),
                PairCosts::Bucketed { levels, gaps, weights } => {
                    let idx = level(y[i]) * levels + level(y[k]);
                    (gaps[idx], weights[idx])
                }
                PairCosts::PerPair { gaps, weights } => (gaps[t], weights[t]),
            };
            (i, k, g, w)
        })
        .collect()
}

/// λ above which `β = 0` is optimal: `‖Xᵀv₁‖∞` with `v₁` the all-ones
/// dual scatter ([`PairSet::ones_dual`] — at `β = 0` every pair's slack
/// is strictly positive, so complementary slackness forces every dual
/// to 1). O(np), never O(|P|).
pub fn lambda_max_rank(ds: &Dataset, pairs: &PairSet) -> f64 {
    lambda_max_rank_weighted(ds, pairs, &PairCosts::UNIFORM)
}

/// Weighted λ_max: at `β = 0` every pair's slack is `g_t > 0`, so every
/// dual sits at its weight bound `w_t` and β stays zero exactly while
/// `λ ≥ ‖Xᵀv_w‖∞` with `v_w` the weight scatter
/// ([`PairSet::weighted_dual`]). Uniform costs reproduce
/// [`lambda_max_rank`] bitwise. O(np + n·L²), never O(|P|) for
/// bucketed costs.
pub fn lambda_max_rank_weighted(ds: &Dataset, pairs: &PairSet, costs: &PairCosts) -> f64 {
    let v = pairs.weighted_dual(costs);
    let mut q = vec![0.0; ds.p()];
    ds.x.tmatvec(&v, &mut q);
    q.iter().fold(0.0f64, |m, x| m.max(x.abs()))
}

/// Initial feature working set: top `k` scores `|q_j|` at `β = 0`.
pub fn initial_rank_features(ds: &Dataset, pairs: &PairSet, k: usize) -> Vec<usize> {
    initial_rank_features_weighted(ds, pairs, &PairCosts::UNIFORM, k)
}

/// Weighted initial feature working set: top `k` scores `|q_j|` of
/// `q = Xᵀv_w` at `β = 0` (see [`lambda_max_rank_weighted`]).
pub fn initial_rank_features_weighted(
    ds: &Dataset,
    pairs: &PairSet,
    costs: &PairCosts,
    k: usize,
) -> Vec<usize> {
    let v = pairs.weighted_dual(costs);
    let mut q = vec![0.0; ds.p()];
    ds.x.tmatvec(&v, &mut q);
    top_k_by_abs(&q, k.min(ds.p()))
}

/// Initial pair working set: `k` pairs spread evenly over the canonical
/// index space (at `β = 0` all pairs are equally violated, so coverage
/// beats scoring). Delegates to
/// [`crate::workloads::pairset::spread_indices`], which always fills the
/// budget — the old stride walk clustered at the front and under-covered
/// the tail when `n_pairs` was not a multiple of `k`.
pub fn initial_pairs(n_pairs: usize, k: usize) -> Vec<usize> {
    crate::workloads::pairset::spread_indices(n_pairs, k)
}

/// Pairwise hinge loss of a support-sparse β over ALL candidate pairs
/// (one margin matvec, then [`PairSet::hinge`] — O(n log n) implicit).
pub fn pairwise_hinge_support(
    ds: &Dataset,
    pairs: &PairSet,
    cols: &[usize],
    vals: &[f64],
) -> f64 {
    let mut m = vec![0.0; ds.n()];
    ds.x.matvec_cols(cols, vals, &mut m);
    pairs.hinge(&m)
}

/// Weighted pairwise hinge `Σ_t w_t·max(0, g_t − (m_i − m_k))` of a
/// support-sparse β over ALL candidate pairs (one margin matvec, then
/// [`PairSet::hinge_weighted`] — O(n·L·log n) for bucketed costs on the
/// implicit representation). Uniform costs reproduce
/// [`pairwise_hinge_support`] bitwise.
pub fn pairwise_hinge_support_weighted(
    ds: &Dataset,
    pairs: &PairSet,
    costs: &PairCosts,
    cols: &[usize],
    vals: &[f64],
) -> f64 {
    let mut m = vec![0.0; ds.n()];
    ds.x.matvec_cols(cols, vals, &mut m);
    pairs.hinge_weighted(&m, costs)
}

/// Violated-pair budget per pricing round: an explicit
/// [`GenParams::max_rows_per_round`] wins, otherwise
/// [`DEFAULT_PAIR_ROWS_PER_ROUND`] keeps a cold large-n solve from
/// swallowing O(n) winner-best rows into the restricted LP per round.
pub fn pair_rows_cap(params: &GenParams) -> usize {
    if params.max_rows_per_round > 0 {
        params.max_rows_per_round
    } else {
        DEFAULT_PAIR_ROWS_PER_ROUND
    }
}

/// The restricted RankSVM LP over a pair working set P′ and feature
/// working set J.
pub struct RestrictedRank<'p> {
    solver: SimplexSolver,
    lambda: f64,
    /// The candidate pair set (the index space of the row channel).
    pairs: &'p PairSet,
    /// Per-pair `(gap, weight)` costs — [`PairCosts::UNIFORM`] is the
    /// original unweighted LP, bitwise.
    costs: &'p PairCosts,
    /// Pair index handled by LP row position r.
    rows_t: Vec<usize>,
    /// pair index → LP row position (absent when t ∉ P′). A map, not a
    /// dense vector: the candidate space is O(n²) and P′ stays small.
    row_pos: HashMap<usize, usize>,
    /// Feature handled by column-pair position.
    cols_j: Vec<usize>,
    /// feature j → column-pair position.
    pos_j: Vec<Option<usize>>,
    /// β⁺ / β⁻ variable ids per column-pair position.
    bp: Vec<VarId>,
    bm: Vec<VarId>,
    /// Workers for the pair pricing sweep (see [`PairSet::price`]).
    threads: usize,
    /// Cap on violated pairs returned per pricing round (0 = every
    /// winner-best pair).
    pair_cap: usize,
    /// Cost decomposition `cost_v(λ) = cfix[v] + λ·cvar[v]` maintained
    /// alongside every `add_*` — the exact-path breakpoint scan reads it.
    cfix: Vec<f64>,
    cvar: Vec<f64>,
}

impl<'p> RestrictedRank<'p> {
    /// Build the restricted model for the given pair / feature working
    /// sets (uniform costs — the original unweighted RankSVM, bitwise).
    pub fn new(
        ds: &Dataset,
        pairs: &'p PairSet,
        lambda: f64,
        t_init: &[usize],
        j_init: &[usize],
    ) -> Self {
        Self::new_weighted(ds, pairs, &PairCosts::UNIFORM, lambda, t_init, j_init)
    }

    /// Build the restricted model with per-pair `(gap, weight)` costs:
    /// pair `t`'s slack column costs `w_t` and its margin row reads
    /// `ξ_t + Σ_j (x_ij − x_kj)(β⁺_j − β⁻_j) ≥ g_t`. The exact-path
    /// cost decomposition stays valid (gaps land in the RHS; `cfix`
    /// carries `w_t`).
    pub fn new_weighted(
        ds: &Dataset,
        pairs: &'p PairSet,
        costs: &'p PairCosts,
        lambda: f64,
        t_init: &[usize],
        j_init: &[usize],
    ) -> Self {
        debug_assert!(costs.validate(pairs).is_ok(), "invalid pair costs");
        let mut me = Self {
            solver: SimplexSolver::new(LpModel::new()),
            lambda,
            pairs,
            costs,
            rows_t: Vec::new(),
            row_pos: HashMap::new(),
            cols_j: Vec::new(),
            pos_j: vec![None; ds.p()],
            bp: Vec::new(),
            bm: Vec::new(),
            threads: 1,
            pair_cap: 0,
            cfix: Vec::new(),
            cvar: Vec::new(),
        };
        me.add_pairs(ds, t_init);
        me.add_features(ds, j_init);
        me
    }

    /// Current pair working set P′ (pair indices, insertion order).
    pub fn t_set(&self) -> &[usize] {
        &self.rows_t
    }

    /// Current feature working set J (insertion order).
    pub fn j_set(&self) -> &[usize] {
        &self.cols_j
    }

    /// Bring pairs into P′: appends the margin rows
    /// `ξ_ik + Σ_{j∈J} (x_ij − x_kj)(β⁺_j − β⁻_j) ≥ g_t` with the slack
    /// column costed `w_t` (both 1 under uniform costs).
    pub fn add_pairs(&mut self, ds: &Dataset, ts: &[usize]) {
        for &t in ts {
            if self.row_pos.contains_key(&t) {
                continue;
            }
            let (i, k) = self.pairs.pair(t);
            let (g, w) = self.costs.gap_weight(self.pairs, t);
            let xi = self.solver.add_col(w, 0.0, f64::INFINITY, &[]);
            let mut coefs: Vec<(VarId, f64)> = Vec::with_capacity(1 + 2 * self.cols_j.len());
            coefs.push((xi, 1.0));
            for (pos, &j) in self.cols_j.iter().enumerate() {
                let d = ds.x.get(i, j) - ds.x.get(k, j);
                if d != 0.0 {
                    coefs.push((self.bp[pos], d));
                    coefs.push((self.bm[pos], -d));
                }
            }
            self.solver.add_row(g, f64::INFINITY, &coefs);
            self.row_pos.insert(t, self.rows_t.len());
            self.rows_t.push(t);
            self.cfix.push(w);
            self.cvar.push(0.0);
        }
    }

    /// Bring features into J: appends the `β⁺_j/β⁻_j` pair (cost λ) with
    /// coefficients `±(x_ij − x_kj)` on the existing margin rows.
    pub fn add_features(&mut self, ds: &Dataset, features: &[usize]) {
        for &j in features {
            if self.pos_j[j].is_some() {
                continue;
            }
            // densify column j once, then O(1) per existing pair row
            let mut xj = vec![0.0; ds.n()];
            for (i, v) in ds.x.col_entries(j) {
                xj[i] = v;
            }
            let mut pos_coefs = Vec::with_capacity(self.rows_t.len());
            let mut neg_coefs = Vec::with_capacity(self.rows_t.len());
            for (r, &t) in self.rows_t.iter().enumerate() {
                let (i, k) = self.pairs.pair(t);
                let d = xj[i] - xj[k];
                if d != 0.0 {
                    pos_coefs.push((r, d));
                    neg_coefs.push((r, -d));
                }
            }
            let bp = self.solver.add_col(self.lambda, 0.0, f64::INFINITY, &pos_coefs);
            let bm = self.solver.add_col(self.lambda, 0.0, f64::INFINITY, &neg_coefs);
            self.pos_j[j] = Some(self.cols_j.len());
            self.cols_j.push(j);
            self.bp.push(bp);
            self.bm.push(bm);
            self.cfix.extend_from_slice(&[0.0, 0.0]);
            self.cvar.extend_from_slice(&[1.0, 1.0]);
        }
    }

    /// Largest λ' in `[lambda_lo, lambda)` where the current basis stops
    /// being cost-optimal for the *restricted* model — the exact-path
    /// driver's breakpoint scan (two BTRANs + one nonbasic pass).
    pub(crate) fn next_breakpoint(&mut self, lambda: f64, lambda_lo: f64) -> Option<f64> {
        crate::simplex::next_cost_breakpoint(
            &mut self.solver,
            &self.cfix,
            &self.cvar,
            lambda,
            lambda_lo,
        )
    }

    /// Change λ in place (costs of all β halves); keeps the basis for
    /// primal warm starts — the λ-path driver's hook.
    pub fn set_lambda(&mut self, lambda: f64) {
        self.lambda = lambda;
        for t in 0..self.cols_j.len() {
            self.solver.set_col_cost(self.bp[t], lambda);
            self.solver.set_col_cost(self.bm[t], lambda);
        }
    }

    /// Worker threads for the dense dual-simplex pricing row (see
    /// [`crate::simplex::SimplexSolver::set_threads`]) and for the
    /// implicit pair-pricing sweep.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
        self.solver.set_threads(threads);
    }

    /// Cap the violated pairs returned per pricing round (0 = every
    /// winner-best pair). Drivers set this through [`pair_rows_cap`].
    pub fn set_pair_cap(&mut self, cap: usize) {
        self.pair_cap = cap;
    }

    /// Solve the restricted LP (warm-started).
    pub fn solve(&mut self) -> Status {
        self.solver.solve()
    }

    /// Restricted-LP objective.
    pub fn objective(&self) -> f64 {
        self.solver.objective()
    }

    /// Simplex iterations so far (primal + dual, cumulative).
    pub fn simplex_iters(&self) -> usize {
        self.solver.stats.primal_iters + self.solver.stats.dual_iters
    }

    /// Coefficients on the working set: `(j, β_j)` pairs.
    pub fn beta_support(&self) -> Vec<(usize, f64)> {
        let mut out = Vec::with_capacity(self.cols_j.len());
        for (t, &j) in self.cols_j.iter().enumerate() {
            let b = self.solver.col_value(self.bp[t]) - self.solver.col_value(self.bm[t]);
            if b != 0.0 {
                out.push((j, b));
            }
        }
        out
    }

    /// Price left-out pairs: one margin matvec `m = Xβ`, then the
    /// [`PairSet::price`] winner-best sweep (O(n log n) implicit,
    /// O(|P|) enumerated) — returns `(t, 1 − (m_i − m_k))` for the
    /// cap's worth of most violated pairs `t ∉ P′`.
    ///
    /// On sparse designs the margin matvec rides `Design::matvec_cols`
    /// (CSC `col_axpy` over the support), so the whole pair-pricing
    /// round costs O(Σ_{j∈supp(β)} nnz_j + n log n) — no dense pass.
    pub fn price_pairs(&self, ds: &Dataset, eps: f64) -> Vec<(usize, f64)> {
        let support = self.beta_support();
        let cols: Vec<usize> = support.iter().map(|&(j, _)| j).collect();
        let vals: Vec<f64> = support.iter().map(|&(_, v)| v).collect();
        let mut m = vec![0.0; ds.n()];
        ds.x.matvec_cols(&cols, &vals, &mut m);
        let mut excluded = self.rows_t.clone();
        excluded.sort_unstable();
        let (cands, _scan) =
            self.pairs
                .price_weighted(&m, eps, &excluded, self.pair_cap, self.threads, self.costs);
        cands
    }

    /// The pair costs this restricted model was built with.
    pub fn costs(&self) -> &'p PairCosts {
        self.costs
    }

    /// Which pair-scan strategy [`Self::price_pairs`] runs for this
    /// cost/representation combination (see
    /// [`crate::workloads::pairset::PairScan`]).
    pub fn pair_scan(&self) -> &'static str {
        self.costs.scan(self.pairs).as_str()
    }

    /// Price left-out features: scatter the pair duals into
    /// `v_i = Σ π_{(i,·)} − Σ π_{(·,i)}`, then `q = Xᵀv` through the
    /// pricer; returns `(j, |q_j| − λ)` for every `j ∉ J` violating by
    /// more than ε.
    pub fn price_features(
        &self,
        ds: &Dataset,
        pricer: &dyn Pricer,
        eps: f64,
    ) -> Vec<(usize, f64)> {
        let mut v = vec![0.0; ds.n()];
        for (r, &t) in self.rows_t.iter().enumerate() {
            let pi = self.solver.row_dual(r);
            if pi != 0.0 {
                let (i, k) = self.pairs.pair(t);
                v[i] += pi;
                v[k] -= pi;
            }
        }
        let mut q = vec![0.0; ds.p()];
        pricer.score(&v, &mut q);
        let mut out = Vec::new();
        for (j, &qj) in q.iter().enumerate() {
            if self.pos_j[j].is_none() {
                let viol = qj.abs() - self.lambda;
                if viol > eps {
                    out.push((j, viol));
                }
            }
        }
        out
    }
}

/// [`RestrictedRank`] adapted to the generic engine: both channels live
/// (pairs are the constraint channel, features the column channel).
pub struct RankProblem<'a, 'p> {
    rr: RestrictedRank<'p>,
    ds: &'a Dataset,
    pricer: &'a dyn Pricer,
}

impl<'a, 'p> RankProblem<'a, 'p> {
    /// Wrap a restricted model.
    pub fn new(rr: RestrictedRank<'p>, ds: &'a Dataset, pricer: &'a dyn Pricer) -> Self {
        Self { rr, ds, pricer }
    }

    /// The wrapped restricted model.
    pub fn inner(&self) -> &RestrictedRank<'p> {
        &self.rr
    }

    /// Mutable access to the wrapped restricted model (the exact-path
    /// driver's breakpoint scan).
    pub fn inner_mut(&mut self) -> &mut RestrictedRank<'p> {
        &mut self.rr
    }

    /// Change λ in place (warm-start preserving) — the path driver's hook.
    pub fn set_lambda(&mut self, lambda: f64) {
        self.rr.set_lambda(lambda);
    }
}

impl Snapshot for RankProblem<'_, '_> {
    fn export_working_set(&self) -> WorkingSet {
        // row indices address the CANONICAL pair-index space of the
        // candidate [`PairSet`], which is derived deterministically from
        // the sorted relevance order — snapshots are restorable against
        // either representation (enumerated or implicit) of the same y
        WorkingSet { cols: self.rr.j_set().to_vec(), rows: self.rr.t_set().to_vec() }
    }
    fn import_working_set(&mut self, ws: &WorkingSet) {
        self.rr.add_pairs(self.ds, &ws.rows);
        self.rr.add_features(self.ds, &ws.cols);
    }
}

impl RestrictedProblem for RankProblem<'_, '_> {
    fn solve(&mut self) -> Status {
        self.rr.solve()
    }
    fn objective(&self) -> f64 {
        self.rr.objective()
    }
    fn simplex_iters(&self) -> usize {
        self.rr.simplex_iters()
    }
    fn price_rows(&mut self, eps: f64) -> Vec<(usize, f64)> {
        self.rr.price_pairs(self.ds, eps)
    }
    fn price_cols(&mut self, eps: f64) -> Vec<(usize, f64)> {
        self.rr.price_features(self.ds, self.pricer, eps)
    }
    fn add_rows(&mut self, idx: &[usize]) {
        self.rr.add_pairs(self.ds, idx);
    }
    fn add_cols(&mut self, idx: &[usize]) {
        self.rr.add_features(self.ds, idx);
    }
    fn working_set_size(&self) -> usize {
        self.rr.j_set().len() + self.rr.t_set().len()
    }
    fn reprice_at(&mut self, lambda: f64) {
        self.rr.set_lambda(lambda);
    }
}

/// Package the restricted solution as an [`SvmSolution`]: `beta0` is 0
/// (no intercept), `objective` is the FULL problem's value — pairwise
/// hinge over every candidate pair plus `λ‖β‖₁`; `rows` holds the pair
/// indices of the final working set.
fn finish(
    ds: &Dataset,
    pairs: &PairSet,
    rr: &RestrictedRank<'_>,
    lambda: f64,
    stats: GenStats,
) -> SvmSolution {
    let report = crate::coordinator::report::ranksvm_report_weighted(
        ds,
        pairs,
        rr.costs(),
        &rr.beta_support(),
        lambda,
    );
    let mut cols = rr.j_set().to_vec();
    cols.sort_unstable();
    let mut rows = rr.t_set().to_vec();
    rows.sort_unstable();
    SvmSolution { beta: report.beta, beta0: 0.0, objective: report.objective, stats, cols, rows }
}

/// Column-and-constraint generation for RankSVM over the given candidate
/// pair set. `t_init`/`j_init` seed the pair and feature working sets;
/// empty seeds default to [`GenParams::seed_budget`] spread pairs and
/// top-budget `|q_j|` features (callers wanting a first-order seed go
/// through [`crate::engine::Initializer::seed_ranksvm`]). Per-round
/// violated-pair additions are bounded by [`pair_rows_cap`].
pub fn ranksvm_generation(
    ds: &Dataset,
    backend: &dyn Backend,
    pairs: &PairSet,
    lambda: f64,
    t_init: &[usize],
    j_init: &[usize],
    params: &GenParams,
) -> SvmSolution {
    ranksvm_generation_costed(
        ds,
        backend,
        pairs,
        &PairCosts::UNIFORM,
        lambda,
        t_init,
        j_init,
        params,
    )
}

/// [`ranksvm_generation`] with per-pair `(gap, weight)` costs: the
/// restricted LP carries `w_t`-costed slacks and `g_t` margin RHS, the
/// pricing sweep runs [`PairSet::price_weighted`], and the returned
/// stats name the scan that ran
/// ([`crate::engine::GenStats::pair_scan`]). Uniform costs reproduce
/// [`ranksvm_generation`] bitwise.
#[allow(clippy::too_many_arguments)]
pub fn ranksvm_generation_costed(
    ds: &Dataset,
    backend: &dyn Backend,
    pairs: &PairSet,
    costs: &PairCosts,
    lambda: f64,
    t_init: &[usize],
    j_init: &[usize],
    params: &GenParams,
) -> SvmSolution {
    let t_init: Vec<usize> = if t_init.is_empty() {
        pairs.spread(params.seed_budget)
    } else {
        t_init.to_vec()
    };
    let j_init: Vec<usize> = if j_init.is_empty() {
        initial_rank_features_weighted(ds, pairs, costs, params.seed_budget)
    } else {
        j_init.to_vec()
    };
    let pricer = BackendPricer::new(backend, params.threads);
    let mut rr = RestrictedRank::new_weighted(ds, pairs, costs, lambda, &t_init, &j_init);
    rr.set_threads(params.threads);
    rr.set_pair_cap(pair_rows_cap(params));
    let mut prob = RankProblem::new(rr, ds, &pricer);
    let mut stats = GenEngine::new(params).run(&mut prob);
    stats.rows_added += t_init.len();
    stats.cols_added += j_init.len();
    stats.pair_scan = Some(costs.scan(pairs).as_str());
    finish(ds, pairs, prob.inner(), lambda, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::baselines::ranksvm_full::solve_full_ranksvm;
    use crate::data::synthetic::{generate_ranksvm, RankSpec};
    use crate::engine::PairMode;
    use crate::rng::Xoshiro256;

    fn small_ds(n: usize, p: usize, seed: u64) -> Dataset {
        let spec = RankSpec { n, p, k0: 5.min(p), rho: 0.1, noise: 0.3, standardize: true };
        generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(seed))
    }

    fn pair_set(ds: &Dataset) -> PairSet {
        PairSet::build(&ds.y, PairMode::Auto)
    }

    #[test]
    fn pairs_enumeration_is_correct() {
        // canonical order: winners by sample index, losers by (y, index)
        assert_eq!(ranking_pairs(&[3.0, 1.0, 2.0]), vec![(0, 1), (0, 2), (2, 1)]);
        assert_eq!(ranking_pairs(&[3.0, 2.0, 1.0]), vec![(0, 2), (0, 1), (1, 2)]);
        assert!(ranking_pairs(&[1.0, 1.0]).is_empty(), "ties produce no pairs");
    }

    #[test]
    fn initial_pairs_fills_the_budget_via_spread() {
        // the old stride walk returned a front-clustered set when
        // n_pairs was not a multiple of k; the spread fix is pinned in
        // pairset — here we pin that this helper IS that spread
        assert_eq!(initial_pairs(29, 10).len(), 10);
        let ds = small_ds(12, 8, 608);
        let ps = pair_set(&ds);
        assert_eq!(initial_pairs(ps.len(), 7), ps.spread(7));
        assert!(initial_pairs(0, 5).is_empty());
    }

    #[test]
    fn cg_matches_full_pairwise_lp() {
        let ds = small_ds(20, 30, 601);
        let pairs = pair_set(&ds);
        let lambda = 0.05 * lambda_max_rank(&ds, &pairs);
        let backend = NativeBackend::new(&ds.x);
        let full = solve_full_ranksvm(&ds, &pairs.materialize(), lambda);
        let params = GenParams { eps: 1e-9, ..Default::default() };
        let sol = ranksvm_generation(&ds, &backend, &pairs, lambda, &[], &[], &params);
        assert!(sol.stats.converged, "engine must report ε-optimality");
        assert!(
            (sol.objective - full.objective).abs() / full.objective.max(1e-9) < 1e-6,
            "cg {} full {}",
            sol.objective,
            full.objective
        );
        // only a fraction of the O(n²) pairs should have been materialized
        assert!(
            sol.rows.len() < pairs.len(),
            "working set {} of {} pairs",
            sol.rows.len(),
            pairs.len()
        );
    }

    #[test]
    fn implicit_and_enumerated_generation_agree() {
        // same canonical index space ⇒ identical working sets; the
        // full-problem hinge is summed differently (list scan vs the
        // Fenwick sweep), so objectives agree to tolerance
        let ds = small_ds(26, 20, 607);
        let backend = NativeBackend::new(&ds.x);
        let params = GenParams { eps: 1e-8, ..Default::default() };
        let pe = PairSet::build(&ds.y, PairMode::Enumerate);
        let pi = PairSet::build(&ds.y, PairMode::Implicit);
        let lambda = 0.05 * lambda_max_rank(&ds, &pe);
        assert_eq!(lambda, 0.05 * lambda_max_rank(&ds, &pi), "λ_max is mode-independent");
        let a = ranksvm_generation(&ds, &backend, &pe, lambda, &[], &[], &params);
        let b = ranksvm_generation(&ds, &backend, &pi, lambda, &[], &[], &params);
        assert_eq!(a.cols, b.cols, "feature working sets must be identical");
        assert_eq!(a.rows, b.rows, "pair working sets must be identical");
        assert!(
            (a.objective - b.objective).abs() <= 1e-9 * a.objective.abs().max(1.0),
            "enumerated {} implicit {}",
            a.objective,
            b.objective
        );
    }

    #[test]
    fn lambda_above_max_gives_zero_solution() {
        let ds = small_ds(15, 12, 602);
        let pairs = pair_set(&ds);
        let lambda = 1.01 * lambda_max_rank(&ds, &pairs);
        let backend = NativeBackend::new(&ds.x);
        let sol =
            ranksvm_generation(&ds, &backend, &pairs, lambda, &[], &[], &GenParams::default());
        assert_eq!(sol.support_size(), 0, "beta must be zero above lambda_max");
    }

    #[test]
    fn solution_orders_informative_pairs() {
        let ds = small_ds(30, 20, 603);
        let pairs = pair_set(&ds);
        let lambda = 0.02 * lambda_max_rank(&ds, &pairs);
        let backend = NativeBackend::new(&ds.x);
        let params = GenParams { eps: 1e-7, ..Default::default() };
        let sol = ranksvm_generation(&ds, &backend, &pairs, lambda, &[], &[], &params);
        // scoring function must get most pairs right (concordance)
        let mut m = vec![0.0; ds.n()];
        ds.x.matvec(&sol.beta, &mut m);
        let mut good = 0usize;
        pairs.for_each(|_, i, k| {
            if m[i] > m[k] {
                good += 1;
            }
        });
        assert!(
            good * 10 >= pairs.len() * 7,
            "only {good}/{} pairs concordant",
            pairs.len()
        );
    }

    #[test]
    fn feature_pricing_matches_brute_force() {
        let ds = small_ds(15, 25, 604);
        let pairs = pair_set(&ds);
        let lambda = 0.1 * lambda_max_rank(&ds, &pairs);
        let t_init = pairs.spread(8);
        let j_init = initial_rank_features(&ds, &pairs, 4);
        let mut rr = RestrictedRank::new(&ds, &pairs, lambda, &t_init, &j_init);
        assert_eq!(rr.solve(), Status::Optimal);

        let backend = NativeBackend::new(&ds.x);
        let pricer = BackendPricer::new(&backend, 1);
        let fast = rr.price_features(&ds, &pricer, 1e-9);

        // brute force: q_j = Σ_rows π_t (x_ij − x_kj) feature by feature
        let mut slow = Vec::new();
        for j in 0..ds.p() {
            if rr.pos_j[j].is_some() {
                continue;
            }
            let mut qj = 0.0;
            for (r, &t) in rr.t_set().iter().enumerate() {
                let (i, k) = pairs.pair(t);
                qj += rr.solver.row_dual(r) * (ds.x.get(i, j) - ds.x.get(k, j));
            }
            let viol = qj.abs() - lambda;
            if viol > 1e-9 {
                slow.push((j, viol));
            }
        }
        assert_eq!(fast.len(), slow.len(), "fast {fast:?} slow {slow:?}");
        for (&(jf, vf), &(js, vs)) in fast.iter().zip(&slow) {
            assert_eq!(jf, js);
            assert!((vf - vs).abs() < 1e-8, "j={jf}: fast {vf} slow {vs}");
        }
    }

    #[test]
    fn pair_duals_in_unit_box() {
        let ds = small_ds(12, 10, 605);
        let pairs = pair_set(&ds);
        let lambda = 0.1 * lambda_max_rank(&ds, &pairs);
        let all_t: Vec<usize> = (0..pairs.len()).collect();
        let all_j: Vec<usize> = (0..ds.p()).collect();
        let mut rr = RestrictedRank::new(&ds, &pairs, lambda, &all_t, &all_j);
        assert_eq!(rr.solve(), Status::Optimal);
        for r in 0..rr.t_set().len() {
            let pi = rr.solver.row_dual(r);
            assert!((-1e-7..=1.0 + 1e-7).contains(&pi), "π[{r}] = {pi} outside [0,1]");
        }
    }

    #[test]
    fn warm_lambda_path_matches_fresh_solves() {
        let ds = small_ds(18, 15, 606);
        let pairs = pair_set(&ds);
        let lmax = lambda_max_rank(&ds, &pairs);
        let backend = NativeBackend::new(&ds.x);
        let params = GenParams { eps: 1e-9, ..Default::default() };
        let pricer = BackendPricer::new(&backend, 1);
        let t_init = pairs.spread(10);
        let j_init = initial_rank_features(&ds, &pairs, 5);
        let mut prob = RankProblem::new(
            RestrictedRank::new(&ds, &pairs, 0.5 * lmax, &t_init, &j_init),
            &ds,
            &pricer,
        );
        let engine = GenEngine::new(&params);
        for frac in [0.5, 0.2, 0.08] {
            let lambda = frac * lmax;
            prob.set_lambda(lambda);
            engine.run(&mut prob);
            let support = prob.inner().beta_support();
            let cols: Vec<usize> = support.iter().map(|&(j, _)| j).collect();
            let vals: Vec<f64> = support.iter().map(|&(_, v)| v).collect();
            let warm = pairwise_hinge_support(&ds, &pairs, &cols, &vals)
                + lambda * vals.iter().map(|v| v.abs()).sum::<f64>();
            let fresh =
                ranksvm_generation(&ds, &backend, &pairs, lambda, &[], &[], &params).objective;
            assert!(
                (warm - fresh).abs() / fresh.max(1e-9) < 1e-5,
                "λ={lambda}: warm {warm} fresh {fresh}"
            );
        }
    }
}
