//! The RankSVM comparison-pair abstraction: one canonical index space
//! over `P = {(i, k) : y_i > y_k}`, two interchangeable representations.
//!
//! RankSVM's constraint channel lives on the O(n²) comparison pairs, and
//! the paper's central claim — generation stays cheap because the
//! *restricted* LP is tiny — only survives at scale if pricing is
//! **sublinear in the implicit constraint set**. A materialized pair
//! list makes every pricing round (and every λ_max / hinge / seeding
//! helper) Ω(n²); this module replaces it with a [`PairSet`] built from
//! **one O(n log n) sort of the relevance scores**:
//!
//! * samples are sorted by `(y ascending, index ascending)` into
//!   `order`, with tie groups bucketed so repeated relevance levels
//!   produce no pairs among themselves;
//! * the losers of winner `i` are exactly the sorted prefix
//!   `order[..below(i)]`, where `below(i)` is the number of samples with
//!   strictly smaller relevance;
//! * the **canonical pair index** of `(i, k)` is
//!   `offset(i) + sorted_pos(k)` — winners ascending by sample index,
//!   losers ascending by sorted position. Both representations share
//!   this space, so working-set snapshots (and the serve layer's
//!   warm-start cache) are valid under either and survive switching
//!   between them.
//!
//! Operations and costs (`n` samples, `|P|` pairs, `K` the round cap):
//!
//! | operation | [`Enumerated`](PairSet::is_enumerated) | implicit |
//! |---|---|---|
//! | build | O(n log n + \|P\|) | O(n log n) |
//! | [`PairSet::pair`] | O(1) | O(log n) |
//! | [`PairSet::price`] | O(\|P\|) | O(n log n) |
//! | [`PairSet::hinge`] | O(\|P\|) | O(n log n) |
//! | [`PairSet::ones_dual`] | O(n) | O(n) |
//! | memory | 8 bytes/pair | O(n) |
//!
//! The pricing sweep finds, for every winner `i`, its most violated pair
//! `argmax_k 1 − (m_i − m_k)` — a running prefix maximum of the margins
//! in sorted order (equivalently a prefix *minimum* of `m_i − m_k`) —
//! and keeps the `K` most violated winner-best pairs overall. Pairs
//! already in the working set are excluded through an O(n)-build
//! leftmost-argmax tournament tree queried on the prefix minus the
//! excluded positions. The per-winner scan chunks across scoped worker
//! threads exactly like [`crate::backend::par_xtv`], and is bit-identical
//! at any thread count. See `docs/ranksvm-scaling.md` for the full
//! derivation and when enumeration still wins.

use std::collections::HashMap;

use crate::engine::PairMode;

/// Above this many candidate pairs, [`PairMode::Auto`] stops
/// materializing the list (2²¹ pairs ≈ 16 MB at 8 bytes/pair). The
/// first-order RankSVM seed uses the same threshold: the pairwise FISTA
/// iterates are Θ(|P|)-length vectors, so past it
/// [`crate::engine::Initializer`] falls back to closed-form screening.
pub const ENUM_PAIR_CAP: usize = 1 << 21;

/// Default cap on violated pairs returned per pricing round when
/// [`crate::engine::GenParams::max_rows_per_round`] is unset: the sweep
/// surfaces at most one pair per winner, and this keeps a cold large-n
/// solve from swallowing O(n) margin rows into the LP in one round.
pub const DEFAULT_PAIR_ROWS_PER_ROUND: usize = 256;

/// Below this many samples the pricing sweep stays serial — worker
/// spawn/join overhead would dominate the O(n) per-winner scan (the
/// same reasoning as `backend::PAR_MIN_WORK`).
const PAR_MIN_SAMPLES: usize = 4096;

/// `k` indices spread evenly over `0..n_items`: with `k` clamped into
/// `[1, n_items]`, returns `j·n_items/k` for `j = 0..k` — exactly `k`
/// strictly increasing indices whose largest gap is at most
/// `⌈n_items/k⌉` (empty only when `n_items = 0`). The old
/// `stride = n_items/k` walk clustered at the front, covering only the
/// first `k·⌊n_items/k⌋` items whenever `n_items` was not a multiple
/// of `k`.
pub fn spread_indices(n_items: usize, k: usize) -> Vec<usize> {
    if n_items == 0 {
        return Vec::new();
    }
    let k = k.min(n_items).max(1);
    (0..k).map(|j| j * n_items / k).collect()
}

/// The comparison-pair candidate set behind one canonical index space.
///
/// Construct with [`PairSet::build`]; the [`PairMode`] only selects the
/// *representation* — every index-space operation returns identical
/// results in either mode (pinned by the cross-representation tests).
pub struct PairSet {
    n: usize,
    total: usize,
    /// Sample indices sorted by `(y asc, index asc)`, NaN responses last.
    order: Vec<u32>,
    /// Inverse of `order`: sample index → sorted position.
    sorted_pos: Vec<u32>,
    /// Sample index → number of samples with strictly smaller `y`
    /// (= start of its tie group in `order`; 0 for NaN responses, which
    /// win and lose nothing — matching `y_i > y_k` being false for NaN).
    below: Vec<u32>,
    /// Sample index → end (exclusive) of its tie group in `order`
    /// (`n` for NaN responses).
    tie_hi: Vec<u32>,
    /// Number of rankable (non-NaN) samples: `order[..ranked]`.
    ranked: usize,
    /// `offset[i]..offset[i+1]` is winner `i`'s canonical index block.
    offset: Vec<usize>,
    /// The materialized list (canonical order) — `Some` iff enumerated.
    pairs: Option<Vec<(u32, u32)>>,
}

impl PairSet {
    /// Build the pair set over relevance scores `y`. `Auto` enumerates
    /// while `|P| ≤` [`ENUM_PAIR_CAP`] and goes implicit beyond.
    pub fn build(y: &[f64], mode: PairMode) -> PairSet {
        let mut ps = PairSet::scaffold(y);
        let enumerate = match mode {
            PairMode::Enumerate => true,
            PairMode::Implicit => false,
            PairMode::Auto => ps.total <= ENUM_PAIR_CAP,
        };
        if enumerate {
            ps.pairs = Some(ps.enumerate_list());
        }
        ps
    }

    /// The sorted-order scaffold every operation runs on (no pair list).
    /// NaN responses sort last and participate in no pair (the reference
    /// predicate `y_i > y_k` is false whenever either side is NaN), so
    /// garbage labels degrade to an empty candidate set instead of a
    /// panic — the serve layer turns that into a protocol error.
    fn scaffold(y: &[f64]) -> PairSet {
        let n = y.len();
        assert!(n < u32::MAX as usize, "sample count exceeds the pair index space");
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            let (ya, yb) = (y[a as usize], y[b as usize]);
            match (ya.is_nan(), yb.is_nan()) {
                (false, false) => ya.total_cmp(&yb).then(a.cmp(&b)),
                (true, true) => a.cmp(&b),
                (false, true) => std::cmp::Ordering::Less,
                (true, false) => std::cmp::Ordering::Greater,
            }
        });
        let ranked =
            order.iter().position(|&i| y[i as usize].is_nan()).unwrap_or(n);
        let mut below = vec![0u32; n];
        let mut tie_hi = vec![0u32; n];
        let mut sorted_pos = vec![0u32; n];
        let mut s = 0usize;
        while s < ranked {
            let mut e = s + 1;
            while e < ranked && y[order[e] as usize] == y[order[s] as usize] {
                e += 1;
            }
            for pos in s..e {
                let idx = order[pos] as usize;
                below[idx] = s as u32;
                tie_hi[idx] = e as u32;
                sorted_pos[idx] = pos as u32;
            }
            s = e;
        }
        for pos in ranked..n {
            let idx = order[pos] as usize;
            below[idx] = 0;
            tie_hi[idx] = n as u32;
            sorted_pos[idx] = pos as u32;
        }
        let mut offset = Vec::with_capacity(n + 1);
        offset.push(0usize);
        for i in 0..n {
            offset.push(offset[i] + below[i] as usize);
        }
        let total = offset[n];
        PairSet { n, total, order, sorted_pos, below, tie_hi, ranked, offset, pairs: None }
    }

    /// The canonical pair list: winners ascending by sample index,
    /// losers ascending by sorted position.
    fn enumerate_list(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.total);
        for i in 0..self.n {
            let b = self.below[i] as usize;
            for &k in &self.order[..b] {
                out.push((i as u32, k));
            }
        }
        out
    }

    /// Number of candidate pairs `|P|`.
    pub fn len(&self) -> usize {
        self.total
    }

    /// Whether the candidate set is empty (all responses tied).
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Number of samples `n`.
    pub fn n_samples(&self) -> usize {
        self.n
    }

    /// Whether the pair list is materialized.
    pub fn is_enumerated(&self) -> bool {
        self.pairs.is_some()
    }

    /// Estimated resident bytes: the four per-sample u32 index arrays,
    /// the `n+1` offset array, and (when enumerated) the materialized
    /// pair list. The same accounting convention as
    /// `Design::resident_bytes` — buffer payloads, not allocator
    /// overhead — so the serve layer's `stats` can report what a cached
    /// pair set costs to keep alive.
    pub fn resident_bytes(&self) -> usize {
        16 * self.n
            + 8 * self.offset.len()
            + self.pairs.as_ref().map_or(0, |p| 8 * p.len())
    }

    /// Representation name for logs and bench labels.
    pub fn mode(&self) -> &'static str {
        if self.pairs.is_some() {
            "enumerated"
        } else {
            "implicit"
        }
    }

    /// Winner of canonical pair `t` (the `i` with
    /// `offset[i] ≤ t < offset[i+1]`).
    fn winner_of(&self, t: usize) -> usize {
        debug_assert!(t < self.total, "pair index {t} out of range {}", self.total);
        self.offset.partition_point(|&o| o <= t) - 1
    }

    /// Canonical index of the pair `(i, k)`, or `None` when
    /// `y_i ≤ y_k` (not a candidate pair). O(1) in either
    /// representation: `offset(i) + sorted_pos(k)` — a loser's sorted
    /// position lies below the winner's tie-group start exactly when
    /// its relevance is strictly smaller.
    pub fn index_of(&self, i: usize, k: usize) -> Option<usize> {
        if self.sorted_pos[k] < self.below[i] {
            Some(self.offset[i] + self.sorted_pos[k] as usize)
        } else {
            None
        }
    }

    /// The `(winner, loser)` sample indices of canonical pair `t`.
    /// O(1) enumerated, O(log n) implicit.
    pub fn pair(&self, t: usize) -> (usize, usize) {
        if let Some(list) = &self.pairs {
            let (i, k) = list[t];
            return (i as usize, k as usize);
        }
        let i = self.winner_of(t);
        (i, self.order[t - self.offset[i]] as usize)
    }

    /// Stream every pair as `(canonical index, winner, loser)` in
    /// canonical order, without materializing a list. O(|P|) time,
    /// O(1) extra memory.
    pub fn for_each(&self, mut f: impl FnMut(usize, usize, usize)) {
        if let Some(list) = &self.pairs {
            for (t, &(i, k)) in list.iter().enumerate() {
                f(t, i as usize, k as usize);
            }
            return;
        }
        let mut t = 0usize;
        for i in 0..self.n {
            for r in 0..self.below[i] as usize {
                f(t, i, self.order[r] as usize);
                t += 1;
            }
        }
    }

    /// Materialize the canonical pair list as `(usize, usize)` tuples —
    /// for the independent full-LP baseline and tests only (O(|P|)
    /// memory by definition).
    pub fn materialize(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.total);
        self.for_each(|_, i, k| out.push((i, k)));
        out
    }

    /// `k` pair indices spread evenly over the canonical index space —
    /// the β = 0 seed, where every pair is equally violated and coverage
    /// beats scoring (see [`spread_indices`]).
    pub fn spread(&self, k: usize) -> Vec<usize> {
        spread_indices(self.total, k)
    }

    /// The all-ones-dual scatter `v_i = #{k : (i,k) ∈ P} − #{k : (k,i) ∈
    /// P}` = `below(i) − above(i)`, in O(n) — the vector behind λ_max and
    /// the initial feature scores (at β = 0 every dual is 1). Only the
    /// `ranked` (non-NaN) samples sit above anything.
    pub fn ones_dual(&self) -> Vec<f64> {
        (0..self.n)
            .map(|i| {
                self.below[i] as f64
                    - self.ranked.saturating_sub(self.tie_hi[i] as usize) as f64
            })
            .collect()
    }

    /// Content fingerprint of the canonical index space (FNV-1a over the
    /// sorted order and the tie structure). Identical for both
    /// representations of the same `y`, so warm-start snapshots keyed by
    /// it survive switching [`PairMode`].
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::rng::Fnv1a::new();
        h.eat(&(self.n as u64).to_le_bytes());
        h.eat(&(self.total as u64).to_le_bytes());
        for &p in &self.order {
            h.eat(&p.to_le_bytes());
        }
        for &b in &self.below {
            h.eat(&b.to_le_bytes());
        }
        h.finish()
    }

    /// Price the pair channel: for every winner `i`, the most violated
    /// non-excluded pair `(i, k*)` (`k* = argmax_k m_k` over the sorted
    /// prefix, leftmost on margin ties), keeping the `cap` most violated
    /// winner-best pairs overall, ordered `(violation desc, index asc)`.
    /// `cap = 0` keeps them all (still at most one per winner).
    ///
    /// `m` is the full margin vector `Xβ` (length n); `excluded` is the
    /// current working set P′ as **sorted ascending** canonical indices.
    /// Enumerated cost is O(|P|); implicit cost is O(n log n) with the
    /// per-winner scan chunked over `threads` scoped workers —
    /// bit-identical for any thread count, and identical between the two
    /// representations (the violation arithmetic is the same expression).
    pub fn price(
        &self,
        m: &[f64],
        eps: f64,
        excluded: &[usize],
        cap: usize,
        threads: usize,
    ) -> Vec<(usize, f64)> {
        debug_assert_eq!(m.len(), self.n);
        debug_assert!(
            excluded.windows(2).all(|w| w[0] < w[1]),
            "excluded pair indices must be sorted ascending"
        );
        let mut cands = match &self.pairs {
            Some(list) => winner_best_enumerated(list, m, eps, excluded),
            None => self.winner_best_implicit(m, eps, excluded, threads),
        };
        cands.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        if cap > 0 && cands.len() > cap {
            cands.truncate(cap);
        }
        cands
    }

    /// The implicit winner-best scan: prefix max of margins in sorted
    /// order for exclusion-free winners, tournament-tree interval argmax
    /// for the (few) winners with pairs already in P′.
    fn winner_best_implicit(
        &self,
        m: &[f64],
        eps: f64,
        excluded: &[usize],
        threads: usize,
    ) -> Vec<(usize, f64)> {
        let n = self.n;
        if self.total == 0 {
            return Vec::new();
        }
        // margins in sorted order + running prefix max (leftmost ties)
        let mm: Vec<f64> = self.order.iter().map(|&idx| m[idx as usize]).collect();
        let mut pmax: Vec<(f64, u32)> = Vec::with_capacity(n);
        let mut best = (f64::NEG_INFINITY, 0u32);
        for (pos, &v) in mm.iter().enumerate() {
            if v > best.0 {
                best = (v, pos as u32);
            }
            pmax.push(best);
        }
        // group the excluded pairs' loser positions by winner (sorted
        // input ⇒ each winner's positions arrive ascending)
        let mut excl: HashMap<usize, Vec<usize>> = HashMap::new();
        for &t in excluded {
            let i = self.winner_of(t);
            excl.entry(i).or_default().push(t - self.offset[i]);
        }
        let tree = if excl.is_empty() { None } else { Some(MaxTree::build(&mm)) };

        let run = |lo: usize, hi: usize| -> Vec<(usize, f64)> {
            let mut out = Vec::new();
            for i in lo..hi {
                let b = self.below[i] as usize;
                if b == 0 {
                    continue;
                }
                let hit = match excl.get(&i) {
                    None => {
                        let (val, pos) = pmax[b - 1];
                        Some((pos as usize, val))
                    }
                    Some(ex) => best_excluding(tree.as_ref().expect("tree built"), b, ex),
                };
                if let Some((pos, val)) = hit {
                    // the same expression the enumerated scan evaluates,
                    // so the two representations agree bitwise
                    let viol = 1.0 - (m[i] - val);
                    if viol > eps {
                        out.push((self.offset[i] + pos, viol));
                    }
                }
            }
            out
        };

        let t = threads.max(1).min(n);
        if t <= 1 || n < PAR_MIN_SAMPLES {
            return run(0, n);
        }
        let chunk = n.div_ceil(t);
        let parts: Vec<Vec<(usize, f64)>> = std::thread::scope(|scope| {
            let run = &run;
            let mut handles = Vec::with_capacity(t);
            for c in 0..t {
                let lo = c * chunk;
                let hi = ((c + 1) * chunk).min(n);
                if lo >= hi {
                    break;
                }
                handles.push(scope.spawn(move || run(lo, hi)));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("pair pricing worker panicked"))
                .collect()
        });
        parts.concat()
    }

    /// Total pairwise hinge `Σ_{(i,k)∈P} max(0, 1 − (m_i − m_k))` of a
    /// margin vector over ALL candidate pairs. Enumerated: one O(|P|)
    /// pass. Implicit: O(n log n) — walk the tie groups in ascending
    /// relevance, maintaining Fenwick count/sum trees over margin ranks;
    /// each winner reads the count `c` and sum `S` of inserted (strictly
    /// lower-relevance) margins above `m_i − 1`, contributing
    /// `S + c·(1 − m_i)`.
    pub fn hinge(&self, m: &[f64]) -> f64 {
        debug_assert_eq!(m.len(), self.n);
        if let Some(list) = &self.pairs {
            return list
                .iter()
                .map(|&(i, k)| (1.0 - (m[i as usize] - m[k as usize])).max(0.0))
                .sum();
        }
        let n = self.n;
        if self.total == 0 {
            return 0.0;
        }
        let mm: Vec<f64> = self.order.iter().map(|&idx| m[idx as usize]).collect();
        // margin ranks (ascending, ties by position)
        let mut by_margin: Vec<u32> = (0..n as u32).collect();
        by_margin.sort_unstable_by(|&a, &b| {
            mm[a as usize].total_cmp(&mm[b as usize]).then(a.cmp(&b))
        });
        let mut rank_of = vec![0u32; n];
        for (r, &pos) in by_margin.iter().enumerate() {
            rank_of[pos as usize] = r as u32;
        }
        let sorted_margins: Vec<f64> = by_margin.iter().map(|&p| mm[p as usize]).collect();
        // Fenwick trees indexed by DESCENDING margin rank, so "margins
        // above a threshold" is a pure prefix sum (no cancellation).
        let mut cnt = Fenwick::new(n);
        let mut sum = Fenwick::new(n);
        let mut acc = 0.0;
        let mut s = 0usize;
        while s < n {
            let e = self.tie_hi[self.order[s] as usize] as usize;
            if s > 0 {
                for &idx in &self.order[s..e] {
                    if self.below[idx as usize] == 0 {
                        continue; // NaN bucket: wins nothing
                    }
                    let mi = m[idx as usize];
                    let theta = mi - 1.0;
                    // first ascending rank with margin strictly above θ
                    let lo = sorted_margins.partition_point(|&v| v <= theta);
                    if lo < n {
                        let len = n - lo; // descending ranks 0..len
                        let c = cnt.prefix(len);
                        let sm = sum.prefix(len);
                        acc += sm + c * (1.0 - mi);
                    }
                }
            }
            for pos in s..e {
                let desc = n - 1 - rank_of[pos] as usize;
                cnt.add(desc, 1.0);
                sum.add(desc, mm[pos]);
            }
            s = e;
        }
        acc
    }
}

/// Winner-best scan over the materialized list: the canonical order is
/// winner-ascending, so one pass with a running per-winner best (strict
/// `>` keeps the first — i.e. leftmost sorted position — on ties)
/// suffices. Kept independent of the implicit sweep so the two act as
/// cross-checks of each other.
fn winner_best_enumerated(
    list: &[(u32, u32)],
    m: &[f64],
    eps: f64,
    excluded: &[usize],
) -> Vec<(usize, f64)> {
    let mut out = Vec::new();
    let mut ex = excluded.iter().peekable();
    let mut cur: Option<(u32, usize, f64)> = None; // (winner, t, viol)
    for (t, &(i, k)) in list.iter().enumerate() {
        if ex.peek() == Some(&&t) {
            ex.next();
            continue;
        }
        let viol = 1.0 - (m[i as usize] - m[k as usize]);
        match cur {
            Some((w, _, bv)) if w == i => {
                if viol > bv {
                    cur = Some((i, t, viol));
                }
            }
            Some((_, bt, bv)) => {
                if bv > eps {
                    out.push((bt, bv));
                }
                cur = Some((i, t, viol));
            }
            None => cur = Some((i, t, viol)),
        }
    }
    if let Some((_, bt, bv)) = cur {
        if bv > eps {
            out.push((bt, bv));
        }
    }
    out
}

/// Max over `[0, b)` minus the excluded positions `ex` (sorted
/// ascending, all `< b`): the union of at most `|ex| + 1` intervals,
/// each one tournament-tree query. Leftmost position on value ties.
fn best_excluding(tree: &MaxTree, b: usize, ex: &[usize]) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    let mut lo = 0usize;
    for &e in ex {
        if e >= b {
            break;
        }
        take_better(tree, lo, e, &mut best);
        lo = e + 1;
    }
    take_better(tree, lo, b, &mut best);
    best
}

fn take_better(tree: &MaxTree, l: usize, r: usize, best: &mut Option<(usize, f64)>) {
    if l >= r {
        return;
    }
    if let Some((val, pos)) = tree.query(l, r) {
        let replace = match *best {
            None => true,
            Some((bp, bv)) => val > bv || (val == bv && pos < bp),
        };
        if replace {
            *best = Some((pos, val));
        }
    }
}

/// A static leftmost-argmax tournament tree over a fixed f64 array
/// (O(n) build, O(log n) range queries) — resolves the pricing sweep's
/// per-winner best loser when some prefix positions are excluded.
struct MaxTree {
    size: usize,
    val: Vec<f64>,
    pos: Vec<u32>,
}

impl MaxTree {
    fn build(m: &[f64]) -> MaxTree {
        let size = m.len().next_power_of_two().max(1);
        let mut val = vec![f64::NEG_INFINITY; 2 * size];
        let mut pos = vec![u32::MAX; 2 * size];
        for (i, &v) in m.iter().enumerate() {
            val[size + i] = v;
            pos[size + i] = i as u32;
        }
        for i in (1..size).rev() {
            // `>=` keeps the left child on ties ⇒ stored pos is the
            // leftmost argmax of the node's segment
            if val[2 * i] >= val[2 * i + 1] {
                val[i] = val[2 * i];
                pos[i] = pos[2 * i];
            } else {
                val[i] = val[2 * i + 1];
                pos[i] = pos[2 * i + 1];
            }
        }
        MaxTree { size, val, pos }
    }

    /// `(max value, leftmost argmax)` over `[l, r)`.
    fn query(&self, mut l: usize, mut r: usize) -> Option<(f64, usize)> {
        if l >= r {
            return None;
        }
        let mut best = (f64::NEG_INFINITY, u32::MAX);
        l += self.size;
        r += self.size;
        while l < r {
            if l & 1 == 1 {
                best = better(best, (self.val[l], self.pos[l]));
                l += 1;
            }
            if r & 1 == 1 {
                r -= 1;
                best = better(best, (self.val[r], self.pos[r]));
            }
            l >>= 1;
            r >>= 1;
        }
        Some((best.0, best.1 as usize))
    }
}

/// Larger value wins; smaller position breaks exact ties.
fn better(a: (f64, u32), b: (f64, u32)) -> (f64, u32) {
    if b.0 > a.0 || (b.0 == a.0 && b.1 < a.1) {
        b
    } else {
        a
    }
}

/// A Fenwick (binary indexed) tree of f64 prefix sums — the hinge
/// accumulator. Deterministic accumulation order regardless of callers'
/// threading (it is only ever driven serially).
struct Fenwick {
    tree: Vec<f64>,
}

impl Fenwick {
    fn new(n: usize) -> Fenwick {
        Fenwick { tree: vec![0.0; n + 1] }
    }

    fn add(&mut self, i: usize, v: f64) {
        let mut i = i + 1;
        while i < self.tree.len() {
            self.tree[i] += v;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum over `[0, i)`.
    fn prefix(&self, i: usize) -> f64 {
        let mut i = i.min(self.tree.len() - 1);
        let mut s = 0.0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::workloads::ranksvm::ranking_pairs;

    /// y with repeated levels, margins pseudo-random — the tie-heavy
    /// instance the cross-checks run on.
    fn tied_instance(n: usize, levels: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let y: Vec<f64> = (0..n).map(|_| (rng.uniform() * levels as f64).floor()).collect();
        let m: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (y, m)
    }

    /// Independent brute-force winner-best pricing off the reference
    /// enumeration.
    fn brute_force_price(y: &[f64], m: &[f64], eps: f64, excluded: &[usize]) -> Vec<(usize, f64)> {
        let list = ranking_pairs(y);
        let mut best: HashMap<usize, (usize, f64)> = HashMap::new();
        for (t, &(i, k)) in list.iter().enumerate() {
            if excluded.binary_search(&t).is_ok() {
                continue;
            }
            let viol = 1.0 - (m[i] - m[k]);
            match best.get(&i) {
                Some(&(_, bv)) if viol <= bv => {}
                _ => {
                    best.insert(i, (t, viol));
                }
            }
        }
        let mut out: Vec<(usize, f64)> =
            best.into_values().filter(|&(_, v)| v > eps).collect();
        out.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        out
    }

    #[test]
    fn canonical_enumeration_matches_reference() {
        for (n, levels, seed) in [(1usize, 1usize, 1u64), (17, 3, 2), (40, 7, 3), (25, 25, 4)] {
            let (y, _) = tied_instance(n, levels, seed);
            let e = PairSet::build(&y, PairMode::Enumerate);
            let i = PairSet::build(&y, PairMode::Implicit);
            let reference = ranking_pairs(&y);
            assert_eq!(e.materialize(), reference, "enumerated list");
            assert_eq!(i.materialize(), reference, "implicit streaming");
            assert_eq!(e.len(), reference.len());
            assert_eq!(i.len(), reference.len());
            for (t, &want) in reference.iter().enumerate() {
                assert_eq!(e.pair(t), want, "enumerated pair({t})");
                assert_eq!(i.pair(t), want, "implicit pair({t})");
                assert_eq!(e.index_of(want.0, want.1), Some(t), "index_of roundtrip");
                assert_eq!(i.index_of(want.0, want.1), Some(t));
                assert_eq!(i.index_of(want.1, want.0), None, "reversed pair is no candidate");
            }
        }
    }

    #[test]
    fn auto_mode_switches_on_the_pair_count() {
        let (y, _) = tied_instance(30, 5, 9);
        assert!(PairSet::build(&y, PairMode::Auto).is_enumerated(), "small |P| enumerates");
        assert!(!PairSet::build(&y, PairMode::Implicit).is_enumerated());
        assert_eq!(PairSet::build(&y, PairMode::Implicit).mode(), "implicit");
    }

    #[test]
    fn all_tied_responses_give_an_empty_set() {
        let y = vec![2.0; 12];
        for mode in [PairMode::Enumerate, PairMode::Implicit] {
            let ps = PairSet::build(&y, mode);
            assert!(ps.is_empty());
            assert!(ps.spread(5).is_empty());
            assert!(ps.price(&[0.0; 12], 0.0, &[], 0, 1).is_empty());
            assert_eq!(ps.hinge(&[0.0; 12]), 0.0);
            assert!(ps.ones_dual().iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn nan_responses_join_no_pair_and_never_panic() {
        // a NaN label (parseable from a libsvm file) must degrade
        // gracefully — the serve layer's never-panics contract depends
        // on it — and match the reference predicate, where y_i > y_k is
        // false whenever either side is NaN
        let y = [2.0, f64::NAN, 1.0, 2.0, f64::NAN, 3.0];
        let reference = ranking_pairs(&y);
        assert!(reference.iter().all(|&(i, k)| i != 1 && k != 1 && i != 4 && k != 4));
        for mode in [PairMode::Enumerate, PairMode::Implicit] {
            let ps = PairSet::build(&y, mode);
            assert_eq!(ps.materialize(), reference, "{mode:?}");
            assert_eq!(ps.index_of(5, 1), None, "NaN never loses");
            assert_eq!(ps.index_of(1, 2), None, "NaN never wins");
            let m = [0.5, -1.0, 0.25, 0.0, 2.0, -0.75];
            let priced = ps.price(&m, f64::NEG_INFINITY, &[], 0, 1);
            for &(t, _) in &priced {
                let (i, k) = ps.pair(t);
                assert!(i != 1 && i != 4 && k != 1 && k != 4);
            }
            // hinge over the same margins matches the reference sum
            let want: f64 =
                reference.iter().map(|&(i, k)| (1.0 - (m[i] - m[k])).max(0.0)).sum();
            assert!((ps.hinge(&m) - want).abs() < 1e-12, "{mode:?} hinge");
            // the all-ones dual only counts rankable samples
            let mut dual = vec![0.0; y.len()];
            for &(i, k) in &reference {
                dual[i] += 1.0;
                dual[k] -= 1.0;
            }
            assert_eq!(ps.ones_dual(), dual, "{mode:?} ones_dual");
        }
        // all-NaN responses: an empty candidate set, not a crash
        let all_nan = [f64::NAN; 4];
        assert!(PairSet::build(&all_nan, PairMode::Implicit).is_empty());
    }

    #[test]
    fn ones_dual_matches_the_pair_scatter() {
        let (y, _) = tied_instance(35, 6, 11);
        let ps = PairSet::build(&y, PairMode::Implicit);
        let mut want = vec![0.0; y.len()];
        for (i, k) in ranking_pairs(&y) {
            want[i] += 1.0;
            want[k] -= 1.0;
        }
        assert_eq!(ps.ones_dual(), want);
    }

    #[test]
    fn spread_indices_fill_the_budget_and_cover_the_tail() {
        // the regression: n barely above k used to cluster at the front
        let s = spread_indices(29, 10);
        assert_eq!(s.len(), 10, "must return exactly k indices");
        assert!(s.windows(2).all(|w| w[0] < w[1]), "strictly increasing");
        assert!(*s.last().unwrap() >= 29 - 3, "tail must be covered: {s:?}");
        for (a, b) in s.iter().zip(s.iter().skip(1)) {
            assert!(b - a <= 3, "gap {a}..{b} exceeds ceil(29/10)");
        }
        assert_eq!(spread_indices(10, 4), vec![0, 2, 5, 7]);
        assert_eq!(spread_indices(3, 10), vec![0, 1, 2], "k clamps to n");
        assert!(spread_indices(0, 5).is_empty());
        assert_eq!(spread_indices(7, 0), vec![0], "k clamps up to 1");
    }

    #[test]
    fn price_agrees_across_representations_and_brute_force() {
        for seed in [21u64, 22, 23, 24, 25] {
            let (y, m) = tied_instance(60, 4 + (seed as usize % 5), seed);
            let e = PairSet::build(&y, PairMode::Enumerate);
            let i = PairSet::build(&y, PairMode::Implicit);
            assert_eq!(e.fingerprint(), i.fingerprint());
            if e.is_empty() {
                continue;
            }
            // exclude a spread of pairs plus a dense run inside one winner
            let mut excluded = e.spread(15);
            excluded.extend((0..e.len().min(6)).skip(1));
            excluded.sort_unstable();
            excluded.dedup();
            for eps in [0.0, 0.3] {
                for cap in [0usize, 3, 7] {
                    let a = e.price(&m, eps, &excluded, cap, 1);
                    let b = i.price(&m, eps, &excluded, cap, 1);
                    assert_eq!(a, b, "seed {seed} eps {eps} cap {cap}");
                    if cap == 0 {
                        let brute = brute_force_price(&y, &m, eps, &excluded);
                        assert_eq!(a, brute, "brute force, seed {seed} eps {eps}");
                    }
                }
            }
        }
    }

    #[test]
    fn price_excludes_every_working_set_pair() {
        let (y, m) = tied_instance(30, 3, 31);
        let ps = PairSet::build(&y, PairMode::Implicit);
        // excluding a winner's whole block must silence that winner
        let (w, _) = ps.pair(0);
        let block: Vec<usize> = (ps.offset[w]..ps.offset[w + 1]).collect();
        let priced = ps.price(&m, f64::NEG_INFINITY, &block, 0, 1);
        for &(t, _) in &priced {
            assert!(!block.contains(&t), "excluded pair {t} still priced");
            assert_ne!(ps.pair(t).0, w, "silenced winner resurfaced");
        }
    }

    #[test]
    fn implicit_price_is_thread_independent() {
        // n above the spawn gate so workers actually run
        let (y, m) = tied_instance(6000, 97, 41);
        let ps = PairSet::build(&y, PairMode::Implicit);
        assert!(ps.n_samples() >= PAR_MIN_SAMPLES);
        let excluded = ps.spread(48);
        let serial = ps.price(&m, 0.0, &excluded, 0, 1);
        assert!(!serial.is_empty());
        for t in [2usize, 4, 7] {
            assert_eq!(ps.price(&m, 0.0, &excluded, 0, t), serial, "{t} threads diverged");
        }
        // the cap keeps the most-violated prefix of the same ordering
        let capped = ps.price(&m, 0.0, &excluded, 50, 4);
        assert_eq!(capped.as_slice(), &serial[..50]);
    }

    #[test]
    fn hinge_matches_the_enumerated_sum() {
        for seed in [51u64, 52, 53] {
            let (y, m) = tied_instance(80, 6, seed);
            let e = PairSet::build(&y, PairMode::Enumerate);
            let i = PairSet::build(&y, PairMode::Implicit);
            let he = e.hinge(&m);
            let hi = i.hinge(&m);
            assert!(
                (he - hi).abs() <= 1e-8 * he.abs().max(1.0),
                "seed {seed}: enumerated {he} implicit {hi}"
            );
            // β = 0 ⇒ every pair contributes exactly 1
            let zeros = vec![0.0; y.len()];
            assert_eq!(e.hinge(&zeros), e.len() as f64);
            assert!((i.hinge(&zeros) - i.len() as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn fingerprint_tracks_the_index_space() {
        let (ya, _) = tied_instance(25, 4, 61);
        let (yb, _) = tied_instance(25, 4, 62);
        let a = PairSet::build(&ya, PairMode::Enumerate);
        let b = PairSet::build(&yb, PairMode::Enumerate);
        assert_ne!(a.fingerprint(), b.fingerprint(), "different y, different print");
        let a2 = PairSet::build(&ya, PairMode::Implicit);
        assert_eq!(
            a.fingerprint(),
            a2.fingerprint(),
            "the fingerprint is representation-independent"
        );
    }

    #[test]
    fn max_tree_finds_leftmost_argmax() {
        let m = [1.0, 5.0, 5.0, 2.0, 5.0, -1.0];
        let tree = MaxTree::build(&m);
        assert_eq!(tree.query(0, 6), Some((5.0, 1)));
        assert_eq!(tree.query(2, 6), Some((5.0, 2)));
        assert_eq!(tree.query(3, 6), Some((5.0, 4)));
        assert_eq!(tree.query(3, 4), Some((2.0, 3)));
        assert_eq!(tree.query(3, 3), None);
        assert_eq!(best_excluding(&tree, 6, &[1, 2]), Some((4, 5.0)));
        assert_eq!(best_excluding(&tree, 3, &[1, 2]), Some((0, 1.0)));
        assert_eq!(best_excluding(&tree, 1, &[0]), None);
    }
}
