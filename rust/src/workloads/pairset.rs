//! The RankSVM comparison-pair abstraction: one canonical index space
//! over `P = {(i, k) : y_i > y_k}`, two interchangeable representations.
//!
//! RankSVM's constraint channel lives on the O(n²) comparison pairs, and
//! the paper's central claim — generation stays cheap because the
//! *restricted* LP is tiny — only survives at scale if pricing is
//! **sublinear in the implicit constraint set**. A materialized pair
//! list makes every pricing round (and every λ_max / hinge / seeding
//! helper) Ω(n²); this module replaces it with a [`PairSet`] built from
//! **one O(n log n) sort of the relevance scores**:
//!
//! * samples are sorted by `(y ascending, index ascending)` into
//!   `order`, with tie groups bucketed so repeated relevance levels
//!   produce no pairs among themselves;
//! * the losers of winner `i` are exactly the sorted prefix
//!   `order[..below(i)]`, where `below(i)` is the number of samples with
//!   strictly smaller relevance;
//! * the **canonical pair index** of `(i, k)` is
//!   `offset(i) + sorted_pos(k)` — winners ascending by sample index,
//!   losers ascending by sorted position. Both representations share
//!   this space, so working-set snapshots (and the serve layer's
//!   warm-start cache) are valid under either and survive switching
//!   between them.
//!
//! Operations and costs (`n` samples, `|P|` pairs, `K` the round cap):
//!
//! | operation | [`Enumerated`](PairSet::is_enumerated) | implicit |
//! |---|---|---|
//! | build | O(n log n + \|P\|) | O(n log n) |
//! | [`PairSet::pair`] | O(1) | O(log n) |
//! | [`PairSet::price`] | O(\|P\|) | O(n log n) |
//! | [`PairSet::hinge`] | O(\|P\|) | O(n log n) |
//! | [`PairSet::ones_dual`] | O(n) | O(n) |
//! | memory | 8 bytes/pair | O(n) |
//!
//! The pricing sweep finds, for every winner `i`, its most violated pair
//! `argmax_k 1 − (m_i − m_k)` — a running prefix maximum of the margins
//! in sorted order (equivalently a prefix *minimum* of `m_i − m_k`) —
//! and keeps the `K` most violated winner-best pairs overall. Pairs
//! already in the working set are excluded through an O(n)-build
//! leftmost-argmax tournament tree queried on the prefix minus the
//! excluded positions. The per-winner scan chunks across scoped worker
//! threads exactly like [`crate::backend::par_xtv`], and is bit-identical
//! at any thread count. See `docs/ranksvm-scaling.md` for the full
//! derivation and when enumeration still wins.
//!
//! **Weighted, gapped pairs.** [`PairCosts`] attaches a margin gap `g_t`
//! and a positive weight `w_t` to every candidate pair, turning the
//! hinge into `w_t·max(0, g_t − (m_i − m_k))` (rank2plan's extension of
//! the paper's uniform `g = w = 1`). Pricing stays sublinear whenever
//! the costs are constant per *relevance-level pair*
//! ([`PairCosts::Bucketed`]): the prefix-max sweep generalizes to one
//! per-level-bucket max — O(n·L) for L levels — because within a bucket
//! the violation is a fixed increasing function of the loser margin.
//! Arbitrary per-pair costs break that monotone structure, so
//! [`PairCosts::PerPair`] falls back to an O(|P|) enumeration of the
//! candidate space; [`PairScan`] names which scan ran (surfaced in
//! [`crate::engine::GenStats::pair_scan`]). Uniform costs route through
//! the original code paths and are **bitwise identical** to the
//! unweighted implementation.

use std::collections::HashMap;

use crate::engine::PairMode;

/// Above this many candidate pairs, [`PairMode::Auto`] stops
/// materializing the list (2²¹ pairs ≈ 16 MB at 8 bytes/pair). The
/// first-order RankSVM seed uses the same threshold: the pairwise FISTA
/// iterates are Θ(|P|)-length vectors, so past it
/// [`crate::engine::Initializer`] falls back to closed-form screening.
pub const ENUM_PAIR_CAP: usize = 1 << 21;

/// Default cap on violated pairs returned per pricing round when
/// [`crate::engine::GenParams::max_rows_per_round`] is unset: the sweep
/// surfaces at most one pair per winner, and this keeps a cold large-n
/// solve from swallowing O(n) margin rows into the LP in one round.
pub const DEFAULT_PAIR_ROWS_PER_ROUND: usize = 256;

/// Below this many samples the pricing sweep stays serial — worker
/// spawn/join overhead would dominate the O(n) per-winner scan (the
/// same reasoning as `backend::PAR_MIN_WORK`).
const PAR_MIN_SAMPLES: usize = 4096;

/// `k` indices spread evenly over `0..n_items`: with `k` clamped into
/// `[1, n_items]`, returns `j·n_items/k` for `j = 0..k` — exactly `k`
/// strictly increasing indices whose largest gap is at most
/// `⌈n_items/k⌉` (empty only when `n_items = 0`). The old
/// `stride = n_items/k` walk clustered at the front, covering only the
/// first `k·⌊n_items/k⌋` items whenever `n_items` was not a multiple
/// of `k`.
pub fn spread_indices(n_items: usize, k: usize) -> Vec<usize> {
    if n_items == 0 {
        return Vec::new();
    }
    let k = k.min(n_items).max(1);
    (0..k).map(|j| j * n_items / k).collect()
}

/// The comparison-pair candidate set behind one canonical index space.
///
/// Construct with [`PairSet::build`]; the [`PairMode`] only selects the
/// *representation* — every index-space operation returns identical
/// results in either mode (pinned by the cross-representation tests).
pub struct PairSet {
    n: usize,
    total: usize,
    /// Sample indices sorted by `(y asc, index asc)`, NaN responses last.
    order: Vec<u32>,
    /// Inverse of `order`: sample index → sorted position.
    sorted_pos: Vec<u32>,
    /// Sample index → number of samples with strictly smaller `y`
    /// (= start of its tie group in `order`; 0 for NaN responses, which
    /// win and lose nothing — matching `y_i > y_k` being false for NaN).
    below: Vec<u32>,
    /// Sample index → end (exclusive) of its tie group in `order`
    /// (`n` for NaN responses).
    tie_hi: Vec<u32>,
    /// Number of rankable (non-NaN) samples: `order[..ranked]`.
    ranked: usize,
    /// Sample index → relevance-level id (tie groups numbered ascending
    /// by `y`); `u32::MAX` for NaN responses, which sit in no level.
    level_of: Vec<u32>,
    /// Start position in `order` of each level's tie group plus the
    /// `ranked` end sentinel: `level_lo[l]..level_lo[l+1]` is level `l`.
    level_lo: Vec<usize>,
    /// `offset[i]..offset[i+1]` is winner `i`'s canonical index block.
    offset: Vec<usize>,
    /// The materialized list (canonical order) — `Some` iff enumerated.
    pairs: Option<Vec<(u32, u32)>>,
}

impl PairSet {
    /// Build the pair set over relevance scores `y`. `Auto` enumerates
    /// while `|P| ≤` [`ENUM_PAIR_CAP`] and goes implicit beyond.
    pub fn build(y: &[f64], mode: PairMode) -> PairSet {
        let mut ps = PairSet::scaffold(y);
        let enumerate = match mode {
            PairMode::Enumerate => true,
            PairMode::Implicit => false,
            PairMode::Auto => ps.total <= ENUM_PAIR_CAP,
        };
        if enumerate {
            ps.pairs = Some(ps.enumerate_list());
        }
        ps
    }

    /// The sorted-order scaffold every operation runs on (no pair list).
    /// NaN responses sort last and participate in no pair (the reference
    /// predicate `y_i > y_k` is false whenever either side is NaN), so
    /// garbage labels degrade to an empty candidate set instead of a
    /// panic — the serve layer turns that into a protocol error.
    fn scaffold(y: &[f64]) -> PairSet {
        let n = y.len();
        assert!(n < u32::MAX as usize, "sample count exceeds the pair index space");
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            let (ya, yb) = (y[a as usize], y[b as usize]);
            match (ya.is_nan(), yb.is_nan()) {
                (false, false) => ya.total_cmp(&yb).then(a.cmp(&b)),
                (true, true) => a.cmp(&b),
                (false, true) => std::cmp::Ordering::Less,
                (true, false) => std::cmp::Ordering::Greater,
            }
        });
        let ranked =
            order.iter().position(|&i| y[i as usize].is_nan()).unwrap_or(n);
        let mut below = vec![0u32; n];
        let mut tie_hi = vec![0u32; n];
        let mut sorted_pos = vec![0u32; n];
        let mut level_of = vec![u32::MAX; n];
        let mut level_lo = Vec::new();
        let mut s = 0usize;
        while s < ranked {
            let mut e = s + 1;
            while e < ranked && y[order[e] as usize] == y[order[s] as usize] {
                e += 1;
            }
            let lvl = level_lo.len() as u32;
            level_lo.push(s);
            for pos in s..e {
                let idx = order[pos] as usize;
                below[idx] = s as u32;
                tie_hi[idx] = e as u32;
                sorted_pos[idx] = pos as u32;
                level_of[idx] = lvl;
            }
            s = e;
        }
        level_lo.push(ranked);
        for pos in ranked..n {
            let idx = order[pos] as usize;
            below[idx] = 0;
            tie_hi[idx] = n as u32;
            sorted_pos[idx] = pos as u32;
        }
        let mut offset = Vec::with_capacity(n + 1);
        offset.push(0usize);
        for i in 0..n {
            offset.push(offset[i] + below[i] as usize);
        }
        let total = offset[n];
        PairSet {
            n,
            total,
            order,
            sorted_pos,
            below,
            tie_hi,
            ranked,
            level_of,
            level_lo,
            offset,
            pairs: None,
        }
    }

    /// The canonical pair list: winners ascending by sample index,
    /// losers ascending by sorted position.
    fn enumerate_list(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.total);
        for i in 0..self.n {
            let b = self.below[i] as usize;
            for &k in &self.order[..b] {
                out.push((i as u32, k));
            }
        }
        out
    }

    /// Number of candidate pairs `|P|`.
    pub fn len(&self) -> usize {
        self.total
    }

    /// Whether the candidate set is empty (all responses tied).
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Number of samples `n`.
    pub fn n_samples(&self) -> usize {
        self.n
    }

    /// Number of distinct (finite) relevance levels `L`. Pairs exist
    /// only between different levels, so `L ≤ 1` ⇔ the set is empty.
    pub fn n_levels(&self) -> usize {
        self.level_lo.len() - 1
    }

    /// The relevance-level id of sample `i` (levels numbered ascending
    /// by `y`), or `None` for a NaN response.
    pub fn level_of(&self, i: usize) -> Option<usize> {
        (self.level_of[i] != u32::MAX).then_some(self.level_of[i] as usize)
    }

    /// Level tie-group bounds in the sorted order: `level_bounds()[l] ..
    /// level_bounds()[l+1]` are the sorted positions of level `l`
    /// (length [`Self::n_levels`] + 1; the last entry is the count of
    /// rankable samples).
    pub fn level_bounds(&self) -> &[usize] {
        &self.level_lo
    }

    /// The samples in `(y asc, index asc)` sorted order (NaN responses
    /// last) — the order [`Self::level_bounds`] indexes into.
    pub fn sorted_order(&self) -> &[u32] {
        &self.order
    }

    /// Whether the pair list is materialized.
    pub fn is_enumerated(&self) -> bool {
        self.pairs.is_some()
    }

    /// Estimated resident bytes: the four per-sample u32 index arrays,
    /// the `n+1` offset array, and (when enumerated) the materialized
    /// pair list. The same accounting convention as
    /// `Design::resident_bytes` — buffer payloads, not allocator
    /// overhead — so the serve layer's `stats` can report what a cached
    /// pair set costs to keep alive.
    pub fn resident_bytes(&self) -> usize {
        16 * self.n
            + 8 * self.offset.len()
            + self.pairs.as_ref().map_or(0, |p| 8 * p.len())
    }

    /// Representation name for logs and bench labels.
    pub fn mode(&self) -> &'static str {
        if self.pairs.is_some() {
            "enumerated"
        } else {
            "implicit"
        }
    }

    /// Winner of canonical pair `t` (the `i` with
    /// `offset[i] ≤ t < offset[i+1]`).
    fn winner_of(&self, t: usize) -> usize {
        debug_assert!(t < self.total, "pair index {t} out of range {}", self.total);
        self.offset.partition_point(|&o| o <= t) - 1
    }

    /// Canonical index of the pair `(i, k)`, or `None` when
    /// `y_i ≤ y_k` (not a candidate pair). O(1) in either
    /// representation: `offset(i) + sorted_pos(k)` — a loser's sorted
    /// position lies below the winner's tie-group start exactly when
    /// its relevance is strictly smaller.
    pub fn index_of(&self, i: usize, k: usize) -> Option<usize> {
        if self.sorted_pos[k] < self.below[i] {
            Some(self.offset[i] + self.sorted_pos[k] as usize)
        } else {
            None
        }
    }

    /// The `(winner, loser)` sample indices of canonical pair `t`.
    /// O(1) enumerated, O(log n) implicit.
    pub fn pair(&self, t: usize) -> (usize, usize) {
        if let Some(list) = &self.pairs {
            let (i, k) = list[t];
            return (i as usize, k as usize);
        }
        let i = self.winner_of(t);
        (i, self.order[t - self.offset[i]] as usize)
    }

    /// Stream every pair as `(canonical index, winner, loser)` in
    /// canonical order, without materializing a list. O(|P|) time,
    /// O(1) extra memory.
    pub fn for_each(&self, mut f: impl FnMut(usize, usize, usize)) {
        if let Some(list) = &self.pairs {
            for (t, &(i, k)) in list.iter().enumerate() {
                f(t, i as usize, k as usize);
            }
            return;
        }
        let mut t = 0usize;
        for i in 0..self.n {
            for r in 0..self.below[i] as usize {
                f(t, i, self.order[r] as usize);
                t += 1;
            }
        }
    }

    /// Materialize the canonical pair list as `(usize, usize)` tuples —
    /// for the independent full-LP baseline and tests only (O(|P|)
    /// memory by definition).
    pub fn materialize(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.total);
        self.for_each(|_, i, k| out.push((i, k)));
        out
    }

    /// `k` pair indices spread evenly over the canonical index space —
    /// the β = 0 seed, where every pair is equally violated and coverage
    /// beats scoring (see [`spread_indices`]).
    pub fn spread(&self, k: usize) -> Vec<usize> {
        spread_indices(self.total, k)
    }

    /// The all-ones-dual scatter `v_i = #{k : (i,k) ∈ P} − #{k : (k,i) ∈
    /// P}` = `below(i) − above(i)`, in O(n) — the vector behind λ_max and
    /// the initial feature scores (at β = 0 every dual is 1). Only the
    /// `ranked` (non-NaN) samples sit above anything.
    pub fn ones_dual(&self) -> Vec<f64> {
        (0..self.n)
            .map(|i| {
                self.below[i] as f64
                    - self.ranked.saturating_sub(self.tie_hi[i] as usize) as f64
            })
            .collect()
    }

    /// Content fingerprint of the canonical index space (FNV-1a over the
    /// sorted order and the tie structure). Identical for both
    /// representations of the same `y`, so warm-start snapshots keyed by
    /// it survive switching [`PairMode`].
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::rng::Fnv1a::new();
        h.eat(&(self.n as u64).to_le_bytes());
        h.eat(&(self.total as u64).to_le_bytes());
        for &p in &self.order {
            h.eat(&p.to_le_bytes());
        }
        for &b in &self.below {
            h.eat(&b.to_le_bytes());
        }
        h.finish()
    }

    /// Price the pair channel: for every winner `i`, the most violated
    /// non-excluded pair `(i, k*)` (`k* = argmax_k m_k` over the sorted
    /// prefix, leftmost on margin ties), keeping the `cap` most violated
    /// winner-best pairs overall, ordered `(violation desc, index asc)`.
    /// `cap = 0` keeps them all (still at most one per winner).
    ///
    /// `m` is the full margin vector `Xβ` (length n); `excluded` is the
    /// current working set P′ as **sorted ascending** canonical indices.
    /// Enumerated cost is O(|P|); implicit cost is O(n log n) with the
    /// per-winner scan chunked over `threads` scoped workers —
    /// bit-identical for any thread count, and identical between the two
    /// representations (the violation arithmetic is the same expression).
    pub fn price(
        &self,
        m: &[f64],
        eps: f64,
        excluded: &[usize],
        cap: usize,
        threads: usize,
    ) -> Vec<(usize, f64)> {
        debug_assert_eq!(m.len(), self.n);
        debug_assert!(
            excluded.windows(2).all(|w| w[0] < w[1]),
            "excluded pair indices must be sorted ascending"
        );
        let mut cands = match &self.pairs {
            Some(list) => winner_best_enumerated(list, m, eps, excluded),
            None => self.winner_best_implicit(m, eps, excluded, threads),
        };
        cands.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        if cap > 0 && cands.len() > cap {
            cands.truncate(cap);
        }
        cands
    }

    /// The implicit winner-best scan: prefix max of margins in sorted
    /// order for exclusion-free winners, tournament-tree interval argmax
    /// for the (few) winners with pairs already in P′.
    fn winner_best_implicit(
        &self,
        m: &[f64],
        eps: f64,
        excluded: &[usize],
        threads: usize,
    ) -> Vec<(usize, f64)> {
        let n = self.n;
        if self.total == 0 {
            return Vec::new();
        }
        // margins in sorted order + running prefix max (leftmost ties)
        let mm: Vec<f64> = self.order.iter().map(|&idx| m[idx as usize]).collect();
        let mut pmax: Vec<(f64, u32)> = Vec::with_capacity(n);
        let mut best = (f64::NEG_INFINITY, 0u32);
        for (pos, &v) in mm.iter().enumerate() {
            if v > best.0 {
                best = (v, pos as u32);
            }
            pmax.push(best);
        }
        // group the excluded pairs' loser positions by winner (sorted
        // input ⇒ each winner's positions arrive ascending)
        let mut excl: HashMap<usize, Vec<usize>> = HashMap::new();
        for &t in excluded {
            let i = self.winner_of(t);
            excl.entry(i).or_default().push(t - self.offset[i]);
        }
        let tree = if excl.is_empty() { None } else { Some(MaxTree::build(&mm)) };

        let run = |lo: usize, hi: usize| -> Vec<(usize, f64)> {
            let mut out = Vec::new();
            for i in lo..hi {
                let b = self.below[i] as usize;
                if b == 0 {
                    continue;
                }
                let hit = match excl.get(&i) {
                    None => {
                        let (val, pos) = pmax[b - 1];
                        Some((pos as usize, val))
                    }
                    Some(ex) => best_excluding(tree.as_ref().expect("tree built"), b, ex),
                };
                if let Some((pos, val)) = hit {
                    // the same expression the enumerated scan evaluates,
                    // so the two representations agree bitwise
                    let viol = 1.0 - (m[i] - val);
                    if viol > eps {
                        out.push((self.offset[i] + pos, viol));
                    }
                }
            }
            out
        };

        let t = threads.max(1).min(n);
        if t <= 1 || n < PAR_MIN_SAMPLES {
            return run(0, n);
        }
        let chunk = n.div_ceil(t);
        let parts: Vec<Vec<(usize, f64)>> = std::thread::scope(|scope| {
            let run = &run;
            let mut handles = Vec::with_capacity(t);
            for c in 0..t {
                let lo = c * chunk;
                let hi = ((c + 1) * chunk).min(n);
                if lo >= hi {
                    break;
                }
                handles.push(scope.spawn(move || run(lo, hi)));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("pair pricing worker panicked"))
                .collect()
        });
        parts.concat()
    }

    /// Total pairwise hinge `Σ_{(i,k)∈P} max(0, 1 − (m_i − m_k))` of a
    /// margin vector over ALL candidate pairs. Enumerated: one O(|P|)
    /// pass. Implicit: O(n log n) — walk the tie groups in ascending
    /// relevance, maintaining Fenwick count/sum trees over margin ranks;
    /// each winner reads the count `c` and sum `S` of inserted (strictly
    /// lower-relevance) margins above `m_i − 1`, contributing
    /// `S + c·(1 − m_i)`.
    pub fn hinge(&self, m: &[f64]) -> f64 {
        debug_assert_eq!(m.len(), self.n);
        if let Some(list) = &self.pairs {
            return list
                .iter()
                .map(|&(i, k)| (1.0 - (m[i as usize] - m[k as usize])).max(0.0))
                .sum();
        }
        let n = self.n;
        if self.total == 0 {
            return 0.0;
        }
        let mm: Vec<f64> = self.order.iter().map(|&idx| m[idx as usize]).collect();
        // margin ranks (ascending, ties by position)
        let mut by_margin: Vec<u32> = (0..n as u32).collect();
        by_margin.sort_unstable_by(|&a, &b| {
            mm[a as usize].total_cmp(&mm[b as usize]).then(a.cmp(&b))
        });
        let mut rank_of = vec![0u32; n];
        for (r, &pos) in by_margin.iter().enumerate() {
            rank_of[pos as usize] = r as u32;
        }
        let sorted_margins: Vec<f64> = by_margin.iter().map(|&p| mm[p as usize]).collect();
        // Fenwick trees indexed by DESCENDING margin rank, so "margins
        // above a threshold" is a pure prefix sum (no cancellation).
        let mut cnt = Fenwick::new(n);
        let mut sum = Fenwick::new(n);
        let mut acc = 0.0;
        let mut s = 0usize;
        while s < n {
            let e = self.tie_hi[self.order[s] as usize] as usize;
            if s > 0 {
                for &idx in &self.order[s..e] {
                    if self.below[idx as usize] == 0 {
                        continue; // NaN bucket: wins nothing
                    }
                    let mi = m[idx as usize];
                    let theta = mi - 1.0;
                    // first ascending rank with margin strictly above θ
                    let lo = sorted_margins.partition_point(|&v| v <= theta);
                    if lo < n {
                        let len = n - lo; // descending ranks 0..len
                        let c = cnt.prefix(len);
                        let sm = sum.prefix(len);
                        acc += sm + c * (1.0 - mi);
                    }
                }
            }
            for pos in s..e {
                let desc = n - 1 - rank_of[pos] as usize;
                cnt.add(desc, 1.0);
                sum.add(desc, mm[pos]);
            }
            s = e;
        }
        acc
    }

    /// Weighted, gapped pricing: for every winner `i` the most violated
    /// non-excluded pair under `viol = w_t·(g_t − (m_i − m_k))`, keeping
    /// the `cap` most violated winner-best pairs ordered
    /// `(violation desc, index asc)` — the same contract as
    /// [`Self::price`], which is exactly what uniform costs delegate to
    /// (bitwise: `1·x = x` and `1 − d` is the unweighted expression).
    /// The second return names the scan that ran (see [`PairScan`]):
    /// bucketed costs keep the sweep sublinear at O(n·L); per-pair costs
    /// on the implicit representation fall back to an O(|P|) streamed
    /// enumeration of the candidate space.
    pub fn price_weighted(
        &self,
        m: &[f64],
        eps: f64,
        excluded: &[usize],
        cap: usize,
        threads: usize,
        costs: &PairCosts,
    ) -> (Vec<(usize, f64)>, PairScan) {
        let scan = costs.scan(self);
        if matches!(costs, PairCosts::Uniform) {
            return (self.price(m, eps, excluded, cap, threads), scan);
        }
        debug_assert_eq!(m.len(), self.n);
        debug_assert!(
            excluded.windows(2).all(|w| w[0] < w[1]),
            "excluded pair indices must be sorted ascending"
        );
        let mut cands = match (&self.pairs, costs) {
            (Some(list), _) => winner_best_enumerated_weighted(self, list, m, eps, excluded, costs),
            (None, PairCosts::Bucketed { levels, gaps, weights }) => {
                self.winner_best_bucketed(m, eps, excluded, threads, *levels, gaps, weights)
            }
            (None, _) => self.winner_best_streamed(m, eps, excluded, costs),
        };
        cands.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        if cap > 0 && cands.len() > cap {
            cands.truncate(cap);
        }
        (cands, scan)
    }

    /// The bucketed winner-best scan: costs are constant per
    /// (winner level, loser level), so within one loser-level bucket the
    /// violation is a fixed increasing function of the loser margin and
    /// the bucket's best partner is its max-margin sample (leftmost on
    /// ties — the smallest canonical index, matching the enumerated
    /// scan's first-wins rule). One precomputed `(max, leftmost pos)`
    /// per level replaces the prefix-max array; winners with working-set
    /// exclusions query the tournament tree per bucket interval.
    /// O(n·L) after the margin gather, chunked over `threads` exactly
    /// like the uniform sweep (bit-identical at any thread count).
    #[allow(clippy::too_many_arguments)]
    fn winner_best_bucketed(
        &self,
        m: &[f64],
        eps: f64,
        excluded: &[usize],
        threads: usize,
        levels: usize,
        gaps: &[f64],
        weights: &[f64],
    ) -> Vec<(usize, f64)> {
        let n = self.n;
        if self.total == 0 {
            return Vec::new();
        }
        let mm: Vec<f64> = self.order.iter().map(|&idx| m[idx as usize]).collect();
        // per-level (max margin, leftmost sorted position)
        let nl = self.n_levels();
        debug_assert_eq!(levels, nl, "bucketed cost table does not match the level count");
        let mut bbest: Vec<(f64, u32)> = vec![(f64::NEG_INFINITY, u32::MAX); nl];
        for lvl in 0..nl {
            for pos in self.level_lo[lvl]..self.level_lo[lvl + 1] {
                if mm[pos] > bbest[lvl].0 {
                    bbest[lvl] = (mm[pos], pos as u32);
                }
            }
        }
        let mut excl: HashMap<usize, Vec<usize>> = HashMap::new();
        for &t in excluded {
            let i = self.winner_of(t);
            excl.entry(i).or_default().push(t - self.offset[i]);
        }
        let tree = if excl.is_empty() { None } else { Some(MaxTree::build(&mm)) };

        let run = |lo: usize, hi: usize| -> Vec<(usize, f64)> {
            let mut out = Vec::new();
            for i in lo..hi {
                if self.below[i] == 0 {
                    continue;
                }
                let a = self.level_of[i] as usize;
                let row = a * levels;
                let mut best: Option<(usize, f64)> = None; // (pos, viol)
                let ex = excl.get(&i);
                for lvl in 0..a {
                    let hit = match ex {
                        // ascending levels scan ascending position
                        // ranges, so strict `>` keeps the lowest
                        // canonical index on violation ties — the same
                        // tie-break as the streamed per-pair scan
                        None => {
                            let (val, pos) = bbest[lvl];
                            (pos != u32::MAX).then_some((pos as usize, val))
                        }
                        Some(ex) => best_excluding_range(
                            tree.as_ref().expect("tree built"),
                            self.level_lo[lvl],
                            self.level_lo[lvl + 1],
                            ex,
                        ),
                    };
                    if let Some((pos, val)) = hit {
                        let viol = weights[row + lvl] * (gaps[row + lvl] - (m[i] - val));
                        let replace = match best {
                            None => true,
                            Some((_, bv)) => viol > bv,
                        };
                        if replace {
                            best = Some((pos, viol));
                        }
                    }
                }
                if let Some((pos, viol)) = best {
                    if viol > eps {
                        out.push((self.offset[i] + pos, viol));
                    }
                }
            }
            out
        };

        let t = threads.max(1).min(n);
        if t <= 1 || n < PAR_MIN_SAMPLES {
            return run(0, n);
        }
        let chunk = n.div_ceil(t);
        let parts: Vec<Vec<(usize, f64)>> = std::thread::scope(|scope| {
            let run = &run;
            let mut handles = Vec::with_capacity(t);
            for c in 0..t {
                let lo = c * chunk;
                let hi = ((c + 1) * chunk).min(n);
                if lo >= hi {
                    break;
                }
                handles.push(scope.spawn(move || run(lo, hi)));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("pair pricing worker panicked"))
                .collect()
        });
        parts.concat()
    }

    /// The enumeration fallback for per-pair costs on the implicit
    /// representation: stream every winner's canonical block — O(|P|)
    /// time, O(1) extra memory, no pair list. Serial by design (and
    /// therefore trivially thread-count independent); the typed
    /// [`PairScan::EnumeratedPerPair`] reason tells callers the
    /// sublinear contract did not apply.
    fn winner_best_streamed(
        &self,
        m: &[f64],
        eps: f64,
        excluded: &[usize],
        costs: &PairCosts,
    ) -> Vec<(usize, f64)> {
        let mut out = Vec::new();
        let mut ex = excluded.iter().peekable();
        for i in 0..self.n {
            let b = self.below[i] as usize;
            if b == 0 {
                continue;
            }
            let base = self.offset[i];
            let mut best: Option<(usize, f64)> = None;
            for r in 0..b {
                let t = base + r;
                if ex.peek() == Some(&&t) {
                    ex.next();
                    continue;
                }
                let k = self.order[r] as usize;
                let (g, w) = costs.gap_weight_for(self, t, i, k);
                let viol = w * (g - (m[i] - m[k]));
                let replace = match best {
                    None => true,
                    Some((_, bv)) => viol > bv,
                };
                if replace {
                    best = Some((t, viol));
                }
            }
            if let Some((t, v)) = best {
                if v > eps {
                    out.push((t, v));
                }
            }
        }
        out
    }

    /// Total weighted, gapped hinge `Σ_t w_t·max(0, g_t − (m_i − m_k))`
    /// over ALL candidate pairs. Uniform costs route to [`Self::hinge`]
    /// (identical arithmetic); bucketed costs on the implicit
    /// representation aggregate per level — sorted per-level margins
    /// with suffix sums answer each winner's per-bucket sum
    /// `w·(S + c·(g − m_i))` over the `c` losers with `m_k > m_i − g` in
    /// two binary searches, O(n·L·log n) total; per-pair costs stream
    /// the canonical order in O(|P|).
    pub fn hinge_weighted(&self, m: &[f64], costs: &PairCosts) -> f64 {
        debug_assert_eq!(m.len(), self.n);
        if matches!(costs, PairCosts::Uniform) {
            return self.hinge(m);
        }
        if self.total == 0 {
            return 0.0;
        }
        if let (None, PairCosts::Bucketed { levels, gaps, weights }) = (&self.pairs, costs) {
            let nl = self.n_levels();
            debug_assert_eq!(*levels, nl);
            // per-level sorted margins (ascending) + suffix sums
            let mut lvl_sorted: Vec<Vec<f64>> = Vec::with_capacity(nl);
            let mut lvl_suffix: Vec<Vec<f64>> = Vec::with_capacity(nl);
            for lvl in 0..nl {
                let mut ms: Vec<f64> = self.order[self.level_lo[lvl]..self.level_lo[lvl + 1]]
                    .iter()
                    .map(|&idx| m[idx as usize])
                    .collect();
                ms.sort_unstable_by(f64::total_cmp);
                let mut suf = vec![0.0; ms.len() + 1];
                for j in (0..ms.len()).rev() {
                    suf[j] = suf[j + 1] + ms[j];
                }
                lvl_sorted.push(ms);
                lvl_suffix.push(suf);
            }
            let mut acc = 0.0;
            for i in 0..self.n {
                if self.below[i] == 0 {
                    continue;
                }
                let a = self.level_of[i] as usize;
                let row = a * nl;
                for lvl in 0..a {
                    let (g, w) = (gaps[row + lvl], weights[row + lvl]);
                    let theta = m[i] - g;
                    let ms = &lvl_sorted[lvl];
                    let lo = ms.partition_point(|&v| v <= theta);
                    if lo < ms.len() {
                        let c = (ms.len() - lo) as f64;
                        let s = lvl_suffix[lvl][lo];
                        acc += w * (s + c * (g - m[i]));
                    }
                }
            }
            return acc;
        }
        // enumerated list, or per-pair costs: one pass over the
        // canonical order (the list when materialized, streamed when not)
        let mut acc = 0.0;
        self.for_each(|t, i, k| {
            let (g, w) = costs.gap_weight_for(self, t, i, k);
            acc += w * (g - (m[i] - m[k])).max(0.0);
        });
        acc
    }

    /// The weighted all-ones-dual scatter: at β = 0 every pair's dual is
    /// its weight, so `v_i = Σ_{(i,k)∈P} w − Σ_{(k,i)∈P} w` — the vector
    /// behind the weighted λ_max and initial feature scores. Uniform
    /// costs are [`Self::ones_dual`]; bucketed costs aggregate per level
    /// in O(n + L²) (identical in both representations); per-pair costs
    /// stream the canonical order in O(|P|).
    pub fn weighted_dual(&self, costs: &PairCosts) -> Vec<f64> {
        match costs {
            PairCosts::Uniform => self.ones_dual(),
            PairCosts::Bucketed { levels, weights, .. } => {
                let nl = self.n_levels();
                debug_assert_eq!(*levels, nl);
                let cnt: Vec<f64> = (0..nl)
                    .map(|l| (self.level_lo[l + 1] - self.level_lo[l]) as f64)
                    .collect();
                // per-level win/lose weight totals, then one O(n) scatter
                let mut win = vec![0.0; nl];
                let mut lose = vec![0.0; nl];
                for a in 0..nl {
                    for b in 0..a {
                        let w = weights[a * nl + b];
                        win[a] += w * cnt[b];
                        lose[b] += w * cnt[a];
                    }
                }
                (0..self.n)
                    .map(|i| match self.level_of(i) {
                        Some(l) => win[l] - lose[l],
                        None => 0.0,
                    })
                    .collect()
            }
            PairCosts::PerPair { weights, .. } => {
                let mut v = vec![0.0; self.n];
                self.for_each(|t, i, k| {
                    v[i] += weights[t];
                    v[k] -= weights[t];
                });
                v
            }
        }
    }
}

/// Per-pair gaps and weights for the weighted hinge
/// `w_t·max(0, g_t − (m_i − m_k))`.
///
/// The variant encodes the *structure* of the costs, which decides the
/// pricing complexity (see [`PairScan`]): `Uniform` is the paper's
/// `g = w = 1` and routes through the original bitwise-identical code
/// paths; `Bucketed` holds one `(gap, weight)` per
/// (winner level, loser level) and keeps pricing sublinear; `PerPair`
/// is fully general and forces an O(|P|) enumeration. Validate against
/// the [`PairSet`] with [`PairCosts::validate`] before solving.
#[derive(Clone, Debug, PartialEq)]
pub enum PairCosts {
    /// `g_t = w_t = 1` for every pair — the unweighted problem.
    Uniform,
    /// Costs constant per relevance-level pair: entry `a·levels + b`
    /// holds the (gap, weight) of every pair whose winner sits at level
    /// `a` and loser at level `b` (levels numbered ascending by `y`;
    /// only entries with `a > b` are ever read). `levels` must equal
    /// [`PairSet::n_levels`].
    Bucketed {
        /// Number of relevance levels `L` (row stride of the tables).
        levels: usize,
        /// `L×L` row-major gap table `g[a][b]`, each finite and ≥ 0.
        gaps: Vec<f64>,
        /// `L×L` row-major weight table `w[a][b]`, each finite and > 0.
        weights: Vec<f64>,
    },
    /// One (gap, weight) per candidate pair in canonical index order.
    PerPair {
        /// `gaps[t]` for canonical pair `t`, each finite and ≥ 0.
        gaps: Vec<f64>,
        /// `weights[t]` for canonical pair `t`, each finite and > 0.
        weights: Vec<f64>,
    },
}

/// The uniform costs as a `'static` borrow target: `&PairCosts::UNIFORM`
/// promotes to `&'static PairCosts`, so unweighted callers thread costs
/// through borrowing APIs without owning anything.
impl PairCosts {
    /// See the type docs: the unweighted `g = w = 1`.
    pub const UNIFORM: PairCosts = PairCosts::Uniform;

    /// Whether these are the uniform (unweighted) costs.
    pub fn is_uniform(&self) -> bool {
        matches!(self, PairCosts::Uniform)
    }

    /// Build a bucketed table from a per-level-pair rule
    /// `f(winner_level, loser_level) -> (gap, weight)` — evaluated only
    /// on `a > b` (other entries hold the neutral `(1, 1)`).
    pub fn bucketed_by(
        pairs: &PairSet,
        mut f: impl FnMut(usize, usize) -> (f64, f64),
    ) -> PairCosts {
        let nl = pairs.n_levels();
        let mut gaps = vec![1.0; nl * nl];
        let mut weights = vec![1.0; nl * nl];
        for a in 0..nl {
            for b in 0..a {
                let (g, w) = f(a, b);
                gaps[a * nl + b] = g;
                weights[a * nl + b] = w;
            }
        }
        PairCosts::Bucketed { levels: nl, gaps, weights }
    }

    /// Check shape and value constraints against a pair set: table sizes
    /// match (`levels²` bucketed, `|P|` per-pair), gaps are finite and
    /// ≥ 0, weights are finite and > 0 (a zero weight would make every
    /// violation vanish and the leftmost tie-break meaningless).
    pub fn validate(&self, pairs: &PairSet) -> Result<(), String> {
        let check = |gaps: &[f64], weights: &[f64]| -> Result<(), String> {
            for &g in gaps {
                if !g.is_finite() || g < 0.0 {
                    return Err(format!("pair gaps must be finite and >= 0, got {g}"));
                }
            }
            for &w in weights {
                if !w.is_finite() || w <= 0.0 {
                    return Err(format!("pair weights must be finite and > 0, got {w}"));
                }
            }
            Ok(())
        };
        match self {
            PairCosts::Uniform => Ok(()),
            PairCosts::Bucketed { levels, gaps, weights } => {
                if *levels != pairs.n_levels() {
                    return Err(format!(
                        "bucketed costs built for {levels} levels, pair set has {}",
                        pairs.n_levels()
                    ));
                }
                if gaps.len() != levels * levels || weights.len() != levels * levels {
                    return Err(format!(
                        "bucketed tables must be {levels}x{levels} row-major, got {} gaps / {} weights",
                        gaps.len(),
                        weights.len()
                    ));
                }
                check(gaps, weights)
            }
            PairCosts::PerPair { gaps, weights } => {
                if gaps.len() != pairs.len() || weights.len() != pairs.len() {
                    return Err(format!(
                        "per-pair costs need one entry per candidate pair ({}), got {} gaps / {} weights",
                        pairs.len(),
                        gaps.len(),
                        weights.len()
                    ));
                }
                check(gaps, weights)
            }
        }
    }

    /// The (gap, weight) of canonical pair `t`. O(1) for uniform and
    /// per-pair costs; bucketed costs pay one [`PairSet::pair`] lookup.
    pub fn gap_weight(&self, pairs: &PairSet, t: usize) -> (f64, f64) {
        match self {
            PairCosts::Uniform => (1.0, 1.0),
            PairCosts::PerPair { gaps, weights } => (gaps[t], weights[t]),
            PairCosts::Bucketed { .. } => {
                let (i, k) = pairs.pair(t);
                self.gap_weight_for(pairs, t, i, k)
            }
        }
    }

    /// [`Self::gap_weight`] when the caller already knows `(i, k)`.
    fn gap_weight_for(&self, pairs: &PairSet, t: usize, i: usize, k: usize) -> (f64, f64) {
        match self {
            PairCosts::Uniform => (1.0, 1.0),
            PairCosts::PerPair { gaps, weights } => (gaps[t], weights[t]),
            PairCosts::Bucketed { levels, gaps, weights } => {
                let e = pairs.level_of[i] as usize * levels + pairs.level_of[k] as usize;
                (gaps[e], weights[e])
            }
        }
    }

    /// Which pricing scan these costs run on `pairs` — the typed reason
    /// surfaced in [`crate::engine::GenStats::pair_scan`].
    pub fn scan(&self, pairs: &PairSet) -> PairScan {
        match (self, pairs.is_enumerated()) {
            (PairCosts::Uniform, _) => PairScan::Uniform,
            (_, true) => PairScan::EnumeratedList,
            (PairCosts::Bucketed { .. }, false) => PairScan::Bucketed,
            (PairCosts::PerPair { .. }, false) => PairScan::EnumeratedPerPair,
        }
    }
}

/// Which pair-pricing scan ran, and why — the typed reason behind the
/// sublinear-pricing contract of `docs/ranksvm-scaling.md` when gaps and
/// weights are in play.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairScan {
    /// Uniform costs: the original prefix-max sweep (implicit) or list
    /// scan (enumerated).
    Uniform,
    /// Level-bucketed costs on the implicit representation: the O(n·L)
    /// per-bucket sweep — still sublinear in |P|.
    Bucketed,
    /// The pair list was already materialized (|P| ≤ the enumeration
    /// cap), so the weighted scan walks it in O(|P|).
    EnumeratedList,
    /// Per-pair costs on the implicit representation: no monotone
    /// structure to exploit, so pricing streamed the full candidate
    /// space in O(|P|) — the documented fallback.
    EnumeratedPerPair,
}

impl PairScan {
    /// Stable label for stats, serve responses, and metrics.
    pub fn as_str(&self) -> &'static str {
        match self {
            PairScan::Uniform => "uniform",
            PairScan::Bucketed => "bucketed",
            PairScan::EnumeratedList => "enumerated-list",
            PairScan::EnumeratedPerPair => "enumerated-per-pair",
        }
    }
}

/// Weighted winner-best scan over the materialized list — the same
/// running-best pass as [`winner_best_enumerated`] with the violation
/// generalized to `w_t·(g_t − (m_i − m_k))`. Kept separate so the
/// uniform path stays byte-for-byte the pre-weighting implementation.
fn winner_best_enumerated_weighted(
    pairs: &PairSet,
    list: &[(u32, u32)],
    m: &[f64],
    eps: f64,
    excluded: &[usize],
    costs: &PairCosts,
) -> Vec<(usize, f64)> {
    let mut out = Vec::new();
    let mut ex = excluded.iter().peekable();
    let mut cur: Option<(u32, usize, f64)> = None; // (winner, t, viol)
    for (t, &(i, k)) in list.iter().enumerate() {
        if ex.peek() == Some(&&t) {
            ex.next();
            continue;
        }
        let (g, w) = costs.gap_weight_for(pairs, t, i as usize, k as usize);
        let viol = w * (g - (m[i as usize] - m[k as usize]));
        match cur {
            Some((wn, _, bv)) if wn == i => {
                if viol > bv {
                    cur = Some((i, t, viol));
                }
            }
            Some((_, bt, bv)) => {
                if bv > eps {
                    out.push((bt, bv));
                }
                cur = Some((i, t, viol));
            }
            None => cur = Some((i, t, viol)),
        }
    }
    if let Some((_, bt, bv)) = cur {
        if bv > eps {
            out.push((bt, bv));
        }
    }
    out
}

/// Winner-best scan over the materialized list: the canonical order is
/// winner-ascending, so one pass with a running per-winner best (strict
/// `>` keeps the first — i.e. leftmost sorted position — on ties)
/// suffices. Kept independent of the implicit sweep so the two act as
/// cross-checks of each other.
fn winner_best_enumerated(
    list: &[(u32, u32)],
    m: &[f64],
    eps: f64,
    excluded: &[usize],
) -> Vec<(usize, f64)> {
    let mut out = Vec::new();
    let mut ex = excluded.iter().peekable();
    let mut cur: Option<(u32, usize, f64)> = None; // (winner, t, viol)
    for (t, &(i, k)) in list.iter().enumerate() {
        if ex.peek() == Some(&&t) {
            ex.next();
            continue;
        }
        let viol = 1.0 - (m[i as usize] - m[k as usize]);
        match cur {
            Some((w, _, bv)) if w == i => {
                if viol > bv {
                    cur = Some((i, t, viol));
                }
            }
            Some((_, bt, bv)) => {
                if bv > eps {
                    out.push((bt, bv));
                }
                cur = Some((i, t, viol));
            }
            None => cur = Some((i, t, viol)),
        }
    }
    if let Some((_, bt, bv)) = cur {
        if bv > eps {
            out.push((bt, bv));
        }
    }
    out
}

/// Max over `[0, b)` minus the excluded positions `ex` (sorted
/// ascending, all `< b`): the union of at most `|ex| + 1` intervals,
/// each one tournament-tree query. Leftmost position on value ties.
fn best_excluding(tree: &MaxTree, b: usize, ex: &[usize]) -> Option<(usize, f64)> {
    best_excluding_range(tree, 0, b, ex)
}

/// [`best_excluding`] over an arbitrary window `[lo, hi)` — the bucketed
/// sweep's per-level interval query (excluded positions outside the
/// window are skipped, not an error).
fn best_excluding_range(
    tree: &MaxTree,
    lo: usize,
    hi: usize,
    ex: &[usize],
) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    let mut l = lo;
    for &e in &ex[ex.partition_point(|&e| e < lo)..] {
        if e >= hi {
            break;
        }
        take_better(tree, l, e, &mut best);
        l = e + 1;
    }
    take_better(tree, l, hi, &mut best);
    best
}

fn take_better(tree: &MaxTree, l: usize, r: usize, best: &mut Option<(usize, f64)>) {
    if l >= r {
        return;
    }
    if let Some((val, pos)) = tree.query(l, r) {
        let replace = match *best {
            None => true,
            Some((bp, bv)) => val > bv || (val == bv && pos < bp),
        };
        if replace {
            *best = Some((pos, val));
        }
    }
}

/// A static leftmost-argmax tournament tree over a fixed f64 array
/// (O(n) build, O(log n) range queries) — resolves the pricing sweep's
/// per-winner best loser when some prefix positions are excluded.
struct MaxTree {
    size: usize,
    val: Vec<f64>,
    pos: Vec<u32>,
}

impl MaxTree {
    fn build(m: &[f64]) -> MaxTree {
        let size = m.len().next_power_of_two().max(1);
        let mut val = vec![f64::NEG_INFINITY; 2 * size];
        let mut pos = vec![u32::MAX; 2 * size];
        for (i, &v) in m.iter().enumerate() {
            val[size + i] = v;
            pos[size + i] = i as u32;
        }
        for i in (1..size).rev() {
            // `>=` keeps the left child on ties ⇒ stored pos is the
            // leftmost argmax of the node's segment
            if val[2 * i] >= val[2 * i + 1] {
                val[i] = val[2 * i];
                pos[i] = pos[2 * i];
            } else {
                val[i] = val[2 * i + 1];
                pos[i] = pos[2 * i + 1];
            }
        }
        MaxTree { size, val, pos }
    }

    /// `(max value, leftmost argmax)` over `[l, r)`.
    fn query(&self, mut l: usize, mut r: usize) -> Option<(f64, usize)> {
        if l >= r {
            return None;
        }
        let mut best = (f64::NEG_INFINITY, u32::MAX);
        l += self.size;
        r += self.size;
        while l < r {
            if l & 1 == 1 {
                best = better(best, (self.val[l], self.pos[l]));
                l += 1;
            }
            if r & 1 == 1 {
                r -= 1;
                best = better(best, (self.val[r], self.pos[r]));
            }
            l >>= 1;
            r >>= 1;
        }
        Some((best.0, best.1 as usize))
    }
}

/// Larger value wins; smaller position breaks exact ties.
fn better(a: (f64, u32), b: (f64, u32)) -> (f64, u32) {
    if b.0 > a.0 || (b.0 == a.0 && b.1 < a.1) {
        b
    } else {
        a
    }
}

/// A Fenwick (binary indexed) tree of f64 prefix sums — the hinge
/// accumulator. Deterministic accumulation order regardless of callers'
/// threading (it is only ever driven serially).
struct Fenwick {
    tree: Vec<f64>,
}

impl Fenwick {
    fn new(n: usize) -> Fenwick {
        Fenwick { tree: vec![0.0; n + 1] }
    }

    fn add(&mut self, i: usize, v: f64) {
        let mut i = i + 1;
        while i < self.tree.len() {
            self.tree[i] += v;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum over `[0, i)`.
    fn prefix(&self, i: usize) -> f64 {
        let mut i = i.min(self.tree.len() - 1);
        let mut s = 0.0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::workloads::ranksvm::ranking_pairs;

    /// y with repeated levels, margins pseudo-random — the tie-heavy
    /// instance the cross-checks run on.
    fn tied_instance(n: usize, levels: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let y: Vec<f64> = (0..n).map(|_| (rng.uniform() * levels as f64).floor()).collect();
        let m: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (y, m)
    }

    /// Independent brute-force winner-best pricing off the reference
    /// enumeration.
    fn brute_force_price(y: &[f64], m: &[f64], eps: f64, excluded: &[usize]) -> Vec<(usize, f64)> {
        let list = ranking_pairs(y);
        let mut best: HashMap<usize, (usize, f64)> = HashMap::new();
        for (t, &(i, k)) in list.iter().enumerate() {
            if excluded.binary_search(&t).is_ok() {
                continue;
            }
            let viol = 1.0 - (m[i] - m[k]);
            match best.get(&i) {
                Some(&(_, bv)) if viol <= bv => {}
                _ => {
                    best.insert(i, (t, viol));
                }
            }
        }
        let mut out: Vec<(usize, f64)> =
            best.into_values().filter(|&(_, v)| v > eps).collect();
        out.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        out
    }

    #[test]
    fn canonical_enumeration_matches_reference() {
        for (n, levels, seed) in [(1usize, 1usize, 1u64), (17, 3, 2), (40, 7, 3), (25, 25, 4)] {
            let (y, _) = tied_instance(n, levels, seed);
            let e = PairSet::build(&y, PairMode::Enumerate);
            let i = PairSet::build(&y, PairMode::Implicit);
            let reference = ranking_pairs(&y);
            assert_eq!(e.materialize(), reference, "enumerated list");
            assert_eq!(i.materialize(), reference, "implicit streaming");
            assert_eq!(e.len(), reference.len());
            assert_eq!(i.len(), reference.len());
            for (t, &want) in reference.iter().enumerate() {
                assert_eq!(e.pair(t), want, "enumerated pair({t})");
                assert_eq!(i.pair(t), want, "implicit pair({t})");
                assert_eq!(e.index_of(want.0, want.1), Some(t), "index_of roundtrip");
                assert_eq!(i.index_of(want.0, want.1), Some(t));
                assert_eq!(i.index_of(want.1, want.0), None, "reversed pair is no candidate");
            }
        }
    }

    #[test]
    fn auto_mode_switches_on_the_pair_count() {
        let (y, _) = tied_instance(30, 5, 9);
        assert!(PairSet::build(&y, PairMode::Auto).is_enumerated(), "small |P| enumerates");
        assert!(!PairSet::build(&y, PairMode::Implicit).is_enumerated());
        assert_eq!(PairSet::build(&y, PairMode::Implicit).mode(), "implicit");
    }

    #[test]
    fn all_tied_responses_give_an_empty_set() {
        let y = vec![2.0; 12];
        for mode in [PairMode::Enumerate, PairMode::Implicit] {
            let ps = PairSet::build(&y, mode);
            assert!(ps.is_empty());
            assert!(ps.spread(5).is_empty());
            assert!(ps.price(&[0.0; 12], 0.0, &[], 0, 1).is_empty());
            assert_eq!(ps.hinge(&[0.0; 12]), 0.0);
            assert!(ps.ones_dual().iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn nan_responses_join_no_pair_and_never_panic() {
        // a NaN label (parseable from a libsvm file) must degrade
        // gracefully — the serve layer's never-panics contract depends
        // on it — and match the reference predicate, where y_i > y_k is
        // false whenever either side is NaN
        let y = [2.0, f64::NAN, 1.0, 2.0, f64::NAN, 3.0];
        let reference = ranking_pairs(&y);
        assert!(reference.iter().all(|&(i, k)| i != 1 && k != 1 && i != 4 && k != 4));
        for mode in [PairMode::Enumerate, PairMode::Implicit] {
            let ps = PairSet::build(&y, mode);
            assert_eq!(ps.materialize(), reference, "{mode:?}");
            assert_eq!(ps.index_of(5, 1), None, "NaN never loses");
            assert_eq!(ps.index_of(1, 2), None, "NaN never wins");
            let m = [0.5, -1.0, 0.25, 0.0, 2.0, -0.75];
            let priced = ps.price(&m, f64::NEG_INFINITY, &[], 0, 1);
            for &(t, _) in &priced {
                let (i, k) = ps.pair(t);
                assert!(i != 1 && i != 4 && k != 1 && k != 4);
            }
            // hinge over the same margins matches the reference sum
            let want: f64 =
                reference.iter().map(|&(i, k)| (1.0 - (m[i] - m[k])).max(0.0)).sum();
            assert!((ps.hinge(&m) - want).abs() < 1e-12, "{mode:?} hinge");
            // the all-ones dual only counts rankable samples
            let mut dual = vec![0.0; y.len()];
            for &(i, k) in &reference {
                dual[i] += 1.0;
                dual[k] -= 1.0;
            }
            assert_eq!(ps.ones_dual(), dual, "{mode:?} ones_dual");
        }
        // all-NaN responses: an empty candidate set, not a crash
        let all_nan = [f64::NAN; 4];
        assert!(PairSet::build(&all_nan, PairMode::Implicit).is_empty());
    }

    #[test]
    fn ones_dual_matches_the_pair_scatter() {
        let (y, _) = tied_instance(35, 6, 11);
        let ps = PairSet::build(&y, PairMode::Implicit);
        let mut want = vec![0.0; y.len()];
        for (i, k) in ranking_pairs(&y) {
            want[i] += 1.0;
            want[k] -= 1.0;
        }
        assert_eq!(ps.ones_dual(), want);
    }

    #[test]
    fn spread_indices_fill_the_budget_and_cover_the_tail() {
        // the regression: n barely above k used to cluster at the front
        let s = spread_indices(29, 10);
        assert_eq!(s.len(), 10, "must return exactly k indices");
        assert!(s.windows(2).all(|w| w[0] < w[1]), "strictly increasing");
        assert!(*s.last().unwrap() >= 29 - 3, "tail must be covered: {s:?}");
        for (a, b) in s.iter().zip(s.iter().skip(1)) {
            assert!(b - a <= 3, "gap {a}..{b} exceeds ceil(29/10)");
        }
        assert_eq!(spread_indices(10, 4), vec![0, 2, 5, 7]);
        assert_eq!(spread_indices(3, 10), vec![0, 1, 2], "k clamps to n");
        assert!(spread_indices(0, 5).is_empty());
        assert_eq!(spread_indices(7, 0), vec![0], "k clamps up to 1");
    }

    #[test]
    fn price_agrees_across_representations_and_brute_force() {
        for seed in [21u64, 22, 23, 24, 25] {
            let (y, m) = tied_instance(60, 4 + (seed as usize % 5), seed);
            let e = PairSet::build(&y, PairMode::Enumerate);
            let i = PairSet::build(&y, PairMode::Implicit);
            assert_eq!(e.fingerprint(), i.fingerprint());
            if e.is_empty() {
                continue;
            }
            // exclude a spread of pairs plus a dense run inside one winner
            let mut excluded = e.spread(15);
            excluded.extend((0..e.len().min(6)).skip(1));
            excluded.sort_unstable();
            excluded.dedup();
            for eps in [0.0, 0.3] {
                for cap in [0usize, 3, 7] {
                    let a = e.price(&m, eps, &excluded, cap, 1);
                    let b = i.price(&m, eps, &excluded, cap, 1);
                    assert_eq!(a, b, "seed {seed} eps {eps} cap {cap}");
                    if cap == 0 {
                        let brute = brute_force_price(&y, &m, eps, &excluded);
                        assert_eq!(a, brute, "brute force, seed {seed} eps {eps}");
                    }
                }
            }
        }
    }

    #[test]
    fn price_excludes_every_working_set_pair() {
        let (y, m) = tied_instance(30, 3, 31);
        let ps = PairSet::build(&y, PairMode::Implicit);
        // excluding a winner's whole block must silence that winner
        let (w, _) = ps.pair(0);
        let block: Vec<usize> = (ps.offset[w]..ps.offset[w + 1]).collect();
        let priced = ps.price(&m, f64::NEG_INFINITY, &block, 0, 1);
        for &(t, _) in &priced {
            assert!(!block.contains(&t), "excluded pair {t} still priced");
            assert_ne!(ps.pair(t).0, w, "silenced winner resurfaced");
        }
    }

    #[test]
    fn implicit_price_is_thread_independent() {
        // n above the spawn gate so workers actually run
        let (y, m) = tied_instance(6000, 97, 41);
        let ps = PairSet::build(&y, PairMode::Implicit);
        assert!(ps.n_samples() >= PAR_MIN_SAMPLES);
        let excluded = ps.spread(48);
        let serial = ps.price(&m, 0.0, &excluded, 0, 1);
        assert!(!serial.is_empty());
        for t in [2usize, 4, 7] {
            assert_eq!(ps.price(&m, 0.0, &excluded, 0, t), serial, "{t} threads diverged");
        }
        // the cap keeps the most-violated prefix of the same ordering
        let capped = ps.price(&m, 0.0, &excluded, 50, 4);
        assert_eq!(capped.as_slice(), &serial[..50]);
    }

    #[test]
    fn hinge_matches_the_enumerated_sum() {
        for seed in [51u64, 52, 53] {
            let (y, m) = tied_instance(80, 6, seed);
            let e = PairSet::build(&y, PairMode::Enumerate);
            let i = PairSet::build(&y, PairMode::Implicit);
            let he = e.hinge(&m);
            let hi = i.hinge(&m);
            assert!(
                (he - hi).abs() <= 1e-8 * he.abs().max(1.0),
                "seed {seed}: enumerated {he} implicit {hi}"
            );
            // β = 0 ⇒ every pair contributes exactly 1
            let zeros = vec![0.0; y.len()];
            assert_eq!(e.hinge(&zeros), e.len() as f64);
            assert!((i.hinge(&zeros) - i.len() as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn fingerprint_tracks_the_index_space() {
        let (ya, _) = tied_instance(25, 4, 61);
        let (yb, _) = tied_instance(25, 4, 62);
        let a = PairSet::build(&ya, PairMode::Enumerate);
        let b = PairSet::build(&yb, PairMode::Enumerate);
        assert_ne!(a.fingerprint(), b.fingerprint(), "different y, different print");
        let a2 = PairSet::build(&ya, PairMode::Implicit);
        assert_eq!(
            a.fingerprint(),
            a2.fingerprint(),
            "the fingerprint is representation-independent"
        );
    }

    #[test]
    fn max_tree_finds_leftmost_argmax() {
        let m = [1.0, 5.0, 5.0, 2.0, 5.0, -1.0];
        let tree = MaxTree::build(&m);
        assert_eq!(tree.query(0, 6), Some((5.0, 1)));
        assert_eq!(tree.query(2, 6), Some((5.0, 2)));
        assert_eq!(tree.query(3, 6), Some((5.0, 4)));
        assert_eq!(tree.query(3, 4), Some((2.0, 3)));
        assert_eq!(tree.query(3, 3), None);
        assert_eq!(best_excluding(&tree, 6, &[1, 2]), Some((4, 5.0)));
        assert_eq!(best_excluding(&tree, 3, &[1, 2]), Some((0, 1.0)));
        assert_eq!(best_excluding(&tree, 1, &[0]), None);
        assert_eq!(best_excluding_range(&tree, 2, 5, &[0, 4]), Some((2, 5.0)));
        assert_eq!(best_excluding_range(&tree, 3, 4, &[3]), None);
    }

    // ------------------------------------------------------------------
    // weighted, gapped costs
    // ------------------------------------------------------------------

    /// Levels computed independently of PairSet: the rank of y_i among
    /// the distinct finite response values, ascending.
    fn brute_levels(y: &[f64]) -> Vec<Option<usize>> {
        let mut vals: Vec<f64> = y.iter().copied().filter(|v| !v.is_nan()).collect();
        vals.sort_unstable_by(f64::total_cmp);
        vals.dedup();
        y.iter()
            .map(|v| (!v.is_nan()).then(|| vals.partition_point(|&u| u < *v)))
            .collect()
    }

    /// An asymmetric per-level-pair cost rule the weighted tests share.
    fn rule(a: usize, b: usize) -> (f64, f64) {
        (0.5 + 0.25 * (a - b) as f64, 1.0 + 0.5 * (b % 3) as f64 + 0.125 * a as f64)
    }

    /// Brute-force weighted winner-best pricing off the reference
    /// enumeration, with (gap, weight) from independently derived levels.
    fn brute_force_price_weighted(
        y: &[f64],
        m: &[f64],
        eps: f64,
        excluded: &[usize],
        gw: impl Fn(usize, usize, usize) -> (f64, f64), // (t, lvl_i, lvl_k)
    ) -> Vec<(usize, f64)> {
        let list = ranking_pairs(y);
        let lv = brute_levels(y);
        let mut best: HashMap<usize, (usize, f64)> = HashMap::new();
        for (t, &(i, k)) in list.iter().enumerate() {
            if excluded.binary_search(&t).is_ok() {
                continue;
            }
            let (g, w) = gw(t, lv[i].unwrap(), lv[k].unwrap());
            let viol = w * (g - (m[i] - m[k]));
            match best.get(&i) {
                Some(&(_, bv)) if viol <= bv => {}
                _ => {
                    best.insert(i, (t, viol));
                }
            }
        }
        let mut out: Vec<(usize, f64)> =
            best.into_values().filter(|&(_, v)| v > eps).collect();
        out.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        out
    }

    #[test]
    fn uniform_costs_are_bitwise_the_unweighted_scan() {
        let (y, m) = tied_instance(50, 5, 71);
        for mode in [PairMode::Enumerate, PairMode::Implicit] {
            let ps = PairSet::build(&y, mode);
            let excluded = ps.spread(9);
            let (weighted, scan) =
                ps.price_weighted(&m, 0.1, &excluded, 6, 1, &PairCosts::UNIFORM);
            assert_eq!(scan, PairScan::Uniform);
            assert_eq!(weighted, ps.price(&m, 0.1, &excluded, 6, 1), "{mode:?}");
            assert_eq!(
                ps.hinge_weighted(&m, &PairCosts::UNIFORM).to_bits(),
                ps.hinge(&m).to_bits()
            );
            assert_eq!(ps.weighted_dual(&PairCosts::UNIFORM), ps.ones_dual());
        }
    }

    #[test]
    fn weighted_price_agrees_across_scans_and_brute_force() {
        for seed in [81u64, 82, 83] {
            let (mut y, m) = tied_instance(48, 4 + (seed as usize % 3), seed);
            y[7] = f64::NAN; // NaN relevance joins no pair
            let e = PairSet::build(&y, PairMode::Enumerate);
            let imp = PairSet::build(&y, PairMode::Implicit);
            if e.is_empty() {
                continue;
            }
            let bucketed = PairCosts::bucketed_by(&e, rule);
            bucketed.validate(&e).unwrap();
            // the same costs flattened per pair: exercises both the
            // per-pair table and the enumeration fallback
            let mut gaps = vec![0.0; e.len()];
            let mut weights = vec![0.0; e.len()];
            e.for_each(|t, i, k| {
                let (g, w) = bucketed.gap_weight_for(&e, t, i, k);
                gaps[t] = g;
                weights[t] = w;
            });
            let per_pair = PairCosts::PerPair { gaps, weights };
            per_pair.validate(&imp).unwrap();

            let mut excluded = e.spread(11);
            excluded.extend((0..e.len().min(5)).skip(1));
            excluded.sort_unstable();
            excluded.dedup();
            for eps in [0.0, 0.4] {
                for cap in [0usize, 5] {
                    let brute = {
                        let mut b = brute_force_price_weighted(&y, &m, eps, &excluded, |t, a, l| {
                            let _ = t;
                            rule(a, l)
                        });
                        if cap > 0 && b.len() > cap {
                            b.truncate(cap);
                        }
                        b
                    };
                    let (a1, s1) = e.price_weighted(&m, eps, &excluded, cap, 1, &bucketed);
                    let (a2, s2) = imp.price_weighted(&m, eps, &excluded, cap, 1, &bucketed);
                    let (a3, s3) = imp.price_weighted(&m, eps, &excluded, cap, 1, &per_pair);
                    let (a4, s4) = e.price_weighted(&m, eps, &excluded, cap, 1, &per_pair);
                    assert_eq!(s1, PairScan::EnumeratedList);
                    assert_eq!(s2, PairScan::Bucketed);
                    assert_eq!(s3, PairScan::EnumeratedPerPair);
                    assert_eq!(s4, PairScan::EnumeratedList);
                    assert_eq!(a1, brute, "enumerated+bucketed seed {seed} eps {eps}");
                    assert_eq!(a2, brute, "implicit+bucketed seed {seed} eps {eps}");
                    assert_eq!(a3, brute, "implicit+per-pair seed {seed} eps {eps}");
                    assert_eq!(a4, brute, "enumerated+per-pair seed {seed} eps {eps}");
                }
            }
        }
    }

    #[test]
    fn bucketed_sweep_is_thread_independent() {
        let (y, m) = tied_instance(6000, 12, 91);
        let ps = PairSet::build(&y, PairMode::Implicit);
        assert!(ps.n_samples() >= PAR_MIN_SAMPLES);
        let costs = PairCosts::bucketed_by(&ps, rule);
        let excluded = ps.spread(40);
        let (serial, scan) = ps.price_weighted(&m, 0.0, &excluded, 0, 1, &costs);
        assert_eq!(scan, PairScan::Bucketed);
        assert!(!serial.is_empty());
        for t in [2usize, 4, 7] {
            let (par, _) = ps.price_weighted(&m, 0.0, &excluded, 0, t, &costs);
            assert_eq!(par, serial, "{t} threads diverged");
        }
    }

    #[test]
    fn weighted_hinge_and_dual_match_the_pair_scatter() {
        for seed in [95u64, 96] {
            let (mut y, m) = tied_instance(60, 5, seed);
            y[3] = f64::NAN;
            let e = PairSet::build(&y, PairMode::Enumerate);
            let imp = PairSet::build(&y, PairMode::Implicit);
            let costs = PairCosts::bucketed_by(&e, rule);
            let lv = brute_levels(&y);
            let list = ranking_pairs(&y);
            let mut want_hinge = 0.0;
            let mut want_dual = vec![0.0; y.len()];
            for &(i, k) in &list {
                let (g, w) = rule(lv[i].unwrap(), lv[k].unwrap());
                want_hinge += w * (g - (m[i] - m[k])).max(0.0);
                want_dual[i] += w;
                want_dual[k] -= w;
            }
            for ps in [&e, &imp] {
                let h = ps.hinge_weighted(&m, &costs);
                assert!(
                    (h - want_hinge).abs() <= 1e-9 * want_hinge.abs().max(1.0),
                    "seed {seed} {}: hinge {h} want {want_hinge}",
                    ps.mode()
                );
                let d = ps.weighted_dual(&costs);
                for i in 0..y.len() {
                    assert!(
                        (d[i] - want_dual[i]).abs() <= 1e-9,
                        "seed {seed} {}: dual[{i}] {} want {}",
                        ps.mode(),
                        d[i],
                        want_dual[i]
                    );
                }
            }
        }
    }

    #[test]
    fn pair_costs_validate_rejects_bad_shapes_and_values() {
        let (y, _) = tied_instance(20, 4, 99);
        let ps = PairSet::build(&y, PairMode::Enumerate);
        let nl = ps.n_levels();
        assert!(PairCosts::UNIFORM.validate(&ps).is_ok());
        let good = PairCosts::bucketed_by(&ps, |_, _| (1.5, 2.0));
        assert!(good.validate(&ps).is_ok());
        let wrong_levels = PairCosts::Bucketed {
            levels: nl + 1,
            gaps: vec![1.0; (nl + 1) * (nl + 1)],
            weights: vec![1.0; (nl + 1) * (nl + 1)],
        };
        assert!(wrong_levels.validate(&ps).is_err());
        let neg_gap = PairCosts::Bucketed {
            levels: nl,
            gaps: vec![-1.0; nl * nl],
            weights: vec![1.0; nl * nl],
        };
        assert!(neg_gap.validate(&ps).is_err());
        let zero_w = PairCosts::PerPair {
            gaps: vec![1.0; ps.len()],
            weights: vec![0.0; ps.len()],
        };
        assert!(zero_w.validate(&ps).is_err());
        let short = PairCosts::PerPair { gaps: vec![1.0], weights: vec![1.0] };
        assert!(short.validate(&ps).is_err());
    }
}
