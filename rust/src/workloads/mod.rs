//! LP workloads beyond the paper's three SVM coordinators — external
//! validation that the [`crate::engine`] trait boundary generalizes.
//!
//! Each workload is a [`crate::engine::RestrictedProblem`] implementation
//! plus model bookkeeping; the solve → price → expand loop, round caps,
//! stall guard, tracing, and parallel pricing are all inherited from
//! [`crate::engine::GenEngine`]. See `docs/adding-a-workload.md` for a
//! step-by-step guide (RankSVM is the worked example).
//!
//! * [`ranksvm`] — pairwise-hinge L1 ranking: constraint generation over
//!   the O(n²) comparison pairs, column generation over features;
//! * [`pairset`] — RankSVM's comparison-pair abstraction: one canonical
//!   pair-index space with an enumerated representation for small
//!   instances and an implicit sorted-order representation whose pricing
//!   sweep is O(n log n) (see `docs/ranksvm-scaling.md`);
//! * [`dantzig`] — the Dantzig selector `min ‖β‖₁ s.t. ‖Xᵀ(y − Xβ)‖∞ ≤ λ`:
//!   column-and-constraint generation over the p×p correlation system
//!   (Mazumder, Wright & Zheng, arXiv:1908.06515).

pub mod dantzig;
pub mod pairset;
pub mod ranksvm;
