//! The persistent solve service: a zero-dependency daemon that amortizes
//! dataset loading and working-set discovery across requests.
//!
//! Every one-shot `cutgen` invocation rebuilds everything from scratch;
//! this subsystem keeps the expensive state alive between requests:
//!
//! * [`registry::Registry`] — each design matrix is loaded and
//!   fingerprinted **once** and shared via `Arc` across requests and
//!   worker threads;
//! * [`cache::WarmCache`] — after every solve the final working sets are
//!   snapshotted (`engine::Snapshot`) under a `(dataset, workload,
//!   λ-bucket)` key; a later request near a previously solved λ seeds
//!   its restricted model from the snapshot and resumes generation
//!   instead of starting cold — Algorithm 2's warm-start observation,
//!   request-shaped;
//! * a **grid endpoint** that routes through the warm-started λ-path
//!   drivers in `coordinator::path` and seeds the warm-start cache at
//!   **every** visited λ, so later fixed-λ requests near the grid resume
//!   warm;
//! * **first-order cold starts**: a cache miss seeds the restricted
//!   model through the shared `engine::Initializer` (§4 FOM seeding by
//!   default; the request's `"init"` field picks
//!   `auto|screening|fista|blockcd|subsample`, `"seed_budget"` sizes the
//!   seed).
//!
//! The protocol is line-delimited JSON (one request object per line, one
//! response per line, in order — [`json`] is the hand-rolled
//! reader/writer) over two transports ([`transport`]): a
//! `std::net::TcpListener` with a scoped worker pool, and a
//! stdin/stdout mode (`cutgen serve --stdin`) so tests and CI exercise
//! the full protocol without opening a port. `docs/serving.md` is the
//! protocol reference.

#![warn(missing_docs)]

pub mod cache;
pub mod json;
pub mod protocol;
pub mod registry;
pub mod transport;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::backend::NativeBackend;
use crate::coordinator::group::{GroupProblem, RestrictedGroup};
use crate::coordinator::l1svm::{L1Problem, RestrictedL1};
use crate::coordinator::path::{
    dantzig_path, geometric_grid, ranksvm_path, regularization_path, PathSolution,
};
use crate::coordinator::report::{
    dantzig_report, group_report, l1_report, ranksvm_report, slope_report,
};
use crate::coordinator::slope::{RestrictedSlope, SlopeProblem};
use crate::coordinator::{GenParams, GenStats};
use crate::engine::{
    BackendPricer, GenEngine, InitStrategy, Initializer, PairMode, Snapshot, WorkingSet,
};
use crate::error::Result;
use crate::fom::objective::bh_slope_weights;
use crate::workloads::dantzig::{lambda_max_dantzig, DantzigProblem, RestrictedDantzig};
use crate::workloads::pairset::PairSet;
use crate::workloads::ranksvm::{lambda_max_rank, pair_rows_cap, RankProblem, RestrictedRank};
use crate::{bail, ensure, err};

use cache::{CacheEntry, CacheHit, WarmCache};
use json::{kv, Json};
use protocol::{err_response, ok_response, Req, Workload};
use registry::{DatasetEntry, Registry, SynthOpts};

/// Default bound on cached working-set snapshots.
pub const DEFAULT_CACHE_CAP: usize = 256;

/// All shared service state: registry, warm-start cache, counters, and
/// the shutdown flag. One instance serves every connection; requests
/// only hold the cache lock around lookups/inserts, never during solves.
pub struct ServeState {
    /// The dataset registry (name → `Arc`-shared entry).
    pub registry: Registry,
    cache: Mutex<WarmCache>,
    requests: AtomicU64,
    shutdown: AtomicBool,
}

impl ServeState {
    /// Fresh state with a warm-start cache bounded to `cache_cap`.
    pub fn new(cache_cap: usize) -> Self {
        Self {
            registry: Registry::new(),
            cache: Mutex::new(WarmCache::new(cache_cap)),
            requests: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Whether a `shutdown` request has been received.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Handle one request line, returning the response line. Never
    /// panics on protocol input: parse and dispatch errors become
    /// `{"ok":false,"error":…}` responses.
    pub fn handle_line(&self, line: &str) -> String {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let resp = match Json::parse(line) {
            Ok(doc) => {
                let req = Req(&doc);
                match req.str_req("op") {
                    Ok(op) => self
                        .dispatch(op, &req)
                        .unwrap_or_else(|e| err_response(&e.to_string())),
                    Err(e) => err_response(&e.to_string()),
                }
            }
            Err(e) => err_response(&e.to_string()),
        };
        resp.to_string()
    }

    fn dispatch(&self, op: &str, req: &Req) -> Result<Json> {
        match op {
            "register" => self.handle_register(req),
            "solve" => self.handle_solve(req),
            "grid" => self.handle_grid(req),
            "stats" => Ok(self.stats_response()),
            "ping" => Ok(ok_response("ping", Vec::new())),
            "shutdown" => {
                self.shutdown.store(true, Ordering::SeqCst);
                Ok(ok_response("shutdown", Vec::new()))
            }
            other => bail!("unknown op {other:?} (register|solve|grid|stats|ping|shutdown)"),
        }
    }

    fn handle_register(&self, req: &Req) -> Result<Json> {
        let name = req.str_req("name")?;
        let entry = if let Some(path) = req.str_opt("path") {
            self.registry.register_file(name, path)?
        } else if let Some(synth) = req.0.get("synthetic") {
            let s = Req(synth);
            let kind = s.str_opt("kind").unwrap_or("l1");
            let n = s.usize_or("n", 100)?;
            let p = s.usize_or("p", 1000)?;
            let seed = s.usize_or("seed", 0)? as u64;
            let opts = SynthOpts {
                density: synth.get("density").and_then(Json::as_f64),
                group_size: synth.get("group_size").and_then(Json::as_usize),
            };
            self.registry.register_synthetic(name, kind, n, p, seed, &opts)?
        } else {
            bail!("register needs a \"path\" (libsvm file) or a \"synthetic\" spec");
        };
        Ok(ok_response(
            "register",
            vec![
                kv("name", name),
                kv("n", entry.ds.n()),
                kv("p", entry.ds.p()),
                kv("nnz", entry.ds.x.nnz()),
                kv("sparse", entry.ds.x.is_sparse()),
                kv("fingerprint", format!("{:016x}", entry.fingerprint)),
            ],
        ))
    }

    fn handle_solve(&self, req: &Req) -> Result<Json> {
        let name = req.str_req("dataset")?;
        let entry = self
            .registry
            .get(name)
            .ok_or_else(|| err!("unknown dataset {name:?} (register it first)"))?;
        let workload = Workload::parse(req.str_req("workload")?)?;
        let mut gen = gen_from_req(req)?;
        gen.max_cols_per_round = req.usize_or("max_cols_per_round", 0)?;
        gen.max_rows_per_round = req.usize_or("max_rows_per_round", 0)?;
        let group_size = req.usize_or("group_size", 10)?.max(1);
        let use_cache = req.bool_or("cache", true)?;
        let lambda = lambda_for(&entry, workload, req, group_size)?;
        let fp = cache_fp(&entry, workload, group_size);

        let hit: Option<CacheHit> = if use_cache {
            self.cache.lock().expect("cache lock").lookup(fp, workload, lambda)
        } else {
            None
        };
        let seed = hit.as_ref().map(|h| &h.entry.ws);
        let core = solve_one(&entry, workload, lambda, seed, &gen, group_size)?;
        if use_cache {
            self.cache.lock().expect("cache lock").insert(
                fp,
                workload,
                CacheEntry { lambda, objective: core.objective, ws: core.ws.clone() },
            );
        }

        let mut fields = vec![
            kv("dataset", name),
            kv("workload", workload.as_str()),
            kv("init", gen.init.as_str()),
            kv("seeded_by", core.seeded_by),
            kv("lambda", lambda),
            kv("objective", core.objective),
            kv("support", core.support),
            kv("rounds", core.stats.rounds),
            kv("cols_added", core.stats.cols_added),
            kv("rows_added", core.stats.rows_added),
            kv("simplex_iters", core.stats.simplex_iters),
            kv("converged", core.stats.converged),
            kv("working_cols", core.ws.cols.len()),
            kv("working_rows", core.ws.rows.len()),
            kv("warm", hit.is_some()),
        ];
        if let Some(h) = &hit {
            fields.push(kv("warm_lambda", h.entry.lambda));
            fields.push(kv("bucket_distance", h.distance as f64));
        }
        Ok(ok_response("solve", fields))
    }

    fn handle_grid(&self, req: &Req) -> Result<Json> {
        let name = req.str_req("dataset")?;
        let entry = self
            .registry
            .get(name)
            .ok_or_else(|| err!("unknown dataset {name:?} (register it first)"))?;
        let workload = Workload::parse(req.str_req("workload")?)?;
        let k = req.usize_or("grid", 10)?.max(1);
        let ratio = req.f64_or("ratio", 0.7)?;
        ensure!(
            ratio > 0.0 && ratio < 1.0,
            "grid ratio must be in (0, 1), got {ratio}"
        );
        let gen = gen_from_req(req)?;
        let use_cache = req.bool_or("cache", true)?;
        let path: Vec<PathSolution> = match workload {
            Workload::L1svm => {
                let ds = entry.classification();
                let backend = NativeBackend::new(&ds.x);
                let grid = geometric_grid(ds.lambda_max_l1(), k, ratio);
                regularization_path(ds, &backend, &grid, &gen).0
            }
            Workload::Ranksvm => {
                let ds = &entry.ds;
                let mut owned_pairs = None;
                let pairs = pairs_for(&entry, gen.pair_mode, &mut owned_pairs)?;
                let backend = NativeBackend::new(&ds.x);
                let grid = geometric_grid(lambda_max_rank(ds, pairs), k, ratio);
                ranksvm_path(ds, &backend, pairs, &grid, &gen)
            }
            Workload::Dantzig => {
                let ds = &entry.ds;
                let backend = NativeBackend::new(&ds.x);
                let grid = geometric_grid(lambda_max_dantzig(ds), k, ratio);
                dantzig_path(ds, &backend, &grid, &gen)
            }
            other => bail!(
                "grid routes through the warm-started path drivers, available for \
                 l1svm|ranksvm|dantzig (got {:?})",
                other.as_str()
            ),
        };
        // Seed the warm-start cache at EVERY visited λ: a later fixed-λ
        // solve anywhere near the grid resumes from the matching
        // snapshot instead of starting cold.
        let mut seeded = 0usize;
        if use_cache {
            // same key derivation as `solve`, so grid-seeded snapshots
            // actually hit on later fixed-λ requests (grid workloads
            // exclude Group, so the group size never applies here)
            let fp = cache_fp(&entry, workload, 0);
            let mut cache = self.cache.lock().expect("cache lock");
            for pt in &path {
                if !pt.ws.is_empty() {
                    cache.insert(
                        fp,
                        workload,
                        CacheEntry {
                            lambda: pt.lambda,
                            objective: pt.objective,
                            ws: pt.ws.clone(),
                        },
                    );
                    seeded += 1;
                }
            }
        }
        let last = path.last().expect("grid has at least one point");
        let (rounds, simplex_iters) = (last.stats.rounds, last.stats.simplex_iters);
        let points: Vec<Json> = path
            .into_iter()
            .map(|pt| {
                Json::obj(vec![
                    kv("lambda", pt.lambda),
                    kv("objective", pt.objective),
                    kv("support", pt.support),
                    kv("working_set", pt.working_set),
                ])
            })
            .collect();
        Ok(ok_response(
            "grid",
            vec![
                kv("dataset", name),
                kv("workload", workload.as_str()),
                kv("points", points.len()),
                kv("rounds", rounds),
                kv("simplex_iters", simplex_iters),
                kv("cache_seeded", seeded),
                kv("path", points),
            ],
        ))
    }

    fn stats_response(&self) -> Json {
        let cache = self.cache.lock().expect("cache lock");
        // One object per dataset: shape, stored nonzeros, density, and
        // the estimated resident bytes of the design (dense buffer, or
        // both CSR+CSC copies for sparse) — enough to see from outside
        // whether a dataset is riding the sparse kernels and what it
        // costs to keep resident.
        let datasets: Vec<Json> = self
            .registry
            .names()
            .into_iter()
            .filter_map(|name| self.registry.get(&name))
            .map(|entry| {
                let x = &entry.ds.x;
                let cells = (entry.ds.n() * entry.ds.p()).max(1);
                Json::obj(vec![
                    kv("name", entry.name.clone()),
                    kv("n", entry.ds.n()),
                    kv("p", entry.ds.p()),
                    kv("nnz", x.nnz()),
                    kv("density", x.nnz() as f64 / cells as f64),
                    kv("sparse", x.is_sparse()),
                    kv("resident_bytes", x.resident_bytes()),
                ])
            })
            .collect();
        ok_response(
            "stats",
            vec![
                kv("requests", self.requests.load(Ordering::Relaxed) as usize),
                kv("datasets", datasets),
                kv("cache_entries", cache.len()),
                kv("cache_hits", cache.hits as usize),
                kv("cache_misses", cache.misses as usize),
            ],
        )
    }
}

/// Resolve a ranking request's comparison-pair set: the registry's
/// shared Auto [`PairSet`] (built once per dataset), or a request-local
/// one when the request forces a representation. Forcing `enumerate`
/// past the Auto threshold is refused — one request must not allocate
/// the O(n²) pair list inside the long-running service (an aborting
/// allocation would take the whole daemon down, not just the request).
fn pairs_for<'e>(
    entry: &'e DatasetEntry,
    mode: PairMode,
    owned: &'e mut Option<PairSet>,
) -> Result<&'e PairSet> {
    let shared = entry.pairs();
    let pairs: &PairSet = match mode {
        PairMode::Auto => shared,
        // honor the forced representation, but reuse the shared set
        // when it already is one (no per-request rebuild)
        PairMode::Implicit if !shared.is_enumerated() => shared,
        PairMode::Implicit => {
            owned.insert(PairSet::build(&entry.ds.y, PairMode::Implicit))
        }
        PairMode::Enumerate => {
            // Auto enumerates exactly when |P| ≤ ENUM_PAIR_CAP, so a
            // shared implicit set means the list is over the cap —
            // refuse rather than let one request allocate the O(n²)
            // list inside the daemon.
            ensure!(
                shared.is_enumerated(),
                "pair_mode \"enumerate\" would materialize {} pairs (cap {}); \
                 use \"auto\" or \"implicit\"",
                shared.len(),
                crate::workloads::pairset::ENUM_PAIR_CAP
            );
            shared
        }
    };
    ensure!(!pairs.is_empty(), "no comparison pairs: all responses are tied");
    Ok(pairs)
}

/// The warm-cache key for one `(dataset, workload)` request. Group
/// working sets are group indices, so snapshots are only compatible
/// between requests with the same grouping: the group size folds into
/// the fingerprint. RankSVM row snapshots address the canonical
/// pair-index space, so the [`PairSet::fingerprint`] folds in — it is
/// representation-independent, which is what lets snapshots written
/// under one [`PairMode`] warm-start solves under another.
fn cache_fp(entry: &DatasetEntry, workload: Workload, group_size: usize) -> u64 {
    match workload {
        Workload::Group => {
            entry.fingerprint ^ (group_size as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        }
        Workload::Ranksvm => entry.fingerprint ^ entry.pairs().fingerprint(),
        _ => entry.fingerprint,
    }
}

/// Resolve the request's λ: an absolute `"lambda"` wins, otherwise
/// `"lambda_frac"` (default 0.05, Dantzig 0.3) times the workload's
/// λ_max on this dataset. For Slope the resolved value is the scale λ̃
/// of the Benjamini–Hochberg weight sequence.
fn lambda_for(
    entry: &DatasetEntry,
    workload: Workload,
    req: &Req,
    group_size: usize,
) -> Result<f64> {
    if let Some(v) = req.0.get("lambda") {
        let lambda = v.as_f64().ok_or_else(|| err!("field \"lambda\" must be a number"))?;
        ensure!(lambda.is_finite() && lambda > 0.0, "lambda must be positive, got {lambda}");
        return Ok(lambda);
    }
    let frac_default = match workload {
        Workload::Dantzig => 0.3,
        _ => 0.05,
    };
    let frac = req.f64_or("lambda_frac", frac_default)?;
    ensure!(frac.is_finite() && frac > 0.0, "lambda_frac must be positive, got {frac}");
    let lmax = match workload {
        Workload::L1svm | Workload::Slope => entry.classification().lambda_max_l1(),
        Workload::Group => {
            let ds = entry.classification();
            let groups = contiguous_groups(ds.p(), group_size)?;
            ds.lambda_max_group(&groups)
        }
        Workload::Ranksvm => {
            let pairs = entry.pairs();
            ensure!(!pairs.is_empty(), "no comparison pairs: all responses are tied");
            lambda_max_rank(&entry.ds, pairs)
        }
        Workload::Dantzig => lambda_max_dantzig(&entry.ds),
    };
    Ok(frac * lmax)
}

/// Fold the request knobs shared by `solve` and `grid` into a
/// [`GenParams`] (`solve` layers its per-round expansion caps on top).
fn gen_from_req(req: &Req) -> Result<GenParams> {
    Ok(GenParams {
        eps: req.f64_or("eps", 1e-2)?,
        threads: req.usize_or("threads", 1)?.max(1),
        init: init_for(req)?,
        seed_budget: req.usize_or("seed_budget", crate::engine::DEFAULT_SEED_BUDGET)?.max(1),
        pair_mode: pair_mode_for(req)?,
        ..Default::default()
    })
}

/// Parse the optional `"pair_mode"` field (default `auto`): the RankSVM
/// pair-channel representation. `auto` uses the registry's shared
/// [`PairSet`]; `enumerate`/`implicit` build a request-local one in the
/// forced representation (the canonical index space — and therefore the
/// warm-start cache — is identical either way).
fn pair_mode_for(req: &Req) -> Result<PairMode> {
    match req.str_opt("pair_mode") {
        Some(s) => PairMode::parse(s),
        None => {
            ensure!(
                req.0.get("pair_mode").is_none(),
                "field \"pair_mode\" must be a string (auto|enumerate|implicit)"
            );
            Ok(PairMode::Auto)
        }
    }
}

/// Parse the optional `"init"` strategy field (default `auto`, i.e. the
/// per-workload first-order default on a cache miss).
fn init_for(req: &Req) -> Result<InitStrategy> {
    match req.str_opt("init") {
        Some(s) => InitStrategy::parse(s),
        None => {
            ensure!(
                req.0.get("init").is_none(),
                "field \"init\" must be a strategy string \
                 (auto|screening|fista|blockcd|subsample); the seed size knob is \"seed_budget\""
            );
            Ok(InitStrategy::Auto)
        }
    }
}

fn contiguous_groups(p: usize, group_size: usize) -> Result<Vec<Vec<usize>>> {
    let gs = group_size.max(1);
    ensure!(p % gs == 0, "group workload needs p divisible by group_size ({p} % {gs} != 0)");
    Ok((0..p / gs).map(|g| (g * gs..(g + 1) * gs).collect()).collect())
}

/// The part of a solve the protocol reports: objective, support, engine
/// counters, and the exported snapshot that feeds the cache.
pub struct SolveCore {
    /// λ the solve ran at.
    pub lambda: f64,
    /// Full-problem objective.
    pub objective: f64,
    /// Nonzero coefficients.
    pub support: usize,
    /// Engine counters for this run.
    pub stats: GenStats,
    /// Final working sets (the cacheable snapshot).
    pub ws: WorkingSet,
    /// What seeded the restricted model: `"cache"` for a warm snapshot,
    /// else the resolved [`InitStrategy`] that actually ran (`Auto`
    /// already mapped to its per-workload default).
    pub seeded_by: &'static str,
}

/// Solve one request: seed the restricted model from `seed` when warm,
/// from the shared [`Initializer`] otherwise (a cache miss runs the §4
/// first-order seed by default — [`InitStrategy::Auto`] — instead of
/// bare screening), run the engine, and export the final working sets.
pub fn solve_one(
    entry: &DatasetEntry,
    workload: Workload,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
    group_size: usize,
) -> Result<SolveCore> {
    match workload {
        Workload::L1svm => solve_l1(entry, lambda, seed, gen),
        Workload::Group => solve_group(entry, lambda, seed, gen, group_size),
        Workload::Slope => solve_slope(entry, lambda, seed, gen),
        Workload::Ranksvm => solve_ranksvm(entry, lambda, seed, gen),
        Workload::Dantzig => solve_dantzig(entry, lambda, seed, gen),
    }
}

fn solve_l1(
    entry: &DatasetEntry,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
) -> Result<SolveCore> {
    let ds = entry.classification();
    let backend = NativeBackend::new(&ds.x);
    let pricer = BackendPricer::new(&backend, gen.threads);
    let all_i: Vec<usize> = (0..ds.n()).collect();
    let (j_init, seeded_by): (Vec<usize>, &'static str) = match seed {
        Some(ws) if !ws.cols.is_empty() => (ws.cols.clone(), "cache"),
        _ => {
            // Algorithm 1 keeps all margin rows: the column-only seed
            // skips the discarded violated-row scan
            let s = Initializer::from_params(gen).seed_l1_cols(ds, &backend, lambda);
            (s.ws.cols, s.strategy.as_str())
        }
    };
    let mut rl1 = RestrictedL1::new(ds, lambda, &all_i, &j_init);
    rl1.set_threads(gen.threads);
    let mut prob = L1Problem::new(rl1, ds, &pricer, false, true);
    let stats = GenEngine::new(gen).run(&mut prob);
    let mut ws = prob.export_working_set();
    // Algorithm 1 keeps every margin row in the model; snapshotting the
    // full [n] would only bloat the cache.
    ws.rows.clear();
    let (support, b0) = prob.inner().beta_support();
    let report = l1_report(ds, &support, b0, lambda);
    Ok(SolveCore {
        lambda,
        objective: report.objective,
        support: report.support,
        stats,
        ws,
        seeded_by,
    })
}

fn solve_group(
    entry: &DatasetEntry,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
    group_size: usize,
) -> Result<SolveCore> {
    let ds = entry.classification();
    let groups = contiguous_groups(ds.p(), group_size)?;
    let backend = NativeBackend::new(&ds.x);
    let pricer = BackendPricer::new(&backend, gen.threads);
    let (g_init, seeded_by): (Vec<usize>, &'static str) = match seed {
        Some(ws) if !ws.cols.is_empty() => (ws.cols.clone(), "cache"),
        _ => {
            let s = Initializer::from_params(gen).seed_group(ds, &groups, lambda);
            (s.ws.cols, s.strategy.as_str())
        }
    };
    ensure!(
        g_init.iter().all(|&g| g < groups.len()),
        "snapshot group index out of range for group_size {group_size}"
    );
    let mut rg = RestrictedGroup::new(ds, &groups, lambda, &g_init);
    rg.set_threads(gen.threads);
    let mut prob = GroupProblem::new(rg, ds, &pricer);
    let stats = GenEngine::new(gen).run(&mut prob);
    let ws = prob.export_working_set();
    let (support, b0) = prob.inner().beta_support();
    let report = group_report(ds, &groups, &support, b0, lambda);
    Ok(SolveCore {
        lambda,
        objective: report.objective,
        support: report.support,
        stats,
        ws,
        seeded_by,
    })
}

fn solve_slope(
    entry: &DatasetEntry,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
) -> Result<SolveCore> {
    let ds = entry.classification();
    let weights = bh_slope_weights(ds.p(), lambda);
    let backend = NativeBackend::new(&ds.x);
    let pricer = BackendPricer::new(&backend, gen.threads);
    let (j_init, seeded_by): (Vec<usize>, &'static str) = match seed {
        Some(ws) if !ws.cols.is_empty() => (ws.cols.clone(), "cache"),
        _ => {
            let s = Initializer::from_params(gen).seed_slope(ds, &weights);
            (s.ws.cols, s.strategy.as_str())
        }
    };
    // Slope caps column additions per round (paper: 10).
    let mut eng = gen.clone();
    if eng.max_cols_per_round == 0 {
        eng.max_cols_per_round = 10;
    }
    let mut rs = RestrictedSlope::new(ds, &weights, &j_init);
    rs.set_threads(gen.threads);
    let mut prob = SlopeProblem::new(rs, ds, &pricer, true);
    let stats = GenEngine::new(&eng).run(&mut prob);
    let ws = prob.export_working_set();
    let (support, b0) = prob.inner().beta_support();
    let report = slope_report(ds, &weights, &support, b0);
    Ok(SolveCore {
        lambda,
        objective: report.objective,
        support: report.support,
        stats,
        ws,
        seeded_by,
    })
}

fn solve_ranksvm(
    entry: &DatasetEntry,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
) -> Result<SolveCore> {
    let ds = &entry.ds;
    let mut owned_pairs = None;
    let pairs = pairs_for(entry, gen.pair_mode, &mut owned_pairs)?;
    let backend = NativeBackend::new(&ds.x);
    let pricer = BackendPricer::new(&backend, gen.threads);
    let (t_init, j_init, seeded_by) = match seed {
        Some(ws) if !ws.is_empty() => (ws.rows.clone(), ws.cols.clone(), "cache"),
        _ => {
            let s = Initializer::from_params(gen).seed_ranksvm(ds, &backend, pairs, lambda);
            (s.ws.rows, s.ws.cols, s.strategy.as_str())
        }
    };
    ensure!(
        t_init.iter().all(|&t| t < pairs.len()),
        "snapshot pair index out of range (stale pair enumeration?)"
    );
    let mut rr = RestrictedRank::new(ds, pairs, lambda, &t_init, &j_init);
    rr.set_threads(gen.threads);
    rr.set_pair_cap(pair_rows_cap(gen));
    let mut prob = RankProblem::new(rr, ds, &pricer);
    let stats = GenEngine::new(gen).run(&mut prob);
    let ws = prob.export_working_set();
    let report = ranksvm_report(ds, pairs, &prob.inner().beta_support(), lambda);
    Ok(SolveCore {
        lambda,
        objective: report.objective,
        support: report.support,
        stats,
        ws,
        seeded_by,
    })
}

fn solve_dantzig(
    entry: &DatasetEntry,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
) -> Result<SolveCore> {
    let ds = &entry.ds;
    let backend = NativeBackend::new(&ds.x);
    let pricer = BackendPricer::new(&backend, gen.threads);
    let mut rd = RestrictedDantzig::new(ds, lambda, &[]);
    rd.set_threads(gen.threads);
    let mut prob = DantzigProblem::new(rd, ds, &pricer);
    let seeded_by = match seed {
        Some(ws) if !ws.is_empty() => {
            prob.import_working_set(ws);
            "cache"
        }
        _ => {
            let cold = Initializer::from_params(gen).seed_dantzig(ds, &backend, lambda);
            prob.import_working_set(&cold.ws);
            cold.strategy.as_str()
        }
    };
    let stats = GenEngine::new(gen).run(&mut prob);
    let ws = prob.export_working_set();
    let report = dantzig_report(ds.p(), &prob.inner().beta_support());
    Ok(SolveCore {
        lambda,
        // restricted LP objective, matching `dantzig_path`/`finish`
        objective: prob.inner().objective(),
        support: report.support,
        stats,
        ws,
        seeded_by,
    })
}
