//! The persistent solve service: a zero-dependency daemon that amortizes
//! dataset loading and working-set discovery across requests.
//!
//! Every one-shot `cutgen` invocation rebuilds everything from scratch;
//! this subsystem keeps the expensive state alive between requests:
//!
//! * [`registry::Registry`] — each design matrix is loaded and
//!   fingerprinted **once** and shared via `Arc` across requests and
//!   worker threads;
//! * [`cache::WarmCache`] — after every solve the final working sets are
//!   snapshotted (`engine::Snapshot`) under a `(dataset, workload,
//!   λ-bucket)` key; a later request near a previously solved λ seeds
//!   its restricted model from the snapshot and resumes generation
//!   instead of starting cold — Algorithm 2's warm-start observation,
//!   request-shaped;
//! * a **grid endpoint** that routes through the warm-started λ-path
//!   drivers in `coordinator::path` and seeds the warm-start cache at
//!   **every** visited λ, so later fixed-λ requests near the grid resume
//!   warm;
//! * an **exact-path endpoint** (`path_exact`) that rides the
//!   parametric-simplex breakpoint path of [`crate::coordinator::path_exact`]
//!   — pricing the implicit column/constraint space only where the
//!   restricted basis actually changes — and seeds the cache at every
//!   breakpoint, so the whole λ-segment structure becomes warm-start
//!   coverage;
//! * **incremental datasets** — the `update` op derives a new
//!   registered dataset from a parent (samples retired by index and/or
//!   appended from another registered dataset) and re-keys the parent's
//!   feature-indexed snapshots to the child's fingerprint, so the
//!   derived dataset re-solves warm instead of cold;
//! * **first-order cold starts**: a cache miss seeds the restricted
//!   model through the shared `engine::Initializer` (§4 FOM seeding by
//!   default; the request's `"init"` field picks
//!   `auto|screening|fista|blockcd|subsample`, `"seed_budget"` sizes the
//!   seed).
//!
//! Production hardening (all opt-in per request or per daemon):
//!
//! * **deadlines** — a request's `"deadline_ms"` installs a cooperative
//!   stop callback in the generation loop
//!   (`engine::GenEngine::with_should_stop`); an expired solve returns
//!   the best-so-far restricted solution with `"timed_out":true`
//!   instead of holding a worker until convergence;
//! * **LRU + byte-budgeted cache** — [`cache::WarmCache`] evicts by
//!   recency under both an entry cap and an optional resident-byte
//!   budget ([`ServeState::with_cache_bytes`]), reported in `stats`;
//! * **registry-level eviction** — the `unregister` op drops a dataset
//!   and purges its warm-cache snapshots, and
//!   [`ServeState::with_registry_bytes`] bounds the total estimated
//!   bytes of registered datasets, evicting the least-recently-used
//!   dataset (exactly as if it had been `unregister`ed) when a
//!   registration pushes the registry over budget;
//! * **snapshot persistence** — with a persist directory
//!   ([`ServeState::with_persist_dir`]) every cache insert is spilled
//!   to disk ([`persist::SnapshotStore`]) and an in-memory miss lazily
//!   probes the store, so a restarted daemon warm-hits its
//!   predecessor's λ's;
//! * **batched solves** — the `batch` op runs heterogeneous
//!   `(workload, λ)` requests against one dataset through the shared
//!   warm-start machinery (later items warm-hit earlier items'
//!   snapshots) under one shared deadline;
//! * **admission control** — [`ServeState::with_max_inflight`] bounds
//!   concurrently executing solve/grid/batch requests; beyond the bound
//!   the daemon answers `{"ok":false,…,"retry_after":…}` immediately
//!   instead of queueing unboundedly (the TCP accept queue is bounded
//!   the same way in [`transport::serve_tcp`]);
//! * **observability** — every request is counted and latency-bucketed
//!   into the always-on [`obs::Registry`] (the `metrics` op renders it
//!   as Prometheus text exposition); `"trace": true` on a solve/grid
//!   returns the per-round engine events inline, and `--slow-solve-ms`
//!   logs a structured line (with the ring-buffered round trace) for
//!   any heavy request over the threshold. `docs/observability.md` is
//!   the metric and trace-schema catalogue.
//!
//! The protocol is line-delimited JSON (one request object per line, one
//! response per line, in order — [`json`] is the hand-rolled
//! reader/writer) over two transports ([`transport`]): a
//! `std::net::TcpListener` with a scoped worker pool, and a
//! stdin/stdout mode (`cutgen serve --stdin`) so tests and CI exercise
//! the full protocol without opening a port. `docs/serving.md` is the
//! protocol reference.

#![warn(missing_docs)]

pub mod cache;
pub mod json;
pub mod persist;
pub mod protocol;
pub mod registry;
pub mod transport;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::backend::NativeBackend;
use crate::coordinator::group::{GroupProblem, RestrictedGroup};
use crate::coordinator::l1svm::{L1Problem, RestrictedL1};
use crate::coordinator::path::{
    accumulate, dantzig_path_with_stop, geometric_grid, group_path_with_stop,
    ranksvm_path_with_stop, regularization_path_with_stop, PathSolution,
};
use crate::coordinator::path_exact::{
    dantzig_path_exact_with_stop, l1svm_path_exact_with_stop, ranksvm_path_exact_with_stop,
    ExactPath,
};
use crate::coordinator::report::{
    dantzig_report, group_report, l1_report, ranksvm_report, slope_report,
};
use crate::coordinator::slope::{RestrictedSlope, SlopeProblem};
use crate::coordinator::{GenParams, GenStats};
use crate::engine::{
    BackendPricer, GenEngine, InitStrategy, Initializer, PairMode, RatioTarget, Snapshot,
    WorkingSet,
};
use crate::error::Result;
use crate::fom::objective::bh_slope_weights;
use crate::obs::{self, latency_bounds, stderr_line, RingSink, RoundEvent, Span, TraceSink};
use crate::workloads::dantzig::{lambda_max_dantzig, DantzigProblem, RestrictedDantzig};
use crate::workloads::pairset::{PairCosts, PairSet};
use crate::workloads::ranksvm::{lambda_max_rank, pair_rows_cap, RankProblem, RestrictedRank};
use crate::{bail, ensure, err};

use cache::{lambda_bucket, CacheEntry, CacheHit, WarmCache, NEIGHBORHOOD};
use json::{kv, Json};
use persist::SnapshotStore;
use protocol::{err_response, ok_response, Req, Workload};
use registry::{DatasetEntry, Registry, SynthOpts};

/// Default bound on cached working-set snapshots.
pub const DEFAULT_CACHE_CAP: usize = 256;

/// Hard cap on `"requests"` items in one `batch` op — a bound on how
/// long one protocol line can monopolize a worker, not a throughput
/// knob (split larger sweeps across lines; responses stream per line).
pub const MAX_BATCH_REQUESTS: usize = 1024;

/// Backoff hint (milliseconds) carried by admission-control rejections.
pub const RETRY_AFTER_MS: usize = 250;

/// Bound on ring-buffered round events per traced request (`"trace":
/// true` responses and slow-solve log lines keep the *last* this many
/// rounds; earlier rounds are counted in `"trace_dropped"`).
pub const TRACE_RING_CAP: usize = 512;

/// `{"ok":false,…}` with the `retry_after` backoff hint — what an
/// admission-controlled daemon answers (instead of queueing) when all
/// solve slots are busy. Shared by the dispatch layer and the TCP
/// accept-queue bound in [`transport::serve_tcp`].
pub fn busy_response() -> Json {
    Json::obj(vec![
        kv("ok", false),
        kv("error", "server at capacity, retry later"),
        kv("retry_after", RETRY_AFTER_MS),
    ])
}

/// A per-request wall-clock budget. One instance is shared by every
/// solve a request covers (all items of a `batch`), so the budget bounds
/// the request, not each solve.
struct Deadline {
    start: Instant,
    budget: Duration,
}

impl Deadline {
    fn expired(&self) -> bool {
        self.start.elapsed() >= self.budget
    }
}

/// Parse the optional `"deadline_ms"` field (0 or absent = none) into a
/// running deadline.
fn deadline_from(req: &Req) -> Result<Option<Deadline>> {
    let ms = req.usize_or("deadline_ms", 0)?;
    Ok((ms > 0).then(|| Deadline {
        start: Instant::now(),
        budget: Duration::from_millis(ms as u64),
    }))
}

/// All shared service state: registry, warm-start cache, counters, and
/// the shutdown flag. One instance serves every connection; requests
/// only hold the cache lock around lookups/inserts, never during solves.
pub struct ServeState {
    /// The dataset registry (name → `Arc`-shared entry).
    pub registry: Registry,
    cache: Mutex<WarmCache>,
    /// Disk spill/reload for snapshots (None = memory-only cache).
    store: Option<SnapshotStore>,
    requests: AtomicU64,
    /// In-memory misses that were then served from the snapshot store.
    disk_hits: AtomicU64,
    /// Solve/grid/batch requests currently executing.
    inflight: AtomicUsize,
    /// Admission bound on concurrently executing solve/grid/batch
    /// requests (`usize::MAX` = unbounded; 0 = reject all heavy ops,
    /// i.e. drain mode).
    max_inflight: usize,
    shutdown: AtomicBool,
    /// Byte budget for registered datasets (0 = unbounded); see
    /// [`ServeState::with_registry_bytes`].
    registry_max_bytes: usize,
    /// Datasets evicted to satisfy the registry byte budget.
    registry_evictions: AtomicU64,
    /// Always-on metrics registry, rendered by the `metrics` op.
    /// Request counters and latency histograms are recorded at dispatch
    /// time; cache/gauge mirrors are refreshed at scrape time from
    /// their authoritative sources, so `metrics` and `stats` agree.
    pub metrics: obs::Registry,
    /// Monotone per-request id, threaded through log lines so a slow
    /// solve's trace can be correlated with transport-level logging.
    next_req_id: AtomicU64,
    /// Heavy requests slower than this (milliseconds; 0 = disabled) log
    /// one structured stderr line carrying their round trace.
    slow_solve_ms: u64,
}

impl ServeState {
    /// Fresh state with a warm-start cache bounded to `cache_cap`
    /// entries (no byte budget, no persistence, unbounded admission).
    pub fn new(cache_cap: usize) -> Self {
        Self {
            registry: Registry::new(),
            cache: Mutex::new(WarmCache::new(cache_cap)),
            store: None,
            requests: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            inflight: AtomicUsize::new(0),
            max_inflight: usize::MAX,
            shutdown: AtomicBool::new(false),
            registry_max_bytes: 0,
            registry_evictions: AtomicU64::new(0),
            metrics: obs::Registry::new(),
            next_req_id: AtomicU64::new(0),
            slow_solve_ms: 0,
        }
    }

    /// Bound the warm cache's estimated resident bytes (0 = unbounded);
    /// see [`WarmCache::set_max_bytes`].
    pub fn with_cache_bytes(self, max_bytes: usize) -> Self {
        self.cache.lock().expect("cache lock").set_max_bytes(max_bytes);
        self
    }

    /// Bound the total estimated resident bytes of registered datasets
    /// (0 = unbounded). When a registration pushes the registry over the
    /// budget, least-recently-used datasets are evicted exactly as if
    /// they had been `unregister`ed — name dropped, warm-cache
    /// snapshots purged — until the total fits, never evicting the
    /// dataset that was just registered (the bound is therefore
    /// `max(registry_bytes, largest single dataset)`).
    pub fn with_registry_bytes(mut self, max_bytes: usize) -> Self {
        self.registry_max_bytes = max_bytes;
        self
    }

    /// Spill warm-start snapshots to `dir` (created if missing) and
    /// lazily reload them on in-memory misses, so the cache survives a
    /// daemon restart. See [`persist::SnapshotStore`] for the on-disk
    /// format.
    pub fn with_persist_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Result<Self> {
        self.store = Some(SnapshotStore::open(dir)?);
        Ok(self)
    }

    /// Bound concurrently executing solve/grid/batch requests: beyond
    /// `max` the daemon responds [`busy_response`] immediately instead
    /// of queueing. 0 rejects every heavy op (drain mode); lightweight
    /// ops (`ping`, `stats`, `metrics`, `register`, `shutdown`) are
    /// never gated.
    pub fn with_max_inflight(mut self, max: usize) -> Self {
        self.max_inflight = max;
        self
    }

    /// Log a structured slow-solve line (request id, span breakdown,
    /// and the ring-buffered round trace) for any solve/grid slower
    /// than `ms` milliseconds. 0 disables the threshold.
    pub fn with_slow_solve_ms(mut self, ms: u64) -> Self {
        self.slow_solve_ms = ms;
        self
    }

    /// Whether a `shutdown` request has been received.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Try to claim a solve slot; `None` means the daemon is at its
    /// admission bound and the request must be rejected with
    /// [`busy_response`]. The returned guard releases the slot on drop
    /// (including on panic or error paths).
    fn admit(&self) -> Option<InflightGuard<'_>> {
        let mut cur = self.inflight.load(Ordering::SeqCst);
        loop {
            if cur >= self.max_inflight {
                return None;
            }
            match self.inflight.compare_exchange(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return Some(InflightGuard(self)),
                Err(now) => cur = now,
            }
        }
    }

    /// Warm-start lookup: the in-memory cache first, then (on a miss,
    /// when persistence is on) the snapshot store, scanning the same
    /// λ-bucket neighborhood the cache does. A disk hit is promoted
    /// into the in-memory cache so the next request stays off the
    /// filesystem.
    fn warm_lookup(&self, fp: u64, workload: Workload, lambda: f64) -> Option<CacheHit> {
        let mem = self.cache.lock().expect("cache lock").lookup(fp, workload, lambda);
        if mem.is_some() {
            return mem;
        }
        let store = self.store.as_ref()?;
        let bucket = lambda_bucket(lambda);
        for distance in 0..=NEIGHBORHOOD {
            for b in [bucket - distance, bucket + distance] {
                if let Some(entry) = store.load(fp, workload, b) {
                    self.disk_hits.fetch_add(1, Ordering::Relaxed);
                    self.cache.lock().expect("cache lock").insert(fp, workload, entry.clone());
                    return Some(CacheHit { entry, distance });
                }
                if distance == 0 {
                    break; // bucket − 0 == bucket + 0
                }
            }
        }
        None
    }

    /// Insert a snapshot into the in-memory cache, spilling it to the
    /// snapshot store first when persistence is on. A failed spill is
    /// logged and swallowed — persistence is an optimization, never a
    /// reason to fail the solve that produced the snapshot.
    fn cache_store(&self, fp: u64, workload: Workload, entry: CacheEntry) {
        if let Some(store) = &self.store {
            if let Err(e) = store.save(fp, workload, &entry) {
                stderr_line(&format!("[serve] snapshot spill failed: {e}"));
            }
        }
        self.cache.lock().expect("cache lock").insert(fp, workload, entry);
    }

    /// Handle one request line, returning the response line. Never
    /// panics on protocol input: parse and dispatch errors become
    /// `{"ok":false,"error":…}` responses. Every line — including
    /// malformed ones — is counted and latency-bucketed into the
    /// metrics registry under its `(op, workload)` pair.
    pub fn handle_line(&self, line: &str) -> String {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let req_id = self.next_req_id.fetch_add(1, Ordering::Relaxed) + 1;
        let span = Span::start();
        let mut op_label = "invalid";
        let mut wl_label = "none";
        let resp = match Json::parse(line) {
            Ok(doc) => {
                let req = Req(&doc);
                match req.str_req("op") {
                    Ok(op) => {
                        op_label = op_metric_label(op);
                        wl_label = workload_metric_label(&req);
                        self.dispatch(op, &req, req_id)
                            .unwrap_or_else(|e| err_response(&e.to_string()))
                    }
                    Err(e) => err_response(&e.to_string()),
                }
            }
            Err(e) => err_response(&e.to_string()),
        };
        self.metrics
            .counter("cutgen_requests_total", "Requests handled, by op.", &[("op", op_label)])
            .inc();
        self.metrics
            .histogram(
                "cutgen_request_latency_seconds",
                "Wall-clock request latency, by op and workload.",
                &[("op", op_label), ("workload", wl_label)],
                &latency_bounds(),
            )
            .observe_ns(span.elapsed_ns());
        resp.to_string()
    }

    fn dispatch(&self, op: &str, req: &Req, req_id: u64) -> Result<Json> {
        match op {
            "register" => self.handle_register(req),
            "unregister" => self.handle_unregister(req),
            "update" => self.handle_update(req),
            // the heavy ops pass admission control: over the inflight
            // bound they are rejected with a retry_after hint instead of
            // queueing unboundedly behind a busy worker pool
            "solve" | "grid" | "path_exact" | "batch" => match self.admit() {
                Some(_slot) => match op {
                    "solve" => self.handle_solve(req, req_id),
                    "grid" => self.handle_grid(req, req_id),
                    "path_exact" => self.handle_path_exact(req, req_id),
                    _ => self.handle_batch(req, req_id),
                },
                None => {
                    self.metrics
                        .counter(
                            "cutgen_admission_rejections_total",
                            "Heavy requests rejected at the inflight bound.",
                            &[],
                        )
                        .inc();
                    Ok(busy_response())
                }
            },
            "stats" => Ok(self.stats_response()),
            "metrics" => Ok(self.metrics_response()),
            "ping" => Ok(ok_response("ping", Vec::new())),
            "shutdown" => {
                self.shutdown.store(true, Ordering::SeqCst);
                Ok(ok_response("shutdown", Vec::new()))
            }
            other => {
                bail!(
                    "unknown op {other:?} (register|unregister|update|solve|grid|\
                     path_exact|batch|stats|metrics|ping|shutdown)"
                )
            }
        }
    }

    fn handle_register(&self, req: &Req) -> Result<Json> {
        let name = req.str_req("name")?;
        let entry = if let Some(path) = req.str_opt("path") {
            self.registry.register_file(name, path)?
        } else if let Some(synth) = req.0.get("synthetic") {
            let s = Req(synth);
            let kind = s.str_opt("kind").unwrap_or("l1");
            let n = s.usize_or("n", 100)?;
            let p = s.usize_or("p", 1000)?;
            let seed = s.usize_or("seed", 0)? as u64;
            let opts = SynthOpts {
                density: synth.get("density").and_then(Json::as_f64),
                group_size: synth.get("group_size").and_then(Json::as_usize),
            };
            self.registry.register_synthetic(name, kind, n, p, seed, &opts)?
        } else {
            bail!("register needs a \"path\" (libsvm file) or a \"synthetic\" spec");
        };
        self.enforce_registry_budget(name);
        Ok(ok_response(
            "register",
            vec![
                kv("name", name),
                kv("n", entry.ds.n()),
                kv("p", entry.ds.p()),
                kv("nnz", entry.ds.x.nnz()),
                kv("sparse", entry.ds.x.is_sparse()),
                kv("fingerprint", format!("{:016x}", entry.fingerprint)),
            ],
        ))
    }

    /// The `unregister` op: drop a dataset and purge its warm-cache
    /// snapshots. Only the *directly derivable* cache keys are purged —
    /// the base content fingerprint, plus the RankSVM fold when the
    /// pair set was built. Group snapshots fold their group size into
    /// the key and are left to normal LRU eviction: cache entries are
    /// content-keyed, so a leftover snapshot is unreferenced bytes, not
    /// a correctness hazard (see [`WarmCache::purge_fingerprint`]).
    fn handle_unregister(&self, req: &Req) -> Result<Json> {
        let name = req.str_req("name")?;
        let entry = self
            .registry
            .remove(name)
            .ok_or_else(|| err!("unknown dataset {name:?} (nothing to unregister)"))?;
        let freed = entry.resident_bytes();
        let purged = self.purge_cache_for(&entry);
        Ok(ok_response(
            "unregister",
            vec![
                kv("name", name),
                kv("freed_bytes", freed),
                kv("cache_purged", purged),
            ],
        ))
    }

    /// Purge the warm-cache snapshots derivable from a removed entry's
    /// fingerprint, returning how many were dropped.
    fn purge_cache_for(&self, entry: &DatasetEntry) -> usize {
        let mut cache = self.cache.lock().expect("cache lock");
        let mut purged = cache.purge_fingerprint(entry.fingerprint);
        if let Some(pairs) = entry.built_pairs() {
            purged += cache.purge_fingerprint(entry.fingerprint ^ pairs.fingerprint());
        }
        purged
    }

    /// Evict least-recently-used datasets (never `keep`, the name that
    /// was just registered) while the registry is over its byte budget,
    /// treating each victim exactly like an `unregister`. No-op when no
    /// budget is configured.
    fn enforce_registry_budget(&self, keep: &str) {
        if self.registry_max_bytes == 0 {
            return;
        }
        while self.registry.len() > 1
            && self.registry.resident_bytes() > self.registry_max_bytes
        {
            let Some(victim) = self.registry.lru_victim(keep) else { break };
            let Some(entry) = self.registry.remove(&victim) else { break };
            self.registry_evictions.fetch_add(1, Ordering::Relaxed);
            let purged = self.purge_cache_for(&entry);
            stderr_line(&format!(
                "[serve] registry over budget: evicted dataset {victim:?} \
                 ({} bytes, {purged} cache snapshots purged)",
                entry.resident_bytes()
            ));
        }
    }

    /// The `update` op: derive a new registered dataset from a parent —
    /// `"retire"` drops samples by index, `"append_from"` pulls rows
    /// from another registered dataset (same p) — then re-key the
    /// parent's *feature-indexed* warm-cache snapshots (L1-SVM, Slope,
    /// Dantzig) to the child's fingerprint. The paper's warm-start
    /// invariants make those snapshots honest seeds: a changed sample
    /// set moves the optimal basis, but the parent's support is a
    /// dual-feasible working set to resume generation from, so the
    /// child's first solves converge in a few rounds instead of cold.
    /// RankSVM snapshots index sample pairs and Group keys fold the
    /// grouping, so neither is translated.
    fn handle_update(&self, req: &Req) -> Result<Json> {
        let parent_name = req.str_req("dataset")?;
        let name = req.str_req("name")?;
        let parent = self
            .registry
            .get(parent_name)
            .ok_or_else(|| err!("unknown dataset {parent_name:?} (register it first)"))?;
        let n = parent.ds.n();
        let retire = index_list(req.0.get("retire"), "retire", n)?;
        let mut keep_mask = vec![true; n];
        for &i in &retire {
            keep_mask[i] = false;
        }
        let kept: Vec<usize> = (0..n).filter(|&i| keep_mask[i]).collect();
        let retired = n - kept.len();
        let (append_src, append_rows): (Option<Arc<DatasetEntry>>, Vec<usize>) =
            match req.0.get("append_from") {
                None => (None, Vec::new()),
                Some(spec) => {
                    let s = Req(spec);
                    let src_name = s.str_req("dataset")?;
                    let src = self.registry.get(src_name).ok_or_else(|| {
                        err!("unknown append_from dataset {src_name:?} (register it first)")
                    })?;
                    ensure!(
                        src.ds.p() == parent.ds.p(),
                        "append_from dataset has p = {}, parent has p = {}",
                        src.ds.p(),
                        parent.ds.p()
                    );
                    let rows = match spec.get("rows") {
                        None => (0..src.ds.n()).collect(),
                        Some(_) => index_list(spec.get("rows"), "rows", src.ds.n())?,
                    };
                    ensure!(!rows.is_empty(), "append_from \"rows\" must be non-empty");
                    (Some(src), rows)
                }
            };
        ensure!(
            retired > 0 || !append_rows.is_empty(),
            "update needs \"retire\" indices and/or an \"append_from\" spec"
        );
        ensure!(
            !kept.is_empty() || !append_rows.is_empty(),
            "update would produce an empty dataset"
        );
        let x = match &append_src {
            Some(src) => parent.ds.x.stack_rows(&kept, &src.ds.x, &append_rows),
            None => parent.ds.x.subset_rows(&kept),
        };
        let mut y: Vec<f64> = kept.iter().map(|&i| parent.ds.y[i]).collect();
        if let Some(src) = &append_src {
            y.extend(append_rows.iter().map(|&i| src.ds.y[i]));
        }
        let entry = self.registry.insert(name, crate::data::Dataset { x, y });
        self.enforce_registry_budget(name);
        let translated = self
            .cache
            .lock()
            .expect("cache lock")
            .translate_fingerprint(parent.fingerprint, entry.fingerprint);
        // RankSVM snapshots address the parent's canonical *pair* index
        // space, which an edited sample set invalidates — they cannot be
        // re-keyed. Report the skip structurally (count included) instead
        // of letting the child silently cold-solve; see docs/serving.md.
        let rank_skipped = parent.built_pairs().map_or(0, |pp| {
            self.cache
                .lock()
                .expect("cache lock")
                .count_snapshots(parent.fingerprint ^ pp.fingerprint(), Workload::Ranksvm)
        });
        let mut fields = vec![
            kv("name", name),
            kv("parent", parent_name),
            kv("n", entry.ds.n()),
            kv("p", entry.ds.p()),
            kv("retired", retired),
            kv("appended", append_rows.len()),
            kv("fingerprint", format!("{:016x}", entry.fingerprint)),
            kv("cache_translated", translated),
        ];
        if rank_skipped > 0 {
            fields.push(kv("snapshot_skipped", "pair-indexed"));
            fields.push(kv("snapshot_skipped_count", rank_skipped));
        }
        Ok(ok_response("update", fields))
    }

    fn handle_solve(&self, req: &Req, req_id: u64) -> Result<Json> {
        let name = req.str_req("dataset")?;
        let entry = self
            .registry
            .get(name)
            .ok_or_else(|| err!("unknown dataset {name:?} (register it first)"))?;
        let deadline = deadline_from(req)?;
        self.solve_request(name, &entry, req, deadline.as_ref(), req_id)
    }

    /// One fixed-λ solve against an already resolved dataset entry —
    /// the body shared by `solve` (per-request deadline) and each `batch`
    /// item (deadline shared across the whole batch).
    ///
    /// `"trace": true` attaches a bounded [`RingSink`] to the engine and
    /// returns the captured round events inline (`"trace"` array plus a
    /// `"trace_dropped"` count once a run outgrows [`TRACE_RING_CAP`]);
    /// the same ring feeds the `--slow-solve-ms` log line.
    fn solve_request(
        &self,
        name: &str,
        entry: &DatasetEntry,
        req: &Req,
        deadline: Option<&Deadline>,
        req_id: u64,
    ) -> Result<Json> {
        let wall = Span::start();
        let workload = Workload::parse(req.str_req("workload")?)?;
        if req.0.get("target_ratio").is_some() {
            ensure!(
                workload == Workload::Ranksvm,
                "\"target_ratio\" drives the dynamic-λ controller, which applies to the ranksvm \
                 workload only"
            );
            return self.solve_ratio_request(name, entry, req, deadline, req_id, wall);
        }
        let mut gen = gen_from_req(req)?;
        gen.max_cols_per_round = req.usize_or("max_cols_per_round", 0)?;
        gen.max_rows_per_round = req.usize_or("max_rows_per_round", 0)?;
        let group_size = req.usize_or("group_size", 10)?.max(1);
        let use_cache = req.bool_or("cache", true)?;
        let want_trace = req.bool_or("trace", false)?;
        let ring = (want_trace || self.slow_solve_ms > 0)
            .then(|| Arc::new(RingSink::new(TRACE_RING_CAP)));
        if let Some(r) = &ring {
            gen.sink = Some(Arc::clone(r) as Arc<dyn TraceSink>);
        }
        let lambda = lambda_for(entry, workload, req, group_size)?;
        let fp = cache_fp(entry, workload, group_size);

        let hit: Option<CacheHit> = if use_cache {
            self.warm_lookup(fp, workload, lambda)
        } else {
            None
        };
        let seed = hit.as_ref().map(|h| &h.entry.ws);
        // Cooperative stop: the engine polls this once per round, so an
        // expired deadline (or a daemon shutting down) returns the
        // best-so-far restricted solution instead of holding the worker.
        let stop = || {
            if self.shutdown_requested() {
                return true;
            }
            match deadline {
                Some(d) => d.expired(),
                None => false,
            }
        };
        let core = solve_one(entry, workload, lambda, seed, &gen, group_size, Some(&stop))?;
        // Only converged (or stalled-out) working sets feed the cache: a
        // deadline-truncated expansion is a fine answer for its caller
        // but a poor seed to advertise as "converged near this λ".
        if use_cache && !core.stats.timed_out {
            self.cache_store(
                fp,
                workload,
                CacheEntry { lambda, objective: core.objective, ws: core.ws.clone() },
            );
        }
        if core.stats.timed_out {
            self.observe_timeout();
        }

        let wall_ns = wall.elapsed_ns();
        let mut fields = vec![
            kv("dataset", name),
            kv("workload", workload.as_str()),
            kv("init", gen.init.as_str()),
            kv("seeded_by", core.seeded_by),
            kv("lambda", lambda),
            kv("objective", core.objective),
            kv("support", core.support),
            kv("rounds", core.stats.rounds),
            kv("cols_added", core.stats.cols_added),
            kv("rows_added", core.stats.rows_added),
            kv("simplex_iters", core.stats.simplex_iters),
            kv("converged", core.stats.converged),
            kv("timed_out", core.stats.timed_out),
            kv("working_cols", core.ws.cols.len()),
            kv("working_rows", core.ws.rows.len()),
            kv("warm", hit.is_some()),
        ];
        if let Some(h) = &hit {
            fields.push(kv("warm_lambda", h.entry.lambda));
            fields.push(kv("bucket_distance", h.distance as f64));
        }
        if let Some(scan) = core.stats.pair_scan {
            fields.push(kv("pair_scan", scan));
            self.observe_pair_scan(scan);
        }
        // Timing fields ride along only when tracing was asked for:
        // wall clocks are nondeterministic, and untraced responses stay
        // byte-identical across runs (a documented protocol property).
        if want_trace {
            fields.push(kv("wall_ms", ns_to_ms(wall_ns)));
            fields.push(kv("solve_ms", ns_to_ms(core.stats.solve_ns)));
            fields.push(kv("pricing_ms", ns_to_ms(core.stats.pricing_ns)));
            fields.push(kv("seed_ms", ns_to_ms(core.stats.seed_ns)));
            let r = ring.as_ref().expect("ring exists when trace was requested");
            fields.push(kv("trace", trace_events_json(&r.events())));
            fields.push(kv("trace_dropped", r.dropped() as usize));
        }
        let ctx = SlowLogCtx {
            req_id,
            op: "solve",
            dataset: name,
            workload: workload.as_str(),
            lambda,
        };
        self.maybe_log_slow(&ctx, wall_ns, &core.stats, ring.as_deref());
        Ok(ok_response("solve", fields))
    }

    /// One `"target_ratio"` solve: instead of taking λ, run the
    /// dynamic-λ controller
    /// ([`crate::coordinator::controller::resolve_lambda_for_ratio`]),
    /// which bisects λ until the solution's weighted-hinge/‖β‖₁ ratio
    /// lands within `"ratio_tol"` of the target. The converged working
    /// set is cached under the **resolved** λ's bucket — exactly where a
    /// later fixed-λ request near it will look — and the response
    /// carries the resolved λ plus the controller's bookkeeping
    /// (`"achieved_ratio"`, `"controller_solves"`). Available wherever
    /// `solve` is, including `batch` items.
    fn solve_ratio_request(
        &self,
        name: &str,
        entry: &DatasetEntry,
        req: &Req,
        deadline: Option<&Deadline>,
        req_id: u64,
        wall: Span,
    ) -> Result<Json> {
        ensure!(
            req.0.get("lambda").is_none() && req.0.get("lambda_frac").is_none(),
            "\"target_ratio\" resolves λ itself; drop \"lambda\"/\"lambda_frac\""
        );
        let gen = gen_from_req(req)?;
        let ratio = req
            .0
            .get("target_ratio")
            .and_then(Json::as_f64)
            .ok_or_else(|| err!("field \"target_ratio\" must be a number"))?;
        let defaults = RatioTarget::default();
        let target = RatioTarget {
            ratio,
            tol: req.f64_or("ratio_tol", defaults.tol)?,
            max_solves: req.usize_or("max_solves", defaults.max_solves)?,
            ..defaults
        };
        let use_cache = req.bool_or("cache", true)?;
        let ds = &entry.ds;
        let mut owned_pairs = None;
        let pairs = pairs_for(entry, gen.pair_mode, &mut owned_pairs)?;
        let backend = NativeBackend::new(&ds.x);
        let stop = || {
            if self.shutdown_requested() {
                return true;
            }
            match deadline {
                Some(d) => d.expired(),
                None => false,
            }
        };
        let out = crate::coordinator::controller::resolve_lambda_for_ratio(
            ds,
            &backend,
            pairs,
            &PairCosts::UNIFORM,
            &target,
            &gen,
            Some(&stop),
        )
        .map_err(|e| err!("{e}"))?;
        let fp = cache_fp(entry, Workload::Ranksvm, 1);
        if use_cache && !out.total.timed_out {
            self.cache_store(
                fp,
                Workload::Ranksvm,
                CacheEntry {
                    lambda: out.lambda,
                    objective: out.solution.objective,
                    ws: out.ws.clone(),
                },
            );
        }
        if out.total.timed_out {
            self.observe_timeout();
        }
        let wall_ns = wall.elapsed_ns();
        let mut fields = vec![
            kv("dataset", name),
            kv("workload", Workload::Ranksvm.as_str()),
            kv("init", gen.init.as_str()),
            kv("seeded_by", "controller"),
            kv("lambda", out.lambda),
            kv("lambda_max", out.lambda_max),
            kv("target_ratio", ratio),
            kv("achieved_ratio", out.achieved_ratio),
            kv("controller_solves", out.solves),
            kv("objective", out.solution.objective),
            kv("support", out.solution.support_size()),
            kv("rounds", out.total.rounds),
            kv("cols_added", out.total.cols_added),
            kv("rows_added", out.total.rows_added),
            kv("simplex_iters", out.total.simplex_iters),
            kv("converged", out.solution.stats.converged),
            kv("timed_out", out.total.timed_out),
            kv("working_cols", out.ws.cols.len()),
            kv("working_rows", out.ws.rows.len()),
            kv("warm", false),
        ];
        if let Some(scan) = out.total.pair_scan {
            fields.push(kv("pair_scan", scan));
            self.observe_pair_scan(scan);
        }
        let ctx = SlowLogCtx {
            req_id,
            op: "solve",
            dataset: name,
            workload: Workload::Ranksvm.as_str(),
            lambda: out.lambda,
        };
        self.maybe_log_slow(&ctx, wall_ns, &out.total, None);
        Ok(ok_response("solve", fields))
    }

    /// Count one RankSVM pricing scan by strategy (see
    /// [`crate::workloads::pairset::PairScan`]) — how often the
    /// sublinear bucketed/uniform sweeps carry production traffic versus
    /// the enumeration fallbacks.
    fn observe_pair_scan(&self, scan: &'static str) {
        self.metrics
            .counter(
                "cutgen_ranksvm_pair_scans_total",
                "RankSVM pair-channel pricing scans, by strategy.",
                &[("scan", scan)],
            )
            .inc();
    }

    /// The `batch` op: heterogeneous `(workload, λ)` solve items against
    /// **one** dataset, processed in order through the shared dataset
    /// views and warm-start cache — later items warm-hit the snapshots
    /// earlier items just produced, which is what amortizes a
    /// heterogeneous estimator sweep. One `"deadline_ms"` budget covers
    /// the whole batch; per-item failures come back as inline
    /// `{"ok":false,…}` objects in `"results"` without failing the rest.
    fn handle_batch(&self, req: &Req, req_id: u64) -> Result<Json> {
        let name = req.str_req("dataset")?;
        let entry = self
            .registry
            .get(name)
            .ok_or_else(|| err!("unknown dataset {name:?} (register it first)"))?;
        let items = req
            .0
            .get("requests")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("batch needs a \"requests\" array of solve objects"))?;
        ensure!(!items.is_empty(), "batch \"requests\" must be non-empty");
        ensure!(
            items.len() <= MAX_BATCH_REQUESTS,
            "batch capped at {MAX_BATCH_REQUESTS} requests, got {}",
            items.len()
        );
        let deadline = deadline_from(req)?;
        let mut results = Vec::with_capacity(items.len());
        let mut warm_hits = 0usize;
        let mut timed_out = 0usize;
        for item in items {
            let resp = self
                .solve_request(name, &entry, &Req(item), deadline.as_ref(), req_id)
                .unwrap_or_else(|e| err_response(&e.to_string()));
            if resp.get("warm").and_then(Json::as_bool) == Some(true) {
                warm_hits += 1;
            }
            if resp.get("timed_out").and_then(Json::as_bool) == Some(true) {
                timed_out += 1;
            }
            results.push(resp);
        }
        Ok(ok_response(
            "batch",
            vec![
                kv("dataset", name),
                kv("count", results.len()),
                kv("warm_hits", warm_hits),
                kv("timed_out", timed_out),
                kv("results", results),
            ],
        ))
    }

    fn handle_grid(&self, req: &Req, req_id: u64) -> Result<Json> {
        let wall = Span::start();
        let name = req.str_req("dataset")?;
        let entry = self
            .registry
            .get(name)
            .ok_or_else(|| err!("unknown dataset {name:?} (register it first)"))?;
        let workload = Workload::parse(req.str_req("workload")?)?;
        let k = req.usize_or("grid", 10)?.max(1);
        let ratio = req.f64_or("ratio", 0.7)?;
        ensure!(
            ratio > 0.0 && ratio < 1.0,
            "grid ratio must be in (0, 1), got {ratio}"
        );
        let mut gen = gen_from_req(req)?;
        let group_size = req.usize_or("group_size", 10)?.max(1);
        let use_cache = req.bool_or("cache", true)?;
        let want_trace = req.bool_or("trace", false)?;
        let ring = (want_trace || self.slow_solve_ms > 0)
            .then(|| Arc::new(RingSink::new(TRACE_RING_CAP)));
        if let Some(r) = &ring {
            gen.sink = Some(Arc::clone(r) as Arc<dyn TraceSink>);
        }
        let deadline = deadline_from(req)?;
        // Same cooperative stop as `solve`, shared across the whole λ
        // grid: an expired budget truncates the path after the point it
        // ran out on (marked `"timed_out"` per point) instead of holding
        // the worker to the end of the grid.
        let stop = || {
            if self.shutdown_requested() {
                return true;
            }
            match &deadline {
                Some(d) => d.expired(),
                None => false,
            }
        };
        let stop_ref: Option<&dyn Fn() -> bool> = Some(&stop);
        let path: Vec<PathSolution> = match workload {
            Workload::L1svm => {
                let ds = entry.classification();
                let backend = NativeBackend::new(&ds.x);
                let grid = geometric_grid(ds.lambda_max_l1(), k, ratio);
                regularization_path_with_stop(ds, &backend, &grid, &gen, stop_ref).0
            }
            Workload::Group => {
                let ds = entry.classification();
                let groups = contiguous_groups(ds.p(), group_size)?;
                let backend = NativeBackend::new(&ds.x);
                let grid = geometric_grid(ds.lambda_max_group(&groups), k, ratio);
                group_path_with_stop(ds, &backend, &groups, &grid, &gen, stop_ref)
            }
            Workload::Slope => {
                // RestrictedSlope binds its BH weight sequence at
                // construction (the weights themselves scale with λ̃), so
                // there is no in-place λ̃ move to warm-start through —
                // the slope grid chains per-point solves instead, each
                // seeded from the previous point's exported columns.
                let grid =
                    geometric_grid(entry.classification().lambda_max_l1(), k, ratio);
                let mut out: Vec<PathSolution> = Vec::with_capacity(grid.len());
                let mut prev: Option<WorkingSet> = None;
                let mut stats = GenStats::default();
                for &lt in &grid {
                    let core = solve_slope(&entry, lt, prev.as_ref(), &gen, stop_ref)?;
                    let step = core.stats;
                    accumulate(&mut stats, step);
                    prev = Some(core.ws.clone());
                    out.push(PathSolution {
                        lambda: lt,
                        objective: core.objective,
                        support: core.support,
                        working_set: core.ws.cols.len(),
                        stats,
                        step,
                        ws: core.ws,
                    });
                    if step.timed_out {
                        break;
                    }
                }
                out
            }
            Workload::Ranksvm => {
                let ds = &entry.ds;
                let mut owned_pairs = None;
                let pairs = pairs_for(&entry, gen.pair_mode, &mut owned_pairs)?;
                let backend = NativeBackend::new(&ds.x);
                let grid = geometric_grid(lambda_max_rank(ds, pairs), k, ratio);
                ranksvm_path_with_stop(ds, &backend, pairs, &grid, &gen, stop_ref)
            }
            Workload::Dantzig => {
                let ds = &entry.ds;
                let backend = NativeBackend::new(&ds.x);
                let grid = geometric_grid(lambda_max_dantzig(ds), k, ratio);
                dantzig_path_with_stop(ds, &backend, &grid, &gen, stop_ref)
            }
        };
        // Seed the warm-start cache at EVERY visited λ: a later fixed-λ
        // solve anywhere near the grid resumes from the matching
        // snapshot instead of starting cold.
        let mut seeded = 0usize;
        if use_cache {
            // same key derivation as `solve` (including the group-size
            // fold for Group), so grid-seeded snapshots actually hit on
            // later fixed-λ requests
            let fp = cache_fp(&entry, workload, group_size);
            for pt in &path {
                if !pt.ws.is_empty() {
                    self.cache_store(
                        fp,
                        workload,
                        CacheEntry {
                            lambda: pt.lambda,
                            objective: pt.objective,
                            ws: pt.ws.clone(),
                        },
                    );
                    seeded += 1;
                }
            }
        }
        let last = path.last().expect("grid has at least one point");
        let (rounds, simplex_iters) = (last.stats.rounds, last.stats.simplex_iters);
        let final_stats = last.stats;
        let final_lambda = last.lambda;
        // Per-point rollups the way `batch` reports them: every point
        // after the first warm-starts from its predecessor's working
        // set, and a point that hit the shared deadline carries its own
        // `timed_out` flag (the path is truncated right after it).
        let timed_out = path.iter().filter(|pt| pt.step.timed_out).count();
        let warm_hits = path.len().saturating_sub(1);
        let points: Vec<Json> = path
            .into_iter()
            .enumerate()
            .map(|(i, pt)| {
                Json::obj(vec![
                    kv("lambda", pt.lambda),
                    kv("objective", pt.objective),
                    kv("support", pt.support),
                    kv("working_set", pt.working_set),
                    kv("rounds", pt.step.rounds),
                    kv("simplex_iters", pt.step.simplex_iters),
                    kv("warm", i > 0),
                    kv("timed_out", pt.step.timed_out),
                ])
            })
            .collect();
        if timed_out > 0 {
            self.observe_timeout();
        }
        let wall_ns = wall.elapsed_ns();
        let mut fields = vec![
            kv("dataset", name),
            kv("workload", workload.as_str()),
            kv("points", points.len()),
            kv("rounds", rounds),
            kv("simplex_iters", simplex_iters),
            kv("cache_seeded", seeded),
            kv("warm_hits", warm_hits),
            kv("timed_out", timed_out),
            kv("path", points),
        ];
        // same convention as `solve`: nondeterministic wall clocks only
        // appear when the request opted into tracing
        if want_trace {
            fields.push(kv("wall_ms", ns_to_ms(wall_ns)));
            fields.push(kv("solve_ms", ns_to_ms(final_stats.solve_ns)));
            fields.push(kv("pricing_ms", ns_to_ms(final_stats.pricing_ns)));
            fields.push(kv("seed_ms", ns_to_ms(final_stats.seed_ns)));
            let r = ring.as_ref().expect("ring exists when trace was requested");
            fields.push(kv("trace", trace_events_json(&r.events())));
            fields.push(kv("trace_dropped", r.dropped() as usize));
        }
        let ctx = SlowLogCtx {
            req_id,
            op: "grid",
            dataset: name,
            workload: workload.as_str(),
            lambda: final_lambda,
        };
        self.maybe_log_slow(&ctx, wall_ns, &final_stats, ring.as_deref());
        Ok(ok_response("grid", fields))
    }

    /// The `path_exact` op: ride the parametric-simplex breakpoint path
    /// from λ_max down to `lambda_min_frac · λ_max`, pricing the
    /// implicit space only at basis changes (see
    /// [`crate::coordinator::path_exact`]), and seed the warm-start
    /// cache at **every** breakpoint. The response carries both the
    /// breakpoints and the affine segments between them, so a client
    /// can interpolate the exact objective at any intermediate λ
    /// without another solve. Supported for the workloads with a
    /// parametric-λ certificate (l1svm, ranksvm, dantzig); group and
    /// slope requests are refused with a pointer to the `grid` op.
    fn handle_path_exact(&self, req: &Req, req_id: u64) -> Result<Json> {
        let wall = Span::start();
        let name = req.str_req("dataset")?;
        let entry = self
            .registry
            .get(name)
            .ok_or_else(|| err!("unknown dataset {name:?} (register it first)"))?;
        let workload = Workload::parse(req.str_req("workload")?)?;
        let mut gen = gen_from_req(req)?;
        let use_cache = req.bool_or("cache", true)?;
        let want_trace = req.bool_or("trace", false)?;
        let ring = (want_trace || self.slow_solve_ms > 0)
            .then(|| Arc::new(RingSink::new(TRACE_RING_CAP)));
        if let Some(r) = &ring {
            gen.sink = Some(Arc::clone(r) as Arc<dyn TraceSink>);
        }
        let frac_default = match workload {
            Workload::Dantzig => 0.3,
            _ => 0.05,
        };
        let frac = req.f64_or("lambda_min_frac", frac_default)?;
        ensure!(
            frac > 0.0 && frac < 1.0,
            "lambda_min_frac must be in (0, 1), got {frac}"
        );
        let deadline = deadline_from(req)?;
        let stop = || {
            if self.shutdown_requested() {
                return true;
            }
            match &deadline {
                Some(d) => d.expired(),
                None => false,
            }
        };
        let stop_ref: Option<&dyn Fn() -> bool> = Some(&stop);
        let path: ExactPath = match workload {
            Workload::L1svm => {
                let ds = entry.classification();
                let backend = NativeBackend::new(&ds.x);
                let lmax = ds.lambda_max_l1();
                l1svm_path_exact_with_stop(ds, &backend, lmax, frac * lmax, &gen, stop_ref)
            }
            Workload::Ranksvm => {
                let ds = &entry.ds;
                let mut owned_pairs = None;
                let pairs = pairs_for(&entry, gen.pair_mode, &mut owned_pairs)?;
                let backend = NativeBackend::new(&ds.x);
                let lmax = lambda_max_rank(ds, pairs);
                ranksvm_path_exact_with_stop(
                    ds, &backend, pairs, lmax, frac * lmax, &gen, stop_ref,
                )
            }
            Workload::Dantzig => {
                let ds = &entry.ds;
                let backend = NativeBackend::new(&ds.x);
                let lmax = lambda_max_dantzig(ds);
                dantzig_path_exact_with_stop(ds, &backend, lmax, frac * lmax, &gen, stop_ref)
            }
            Workload::Group | Workload::Slope => bail!(
                "path_exact supports l1svm|ranksvm|dantzig; the {} workload has no \
                 parametric-simplex segment certificate — use the \"grid\" op \
                 (warm-started Algorithm 2) instead",
                workload.as_str()
            ),
        };
        // Seed the cache at every breakpoint — the exact analogue of the
        // grid op's per-point seeding, except the λ's are exactly where
        // the solution structure changes. A timed-out ride's last point
        // is withheld: its expansion may not have converged, and only
        // converged working sets are advertised as seeds (same policy as
        // `solve`).
        let mut seeded = 0usize;
        if use_cache {
            let cacheable = if path.timed_out {
                &path.points[..path.points.len().saturating_sub(1)]
            } else {
                &path.points[..]
            };
            let fp = cache_fp(&entry, workload, 1);
            for pt in cacheable {
                if !pt.ws.is_empty() {
                    self.cache_store(
                        fp,
                        workload,
                        CacheEntry {
                            lambda: pt.lambda,
                            objective: pt.objective,
                            ws: pt.ws.clone(),
                        },
                    );
                    seeded += 1;
                }
            }
        }
        if path.timed_out {
            self.observe_timeout();
        }
        let final_lambda = path.points.last().map_or(0.0, |pt| pt.lambda);
        let points: Vec<Json> = path
            .points
            .iter()
            .map(|pt| {
                Json::obj(vec![
                    kv("lambda", pt.lambda),
                    kv("objective", pt.objective),
                    kv("support", pt.support),
                    kv("working_set", pt.working_set),
                    kv("expanded", pt.expanded),
                ])
            })
            .collect();
        let segments: Vec<Json> = path
            .segments
            .iter()
            .map(|s| {
                Json::obj(vec![
                    kv("lambda_hi", s.lambda_hi),
                    kv("lambda_lo", s.lambda_lo),
                    kv("obj_hi", s.obj_hi),
                    kv("obj_lo", s.obj_lo),
                ])
            })
            .collect();
        let wall_ns = wall.elapsed_ns();
        let mut fields = vec![
            kv("dataset", name),
            kv("workload", workload.as_str()),
            kv("breakpoints", path.stats.breakpoints),
            kv("expansions", path.stats.expansions),
            kv("pricing_rounds", path.stats.pricing_rounds),
            kv("simplex_iters", path.stats.simplex_iters),
            kv("cache_seeded", seeded),
            kv("timed_out", path.timed_out),
            kv("truncated", path.truncated),
            kv("points", points),
            kv("segments", segments),
        ];
        // same convention as `solve`/`grid`: nondeterministic wall
        // clocks only appear when the request opted into tracing
        if want_trace {
            fields.push(kv("wall_ms", ns_to_ms(wall_ns)));
            fields.push(kv("solve_ms", ns_to_ms(path.stats.gen.solve_ns)));
            fields.push(kv("pricing_ms", ns_to_ms(path.stats.gen.pricing_ns)));
            fields.push(kv("seed_ms", ns_to_ms(path.stats.gen.seed_ns)));
            let r = ring.as_ref().expect("ring exists when trace was requested");
            fields.push(kv("trace", trace_events_json(&r.events())));
            fields.push(kv("trace_dropped", r.dropped() as usize));
        }
        let ctx = SlowLogCtx {
            req_id,
            op: "path_exact",
            dataset: name,
            workload: workload.as_str(),
            lambda: final_lambda,
        };
        self.maybe_log_slow(&ctx, wall_ns, &path.stats.gen, ring.as_deref());
        Ok(ok_response("path_exact", fields))
    }

    fn stats_response(&self) -> Json {
        let cache = self.cache.lock().expect("cache lock");
        // One object per dataset: shape, stored nonzeros, density, and
        // the estimated resident bytes of the design (dense buffer, or
        // both CSR+CSC copies for sparse) — enough to see from outside
        // whether a dataset is riding the sparse kernels and what it
        // costs to keep resident.
        let datasets: Vec<Json> = self
            .registry
            .names()
            .into_iter()
            .filter_map(|name| self.registry.get(&name))
            .map(|entry| {
                let x = &entry.ds.x;
                let cells = (entry.ds.n() * entry.ds.p()).max(1);
                let mut fields = vec![
                    kv("name", entry.name.clone()),
                    kv("n", entry.ds.n()),
                    kv("p", entry.ds.p()),
                    kv("nnz", x.nnz()),
                    kv("density", x.nnz() as f64 / cells as f64),
                    kv("sparse", x.is_sparse()),
                    kv("resident_bytes", x.resident_bytes()),
                ];
                // the pair set is the other resident derived structure;
                // report it only when some ranking request built it (the
                // accessor never forces the construction)
                if let Some(pairs) = entry.built_pairs() {
                    fields.push(kv("pairs_resident_bytes", pairs.resident_bytes()));
                }
                Json::obj(fields)
            })
            .collect();
        ok_response(
            "stats",
            vec![
                kv("requests", self.requests.load(Ordering::Relaxed) as usize),
                kv("datasets", datasets),
                kv("registry_bytes", self.registry.resident_bytes()),
                kv(
                    "registry_evictions",
                    self.registry_evictions.load(Ordering::Relaxed) as usize,
                ),
                kv("cache_entries", cache.len()),
                kv("cache_hits", cache.hits as usize),
                kv("cache_misses", cache.misses as usize),
                kv("cache_bytes", cache.resident_bytes()),
                kv("cache_evictions", cache.evictions as usize),
                kv("cache_disk_hits", self.disk_hits.load(Ordering::Relaxed) as usize),
            ],
        )
    }

    /// The `metrics` op: refresh the scrape-time mirrors (cache
    /// counters, resident-byte gauges, inflight) from their
    /// authoritative sources, then render the whole registry as
    /// Prometheus text exposition inside the JSON envelope.
    ///
    /// Mirroring at scrape time — rather than instrumenting every cache
    /// event site — keeps the hot paths untouched and guarantees the
    /// counters agree with what the `stats` op reports.
    fn metrics_response(&self) -> Json {
        {
            let cache = self.cache.lock().expect("cache lock");
            sync_counter(
                &self.metrics,
                "cutgen_cache_hits_total",
                "Warm-cache lookups that found a seed in the λ-bucket neighborhood.",
                cache.hits,
            );
            sync_counter(
                &self.metrics,
                "cutgen_cache_misses_total",
                "Warm-cache lookups that found nothing within the neighborhood.",
                cache.misses,
            );
            sync_counter(
                &self.metrics,
                "cutgen_cache_evictions_total",
                "Snapshots evicted to satisfy the entry cap or byte budget.",
                cache.evictions,
            );
            self.metrics
                .gauge("cutgen_cache_entries", "Resident warm-cache snapshots.", &[])
                .set(cache.len() as i64);
            self.metrics
                .gauge(
                    "cutgen_cache_resident_bytes",
                    "Estimated bytes held by resident warm-cache snapshots.",
                    &[],
                )
                .set(cache.resident_bytes() as i64);
        }
        sync_counter(
            &self.metrics,
            "cutgen_cache_disk_hits_total",
            "In-memory misses that were then served from the snapshot store.",
            self.disk_hits.load(Ordering::Relaxed),
        );
        sync_counter(
            &self.metrics,
            "cutgen_registry_evictions_total",
            "Datasets evicted to satisfy the registry byte budget.",
            self.registry_evictions.load(Ordering::Relaxed),
        );
        self.metrics
            .gauge(
                "cutgen_registry_resident_bytes",
                "Estimated bytes held by all registered datasets and their views.",
                &[],
            )
            .set(self.registry.resident_bytes() as i64);
        self.metrics
            .gauge("cutgen_inflight", "Solve/grid/batch requests currently executing.", &[])
            .set(self.inflight.load(Ordering::SeqCst) as i64);
        for name in self.registry.names() {
            if let Some(entry) = self.registry.get(&name) {
                self.metrics
                    .gauge(
                        "cutgen_dataset_resident_bytes",
                        "Estimated resident bytes of a registered design matrix.",
                        &[("dataset", name.as_str())],
                    )
                    .set(entry.ds.x.resident_bytes() as i64);
            }
        }
        ok_response("metrics", vec![kv("exposition", self.metrics.render())])
    }

    /// Count one deadline/shutdown-truncated solve (or grid).
    fn observe_timeout(&self) {
        self.metrics
            .counter(
                "cutgen_timeouts_total",
                "Solves cut short by a deadline or daemon shutdown.",
                &[],
            )
            .inc();
    }

    /// When `--slow-solve-ms` is set and this request ran longer, log
    /// one structured stderr line — request id, identity, span
    /// breakdown, and the ring-buffered round trace — so a production
    /// outlier can be diagnosed offline without re-running it traced.
    fn maybe_log_slow(
        &self,
        ctx: &SlowLogCtx<'_>,
        wall_ns: u64,
        stats: &GenStats,
        ring: Option<&RingSink>,
    ) {
        if self.slow_solve_ms == 0 || wall_ns < self.slow_solve_ms.saturating_mul(1_000_000) {
            return;
        }
        let mut fields = vec![
            kv("req_id", ctx.req_id as f64),
            kv("op", ctx.op),
            kv("dataset", ctx.dataset),
            kv("workload", ctx.workload),
            kv("lambda", ctx.lambda),
            kv("wall_ms", ns_to_ms(wall_ns)),
            kv("solve_ms", ns_to_ms(stats.solve_ns)),
            kv("pricing_ms", ns_to_ms(stats.pricing_ns)),
            kv("seed_ms", ns_to_ms(stats.seed_ns)),
            kv("rounds", stats.rounds),
            kv("timed_out", stats.timed_out),
        ];
        if let Some(r) = ring {
            fields.push(kv("trace", trace_events_json(&r.events())));
        }
        stderr_line(&format!("[serve] slow-solve {}", Json::obj(fields)));
    }
}

/// What a slow-solve log line identifies: the request id and the
/// `(op, dataset, workload, λ)` it ran.
struct SlowLogCtx<'a> {
    req_id: u64,
    op: &'static str,
    dataset: &'a str,
    workload: &'static str,
    lambda: f64,
}

/// Top a registry counter up to `value` at scrape time. The sources
/// mirrored this way (the warm cache's own counters, the disk-hit
/// count) only grow, so the delta is never negative and the exposed
/// counter stays monotone across scrapes.
fn sync_counter(metrics: &obs::Registry, name: &str, help: &str, value: u64) {
    let c = metrics.counter(name, help, &[]);
    let cur = c.get();
    if value > cur {
        c.add(value - cur);
    }
}

/// Known op names pass through; anything else folds into `"other"` so
/// arbitrary request strings cannot grow the label space unboundedly.
fn op_metric_label(op: &str) -> &'static str {
    match op {
        "register" => "register",
        "unregister" => "unregister",
        "update" => "update",
        "solve" => "solve",
        "grid" => "grid",
        "path_exact" => "path_exact",
        "batch" => "batch",
        "stats" => "stats",
        "metrics" => "metrics",
        "ping" => "ping",
        "shutdown" => "shutdown",
        _ => "other",
    }
}

/// The request's workload as a bounded metric label: a recognized
/// `"workload"` field maps to its canonical name, everything else
/// (absent, malformed, or an op that has no workload) to `"none"`.
fn workload_metric_label(req: &Req) -> &'static str {
    match req.str_opt("workload").map(Workload::parse) {
        Some(Ok(w)) => w.as_str(),
        _ => "none",
    }
}

/// Nanoseconds as fractional milliseconds for response fields.
fn ns_to_ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

/// Ring-captured round events as a JSON array — the `"trace"` response
/// field and the slow-solve log payload.
fn trace_events_json(events: &[RoundEvent]) -> Json {
    Json::from(events.iter().map(round_event_json).collect::<Vec<Json>>())
}

/// One engine round event as a JSON object (span fields stay in
/// nanoseconds, matching the JSONL sink schema in `obs::trace`).
fn round_event_json(ev: &RoundEvent) -> Json {
    Json::obj(vec![
        kv("round", ev.round),
        kv("objective", ev.objective),
        kv("viol_rows", ev.viol_rows),
        kv("viol_cols", ev.viol_cols),
        kv("rows_added", ev.rows_added),
        kv("cols_added", ev.cols_added),
        kv("working_set", ev.working_set),
        kv("simplex_iters", ev.simplex_iters),
        kv("solve_ns", ev.solve_ns as f64),
        kv("pricing_ns", ev.pricing_ns as f64),
        kv("expand_ns", ev.expand_ns as f64),
    ])
}

/// RAII token for one admitted solve/grid/batch request: releases the
/// inflight slot on drop, so errors and panics can never leak admission
/// capacity.
struct InflightGuard<'a>(&'a ServeState);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Resolve a ranking request's comparison-pair set: the registry's
/// shared Auto [`PairSet`] (built once per dataset), or a request-local
/// one when the request forces a representation. Forcing `enumerate`
/// past the Auto threshold is refused — one request must not allocate
/// the O(n²) pair list inside the long-running service (an aborting
/// allocation would take the whole daemon down, not just the request).
fn pairs_for<'e>(
    entry: &'e DatasetEntry,
    mode: PairMode,
    owned: &'e mut Option<PairSet>,
) -> Result<&'e PairSet> {
    let shared = entry.pairs();
    let pairs: &PairSet = match mode {
        PairMode::Auto => shared,
        // honor the forced representation, but reuse the shared set
        // when it already is one (no per-request rebuild)
        PairMode::Implicit if !shared.is_enumerated() => shared,
        PairMode::Implicit => {
            owned.insert(PairSet::build(&entry.ds.y, PairMode::Implicit))
        }
        PairMode::Enumerate => {
            // Auto enumerates exactly when |P| ≤ ENUM_PAIR_CAP, so a
            // shared implicit set means the list is over the cap —
            // refuse rather than let one request allocate the O(n²)
            // list inside the daemon.
            ensure!(
                shared.is_enumerated(),
                "pair_mode \"enumerate\" would materialize {} pairs (cap {}); \
                 use \"auto\" or \"implicit\"",
                shared.len(),
                crate::workloads::pairset::ENUM_PAIR_CAP
            );
            shared
        }
    };
    ensure!(!pairs.is_empty(), "no comparison pairs: all responses are tied");
    Ok(pairs)
}

/// The warm-cache key for one `(dataset, workload)` request. Group
/// working sets are group indices, so snapshots are only compatible
/// between requests with the same grouping: the group size folds into
/// the fingerprint. RankSVM row snapshots address the canonical
/// pair-index space, so the [`PairSet::fingerprint`] folds in — it is
/// representation-independent, which is what lets snapshots written
/// under one [`PairMode`] warm-start solves under another.
fn cache_fp(entry: &DatasetEntry, workload: Workload, group_size: usize) -> u64 {
    match workload {
        Workload::Group => {
            entry.fingerprint ^ (group_size as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        }
        Workload::Ranksvm => entry.fingerprint ^ entry.pairs().fingerprint(),
        _ => entry.fingerprint,
    }
}

/// Resolve the request's λ: an absolute `"lambda"` wins, otherwise
/// `"lambda_frac"` (default 0.05, Dantzig 0.3) times the workload's
/// λ_max on this dataset. For Slope the resolved value is the scale λ̃
/// of the Benjamini–Hochberg weight sequence.
fn lambda_for(
    entry: &DatasetEntry,
    workload: Workload,
    req: &Req,
    group_size: usize,
) -> Result<f64> {
    if let Some(v) = req.0.get("lambda") {
        let lambda = v.as_f64().ok_or_else(|| err!("field \"lambda\" must be a number"))?;
        ensure!(lambda.is_finite() && lambda > 0.0, "lambda must be positive, got {lambda}");
        return Ok(lambda);
    }
    let frac_default = match workload {
        Workload::Dantzig => 0.3,
        _ => 0.05,
    };
    let frac = req.f64_or("lambda_frac", frac_default)?;
    ensure!(frac.is_finite() && frac > 0.0, "lambda_frac must be positive, got {frac}");
    let lmax = match workload {
        Workload::L1svm | Workload::Slope => entry.classification().lambda_max_l1(),
        Workload::Group => {
            let ds = entry.classification();
            let groups = contiguous_groups(ds.p(), group_size)?;
            ds.lambda_max_group(&groups)
        }
        Workload::Ranksvm => {
            let pairs = entry.pairs();
            ensure!(!pairs.is_empty(), "no comparison pairs: all responses are tied");
            lambda_max_rank(&entry.ds, pairs)
        }
        Workload::Dantzig => lambda_max_dantzig(&entry.ds),
    };
    Ok(frac * lmax)
}

/// Fold the request knobs shared by `solve` and `grid` into a
/// [`GenParams`] (`solve` layers its per-round expansion caps on top).
fn gen_from_req(req: &Req) -> Result<GenParams> {
    Ok(GenParams {
        eps: req.f64_or("eps", 1e-2)?,
        threads: req.usize_or("threads", 1)?.max(1),
        init: init_for(req)?,
        seed_budget: req.usize_or("seed_budget", crate::engine::DEFAULT_SEED_BUDGET)?.max(1),
        pair_mode: pair_mode_for(req)?,
        ..Default::default()
    })
}

/// Parse the optional `"pair_mode"` field (default `auto`): the RankSVM
/// pair-channel representation. `auto` uses the registry's shared
/// [`PairSet`]; `enumerate`/`implicit` build a request-local one in the
/// forced representation (the canonical index space — and therefore the
/// warm-start cache — is identical either way).
fn pair_mode_for(req: &Req) -> Result<PairMode> {
    match req.str_opt("pair_mode") {
        Some(s) => PairMode::parse(s),
        None => {
            ensure!(
                req.0.get("pair_mode").is_none(),
                "field \"pair_mode\" must be a string (auto|enumerate|implicit)"
            );
            Ok(PairMode::Auto)
        }
    }
}

/// Parse the optional `"init"` strategy field (default `auto`, i.e. the
/// per-workload first-order default on a cache miss).
fn init_for(req: &Req) -> Result<InitStrategy> {
    match req.str_opt("init") {
        Some(s) => InitStrategy::parse(s),
        None => {
            ensure!(
                req.0.get("init").is_none(),
                "field \"init\" must be a strategy string \
                 (auto|screening|fista|blockcd|subsample); the seed size knob is \"seed_budget\""
            );
            Ok(InitStrategy::Auto)
        }
    }
}

/// Parse an optional array field of sample indices, validating each
/// against the exclusive bound `n`. An absent field parses as empty.
fn index_list(field: Option<&Json>, what: &str, n: usize) -> Result<Vec<usize>> {
    let Some(v) = field else { return Ok(Vec::new()) };
    let arr = v
        .as_arr()
        .ok_or_else(|| err!("field {what:?} must be an array of sample indices"))?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let i = item
            .as_usize()
            .ok_or_else(|| err!("{what:?} entries must be non-negative integers"))?;
        ensure!(i < n, "{what:?} index {i} out of range (n = {n})");
        out.push(i);
    }
    Ok(out)
}

fn contiguous_groups(p: usize, group_size: usize) -> Result<Vec<Vec<usize>>> {
    let gs = group_size.max(1);
    ensure!(p % gs == 0, "group workload needs p divisible by group_size ({p} % {gs} != 0)");
    Ok((0..p / gs).map(|g| (g * gs..(g + 1) * gs).collect()).collect())
}

/// The part of a solve the protocol reports: objective, support, engine
/// counters, and the exported snapshot that feeds the cache.
pub struct SolveCore {
    /// λ the solve ran at.
    pub lambda: f64,
    /// Full-problem objective.
    pub objective: f64,
    /// Nonzero coefficients.
    pub support: usize,
    /// Engine counters for this run.
    pub stats: GenStats,
    /// Final working sets (the cacheable snapshot).
    pub ws: WorkingSet,
    /// What seeded the restricted model: `"cache"` for a warm snapshot,
    /// else the resolved [`InitStrategy`] that actually ran (`Auto`
    /// already mapped to its per-workload default).
    pub seeded_by: &'static str,
}

/// Build the engine for one solve, installing the caller's cooperative
/// stop callback (deadline/shutdown) when one is given.
fn engine_for<'p>(gen: &'p GenParams, stop: Option<&'p dyn Fn() -> bool>) -> GenEngine<'p> {
    match stop {
        Some(f) => GenEngine::new(gen).with_should_stop(f),
        None => GenEngine::new(gen),
    }
}

/// Solve one request: seed the restricted model from `seed` when warm,
/// from the shared [`Initializer`] otherwise (a cache miss runs the §4
/// first-order seed by default — [`InitStrategy::Auto`] — instead of
/// bare screening), run the engine, and export the final working sets.
/// `stop` (when given) is polled once per generation round: a `true`
/// return ends the run with [`GenStats::timed_out`] set and the
/// best-so-far restricted solution in the result.
pub fn solve_one(
    entry: &DatasetEntry,
    workload: Workload,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
    group_size: usize,
    stop: Option<&dyn Fn() -> bool>,
) -> Result<SolveCore> {
    match workload {
        Workload::L1svm => solve_l1(entry, lambda, seed, gen, stop),
        Workload::Group => solve_group(entry, lambda, seed, gen, group_size, stop),
        Workload::Slope => solve_slope(entry, lambda, seed, gen, stop),
        Workload::Ranksvm => solve_ranksvm(entry, lambda, seed, gen, stop),
        Workload::Dantzig => solve_dantzig(entry, lambda, seed, gen, stop),
    }
}

fn solve_l1(
    entry: &DatasetEntry,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
    stop: Option<&dyn Fn() -> bool>,
) -> Result<SolveCore> {
    let ds = entry.classification();
    let backend = NativeBackend::new(&ds.x);
    let pricer = BackendPricer::new(&backend, gen.threads);
    let all_i: Vec<usize> = (0..ds.n()).collect();
    let seed_span = Span::start();
    let mut primal_guess: Option<(Vec<f64>, f64)> = None;
    let (j_init, seeded_by): (Vec<usize>, &'static str) = match seed {
        Some(ws) if !ws.cols.is_empty() => (ws.cols.clone(), "cache"),
        _ => {
            // Algorithm 1 keeps all margin rows: the column-only seed
            // skips the discarded violated-row scan
            let s = Initializer::from_params(gen).seed_l1_cols(ds, &backend, lambda);
            primal_guess = s.primal;
            (s.ws.cols, s.strategy.as_str())
        }
    };
    let seed_ns = seed_span.elapsed_ns();
    let mut rl1 = RestrictedL1::new(ds, lambda, &all_i, &j_init);
    rl1.set_threads(gen.threads);
    // A first-order seed also carries an approximate primal point:
    // cross it over to a starting basis so the first restricted solve
    // starts near the FOM solution instead of from the slack basis.
    if let Some((beta, b0)) = &primal_guess {
        // a failed crossover leaves the cold-start path intact
        let _ = rl1.crossover_from(ds, beta, *b0);
    }
    let mut prob = L1Problem::new(rl1, ds, &pricer, false, true);
    let mut stats = engine_for(gen, stop).run(&mut prob);
    stats.seed_ns = seed_ns;
    let mut ws = prob.export_working_set();
    // Algorithm 1 keeps every margin row in the model; snapshotting the
    // full [n] would only bloat the cache.
    ws.rows.clear();
    let (support, b0) = prob.inner().beta_support();
    let report = l1_report(ds, &support, b0, lambda);
    Ok(SolveCore {
        lambda,
        objective: report.objective,
        support: report.support,
        stats,
        ws,
        seeded_by,
    })
}

fn solve_group(
    entry: &DatasetEntry,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
    group_size: usize,
    stop: Option<&dyn Fn() -> bool>,
) -> Result<SolveCore> {
    let ds = entry.classification();
    let groups = contiguous_groups(ds.p(), group_size)?;
    let backend = NativeBackend::new(&ds.x);
    let pricer = BackendPricer::new(&backend, gen.threads);
    let seed_span = Span::start();
    let (g_init, seeded_by): (Vec<usize>, &'static str) = match seed {
        Some(ws) if !ws.cols.is_empty() => (ws.cols.clone(), "cache"),
        _ => {
            let s = Initializer::from_params(gen).seed_group(ds, &groups, lambda);
            (s.ws.cols, s.strategy.as_str())
        }
    };
    let seed_ns = seed_span.elapsed_ns();
    ensure!(
        g_init.iter().all(|&g| g < groups.len()),
        "snapshot group index out of range for group_size {group_size}"
    );
    let mut rg = RestrictedGroup::new(ds, &groups, lambda, &g_init);
    rg.set_threads(gen.threads);
    let mut prob = GroupProblem::new(rg, ds, &pricer);
    let mut stats = engine_for(gen, stop).run(&mut prob);
    stats.seed_ns = seed_ns;
    let ws = prob.export_working_set();
    let (support, b0) = prob.inner().beta_support();
    let report = group_report(ds, &groups, &support, b0, lambda);
    Ok(SolveCore {
        lambda,
        objective: report.objective,
        support: report.support,
        stats,
        ws,
        seeded_by,
    })
}

fn solve_slope(
    entry: &DatasetEntry,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
    stop: Option<&dyn Fn() -> bool>,
) -> Result<SolveCore> {
    let ds = entry.classification();
    let weights = bh_slope_weights(ds.p(), lambda);
    let backend = NativeBackend::new(&ds.x);
    let pricer = BackendPricer::new(&backend, gen.threads);
    let seed_span = Span::start();
    let (j_init, seeded_by): (Vec<usize>, &'static str) = match seed {
        Some(ws) if !ws.cols.is_empty() => (ws.cols.clone(), "cache"),
        _ => {
            let s = Initializer::from_params(gen).seed_slope(ds, &weights);
            (s.ws.cols, s.strategy.as_str())
        }
    };
    let seed_ns = seed_span.elapsed_ns();
    // Slope caps column additions per round (paper: 10).
    let mut eng = gen.clone();
    if eng.max_cols_per_round == 0 {
        eng.max_cols_per_round = 10;
    }
    let mut rs = RestrictedSlope::new(ds, &weights, &j_init);
    rs.set_threads(gen.threads);
    let mut prob = SlopeProblem::new(rs, ds, &pricer, true);
    let mut stats = engine_for(&eng, stop).run(&mut prob);
    stats.seed_ns = seed_ns;
    let ws = prob.export_working_set();
    let (support, b0) = prob.inner().beta_support();
    let report = slope_report(ds, &weights, &support, b0);
    Ok(SolveCore {
        lambda,
        objective: report.objective,
        support: report.support,
        stats,
        ws,
        seeded_by,
    })
}

fn solve_ranksvm(
    entry: &DatasetEntry,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
    stop: Option<&dyn Fn() -> bool>,
) -> Result<SolveCore> {
    let ds = &entry.ds;
    let mut owned_pairs = None;
    let pairs = pairs_for(entry, gen.pair_mode, &mut owned_pairs)?;
    let backend = NativeBackend::new(&ds.x);
    let pricer = BackendPricer::new(&backend, gen.threads);
    let seed_span = Span::start();
    let (t_init, j_init, seeded_by) = match seed {
        Some(ws) if !ws.is_empty() => (ws.rows.clone(), ws.cols.clone(), "cache"),
        _ => {
            let s = Initializer::from_params(gen).seed_ranksvm(ds, &backend, pairs, lambda);
            (s.ws.rows, s.ws.cols, s.strategy.as_str())
        }
    };
    let seed_ns = seed_span.elapsed_ns();
    ensure!(
        t_init.iter().all(|&t| t < pairs.len()),
        "snapshot pair index out of range (stale pair enumeration?)"
    );
    let mut rr = RestrictedRank::new(ds, pairs, lambda, &t_init, &j_init);
    rr.set_threads(gen.threads);
    rr.set_pair_cap(pair_rows_cap(gen));
    let mut prob = RankProblem::new(rr, ds, &pricer);
    let mut stats = engine_for(gen, stop).run(&mut prob);
    stats.seed_ns = seed_ns;
    stats.pair_scan = Some(prob.inner().pair_scan());
    let ws = prob.export_working_set();
    let report = ranksvm_report(ds, pairs, &prob.inner().beta_support(), lambda);
    Ok(SolveCore {
        lambda,
        objective: report.objective,
        support: report.support,
        stats,
        ws,
        seeded_by,
    })
}

fn solve_dantzig(
    entry: &DatasetEntry,
    lambda: f64,
    seed: Option<&WorkingSet>,
    gen: &GenParams,
    stop: Option<&dyn Fn() -> bool>,
) -> Result<SolveCore> {
    let ds = &entry.ds;
    let backend = NativeBackend::new(&ds.x);
    let pricer = BackendPricer::new(&backend, gen.threads);
    let mut rd = RestrictedDantzig::new(ds, lambda, &[]);
    rd.set_threads(gen.threads);
    let mut prob = DantzigProblem::new(rd, ds, &pricer);
    let seed_span = Span::start();
    let seeded_by = match seed {
        Some(ws) if !ws.is_empty() => {
            prob.import_working_set(ws);
            "cache"
        }
        _ => {
            let cold = Initializer::from_params(gen).seed_dantzig(ds, &backend, lambda);
            prob.import_working_set(&cold.ws);
            cold.strategy.as_str()
        }
    };
    let seed_ns = seed_span.elapsed_ns();
    let mut stats = engine_for(gen, stop).run(&mut prob);
    stats.seed_ns = seed_ns;
    let ws = prob.export_working_set();
    let report = dantzig_report(ds.p(), &prob.inner().beta_support());
    Ok(SolveCore {
        lambda,
        // restricted LP objective, matching `dantzig_path`/`finish`
        objective: prob.inner().objective(),
        support: report.support,
        stats,
        ws,
        seeded_by,
    })
}
