//! Transports for the line-delimited JSON protocol.
//!
//! * [`serve_lines`] — the protocol loop over any `BufRead`/`Write`
//!   pair. Both other transports and the integration tests are this one
//!   function applied to different endpoints.
//! * [`serve_stdin`] — stdin/stdout transport (`cutgen serve --stdin`):
//!   lets tests and CI exercise the full protocol without opening a
//!   port.
//! * [`serve_tcp`] — `std::net::TcpListener` with a scoped worker pool:
//!   the accept loop hands connections to `workers` threads over a
//!   **bounded** mpsc channel; each connection is one protocol session
//!   (many requests, responses in order). When the queue is full the
//!   acceptor answers [`busy_response`] and closes instead of queueing
//!   unboundedly.
//!
//! Framing is hardened against hostile input through [`Framer`]: lines
//! are capped at [`MAX_LINE_BYTES`] (an over-cap request draws a typed
//! error the moment the cap is crossed — a slow-loris writer cannot make
//! the daemon buffer unboundedly, or wait forever for its newline), and
//! invalid UTF-8 draws a typed error instead of tearing the session
//! down.
//!
//! Shutdown: the `shutdown` op flips the state flag; the worker that
//! served it pokes the listener with an empty connection so the
//! blocking `accept` wakes up and the pool drains.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{self, TrySendError};
use std::sync::Mutex;
use std::time::Duration;

use super::protocol::err_response;
use super::{busy_response, ServeState};
use crate::error::{Context, Result};

/// Hard cap on one request line (1 MiB). Protocol objects are a few
/// hundred bytes; even a `batch` at [`super::MAX_BATCH_REQUESTS`] items
/// fits comfortably.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// What [`Framer::feed`] found in the input it consumed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Frame {
    /// A complete line is buffered — read it with [`Framer::line`],
    /// then release it with [`Framer::clear`].
    Line,
    /// The current line crossed the byte cap and was discarded. Emitted
    /// at most once per offending line, possibly before its newline has
    /// even arrived; the line's remaining bytes are then swallowed
    /// silently.
    Oversized,
    /// More input is needed.
    More,
}

/// Incremental line framer with a hard byte cap.
///
/// Feed it raw chunks as they arrive; it hands back complete
/// newline-terminated lines and polices the cap *while buffering*, so a
/// peer trickling an endless line (slow-loris) is answered and cut off
/// after `cap` bytes instead of growing the buffer without bound.
pub struct Framer {
    buf: Vec<u8>,
    /// Discarding the tail of an oversized line (until its newline).
    skipping: bool,
    cap: usize,
}

impl Framer {
    /// Framer with the given per-line byte cap.
    pub fn new(cap: usize) -> Self {
        Self { buf: Vec::new(), skipping: false, cap }
    }

    /// Consume a prefix of `chunk` (up to and including one newline) and
    /// report what it completed. Returns `(bytes_consumed, frame)`; call
    /// again with the rest of the chunk after handling the frame.
    pub fn feed(&mut self, chunk: &[u8]) -> (usize, Frame) {
        match chunk.iter().position(|&b| b == b'\n') {
            Some(k) => {
                let consumed = k + 1;
                if self.skipping {
                    // tail of a line already reported as oversized
                    self.skipping = false;
                    (consumed, Frame::More)
                } else if self.buf.len() + k > self.cap {
                    self.buf.clear();
                    (consumed, Frame::Oversized)
                } else {
                    self.buf.extend_from_slice(&chunk[..k]);
                    (consumed, Frame::Line)
                }
            }
            None => {
                let consumed = chunk.len();
                if self.skipping {
                    (consumed, Frame::More)
                } else if self.buf.len() + chunk.len() > self.cap {
                    // report now, newline or not: the offender must not
                    // be able to buffer (or stall) past the cap
                    self.buf.clear();
                    self.skipping = true;
                    (consumed, Frame::Oversized)
                } else {
                    self.buf.extend_from_slice(chunk);
                    (consumed, Frame::More)
                }
            }
        }
    }

    /// The buffered line (no newline) after a [`Frame::Line`].
    pub fn line(&self) -> &[u8] {
        &self.buf
    }

    /// Release the buffered line and get ready for the next one.
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// At EOF: an unterminated final line, if a well-sized one is
    /// pending (an oversized tail was already reported and stays
    /// swallowed).
    pub fn take_trailing(&mut self) -> Option<Vec<u8>> {
        if self.skipping || self.buf.is_empty() {
            return None;
        }
        Some(std::mem::take(&mut self.buf))
    }
}

/// The response for a line that crossed [`MAX_LINE_BYTES`].
fn oversized_response() -> String {
    err_response(&format!("request line exceeds {MAX_LINE_BYTES} bytes")).to_string()
}

/// Handle one framed line: UTF-8-validate, skip blanks, dispatch, write
/// the response. Returns `Ok(true)` when the session should end (a
/// `shutdown` request has been served).
fn respond_line<W: Write>(
    state: &ServeState,
    raw: &[u8],
    out: &mut W,
) -> std::io::Result<bool> {
    let resp = match std::str::from_utf8(raw) {
        Ok(text) => {
            let text = text.trim();
            if text.is_empty() {
                return Ok(state.shutdown_requested());
            }
            state.handle_line(text)
        }
        Err(_) => err_response("request line is not valid UTF-8").to_string(),
    };
    writeln!(out, "{resp}")?;
    out.flush()?;
    Ok(state.shutdown_requested())
}

/// Run the protocol over a line-oriented reader/writer pair until EOF
/// or a `shutdown` request, with [`Framer`] hardening (byte-capped
/// lines, typed errors for oversized or non-UTF-8 input).
pub fn serve_lines<R: BufRead, W: Write>(
    state: &ServeState,
    mut reader: R,
    mut out: W,
) -> std::io::Result<()> {
    let mut framer = Framer::new(MAX_LINE_BYTES);
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            // EOF: an unterminated trailing line still gets its response
            if let Some(last) = framer.take_trailing() {
                respond_line(state, &last, &mut out)?;
            }
            return Ok(());
        }
        let (consumed, frame) = framer.feed(chunk);
        reader.consume(consumed);
        match frame {
            Frame::Line => {
                let done = respond_line(state, framer.line(), &mut out)?;
                framer.clear();
                if done {
                    return Ok(());
                }
            }
            Frame::Oversized => {
                writeln!(out, "{}", oversized_response())?;
                out.flush()?;
            }
            Frame::More => {}
        }
    }
}

/// The stdin/stdout transport.
pub fn serve_stdin(state: &ServeState) -> std::io::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    serve_lines(state, stdin.lock(), stdout.lock())
}

fn handle_conn(state: &ServeState, stream: TcpStream) {
    // An idle session must not pin the worker open across a shutdown:
    // poll the read with a timeout and re-check the flag between
    // attempts. Partial input survives in the Framer across timeouts,
    // so a slow writer's request is assembled across polls — up to the
    // byte cap.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    let mut framer = Framer::new(MAX_LINE_BYTES);
    loop {
        let chunk = match reader.fill_buf() {
            Ok(chunk) if chunk.is_empty() => break, // peer closed
            Ok(chunk) => chunk,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if state.shutdown_requested() {
                    break;
                }
                continue;
            }
            Err(_) => break,
        };
        let (consumed, frame) = framer.feed(chunk);
        reader.consume(consumed);
        match frame {
            Frame::Line => {
                // peer hangups mid-write are the peer's business
                let outcome = respond_line(state, framer.line(), &mut writer);
                framer.clear();
                match outcome {
                    Ok(false) => {}
                    Ok(true) | Err(_) => break,
                }
            }
            Frame::Oversized => {
                if writeln!(writer, "{}", oversized_response())
                    .and_then(|()| writer.flush())
                    .is_err()
                {
                    break;
                }
            }
            Frame::More => {}
        }
    }
}

/// The TCP transport: accept connections and serve each as one protocol
/// session on a pool of `workers` scoped threads (clamped to ≥ 1).
/// `queue_cap` bounds connections waiting for a free worker (clamped to
/// ≥ 1): past it the acceptor writes [`busy_response`] — with its
/// `retry_after` backoff hint — and closes, so load shedding is explicit
/// and immediate instead of an unbounded backlog. Returns after a
/// `shutdown` request has been served and the pool has drained.
pub fn serve_tcp(
    state: &ServeState,
    listener: TcpListener,
    workers: usize,
    queue_cap: usize,
) -> std::io::Result<()> {
    let workers = workers.max(1);
    let local = listener.local_addr()?;
    let (tx, rx) = mpsc::sync_channel::<TcpStream>(queue_cap.max(1));
    let rx = Mutex::new(rx);
    // sync_channel has no len(): the acceptor and workers keep the
    // depth gauge themselves (inc on enqueue, dec on dequeue)
    let depth = state.metrics.gauge(
        "cutgen_accept_queue_depth",
        "Accepted connections waiting for a free worker.",
        &[],
    );
    let sheds = state.metrics.counter(
        "cutgen_queue_sheds_total",
        "Connections shed at the bounded accept queue.",
        &[],
    );
    std::thread::scope(|scope| -> std::io::Result<()> {
        for _ in 0..workers {
            let rx = &rx;
            let depth = &depth;
            scope.spawn(move || loop {
                let next = rx.lock().expect("queue lock").recv();
                match next {
                    Ok(stream) => {
                        depth.sub(1);
                        handle_conn(state, stream);
                        if state.shutdown_requested() {
                            // wake the blocking accept so the loop exits
                            let _ = TcpStream::connect(local);
                        }
                    }
                    Err(_) => break, // sender dropped: server is done
                }
            });
        }
        loop {
            let (stream, _) = listener.accept()?;
            if state.shutdown_requested() {
                break; // this was the wake-up poke
            }
            match tx.try_send(stream) {
                Ok(()) => depth.add(1),
                Err(TrySendError::Full(mut stream)) => {
                    // bounded backlog: shed the connection with a typed
                    // busy line instead of queueing it invisibly
                    sheds.inc();
                    let _ = writeln!(stream, "{}", busy_response());
                }
                Err(TrySendError::Disconnected(_)) => break,
            }
        }
        drop(tx);
        Ok(())
    })
}

/// Connect to a running server, send one request line, return the
/// response line.
pub fn client_send(addr: &str, line: &str) -> Result<String> {
    let responses = client_send_many(addr, std::slice::from_ref(&line.to_string()))?;
    responses.into_iter().next().ok_or_else(|| crate::err!("server closed without responding"))
}

/// Connect once and run several request lines through one protocol
/// session, returning the responses in order. Blank lines are skipped.
/// If the server closes the connection mid-session (e.g. right after
/// serving a `shutdown` request), the responses received so far are
/// returned rather than discarded — callers can detect the short count.
pub fn client_send_many(addr: &str, lines: &[String]) -> Result<Vec<String>> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let mut writer = stream.try_clone().context("cloning connection")?;
    let mut reader = BufReader::new(stream);
    let mut out = Vec::new();
    for line in lines {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if writeln!(writer, "{line}").and_then(|()| writer.flush()).is_err() {
            break; // server gone: keep what we already got
        }
        let mut resp = String::new();
        match reader.read_line(&mut resp) {
            // closed or reset mid-session: keep the earlier responses
            Ok(0) | Err(_) => break,
            Ok(_) => out.push(resp.trim_end().to_string()),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn framer_splits_lines_across_chunks() {
        let mut f = Framer::new(64);
        let (c, fr) = f.feed(b"{\"op\":");
        assert_eq!((c, fr), (6, Frame::More));
        let (c, fr) = f.feed(b"\"ping\"}\nrest");
        assert_eq!((c, fr), (8, Frame::Line), "consumes through the newline only");
        assert_eq!(f.line(), b"{\"op\":\"ping\"}");
        f.clear();
        let (c, fr) = f.feed(b"rest");
        assert_eq!((c, fr), (4, Frame::More));
        assert_eq!(f.take_trailing().as_deref(), Some(&b"rest"[..]));
        assert!(f.take_trailing().is_none(), "trailing line is taken once");
    }

    #[test]
    fn framer_rejects_oversized_terminated_line() {
        let mut f = Framer::new(8);
        let (c, fr) = f.feed(b"0123456789ABC\nnext\n");
        assert_eq!(fr, Frame::Oversized);
        assert_eq!(c, 14, "consumes through the offending newline");
        let (c, fr) = f.feed(b"next\n");
        assert_eq!((c, fr), (5, Frame::Line), "session recovers on the next line");
        assert_eq!(f.line(), b"next");
    }

    #[test]
    fn framer_reports_slow_loris_before_the_newline_arrives() {
        let mut f = Framer::new(8);
        assert_eq!(f.feed(b"01234"), (5, Frame::More));
        // cap crossed mid-line: reported immediately, no newline needed
        assert_eq!(f.feed(b"56789"), (5, Frame::Oversized));
        // the rest of the endless line is swallowed without re-reporting
        assert_eq!(f.feed(b"AAAAAAAA"), (8, Frame::More));
        assert!(f.take_trailing().is_none(), "oversized tail never resurfaces");
        // ...until its newline finally lands, then framing resumes
        assert_eq!(f.feed(b"tail\n"), (5, Frame::More));
        assert_eq!(f.feed(b"ok\n"), (3, Frame::Line));
        assert_eq!(f.line(), b"ok");
    }

    #[test]
    fn framer_cap_counts_the_whole_buffered_line() {
        let mut f = Framer::new(8);
        assert_eq!(f.feed(b"0123"), (4, Frame::More));
        assert_eq!(f.feed(b"4567"), (4, Frame::More), "exactly at cap is fine");
        assert_eq!(f.feed(b"\n"), (1, Frame::Line));
        assert_eq!(f.line(), b"01234567");
    }
}
