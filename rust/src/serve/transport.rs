//! Transports for the line-delimited JSON protocol.
//!
//! * [`serve_lines`] — the protocol loop over any `BufRead`/`Write`
//!   pair. Both other transports and the integration tests are this one
//!   function applied to different endpoints.
//! * [`serve_stdin`] — stdin/stdout transport (`cutgen serve --stdin`):
//!   lets tests and CI exercise the full protocol without opening a
//!   port.
//! * [`serve_tcp`] — `std::net::TcpListener` with a scoped worker pool:
//!   the accept loop hands connections to `workers` threads over an
//!   mpsc channel; each connection is one protocol session (many
//!   requests, responses in order).
//!
//! Shutdown: the `shutdown` op flips the state flag; the worker that
//! served it pokes the listener with an empty connection so the
//! blocking `accept` wakes up and the pool drains.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Mutex};
use std::time::Duration;

use super::ServeState;
use crate::error::{Context, Result};

/// Run the protocol over a line-oriented reader/writer pair until EOF
/// or a `shutdown` request.
pub fn serve_lines<R: BufRead, W: Write>(
    state: &ServeState,
    reader: R,
    mut out: W,
) -> std::io::Result<()> {
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let resp = state.handle_line(line);
        writeln!(out, "{resp}")?;
        out.flush()?;
        if state.shutdown_requested() {
            break;
        }
    }
    Ok(())
}

/// The stdin/stdout transport.
pub fn serve_stdin(state: &ServeState) -> std::io::Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    serve_lines(state, stdin.lock(), stdout.lock())
}

fn handle_conn(state: &ServeState, stream: TcpStream) {
    // An idle session must not pin the worker open across a shutdown:
    // poll the read with a timeout and re-check the flag between
    // attempts. A timed-out read may leave a partial line in `line`
    // (read_line appends what it consumed before erroring), so the
    // buffer is only cleared after a complete line is processed.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break, // peer closed
            Ok(_) => {
                let req = line.trim();
                if !req.is_empty() {
                    let resp = state.handle_line(req);
                    // peer hangups mid-write are the peer's business
                    if writeln!(writer, "{resp}").and_then(|()| writer.flush()).is_err() {
                        break;
                    }
                }
                line.clear();
                if state.shutdown_requested() {
                    break;
                }
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if state.shutdown_requested() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// The TCP transport: accept connections and serve each as one protocol
/// session on a pool of `workers` scoped threads (clamped to ≥ 1).
/// Returns after a `shutdown` request has been served and the pool has
/// drained.
pub fn serve_tcp(
    state: &ServeState,
    listener: TcpListener,
    workers: usize,
) -> std::io::Result<()> {
    let workers = workers.max(1);
    let local = listener.local_addr()?;
    let (tx, rx) = mpsc::channel::<TcpStream>();
    let rx = Mutex::new(rx);
    std::thread::scope(|scope| -> std::io::Result<()> {
        for _ in 0..workers {
            let rx = &rx;
            scope.spawn(move || loop {
                let next = rx.lock().expect("queue lock").recv();
                match next {
                    Ok(stream) => {
                        handle_conn(state, stream);
                        if state.shutdown_requested() {
                            // wake the blocking accept so the loop exits
                            let _ = TcpStream::connect(local);
                        }
                    }
                    Err(_) => break, // sender dropped: server is done
                }
            });
        }
        loop {
            let (stream, _) = listener.accept()?;
            if state.shutdown_requested() {
                break; // this was the wake-up poke
            }
            if tx.send(stream).is_err() {
                break;
            }
        }
        drop(tx);
        Ok(())
    })
}

/// Connect to a running server, send one request line, return the
/// response line.
pub fn client_send(addr: &str, line: &str) -> Result<String> {
    let responses = client_send_many(addr, std::slice::from_ref(&line.to_string()))?;
    responses.into_iter().next().ok_or_else(|| crate::err!("server closed without responding"))
}

/// Connect once and run several request lines through one protocol
/// session, returning the responses in order. Blank lines are skipped.
/// If the server closes the connection mid-session (e.g. right after
/// serving a `shutdown` request), the responses received so far are
/// returned rather than discarded — callers can detect the short count.
pub fn client_send_many(addr: &str, lines: &[String]) -> Result<Vec<String>> {
    let stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let mut writer = stream.try_clone().context("cloning connection")?;
    let mut reader = BufReader::new(stream);
    let mut out = Vec::new();
    for line in lines {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if writeln!(writer, "{line}").and_then(|()| writer.flush()).is_err() {
            break; // server gone: keep what we already got
        }
        let mut resp = String::new();
        match reader.read_line(&mut resp) {
            // closed or reset mid-session: keep the earlier responses
            Ok(0) | Err(_) => break,
            Ok(_) => out.push(resp.trim_end().to_string()),
        }
    }
    Ok(out)
}
