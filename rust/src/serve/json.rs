//! A hand-rolled JSON value: recursive-descent reader and compact
//! writer.
//!
//! The offline image carries no serde; the runtime layer already ships
//! the strict scalar extractor `runtime::json_usize` for its
//! machine-generated manifest, and this module extends the same idiom to
//! full documents for the serve protocol: every request and response is
//! one JSON object per line. The parser is strict — unterminated
//! containers, bad escapes, bare garbage after the document, or invalid
//! numbers are errors, never silent truncations.

use std::fmt;

use crate::error::Result;
use crate::{bail, err};

/// A JSON document node.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always held as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; insertion order is preserved (lookups are linear —
    /// protocol objects are small).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete document; trailing non-whitespace is an error.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i < p.b.len() {
            bail!("json: trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// Numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric payload as a usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= usize::MAX as f64 => {
                Some(*n as usize)
            }
            _ => None,
        }
    }

    /// Boolean payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array payload, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a.as_slice()),
            _ => None,
        }
    }

    /// Build an object from `(key, value)` pairs.
    pub fn obj(fields: Vec<(String, Json)>) -> Json {
        Json::Obj(fields)
    }
}

/// Convenience for building object fields: `kv("ok", true)`.
pub fn kv(key: &str, value: impl Into<Json>) -> (String, Json) {
    (key.to_string(), value.into())
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

impl From<Vec<Json>> for Json {
    fn from(a: Vec<Json>) -> Json {
        Json::Arr(a)
    }
}

impl fmt::Display for Json {
    /// Compact serialization (no whitespace), one line per document.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    write!(f, "{n}")
                } else {
                    f.write_str("null") // NaN/inf are not JSON
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                f.write_str("[")?;
                for (k, v) in a.iter().enumerate() {
                    if k > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(fields) => {
                f.write_str("{")?;
                for (k, (key, v)) in fields.iter().enumerate() {
                    if k > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, key)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("json: expected {:?} at byte {}", c as char, self.i);
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| err!("json: unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("json: unexpected byte {:?} at {}", c as char, self.i),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("json: bad literal at byte {}", self.i);
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii");
        let n: f64 =
            text.parse().map_err(|_| err!("json: bad number {text:?} at byte {start}"))?;
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self
                .b
                .get(self.i)
                .ok_or_else(|| err!("json: unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self
                        .b
                        .get(self.i)
                        .ok_or_else(|| err!("json: unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => out.push(self.unicode_escape()?),
                        _ => bail!("json: bad escape \\{} at byte {}", e as char, self.i - 1),
                    }
                }
                _ => {
                    // re-borrow the raw bytes to keep multi-byte UTF-8 intact
                    let rest = &self.b[self.i - 1..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| err!("json: invalid utf-8 in string"))?;
                    let ch = s.chars().next().expect("nonempty");
                    out.push(ch);
                    self.i += ch.len_utf8() - 1;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("json: truncated \\u escape");
        }
        let text = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| err!("json: bad \\u escape"))?;
        let v = u32::from_str_radix(text, 16).map_err(|_| err!("json: bad \\u escape {text:?}"))?;
        self.i += 4;
        Ok(v)
    }

    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        if (0xD800..0xDC00).contains(&hi) {
            // surrogate pair: require the low half immediately after
            if self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u') {
                self.i += 2;
                let lo = self.hex4()?;
                if !(0xDC00..0xE000).contains(&lo) {
                    bail!("json: invalid low surrogate {lo:#x}");
                }
                let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                return char::from_u32(c).ok_or_else(|| err!("json: invalid surrogate pair"));
            }
            bail!("json: lone high surrogate {hi:#x}");
        }
        char::from_u32(hi).ok_or_else(|| err!("json: invalid \\u codepoint {hi:#x}"))
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            fields.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => bail!("json: expected ',' or '}}' at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("json: expected ',' or ']' at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_protocol_shapes() {
        let v = Json::parse(
            r#"{"op":"solve","dataset":"d1","lambda_frac":0.05,"cache":true,"grid":[1,2.5]}"#,
        )
        .unwrap();
        assert_eq!(v.get("op").unwrap().as_str(), Some("solve"));
        assert_eq!(v.get("lambda_frac").unwrap().as_f64(), Some(0.05));
        assert_eq!(v.get("cache").unwrap().as_bool(), Some(true));
        let grid = v.get("grid").unwrap().as_arr().unwrap();
        assert_eq!(grid.len(), 2);
        assert_eq!(grid[1].as_f64(), Some(2.5));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn roundtrips_through_display() {
        let text = r#"{"a":null,"b":[true,false,-1.5,"x\"y\\z"],"c":{"n":3}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.to_string(), text);
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn escapes_and_unicode() {
        let v = Json::parse(r#""line\nbreak é 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("line\nbreak é 😀"));
        // writer escapes control characters back out
        let out = Json::Str("a\nb\u{0001}".to_string()).to_string();
        assert_eq!(out, "\"a\\nb\\u0001\"");
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,",
            r#"{"a" 1}"#,
            r#"{"a":1} trailing"#,
            "01a",
            r#""unterminated"#,
            r#""bad \q escape""#,
            "tru",
            "nul",
            "[1 2]",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn integer_numbers_print_bare() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(0.1).to_string(), "0.1");
        assert_eq!(Json::from(42usize).to_string(), "42");
    }

    #[test]
    fn usize_accessor_is_strict() {
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
        assert_eq!(Json::Num(7.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Str("7".into()).as_usize(), None);
    }
}
