//! Protocol vocabulary: workload names, request field extraction, and
//! response construction.
//!
//! The wire format is line-delimited JSON — one request object in, one
//! response object out, in order. Every response carries `"ok"`; error
//! responses carry `"error"` with a human-readable message and never
//! tear down the connection. See `docs/serving.md` for the full
//! reference with examples.

use super::json::{kv, Json};
use crate::error::Result;
use crate::{bail, err};

/// The five estimators the service can solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Workload {
    /// L1-SVM column generation (paper Algorithm 1).
    L1svm,
    /// Group-SVM column generation on groups (§2.4).
    Group,
    /// Slope-SVM column-and-cut generation (Algorithms 5–7).
    Slope,
    /// RankSVM: pairwise-hinge L1 ranking.
    Ranksvm,
    /// Dantzig selector: CCG over the correlation system.
    Dantzig,
}

impl Workload {
    /// All workloads, in protocol-name order.
    pub const ALL: [Workload; 5] = [
        Workload::L1svm,
        Workload::Group,
        Workload::Slope,
        Workload::Ranksvm,
        Workload::Dantzig,
    ];

    /// Parse a protocol workload name.
    pub fn parse(name: &str) -> Result<Workload> {
        Ok(match name {
            "l1svm" => Workload::L1svm,
            "group" => Workload::Group,
            "slope" => Workload::Slope,
            "ranksvm" => Workload::Ranksvm,
            "dantzig" => Workload::Dantzig,
            other => bail!("unknown workload {other:?} (l1svm|group|slope|ranksvm|dantzig)"),
        })
    }

    /// Protocol name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Workload::L1svm => "l1svm",
            Workload::Group => "group",
            Workload::Slope => "slope",
            Workload::Ranksvm => "ranksvm",
            Workload::Dantzig => "dantzig",
        }
    }
}

/// Typed field access over a request object, with protocol-shaped errors.
pub struct Req<'a>(
    /// The parsed request document.
    pub &'a Json,
);

impl Req<'_> {
    /// Required string field.
    pub fn str_req(&self, key: &str) -> Result<&str> {
        self.0
            .get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| err!("request needs a string field {key:?}"))
    }

    /// Optional string field.
    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.0.get(key).and_then(Json::as_str)
    }

    /// Optional number field with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => v.as_f64().ok_or_else(|| err!("field {key:?} must be a number")),
        }
    }

    /// Optional non-negative-integer field with a default.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => {
                v.as_usize().ok_or_else(|| err!("field {key:?} must be a non-negative integer"))
            }
        }
    }

    /// Optional boolean field with a default.
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => v.as_bool().ok_or_else(|| err!("field {key:?} must be a boolean")),
        }
    }
}

/// `{"ok":true,"op":<op>, ...fields}`.
pub fn ok_response(op: &str, mut fields: Vec<(String, Json)>) -> Json {
    let mut all = vec![kv("ok", true), kv("op", op)];
    all.append(&mut fields);
    Json::obj(all)
}

/// `{"ok":false,"error":<message>}`.
pub fn err_response(message: &str) -> Json {
    Json::obj(vec![kv("ok", false), kv("error", message)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_names_roundtrip() {
        for w in Workload::ALL {
            assert_eq!(Workload::parse(w.as_str()).unwrap(), w);
        }
        assert!(Workload::parse("lasso").is_err());
    }

    #[test]
    fn request_field_extraction() {
        let v = Json::parse(r#"{"op":"solve","k":3,"f":0.5,"b":true,"s":"x"}"#).unwrap();
        let r = Req(&v);
        assert_eq!(r.str_req("op").unwrap(), "solve");
        assert!(r.str_req("nope").is_err());
        assert_eq!(r.usize_or("k", 9).unwrap(), 3);
        assert_eq!(r.usize_or("nope", 9).unwrap(), 9);
        assert!(r.usize_or("f", 0).is_err(), "0.5 is not an integer");
        assert_eq!(r.f64_or("f", 0.0).unwrap(), 0.5);
        assert!(r.bool_or("s", false).is_err());
        assert!(r.bool_or("b", false).unwrap());
    }

    #[test]
    fn responses_have_protocol_shape() {
        let ok = ok_response("stats", vec![kv("n", 2usize)]);
        assert_eq!(ok.to_string(), r#"{"ok":true,"op":"stats","n":2}"#);
        let err = err_response("boom");
        assert_eq!(err.to_string(), r#"{"ok":false,"error":"boom"}"#);
    }
}
