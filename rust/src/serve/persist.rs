//! Disk persistence for warm-start snapshots.
//!
//! A long-running daemon accumulates converged working sets that are
//! expensive to recompute and tiny to store (tens of indices). The
//! [`SnapshotStore`] spills every cache insert to one JSON file per
//! `(fingerprint, workload, λ-bucket)` key, and the serve layer lazily
//! probes the store on an in-memory miss — so a restarted daemon
//! warm-hits the λ's its predecessor already solved. The dataset content
//! fingerprint is part of both the filename and the document, so a stale
//! file can never seed a solve on different data: mismatches (and any
//! other corruption) load as `None`, which is just a cold solve.
//!
//! Writes are atomic per entry: the document goes to a unique temporary
//! file in the same directory and is `rename`d into place, so a crash
//! mid-write leaves either the old snapshot or none — never a torn file.
//!
//! On-disk format (one compact JSON object per file, named
//! `{fingerprint:016x}-{workload}-b{bucket}.json`):
//!
//! ```json
//! {"fingerprint":"00a1b2…","workload":"l1svm","lambda":0.81,
//!  "objective":57.31,"cols":[3,17,42],"rows":[]}
//! ```

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::cache::{lambda_bucket, CacheEntry};
use super::json::{kv, Json};
use super::protocol::Workload;
use crate::engine::WorkingSet;
use crate::err;
use crate::error::Result;

/// A directory of spilled warm-start snapshots, one JSON file per cache
/// key. See the module docs for the on-disk format.
pub struct SnapshotStore {
    dir: PathBuf,
    /// Distinguishes concurrent writers' temporary files within one
    /// process; the pid distinguishes processes.
    tmp_counter: AtomicU64,
}

impl SnapshotStore {
    /// Open (creating if needed) a snapshot directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<SnapshotStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .map_err(|e| err!("persist: cannot create {}: {e}", dir.display()))?;
        Ok(SnapshotStore { dir, tmp_counter: AtomicU64::new(0) })
    }

    /// The directory snapshots are spilled to.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file_for(&self, fingerprint: u64, workload: Workload, bucket: i64) -> PathBuf {
        self.dir
            .join(format!("{fingerprint:016x}-{}-b{bucket}.json", workload.as_str()))
    }

    /// Spill one snapshot, atomically replacing any prior file for its
    /// key (the key's bucket is derived from `entry.lambda`).
    pub fn save(&self, fingerprint: u64, workload: Workload, entry: &CacheEntry) -> Result<()> {
        let bucket = lambda_bucket(entry.lambda);
        let doc = Json::obj(vec![
            kv("fingerprint", format!("{fingerprint:016x}")),
            kv("workload", workload.as_str()),
            kv("lambda", entry.lambda),
            kv("objective", entry.objective),
            kv(
                "cols",
                entry.ws.cols.iter().map(|&j| Json::from(j)).collect::<Vec<_>>(),
            ),
            kv(
                "rows",
                entry.ws.rows.iter().map(|&i| Json::from(i)).collect::<Vec<_>>(),
            ),
        ]);
        let tick = self.tmp_counter.fetch_add(1, Ordering::Relaxed);
        let tmp = self
            .dir
            .join(format!(".tmp-{}-{tick}", std::process::id()));
        fs::write(&tmp, format!("{doc}\n"))
            .map_err(|e| err!("persist: cannot write {}: {e}", tmp.display()))?;
        let path = self.file_for(fingerprint, workload, bucket);
        fs::rename(&tmp, &path).map_err(|e| {
            let _ = fs::remove_file(&tmp);
            err!("persist: cannot rename into {}: {e}", path.display())
        })
    }

    /// Load the snapshot for a key, if a valid one is on disk. Any
    /// corruption — unreadable file, bad JSON, fingerprint/workload/λ
    /// mismatch — returns `None`: a disk miss is always safe (it just
    /// means a cold solve), so this never surfaces an error.
    pub fn load(&self, fingerprint: u64, workload: Workload, bucket: i64) -> Option<CacheEntry> {
        let path = self.file_for(fingerprint, workload, bucket);
        let text = fs::read_to_string(&path).ok()?;
        let doc = Json::parse(text.trim()).ok()?;
        if doc.get("fingerprint")?.as_str()? != format!("{fingerprint:016x}") {
            return None;
        }
        if doc.get("workload")?.as_str()? != workload.as_str() {
            return None;
        }
        let lambda = doc.get("lambda")?.as_f64()?;
        if lambda_bucket(lambda) != bucket {
            return None;
        }
        let objective = doc.get("objective")?.as_f64()?;
        let cols = index_vec(doc.get("cols")?)?;
        let rows = index_vec(doc.get("rows")?)?;
        Some(CacheEntry { lambda, objective, ws: WorkingSet { cols, rows } })
    }
}

/// Strictly decode an array of non-negative integer indices.
fn index_vec(v: &Json) -> Option<Vec<usize>> {
    v.as_arr()?.iter().map(|x| x.as_usize()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("cutgen-persist-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn entry(lambda: f64) -> CacheEntry {
        CacheEntry {
            lambda,
            objective: 3.25,
            ws: WorkingSet { cols: vec![3, 17, 42], rows: vec![5] },
        }
    }

    #[test]
    fn roundtrips_snapshots_exactly() {
        let dir = scratch("roundtrip");
        let store = SnapshotStore::open(&dir).unwrap();
        let e = entry(0.8125);
        store.save(0xdead_beef, Workload::Ranksvm, &e).unwrap();
        let back = store
            .load(0xdead_beef, Workload::Ranksvm, lambda_bucket(0.8125))
            .expect("saved snapshot loads");
        assert_eq!(back.lambda, e.lambda, "f64 text roundtrip is exact");
        assert_eq!(back.objective, e.objective);
        assert_eq!(back.ws, e.ws);
        // wrong key coordinates miss
        assert!(store.load(0xdead_beef, Workload::L1svm, lambda_bucket(0.8125)).is_none());
        assert!(store.load(0xbeef, Workload::Ranksvm, lambda_bucket(0.8125)).is_none());
        assert!(store
            .load(0xdead_beef, Workload::Ranksvm, lambda_bucket(0.8125) + 9)
            .is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_files_load_as_misses() {
        let dir = scratch("corrupt");
        let store = SnapshotStore::open(&dir).unwrap();
        let e = entry(1.0);
        store.save(7, Workload::L1svm, &e).unwrap();
        let path = dir.join(format!("{:016x}-l1svm-b{}.json", 7, lambda_bucket(1.0)));
        assert!(path.is_file(), "snapshot file exists at the documented name");
        for bad in [
            "",                                     // empty
            "{\"fingerprint\":",                    // truncated JSON
            "{\"fingerprint\":\"0000000000000007\"}", // fields missing
            // fingerprint mismatch: a file copied across datasets
            "{\"fingerprint\":\"0000000000000008\",\"workload\":\"l1svm\",\"lambda\":1.0,\"objective\":1.0,\"cols\":[],\"rows\":[]}",
            // λ disagrees with the bucket in the filename
            "{\"fingerprint\":\"0000000000000007\",\"workload\":\"l1svm\",\"lambda\":99.0,\"objective\":1.0,\"cols\":[],\"rows\":[]}",
            // non-integer working-set index
            "{\"fingerprint\":\"0000000000000007\",\"workload\":\"l1svm\",\"lambda\":1.0,\"objective\":1.0,\"cols\":[1.5],\"rows\":[]}",
        ] {
            fs::write(&path, bad).unwrap();
            assert!(
                store.load(7, Workload::L1svm, lambda_bucket(1.0)).is_none(),
                "loaded corrupt doc {bad:?}"
            );
        }
        // a rewrite through save() repairs the key
        store.save(7, Workload::L1svm, &e).unwrap();
        assert!(store.load(7, Workload::L1svm, lambda_bucket(1.0)).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn degenerate_lambda_bucket_is_storable() {
        let dir = scratch("degenerate");
        let store = SnapshotStore::open(&dir).unwrap();
        let e = CacheEntry { lambda: 0.0, objective: 0.0, ws: WorkingSet::default() };
        store.save(1, Workload::Dantzig, &e).unwrap();
        let back = store.load(1, Workload::Dantzig, lambda_bucket(0.0)).unwrap();
        assert_eq!(back.lambda, 0.0);
        assert!(back.ws.is_empty());
        let _ = fs::remove_dir_all(&dir);
    }
}
