//! The warm-start cache: `(dataset fingerprint, workload, λ-bucket)` →
//! working-set snapshot.
//!
//! The paper's central observation (Algorithm 2) is that restricted
//! models warm-started from a nearby λ converge in a handful of rounds.
//! The cache makes that observation request-shaped: after every solve the
//! final [`WorkingSet`] is stored under a logarithmic λ-bucket, and a
//! later request for a nearby λ (same data, same workload) seeds its
//! restricted model from the snapshot instead of the cold heuristics.
//! Lookups scan outward from the requested bucket up to
//! [`NEIGHBORHOOD`] buckets, so a hit means the cached λ is within a
//! factor of roughly `STEP^(NEIGHBORHOOD + ½)` of the request.
//!
//! Bounded two ways: by entry count (`cap`) and optionally by estimated
//! resident bytes (see [`WarmCache::set_max_bytes`]). Eviction is
//! least-recently-used — every lookup hit refreshes its entry's recency,
//! so a daemon hammered at a few hot λ's keeps those snapshots alive no
//! matter how much one-off traffic flows past them.

use std::collections::HashMap;

use super::protocol::Workload;
use crate::engine::WorkingSet;

/// Natural log of the bucket ratio (1.25): buckets are ~25% wide in λ.
const LN_STEP: f64 = 0.223_143_551_314_209_76;

/// How many buckets away a lookup may wander on each side.
pub const NEIGHBORHOOD: i64 = 2;

/// Logarithmic λ-bucket index (non-positive or non-finite λ's share one
/// out-of-band bucket).
pub fn lambda_bucket(lambda: f64) -> i64 {
    if lambda > 0.0 && lambda.is_finite() {
        (lambda.ln() / LN_STEP).round() as i64
    } else {
        i64::MIN / 2
    }
}

/// Cache key: which data, which estimator, which λ-neighborhood.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Dataset content fingerprint (see `serve::registry::fingerprint`).
    pub fingerprint: u64,
    /// Workload the snapshot came from.
    pub workload: Workload,
    /// λ-bucket (see [`lambda_bucket`]).
    pub bucket: i64,
}

/// A stored snapshot plus the solve it came from.
#[derive(Clone, Debug)]
pub struct CacheEntry {
    /// λ the snapshot was converged at.
    pub lambda: f64,
    /// Full-problem objective of that solve.
    pub objective: f64,
    /// The exported working sets.
    pub ws: WorkingSet,
}

impl CacheEntry {
    /// Estimated resident bytes of this entry: the two index vectors plus
    /// a fixed overhead for the key, the scalars, and the map slot. The
    /// same sizing convention as `Design::resident_bytes` — an accounting
    /// estimate, not an allocator measurement.
    pub fn resident_bytes(&self) -> usize {
        96 + 8 * (self.ws.cols.len() + self.ws.rows.len())
    }
}

/// A cache hit: the entry plus how many buckets away it was found.
#[derive(Clone, Debug)]
pub struct CacheHit {
    /// The matched snapshot.
    pub entry: CacheEntry,
    /// Bucket distance (0 = exact bucket).
    pub distance: i64,
}

/// An entry plus its last-touched tick for LRU ordering.
struct Slot {
    entry: CacheEntry,
    last_used: u64,
}

/// Bounded warm-start cache with hit/miss counters and LRU eviction.
pub struct WarmCache {
    map: HashMap<CacheKey, Slot>,
    cap: usize,
    /// Byte budget (0 = unbounded); see [`WarmCache::set_max_bytes`].
    max_bytes: usize,
    /// Current estimated resident bytes across all entries.
    bytes: usize,
    /// Monotone logical clock; bumped on every lookup hit and insert.
    clock: u64,
    /// Lookups that found a snapshot.
    pub hits: u64,
    /// Lookups that found nothing within the neighborhood.
    pub misses: u64,
    /// Entries evicted to satisfy the entry cap or byte budget.
    pub evictions: u64,
}

impl WarmCache {
    /// Cache bounded to `cap` entries (clamped to ≥ 1), no byte budget.
    pub fn new(cap: usize) -> Self {
        Self {
            map: HashMap::new(),
            cap: cap.max(1),
            max_bytes: 0,
            bytes: 0,
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Bound the cache's estimated resident bytes (0 = unbounded). The
    /// least-recently-used entries are evicted until the total fits; a
    /// single entry larger than the budget is kept (the cache never
    /// evicts itself empty), so the bound is `max(max_bytes, largest
    /// entry)`.
    pub fn set_max_bytes(&mut self, max_bytes: usize) {
        self.max_bytes = max_bytes;
        self.evict_over_budget();
    }

    /// Number of stored snapshots.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Estimated resident bytes of all stored snapshots.
    pub fn resident_bytes(&self) -> usize {
        self.bytes
    }

    /// Find the nearest snapshot for `(fingerprint, workload)` within
    /// [`NEIGHBORHOOD`] buckets of λ, preferring smaller distances. A hit
    /// refreshes the entry's recency.
    pub fn lookup(
        &mut self,
        fingerprint: u64,
        workload: Workload,
        lambda: f64,
    ) -> Option<CacheHit> {
        let bucket = lambda_bucket(lambda);
        for distance in 0..=NEIGHBORHOOD {
            for b in [bucket - distance, bucket + distance] {
                let key = CacheKey { fingerprint, workload, bucket: b };
                if let Some(slot) = self.map.get_mut(&key) {
                    self.clock += 1;
                    slot.last_used = self.clock;
                    self.hits += 1;
                    return Some(CacheHit { entry: slot.entry.clone(), distance });
                }
                if distance == 0 {
                    break; // bucket − 0 == bucket + 0
                }
            }
        }
        self.misses += 1;
        None
    }

    /// Store a snapshot under λ's bucket (replacing that bucket's prior
    /// snapshot, if any) and evict least-recently-used entries beyond the
    /// entry cap or byte budget.
    pub fn insert(&mut self, fingerprint: u64, workload: Workload, entry: CacheEntry) {
        let key = CacheKey { fingerprint, workload, bucket: lambda_bucket(entry.lambda) };
        self.clock += 1;
        let added = entry.resident_bytes();
        if let Some(old) = self.map.insert(key, Slot { entry, last_used: self.clock }) {
            self.bytes -= old.entry.resident_bytes();
        }
        self.bytes += added;
        self.evict_over_budget();
    }

    /// Remove every snapshot stored under `fingerprint` (all workloads,
    /// all λ-buckets) and return how many were removed. Used by the
    /// `unregister` op and registry-level eviction. This reclaims bytes,
    /// not correctness: entries are keyed by *content* fingerprint, so a
    /// snapshot left behind could only ever be hit again by re-registering
    /// byte-identical data — for which it is a valid warm start. Purged
    /// entries are not counted in [`WarmCache::evictions`] (they were
    /// invalidated, not squeezed out by the budget).
    pub fn purge_fingerprint(&mut self, fingerprint: u64) -> usize {
        let victims: Vec<CacheKey> =
            self.map.keys().filter(|k| k.fingerprint == fingerprint).copied().collect();
        for key in &victims {
            if let Some(slot) = self.map.remove(key) {
                self.bytes -= slot.entry.resident_bytes();
            }
        }
        victims.len()
    }

    /// Re-key the snapshots stored under `from` to `to`, for the
    /// workloads whose working sets index *features*, not samples:
    /// L1-SVM and Slope columns, and Dantzig rows (which are feature
    /// correlation constraints). RankSVM snapshots index sample pairs
    /// and Group snapshots fold the grouping into their key, so both are
    /// skipped. Returns the number of snapshots copied. This is what
    /// lets an `update`-derived dataset (samples retired or appended)
    /// start warm from its parent's λ-path.
    pub fn translate_fingerprint(&mut self, from: u64, to: u64) -> usize {
        if from == to {
            return 0;
        }
        let items: Vec<(Workload, CacheEntry)> = self
            .map
            .iter()
            .filter(|(k, _)| {
                k.fingerprint == from
                    && matches!(k.workload, Workload::L1svm | Workload::Slope | Workload::Dantzig)
            })
            .map(|(k, slot)| (k.workload, slot.entry.clone()))
            .collect();
        let copied = items.len();
        for (workload, entry) in items {
            self.insert(to, workload, entry);
        }
        copied
    }

    /// Number of snapshots stored under `(fingerprint, workload)` across
    /// all λ-buckets. The `update` op reports this for the pair-indexed
    /// workloads [`WarmCache::translate_fingerprint`] must skip, so a
    /// client learns *how much* warm state the derived dataset did not
    /// inherit instead of silently cold-solving into it.
    pub fn count_snapshots(&self, fingerprint: u64, workload: Workload) -> usize {
        self.map
            .keys()
            .filter(|k| k.fingerprint == fingerprint && k.workload == workload)
            .count()
    }

    /// Evict least-recently-used entries while over the entry cap or the
    /// byte budget, always keeping at least one entry.
    fn evict_over_budget(&mut self) {
        while self.map.len() > 1
            && (self.map.len() > self.cap || (self.max_bytes > 0 && self.bytes > self.max_bytes))
        {
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, slot)| slot.last_used)
                .map(|(key, _)| *key)
                .expect("non-empty map has a minimum");
            if let Some(slot) = self.map.remove(&victim) {
                self.bytes -= slot.entry.resident_bytes();
                self.evictions += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(lambda: f64) -> CacheEntry {
        CacheEntry {
            lambda,
            objective: 1.0,
            ws: WorkingSet { cols: vec![1, 2], rows: vec![] },
        }
    }

    #[test]
    fn buckets_are_logarithmic() {
        assert_eq!(lambda_bucket(1.0), 0);
        assert_eq!(lambda_bucket(1.25), 1);
        assert_eq!(lambda_bucket(0.8), -1);
        // within-bucket wiggle maps to the same index
        assert_eq!(lambda_bucket(0.05), lambda_bucket(0.052));
        // degenerate λ's share the out-of-band bucket
        assert_eq!(lambda_bucket(0.0), lambda_bucket(-3.0));
        assert_ne!(lambda_bucket(0.0), lambda_bucket(1e-300));
    }

    #[test]
    fn lookup_prefers_nearest_bucket() {
        let mut c = WarmCache::new(8);
        c.insert(7, Workload::L1svm, entry(1.0));
        c.insert(7, Workload::L1svm, entry(2.0)); // ~3 buckets up
        let hit = c.lookup(7, Workload::L1svm, 1.02).unwrap();
        assert_eq!(hit.entry.lambda, 1.0);
        assert_eq!(hit.distance, 0);
        // a nearby-but-different bucket still hits, with distance > 0
        let hit = c.lookup(7, Workload::L1svm, 1.35).unwrap();
        assert!(hit.distance > 0);
        // far λ misses
        assert!(c.lookup(7, Workload::L1svm, 50.0).is_none());
        // other fingerprints and workloads are isolated
        assert!(c.lookup(8, Workload::L1svm, 1.0).is_none());
        assert!(c.lookup(7, Workload::Dantzig, 1.0).is_none());
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 3);
    }

    #[test]
    fn eviction_is_bounded_by_the_entry_cap() {
        let mut c = WarmCache::new(2);
        c.insert(1, Workload::L1svm, entry(1.0));
        c.insert(1, Workload::L1svm, entry(10.0));
        c.insert(1, Workload::L1svm, entry(100.0));
        assert_eq!(c.len(), 2);
        assert!(c.lookup(1, Workload::L1svm, 1.0).is_none(), "least-recent evicted");
        assert!(c.lookup(1, Workload::L1svm, 10.0).is_some());
        assert!(c.lookup(1, Workload::L1svm, 100.0).is_some());
        assert_eq!(c.evictions, 1);
        // same-bucket reinsert replaces in place without growing the cache
        c.insert(1, Workload::L1svm, entry(100.0));
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions, 1);
    }

    #[test]
    fn eviction_respects_recency_not_insertion_order() {
        let mut c = WarmCache::new(2);
        c.insert(1, Workload::L1svm, entry(1.0));
        c.insert(1, Workload::L1svm, entry(10.0));
        // touch the older entry: it becomes most-recent
        assert!(c.lookup(1, Workload::L1svm, 1.0).is_some());
        c.insert(1, Workload::L1svm, entry(100.0));
        assert_eq!(c.len(), 2);
        assert!(c.lookup(1, Workload::L1svm, 1.0).is_some(), "touched entry survives");
        assert!(c.lookup(1, Workload::L1svm, 10.0).is_none(), "untouched entry evicted");
        assert!(c.lookup(1, Workload::L1svm, 100.0).is_some());
    }

    #[test]
    fn purge_drops_all_buckets_of_a_fingerprint() {
        let mut c = WarmCache::new(16);
        c.insert(1, Workload::L1svm, entry(1.0));
        c.insert(1, Workload::L1svm, entry(10.0));
        c.insert(1, Workload::Ranksvm, entry(1.0));
        c.insert(2, Workload::L1svm, entry(1.0));
        let bytes_before = c.resident_bytes();
        assert_eq!(c.purge_fingerprint(1), 3);
        assert_eq!(c.len(), 1);
        assert!(c.resident_bytes() < bytes_before);
        assert!(c.lookup(2, Workload::L1svm, 1.0).is_some());
        assert_eq!(c.evictions, 0, "purges are not budget evictions");
        assert_eq!(c.purge_fingerprint(99), 0);
    }

    #[test]
    fn translate_copies_feature_indexed_workloads_only() {
        let mut c = WarmCache::new(16);
        c.insert(1, Workload::L1svm, entry(1.0));
        c.insert(1, Workload::Slope, entry(1.0));
        c.insert(1, Workload::Dantzig, entry(2.0));
        c.insert(1, Workload::Ranksvm, entry(1.0));
        assert_eq!(c.translate_fingerprint(1, 9), 3);
        assert!(c.lookup(9, Workload::L1svm, 1.0).is_some());
        assert!(c.lookup(9, Workload::Slope, 1.0).is_some());
        assert!(c.lookup(9, Workload::Dantzig, 2.0).is_some());
        assert!(c.lookup(9, Workload::Ranksvm, 1.0).is_none(), "pair-indexed: skipped");
        // originals survive the translation
        assert!(c.lookup(1, Workload::L1svm, 1.0).is_some());
        assert_eq!(c.translate_fingerprint(1, 1), 0, "same-fingerprint no-op");
    }

    #[test]
    fn count_snapshots_scopes_by_fingerprint_and_workload() {
        let mut c = WarmCache::new(16);
        c.insert(1, Workload::Ranksvm, entry(1.0));
        c.insert(1, Workload::Ranksvm, entry(10.0));
        c.insert(1, Workload::L1svm, entry(1.0));
        c.insert(2, Workload::Ranksvm, entry(1.0));
        assert_eq!(c.count_snapshots(1, Workload::Ranksvm), 2);
        assert_eq!(c.count_snapshots(1, Workload::L1svm), 1);
        assert_eq!(c.count_snapshots(2, Workload::Ranksvm), 1);
        assert_eq!(c.count_snapshots(3, Workload::Ranksvm), 0);
    }

    #[test]
    fn byte_budget_evicts_lru_and_tracks_accounting() {
        fn big(lambda: f64, cols: usize) -> CacheEntry {
            CacheEntry {
                lambda,
                objective: 1.0,
                ws: WorkingSet { cols: (0..cols).collect(), rows: vec![] },
            }
        }
        let mut c = WarmCache::new(1000);
        // each entry: 96 + 8·100 = 896 bytes; budget fits two, not three
        c.set_max_bytes(2 * 896 + 10);
        c.insert(1, Workload::L1svm, big(1.0, 100));
        assert_eq!(c.resident_bytes(), 896);
        c.insert(1, Workload::L1svm, big(10.0, 100));
        assert_eq!(c.resident_bytes(), 2 * 896);
        assert!(c.lookup(1, Workload::L1svm, 1.0).is_some()); // refresh λ=1
        c.insert(1, Workload::L1svm, big(100.0, 100));
        assert_eq!(c.len(), 2);
        assert_eq!(c.resident_bytes(), 2 * 896);
        assert_eq!(c.evictions, 1);
        assert!(c.lookup(1, Workload::L1svm, 10.0).is_none(), "LRU entry evicted");
        assert!(c.lookup(1, Workload::L1svm, 1.0).is_some());
        // a single entry over the budget is still retained
        let mut tiny = WarmCache::new(1000);
        tiny.set_max_bytes(8);
        tiny.insert(1, Workload::L1svm, big(1.0, 100));
        assert_eq!(tiny.len(), 1, "never evicts down to empty");
        // replacing a bucket updates accounting instead of double-counting
        tiny.set_max_bytes(0);
        tiny.insert(1, Workload::L1svm, big(1.0, 10));
        assert_eq!(tiny.resident_bytes(), 96 + 80);
    }
}
