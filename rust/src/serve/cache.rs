//! The warm-start cache: `(dataset fingerprint, workload, λ-bucket)` →
//! working-set snapshot.
//!
//! The paper's central observation (Algorithm 2) is that restricted
//! models warm-started from a nearby λ converge in a handful of rounds.
//! The cache makes that observation request-shaped: after every solve the
//! final [`WorkingSet`] is stored under a logarithmic λ-bucket, and a
//! later request for a nearby λ (same data, same workload) seeds its
//! restricted model from the snapshot instead of the cold heuristics.
//! Lookups scan outward from the requested bucket up to
//! [`NEIGHBORHOOD`] buckets, so a hit means the cached λ is within a
//! factor of roughly `STEP^(NEIGHBORHOOD + ½)` of the request.
//!
//! Bounded: beyond `cap` entries the oldest-inserted key is evicted
//! (generation working sets are small — tens of indices — so the default
//! cap is generous).

use std::collections::{HashMap, VecDeque};

use super::protocol::Workload;
use crate::engine::WorkingSet;

/// Natural log of the bucket ratio (1.25): buckets are ~25% wide in λ.
const LN_STEP: f64 = 0.223_143_551_314_209_76;

/// How many buckets away a lookup may wander on each side.
pub const NEIGHBORHOOD: i64 = 2;

/// Logarithmic λ-bucket index (non-positive or non-finite λ's share one
/// out-of-band bucket).
pub fn lambda_bucket(lambda: f64) -> i64 {
    if lambda > 0.0 && lambda.is_finite() {
        (lambda.ln() / LN_STEP).round() as i64
    } else {
        i64::MIN / 2
    }
}

/// Cache key: which data, which estimator, which λ-neighborhood.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Dataset content fingerprint (see `serve::registry::fingerprint`).
    pub fingerprint: u64,
    /// Workload the snapshot came from.
    pub workload: Workload,
    /// λ-bucket (see [`lambda_bucket`]).
    pub bucket: i64,
}

/// A stored snapshot plus the solve it came from.
#[derive(Clone, Debug)]
pub struct CacheEntry {
    /// λ the snapshot was converged at.
    pub lambda: f64,
    /// Full-problem objective of that solve.
    pub objective: f64,
    /// The exported working sets.
    pub ws: WorkingSet,
}

/// A cache hit: the entry plus how many buckets away it was found.
#[derive(Clone, Debug)]
pub struct CacheHit {
    /// The matched snapshot.
    pub entry: CacheEntry,
    /// Bucket distance (0 = exact bucket).
    pub distance: i64,
}

/// Bounded warm-start cache with hit/miss counters.
pub struct WarmCache {
    map: HashMap<CacheKey, CacheEntry>,
    /// Keys in insertion order (each key appears once) for FIFO eviction.
    order: VecDeque<CacheKey>,
    cap: usize,
    /// Lookups that found a snapshot.
    pub hits: u64,
    /// Lookups that found nothing within the neighborhood.
    pub misses: u64,
}

impl WarmCache {
    /// Cache bounded to `cap` entries (clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        Self {
            map: HashMap::new(),
            order: VecDeque::new(),
            cap: cap.max(1),
            hits: 0,
            misses: 0,
        }
    }

    /// Number of stored snapshots.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Find the nearest snapshot for `(fingerprint, workload)` within
    /// [`NEIGHBORHOOD`] buckets of λ, preferring smaller distances.
    pub fn lookup(
        &mut self,
        fingerprint: u64,
        workload: Workload,
        lambda: f64,
    ) -> Option<CacheHit> {
        let bucket = lambda_bucket(lambda);
        for distance in 0..=NEIGHBORHOOD {
            for b in [bucket - distance, bucket + distance] {
                let key = CacheKey { fingerprint, workload, bucket: b };
                if let Some(entry) = self.map.get(&key) {
                    self.hits += 1;
                    return Some(CacheHit { entry: entry.clone(), distance });
                }
                if distance == 0 {
                    break; // bucket − 0 == bucket + 0
                }
            }
        }
        self.misses += 1;
        None
    }

    /// Store a snapshot under λ's bucket (replacing that bucket's prior
    /// snapshot, if any) and evict the oldest key beyond the cap.
    pub fn insert(&mut self, fingerprint: u64, workload: Workload, entry: CacheEntry) {
        let key = CacheKey { fingerprint, workload, bucket: lambda_bucket(entry.lambda) };
        if self.map.insert(key, entry).is_none() {
            self.order.push_back(key);
        }
        while self.map.len() > self.cap {
            let oldest = self.order.pop_front().expect("order tracks map");
            self.map.remove(&oldest);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(lambda: f64) -> CacheEntry {
        CacheEntry {
            lambda,
            objective: 1.0,
            ws: WorkingSet { cols: vec![1, 2], rows: vec![] },
        }
    }

    #[test]
    fn buckets_are_logarithmic() {
        assert_eq!(lambda_bucket(1.0), 0);
        assert_eq!(lambda_bucket(1.25), 1);
        assert_eq!(lambda_bucket(0.8), -1);
        // within-bucket wiggle maps to the same index
        assert_eq!(lambda_bucket(0.05), lambda_bucket(0.052));
        // degenerate λ's share the out-of-band bucket
        assert_eq!(lambda_bucket(0.0), lambda_bucket(-3.0));
        assert_ne!(lambda_bucket(0.0), lambda_bucket(1e-300));
    }

    #[test]
    fn lookup_prefers_nearest_bucket() {
        let mut c = WarmCache::new(8);
        c.insert(7, Workload::L1svm, entry(1.0));
        c.insert(7, Workload::L1svm, entry(2.0)); // ~3 buckets up
        let hit = c.lookup(7, Workload::L1svm, 1.02).unwrap();
        assert_eq!(hit.entry.lambda, 1.0);
        assert_eq!(hit.distance, 0);
        // a nearby-but-different bucket still hits, with distance > 0
        let hit = c.lookup(7, Workload::L1svm, 1.35).unwrap();
        assert!(hit.distance > 0);
        // far λ misses
        assert!(c.lookup(7, Workload::L1svm, 50.0).is_none());
        // other fingerprints and workloads are isolated
        assert!(c.lookup(8, Workload::L1svm, 1.0).is_none());
        assert!(c.lookup(7, Workload::Dantzig, 1.0).is_none());
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 3);
    }

    #[test]
    fn eviction_is_fifo_and_bounded() {
        let mut c = WarmCache::new(2);
        c.insert(1, Workload::L1svm, entry(1.0));
        c.insert(1, Workload::L1svm, entry(10.0));
        c.insert(1, Workload::L1svm, entry(100.0));
        assert_eq!(c.len(), 2);
        assert!(c.lookup(1, Workload::L1svm, 1.0).is_none(), "oldest evicted");
        assert!(c.lookup(1, Workload::L1svm, 10.0).is_some());
        assert!(c.lookup(1, Workload::L1svm, 100.0).is_some());
        // same-bucket reinsert replaces in place without growing the order
        c.insert(1, Workload::L1svm, entry(100.0));
        assert_eq!(c.len(), 2);
    }
}
