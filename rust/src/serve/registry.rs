//! The dataset registry: load and fingerprint each design matrix once,
//! share it across requests via `Arc`.
//!
//! Every [`DatasetEntry`] stores the dataset with **raw** responses (the
//! form RankSVM relevances and Dantzig-selector targets need) plus two
//! lazily built, built-at-most-once views:
//!
//! * [`DatasetEntry::classification`] — `y` mapped to ±1 for the
//!   hinge-loss workloads. When the labels already are ±1 (the common
//!   case) this is the stored dataset itself, no copy;
//! * [`DatasetEntry::pairs`] — the RankSVM comparison-pair
//!   [`PairSet`], computed on the first ranking request and reused by
//!   every later one. The registry no longer caches an O(n²) pair
//!   enumeration: the `PairSet` enumerates only below the auto
//!   threshold and otherwise keeps the O(n) sorted-order implicit form.
//!   Its canonical pair indexing (and [`PairSet::fingerprint`], which
//!   keys the ranking warm-start cache) is derived deterministically
//!   from the sorted order of `y`, which is what makes cached
//!   pair-index snapshots restorable — under either representation.
//!
//! The fingerprint keys the warm-start cache: two registrations of the
//! same matrix (even under different names) share cache entries, and
//! re-registering a *different* dataset under an old name can never
//! resurrect stale working sets.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};

use crate::bail;
use crate::data::synthetic::{
    generate_dantzig, generate_group, generate_l1, generate_ranksvm, generate_sparse_text,
    DantzigSpec, GroupSpec, RankSpec, SparseTextSpec, SyntheticSpec,
};
use crate::data::{libsvm, Dataset};
use crate::engine::PairMode;
use crate::error::{Context, Result};
use crate::rng::Xoshiro256;
use crate::workloads::pairset::PairSet;

/// One loaded dataset plus its derived views.
pub struct DatasetEntry {
    /// Registration name.
    pub name: String,
    /// The dataset with raw (unmapped) responses.
    pub ds: Dataset,
    /// Content fingerprint (see [`fingerprint()`]).
    pub fingerprint: u64,
    /// ±1-label view, built at most once (only when `y` is not already ±1).
    class_view: OnceLock<Dataset>,
    /// RankSVM comparison-pair set, built at most once.
    pairs: OnceLock<PairSet>,
    /// Logical tick of the last registry access (insert or lookup) —
    /// the recency the `--registry-bytes` LRU eviction orders by.
    last_used: AtomicU64,
}

impl DatasetEntry {
    /// Wrap a dataset, computing its fingerprint.
    pub fn new(name: &str, ds: Dataset) -> Self {
        let fingerprint = fingerprint(&ds);
        Self {
            name: name.to_string(),
            ds,
            fingerprint,
            class_view: OnceLock::new(),
            pairs: OnceLock::new(),
            last_used: AtomicU64::new(0),
        }
    }

    /// Estimated resident bytes of this entry: the design, the response
    /// vector, and any lazily built views (±1 labels, comparison pairs)
    /// that exist right now. The same sizing convention as
    /// `Design::resident_bytes` — an accounting estimate, not an
    /// allocator measurement.
    pub fn resident_bytes(&self) -> usize {
        self.ds.x.resident_bytes()
            + 8 * self.ds.y.len()
            + self
                .class_view
                .get()
                .map_or(0, |d| d.x.resident_bytes() + 8 * d.y.len())
            + self.built_pairs().map_or(0, |p| p.resident_bytes())
    }

    /// The dataset with labels mapped to ±1 (hinge-loss workloads).
    /// Returns the stored dataset directly when its labels already are
    /// ±1; otherwise clones the design once, on first use.
    pub fn classification(&self) -> &Dataset {
        if self.ds.y.iter().all(|&v| v == 1.0 || v == -1.0) {
            return &self.ds;
        }
        self.class_view.get_or_init(|| Dataset {
            x: self.ds.x.clone(),
            y: self.ds.y.iter().map(|&v| if v > 0.0 { 1.0 } else { -1.0 }).collect(),
        })
    }

    /// The RankSVM comparison-pair set over the raw responses (computed
    /// on first use, shared afterwards; [`PairMode::Auto`] — enumerated
    /// below the threshold, implicit beyond).
    pub fn pairs(&self) -> &PairSet {
        self.pairs.get_or_init(|| PairSet::build(&self.ds.y, PairMode::Auto))
    }

    /// The comparison-pair set *if it has already been built* — `None`
    /// before the first ranking request. Memory accounting (`stats`)
    /// uses this so reporting a dataset's footprint never forces the
    /// pair construction it is trying to measure.
    pub fn built_pairs(&self) -> Option<&PairSet> {
        self.pairs.get()
    }
}

/// Content fingerprint: FNV-1a over the dimensions, stored-nonzero
/// count, every response bit, and the per-column absolute sums of the
/// design — cheap (one O(nnz) pass) yet sensitive to any label edit and
/// to any column's data changing.
pub fn fingerprint(ds: &Dataset) -> u64 {
    let mut h = crate::rng::Fnv1a::new();
    h.eat(&(ds.n() as u64).to_le_bytes());
    h.eat(&(ds.p() as u64).to_le_bytes());
    h.eat(&(ds.x.nnz() as u64).to_le_bytes());
    for &v in &ds.y {
        h.eat(&v.to_bits().to_le_bytes());
    }
    let mut colsums = vec![0.0; ds.p()];
    ds.x.abs_col_sums(&mut colsums);
    for v in colsums {
        h.eat(&v.to_bits().to_le_bytes());
    }
    h.finish()
}

/// The one loading path shared by the registry and the one-shot CLI:
/// read a libsvm file, keeping raw responses when `raw_labels` is set
/// (RankSVM / Dantzig) and mapping to ±1 otherwise.
pub fn load_libsvm(path: &str, raw_labels: bool) -> Result<Dataset> {
    let ds = if raw_labels {
        libsvm::read_file_raw(path, 0)
    } else {
        libsvm::read_file(path, 0)
    };
    ds.with_context(|| format!("loading libsvm file {path}"))
}

/// Knobs for synthetic registration (mirrors `cutgen datagen`).
#[derive(Clone, Copy, Debug, Default)]
pub struct SynthOpts {
    /// Nonzero density for the `sparse` kind (default 0.01).
    pub density: Option<f64>,
    /// Group size for the `group` kind (default 10).
    pub group_size: Option<usize>,
}

/// Generate a synthetic dataset by kind name (`l1`, `sparse`, `group`,
/// `ranksvm`, `dantzig`).
pub fn generate_synthetic(
    kind: &str,
    n: usize,
    p: usize,
    seed: u64,
    opts: &SynthOpts,
) -> Result<Dataset> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    Ok(match kind {
        "l1" => generate_l1(&SyntheticSpec::paper_default(n, p), &mut rng),
        "sparse" => generate_sparse_text(
            &SparseTextSpec {
                n,
                p,
                density: opts.density.unwrap_or(0.01),
                k0: 50.min(p),
                zipf: 1.1,
            },
            &mut rng,
        ),
        "group" => {
            let gs = opts.group_size.unwrap_or(10).max(1);
            if p % gs != 0 {
                bail!("synthetic group data needs p divisible by group_size ({p} % {gs} != 0)");
            }
            generate_group(
                &GroupSpec {
                    n,
                    n_groups: p / gs,
                    group_size: gs,
                    k0_groups: 3.min(p / gs),
                    rho: 0.1,
                    standardize: true,
                },
                &mut rng,
            )
            .data
        }
        "ranksvm" => generate_ranksvm(
            &RankSpec { n, p, k0: 10.min(p), rho: 0.1, noise: 0.3, standardize: true },
            &mut rng,
        ),
        "dantzig" => generate_dantzig(
            &DantzigSpec { n, p, k0: 10.min(p), rho: 0.1, sigma: 0.5, standardize: true },
            &mut rng,
        ),
        other => bail!("unknown synthetic kind {other:?} (l1|sparse|group|ranksvm|dantzig)"),
    })
}

/// Name → dataset map behind a read-write lock: registrations are rare,
/// lookups are every request. Every insert and lookup stamps the entry
/// with a monotone tick so the serve layer's `--registry-bytes` budget
/// can evict the least-recently-used dataset.
#[derive(Default)]
pub struct Registry {
    map: RwLock<HashMap<String, Arc<DatasetEntry>>>,
    /// Monotone logical clock behind the per-entry recency stamps.
    clock: AtomicU64,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Next recency tick (relaxed: ordering between concurrent touches
    /// only needs to be *some* total order, not a synchronized one).
    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Insert (or replace) a dataset under `name`. Replacement is safe
    /// for the warm-start cache because entries are keyed by content
    /// fingerprint, not by name.
    pub fn insert(&self, name: &str, ds: Dataset) -> Arc<DatasetEntry> {
        let entry = Arc::new(DatasetEntry::new(name, ds));
        entry.last_used.store(self.tick(), Ordering::Relaxed);
        self.map.write().expect("registry lock").insert(name.to_string(), entry.clone());
        entry
    }

    /// Load a libsvm file (raw responses preserved) and register it.
    pub fn register_file(&self, name: &str, path: &str) -> Result<Arc<DatasetEntry>> {
        let ds = load_libsvm(path, true)?;
        Ok(self.insert(name, ds))
    }

    /// Generate a synthetic dataset and register it.
    pub fn register_synthetic(
        &self,
        name: &str,
        kind: &str,
        n: usize,
        p: usize,
        seed: u64,
        opts: &SynthOpts,
    ) -> Result<Arc<DatasetEntry>> {
        Ok(self.insert(name, generate_synthetic(kind, n, p, seed, opts)?))
    }

    /// Shared handle to a registered dataset. Refreshes its recency.
    pub fn get(&self, name: &str) -> Option<Arc<DatasetEntry>> {
        let entry = self.map.read().expect("registry lock").get(name).cloned()?;
        entry.last_used.store(self.tick(), Ordering::Relaxed);
        Some(entry)
    }

    /// Drop a dataset, returning the removed entry so the caller can
    /// release derived state (warm-cache snapshots keyed by its
    /// fingerprint). Live `Arc` handles held by in-flight requests stay
    /// valid — removal only unpublishes the name.
    pub fn remove(&self, name: &str) -> Option<Arc<DatasetEntry>> {
        self.map.write().expect("registry lock").remove(name)
    }

    /// Estimated resident bytes across all registered datasets — the
    /// quantity the serve layer's `--registry-bytes` budget bounds.
    pub fn resident_bytes(&self) -> usize {
        self.map.read().expect("registry lock").values().map(|e| e.resident_bytes()).sum()
    }

    /// Name of the least-recently-used dataset other than `except` (a
    /// just-registered entry must never evict itself). `None` when no
    /// other dataset exists.
    pub fn lru_victim(&self, except: &str) -> Option<String> {
        self.map
            .read()
            .expect("registry lock")
            .iter()
            .filter(|(name, _)| name.as_str() != except)
            .min_by_key(|(_, e)| e.last_used.load(Ordering::Relaxed))
            .map(|(name, _)| name.clone())
    }

    /// Number of registered datasets.
    pub fn len(&self) -> usize {
        self.map.read().expect("registry lock").len()
    }

    /// Whether the registry is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.map.read().expect("registry lock").keys().cloned().collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_tracks_content_not_name() {
        let a = generate_synthetic("l1", 20, 15, 3, &SynthOpts::default()).unwrap();
        let b = generate_synthetic("l1", 20, 15, 3, &SynthOpts::default()).unwrap();
        let c = generate_synthetic("l1", 20, 15, 4, &SynthOpts::default()).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b), "same draw, same print");
        assert_ne!(fingerprint(&a), fingerprint(&c), "different seed, different print");
        let mut d = generate_synthetic("l1", 20, 15, 3, &SynthOpts::default()).unwrap();
        d.y[0] = -d.y[0];
        assert_ne!(fingerprint(&a), fingerprint(&d), "label flip changes the print");
    }

    #[test]
    fn classification_view_is_shared_and_lazy() {
        let reg = Registry::new();
        // ±1 labels: the classification view is the stored dataset itself
        let e = reg
            .register_synthetic("c", "l1", 15, 10, 1, &SynthOpts::default())
            .unwrap();
        assert!(std::ptr::eq(e.classification(), &e.ds));
        // real-valued responses: built once, labels mapped by sign
        let r = reg
            .register_synthetic("r", "ranksvm", 12, 8, 1, &SynthOpts::default())
            .unwrap();
        let view = r.classification();
        assert!(!std::ptr::eq(view, &r.ds));
        assert!(std::ptr::eq(view, r.classification()), "second call reuses the view");
        assert!(view.y.iter().all(|&v| v == 1.0 || v == -1.0));
        for (raw, mapped) in r.ds.y.iter().zip(&view.y) {
            assert_eq!(*mapped, if *raw > 0.0 { 1.0 } else { -1.0 });
        }
    }

    #[test]
    fn registry_lookup_and_replace() {
        let reg = Registry::new();
        assert!(reg.is_empty());
        reg.register_synthetic("d", "l1", 10, 6, 1, &SynthOpts::default()).unwrap();
        let first = reg.get("d").unwrap().fingerprint;
        reg.register_synthetic("d", "l1", 10, 6, 2, &SynthOpts::default()).unwrap();
        let second = reg.get("d").unwrap().fingerprint;
        assert_ne!(first, second, "replacement swaps the entry");
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.names(), vec!["d".to_string()]);
        assert!(reg.get("missing").is_none());
    }

    #[test]
    fn remove_bytes_and_lru_ordering() {
        let reg = Registry::new();
        reg.register_synthetic("a", "l1", 12, 8, 1, &SynthOpts::default()).unwrap();
        reg.register_synthetic("b", "l1", 12, 8, 2, &SynthOpts::default()).unwrap();
        assert!(reg.resident_bytes() >= 2 * (12 * 8 * 8 + 12 * 8), "two dense designs + y");
        // "a" was inserted first, so it is the LRU victim ...
        assert_eq!(reg.lru_victim("").as_deref(), Some("a"));
        // ... until a lookup refreshes it, which shifts the victim to "b"
        reg.get("a").unwrap();
        assert_eq!(reg.lru_victim("").as_deref(), Some("b"));
        // the `except` guard protects a just-registered name
        assert_eq!(reg.lru_victim("b").as_deref(), Some("a"));
        let removed = reg.remove("b").expect("b was registered");
        assert_eq!(removed.name, "b");
        assert_eq!(reg.len(), 1);
        assert!(reg.remove("b").is_none(), "second removal is a no-op");
        assert_eq!(reg.lru_victim("a"), None, "no victim besides the protected entry");
        // entry bytes grow when a lazy view is built
        let e = reg.get("a").unwrap();
        let before = e.resident_bytes();
        e.pairs();
        assert!(e.resident_bytes() > before, "built pair set is accounted");
    }

    #[test]
    fn pairs_are_cached_and_deterministic() {
        let reg = Registry::new();
        let e = reg
            .register_synthetic("r", "ranksvm", 10, 6, 5, &SynthOpts::default())
            .unwrap();
        let p1 = e.pairs();
        let p2 = e.pairs();
        assert!(std::ptr::eq(p1, p2));
        assert!(p1.is_enumerated(), "tiny |P| stays enumerated under Auto");
        assert_eq!(p1.materialize(), crate::workloads::ranksvm::ranking_pairs(&e.ds.y));
        // the fingerprint keying the warm cache is representation-free
        let implicit = PairSet::build(&e.ds.y, PairMode::Implicit);
        assert_eq!(p1.fingerprint(), implicit.fingerprint());
    }
}
