//! Linearized ADMM for L1-SVM — the first-order comparator of the kind
//! the paper cites ([2], Balamurugan et al. 2016).
//!
//! Splitting: `min_β,z  Σᵢ (zᵢ)₊ + λ‖β‖₁  s.t.  z = 1 − y∘(X̃γ)` with
//! `γ = (β, β₀)`. The z-update is the hinge prox (closed form), the
//! γ-update is *linearized* (one proximal gradient step on the quadratic
//! coupling term — avoids an inner lasso solve), and the scaled dual `u`
//! ascends the residual. Converges to moderate accuracy fast, then slowly
//! — exactly the behaviour that motivates cutting planes for high
//! accuracy.

use crate::backend::{sigma_max_sq, Backend};
use crate::fom::prox::soft_threshold;

/// ADMM hyperparameters.
#[derive(Clone, Debug)]
pub struct AdmmParams {
    /// Penalty parameter ρ.
    pub rho: f64,
    /// Max iterations.
    pub max_iters: usize,
    /// Stop when primal and dual residuals fall below this.
    pub tol: f64,
}

impl Default for AdmmParams {
    fn default() -> Self {
        Self { rho: 1.0, max_iters: 2000, tol: 1e-4 }
    }
}

/// ADMM output.
#[derive(Clone, Debug)]
pub struct AdmmResult {
    pub beta: Vec<f64>,
    pub beta0: f64,
    pub iters: usize,
    /// Final primal residual ‖z − (1 − y∘X̃γ)‖.
    pub primal_residual: f64,
}

/// prox of `c·(·)₊` at `v`: argmin (z)₊·c + ½(z−v)²  (c = 1/ρ).
#[inline]
fn prox_hinge(v: f64, c: f64) -> f64 {
    if v > c {
        v - c
    } else if v < 0.0 {
        v
    } else {
        0.0
    }
}

/// Run linearized ADMM on the L1-SVM problem.
pub fn admm_l1svm(
    backend: &dyn Backend,
    y: &[f64],
    lambda: f64,
    params: &AdmmParams,
) -> AdmmResult {
    let n = backend.rows();
    let p = backend.cols();
    let rho = params.rho;
    // Lipschitz of the quadratic coupling ρ/2‖…X̃γ…‖²: ρ·σ_max(X̃ᵀX̃)
    let l = rho * sigma_max_sq(backend, 30).max(1e-12) * 1.05;

    let mut beta = vec![0.0; p];
    let mut beta0 = 0.0f64;
    let mut z = vec![0.0; n];
    let mut u = vec![0.0; n]; // scaled dual
    let mut xb = vec![0.0; n];
    let mut grad = vec![0.0; p];
    let mut iters = 0;
    let mut r_norm = f64::INFINITY;

    for t in 0..params.max_iters {
        iters = t + 1;
        // margins m = 1 − y∘(Xβ + β₀)
        backend.xb(&beta, &mut xb);
        // z-update: prox_{hinge/ρ}(m − u)
        let mut r_sq = 0.0;
        let mut s = vec![0.0; n]; // residual direction for γ-step: (z − m + u)
        for i in 0..n {
            let m_i = 1.0 - y[i] * (xb[i] + beta0);
            z[i] = prox_hinge(m_i - u[i], 1.0 / rho);
            let r = z[i] - m_i;
            r_sq += r * r;
            s[i] = r + u[i];
        }
        r_norm = r_sq.sqrt();
        // γ-update (linearized): the gradient of ρ/2‖z − m(γ) + u‖² w.r.t.
        // γ is ρ·X̃ᵀ(y ∘ s) (since ∂m/∂γ = −diag(y)X̃); take one descent
        // step then prox.
        let v: Vec<f64> = s.iter().zip(y).map(|(si, yi)| yi * si * rho).collect();
        backend.xtv(&v, &mut grad);
        let g0: f64 = v.iter().sum();
        for (b, g) in beta.iter_mut().zip(&grad) {
            *b -= g / l;
        }
        beta0 -= g0 / l;
        soft_threshold(&mut beta, lambda / l);
        // u-update
        backend.xb(&beta, &mut xb);
        let mut dual_move = 0.0;
        for i in 0..n {
            let m_i = 1.0 - y[i] * (xb[i] + beta0);
            let r = z[i] - m_i;
            u[i] += r;
            dual_move += r * r;
        }
        if r_norm < params.tol && dual_move.sqrt() < params.tol {
            break;
        }
    }
    AdmmResult { beta, beta0, iters, primal_residual: r_norm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::baselines::full_lp::solve_full_l1;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::fom::objective::l1_objective;
    use crate::rng::Xoshiro256;

    #[test]
    fn admm_approaches_lp_optimum() {
        let spec = SyntheticSpec { n: 40, p: 30, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(161));
        let lambda = 0.05 * ds.lambda_max_l1();
        let opt = solve_full_l1(&ds, lambda).objective;
        let backend = NativeBackend::new(&ds.x);
        let res = admm_l1svm(
            &backend,
            &ds.y,
            lambda,
            &AdmmParams { max_iters: 8000, tol: 1e-7, ..Default::default() },
        );
        let obj = l1_objective(&backend, &ds.y, &res.beta, res.beta0, lambda);
        let gap = (obj - opt) / opt.max(1e-9);
        assert!(gap < 0.02, "admm obj {obj} vs LP {opt} (gap {gap})");
        assert!(gap > -1e-6, "cannot beat the LP optimum");
    }

    #[test]
    fn admm_residual_shrinks() {
        let spec = SyntheticSpec { n: 30, p: 20, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(162));
        let lambda = 0.1 * ds.lambda_max_l1();
        let backend = NativeBackend::new(&ds.x);
        let short = admm_l1svm(&backend, &ds.y, lambda, &AdmmParams { max_iters: 10, tol: 0.0, ..Default::default() });
        let long = admm_l1svm(&backend, &ds.y, lambda, &AdmmParams { max_iters: 2000, tol: 0.0, ..Default::default() });
        assert!(long.primal_residual < short.primal_residual);
    }
}
