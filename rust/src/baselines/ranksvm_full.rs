//! Full-LP baseline for RankSVM: materialize every comparison pair — one
//! hinge slack and one margin row per pair, O(|P|·p) coefficients — and
//! solve in one shot. The point of comparison for the constraint
//! generation in [`crate::workloads::ranksvm`], constructed independently
//! of that module so agreement is a genuine cross-check.

use crate::coordinator::{GenStats, SvmSolution};
use crate::data::Dataset;
use crate::simplex::{LpModel, SimplexSolver, Status};

/// Solve the full pairwise-hinge L1 ranking LP at one λ:
/// `min Σ_t ξ_t + λ Σ_j (β⁺_j + β⁻_j)` s.t.
/// `ξ_t + Σ_j (x_ij − x_kj)(β⁺_j − β⁻_j) ≥ 1` for every pair `t = (i,k)`.
pub fn solve_full_ranksvm(
    ds: &Dataset,
    pairs: &[(usize, usize)],
    lambda: f64,
) -> SvmSolution {
    let costed: Vec<(usize, usize, f64, f64)> =
        pairs.iter().map(|&(i, k)| (i, k, 1.0, 1.0)).collect();
    solve_full_ranksvm_weighted(ds, &costed, lambda)
}

/// The weighted/gapped full LP: each pair carries `(i, k, gap, weight)`
/// (the [`crate::workloads::ranksvm::ranking_pairs_costed`] reference
/// enumeration) — the slack costs `weight` and the margin row reads
/// `ξ + Σ_j (x_ij − x_kj)(β⁺_j − β⁻_j) ≥ gap`:
/// `min Σ_t w_t ξ_t + λ Σ_j (β⁺_j + β⁻_j)`. Uniform costs reproduce
/// [`solve_full_ranksvm`] bitwise.
pub fn solve_full_ranksvm_weighted(
    ds: &Dataset,
    pairs: &[(usize, usize, f64, f64)],
    lambda: f64,
) -> SvmSolution {
    let p = ds.p();
    let mut model = LpModel::new();
    let bp: Vec<_> = (0..p).map(|_| model.add_col_nonneg(lambda, &[])).collect();
    let bm: Vec<_> = (0..p).map(|_| model.add_col_nonneg(lambda, &[])).collect();
    for &(i, k, g, w) in pairs {
        let xi = model.add_col_nonneg(w, &[]);
        let mut coefs = Vec::with_capacity(1 + 2 * p);
        coefs.push((xi, 1.0));
        for j in 0..p {
            let d = ds.x.get(i, j) - ds.x.get(k, j);
            if d != 0.0 {
                coefs.push((bp[j], d));
                coefs.push((bm[j], -d));
            }
        }
        model.add_row_ge(g, &coefs);
    }

    let mut solver = SimplexSolver::new(model);
    let st = solver.solve();
    if st != Status::Optimal {
        let msg = format!("[ranksvm_full] solve did not reach optimality: {st:?}");
        crate::obs::stderr_line(&msg);
    }
    let mut beta = vec![0.0; p];
    for j in 0..p {
        beta[j] = solver.col_value(bp[j]) - solver.col_value(bm[j]);
    }
    SvmSolution {
        beta,
        beta0: 0.0,
        objective: solver.objective(),
        stats: GenStats {
            rounds: 1,
            cols_added: p,
            rows_added: pairs.len(),
            simplex_iters: solver.stats.primal_iters + solver.stats.dual_iters,
            converged: st == Status::Optimal,
            ..Default::default()
        },
        cols: (0..p).collect(),
        rows: (0..pairs.len()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_ranksvm, RankSpec};
    use crate::engine::PairMode;
    use crate::rng::Xoshiro256;
    use crate::workloads::pairset::PairSet;
    use crate::workloads::ranksvm::{lambda_max_rank, pairwise_hinge_support};

    #[test]
    fn full_lp_objective_decomposes() {
        let spec = RankSpec { n: 15, p: 10, k0: 3, rho: 0.1, noise: 0.3, standardize: true };
        let ds = generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(181));
        let pairs = PairSet::build(&ds.y, PairMode::Enumerate);
        let lambda = 0.1 * lambda_max_rank(&ds, &pairs);
        let sol = solve_full_ranksvm(&ds, &pairs.materialize(), lambda);
        // LP objective = pairwise hinge + λ‖β‖₁ recomputed from scratch
        let support: Vec<(usize, f64)> = sol
            .beta
            .iter()
            .enumerate()
            .filter(|(_, v)| **v != 0.0)
            .map(|(j, v)| (j, *v))
            .collect();
        let cols: Vec<usize> = support.iter().map(|&(j, _)| j).collect();
        let vals: Vec<f64> = support.iter().map(|&(_, v)| v).collect();
        let hinge = pairwise_hinge_support(&ds, &pairs, &cols, &vals);
        let l1: f64 = vals.iter().map(|v| v.abs()).sum();
        assert!(
            (sol.objective - (hinge + lambda * l1)).abs() < 1e-6,
            "lp {} recomputed {}",
            sol.objective,
            hinge + lambda * l1
        );
    }

    #[test]
    fn empty_pair_set_gives_zero() {
        let spec = RankSpec { n: 8, p: 5, k0: 2, rho: 0.0, noise: 0.1, standardize: true };
        let ds = generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(182));
        let sol = solve_full_ranksvm(&ds, &[], 0.5);
        assert_eq!(sol.support_size(), 0);
        assert!(sol.objective.abs() < 1e-12);
    }
}
