//! Baseline solvers the paper compares against.
//!
//! * [`full_lp`] — solve the *full* LP model with no generation: the
//!   paper's “LP solver” rows (Gurobi on the complete model). Shares
//!   the simplex substrate with the coordinators, so timing comparisons
//!   are apples-to-apples (see DESIGN.md §Substitutions).
//! * [`psm`] — the parametric simplex method of Pang et al. (2017),
//!   Table 4's state-of-the-art comparator.
//! * [`admm`] — a linearized-ADMM first-order baseline for L1-SVM
//!   (the [2]-style comparator mentioned in §1).
//! * [`slope_full`] — the O(p²) LP reformulation of the Slope norm
//!   (Appendix A.2), which is what CVXPY canonicalizes Slope-SVM to —
//!   Table 5/6's comparator.
//! * [`ranksvm_full`] / [`dantzig_full`] — complete-model baselines for
//!   the [`crate::workloads`] estimators (every comparison pair / every
//!   correlation row materialized), built independently of the
//!   generation code so cross-method agreement is a genuine check.

pub mod admm;
pub mod dantzig_full;
pub mod full_lp;
pub mod psm;
pub mod ranksvm_full;
pub mod slope_full;
