//! PSM — the parametric simplex method for L1-SVM (Pang, Liu, Vanderbei
//! & Zhao, NeurIPS 2017), the Table 4 comparator.
//!
//! PSM treats λ as the parametric cost multiplier of the |β| halves,
//! starts at λ_max where the trivial basis is optimal, and pivots down
//! the breakpoint path to the target λ. Unlike the coordinators it holds
//! the **full model** (all p column pairs), so every breakpoint prices
//! all 2p+… columns — which is exactly why it loses to column generation
//! at large p.

use crate::coordinator::{GenStats, SvmSolution};
use crate::data::Dataset;
use crate::simplex::{LpModel, ParametricSimplex, SimplexSolver, Status, VarId};

/// Result wrapper with the breakpoint count.
pub struct PsmResult {
    pub solution: SvmSolution,
    /// Breakpoints visited on the λ path.
    pub breakpoints: usize,
    pub status: Status,
}

/// Run PSM from λ_max down to `lambda`.
pub fn psm_l1svm(ds: &Dataset, lambda: f64) -> PsmResult {
    let n = ds.n();
    let p = ds.p();
    let lambda_max = ds.lambda_max_l1();
    // Clamp so the ride is always downward even when the caller's λ sits
    // above λ_max (the λ_max sanity tests do exactly that).
    let lambda_start = (lambda_max * 1.001).max(lambda);

    // Full model, costs at λ_start.
    let mut model = LpModel::new();
    let b0 = model.add_col_free(0.0, &[]);
    let xi: Vec<VarId> = (0..n).map(|_| model.add_col(1.0, 0.0, f64::INFINITY, &[])).collect();
    let bp: Vec<VarId> =
        (0..p).map(|_| model.add_col(lambda_start, 0.0, f64::INFINITY, &[])).collect();
    let bm: Vec<VarId> =
        (0..p).map(|_| model.add_col(lambda_start, 0.0, f64::INFINITY, &[])).collect();
    for i in 0..n {
        let yi = ds.y[i];
        let mut coefs: Vec<(VarId, f64)> = Vec::with_capacity(2 + 2 * p);
        coefs.push((xi[i], 1.0));
        coefs.push((b0, yi));
        for (j, v) in (0..p).map(|j| (j, ds.x.get(i, j))) {
            if v != 0.0 {
                coefs.push((bp[j], yi * v));
                coefs.push((bm[j], -yi * v));
            }
        }
        model.add_row(1.0, f64::INFINITY, &coefs);
    }
    let nvars = model.num_vars();
    let mut c_fix = vec![0.0; nvars];
    let mut c_var = vec![0.0; nvars];
    for &v in &xi {
        c_fix[v] = 1.0;
    }
    for &v in bp.iter().chain(&bm) {
        c_var[v] = 1.0;
    }
    let solver = SimplexSolver::new(model);
    let mut psm = ParametricSimplex::new(solver, c_fix, c_var);
    let (path, status) =
        psm.run(lambda_start, lambda, 100_000).expect("lambda_start clamped >= lambda");

    let mut beta = vec![0.0; p];
    for j in 0..p {
        beta[j] = psm.solver.col_value(bp[j]) - psm.solver.col_value(bm[j]);
    }
    let beta0 = psm.solver.col_value(b0);
    let stats = GenStats {
        rounds: path.len(),
        cols_added: p,
        rows_added: n,
        simplex_iters: psm.solver.stats.primal_iters + psm.solver.stats.dual_iters,
        converged: true,
        ..Default::default()
    };
    PsmResult {
        solution: SvmSolution {
            beta,
            beta0,
            objective: psm.solver.objective(),
            stats,
            cols: (0..p).collect(),
            rows: (0..n).collect(),
        },
        breakpoints: path.len(),
        status,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::full_lp::solve_full_l1;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::rng::Xoshiro256;

    #[test]
    fn psm_matches_direct_solve() {
        let spec = SyntheticSpec { n: 30, p: 25, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(151));
        let lambda = 0.05 * ds.lambda_max_l1();
        let res = psm_l1svm(&ds, lambda);
        assert_eq!(res.status, Status::Optimal);
        let direct = solve_full_l1(&ds, lambda);
        assert!(
            (res.solution.objective - direct.objective).abs() / direct.objective.max(1e-9) < 1e-5,
            "psm {} direct {}",
            res.solution.objective,
            direct.objective
        );
        assert!(res.breakpoints >= 2, "expected a nontrivial path");
    }

    #[test]
    fn psm_null_solution_at_lambda_max() {
        let spec = SyntheticSpec { n: 20, p: 15, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(152));
        let lam = ds.lambda_max_l1() * 1.0005;
        let res = psm_l1svm(&ds, lam);
        assert_eq!(res.status, Status::Optimal);
        assert_eq!(res.solution.support_size(), 0);
    }
}
