//! The O(p²) LP reformulation of Slope-SVM (Appendix A.2) — the
//! “CVXPY” comparator of Tables 5–6.
//!
//! Writing `λ̃_m = λ_m − λ_{m+1} ≥ 0` (λ_{p+1} := 0), the Slope penalty
//! telescopes into `Σ_m λ̃_m · (sum of the m largest |β|)`, and each
//! partial sum is modeled with the classic LP epigraph of a sum-of-top-m:
//! `m·θ_m + Σᵢ v_{m,i}` with `v_{m,i} + θ_m ≥ |β_i|, v ≥ 0, θ_m ≥ 0`.
//! Only levels with `λ̃_m > 0` need a block, so:
//!
//! * two-level weights (Table 5): 2 blocks → O(p) rows — slow but
//!   feasible, like CVXPY+Gurobi;
//! * distinct weights (Table 6): p blocks → O(p²) rows — explodes
//!   almost immediately, like CVXPY+Ecos (which crashed at p = 200).
//!
//! `MAX_ROWS` plays the role of the solver crash: beyond it we return
//! `None` (reported as “—” in the tables, matching the paper).

use crate::coordinator::{GenStats, SvmSolution};
use crate::data::Dataset;
use crate::simplex::{LpModel, SimplexSolver, Status, VarId};

/// Row-count guard standing in for the memory/crash limit of the
/// canonicalized CVXPY models (our dense-basis simplex factorizes an
/// m×m LU, so m beyond a few thousand is as fatal as Ecos's crash at
/// p = 200 in the paper).
pub const MAX_ROWS: usize = 3_000;

/// Solve Slope-SVM through the A.2 reformulation. Returns `None` when the
/// canonicalized model exceeds [`MAX_ROWS`] rows (the “CVXPY crashed /
/// did not converge” case).
pub fn solve_slope_full(ds: &Dataset, lambda: &[f64]) -> Option<SvmSolution> {
    let n = ds.n();
    let p = ds.p();
    assert_eq!(lambda.len(), p);
    // active levels: λ̃_m > 0
    let mut levels: Vec<(usize, f64)> = Vec::new();
    for m in 0..p {
        let next = if m + 1 < p { lambda[m + 1] } else { 0.0 };
        let tilde = lambda[m] - next;
        if tilde > 1e-12 {
            levels.push((m + 1, tilde)); // 1-based m
        }
    }
    let total_rows = n + levels.len() * p;
    if total_rows > MAX_ROWS {
        return None;
    }

    let mut model = LpModel::new();
    let b0 = model.add_col_free(0.0, &[]);
    let xi: Vec<VarId> = (0..n).map(|_| model.add_col(1.0, 0.0, f64::INFINITY, &[])).collect();
    let bp: Vec<VarId> = (0..p).map(|_| model.add_col(0.0, 0.0, f64::INFINITY, &[])).collect();
    let bm: Vec<VarId> = (0..p).map(|_| model.add_col(0.0, 0.0, f64::INFINITY, &[])).collect();
    // margin rows
    for i in 0..n {
        let yi = ds.y[i];
        let mut coefs: Vec<(VarId, f64)> = Vec::with_capacity(2 + 2 * p);
        coefs.push((xi[i], 1.0));
        coefs.push((b0, yi));
        for j in 0..p {
            let v = ds.x.get(i, j);
            if v != 0.0 {
                coefs.push((bp[j], yi * v));
                coefs.push((bm[j], -yi * v));
            }
        }
        model.add_row(1.0, f64::INFINITY, &coefs);
    }
    // sum-of-top-m blocks
    for &(m, tilde) in &levels {
        // θ_m costs λ̃_m·m ; each v_{m,i} costs λ̃_m
        let theta = model.add_col(tilde * m as f64, 0.0, f64::INFINITY, &[]);
        for j in 0..p {
            let v = model.add_col(tilde, 0.0, f64::INFINITY, &[]);
            // v_{m,j} + θ_m − β⁺_j − β⁻_j ≥ 0
            model.add_row(
                0.0,
                f64::INFINITY,
                &[(v, 1.0), (theta, 1.0), (bp[j], -1.0), (bm[j], -1.0)],
            );
        }
    }

    let mut solver = SimplexSolver::new(model);
    let st = solver.solve();
    if st != Status::Optimal {
        return None;
    }
    let mut beta = vec![0.0; p];
    for j in 0..p {
        beta[j] = solver.col_value(bp[j]) - solver.col_value(bm[j]);
    }
    let beta0 = solver.col_value(b0);
    Some(SvmSolution {
        beta,
        beta0,
        objective: solver.objective(),
        stats: GenStats {
            rounds: 1,
            cols_added: solver.model().num_vars(),
            rows_added: solver.model().num_rows(),
            simplex_iters: solver.stats.primal_iters + solver.stats.dual_iters,
            converged: true,
            ..Default::default()
        },
        cols: (0..p).collect(),
        rows: (0..n).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::coordinator::slope::slope_column_constraint_generation;
    use crate::coordinator::GenParams;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::fom::objective::{bh_slope_weights, two_level_slope_weights};
    use crate::rng::Xoshiro256;

    fn ds(n: usize, p: usize, seed: u64) -> Dataset {
        let spec = SyntheticSpec { n, p, k0: 4.min(p), rho: 0.1, standardize: true };
        generate_l1(&spec, &mut Xoshiro256::seed_from_u64(seed))
    }

    #[test]
    fn full_formulation_matches_cutting_planes_two_level() {
        let d = ds(20, 15, 171);
        let lambda = two_level_slope_weights(15, 4, 0.05 * d.lambda_max_l1());
        let full = solve_slope_full(&d, &lambda).expect("fits in row budget");
        let backend = NativeBackend::new(&d.x);
        let cg = slope_column_constraint_generation(
            &d,
            &backend,
            &lambda,
            &[0, 1],
            &GenParams { eps: 1e-7, ..Default::default() },
        );
        assert!(
            (full.objective - cg.objective).abs() / cg.objective.max(1e-9) < 1e-4,
            "full {} cg {}",
            full.objective,
            cg.objective
        );
    }

    #[test]
    fn full_formulation_matches_cutting_planes_distinct() {
        let d = ds(15, 8, 172);
        let lambda = bh_slope_weights(8, 0.04 * d.lambda_max_l1());
        let full = solve_slope_full(&d, &lambda).expect("fits");
        let backend = NativeBackend::new(&d.x);
        let cg = slope_column_constraint_generation(
            &d,
            &backend,
            &lambda,
            &[0],
            &GenParams { eps: 1e-7, ..Default::default() },
        );
        assert!(
            (full.objective - cg.objective).abs() / cg.objective.max(1e-9) < 1e-4,
            "full {} cg {}",
            full.objective,
            cg.objective
        );
    }

    #[test]
    fn row_budget_guard_triggers() {
        // distinct weights with large p → p² rows → refused, like Ecos.
        let d = ds(10, 300, 173);
        let lambda = bh_slope_weights(300, 0.01);
        assert!(solve_slope_full(&d, &lambda).is_none());
    }
}
