//! The “LP solver” baseline: build the complete LP (every column, every
//! constraint) and solve it in one shot — no column or constraint
//! generation. This is what the paper runs Gurobi on; the gap between
//! this and the coordinators is the paper's headline effect.

use crate::coordinator::l1svm::RestrictedL1;
use crate::coordinator::{GenStats, SvmSolution};
use crate::data::Dataset;
use crate::simplex::Status;

/// Solve the full L1-SVM LP (Problem 5). `warm` re-solves an existing
/// model across λ values (the “LP warm-start” row of Table 1).
pub struct FullL1Lp {
    inner: RestrictedL1,
    ds_n: usize,
    ds_p: usize,
}

impl FullL1Lp {
    /// Build the complete model.
    pub fn new(ds: &Dataset, lambda: f64) -> Self {
        let all_i: Vec<usize> = (0..ds.n()).collect();
        let all_j: Vec<usize> = (0..ds.p()).collect();
        Self {
            inner: RestrictedL1::new(ds, lambda, &all_i, &all_j),
            ds_n: ds.n(),
            ds_p: ds.p(),
        }
    }

    /// Change λ (for warm-started λ-grids) without rebuilding.
    pub fn set_lambda(&mut self, lambda: f64) {
        self.inner.set_lambda(lambda);
    }

    /// Solve and package the solution.
    pub fn solve(&mut self, lambda: f64) -> SvmSolution {
        let st = self.inner.solve();
        debug_assert_eq!(st, Status::Optimal, "full LP: {st:?}");
        let (support, beta0) = self.inner.beta_support();
        let mut beta = vec![0.0; self.ds_p];
        for &(j, v) in &support {
            beta[j] = v;
        }
        let _ = lambda;
        SvmSolution {
            beta,
            beta0,
            objective: self.inner.objective(),
            stats: GenStats {
                rounds: 1,
                cols_added: self.ds_p,
                rows_added: self.ds_n,
                simplex_iters: self.inner.simplex_iters(),
                converged: true,
                ..Default::default()
            },
            cols: (0..self.ds_p).collect(),
            rows: (0..self.ds_n).collect(),
        }
    }
}

/// One-shot convenience: solve the full L1-SVM LP at a single λ.
pub fn solve_full_l1(ds: &Dataset, lambda: f64) -> SvmSolution {
    FullL1Lp::new(ds, lambda).solve(lambda)
}

/// One-shot full Group-SVM LP (all groups in the model).
pub fn solve_full_group(ds: &Dataset, groups: &[Vec<usize>], lambda: f64) -> SvmSolution {
    let all: Vec<usize> = (0..groups.len()).collect();
    let backend = crate::backend::NativeBackend::new(&ds.x);
    // with every group present, the pricing loop exits after one round
    crate::coordinator::group::group_column_generation(
        ds,
        &backend,
        groups,
        lambda,
        &all,
        &crate::coordinator::GenParams::default(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::coordinator::l1svm::column_generation;
    use crate::coordinator::GenParams;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::rng::Xoshiro256;

    #[test]
    fn full_lp_matches_column_generation() {
        let spec = SyntheticSpec { n: 30, p: 50, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(141));
        let lambda = 0.05 * ds.lambda_max_l1();
        let full = solve_full_l1(&ds, lambda);
        let backend = NativeBackend::new(&ds.x);
        let cg = column_generation(
            &ds,
            &backend,
            lambda,
            &[0],
            &GenParams { eps: 1e-7, ..Default::default() },
        );
        assert!(
            (full.objective - cg.objective).abs() / cg.objective.max(1e-9) < 1e-5,
            "full {} cg {}",
            full.objective,
            cg.objective
        );
    }

    #[test]
    fn warm_start_lambda_grid_is_consistent() {
        let spec = SyntheticSpec { n: 25, p: 30, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(142));
        let lmax = ds.lambda_max_l1();
        let grid = [0.5 * lmax, 0.25 * lmax, 0.1 * lmax];
        let mut warm = FullL1Lp::new(&ds, grid[0]);
        for &lam in &grid {
            warm.set_lambda(lam);
            let sol = warm.solve(lam);
            let fresh = solve_full_l1(&ds, lam);
            assert!(
                (sol.objective - fresh.objective).abs() / fresh.objective.max(1e-9) < 1e-6,
                "λ={lam}: warm {} fresh {}",
                sol.objective,
                fresh.objective
            );
        }
    }
}
