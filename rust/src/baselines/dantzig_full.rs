//! Full-LP baseline for the Dantzig selector: build the complete model —
//! all `p` ranged correlation rows, all `2p` coefficient columns, Gram
//! entries formed explicitly — and solve it in one shot. O(p²n) build,
//! O(p²) memory; the point of comparison for the column-and-constraint
//! generation in [`crate::workloads::dantzig`], constructed independently
//! of that module so agreement is a genuine cross-check.

use crate::coordinator::{GenStats, SvmSolution};
use crate::data::Dataset;
use crate::simplex::{LpModel, SimplexSolver, Status};

/// Solve the full Dantzig-selector LP at one λ:
/// `min Σ_j (β⁺_j + β⁻_j)` s.t. `c_i − λ ≤ Σ_j A_ij (β_j⁺ − β_j⁻) ≤ c_i + λ`
/// with `c = Xᵀy`, `A = XᵀX`.
pub fn solve_full_dantzig(ds: &Dataset, lambda: f64) -> SvmSolution {
    let n = ds.n();
    let p = ds.p();
    let mut c = vec![0.0; p];
    ds.x.tmatvec(&ds.y, &mut c);

    // densify X column by column once: gram[i][j] needs every pair
    let cols_dense: Vec<Vec<f64>> = (0..p)
        .map(|j| {
            let mut col = vec![0.0; n];
            for (i, v) in ds.x.col_entries(j) {
                col[i] = v;
            }
            col
        })
        .collect();

    let mut model = LpModel::new();
    let bp: Vec<_> = (0..p).map(|_| model.add_col_nonneg(1.0, &[])).collect();
    let bm: Vec<_> = (0..p).map(|_| model.add_col_nonneg(1.0, &[])).collect();
    for i in 0..p {
        let mut coefs = Vec::with_capacity(2 * p);
        for j in 0..p {
            let a: f64 =
                cols_dense[i].iter().zip(&cols_dense[j]).map(|(u, v)| u * v).sum();
            if a != 0.0 {
                coefs.push((bp[j], a));
                coefs.push((bm[j], -a));
            }
        }
        model.add_row(c[i] - lambda, c[i] + lambda, &coefs);
    }

    let mut solver = SimplexSolver::new(model);
    let st = solver.solve();
    if st != Status::Optimal {
        let msg = format!("[dantzig_full] solve did not reach optimality: {st:?}");
        crate::obs::stderr_line(&msg);
    }
    let mut beta = vec![0.0; p];
    for j in 0..p {
        beta[j] = solver.col_value(bp[j]) - solver.col_value(bm[j]);
    }
    SvmSolution {
        beta,
        beta0: 0.0,
        objective: solver.objective(),
        stats: GenStats {
            rounds: 1,
            cols_added: p,
            rows_added: p,
            simplex_iters: solver.stats.primal_iters + solver.stats.dual_iters,
            converged: st == Status::Optimal,
            ..Default::default()
        },
        cols: (0..p).collect(),
        rows: (0..p).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_dantzig, DantzigSpec};
    use crate::rng::Xoshiro256;

    #[test]
    fn full_lp_feasible_and_sparse() {
        let spec = DantzigSpec { n: 40, p: 20, k0: 4, rho: 0.1, sigma: 0.4, standardize: true };
        let ds = generate_dantzig(&spec, &mut Xoshiro256::seed_from_u64(171));
        let lmax = crate::workloads::dantzig::lambda_max_dantzig(&ds);
        let sol = solve_full_dantzig(&ds, 0.3 * lmax);
        // the constraint ‖Xᵀ(y − Xβ)‖∞ ≤ λ must hold at the solution
        let mut xb = vec![0.0; ds.n()];
        ds.x.matvec(&sol.beta, &mut xb);
        let u: Vec<f64> = ds.y.iter().zip(&xb).map(|(y, m)| y - m).collect();
        let mut r = vec![0.0; ds.p()];
        ds.x.tmatvec(&u, &mut r);
        let linf = r.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        assert!(linf <= 0.3 * lmax + 1e-6, "‖Xᵀu‖∞ = {linf}");
        // objective is exactly ‖β‖₁
        let l1: f64 = sol.beta.iter().map(|v| v.abs()).sum();
        assert!((sol.objective - l1).abs() < 1e-8);
    }

    #[test]
    fn objective_shrinks_as_lambda_grows() {
        let spec = DantzigSpec { n: 30, p: 15, k0: 3, rho: 0.1, sigma: 0.3, standardize: true };
        let ds = generate_dantzig(&spec, &mut Xoshiro256::seed_from_u64(172));
        let lmax = crate::workloads::dantzig::lambda_max_dantzig(&ds);
        let tight = solve_full_dantzig(&ds, 0.2 * lmax).objective;
        let loose = solve_full_dantzig(&ds, 0.6 * lmax).objective;
        let zero = solve_full_dantzig(&ds, 1.01 * lmax).objective;
        assert!(tight >= loose - 1e-9, "tight {tight} loose {loose}");
        assert!(zero.abs() < 1e-9, "λ > λ_max must give β = 0, got {zero}");
    }
}
