//! Atomic metric instruments and a Prometheus text-exposition registry.
//!
//! Three instrument kinds, all updated with relaxed atomics so they are
//! cheap enough to leave on in every build:
//!
//! * [`Counter`] — monotone `u64` (requests served, cache hits, sheds);
//! * [`Gauge`] — signed level (`i64`: inflight solves, queue depth,
//!   resident cache bytes);
//! * [`Histogram`] — fixed-boundary latency distribution in
//!   nanoseconds; [`latency_bounds`] gives the standard log-spaced
//!   ladder (100 µs · 4^k, twelve buckets from 100 µs to ~7 min, plus
//!   the implicit `+Inf` overflow bucket).
//!
//! A [`Registry`] hands out `Arc` handles keyed by `(name, labels)` —
//! registering the same series twice returns the same handle — and
//! [`Registry::render`] writes the whole registry in Prometheus text
//! exposition format (`# HELP`/`# TYPE` headers, cumulative
//! `_bucket{le="…"}` series, `_sum` in seconds, `_count`).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A monotonically increasing counter (relaxed atomic `u64`).
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// A signed instantaneous level (relaxed atomic `i64`).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    /// Overwrite the level.
    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    /// Add `n` (may be negative).
    pub fn add(&self, n: i64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    /// Subtract `n`.
    pub fn sub(&self, n: i64) {
        self.v.fetch_sub(n, Ordering::Relaxed);
    }

    /// Current level.
    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// The standard request-latency bucket ladder: 100 µs · 4^k nanoseconds
/// for k = 0..12 (100 µs, 400 µs, 1.6 ms, … ~7 min), log-spaced so one
/// ladder covers both sub-millisecond warm hits and multi-second cold
/// grids. Observations beyond the last bound land in the implicit
/// `+Inf` overflow bucket.
pub fn latency_bounds() -> Vec<u64> {
    let mut bounds = Vec::with_capacity(12);
    let mut ns = 100_000u64; // 100 µs
    for _ in 0..12 {
        bounds.push(ns);
        ns *= 4;
    }
    bounds
}

/// A fixed-boundary histogram over nanosecond observations.
///
/// Bucket semantics match Prometheus: an observation `x` lands in the
/// first bucket whose upper bound satisfies `x <= bound`, or in the
/// `+Inf` overflow bucket past the last bound. Internally the buckets
/// are *disjoint* counts; [`Registry::render`] emits the cumulative
/// `_bucket{le=…}` form the exposition format requires.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<u64>,
    buckets: Vec<AtomicU64>, // bounds.len() + 1: last is +Inf overflow
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Histogram {
    /// Build with strictly increasing upper bounds (nanoseconds).
    pub fn new(bounds: &[u64]) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must increase");
        Self {
            bounds: bounds.to_vec(),
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Record one observation of `ns` nanoseconds.
    pub fn observe_ns(&self, ns: u64) {
        let idx = self.bounds.partition_point(|&b| b < ns);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one observation from a [`Duration`].
    pub fn observe(&self, d: Duration) {
        self.observe_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations in nanoseconds.
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    /// Upper bounds (nanoseconds), excluding the implicit `+Inf`.
    pub fn bounds(&self) -> &[u64] {
        &self.bounds
    }

    /// Per-bucket *disjoint* counts; the last entry is the `+Inf`
    /// overflow bucket.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

/// One registered series: a label set and its instrument handle.
#[derive(Debug)]
struct Family<T> {
    help: String,
    // keyed by the rendered label block ("" or `{k="v",…}`) — dedupes
    // re-registration and gives deterministic exposition order
    series: BTreeMap<String, Arc<T>>,
}

impl<T> Family<T> {
    fn new(help: &str) -> Self {
        Self { help: help.to_string(), series: BTreeMap::new() }
    }
}

/// A process-wide metric registry.
///
/// Handles are `Arc`s: fetch once at wiring time, update lock-free
/// forever after. The internal mutexes are touched only by
/// registration and [`Registry::render`].
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Family<Counter>>>,
    gauges: Mutex<BTreeMap<String, Family<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Family<Histogram>>>,
}

impl Registry {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create the counter series `name{labels}`.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let key = label_block(labels);
        let mut map = self.counters.lock().unwrap();
        let fam = map.entry(name.to_string()).or_insert_with(|| Family::new(help));
        Arc::clone(fam.series.entry(key).or_default())
    }

    /// Get or create the gauge series `name{labels}`.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let key = label_block(labels);
        let mut map = self.gauges.lock().unwrap();
        let fam = map.entry(name.to_string()).or_insert_with(|| Family::new(help));
        Arc::clone(fam.series.entry(key).or_default())
    }

    /// Get or create the histogram series `name{labels}` with the given
    /// bucket bounds (nanoseconds; see [`latency_bounds`]).
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        bounds: &[u64],
    ) -> Arc<Histogram> {
        let key = label_block(labels);
        let mut map = self.histograms.lock().unwrap();
        let fam = map.entry(name.to_string()).or_insert_with(|| Family::new(help));
        Arc::clone(fam.series.entry(key).or_insert_with(|| Arc::new(Histogram::new(bounds))))
    }

    /// Render the whole registry in Prometheus text exposition format.
    ///
    /// Counters first, then gauges, then histograms, each family sorted
    /// by name and each series by label block, so the output is
    /// deterministic and diff-friendly. Histogram `_sum` and `le`
    /// bounds are emitted in seconds per Prometheus convention.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, fam) in self.counters.lock().unwrap().iter() {
            let _ = writeln!(out, "# HELP {name} {}", fam.help);
            let _ = writeln!(out, "# TYPE {name} counter");
            for (block, c) in &fam.series {
                let _ = writeln!(out, "{name}{block} {}", c.get());
            }
        }
        for (name, fam) in self.gauges.lock().unwrap().iter() {
            let _ = writeln!(out, "# HELP {name} {}", fam.help);
            let _ = writeln!(out, "# TYPE {name} gauge");
            for (block, g) in &fam.series {
                let _ = writeln!(out, "{name}{block} {}", g.get());
            }
        }
        for (name, fam) in self.histograms.lock().unwrap().iter() {
            let _ = writeln!(out, "# HELP {name} {}", fam.help);
            let _ = writeln!(out, "# TYPE {name} histogram");
            for (block, h) in &fam.series {
                let counts = h.bucket_counts();
                let mut cum = 0u64;
                for (i, bound) in h.bounds().iter().enumerate() {
                    cum += counts[i];
                    let le = secs(*bound);
                    let _ = writeln!(out, "{name}_bucket{} {cum}", with_le(block, &le));
                }
                let total = h.count();
                let _ = writeln!(out, "{name}_bucket{} {total}", with_le(block, "+Inf"));
                let _ = writeln!(out, "{name}_sum{block} {}", secs(h.sum_ns()));
                let _ = writeln!(out, "{name}_count{block} {total}");
            }
        }
        out
    }
}

/// Render a label set as `{k="v",…}` (or `""` when empty), escaping
/// backslash, double-quote, and newline per the exposition format.
fn label_block(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut out = String::from("{");
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{k}=\"{}\"", escape_label(v));
    }
    out.push('}');
    out
}

/// Splice an `le="…"` label into an existing (possibly empty) block.
fn with_le(block: &str, le: &str) -> String {
    if block.is_empty() {
        format!("{{le=\"{le}\"}}")
    } else {
        // block ends in '}': replace it with `,le="…"}`
        format!("{},le=\"{le}\"}}", &block[..block.len() - 1])
    }
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Nanoseconds as seconds, shortest round-trip decimal (`0.0001`, `2.5`).
fn secs(ns: u64) -> String {
    format!("{}", ns as f64 / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_bounds_are_log_spaced() {
        let b = latency_bounds();
        assert_eq!(b.len(), 12);
        assert_eq!(b[0], 100_000); // 100 µs
        for w in b.windows(2) {
            assert_eq!(w[1], w[0] * 4);
        }
        // top of the ladder covers a multi-minute grid solve
        assert!(b[11] > 400_000_000_000); // > 400 s
    }

    #[test]
    fn histogram_bucket_edges_are_inclusive() {
        let h = Histogram::new(&[100, 1_000, 10_000]);
        h.observe_ns(0); // below everything -> first bucket
        h.observe_ns(100); // exactly on a bound -> that bucket (le semantics)
        h.observe_ns(101); // just past -> next bucket
        h.observe_ns(1_000);
        h.observe_ns(10_000);
        h.observe_ns(10_001); // past the last bound -> +Inf overflow
        h.observe_ns(u64::MAX / 2);
        assert_eq!(h.bucket_counts(), vec![2, 2, 2, 1]);
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum_ns(), 100 + 101 + 1_000 + 10_000 + 10_001 + u64::MAX / 2);
    }

    #[test]
    fn exposition_golden() {
        let reg = Registry::new();
        reg.counter("cutgen_requests_total", "Requests handled.", &[("op", "solve")]).add(3);
        reg.counter("cutgen_requests_total", "Requests handled.", &[("op", "ping")]).inc();
        reg.gauge("cutgen_inflight", "Heavy ops in flight.", &[]).set(2);
        let h = reg.histogram(
            "cutgen_latency",
            "Request latency.",
            &[("op", "solve")],
            &[1_000_000, 4_000_000], // 1 ms, 4 ms
        );
        h.observe_ns(500_000); // 0.5 ms -> first bucket
        h.observe_ns(2_000_000); // 2 ms -> second bucket
        h.observe_ns(9_000_000); // 9 ms -> +Inf
        let got = reg.render();
        let want = "\
# HELP cutgen_requests_total Requests handled.
# TYPE cutgen_requests_total counter
cutgen_requests_total{op=\"ping\"} 1
cutgen_requests_total{op=\"solve\"} 3
# HELP cutgen_inflight Heavy ops in flight.
# TYPE cutgen_inflight gauge
cutgen_inflight 2
# HELP cutgen_latency Request latency.
# TYPE cutgen_latency histogram
cutgen_latency_bucket{op=\"solve\",le=\"0.001\"} 1
cutgen_latency_bucket{op=\"solve\",le=\"0.004\"} 2
cutgen_latency_bucket{op=\"solve\",le=\"+Inf\"} 3
cutgen_latency_sum{op=\"solve\"} 0.0115
cutgen_latency_count{op=\"solve\"} 3
";
        assert_eq!(got, want);
    }

    #[test]
    fn reregistration_returns_the_same_series() {
        let reg = Registry::new();
        let a = reg.counter("c", "h", &[("k", "v")]);
        let b = reg.counter("c", "h", &[("k", "v")]);
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2);
        assert!(Arc::ptr_eq(&a, &b));
        // distinct labels are distinct series
        let c = reg.counter("c", "h", &[("k", "w")]);
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn label_values_are_escaped() {
        let reg = Registry::new();
        reg.counter("c", "h", &[("k", "a\"b\\c\nd")]).inc();
        let out = reg.render();
        assert!(out.contains("c{k=\"a\\\"b\\\\c\\nd\"} 1"), "got: {out}");
    }

    #[test]
    fn counters_are_monotone_under_scoped_workers() {
        let reg = Registry::new();
        let c = reg.counter("work_total", "units", &[]);
        let g = reg.gauge("level", "level", &[]);
        let h = reg.histogram("lat", "lat", &[], &latency_bounds());
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for i in 0..1_000u64 {
                        c.inc();
                        g.add(1);
                        h.observe_ns(i * 1_000);
                    }
                });
            }
        });
        assert_eq!(c.get(), 8_000);
        assert_eq!(g.get(), 8_000);
        assert_eq!(h.count(), 8_000);
        let per_thread: u64 = (0..1_000u64).map(|i| i * 1_000).sum();
        assert_eq!(h.sum_ns(), 8 * per_thread);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), 8_000);
    }
}
