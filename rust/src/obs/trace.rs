//! Structured round tracing: typed events, sinks, and the span timer.
//!
//! `GenEngine::run` emits one [`RoundEvent`] per generation round
//! through an optional [`TraceSink`] — the machine-readable form of the
//! `--trace` stderr lines, carrying the per-round wall-clock spans
//! (restricted re-solve, pricing scan, working-set expansion) that back
//! the paper's solve-time breakdown tables. Three sinks cover the three
//! consumers:
//!
//! * [`StderrSink`] — the human form; byte-for-byte the historical
//!   `--trace` output, but written one atomic line at a time via
//!   [`stderr_line`] so concurrent serve workers never interleave;
//! * [`JsonlSink`] — one JSON object per line to a file
//!   (`--trace-json PATH`); `docs/observability.md` shows how to fold
//!   the file into a paper-style time-breakdown table;
//! * [`RingSink`] — a bounded in-memory ring the serve layer drains
//!   into `"trace": true` responses and slow-solve log lines.

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

/// What happened in one generation round.
///
/// Counts are per-round deltas except `working_set` (the restricted
/// model's total column+row count after this round's expansion) and
/// `simplex_iters` (cumulative for the run, matching the `--trace`
/// line). Spans are wall-clock nanoseconds from a monotonic [`Span`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundEvent {
    /// 1-based round number within this engine run.
    pub round: usize,
    /// Restricted objective after this round's re-solve.
    pub objective: f64,
    /// Rows (constraints/cuts) priced above ε this round.
    pub viol_rows: usize,
    /// Columns priced above ε this round.
    pub viol_cols: usize,
    /// Rows actually brought into the model (after the round cap).
    pub rows_added: usize,
    /// Columns actually brought into the model (after the round cap).
    pub cols_added: usize,
    /// Working-set size (columns + rows) after expansion; 0 for
    /// adapters that don't report it.
    pub working_set: usize,
    /// Simplex iterations accumulated by this run so far.
    pub simplex_iters: usize,
    /// Nanoseconds in this round's restricted re-solve.
    pub solve_ns: u64,
    /// Nanoseconds pricing left-out rows and columns this round.
    pub pricing_ns: u64,
    /// Nanoseconds expanding the working sets this round.
    pub expand_ns: u64,
}

/// Receives engine trace output.
///
/// Implementations must be thread-safe (`Send + Sync`): one sink may
/// be shared by concurrent serve workers, and `GenParams` clones carry
/// the sink across threads. `Debug` keeps `GenParams`'s derive intact.
pub trait TraceSink: Send + Sync + std::fmt::Debug {
    /// One generation round completed.
    fn round(&self, ev: &RoundEvent);
    /// A non-round engine message (caller stop, stall abort).
    fn message(&self, text: &str);
    /// An exact-path breakpoint was emitted (λ, full objective, whether
    /// full-space pricing expanded the working set there). Default:
    /// routed through [`TraceSink::message`], so existing sinks pick it
    /// up without changes.
    fn breakpoint(&self, lambda: f64, objective: f64, expanded: bool) {
        self.message(&format!(
            "path breakpoint: lambda {lambda:.6e}, obj {objective:.6e}, expanded {expanded}"
        ));
    }
}

/// A monotonic wall-clock section timer.
///
/// ```
/// use cutgen::obs::Span;
/// let span = Span::start();
/// let ns = span.elapsed_ns(); // nanoseconds since start, monotonic
/// assert!(ns < 1_000_000_000);
/// ```
#[derive(Clone, Copy, Debug)]
pub struct Span(Instant);

impl Span {
    /// Start timing now.
    pub fn start() -> Self {
        Span(Instant::now())
    }

    /// Nanoseconds since [`Span::start`] (saturating at `u64::MAX`).
    pub fn elapsed_ns(&self) -> u64 {
        self.0.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }
}

/// Write one line to stderr in a single `write_all`.
///
/// The one sanctioned stderr path for library code: a lone `eprintln!`
/// interleaves with other writers mid-line under concurrency, so CI
/// lints `eprintln!` out of `rust/src` and everything routes through
/// here instead.
pub fn stderr_line(line: &str) {
    let mut buf = String::with_capacity(line.len() + 1);
    buf.push_str(line);
    buf.push('\n');
    let mut err = io::stderr().lock();
    let _ = err.write_all(buf.as_bytes());
}

/// The human sink: reproduces the historical `--trace` stderr lines.
#[derive(Debug, Default)]
pub struct StderrSink;

impl TraceSink for StderrSink {
    fn round(&self, ev: &RoundEvent) {
        stderr_line(&format!(
            "[engine] round {:>4}: obj {:.6e}, viol rows/cols {}/{}, simplex {}",
            ev.round, ev.objective, ev.viol_rows, ev.viol_cols, ev.simplex_iters,
        ));
    }

    fn message(&self, text: &str) {
        stderr_line(&format!("[engine] {text}"));
    }
}

/// One JSON object per line to a file, flushed per event so traces
/// survive a crash mid-solve.
#[derive(Debug)]
pub struct JsonlSink {
    w: Mutex<BufWriter<File>>,
}

impl JsonlSink {
    /// Create (truncating) the trace file.
    pub fn create(path: &Path) -> io::Result<Self> {
        Ok(Self { w: Mutex::new(BufWriter::new(File::create(path)?)) })
    }

    fn write_line(&self, line: &str) {
        let mut w = self.w.lock().unwrap();
        let _ = writeln!(w, "{line}");
        let _ = w.flush();
    }
}

impl TraceSink for JsonlSink {
    fn round(&self, ev: &RoundEvent) {
        self.write_line(&round_json(ev));
    }

    fn message(&self, text: &str) {
        self.write_line(&format!("{{\"event\":\"message\",\"text\":\"{}\"}}", json_escape(text)));
    }
}

/// Serialize a [`RoundEvent`] as one JSONL record (`"event":"round"`).
pub fn round_json(ev: &RoundEvent) -> String {
    let mut s = String::with_capacity(192);
    s.push_str("{\"event\":\"round\"");
    let _ = write!(s, ",\"round\":{}", ev.round);
    let _ = write!(s, ",\"objective\":{}", json_f64(ev.objective));
    let _ = write!(s, ",\"viol_rows\":{}", ev.viol_rows);
    let _ = write!(s, ",\"viol_cols\":{}", ev.viol_cols);
    let _ = write!(s, ",\"rows_added\":{}", ev.rows_added);
    let _ = write!(s, ",\"cols_added\":{}", ev.cols_added);
    let _ = write!(s, ",\"working_set\":{}", ev.working_set);
    let _ = write!(s, ",\"simplex_iters\":{}", ev.simplex_iters);
    let _ = write!(s, ",\"solve_ns\":{}", ev.solve_ns);
    let _ = write!(s, ",\"pricing_ns\":{}", ev.pricing_ns);
    let _ = write!(s, ",\"expand_ns\":{}", ev.expand_ns);
    s.push('}');
    s
}

/// A finite f64 as a JSON number, non-finite as `null`.
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// A bounded in-memory ring of the most recent round events.
///
/// Serve attaches one per traced request and drains it into the
/// response; the bound caps memory for pathological round counts, and
/// [`RingSink::dropped`] says how many early rounds were truncated.
/// Non-round messages are not buffered (they are terminal one-liners
/// already summarized by `GenStats`).
#[derive(Debug)]
pub struct RingSink {
    cap: usize,
    inner: Mutex<Ring>,
}

#[derive(Debug, Default)]
struct Ring {
    events: VecDeque<RoundEvent>,
    dropped: u64,
}

impl RingSink {
    /// A ring keeping the last `cap` rounds (clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), inner: Mutex::new(Ring::default()) }
    }

    /// The buffered events, oldest first.
    pub fn events(&self) -> Vec<RoundEvent> {
        self.inner.lock().unwrap().events.iter().copied().collect()
    }

    /// How many early rounds were evicted to honor the bound.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }
}

impl TraceSink for RingSink {
    fn round(&self, ev: &RoundEvent) {
        let mut ring = self.inner.lock().unwrap();
        if ring.events.len() == self.cap {
            ring.events.pop_front();
            ring.dropped += 1;
        }
        ring.events.push_back(*ev);
    }

    fn message(&self, _text: &str) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(round: usize) -> RoundEvent {
        RoundEvent { round, objective: -0.5, cols_added: 1, solve_ns: 10, ..Default::default() }
    }

    #[test]
    fn ring_keeps_the_newest_events() {
        let ring = RingSink::new(4);
        for r in 1..=10 {
            ring.round(&ev(r));
        }
        let evs = ring.events();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs.iter().map(|e| e.round).collect::<Vec<_>>(), vec![7, 8, 9, 10]);
        assert_eq!(ring.dropped(), 6);
    }

    #[test]
    fn ring_under_capacity_drops_nothing() {
        let ring = RingSink::new(8);
        for r in 1..=3 {
            ring.round(&ev(r));
        }
        assert_eq!(ring.events().len(), 3);
        assert_eq!(ring.dropped(), 0);
    }

    #[test]
    fn round_json_is_stable_and_parseable() {
        let e = RoundEvent {
            round: 3,
            objective: -1.25,
            viol_rows: 2,
            viol_cols: 7,
            rows_added: 2,
            cols_added: 5,
            working_set: 40,
            simplex_iters: 19,
            solve_ns: 1_000,
            pricing_ns: 2_000,
            expand_ns: 30,
        };
        let line = round_json(&e);
        assert_eq!(
            line,
            "{\"event\":\"round\",\"round\":3,\"objective\":-1.25,\"viol_rows\":2,\
             \"viol_cols\":7,\"rows_added\":2,\"cols_added\":5,\"working_set\":40,\
             \"simplex_iters\":19,\"solve_ns\":1000,\"pricing_ns\":2000,\"expand_ns\":30}"
        );
        // round-trips through the serve-layer parser
        let v = crate::serve::json::Json::parse(&line).expect("valid JSON");
        assert_eq!(v.get("round").and_then(|j| j.as_usize()), Some(3));
        assert_eq!(v.get("objective").and_then(|j| j.as_f64()), Some(-1.25));
    }

    #[test]
    fn non_finite_objectives_serialize_as_null() {
        let line = round_json(&RoundEvent { objective: f64::NAN, ..Default::default() });
        assert!(line.contains("\"objective\":null"), "got: {line}");
    }

    #[test]
    fn jsonl_sink_writes_one_line_per_event() {
        let path =
            std::env::temp_dir().join(format!("cutgen_trace_test_{}.jsonl", std::process::id()));
        let sink = JsonlSink::create(&path).expect("create trace file");
        sink.round(&ev(1));
        sink.round(&ev(2));
        sink.message("stalled after 5 flat rounds");
        let text = std::fs::read_to_string(&path).expect("read back");
        let _ = std::fs::remove_file(&path);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("\"round\":1"));
        assert!(lines[2].contains("\"event\":\"message\""));
        for l in &lines {
            crate::serve::json::Json::parse(l).expect("each line is valid JSON");
        }
    }

    #[test]
    fn span_is_monotone() {
        let span = Span::start();
        let a = span.elapsed_ns();
        let b = span.elapsed_ns();
        assert!(b >= a);
    }
}
