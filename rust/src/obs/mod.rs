//! Observability: metrics registry, structured round tracing, and the
//! monotonic span helper behind both.
//!
//! The source paper's central claim is a *timing* claim — generation
//! beats monolithic solves because restricted re-solves and pricing
//! scans are cheap per round — so this layer makes every solve explain
//! where its time went, in two always-cheap forms:
//!
//! * [`metrics`] — a zero-dependency registry of atomic [`Counter`]s,
//!   [`Gauge`]s, and fixed-boundary log-spaced latency [`Histogram`]s,
//!   rendered on demand in Prometheus text-exposition format
//!   ([`Registry::render`]). Instruments are lock-free on the hot path
//!   (relaxed atomics); the registry lock is only taken at
//!   registration and render time.
//! * [`trace`] — typed per-round events ([`RoundEvent`]) emitted by
//!   `GenEngine::run` through a [`TraceSink`]: human stderr lines
//!   ([`StderrSink`], what `--trace` prints), JSONL files
//!   ([`JsonlSink`], `--trace-json`), or a bounded in-memory ring
//!   ([`RingSink`], what serve returns for `"trace": true` requests and
//!   logs for slow solves).
//!
//! [`stderr_line`] is the one sanctioned way to write to stderr from
//! library code: a single `write_all` per line, so concurrent serve
//! workers never interleave half-lines (CI lints `eprintln!` outside
//! this module). [`Span`] wraps `std::time::Instant` for the wall-clock
//! sections (`solve_ns`/`pricing_ns`/`seed_ns`) that survive into
//! `GenStats` and the serve layer's reports.

#![warn(missing_docs)]

pub mod metrics;
pub mod trace;

pub use metrics::{latency_bounds, Counter, Gauge, Histogram, Registry};
pub use trace::{stderr_line, JsonlSink, RingSink, RoundEvent, Span, StderrSink, TraceSink};
