//! Hand-rolled CLI (the offline image carries no clap).
//!
//! ```text
//! cutgen doctor
//! cutgen datagen  --kind l1|group|sparse --n N --p P [--seed S] --out FILE
//! cutgen train    --data FILE | --synthetic N,P  [--penalty l1|group|slope]
//!                 [--lambda-frac F] [--method fo-clg|clg|cng|clcng|full-lp|psm]
//!                 [--backend native|pjrt] [--eps E] [--group-size G]
//!                 [--init auto|screening|fista|blockcd|subsample] [--seed-budget K]
//!                 [--threads T] [--trace] [--trace-json FILE]
//! cutgen path     --synthetic N,P [--path grid|exact] [--grid K] [--ratio R]
//!                 [--lambda-min-frac F] [--seed-budget K] [--threads T]
//! cutgen ranksvm  --synthetic N,P | --data FILE  [--lambda-frac F]
//!                 [--method gen|full-lp] [--grid K] [--path exact] [--eps E] [--init S]
//!                 [--pair-mode auto|enumerate|implicit]
//!                 [--level-gap G] [--level-weight W]
//!                 [--target-ratio R] [--ratio-tol T]
//!                 [--seed-budget K] [--threads T] [--trace] [--trace-json FILE]
//! cutgen dantzig  --synthetic N,P | --data FILE  [--lambda-frac F]
//!                 [--method gen|full-lp] [--grid K] [--path exact] [--eps E] [--init S]
//!                 [--seed-budget K] [--threads T] [--trace] [--trace-json FILE]
//! cutgen serve    [--port 7878] [--host 127.0.0.1] [--workers W]
//!                 [--cache-cap N] [--cache-bytes B] [--registry-bytes B]
//!                 [--persist-dir DIR]
//!                 [--max-inflight N] [--queue-cap N] [--slow-solve-ms MS] [--stdin]
//! cutgen client   [--port 7878] [--host H] --send '<json>' | --file requests.jsonl
//!                 | --metrics
//! cutgen bench    --exp table1|…|fig4|all [--scale smoke|default|paper]
//! ```
//!
//! `--init` selects the §4 first-order initialization strategy for cold
//! solves (`auto` = per-workload FOM default; `screening` = the
//! closed-form λ_max top-k); `--seed-budget` sizes the seed. They apply
//! to `train --method clg|cng` and the group/slope penalties, to
//! `path`, and to `ranksvm`/`dantzig`; the paper-method runners
//! (`fo-clg`, `clcng`) pin their own §5 FOM configuration and ignore
//! them. `--pair-mode` picks RankSVM's comparison-pair representation
//! (`auto` enumerates small candidate sets, goes implicit — O(n log n)
//! pricing, no O(n²) list — beyond; see `docs/ranksvm-scaling.md`).
//!
//! RankSVM extras: `--level-gap G` / `--level-weight W` put bucketed
//! per-level-difference costs on the pairs (gap `1 + G·(a−b−1)`, weight
//! `W^(a−b−1)` for winner level `a`, loser level `b` — a simple
//! severity ramp exercising the weighted/gapped machinery end to end);
//! `--target-ratio R` hands λ selection to the dynamic controller,
//! which bisects λ until weighted-hinge/‖β‖₁ lands within
//! `--ratio-tol` (default 0.1, relative) of `R` — see
//! `coordinator::controller`.
//!
//! `--path exact` switches the λ-path subcommands from the fixed
//! geometric grid (Algorithm 2) to the exact parametric breakpoint ride
//! of `coordinator::path_exact` — it descends from λ_max to
//! `--lambda-min-frac`·λ_max (default 0.05) and prices the implicit
//! space only where the restricted basis changes; see
//! `docs/path-exact.md`. Group/Slope keep the grid (no parametric
//! certificate exists for them).
//!
//! `--trace` prints one human-readable stderr line per generation
//! round; `--trace-json FILE` additionally streams the typed round
//! events as JSONL (schema in `docs/observability.md`) for offline
//! time-breakdown analysis. The two compose — either or both.

use std::collections::BTreeMap;

use crate::error::{Context, Result};
use crate::{bail, ensure, err};

use crate::backend::{Backend, NativeBackend};
use crate::coordinator::path::{geometric_grid, regularization_path};
use crate::coordinator::{GenParams, SvmSolution};
use crate::data::synthetic::{
    generate_dantzig, generate_group, generate_l1, generate_ranksvm, generate_sparse_text,
    DantzigSpec, GroupSpec, RankSpec, SparseTextSpec, SyntheticSpec,
};
use crate::data::{libsvm, Dataset};
use crate::engine::{InitStrategy, Initializer, PairMode};
use crate::exps::{run_experiment, Scale, ALL_EXPERIMENTS};
use crate::rng::Xoshiro256;
use crate::workloads::pairset::{PairCosts, PairSet};

/// Parsed command line: subcommand + `--key value` options.
pub struct Args {
    /// First positional argument.
    pub command: String,
    /// `--key value` pairs (flags get "true").
    pub opts: BTreeMap<String, String>,
}

/// Parse `argv[1..]`.
pub fn parse_args<I: Iterator<Item = String>>(mut argv: I) -> Result<Args> {
    let command = argv.next().unwrap_or_else(|| "help".to_string());
    let mut opts = BTreeMap::new();
    let mut pending: Option<String> = None;
    for tok in argv {
        if let Some(stripped) = tok.strip_prefix("--") {
            if let Some(key) = pending.take() {
                opts.insert(key, "true".to_string()); // previous was a flag
            }
            pending = Some(stripped.to_string());
        } else if let Some(key) = pending.take() {
            opts.insert(key, tok);
        } else {
            bail!("unexpected positional argument {tok:?}");
        }
    }
    if let Some(key) = pending {
        opts.insert(key, "true".to_string());
    }
    Ok(Args { command, opts })
}

impl Args {
    fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }
    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number")),
        }
    }
    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer")),
        }
    }
    /// Generation params with the shared `--eps/--threads/--trace/
    /// --trace-json/--init/--seed-budget/--pair-mode` knobs folded in.
    fn gen_params(&self) -> Result<GenParams> {
        let init = match self.get("init") {
            Some(s) => InitStrategy::parse(s)?,
            None => InitStrategy::Auto,
        };
        let pair_mode = match self.get("pair-mode") {
            Some(s) => PairMode::parse(s)?,
            None => PairMode::Auto,
        };
        // --trace-json streams typed round events to a JSONL file,
        // independent of the human-readable --trace stderr lines
        let sink: Option<std::sync::Arc<dyn crate::obs::TraceSink>> =
            match self.get("trace-json") {
                Some(path) => {
                    let s = crate::obs::JsonlSink::create(std::path::Path::new(path))
                        .with_context(|| format!("creating --trace-json file {path}"))?;
                    Some(std::sync::Arc::new(s))
                }
                None => None,
            };
        Ok(GenParams {
            eps: self.get_f64("eps", 1e-2)?,
            threads: self.get_usize("threads", 1)?.max(1),
            trace: self.get("trace").is_some(),
            sink,
            init,
            seed_budget: self
                .get_usize("seed-budget", crate::engine::DEFAULT_SEED_BUDGET)?
                .max(1),
            pair_mode,
            ..Default::default()
        })
    }
}

const HELP: &str = "\
cutgen — column & constraint generation for L1/Group/Slope-SVM LPs
  (reproduction of Dedieu & Mazumder 2018; see README.md)

USAGE: cutgen <command> [--options]

COMMANDS
  doctor                 check the PJRT runtime and artifacts
  datagen                write a synthetic dataset in libsvm format
  train                  fit one model at a fixed lambda
  path                   warm-started regularization path
  ranksvm                pairwise-hinge L1 ranking (constraint generation)
  dantzig                Dantzig selector (column-and-constraint generation)
  serve                  persistent solve service (warm-start cache; see docs/serving.md)
  client                 send protocol requests to a running server
  bench                  regenerate a paper table/figure (or `--exp all`)
  help                   this text

Run `cutgen <command>` with no options for that command's defaults.";

/// CLI entry point.
pub fn main_with(args: Args) -> Result<()> {
    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "doctor" => doctor(),
        "datagen" => datagen(&args),
        "train" => train(&args),
        "path" => path_cmd(&args),
        "ranksvm" => ranksvm_cmd(&args),
        "dantzig" => dantzig_cmd(&args),
        "serve" => serve_cmd(&args),
        "client" => client_cmd(&args),
        "bench" => bench(&args),
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

fn doctor() -> Result<()> {
    println!("cutgen doctor");
    match crate::runtime::smoke() {
        Ok(platform) => println!("  PJRT CPU client: ok (platform = {platform})"),
        Err(e) => println!("  PJRT CPU client: FAILED ({e})"),
    }
    if crate::runtime::PjrtRuntime::artifacts_available() {
        let rt = crate::runtime::PjrtRuntime::load(crate::runtime::PjrtRuntime::default_dir())?;
        println!(
            "  artifacts: ok (tile {}x{}, dir {})",
            rt.meta.tn,
            rt.meta.tp,
            crate::runtime::PjrtRuntime::default_dir().display()
        );
    } else {
        println!("  artifacts: MISSING — run `make artifacts`");
    }
    println!("  simplex self-check: ");
    let mut m = crate::simplex::LpModel::new();
    let x = m.add_col_nonneg(1.0, &[]);
    m.add_row_ge(1.0, &[(x, 1.0)]);
    let mut s = crate::simplex::SimplexSolver::new(m);
    ensure!(s.solve() == crate::simplex::Status::Optimal, "simplex self-check failed");
    println!("    ok (min x s.t. x >= 1 -> {})", s.objective());
    Ok(())
}

fn datagen(args: &Args) -> Result<()> {
    let kind = args.get("kind").unwrap_or("l1");
    let n = args.get_usize("n", 100)?;
    let p = args.get_usize("p", 1000)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let out = args.get("out").ok_or_else(|| err!("--out FILE required"))?;
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let ds = match kind {
        "l1" => generate_l1(&SyntheticSpec::paper_default(n, p), &mut rng),
        "group" => {
            let gs = args.get_usize("group-size", 10)?;
            generate_group(
                &GroupSpec {
                    n,
                    n_groups: p / gs,
                    group_size: gs,
                    k0_groups: 3,
                    rho: 0.1,
                    standardize: true,
                },
                &mut rng,
            )
            .data
        }
        "sparse" => generate_sparse_text(
            &SparseTextSpec { n, p, density: args.get_f64("density", 0.002)?, k0: 50, zipf: 1.1 },
            &mut rng,
        ),
        other => bail!("unknown --kind {other:?} (l1|group|sparse)"),
    };
    libsvm::write_file(&ds, out)?;
    println!("wrote {} ({} x {}, nnz {})", out, ds.n(), ds.p(), ds.x.nnz());
    Ok(())
}

fn load_or_generate(args: &Args) -> Result<Dataset> {
    if let Some(file) = args.get("data") {
        // one loading path with the serve registry (labels mapped to ±1)
        let ds = crate::serve::registry::load_libsvm(file, false)?;
        println!("loaded {} ({} x {}, nnz {})", file, ds.n(), ds.p(), ds.x.nnz());
        Ok(ds)
    } else {
        let spec = args.get("synthetic").unwrap_or("100,1000");
        let (n, p) = spec
            .split_once(',')
            .and_then(|(a, b)| Some((a.parse().ok()?, b.parse().ok()?)))
            .ok_or_else(|| err!("--synthetic expects N,P"))?;
        let seed = args.get_usize("seed", 0)? as u64;
        Ok(generate_l1(&SyntheticSpec::paper_default(n, p), &mut Xoshiro256::seed_from_u64(seed)))
    }
}

fn report(sol: &SvmSolution, secs: f64) {
    println!("  objective     {:.6}", sol.objective);
    println!("  support       {}", sol.support_size());
    println!("  working set   |J| = {}, |I| = {}", sol.cols.len(), sol.rows.len());
    println!(
        "  generation    {} rounds, {} cols, {} rows, {} simplex iters",
        sol.stats.rounds, sol.stats.cols_added, sol.stats.rows_added, sol.stats.simplex_iters
    );
    println!("  time          {secs:.3}s");
}

fn train(args: &Args) -> Result<()> {
    let ds = load_or_generate(args)?;
    let lambda_frac = args.get_f64("lambda-frac", 0.01)?;
    let method = args.get("method").unwrap_or("fo-clg");
    let penalty = args.get("penalty").unwrap_or("l1");
    let use_pjrt = args.get("backend") == Some("pjrt");
    let gen = args.gen_params()?;
    // single source of truth for the shared knobs (gen_params parses them)
    let eps = gen.eps;
    let threads = gen.threads;
    // The shared method runners (fo-clg, clcng, slope init) build their own
    // GenParams; the env knob routes the thread count to them too.
    std::env::set_var("CUTGEN_THREADS", threads.to_string());

    // optional PJRT runtime (owned here so the backend can borrow it)
    let rt = if use_pjrt {
        Some(crate::runtime::PjrtRuntime::load(crate::runtime::PjrtRuntime::default_dir())?)
    } else {
        None
    };
    let pjrt_backend = match &rt {
        Some(rt) => Some(crate::runtime::PjrtBackend::new(rt, &ds.x)?),
        None => None,
    };
    let native = NativeBackend::new(&ds.x);
    let backend: &dyn Backend = match &pjrt_backend {
        Some(b) => b,
        None => &native,
    };
    println!("backend: {}", backend.name());

    match penalty {
        "l1" => {
            let lambda = lambda_frac * ds.lambda_max_l1();
            // fo-clg / clcng are the paper's §5 methods with their own
            // pinned FOM configuration; only clg/cng consume --init
            let init_label = match method {
                "clg" | "cng" => gen.init.as_str(),
                "fo-clg" | "clcng" => "method-defined",
                _ => "n/a",
            };
            println!(
                "L1-SVM: n={}, p={}, λ={lambda:.4} ({lambda_frac}·λ_max), init {init_label}",
                ds.n(),
                ds.p(),
            );
            let (sol, t) = crate::exps::time_it(|| -> Result<SvmSolution> {
                Ok(match method {
                    "fo-clg" => crate::exps::common::fo_clg(&ds, lambda, eps, 100).0,
                    "clg" => {
                        // §4 default behavior: FOM-seeded cold solve
                        // (--init screening restores the bare top-k seed);
                        // column-only — Algorithm 1 keeps all margin rows.
                        // The seed's primal guess also picks the starting
                        // basis via crossover.
                        let seed =
                            Initializer::from_params(&gen).seed_l1_cols(&ds, backend, lambda);
                        crate::coordinator::l1svm::column_generation_seeded(
                            &ds, backend, lambda, &seed, &gen,
                        )
                    }
                    "cng" => {
                        let seed = Initializer::from_params(&gen).seed_l1(&ds, backend, lambda);
                        crate::coordinator::l1svm::constraint_generation(
                            &ds,
                            lambda,
                            &seed.ws.rows,
                            &gen,
                        )
                    }
                    "clcng" => crate::exps::common::sfo_cl_cng(&ds, lambda, eps, 200, 1).0,
                    "full-lp" => crate::baselines::full_lp::solve_full_l1(&ds, lambda),
                    "psm" => crate::baselines::psm::psm_l1svm(&ds, lambda).solution,
                    other => bail!("unknown --method {other:?}"),
                })
            });
            report(&sol?, t);
        }
        "group" => {
            let gs = args.get_usize("group-size", 10)?;
            ensure!(ds.p() % gs == 0, "p must be a multiple of --group-size");
            let groups: Vec<Vec<usize>> =
                (0..ds.p() / gs).map(|g| (g * gs..(g + 1) * gs).collect()).collect();
            let lambda = lambda_frac * ds.lambda_max_group(&groups);
            println!(
                "Group-SVM: {} groups of {gs}, λ={lambda:.4}, init {}",
                groups.len(),
                gen.init.as_str()
            );
            let init = Initializer::from_params(&gen).seed_group(&ds, &groups, lambda).ws.cols;
            let (sol, t) = crate::exps::time_it(|| {
                crate::coordinator::group::group_column_generation(
                    &ds, backend, &groups, lambda, &init, &gen,
                )
            });
            report(&sol, t);
        }
        "slope" => {
            let lt = lambda_frac * ds.lambda_max_l1();
            let lambda = crate::fom::objective::bh_slope_weights(ds.p(), lt);
            println!("Slope-SVM (BH weights): λ̃={lt:.4}, init {}", gen.init.as_str());
            // the §5 slope config seeds with up to 100 columns; an
            // explicit --seed-budget still wins
            let mut ini = Initializer::from_params(&gen);
            if args.get("seed-budget").is_none() {
                ini.budget = 100;
            }
            let init = ini.seed_slope(&ds, &lambda).ws.cols;
            let slope_gen = GenParams { max_cols_per_round: 10, ..gen.clone() };
            let (sol, t) = crate::exps::time_it(|| {
                crate::coordinator::slope::slope_column_constraint_generation(
                    &ds, backend, &lambda, &init, &slope_gen,
                )
            });
            report(&sol, t);
        }
        other => bail!("unknown --penalty {other:?} (l1|group|slope)"),
    }
    Ok(())
}

fn path_cmd(args: &Args) -> Result<()> {
    let ds = load_or_generate(args)?;
    let k = args.get_usize("grid", 20)?;
    let ratio = args.get_f64("ratio", 0.7)?;
    let gen = args.gen_params()?;
    let lmax = ds.lambda_max_l1();
    let backend = NativeBackend::new(&ds.x);
    match args.get("path").unwrap_or("grid") {
        "grid" => {
            let grid = geometric_grid(lmax, k, ratio);
            let ((path, _), t) =
                crate::exps::time_it(|| regularization_path(&ds, &backend, &grid, &gen));
            report_path(&path, t);
        }
        "exact" => {
            let llo = args.get_f64("lambda-min-frac", 0.05)? * lmax;
            let (path, t) = crate::exps::time_it(|| {
                crate::coordinator::path_exact::l1svm_path_exact(&ds, &backend, lmax, llo, &gen)
            });
            report_exact_path(&path, t);
        }
        other => bail!("unknown --path {other:?} (grid|exact)"),
    }
    Ok(())
}

/// `--data FILE` or a workload-specific synthetic draw (`--synthetic N,P`
/// with real-valued responses — RankSVM and the Dantzig selector are not
/// two-class problems, so `train`'s ±1 generator does not apply).
fn load_or_generate_regression(args: &Args, rank: bool) -> Result<Dataset> {
    if let Some(file) = args.get("data") {
        // one loading path with the serve registry; raw labels preserved —
        // coercing responses to ±1 would destroy the ranking/regression
        // targets (this is what used to make these subcommands
        // synthetic-only in practice)
        let ds = crate::serve::registry::load_libsvm(file, true)?;
        println!("loaded {} ({} x {}, nnz {})", file, ds.n(), ds.p(), ds.x.nnz());
        return Ok(ds);
    }
    let spec = args.get("synthetic").unwrap_or("60,200");
    let (n, p) = spec
        .split_once(',')
        .and_then(|(a, b)| Some((a.parse().ok()?, b.parse().ok()?)))
        .ok_or_else(|| err!("--synthetic expects N,P"))?;
    let seed = args.get_usize("seed", 0)? as u64;
    let mut rng = Xoshiro256::seed_from_u64(seed);
    Ok(if rank {
        generate_ranksvm(
            &RankSpec { n, p, k0: 10.min(p), rho: 0.1, noise: 0.3, standardize: true },
            &mut rng,
        )
    } else {
        generate_dantzig(
            &DantzigSpec { n, p, k0: 10.min(p), rho: 0.1, sigma: 0.5, standardize: true },
            &mut rng,
        )
    })
}

/// Print a decreasing-λ path table.
fn report_path(path: &[crate::coordinator::path::PathSolution], secs: f64) {
    println!("{:>12} {:>12} {:>8} {:>8}", "lambda", "objective", "nnz", "|J|");
    for pt in path {
        println!(
            "{:>12.5} {:>12.5} {:>8} {:>8}",
            pt.lambda, pt.objective, pt.support, pt.working_set
        );
    }
    println!(
        "total {secs:.3}s, {} simplex iterations",
        path.last().unwrap().stats.simplex_iters
    );
}

/// Print an exact-path breakpoint table (one row per basis change).
fn report_exact_path(path: &crate::coordinator::path_exact::ExactPath, secs: f64) {
    println!(
        "{:>12} {:>12} {:>8} {:>8} {:>9}",
        "lambda", "objective", "nnz", "|J|", "expanded"
    );
    for pt in &path.points {
        println!(
            "{:>12.5} {:>12.5} {:>8} {:>8} {:>9}",
            pt.lambda, pt.objective, pt.support, pt.working_set, pt.expanded
        );
    }
    println!(
        "total {secs:.3}s: {} breakpoints ({} expanding), {} pricing rounds, {} simplex \
         iterations{}{}",
        path.stats.breakpoints,
        path.stats.expansions,
        path.stats.pricing_rounds,
        path.stats.simplex_iters,
        if path.timed_out { ", timed out" } else { "" },
        if path.truncated { ", truncated" } else { "" },
    );
}

fn ranksvm_cmd(args: &Args) -> Result<()> {
    let ds = load_or_generate_regression(args, true)?;
    let gen = args.gen_params()?;
    let pairs = PairSet::build(&ds.y, gen.pair_mode);
    ensure!(!pairs.is_empty(), "no comparison pairs: all responses are tied");
    let level_gap = args.get_f64("level-gap", 0.0)?;
    let level_weight = args.get_f64("level-weight", 1.0)?;
    let costs = if level_gap == 0.0 && level_weight == 1.0 {
        PairCosts::UNIFORM
    } else {
        ensure!(
            level_gap >= 0.0 && level_gap.is_finite() && level_weight > 0.0
                && level_weight.is_finite(),
            "--level-gap must be finite ≥ 0 and --level-weight finite > 0"
        );
        // severity ramp in the level difference: adjacent levels keep
        // the unit costs, wider splits demand more margin and cost more
        PairCosts::bucketed_by(&pairs, |a, b| {
            let d = (a - b - 1) as f64;
            (1.0 + level_gap * d, level_weight.powf(d))
        })
    };
    costs.validate(&pairs).map_err(|e| err!("{e}"))?;
    let lmax = crate::workloads::ranksvm::lambda_max_rank_weighted(&ds, &pairs, &costs);
    let lambda_frac = args.get_f64("lambda-frac", 0.05)?;
    let backend = NativeBackend::new(&ds.x);
    println!(
        "RankSVM: n={}, p={}, |P|={} pairs ({}, {} scan), λ_max={lmax:.4}, init {}",
        ds.n(),
        ds.p(),
        pairs.len(),
        pairs.mode(),
        costs.scan(&pairs).as_str(),
        gen.init.as_str()
    );
    if let Some(r) = args.get("target-ratio") {
        let ratio: f64 = r.parse().with_context(|| "--target-ratio expects a number")?;
        ensure!(
            matches!(args.get("method"), None | Some("gen")) && args.get("grid").is_none()
                && args.get("path").is_none(),
            "--target-ratio drives the generation solver at one resolved λ; drop \
             --method/--grid/--path"
        );
        let target = crate::engine::RatioTarget {
            ratio,
            tol: args.get_f64("ratio-tol", 0.1)?,
            ..Default::default()
        };
        let (out, t) = crate::exps::time_it(|| {
            crate::coordinator::controller::resolve_lambda_for_ratio(
                &ds, &backend, &pairs, &costs, &target, &gen, None,
            )
        });
        let out = out.map_err(|e| err!("{e}"))?;
        println!(
            "controller: λ = {:.5} ({:.4}·λ_max), slack/‖β‖₁ = {:.4} (target {ratio}), {} solves",
            out.lambda,
            out.lambda / out.lambda_max,
            out.achieved_ratio,
            out.solves
        );
        report(&out.solution, t);
        return Ok(());
    }
    if args.get("path").is_some() || args.get("grid").is_some() {
        ensure!(
            costs.is_uniform(),
            "--level-gap/--level-weight run the fixed-λ (or --target-ratio) solvers; the λ-path \
             drivers are uniform-cost"
        );
    }
    if args.get("path") == Some("exact") {
        let llo = args.get_f64("lambda-min-frac", 0.05)? * lmax;
        let (path, t) = crate::exps::time_it(|| {
            crate::coordinator::path_exact::ranksvm_path_exact(
                &ds, &backend, &pairs, lmax, llo, &gen,
            )
        });
        report_exact_path(&path, t);
        return Ok(());
    }
    if let Some(k) = args.get("grid") {
        ensure!(
            matches!(args.get("method"), None | Some("gen")),
            "--grid runs the warm-started generation path; drop --method"
        );
        let k: usize = k.parse().with_context(|| "--grid expects an integer")?;
        let ratio = args.get_f64("ratio", 0.7)?;
        let grid = geometric_grid(lmax, k, ratio);
        let (path, t) = crate::exps::time_it(|| {
            crate::coordinator::path::ranksvm_path(&ds, &backend, &pairs, &grid, &gen)
        });
        report_path(&path, t);
        return Ok(());
    }
    let lambda = lambda_frac * lmax;
    println!("λ = {lambda:.4} ({lambda_frac}·λ_max)");
    let (sol, t) = match args.get("method").unwrap_or("gen") {
        "gen" => crate::exps::time_it(|| {
            let seed = Initializer::from_params(&gen)
                .seed_ranksvm_costed(&ds, &backend, &pairs, &costs, lambda);
            crate::workloads::ranksvm::ranksvm_generation_costed(
                &ds,
                &backend,
                &pairs,
                &costs,
                lambda,
                &seed.ws.rows,
                &seed.ws.cols,
                &gen,
            )
        }),
        "full-lp" => crate::exps::time_it(|| {
            // the complete-model baseline materializes every pair by
            // definition — small-n cross-checks only
            crate::baselines::ranksvm_full::solve_full_ranksvm_weighted(
                &ds,
                &crate::workloads::ranksvm::ranking_pairs_costed(&ds.y, &costs),
                lambda,
            )
        }),
        other => bail!("unknown --method {other:?} (gen|full-lp)"),
    };
    report(&sol, t);
    Ok(())
}

fn dantzig_cmd(args: &Args) -> Result<()> {
    let ds = load_or_generate_regression(args, false)?;
    let lmax = crate::workloads::dantzig::lambda_max_dantzig(&ds);
    let lambda_frac = args.get_f64("lambda-frac", 0.3)?;
    let backend = NativeBackend::new(&ds.x);
    let gen = args.gen_params()?;
    println!(
        "Dantzig selector: n={}, p={}, λ_max={lmax:.4}, init {}",
        ds.n(),
        ds.p(),
        gen.init.as_str()
    );
    if args.get("path") == Some("exact") {
        let llo = args.get_f64("lambda-min-frac", 0.3)? * lmax;
        let (path, t) = crate::exps::time_it(|| {
            crate::coordinator::path_exact::dantzig_path_exact(&ds, &backend, lmax, llo, &gen)
        });
        report_exact_path(&path, t);
        return Ok(());
    }
    if let Some(k) = args.get("grid") {
        ensure!(
            matches!(args.get("method"), None | Some("gen")),
            "--grid runs the warm-started generation path; drop --method"
        );
        let k: usize = k.parse().with_context(|| "--grid expects an integer")?;
        let ratio = args.get_f64("ratio", 0.7)?;
        let grid = geometric_grid(lmax, k, ratio);
        let (path, t) = crate::exps::time_it(|| {
            crate::coordinator::path::dantzig_path(&ds, &backend, &grid, &gen)
        });
        report_path(&path, t);
        return Ok(());
    }
    let lambda = lambda_frac * lmax;
    println!("λ = {lambda:.4} ({lambda_frac}·λ_max)");
    let (sol, t) = match args.get("method").unwrap_or("gen") {
        "gen" => crate::exps::time_it(|| {
            let seed = Initializer::from_params(&gen).seed_dantzig(&ds, &backend, lambda);
            crate::workloads::dantzig::dantzig_generation(
                &ds,
                &backend,
                lambda,
                &seed.ws.rows,
                &gen,
            )
        }),
        "full-lp" => crate::exps::time_it(|| {
            crate::baselines::dantzig_full::solve_full_dantzig(&ds, lambda)
        }),
        other => bail!("unknown --method {other:?} (gen|full-lp)"),
    };
    report(&sol, t);
    Ok(())
}

/// `cutgen serve`: run the persistent solve service. `--stdin` speaks
/// the protocol over stdin/stdout (tests, CI, piping); otherwise a TCP
/// listener with a worker pool and a bounded accept queue
/// (`--queue-cap`). `--cache-bytes` bounds the warm cache's resident
/// bytes (0 = entry cap only), `--persist-dir` spills snapshots to disk
/// so warm starts survive restarts, and `--max-inflight` caps
/// concurrent solves (0 = unlimited); excess load is rejected with a
/// `retry_after` hint. `--slow-solve-ms` logs a structured stderr line
/// (with the round trace) for any solve/grid over the threshold.
/// `--registry-bytes` bounds the resident bytes of *registered
/// datasets* (0 = unbounded): past the budget the least-recently-used
/// dataset is evicted, exactly as if it had been `unregister`ed. See
/// `docs/serving.md` and `docs/observability.md`.
fn serve_cmd(args: &Args) -> Result<()> {
    let cache_cap = args.get_usize("cache-cap", crate::serve::DEFAULT_CACHE_CAP)?;
    let cache_bytes = args.get_usize("cache-bytes", 0)?;
    let registry_bytes = args.get_usize("registry-bytes", 0)?;
    let max_inflight = args.get_usize("max-inflight", 0)?;
    let slow_solve_ms = args.get_usize("slow-solve-ms", 0)?;
    let mut state = crate::serve::ServeState::new(cache_cap);
    if cache_bytes > 0 {
        state = state.with_cache_bytes(cache_bytes);
    }
    if registry_bytes > 0 {
        state = state.with_registry_bytes(registry_bytes);
    }
    if max_inflight > 0 {
        state = state.with_max_inflight(max_inflight);
    }
    if slow_solve_ms > 0 {
        state = state.with_slow_solve_ms(slow_solve_ms as u64);
    }
    if let Some(dir) = args.get("persist-dir") {
        state = state
            .with_persist_dir(dir)
            .with_context(|| format!("opening persist dir {dir}"))?;
    }
    if args.get("stdin").is_some() {
        crate::serve::transport::serve_stdin(&state)?;
        return Ok(());
    }
    let host = args.get("host").unwrap_or("127.0.0.1");
    let port = args.get_usize("port", 7878)?;
    let workers = args.get_usize("workers", 4)?.max(1);
    let queue_cap = args.get_usize("queue-cap", 64)?.max(1);
    let addr = format!("{host}:{port}");
    let listener = std::net::TcpListener::bind(&addr)
        .with_context(|| format!("binding {addr}"))?;
    crate::obs::stderr_line(&format!(
        "cutgen serve: listening on {addr} ({workers} workers, cache cap {cache_cap}); \
         send {{\"op\":\"shutdown\"}} to stop"
    ));
    crate::serve::transport::serve_tcp(&state, listener, workers, queue_cap)?;
    Ok(())
}

/// `cutgen client`: send request lines to a running server and print the
/// response lines. `--send` takes one inline JSON request; `--file`
/// streams a `.jsonl` file through one connection; `--metrics` fetches
/// the server's Prometheus text exposition and prints it raw (ready to
/// pipe to a scrape file or `promtool`).
fn client_cmd(args: &Args) -> Result<()> {
    let host = args.get("host").unwrap_or("127.0.0.1");
    let addr = format!("{host}:{}", args.get_usize("port", 7878)?);
    if args.get("metrics").is_some() {
        let resp = crate::serve::transport::client_send(&addr, "{\"op\":\"metrics\"}")?;
        let doc = crate::serve::json::Json::parse(&resp)?;
        match doc.get("exposition").and_then(|v| v.as_str()) {
            // the exposition text ends with its own newline
            Some(text) => print!("{text}"),
            None => bail!("server returned no exposition: {resp}"),
        }
        return Ok(());
    }
    if let Some(line) = args.get("send") {
        println!("{}", crate::serve::transport::client_send(&addr, line)?);
        return Ok(());
    }
    if let Some(file) = args.get("file") {
        let text = std::fs::read_to_string(file)
            .with_context(|| format!("reading request file {file}"))?;
        let lines: Vec<String> = text.lines().map(str::to_string).collect();
        for resp in crate::serve::transport::client_send_many(&addr, &lines)? {
            println!("{resp}");
        }
        return Ok(());
    }
    bail!("client needs --send '<json-request>' or --file <requests.jsonl>")
}

fn bench(args: &Args) -> Result<()> {
    let scale = args
        .get("scale")
        .map(|s| Scale::parse(s).ok_or_else(|| err!("bad --scale (smoke|default|paper)")))
        .transpose()?
        .unwrap_or(Scale::Default);
    let exp = args.get("exp").unwrap_or("all");
    if exp == "all" {
        for id in ALL_EXPERIMENTS {
            run_experiment(id, scale);
        }
    } else {
        run_experiment(exp, scale)
            .ok_or_else(|| err!("unknown --exp {exp:?}; one of {ALL_EXPERIMENTS:?} or all"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        parse_args(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parse_basic() {
        let a = args(&["train", "--lambda-frac", "0.05", "--verbose"]);
        assert_eq!(a.command, "train");
        assert_eq!(a.get("lambda-frac"), Some("0.05"));
        assert_eq!(a.get("verbose"), Some("true"));
        assert_eq!(a.get_f64("lambda-frac", 0.0).unwrap(), 0.05);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn parse_rejects_stray_positional() {
        assert!(parse_args(["train", "oops"].iter().map(|s| s.to_string())).is_err());
    }

    #[test]
    fn train_on_tiny_synthetic_runs() {
        let a = args(&["train", "--synthetic", "30,80", "--method", "clg"]);
        main_with(a).unwrap();
    }

    #[test]
    fn train_with_explicit_init_strategies_runs() {
        for strat in ["screening", "fista"] {
            let a = args(&[
                "train",
                "--synthetic",
                "25,50",
                "--method",
                "clg",
                "--init",
                strat,
                "--seed-budget",
                "5",
            ]);
            main_with(a).unwrap();
        }
        let bad = args(&["train", "--synthetic", "25,50", "--init", "magic"]);
        assert!(main_with(bad).is_err(), "unknown strategy must error");
    }

    #[test]
    fn path_on_tiny_synthetic_runs() {
        let a = args(&["path", "--synthetic", "30,60", "--grid", "5"]);
        main_with(a).unwrap();
    }

    #[test]
    fn path_exact_on_tiny_synthetic_runs() {
        let a = args(&[
            "path",
            "--synthetic",
            "30,60",
            "--path",
            "exact",
            "--lambda-min-frac",
            "0.3",
        ]);
        main_with(a).unwrap();
        let bad = args(&["path", "--synthetic", "30,60", "--path", "magic"]);
        assert!(main_with(bad).is_err(), "unknown path mode must error");
        // the exact ride is wired for the ranksvm/dantzig subcommands too
        let r = args(&[
            "ranksvm",
            "--synthetic",
            "15,20",
            "--path",
            "exact",
            "--lambda-min-frac",
            "0.4",
        ]);
        main_with(r).unwrap();
        let d = args(&[
            "dantzig",
            "--synthetic",
            "25,15",
            "--path",
            "exact",
            "--lambda-min-frac",
            "0.5",
        ]);
        main_with(d).unwrap();
    }

    #[test]
    fn ranksvm_on_tiny_synthetic_runs() {
        let a = args(&["ranksvm", "--synthetic", "20,30", "--lambda-frac", "0.05"]);
        main_with(a).unwrap();
        let b = args(&["ranksvm", "--synthetic", "15,20", "--grid", "3"]);
        main_with(b).unwrap();
        // the forced implicit representation drives the same pipeline
        let c = args(&[
            "ranksvm",
            "--synthetic",
            "18,25",
            "--lambda-frac",
            "0.05",
            "--pair-mode",
            "implicit",
        ]);
        main_with(c).unwrap();
        let bad = args(&["ranksvm", "--synthetic", "15,20", "--pair-mode", "magic"]);
        assert!(main_with(bad).is_err(), "unknown pair mode must error");
    }

    #[test]
    fn ranksvm_weighted_and_controller_flags_run() {
        // bucketed severity ramp through gen and the full-LP baseline
        let a = args(&[
            "ranksvm",
            "--synthetic",
            "16,20",
            "--lambda-frac",
            "0.05",
            "--level-gap",
            "0.5",
            "--level-weight",
            "1.5",
        ]);
        main_with(a).unwrap();
        let b = args(&[
            "ranksvm",
            "--synthetic",
            "14,12",
            "--method",
            "full-lp",
            "--level-gap",
            "0.5",
        ]);
        main_with(b).unwrap();
        // the dynamic-λ controller resolves λ from a ratio target
        let c = args(&["ranksvm", "--synthetic", "16,20", "--target-ratio", "2.0"]);
        main_with(c).unwrap();
        // conflicts and bad values error loudly
        let d = args(&["ranksvm", "--synthetic", "15,20", "--target-ratio", "2.0", "--grid", "3"]);
        assert!(main_with(d).is_err(), "--target-ratio conflicts with --grid");
        let e = args(&["ranksvm", "--synthetic", "15,20", "--target-ratio", "-1"]);
        assert!(main_with(e).is_err(), "negative ratio target must error");
        let f = args(&["ranksvm", "--synthetic", "15,20", "--grid", "3", "--level-gap", "0.5"]);
        assert!(main_with(f).is_err(), "the λ-path drivers are uniform-cost");
        let g = args(&["ranksvm", "--synthetic", "15,20", "--level-weight", "0"]);
        assert!(main_with(g).is_err(), "zero level weight must error");
    }

    #[test]
    fn dantzig_on_tiny_synthetic_runs() {
        let a = args(&["dantzig", "--synthetic", "25,20", "--lambda-frac", "0.3"]);
        main_with(a).unwrap();
        let b = args(&["dantzig", "--synthetic", "25,15", "--grid", "3"]);
        main_with(b).unwrap();
        let c = args(&["dantzig", "--synthetic", "20,12", "--method", "full-lp"]);
        main_with(c).unwrap();
        // --grid and an explicit non-gen --method conflict loudly
        let d = args(&["dantzig", "--synthetic", "20,12", "--grid", "3", "--method", "full-lp"]);
        assert!(main_with(d).is_err());
    }

    #[test]
    fn trace_json_flag_streams_round_events() {
        let out = std::env::temp_dir()
            .join(format!("cutgen_cli_trace_{}.jsonl", std::process::id()));
        let a = args(&[
            "train",
            "--synthetic",
            "30,80",
            "--method",
            "clg",
            "--trace-json",
            out.to_str().unwrap(),
        ]);
        main_with(a).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        std::fs::remove_file(&out).ok();
        let lines: Vec<&str> = text.lines().collect();
        assert!(!lines.is_empty(), "at least one round event");
        assert!(lines.iter().any(|l| l.contains("\"event\":\"round\"")));
        for l in &lines {
            crate::serve::json::Json::parse(l).expect("every trace line is valid JSON");
        }
    }

    #[test]
    fn client_without_request_errors() {
        let a = args(&["client", "--port", "1"]);
        assert!(main_with(a).is_err());
    }

    #[test]
    fn datagen_roundtrip() {
        let out = std::env::temp_dir().join("cutgen_cli_datagen.svm");
        let a = args(&[
            "datagen",
            "--kind",
            "sparse",
            "--n",
            "50",
            "--p",
            "200",
            "--out",
            out.to_str().unwrap(),
        ]);
        main_with(a).unwrap();
        let b = args(&[
            "train",
            "--data",
            out.to_str().unwrap(),
            "--method",
            "clg",
            "--lambda-frac",
            "0.05",
        ]);
        main_with(b).unwrap();
        std::fs::remove_file(out).ok();
    }
}
