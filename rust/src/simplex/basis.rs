//! Basis factorization: LU + product-form (eta) updates.
//!
//! The basis matrix `B` collects `m` columns of `Â = [A | −I]`. We hold a
//! dense LU of `B₀` and an eta file of pivots applied since the last
//! refactorization, giving
//!
//! `B = B₀ · E₁ · E₂ ⋯ E_k`,   `E_t` = identity with column `r_t`
//! replaced by `w_t = (B₀E₁⋯E_{t−1})⁻¹ a_{q_t}`.
//!
//! * FTRAN `B x = b`:  solve `B₀ z = b` by LU, then apply each eta:
//!   `x_{r} ← x_r / w_r`, `x_i ← x_i − w_i x_r`.
//! * BTRAN `Bᵀ y = c`: apply eta *transposes* in reverse, then LU-solve
//!   `B₀ᵀ y = z`.

use crate::linalg::Lu;

/// One product-form update: pivot row `r`, transformed column `w`.
///
/// `w` is stored **dense** (with `w[r]` zeroed; the pivot kept aside):
/// the FTRAN/BTRAN inner loops then become straight-line axpy/dot over a
/// contiguous slice, which vectorizes — the (index, value) pair encoding
/// it replaced cost ~15% of end-to-end time in gather/scatter (see
/// EXPERIMENTS.md §Perf).
#[derive(Clone, Debug)]
struct Eta {
    r: usize,
    /// Dense w with the pivot position zeroed.
    w: Vec<f64>,
    pivot: f64,
}

/// Basis with refactorization support.
#[derive(Clone, Debug)]
pub struct Basis {
    m: usize,
    lu: Lu,
    etas: Vec<Eta>,
}

impl Basis {
    /// Factorize the basis given as dense column-major columns
    /// (`cols[k]` = column occupying basis position `k`).
    pub fn factorize(cols: &[Vec<f64>]) -> Self {
        let m = cols.len();
        let mut flat = vec![0.0; m * m];
        for (k, col) in cols.iter().enumerate() {
            debug_assert_eq!(col.len(), m);
            for i in 0..m {
                flat[i * m + k] = col[i];
            }
        }
        let lu = Lu::factorize_flat(m, &flat);
        Self { m, lu, etas: Vec::new() }
    }

    /// Basis dimension.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Number of eta updates since last refactorization.
    pub fn num_etas(&self) -> usize {
        self.etas.len()
    }

    /// Whether the base factorization hit singularity.
    pub fn is_singular(&self) -> bool {
        self.lu.is_singular()
    }

    /// FTRAN: overwrite `b` with `B⁻¹ b`.
    pub fn ftran(&self, b: &mut [f64]) {
        self.lu.solve(b);
        for eta in &self.etas {
            let xr = b[eta.r] / eta.pivot;
            if xr != 0.0 {
                for (bi, wi) in b.iter_mut().zip(&eta.w) {
                    *bi -= wi * xr;
                }
            }
            b[eta.r] = xr;
        }
    }

    /// BTRAN: overwrite `c` with `B⁻ᵀ c`.
    pub fn btran(&self, c: &mut [f64]) {
        for eta in self.etas.iter().rev() {
            // Solve Eᵀ z = c: z_i = c_i (i≠r); w_r z_r + Σ_{i≠r} w_i z_i = c_r.
            let mut s = c[eta.r];
            let mut dot = 0.0;
            for (ci, wi) in c.iter().zip(&eta.w) {
                dot += wi * ci;
            }
            s -= dot; // w[r] is zeroed, so the full dot is exactly Σ_{i≠r}
            c[eta.r] = s / eta.pivot;
        }
        self.lu.solve_transposed(c);
    }

    /// Record a pivot: position `r` replaced by a column whose FTRAN'd
    /// image is `w` (`w = B⁻¹ a_q`, computed *before* this update).
    /// Returns `false` (update refused) when the pivot is numerically bad.
    pub fn push_eta(&mut self, r: usize, w: &[f64]) -> bool {
        let pivot = w[r];
        if pivot.abs() < 1e-11 {
            return false;
        }
        let mut dense = w.to_vec();
        dense[r] = 0.0;
        self.etas.push(Eta { r, w: dense, pivot });
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn dense_matvec(cols: &[Vec<f64>], x: &[f64]) -> Vec<f64> {
        let m = cols.len();
        let mut out = vec![0.0; m];
        for (k, col) in cols.iter().enumerate() {
            for i in 0..m {
                out[i] += col[i] * x[k];
            }
        }
        out
    }

    fn dense_tmatvec(cols: &[Vec<f64>], x: &[f64]) -> Vec<f64> {
        cols.iter().map(|c| c.iter().zip(x).map(|(a, b)| a * b).sum()).collect()
    }

    #[test]
    fn ftran_btran_identity() {
        let cols = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let b = Basis::factorize(&cols);
        let mut v = vec![2.0, 3.0];
        b.ftran(&mut v);
        assert_eq!(v, vec![2.0, 3.0]);
        b.btran(&mut v);
        assert_eq!(v, vec![2.0, 3.0]);
    }

    #[test]
    fn eta_update_matches_refactorization() {
        let mut rng = Xoshiro256::seed_from_u64(21);
        let m = 12;
        // random well-conditioned basis
        let mut cols: Vec<Vec<f64>> = (0..m)
            .map(|k| {
                let mut c: Vec<f64> = (0..m).map(|_| rng.normal() * 0.3).collect();
                c[k] += 3.0;
                c
            })
            .collect();
        let mut basis = Basis::factorize(&cols);
        assert!(!basis.is_singular());

        // Perform several replacements, tracking ground truth columns.
        for step in 0..8 {
            let r = step % m;
            let mut a_q: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            a_q[r] += 4.0; // keep invertible
            let mut w = a_q.clone();
            basis.ftran(&mut w);
            assert!(basis.push_eta(r, &w), "pivot too small at step {step}");
            cols[r] = a_q;

            // Check FTRAN against a fresh factorization.
            let fresh = Basis::factorize(&cols);
            let x_true: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let rhs = dense_matvec(&cols, &x_true);
            let mut x1 = rhs.clone();
            basis.ftran(&mut x1);
            let mut x2 = rhs;
            fresh.ftran(&mut x2);
            for (a, b) in x1.iter().zip(&x_true) {
                assert!((a - b).abs() < 1e-7, "ftran mismatch step {step}");
            }
            for (a, b) in x1.iter().zip(&x2) {
                assert!((a - b).abs() < 1e-7);
            }

            // BTRAN check.
            let trhs = dense_tmatvec(&cols, &x_true);
            let mut y1 = trhs.clone();
            basis.btran(&mut y1);
            for (a, b) in y1.iter().zip(&x_true) {
                assert!((a - b).abs() < 1e-7, "btran mismatch step {step}");
            }
        }
        assert_eq!(basis.num_etas(), 8);
    }

    #[test]
    fn refuses_tiny_pivot() {
        let cols = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let mut b = Basis::factorize(&cols);
        let w = vec![1e-14, 1.0];
        assert!(!b.push_eta(0, &w));
        assert_eq!(b.num_etas(), 0);
    }
}
