//! A bounded-variable revised simplex LP solver with warm starting.
//!
//! This is the repository's substitute for the commercial LP solver the
//! paper drives (Gurobi 6.5.2): it provides exactly the capabilities the
//! cutting-plane framework needs —
//!
//! 1. **primal simplex** warm starts after *columns* are added
//!    (column generation keeps the basis primal feasible);
//! 2. **dual simplex** warm starts after *rows* are added
//!    (constraint generation / Slope cuts keep the basis dual feasible);
//! 3. ranged rows, variable bounds (including free variables such as the
//!    SVM intercept β₀), dual values and reduced costs.
//!
//! # Computational form
//!
//! The model `min cᵀx  s.t.  Lᵢ ≤ aᵢᵀx ≤ Uᵢ,  l ≤ x ≤ u` is held as
//! `Âx̂ = 0` with `Â = [A | −I]` — one *logical* variable per row, bounded
//! by the row range. A basis is `m` columns of `Â`; between periodic LU
//! refactorizations the basis inverse is maintained in product form
//! (eta file). Cold starts use the all-logical basis, which is **dual
//! feasible** whenever all structural costs are ≥ 0 — true for every LP in
//! this library (hinge slacks cost 1, |β| halves cost λ ≥ 0, η costs 1,
//! β₀ is free with cost 0) — so a cold solve is simply a dual-simplex run.
//!
//! # References
//!
//! Bertsimas & Tsitsiklis, *Introduction to Linear Optimization* (1997),
//! chapters 3–6; Maros, *Computational Techniques of the Simplex Method*
//! (2003) for the bounded ratio tests and the product-form update.

mod basis;
mod model;
mod parametric;
mod solver;

pub use basis::Basis;
pub use model::{LpModel, RowId, VarId};
pub use parametric::{ParametricSimplex, PathPoint};
pub(crate) use parametric::next_cost_breakpoint;
pub use solver::{SimplexSolver, SolveStats, Status, VarStatus};

/// Numerical tolerances shared by the solver components.
#[derive(Clone, Copy, Debug)]
pub struct Tolerances {
    /// Primal feasibility tolerance (bound violations).
    pub feas: f64,
    /// Dual feasibility tolerance (reduced-cost sign violations).
    pub opt: f64,
    /// Minimum admissible pivot magnitude.
    pub pivot: f64,
    /// Refactorize after this many eta updates.
    pub refactor_every: usize,
    /// Hard iteration limit (per `solve` call).
    pub max_iters: usize,
}

impl Default for Tolerances {
    fn default() -> Self {
        Self {
            feas: 1e-7,
            opt: 1e-7,
            pivot: 1e-9,
            refactor_every: 256,
            max_iters: 2_000_000,
        }
    }
}

#[cfg(test)]
mod tests;
