//! Integration and property tests for the simplex solver.
//!
//! LP optimality is fully characterized by the KKT conditions, so the
//! randomized tests verify: primal feasibility, dual feasibility
//! (reduced-cost signs), and complementary slackness — for every random
//! instance. Warm-start tests verify the column/constraint-generation
//! invariants the coordinators rely on.

use super::*;
use crate::rng::Xoshiro256;

const TOL: f64 = 1e-6;

/// Full KKT verification of the solver's claimed optimum.
fn assert_kkt(solver: &mut SimplexSolver) {
    let x = solver.col_values();
    let m = solver.model().num_rows();
    // 1. primal feasibility
    let pinf = solver.model().infeasibility_of(&x);
    assert!(pinf <= TOL, "primal infeasibility {pinf}");
    // 2. dual feasibility
    let dinf = solver.dual_infeasibility();
    assert!(dinf <= TOL, "dual infeasibility {dinf}");
    // 3. complementary slackness on rows
    let act = solver.model().activities_of(&x);
    for r in 0..m {
        let y = solver.row_dual(r);
        let (lo, hi) = (solver.model().row_lo[r], solver.model().row_hi[r]);
        let at_lo = lo.is_finite() && (act[r] - lo).abs() <= 1e-5;
        let at_hi = hi.is_finite() && (hi - act[r]).abs() <= 1e-5;
        if !at_lo && !at_hi {
            assert!(y.abs() <= 1e-5, "row {r}: interior activity but dual {y}");
        }
        if at_lo && !at_hi {
            assert!(y >= -1e-6, "row {r}: at lower bound but dual {y} < 0");
        }
        if at_hi && !at_lo {
            assert!(y <= 1e-6, "row {r}: at upper bound but dual {y} > 0");
        }
    }
    // 4. complementary slackness on columns
    for j in 0..solver.model().num_vars() {
        let d = solver.col_reduced_cost(j);
        let (lb, ub) = (solver.model().lb[j], solver.model().ub[j]);
        let at_lb = lb.is_finite() && (x[j] - lb).abs() <= 1e-5;
        let at_ub = ub.is_finite() && (ub - x[j]).abs() <= 1e-5;
        if !at_lb && !at_ub {
            assert!(d.abs() <= 1e-5, "col {j}: interior value {} but d {d}", x[j]);
        }
    }
}

#[test]
fn diet_like_lp() {
    // min 2x + 3y  s.t. x + y >= 4, x + 2y >= 6, x,y >= 0.
    // Optimal: x = 2, y = 2, obj = 10.
    let mut m = LpModel::new();
    let x = m.add_col_nonneg(2.0, &[]);
    let y = m.add_col_nonneg(3.0, &[]);
    m.add_row_ge(4.0, &[(x, 1.0), (y, 1.0)]);
    m.add_row_ge(6.0, &[(x, 1.0), (y, 2.0)]);
    let mut s = SimplexSolver::new(m);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.objective() - 10.0).abs() < TOL, "obj {}", s.objective());
    assert!((s.col_value(x) - 2.0).abs() < TOL);
    assert!((s.col_value(y) - 2.0).abs() < TOL);
    assert_kkt(&mut s);
}

#[test]
fn equality_rows_and_free_variable() {
    // min |t| modeled as t+ + t-, with free variable z:
    // min t+ + t-   s.t.  z = 3 (eq),  t+ - t- + z = 1  => t = -2, obj 2.
    let mut m = LpModel::new();
    let tp = m.add_col_nonneg(1.0, &[]);
    let tm = m.add_col_nonneg(1.0, &[]);
    let z = m.add_col_free(0.0, &[]);
    m.add_row_eq(3.0, &[(z, 1.0)]);
    m.add_row_eq(1.0, &[(tp, 1.0), (tm, -1.0), (z, 1.0)]);
    let mut s = SimplexSolver::new(m);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.objective() - 2.0).abs() < TOL, "obj {}", s.objective());
    assert!((s.col_value(z) - 3.0).abs() < TOL);
    assert!((s.col_value(tm) - 2.0).abs() < TOL);
    assert_kkt(&mut s);
}

#[test]
fn upper_bounded_variables_and_ranged_row() {
    // min -x - 2y  is not allowed (negative costs with inf ub) — use
    // finite upper bounds so the crash basis stays dual feasible.
    // min -x - 2y, x ∈ [0,3], y ∈ [0,2], x + y ∈ [1, 4].
    // Optimum: y = 2, x = 2 (row at upper), obj = -6.
    let mut m = LpModel::new();
    let x = m.add_col(-1.0, 0.0, 3.0, &[]);
    let y = m.add_col(-2.0, 0.0, 2.0, &[]);
    m.add_row(1.0, 4.0, &[(x, 1.0), (y, 1.0)]);
    let mut s = SimplexSolver::new(m);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.objective() + 6.0).abs() < TOL, "obj {}", s.objective());
    assert_kkt(&mut s);
}

#[test]
fn unbounded_detected() {
    // min -x, x >= 0 — wait, negative cost with infinite ub panics by
    // design; check unboundedness through a free variable instead:
    // min 0·x + z where z free and no constraint ties z: cost 1 on z free
    // => unbounded below.
    let mut m = LpModel::new();
    let _x = m.add_col_nonneg(1.0, &[]);
    let z = m.add_col_free(1.0, &[]);
    m.add_row_ge(0.0, &[(z, 0.0)]); // z not actually constrained
    let mut s = SimplexSolver::new(m);
    // crash basis: z free with positive cost => dual infeasible free var;
    // primal simplex should drive it to -inf.
    let st = s.solve();
    assert_eq!(st, Status::Unbounded);
}

#[test]
fn infeasible_detected() {
    // x >= 0, x <= -1 via rows: x >= 2 and x <= 1 → infeasible.
    let mut m = LpModel::new();
    let x = m.add_col_nonneg(1.0, &[]);
    m.add_row_ge(2.0, &[(x, 1.0)]);
    m.add_row_le(1.0, &[(x, 1.0)]);
    let mut s = SimplexSolver::new(m);
    assert_eq!(s.solve(), Status::Infeasible);
}

#[test]
fn no_rows_model() {
    let mut m = LpModel::new();
    let x = m.add_col(3.0, 1.0, 10.0, &[]);
    let y = m.add_col(-1.0, 0.0, 2.0, &[]);
    let mut s = SimplexSolver::new(m);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.col_value(x) - 1.0).abs() < TOL);
    assert!((s.col_value(y) - 2.0).abs() < TOL);
}

#[test]
fn degenerate_lp_terminates() {
    // Multiple redundant constraints through the same vertex.
    let mut m = LpModel::new();
    let x = m.add_col_nonneg(1.0, &[]);
    let y = m.add_col_nonneg(1.0, &[]);
    for _ in 0..6 {
        m.add_row_ge(1.0, &[(x, 1.0), (y, 1.0)]);
    }
    m.add_row_ge(1.0, &[(x, 2.0), (y, 1.0)]);
    m.add_row_ge(1.0, &[(x, 1.0), (y, 2.0)]);
    let mut s = SimplexSolver::new(m);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.objective() - 1.0).abs() < TOL, "obj {}", s.objective());
    assert_kkt(&mut s);
}

/// Generate a random feasible, bounded LP with nonnegative costs
/// (the class this library produces) and KKT-verify the solve.
fn random_lp_roundtrip(seed: u64, nv: usize, nr: usize) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut m = LpModel::new();
    // variables: nonnegative, some with finite ub, one free (cost 0)
    let mut vars = Vec::new();
    for _ in 0..nv {
        let cost = rng.uniform() * 2.0;
        let ub = if rng.uniform() < 0.3 { rng.uniform() * 3.0 + 0.5 } else { f64::INFINITY };
        vars.push(m.add_col(cost, 0.0, ub, &[]));
    }
    let free = m.add_col_free(0.0, &[]);
    // a feasible point to anchor row bounds
    let x0: Vec<f64> = (0..nv)
        .map(|j| {
            let ub = m.ub[j];
            let hi = if ub.is_finite() { ub } else { 2.0 };
            rng.uniform() * hi
        })
        .collect();
    let z0 = rng.normal() * 0.5;
    for _ in 0..nr {
        let mut coefs = Vec::new();
        let mut act = 0.0;
        for (k, &v) in vars.iter().enumerate() {
            if rng.uniform() < 0.6 {
                let a = rng.normal();
                coefs.push((v, a));
                act += a * x0[k];
            }
        }
        if rng.uniform() < 0.5 {
            let a = rng.normal();
            coefs.push((free, a));
            act += a * z0;
        }
        match rng.below(3) {
            0 => m.add_row_ge(act - rng.uniform(), &coefs),
            1 => m.add_row_le(act + rng.uniform(), &coefs),
            _ => m.add_row(act - rng.uniform(), act + rng.uniform(), &coefs),
        };
    }
    let mut s = SimplexSolver::new(m);
    let st = s.solve();
    assert_eq!(st, Status::Optimal, "seed {seed}");
    assert_kkt(&mut s);
}

#[test]
fn random_lps_kkt_small() {
    for seed in 0..40 {
        random_lp_roundtrip(seed, 5, 4);
    }
}

#[test]
fn random_lps_kkt_medium() {
    for seed in 100..120 {
        random_lp_roundtrip(seed, 15, 10);
    }
}

#[test]
fn random_lps_kkt_tall_and_wide() {
    for seed in 200..210 {
        random_lp_roundtrip(seed, 4, 20); // more rows than vars
        random_lp_roundtrip(seed + 50, 25, 5); // more vars than rows
    }
}

#[test]
fn warm_start_add_column_reoptimizes_primal() {
    // min x1 + x2 s.t. x1 + x2 >= 2. Optimal obj 2.
    let mut m = LpModel::new();
    let a = m.add_col_nonneg(1.0, &[]);
    let b = m.add_col_nonneg(1.0, &[]);
    let r = m.add_row_ge(2.0, &[(a, 1.0), (b, 1.0)]);
    let mut s = SimplexSolver::new(m);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.objective() - 2.0).abs() < TOL);
    let iters_before = s.stats.primal_iters + s.stats.dual_iters;

    // cheap new column covering the row twice as fast:
    let c = s.add_col(0.5, 0.0, f64::INFINITY, &[(r, 2.0)]);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.objective() - 0.5).abs() < TOL, "obj {}", s.objective());
    assert!((s.col_value(c) - 1.0).abs() < TOL);
    assert_kkt(&mut s);
    let iters_after = s.stats.primal_iters + s.stats.dual_iters;
    assert!(iters_after - iters_before <= 4, "warm start took {} iters", iters_after - iters_before);
}

#[test]
fn warm_start_add_row_reoptimizes_dual() {
    // min x + y s.t. x + y >= 1 → obj 1, then add x >= 2 → obj 2.
    let mut m = LpModel::new();
    let x = m.add_col_nonneg(1.0, &[]);
    let y = m.add_col_nonneg(1.0, &[]);
    m.add_row_ge(1.0, &[(x, 1.0), (y, 1.0)]);
    let mut s = SimplexSolver::new(m);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.objective() - 1.0).abs() < TOL);

    s.add_row(2.0, f64::INFINITY, &[(x, 1.0)]);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.objective() - 2.0).abs() < TOL, "obj {}", s.objective());
    assert!((s.col_value(x) - 2.0).abs() < TOL);
    assert_kkt(&mut s);
}

#[test]
fn warm_start_set_row_bounds_reoptimizes_dual() {
    // min x + y s.t. 1 ≤ x + y ≤ 5 → obj 1; tighten to 3 ≤ · ≤ 5 → obj 3.
    let mut m = LpModel::new();
    let x = m.add_col_nonneg(1.0, &[]);
    let y = m.add_col_nonneg(1.0, &[]);
    let r = m.add_row(1.0, 5.0, &[(x, 1.0), (y, 1.0)]);
    let mut s = SimplexSolver::new(m);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.objective() - 1.0).abs() < TOL);

    s.set_row_bounds(r, 3.0, 5.0);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.objective() - 3.0).abs() < TOL, "obj {}", s.objective());
    assert_kkt(&mut s);

    // relax back down: primal simplex resumes from the tightened basis
    s.set_row_bounds(r, 0.5, 5.0);
    assert_eq!(s.solve(), Status::Optimal);
    assert!((s.objective() - 0.5).abs() < TOL, "obj {}", s.objective());
    assert_kkt(&mut s);
}

#[test]
fn set_row_bounds_matches_cold_solve_on_random_instances() {
    for seed in 0..10 {
        let mut rng = Xoshiro256::seed_from_u64(3000 + seed);
        let (mut warm, _) = random_feasible_lp(&mut rng, 6, 4);
        assert_eq!(warm.solve(), Status::Optimal);
        // shift every row range by a small random amount (keeping lo ≤ hi
        // and a known feasible interior point, see random_feasible_lp)
        let shifts: Vec<f64> = (0..4).map(|_| rng.uniform_in(-0.4, 0.4)).collect();
        let mut cold_model = warm.model().clone();
        for r in 0..4 {
            let lo = warm.model().row_lo[r] + shifts[r];
            let hi = warm.model().row_hi[r] + shifts[r];
            warm.set_row_bounds(r, lo, hi);
            cold_model.row_lo[r] = lo;
            cold_model.row_hi[r] = hi;
        }
        let ws = warm.solve();
        let mut cold = SimplexSolver::new(cold_model);
        let cs = cold.solve();
        assert_eq!(ws, cs, "seed {seed}: warm {ws:?} cold {cs:?}");
        if ws == Status::Optimal {
            assert!(
                (warm.objective() - cold.objective()).abs() < 1e-6,
                "seed {seed}: warm {} cold {}",
                warm.objective(),
                cold.objective()
            );
            assert_kkt(&mut warm);
        }
    }
}

/// A small random LP with wide ranged rows around a known interior point,
/// so moderate bound shifts keep it feasible.
fn random_feasible_lp(rng: &mut Xoshiro256, nv: usize, nr: usize) -> (SimplexSolver, Vec<f64>) {
    let mut m = LpModel::new();
    let vars: Vec<_> =
        (0..nv).map(|_| m.add_col(rng.uniform_in(0.1, 2.0), 0.0, 3.0, &[])).collect();
    let x0: Vec<f64> = (0..nv).map(|_| rng.uniform_in(0.5, 2.0)).collect();
    for _ in 0..nr {
        let coefs: Vec<(VarId, f64)> =
            vars.iter().map(|&v| (v, rng.uniform_in(-1.0, 1.0))).collect();
        let act: f64 = coefs.iter().map(|&(v, c)| c * x0[v]).sum();
        m.add_row(act - 2.0, act + 2.0, &coefs);
    }
    (SimplexSolver::new(m), x0)
}

#[test]
fn warm_start_matches_cold_solve_on_random_instances() {
    for seed in 0..15 {
        let mut rng = Xoshiro256::seed_from_u64(1000 + seed);
        // Base LP
        let nv = 8;
        let mut m = LpModel::new();
        let vars: Vec<_> = (0..nv).map(|_| m.add_col_nonneg(rng.uniform() + 0.1, &[])).collect();
        // Anchor row bounds at a feasible point so the instance is feasible.
        let x0: Vec<f64> = (0..nv).map(|_| rng.uniform() * 2.0).collect();
        for _ in 0..4 {
            let mut act = 0.0;
            let coefs: Vec<_> = vars
                .iter()
                .enumerate()
                .filter_map(|(k, &v)| {
                    if rng.uniform() < 0.7 {
                        let a = rng.uniform() * 2.0 - 0.5;
                        act += a * x0[k];
                        Some((v, a))
                    } else {
                        None
                    }
                })
                .collect();
            m.add_row_ge(act - rng.uniform(), &coefs);
        }
        let mut warm = SimplexSolver::new(m.clone());
        assert_eq!(warm.solve(), Status::Optimal);

        // Mutate: add 3 columns and 2 rows incrementally.
        let mut cold_model = m;
        for _ in 0..3 {
            let cost = rng.uniform() + 0.05;
            let coefs: Vec<_> = (0..cold_model.num_rows())
                .filter_map(|r| {
                    if rng.uniform() < 0.8 { Some((r, rng.uniform() * 2.0)) } else { None }
                })
                .collect();
            warm.add_col(cost, 0.0, f64::INFINITY, &coefs);
            cold_model.add_col(cost, 0.0, f64::INFINITY, &coefs);
            assert_eq!(warm.solve(), Status::Optimal);
        }
        for _ in 0..2 {
            let coefs: Vec<_> = (0..cold_model.num_vars())
                .filter_map(|j| {
                    if rng.uniform() < 0.5 { Some((j, rng.uniform())) } else { None }
                })
                .collect();
            let lo = rng.uniform() * 0.5;
            warm.add_row(lo, f64::INFINITY, &coefs);
            cold_model.add_row(lo, f64::INFINITY, &coefs);
            assert_eq!(warm.solve(), Status::Optimal);
        }

        let mut cold = SimplexSolver::new(cold_model);
        assert_eq!(cold.solve(), Status::Optimal);
        assert!(
            (warm.objective() - cold.objective()).abs() < 1e-6,
            "seed {seed}: warm {} cold {}",
            warm.objective(),
            cold.objective()
        );
        assert_kkt(&mut warm);
    }
}

#[test]
fn parametric_path_matches_direct_solves() {
    // min Σ ξ_i + λ Σ (β+ + β-) — a tiny L1-SVM-shaped LP.
    let mut rng = Xoshiro256::seed_from_u64(7);
    let (n, p) = (12, 6);
    let x: Vec<Vec<f64>> = (0..n).map(|_| (0..p).map(|_| rng.normal()).collect()).collect();
    let y: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();

    let build = |lambda: f64| -> LpModel {
        let mut m = LpModel::new();
        let xi: Vec<_> = (0..n).map(|_| m.add_col_nonneg(1.0, &[])).collect();
        let bp: Vec<_> = (0..p).map(|_| m.add_col_nonneg(lambda, &[])).collect();
        let bm: Vec<_> = (0..p).map(|_| m.add_col_nonneg(lambda, &[])).collect();
        let b0 = m.add_col_free(0.0, &[]);
        for i in 0..n {
            let mut coefs = vec![(xi[i], 1.0), (b0, y[i])];
            for j in 0..p {
                coefs.push((bp[j], y[i] * x[i][j]));
                coefs.push((bm[j], -y[i] * x[i][j]));
            }
            m.add_row_ge(1.0, &coefs);
        }
        m
    };

    let lambda_hi = 6.0;
    let lambda_lo = 0.3;
    // direct solve at λ_lo:
    let mut direct = SimplexSolver::new(build(lambda_lo));
    assert_eq!(direct.solve(), Status::Optimal);

    // parametric ride from λ_hi to λ_lo:
    let model = build(lambda_hi);
    let nvars = model.num_vars();
    let mut c_fix = vec![0.0; nvars];
    let mut c_var = vec![0.0; nvars];
    for j in 0..nvars {
        if j < n {
            c_fix[j] = 1.0; // ξ
        } else if j < n + 2 * p {
            c_var[j] = 1.0; // β halves
        }
    }
    let solver = SimplexSolver::new(model);
    let mut psm = ParametricSimplex::new(solver, c_fix, c_var);
    let (path, st) = psm.run(lambda_hi, lambda_lo, 10_000).unwrap();
    assert_eq!(st, Status::Optimal);
    assert!(path.len() >= 2, "expected breakpoints, got {}", path.len());
    assert!(
        (psm.solver.objective() - direct.objective()).abs() < 1e-5,
        "psm {} direct {}",
        psm.solver.objective(),
        direct.objective()
    );
}

#[test]
fn parametric_run_rejects_unordered_grid() {
    // An ascending (start, target) pair must surface as a typed error —
    // the serve layer's never-panics contract routes user grids here.
    let mut m = LpModel::new();
    let x = m.add_col_nonneg(1.0, &[]);
    m.add_row_ge(1.0, &[(x, 1.0)]);
    let s = SimplexSolver::new(m);
    let mut psm = ParametricSimplex::new(s, vec![0.0], vec![1.0]);
    let err = psm.run(1.0, 2.0, 100).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("lambda_target"), "unexpected message: {msg}");
}

/// Long pivot chains must refactorize within `tol.refactor_every` eta
/// updates and keep KKT residuals at refactorization quality. With a
/// deliberately tiny eta budget, a chain of warm re-solves exercises
/// many refactorize→eta-drift→refactorize cycles on the same basis
/// machinery; the residuals prove the product-form updates never let
/// the factorization drift loose.
#[test]
fn eta_file_drift_bounded_by_refactor_budget() {
    let tol = Tolerances { feas: 1e-9, opt: 1e-9, refactor_every: 8, ..Tolerances::default() };
    let mut rng = Xoshiro256::seed_from_u64(4242);
    let (solver, _) = random_feasible_lp(&mut rng, 24, 16);
    let mut s = solver.with_tolerances(tol);
    assert_eq!(s.solve(), Status::Optimal);
    // A long chain of bound perturbations, each warm re-solved.
    for round in 0..25 {
        for r in 0..16 {
            let shift = rng.uniform_in(-0.15, 0.15);
            let lo = s.model().row_lo[r] + shift;
            let hi = s.model().row_hi[r] + shift;
            s.set_row_bounds(r, lo, hi);
        }
        assert_eq!(s.solve(), Status::Optimal, "round {round}");
        assert!(s.primal_infeasibility() <= 1e-8, "round {round}: pinf {}", s.primal_infeasibility());
        let dinf = s.dual_infeasibility();
        assert!(dinf <= 1e-8, "round {round}: dinf {dinf}");
    }
    let iters = s.stats.primal_iters + s.stats.dual_iters;
    assert!(iters > 4 * tol.refactor_every, "chain too short to exercise drift: {iters} iters");
    // Every pivot appends at most one eta, and the eta file is rebuilt
    // whenever it reaches refactor_every — so the refactorization count
    // must keep pace with the pivot count (2x slack for bound flips,
    // which iterate without growing the eta file).
    assert!(
        s.stats.refactors >= iters / (2 * tol.refactor_every),
        "eta file outgrew its budget: {} refactors over {iters} iters",
        s.stats.refactors
    );
}

/// The dense dual-simplex pricing row must be a pure speed knob: a cold
/// solve (all-logical basis → dual simplex) over a model wide enough to
/// engage the chunked parallel pass must produce bit-identical pivots,
/// iteration counts and solutions at any thread count.
#[test]
fn parallel_dual_pricing_row_is_bit_identical() {
    let mut rng = Xoshiro256::seed_from_u64(909);
    let (nv, m) = (400, 50); // nv clears PAR_PRICE_MIN_COLS
    let mut model = LpModel::new();
    let mut vars = Vec::with_capacity(nv);
    for _ in 0..nv {
        vars.push(model.add_col_nonneg(0.05 + rng.uniform(), &[]));
    }
    // feasible by construction: b = A x0 − slack with x0 ≥ 0
    let x0: Vec<f64> = (0..nv).map(|_| rng.uniform()).collect();
    for _ in 0..m {
        let mut coefs = Vec::new();
        let mut ax0 = 0.0;
        for (&v, &x) in vars.iter().zip(&x0) {
            if rng.uniform() < 0.15 {
                let a = rng.normal();
                ax0 += a * x;
                coefs.push((v, a));
            }
        }
        model.add_row_ge(ax0 - 0.1 - rng.uniform(), &coefs);
    }

    let mut serial = SimplexSolver::new(model.clone());
    serial.set_threads(1);
    assert_eq!(serial.solve(), Status::Optimal);
    assert_kkt(&mut serial);

    for threads in [2usize, 4, 7] {
        let mut par = SimplexSolver::new(model.clone());
        par.set_threads(threads);
        assert_eq!(par.solve(), Status::Optimal);
        assert_eq!(
            (serial.stats.primal_iters, serial.stats.dual_iters),
            (par.stats.primal_iters, par.stats.dual_iters),
            "pivot trajectory differs at {threads} threads"
        );
        assert_eq!(serial.objective(), par.objective(), "objective differs at {threads} threads");
        assert_eq!(serial.col_values(), par.col_values(), "solution differs at {threads} threads");
    }
}
