//! Incremental LP model: columns with bounds and costs, ranged rows.
//!
//! Rows and columns can be appended at any time; the solver layers basis
//! bookkeeping on top so additions warm-start (see `solver.rs`).

/// Index of a structural variable.
pub type VarId = usize;
/// Index of a row (constraint).
pub type RowId = usize;

use crate::linalg::fmadd;

/// Sparse structural column: coefficient entries by row.
#[derive(Clone, Debug, Default)]
pub(crate) struct Column {
    pub rows: Vec<RowId>,
    pub vals: Vec<f64>,
}

impl Column {
    /// Gather dot `colᵀy` with four independent accumulators — the
    /// indexed loads cannot autovectorize, but splitting the FP
    /// dependency chain still roughly doubles throughput on the long
    /// columns the dense pricing row `α = Aᵀρ` scans. The reduction
    /// order is fixed by the entry order alone, so serial and chunked
    /// parallel pricing (which both call this per column) agree bitwise.
    pub fn dot_dense(&self, y: &[f64]) -> f64 {
        let n = self.rows.len();
        let chunks = n / 4;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        for k in 0..chunks {
            let i = 4 * k;
            s0 = fmadd(self.vals[i], y[self.rows[i]], s0);
            s1 = fmadd(self.vals[i + 1], y[self.rows[i + 1]], s1);
            s2 = fmadd(self.vals[i + 2], y[self.rows[i + 2]], s2);
            s3 = fmadd(self.vals[i + 3], y[self.rows[i + 3]], s3);
        }
        let mut s = (s0 + s1) + (s2 + s3);
        for i in 4 * chunks..n {
            s = fmadd(self.vals[i], y[self.rows[i]], s);
        }
        s
    }

    /// Fused double dot: `(colᵀa, colᵀb)` in one pass over the entries —
    /// the dual-simplex pricing loop needs both `α_j = colᵀρ` and the
    /// reduced cost `c_j − colᵀy`, and fusing them halves the traffic
    /// over the column data (see EXPERIMENTS.md §Perf). Two accumulators
    /// per output, same fixed reduction order as [`Column::dot_dense`].
    #[inline]
    pub fn dot2_dense(&self, a: &[f64], b: &[f64]) -> (f64, f64) {
        let n = self.rows.len();
        let chunks = n / 2;
        let (mut sa0, mut sa1, mut sb0, mut sb1) = (0.0, 0.0, 0.0, 0.0);
        for k in 0..chunks {
            let i = 2 * k;
            let (r0, v0) = (self.rows[i], self.vals[i]);
            let (r1, v1) = (self.rows[i + 1], self.vals[i + 1]);
            sa0 = fmadd(v0, a[r0], sa0);
            sa1 = fmadd(v1, a[r1], sa1);
            sb0 = fmadd(v0, b[r0], sb0);
            sb1 = fmadd(v1, b[r1], sb1);
        }
        let mut sa = sa0 + sa1;
        let mut sb = sb0 + sb1;
        if n % 2 == 1 {
            let (r, v) = (self.rows[n - 1], self.vals[n - 1]);
            sa = fmadd(v, a[r], sa);
            sb = fmadd(v, b[r], sb);
        }
        (sa, sb)
    }
}

/// An LP: `min cᵀx` s.t. `row_lo ≤ Ax ≤ row_hi`, `lb ≤ x ≤ ub`.
///
/// Use `f64::INFINITY` / `NEG_INFINITY` for absent bounds; `row_lo ==
/// row_hi` makes an equality row.
#[derive(Clone, Debug, Default)]
pub struct LpModel {
    pub(crate) cost: Vec<f64>,
    pub(crate) lb: Vec<f64>,
    pub(crate) ub: Vec<f64>,
    pub(crate) cols: Vec<Column>,
    pub(crate) row_lo: Vec<f64>,
    pub(crate) row_hi: Vec<f64>,
    /// Row-wise view of the structural matrix (kept in sync with `cols`);
    /// needed by the dual simplex pricing row and row additions.
    pub(crate) rows: Vec<Vec<(VarId, f64)>>,
}

impl LpModel {
    /// Empty model.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of structural variables.
    pub fn num_vars(&self) -> usize {
        self.cost.len()
    }

    /// Number of rows.
    pub fn num_rows(&self) -> usize {
        self.row_lo.len()
    }

    /// Add a row `lo ≤ Σ coef·x ≤ hi` over *existing* variables.
    pub fn add_row(&mut self, lo: f64, hi: f64, coefs: &[(VarId, f64)]) -> RowId {
        assert!(lo <= hi, "row bounds crossed");
        let r = self.row_lo.len();
        self.row_lo.push(lo);
        self.row_hi.push(hi);
        let mut row = Vec::with_capacity(coefs.len());
        for &(j, v) in coefs {
            assert!(j < self.num_vars(), "row references unknown variable");
            if v != 0.0 {
                self.cols[j].rows.push(r);
                self.cols[j].vals.push(v);
                row.push((j, v));
            }
        }
        self.rows.push(row);
        r
    }

    /// Add a variable with cost, bounds and coefficients in *existing* rows.
    pub fn add_col(&mut self, cost: f64, lb: f64, ub: f64, coefs: &[(RowId, f64)]) -> VarId {
        assert!(lb <= ub, "column bounds crossed");
        let j = self.cost.len();
        self.cost.push(cost);
        self.lb.push(lb);
        self.ub.push(ub);
        let mut col = Column::default();
        for &(r, v) in coefs {
            assert!(r < self.num_rows(), "column references unknown row");
            if v != 0.0 {
                col.rows.push(r);
                col.vals.push(v);
                self.rows[r].push((j, v));
            }
        }
        self.cols.push(col);
        j
    }

    /// Convenience: `Σ coef·x ≥ lo`.
    pub fn add_row_ge(&mut self, lo: f64, coefs: &[(VarId, f64)]) -> RowId {
        self.add_row(lo, f64::INFINITY, coefs)
    }

    /// Convenience: `Σ coef·x ≤ hi`.
    pub fn add_row_le(&mut self, hi: f64, coefs: &[(VarId, f64)]) -> RowId {
        self.add_row(f64::NEG_INFINITY, hi, coefs)
    }

    /// Convenience: equality row.
    pub fn add_row_eq(&mut self, b: f64, coefs: &[(VarId, f64)]) -> RowId {
        self.add_row(b, b, coefs)
    }

    /// Convenience: nonnegative variable.
    pub fn add_col_nonneg(&mut self, cost: f64, coefs: &[(RowId, f64)]) -> VarId {
        self.add_col(cost, 0.0, f64::INFINITY, coefs)
    }

    /// Convenience: free variable.
    pub fn add_col_free(&mut self, cost: f64, coefs: &[(RowId, f64)]) -> VarId {
        self.add_col(cost, f64::NEG_INFINITY, f64::INFINITY, coefs)
    }

    /// Objective value of a given structural point (no feasibility check).
    pub fn objective_of(&self, x: &[f64]) -> f64 {
        self.cost.iter().zip(x).map(|(c, v)| c * v).sum()
    }

    /// Row activities `Ax` of a structural point.
    pub fn activities_of(&self, x: &[f64]) -> Vec<f64> {
        let mut act = vec![0.0; self.num_rows()];
        for (j, col) in self.cols.iter().enumerate() {
            if x[j] != 0.0 {
                for (r, v) in col.rows.iter().zip(&col.vals) {
                    act[*r] += v * x[j];
                }
            }
        }
        act
    }

    /// Max primal violation of a structural point (bounds + rows).
    pub fn infeasibility_of(&self, x: &[f64]) -> f64 {
        let mut viol = 0.0f64;
        for j in 0..self.num_vars() {
            viol = viol.max(self.lb[j] - x[j]).max(x[j] - self.ub[j]);
        }
        for (r, a) in self.activities_of(x).into_iter().enumerate() {
            viol = viol.max(self.row_lo[r] - a).max(a - self.row_hi[r]);
        }
        viol.max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_query() {
        let mut m = LpModel::new();
        let x = m.add_col_nonneg(1.0, &[]);
        let y = m.add_col(2.0, -1.0, 5.0, &[]);
        let r = m.add_row_ge(1.0, &[(x, 1.0), (y, 2.0)]);
        assert_eq!(m.num_vars(), 2);
        assert_eq!(m.num_rows(), 1);
        assert_eq!(m.rows[r], vec![(x, 1.0), (y, 2.0)]);
        assert_eq!(m.cols[x].rows, vec![r]);
        // add a column touching the existing row
        let z = m.add_col_nonneg(0.5, &[(r, -1.0)]);
        assert_eq!(m.rows[r].len(), 3);
        assert_eq!(m.cols[z].vals, vec![-1.0]);
    }

    #[test]
    fn objective_activity_infeasibility() {
        let mut m = LpModel::new();
        let x = m.add_col_nonneg(1.0, &[]);
        let y = m.add_col_nonneg(1.0, &[]);
        m.add_row_ge(2.0, &[(x, 1.0), (y, 1.0)]);
        assert_eq!(m.objective_of(&[1.0, 2.0]), 3.0);
        assert_eq!(m.activities_of(&[1.0, 2.0]), vec![3.0]);
        assert_eq!(m.infeasibility_of(&[1.0, 2.0]), 0.0);
        assert_eq!(m.infeasibility_of(&[0.5, 0.5]), 1.0);
        assert_eq!(m.infeasibility_of(&[-1.0, 3.0]), 1.0);
    }

    #[test]
    fn zero_coefficients_dropped() {
        let mut m = LpModel::new();
        let x = m.add_col_nonneg(1.0, &[]);
        let r = m.add_row_ge(0.0, &[(x, 0.0)]);
        assert!(m.rows[r].is_empty());
        assert!(m.cols[x].rows.is_empty());
    }
}
