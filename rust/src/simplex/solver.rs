//! The revised simplex solver: primal and dual iterations over a shared
//! basis, with incremental column/row additions that preserve warm starts.
//!
//! See the module-level docs in `mod.rs` for the computational form and
//! the warm-start invariants (columns → primal feasible; rows → dual
//! feasible).

use super::basis::Basis;
use super::model::{LpModel, RowId, VarId};
use super::Tolerances;

/// A basis member: a structural column or a row's logical variable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum BVar {
    /// Structural variable `j`.
    Col(usize),
    /// Logical (slack) of row `r`; its column in `Â` is `−e_r`.
    Log(usize),
}

/// Where a variable currently sits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VarStatus {
    /// In the basis, at position `.0` (row of the basis system).
    Basic(usize),
    /// Nonbasic at its lower bound.
    AtLower,
    /// Nonbasic at its upper bound.
    AtUpper,
    /// Nonbasic free variable pinned at zero.
    FreeZero,
}

/// Result of a `solve` call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// KKT-optimal within tolerances.
    Optimal,
    /// Objective unbounded below.
    Unbounded,
    /// Primal infeasible (detected by the dual simplex).
    Infeasible,
    /// Iteration limit hit.
    IterLimit,
}

/// Counters from the last `solve`.
#[derive(Clone, Copy, Debug, Default)]
pub struct SolveStats {
    /// Primal simplex iterations performed.
    pub primal_iters: usize,
    /// Dual simplex iterations performed.
    pub dual_iters: usize,
    /// Basis refactorizations.
    pub refactors: usize,
}

/// Bounded-variable revised simplex with warm starting.
pub struct SimplexSolver {
    pub(crate) model: LpModel,
    tol: Tolerances,
    /// Status of structural variables.
    col_status: Vec<VarStatus>,
    /// Status of logical variables (one per row).
    row_status: Vec<VarStatus>,
    /// Basis members by position.
    basis_vars: Vec<BVar>,
    /// Values of basic variables by position.
    x_basic: Vec<f64>,
    /// Factorized basis (None until first solve / after structural reset).
    factor: Option<Basis>,
    /// Dual prices y (valid after solve).
    duals: Vec<f64>,
    /// Running stats (cumulative across solves).
    pub stats: SolveStats,
    /// Bland's-rule mode (anti-cycling), switched on after stalls.
    bland: bool,
    /// Consecutive degenerate iterations (stall detector).
    stall: usize,
    /// Worker threads for the dense dual-simplex pricing row (1 = serial).
    threads: usize,
}

/// Below this many structural columns the parallel pricing row is not
/// worth the thread-spawn overhead (a few µs per scoped worker vs
/// sub-µs column dots); the serial path is used regardless of
/// [`SimplexSolver::set_threads`].
const PAR_PRICE_MIN_COLS: usize = 256;

const INF: f64 = f64::INFINITY;

impl SimplexSolver {
    /// Wrap a model; nothing is factorized until the first `solve`.
    pub fn new(model: LpModel) -> Self {
        let nv = model.num_vars();
        let m = model.num_rows();
        let mut s = Self {
            model,
            tol: Tolerances::default(),
            col_status: Vec::new(),
            row_status: Vec::new(),
            basis_vars: Vec::new(),
            x_basic: Vec::new(),
            factor: None,
            duals: vec![0.0; m],
            stats: SolveStats::default(),
            bland: false,
            stall: 0,
            threads: 1,
        };
        s.sync_new_cols(nv);
        s.sync_new_rows(m);
        s
    }

    /// Override tolerances.
    pub fn with_tolerances(mut self, tol: Tolerances) -> Self {
        self.tol = tol;
        self
    }

    /// Worker threads for the dense dual-simplex pricing row (clamped to
    /// ≥ 1). Pricing results — and therefore pivots, iteration counts and
    /// solutions — are bit-identical at any thread count: each column's
    /// dot `α_j = a_jᵀρ` is computed by exactly one worker with the same
    /// accumulation order as the serial loop.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    /// Immutable model access.
    pub fn model(&self) -> &LpModel {
        &self.model
    }

    // ------------------------------------------------------------------
    // Incremental model edits (warm-start preserving)
    // ------------------------------------------------------------------

    /// Add a column; the basis is untouched, the new variable starts
    /// nonbasic (at lower bound when finite, else at upper, else free-0),
    /// so primal feasibility of the current basis is preserved.
    pub fn add_col(&mut self, cost: f64, lb: f64, ub: f64, coefs: &[(RowId, f64)]) -> VarId {
        let j = self.model.add_col(cost, lb, ub, coefs);
        self.sync_new_cols(j + 1);
        j
    }

    /// Add a row; its logical enters the basis (keeping the old duals and
    /// hence dual feasibility intact — the new dual price is exactly 0),
    /// so the next `solve` warm-starts with the dual simplex.
    pub fn add_row(&mut self, lo: f64, hi: f64, coefs: &[(VarId, f64)]) -> RowId {
        let r = self.model.add_row(lo, hi, coefs);
        self.sync_new_rows(r + 1);
        r
    }

    fn sync_new_cols(&mut self, upto: usize) {
        while self.col_status.len() < upto {
            let j = self.col_status.len();
            let (lb, ub) = (self.model.lb[j], self.model.ub[j]);
            let st = if lb.is_finite() {
                VarStatus::AtLower
            } else if ub.is_finite() {
                VarStatus::AtUpper
            } else {
                VarStatus::FreeZero
            };
            self.col_status.push(st);
        }
    }

    fn sync_new_rows(&mut self, upto: usize) {
        while self.row_status.len() < upto {
            let r = self.row_status.len();
            let pos = self.basis_vars.len();
            self.basis_vars.push(BVar::Log(r));
            self.row_status.push(VarStatus::Basic(pos));
            self.x_basic.push(0.0); // recomputed on refactorize
            self.duals.push(0.0);
            self.factor = None; // dimensions changed → refactorize lazily
        }
    }

    // ------------------------------------------------------------------
    // Variable metadata helpers
    // ------------------------------------------------------------------

    fn bounds_of(&self, v: BVar) -> (f64, f64) {
        match v {
            BVar::Col(j) => (self.model.lb[j], self.model.ub[j]),
            BVar::Log(r) => (self.model.row_lo[r], self.model.row_hi[r]),
        }
    }

    fn cost_of(&self, v: BVar) -> f64 {
        match v {
            BVar::Col(j) => self.model.cost[j],
            BVar::Log(_) => 0.0,
        }
    }

    fn status_of(&self, v: BVar) -> VarStatus {
        match v {
            BVar::Col(j) => self.col_status[j],
            BVar::Log(r) => self.row_status[r],
        }
    }

    fn set_status(&mut self, v: BVar, st: VarStatus) {
        match v {
            BVar::Col(j) => self.col_status[j] = st,
            BVar::Log(r) => self.row_status[r] = st,
        }
    }

    /// Current value of any variable.
    fn value_of(&self, v: BVar) -> f64 {
        match self.status_of(v) {
            VarStatus::Basic(pos) => self.x_basic[pos],
            VarStatus::AtLower => self.bounds_of(v).0,
            VarStatus::AtUpper => self.bounds_of(v).1,
            VarStatus::FreeZero => 0.0,
        }
    }

    /// Dense column of `Â` for variable `v` (length m).
    fn dense_column(&self, v: BVar, out: &mut [f64]) {
        out.fill(0.0);
        match v {
            BVar::Col(j) => {
                let col = &self.model.cols[j];
                for (r, val) in col.rows.iter().zip(&col.vals) {
                    out[*r] = *val;
                }
            }
            BVar::Log(r) => out[r] = -1.0,
        }
    }

    // ------------------------------------------------------------------
    // Basis maintenance
    // ------------------------------------------------------------------

    fn refactorize(&mut self) {
        let m = self.model.num_rows();
        debug_assert_eq!(self.basis_vars.len(), m);
        let mut cols = Vec::with_capacity(m);
        let mut buf = vec![0.0; m];
        for &v in &self.basis_vars {
            self.dense_column(v, &mut buf);
            cols.push(buf.clone());
        }
        let factor = Basis::factorize(&cols);
        if factor.is_singular() {
            // Repair: replace dependent basic columns with their row logicals.
            self.repair_basis();
            return;
        }
        self.factor = Some(factor);
        self.stats.refactors += 1;
        self.recompute_x_basic();
    }

    /// Fall back to a crash basis keeping as many current basics as
    /// possible; used only when a singular basis sneaks in numerically.
    fn repair_basis(&mut self) {
        let m = self.model.num_rows();
        // Reset everything nonbasic, then re-seat the all-logical basis.
        for j in 0..self.model.num_vars() {
            if matches!(self.col_status[j], VarStatus::Basic(_)) {
                let (lb, ub) = (self.model.lb[j], self.model.ub[j]);
                self.col_status[j] = if lb.is_finite() {
                    VarStatus::AtLower
                } else if ub.is_finite() {
                    VarStatus::AtUpper
                } else {
                    VarStatus::FreeZero
                };
            }
        }
        self.basis_vars = (0..m).map(BVar::Log).collect();
        for r in 0..m {
            self.row_status[r] = VarStatus::Basic(r);
        }
        self.x_basic = vec![0.0; m];
        let mut cols = Vec::with_capacity(m);
        let mut buf = vec![0.0; m];
        for &v in &self.basis_vars.clone() {
            self.dense_column(v, &mut buf);
            cols.push(buf.clone());
        }
        self.factor = Some(Basis::factorize(&cols));
        self.stats.refactors += 1;
        self.recompute_x_basic();
    }

    /// `x_B = B⁻¹ (0 − N x_N)` from scratch.
    fn recompute_x_basic(&mut self) {
        let m = self.model.num_rows();
        let mut rhs = vec![0.0; m];
        // Structural nonbasic contributions.
        for j in 0..self.model.num_vars() {
            let st = self.col_status[j];
            let val = match st {
                VarStatus::Basic(_) => continue,
                VarStatus::AtLower => self.model.lb[j],
                VarStatus::AtUpper => self.model.ub[j],
                VarStatus::FreeZero => 0.0,
            };
            if val != 0.0 {
                let col = &self.model.cols[j];
                for (r, v) in col.rows.iter().zip(&col.vals) {
                    rhs[*r] -= v * val;
                }
            }
        }
        // Logical nonbasic contributions (column −e_r).
        for r in 0..m {
            let val = match self.row_status[r] {
                VarStatus::Basic(_) => continue,
                VarStatus::AtLower => self.model.row_lo[r],
                VarStatus::AtUpper => self.model.row_hi[r],
                VarStatus::FreeZero => 0.0,
            };
            rhs[r] += val;
        }
        self.factor.as_ref().expect("factorized").ftran(&mut rhs);
        self.x_basic = rhs;
    }

    /// Dual prices `y = B⁻ᵀ c_B`.
    fn compute_duals(&mut self) {
        let m = self.model.num_rows();
        let mut y = vec![0.0; m];
        for (pos, &v) in self.basis_vars.iter().enumerate() {
            y[pos] = self.cost_of(v);
        }
        self.factor.as_ref().expect("factorized").btran(&mut y);
        self.duals = y;
    }

    /// Reduced cost of a variable given current duals.
    fn reduced_cost_of(&self, v: BVar) -> f64 {
        match v {
            BVar::Col(j) => self.model.cost[j] - self.model.cols[j].dot_dense(&self.duals),
            BVar::Log(r) => self.duals[r],
        }
    }

    fn ensure_factorized(&mut self) {
        if self.factor.is_none()
            || self.factor.as_ref().unwrap().m() != self.model.num_rows()
        {
            self.refactorize();
        }
    }

    // ------------------------------------------------------------------
    // Feasibility measures
    // ------------------------------------------------------------------

    /// Max violation of basic-variable bounds.
    pub fn primal_infeasibility(&self) -> f64 {
        let mut worst = 0.0f64;
        for (pos, &v) in self.basis_vars.iter().enumerate() {
            let (lb, ub) = self.bounds_of(v);
            let x = self.x_basic[pos];
            worst = worst.max(lb - x).max(x - ub);
        }
        worst.max(0.0)
    }

    /// Max reduced-cost sign violation over nonbasic variables.
    pub fn dual_infeasibility(&mut self) -> f64 {
        self.compute_duals();
        let mut worst = 0.0f64;
        let all = self.iter_all_vars();
        for v in all {
            let st = self.status_of(v);
            let d = self.reduced_cost_of(v);
            let (lb, ub) = self.bounds_of(v);
            match st {
                VarStatus::Basic(_) => {}
                VarStatus::AtLower => {
                    // may increase ⇒ need d ≥ 0, unless it could also
                    // decrease (lb == ub handled as fixed: any d fine)
                    if lb < ub {
                        worst = worst.max(-d);
                    }
                }
                VarStatus::AtUpper => {
                    if lb < ub {
                        worst = worst.max(d);
                    }
                }
                VarStatus::FreeZero => worst = worst.max(d.abs()),
            }
        }
        worst
    }

    fn iter_all_vars(&self) -> Vec<BVar> {
        let mut v: Vec<BVar> = (0..self.model.num_vars()).map(BVar::Col).collect();
        v.extend((0..self.model.num_rows()).map(BVar::Log));
        v
    }

    // ------------------------------------------------------------------
    // Solve dispatch
    // ------------------------------------------------------------------

    /// Optimize from the current basis. Chooses the primal or dual simplex
    /// from the warm-start state automatically.
    pub fn solve(&mut self) -> Status {
        if self.model.num_rows() == 0 {
            return self.solve_unconstrained();
        }
        self.ensure_factorized();
        self.recompute_x_basic();
        self.bland = false;
        self.stall = 0;

        let pinf = self.primal_infeasibility();
        if pinf <= self.tol.feas {
            return self.primal_simplex();
        }
        let dinf = self.dual_infeasibility();
        if dinf <= self.tol.opt {
            let st = self.dual_simplex();
            if st != Status::Optimal {
                return st;
            }
            // Clean up any residual dual infeasibility (tolerance drift).
            return self.primal_simplex();
        }
        // Neither feasible: reset to the all-logical crash basis, which is
        // dual feasible whenever every cost is ≥ 0 (all LPs in this
        // library) or the offending variables have finite opposite bounds.
        self.crash_basis();
        let dinf = self.dual_infeasibility();
        if dinf <= self.tol.opt {
            let st = self.dual_simplex();
            if st != Status::Optimal {
                return st;
            }
            return self.primal_simplex();
        }
        // Generic phase-1 is out of scope (never reached by this library's
        // models); fail loudly rather than silently.
        panic!(
            "SimplexSolver: cold start is neither primal nor dual feasible \
             (a structural cost is negative with an infinite opposite bound); \
             generic phase-1 is not implemented"
        );
    }

    fn solve_unconstrained(&mut self) -> Status {
        for j in 0..self.model.num_vars() {
            let c = self.model.cost[j];
            let (lb, ub) = (self.model.lb[j], self.model.ub[j]);
            let st = if c > 0.0 {
                if !lb.is_finite() {
                    return Status::Unbounded;
                }
                VarStatus::AtLower
            } else if c < 0.0 {
                if !ub.is_finite() {
                    return Status::Unbounded;
                }
                VarStatus::AtUpper
            } else if lb.is_finite() {
                VarStatus::AtLower
            } else if ub.is_finite() {
                VarStatus::AtUpper
            } else {
                VarStatus::FreeZero
            };
            self.col_status[j] = st;
        }
        Status::Optimal
    }

    fn crash_basis(&mut self) {
        let m = self.model.num_rows();
        for j in 0..self.model.num_vars() {
            let c = self.model.cost[j];
            let (lb, ub) = (self.model.lb[j], self.model.ub[j]);
            self.col_status[j] = if c >= 0.0 {
                if lb.is_finite() {
                    VarStatus::AtLower
                } else if c == 0.0 {
                    if ub.is_finite() { VarStatus::AtUpper } else { VarStatus::FreeZero }
                } else if ub.is_finite() {
                    VarStatus::AtUpper
                } else {
                    VarStatus::FreeZero // dual-infeasible; caught by caller
                }
            } else if ub.is_finite() {
                VarStatus::AtUpper
            } else {
                VarStatus::FreeZero // dual-infeasible; caught by caller
            };
        }
        self.basis_vars = (0..m).map(BVar::Log).collect();
        for r in 0..m {
            self.row_status[r] = VarStatus::Basic(r);
        }
        self.x_basic = vec![0.0; m];
        self.refactorize();
    }

    // ------------------------------------------------------------------
    // Primal simplex
    // ------------------------------------------------------------------

    fn primal_simplex(&mut self) -> Status {
        let m = self.model.num_rows();
        let mut w = vec![0.0; m];
        for _iter in 0..self.tol.max_iters {
            if self.factor.as_ref().unwrap().num_etas() >= self.tol.refactor_every {
                self.refactorize();
            }
            self.stats.primal_iters += 1;
            self.compute_duals();

            // --- pricing: entering variable ---
            let mut entering: Option<(BVar, f64, f64)> = None; // (var, d, score)
            let nv = self.model.num_vars();
            let consider = |this: &Self,
                            v: BVar,
                            entering: &mut Option<(BVar, f64, f64)>| {
                let st = this.status_of(v);
                let (lb, ub) = this.bounds_of(v);
                if lb == ub {
                    return; // fixed
                }
                let d = this.reduced_cost_of(v);
                let score = match st {
                    VarStatus::Basic(_) => return,
                    VarStatus::AtLower => -d,
                    VarStatus::AtUpper => d,
                    VarStatus::FreeZero => d.abs(),
                };
                if score > this.tol.opt {
                    if this.bland {
                        if entering.is_none() {
                            *entering = Some((v, d, score));
                        }
                    } else if entering.map_or(true, |(_, _, s)| score > s) {
                        *entering = Some((v, d, score));
                    }
                }
            };
            for j in 0..nv {
                consider(self, BVar::Col(j), &mut entering);
            }
            for r in 0..m {
                consider(self, BVar::Log(r), &mut entering);
            }
            let Some((q, d_q, _)) = entering else {
                return Status::Optimal;
            };

            // --- direction and FTRAN ---
            let sigma = match self.status_of(q) {
                VarStatus::AtUpper => -1.0,
                VarStatus::FreeZero => {
                    if d_q < 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                }
                _ => 1.0,
            };
            self.dense_column(q, &mut w);
            self.factor.as_ref().unwrap().ftran(&mut w);

            // --- bounded ratio test ---
            let (lb_q, ub_q) = self.bounds_of(q);
            let mut t_best = if lb_q.is_finite() && ub_q.is_finite() {
                ub_q - lb_q // bound flip distance
            } else {
                INF
            };
            let mut leaving: Option<(usize, bool)> = None; // (pos, hit_lower)
            for (k, &wk) in w.iter().enumerate() {
                if wk.abs() < self.tol.pivot {
                    continue;
                }
                let delta = sigma * wk;
                let bv = self.basis_vars[k];
                let (lbk, ubk) = self.bounds_of(bv);
                let xk = self.x_basic[k];
                let (t, hit_lower) = if delta > 0.0 {
                    if !lbk.is_finite() {
                        continue;
                    }
                    (((xk - lbk) / delta).max(0.0), true)
                } else {
                    if !ubk.is_finite() {
                        continue;
                    }
                    (((xk - ubk) / delta).max(0.0), false)
                };
                let better = if self.bland {
                    t < t_best - 1e-12
                        || (t < t_best + 1e-12 && leaving.is_none())
                } else {
                    t < t_best - 1e-9
                        || (t < t_best + 1e-9
                            && leaving.map_or(t < t_best, |(kb, _)| {
                                wk.abs() > w[kb].abs()
                            }))
                };
                if better {
                    t_best = t;
                    leaving = Some((k, hit_lower));
                }
            }

            if !t_best.is_finite() {
                return Status::Unbounded;
            }

            // stall detection → Bland's rule
            if t_best < 1e-11 {
                self.stall += 1;
                if self.stall > 500 + 10 * m {
                    self.bland = true;
                }
            } else {
                self.stall = 0;
            }

            match leaving {
                None => {
                    // Bound flip: q jumps to its opposite bound.
                    let t = t_best;
                    for (k, &wk) in w.iter().enumerate() {
                        self.x_basic[k] -= sigma * wk * t;
                    }
                    let new_st = if sigma > 0.0 { VarStatus::AtUpper } else { VarStatus::AtLower };
                    self.set_status(q, new_st);
                }
                Some((r, hit_lower)) => {
                    if !self.factor.as_mut().unwrap().push_eta(r, &w) {
                        // numerically bad pivot → refactorize & retry
                        self.refactorize();
                        continue;
                    }
                    let t = t_best;
                    let v_q = self.value_of(q);
                    for (k, &wk) in w.iter().enumerate() {
                        self.x_basic[k] -= sigma * wk * t;
                    }
                    let leaving_var = self.basis_vars[r];
                    let (lbl, ubl) = self.bounds_of(leaving_var);
                    self.set_status(
                        leaving_var,
                        if hit_lower {
                            debug_assert!(lbl.is_finite());
                            VarStatus::AtLower
                        } else {
                            debug_assert!(ubl.is_finite());
                            VarStatus::AtUpper
                        },
                    );
                    self.basis_vars[r] = q;
                    self.x_basic[r] = v_q + sigma * t;
                    self.set_status(q, VarStatus::Basic(r));
                }
            }
        }
        Status::IterLimit
    }

    // ------------------------------------------------------------------
    // Dual simplex
    // ------------------------------------------------------------------

    fn dual_simplex(&mut self) -> Status {
        let m = self.model.num_rows();
        let nv = self.model.num_vars();
        let mut rho = vec![0.0; m];
        let mut w = vec![0.0; m];
        // Incrementally-maintained reduced costs (the textbook dual
        // update d ← d − θ·α after each pivot): saves one BTRAN and one
        // column pass per iteration vs recomputing from duals — the
        // pricing loop dominated the profile (EXPERIMENTS.md §Perf).
        let mut d_struct = vec![0.0; nv];
        let mut d_log = vec![0.0; m];
        let mut alpha_struct = vec![0.0; nv];
        let mut alpha_log = vec![0.0; m];
        self.refresh_reduced_costs(&mut d_struct, &mut d_log);
        for _iter in 0..self.tol.max_iters {
            if self.factor.as_ref().unwrap().num_etas() >= self.tol.refactor_every {
                self.refactorize();
                self.refresh_reduced_costs(&mut d_struct, &mut d_log);
            }
            self.stats.dual_iters += 1;

            // --- leaving: most infeasible basic variable ---
            let mut leaving: Option<(usize, f64, bool)> = None; // (pos, viol, below_lb)
            for (pos, &v) in self.basis_vars.iter().enumerate() {
                let (lb, ub) = self.bounds_of(v);
                let x = self.x_basic[pos];
                let below = lb - x;
                let above = x - ub;
                let (viol, is_below) = if below >= above { (below, true) } else { (above, false) };
                if viol > self.tol.feas
                    && leaving.map_or(true, |(_, bv, _)| viol > bv)
                {
                    leaving = Some((pos, viol, is_below));
                }
            }
            let Some((r, _, below_lb)) = leaving else {
                return Status::Optimal;
            };

            // --- pricing row ρ = B⁻ᵀ e_r, α_j = ρᵀ â_j ---
            rho.fill(0.0);
            rho[r] = 1.0;
            self.factor.as_ref().unwrap().btran(&mut rho);

            // admissibility by leaving direction:
            //   x_r below lb ⇒ x_r must increase; dx_r/dx_q = −α_q
            //   at-lower q (Δ>0) needs α_q<0; at-upper q (Δ<0) needs α_q>0
            //   (signs mirror when x_r is above ub)
            let need_neg_alpha_for_lower = below_lb;
            let mut best: Option<(BVar, f64, f64)> = None; // (var, alpha, ratio)
            let consider = |this: &Self,
                            v: BVar,
                            st: VarStatus,
                            alpha: f64,
                            d: f64,
                            best: &mut Option<(BVar, f64, f64)>| {
                let admissible = match st {
                    VarStatus::Basic(_) => false,
                    VarStatus::AtLower => {
                        if need_neg_alpha_for_lower { alpha < -this.tol.pivot } else { alpha > this.tol.pivot }
                    }
                    VarStatus::AtUpper => {
                        if need_neg_alpha_for_lower { alpha > this.tol.pivot } else { alpha < -this.tol.pivot }
                    }
                    VarStatus::FreeZero => alpha.abs() > this.tol.pivot,
                };
                if !admissible {
                    return;
                }
                let ratio = (d / alpha).abs();
                let better = if this.bland {
                    best.is_none()
                } else {
                    match best {
                        None => true,
                        Some((_, ba, br)) => {
                            ratio < *br - 1e-10
                                || (ratio < *br + 1e-10 && alpha.abs() > ba.abs())
                        }
                    }
                };
                if better {
                    *best = Some((v, alpha, ratio));
                }
            };
            // Structural columns: the dense pricing row α = Aᵀρ is the
            // dual simplex's hot pass — filled (in parallel when
            // `set_threads` > 1) into `alpha_struct`, then scanned
            // serially for the ratio test so tie-breaking stays
            // deterministic; reduced costs come from the incremental
            // cache.
            self.price_dual_row(&rho, &mut alpha_struct);
            for (j, &alpha) in alpha_struct.iter().enumerate() {
                let st = self.col_status[j];
                if matches!(st, VarStatus::Basic(_)) || self.model.lb[j] == self.model.ub[j] {
                    continue;
                }
                consider(self, BVar::Col(j), st, alpha, d_struct[j], &mut best);
            }
            for rr in 0..m {
                let st = self.row_status[rr];
                if matches!(st, VarStatus::Basic(_))
                    || self.model.row_lo[rr] == self.model.row_hi[rr]
                {
                    alpha_log[rr] = 0.0;
                    continue;
                }
                let alpha = -rho[rr];
                alpha_log[rr] = alpha;
                consider(self, BVar::Log(rr), st, alpha, d_log[rr], &mut best);
            }
            let Some((q, alpha_q, ratio)) = best else {
                return Status::Infeasible;
            };

            if ratio < 1e-11 {
                self.stall += 1;
                if self.stall > 500 + 10 * m {
                    self.bland = true;
                }
            } else {
                self.stall = 0;
            }

            // --- FTRAN of entering column; consistency check ---
            self.dense_column(q, &mut w);
            self.factor.as_ref().unwrap().ftran(&mut w);
            if (w[r] - alpha_q).abs() > 1e-6 * (1.0 + alpha_q.abs()) {
                self.refactorize();
                continue;
            }
            if !self.factor.as_mut().unwrap().push_eta(r, &w) {
                self.refactorize();
                continue;
            }

            // --- pivot: drive x_r to its violated bound ---
            let leaving_var = self.basis_vars[r];
            let (lbl, ubl) = self.bounds_of(leaving_var);
            let target = if below_lb { lbl } else { ubl };
            let x_r = self.x_basic[r];
            let dxq = (x_r - target) / alpha_q;
            let v_q = self.value_of(q);
            for (k, &wk) in w.iter().enumerate() {
                self.x_basic[k] -= dxq * wk;
            }
            self.set_status(
                leaving_var,
                if below_lb { VarStatus::AtLower } else { VarStatus::AtUpper },
            );
            self.basis_vars[r] = q;
            self.x_basic[r] = v_q + dxq;
            self.set_status(q, VarStatus::Basic(r));

            // --- incremental dual update: d ← d − θ·α (θ = d_q/α_q) ---
            let theta = match q {
                BVar::Col(j) => d_struct[j],
                BVar::Log(rr) => d_log[rr],
            } / alpha_q;
            if theta != 0.0 {
                for j in 0..nv {
                    let a = alpha_struct[j];
                    if a != 0.0 {
                        d_struct[j] -= theta * a;
                    }
                }
                for rr in 0..m {
                    let a = alpha_log[rr];
                    if a != 0.0 {
                        d_log[rr] -= theta * a;
                    }
                }
            }
            // entering variable is now basic (d = 0); leaving var takes −θ
            match q {
                BVar::Col(j) => d_struct[j] = 0.0,
                BVar::Log(rr) => d_log[rr] = 0.0,
            }
            match leaving_var {
                BVar::Col(j) => d_struct[j] = -theta,
                BVar::Log(rr) => d_log[rr] = -theta,
            }
        }
        Status::IterLimit
    }

    /// Fill `alpha[j] = a_jᵀρ` for every structural column eligible to
    /// enter (0.0 for basic or fixed columns), with each dot running the
    /// register-tiled gather kernel of `Column::dot_dense` and the column
    /// range chunked across `std::thread::scope` workers when
    /// [`SimplexSolver::set_threads`]
    /// is above 1 and the model clears [`PAR_PRICE_MIN_COLS`] — the same
    /// chunked-range pattern `engine::BackendPricer` uses for `Xᵀv`. Each
    /// α_j is produced by exactly one worker with the serial accumulation
    /// order, so the pricing row is bit-identical at any thread count.
    fn price_dual_row(&self, rho: &[f64], alpha: &mut [f64]) {
        let nv = alpha.len();
        let fill = |j0: usize, out: &mut [f64]| {
            for (k, a) in out.iter_mut().enumerate() {
                let j = j0 + k;
                *a = if matches!(self.col_status[j], VarStatus::Basic(_))
                    || self.model.lb[j] == self.model.ub[j]
                {
                    0.0
                } else {
                    self.model.cols[j].dot_dense(rho)
                };
            }
        };
        let t = self.threads.min(nv);
        if t <= 1 || nv < PAR_PRICE_MIN_COLS {
            fill(0, alpha);
            return;
        }
        let chunk = nv.div_ceil(t);
        std::thread::scope(|scope| {
            for (c, slice) in alpha.chunks_mut(chunk).enumerate() {
                let fill = &fill;
                scope.spawn(move || fill(c * chunk, slice));
            }
        });
    }

    /// Rebuild the dual-simplex reduced-cost cache from the current basis.
    fn refresh_reduced_costs(&mut self, d_struct: &mut [f64], d_log: &mut [f64]) {
        self.compute_duals();
        for j in 0..self.model.num_vars() {
            d_struct[j] = self.model.cost[j] - self.model.cols[j].dot_dense(&self.duals);
        }
        for r in 0..self.model.num_rows() {
            d_log[r] = self.duals[r];
        }
    }

    /// Change a structural cost in place. Primal feasibility of the
    /// current basis is unaffected, so the next `solve` warm-starts with
    /// the primal simplex — this is how the regularization-path driver
    /// moves λ without rebuilding the model.
    pub fn set_col_cost(&mut self, j: VarId, cost: f64) {
        self.model.cost[j] = cost;
    }

    /// Change a row's range in place. The basis, costs and duals are
    /// untouched, so dual feasibility is preserved (a nonbasic logical
    /// stays on the *same side* it was on, keeping its reduced-cost sign
    /// valid); primal feasibility may break and is repaired by the dual
    /// simplex on the next `solve` — this is how the Dantzig-selector
    /// path driver moves λ without rebuilding the model.
    pub fn set_row_bounds(&mut self, r: RowId, lo: f64, hi: f64) {
        assert!(lo <= hi, "row bounds crossed");
        self.model.row_lo[r] = lo;
        self.model.row_hi[r] = hi;
        match self.row_status[r] {
            VarStatus::Basic(_) => {}
            VarStatus::AtLower if lo.is_finite() => {}
            VarStatus::AtUpper if hi.is_finite() => {}
            _ => {
                // the bound this logical sat on vanished: re-snap
                self.row_status[r] = if lo.is_finite() {
                    VarStatus::AtLower
                } else if hi.is_finite() {
                    VarStatus::AtUpper
                } else {
                    VarStatus::FreeZero
                };
            }
        }
    }

    // ------------------------------------------------------------------
    // Solution accessors
    // ------------------------------------------------------------------

    /// Value of structural variable `j`.
    pub fn col_value(&self, j: VarId) -> f64 {
        self.value_of(BVar::Col(j))
    }

    /// All structural values.
    pub fn col_values(&self) -> Vec<f64> {
        (0..self.model.num_vars()).map(|j| self.col_value(j)).collect()
    }

    /// Row activity `aᵢᵀx` (= the logical's value).
    pub fn row_activity(&self, r: RowId) -> f64 {
        self.value_of(BVar::Log(r))
    }

    /// Dual price of row `r` (valid after `solve`).
    pub fn row_dual(&self, r: RowId) -> f64 {
        self.duals[r]
    }

    /// All dual prices.
    pub fn duals(&self) -> &[f64] {
        &self.duals
    }

    /// Reduced cost of structural variable `j` (valid after `solve`).
    pub fn col_reduced_cost(&self, j: VarId) -> f64 {
        self.reduced_cost_of(BVar::Col(j))
    }

    /// Objective value at the current point.
    pub fn objective(&self) -> f64 {
        let mut obj = 0.0;
        for j in 0..self.model.num_vars() {
            obj += self.model.cost[j] * self.col_value(j);
        }
        obj
    }

    /// Whether variable `j` is basic.
    pub fn is_basic(&self, j: VarId) -> bool {
        matches!(self.col_status[j], VarStatus::Basic(_))
    }

    /// Status of structural variable `j`.
    pub fn col_status(&self, j: VarId) -> VarStatus {
        self.col_status[j]
    }

    // Internal hooks for the parametric simplex (same crate only).
    pub(crate) fn duals_for_costs(&mut self, costs: &dyn Fn(BVar) -> f64) -> Vec<f64> {
        let m = self.model.num_rows();
        let mut y = vec![0.0; m];
        for (pos, &v) in self.basis_vars.iter().enumerate() {
            y[pos] = costs(v);
        }
        self.ensure_factorized();
        self.factor.as_ref().unwrap().btran(&mut y);
        y
    }

    pub(crate) fn nonbasic_vars(&self) -> Vec<BVar> {
        self.iter_all_vars()
            .into_iter()
            .filter(|&v| !matches!(self.status_of(v), VarStatus::Basic(_)))
            .collect()
    }

    pub(crate) fn status_of_pub(&self, v: BVar) -> VarStatus {
        self.status_of(v)
    }

    pub(crate) fn column_dot(&self, v: BVar, y: &[f64]) -> f64 {
        match v {
            BVar::Col(j) => self.model.cols[j].dot_dense(y),
            BVar::Log(r) => -y[r],
        }
    }

    pub(crate) fn cost_of_pub(&self, v: BVar) -> f64 {
        self.cost_of(v)
    }

    // ------------------------------------------------------------------
    // Parametric-path and crossover hooks (same crate only)
    // ------------------------------------------------------------------

    /// RHS-parametric breakpoint scan for models whose **every** row
    /// range moves as `[centers[r] − λ, centers[r] + λ]` (the Dantzig
    /// selector's restricted LP). With the basis fixed, each basic value
    /// is affine in λ: `x_B(λ') = x_B + (λ' − λ)·w` with `w = B⁻¹d`,
    /// where `d_r` is the λ-derivative of the nonbasic logical sitting
    /// on row `r`'s moving bound (−1 at the lower bound, +1 at the
    /// upper; 0 for basic logicals). Returns the largest λ' in
    /// `[lambda_lo, lambda)` at which some basic variable hits a
    /// (possibly itself moving) bound — the RHS analogue of the
    /// cost-parametric scan in `parametric.rs` — or `None` when the
    /// basis stays primal-feasible down to `lambda_lo`.
    pub(crate) fn next_rhs_breakpoint(
        &mut self,
        centers: &[f64],
        lambda: f64,
        lambda_lo: f64,
    ) -> Option<f64> {
        let m = self.model.num_rows();
        debug_assert_eq!(centers.len(), m);
        if m == 0 {
            return None;
        }
        self.ensure_factorized();
        self.recompute_x_basic();
        let mut d = vec![0.0; m];
        for r in 0..m {
            match self.row_status[r] {
                VarStatus::AtLower => d[r] = -1.0,
                VarStatus::AtUpper => d[r] = 1.0,
                _ => {}
            }
        }
        self.factor.as_ref().expect("factorized").ftran(&mut d);
        let mut next: Option<f64> = None;
        let mut push = |cand: f64, next: &mut Option<f64>| {
            if cand < lambda - 1e-10
                && cand >= lambda_lo - 1e-10
                && next.map_or(true, |l| cand > l)
            {
                *next = Some(cand);
            }
        };
        for (pos, &v) in self.basis_vars.iter().enumerate() {
            let w = d[pos];
            let x = self.x_basic[pos];
            match v {
                BVar::Col(j) => {
                    // Fixed bounds: x + (λ'−λ)·w hits lb or ub.
                    if w.abs() < 1e-12 {
                        continue;
                    }
                    let (lb, ub) = (self.model.lb[j], self.model.ub[j]);
                    if lb.is_finite() {
                        push(lambda + (lb - x) / w, &mut next);
                    }
                    if ub.is_finite() {
                        push(lambda + (ub - x) / w, &mut next);
                    }
                }
                BVar::Log(r) => {
                    // Moving bounds: x + (λ'−λ)·w = centers[r] ∓ λ'.
                    let c = centers[r];
                    if (w + 1.0).abs() > 1e-12 {
                        push((c - x + lambda * w) / (w + 1.0), &mut next);
                    }
                    if (w - 1.0).abs() > 1e-12 {
                        push((c - x + lambda * w) / (w - 1.0), &mut next);
                    }
                }
            }
        }
        next
    }

    /// Crossover from an external primal guess: seat the `preferred`
    /// structural variables in the basis (greedily matched to rows by
    /// largest remaining |coefficient|), pin any out-of-bounds basic
    /// values by temporarily relaxing the violated bound to the value
    /// itself, run the primal simplex on the pinned problem, then
    /// restore the true bounds. Costs, duals and reduced costs never
    /// involve bounds, so the restore leaves the solver dual-feasible
    /// near the guess and the next [`SimplexSolver::solve`] finishes
    /// with a short dual-simplex cleanup instead of replaying the
    /// expansion from the all-logical crash basis. Returns `false`
    /// (leaving a cold-startable state) when no seat survives — an
    /// empty guess, all-zero candidate columns, or a numerically
    /// singular seating that `repair_basis` reset.
    pub(crate) fn crossover_from_guess(&mut self, preferred: &[VarId]) -> bool {
        let m = self.model.num_rows();
        if m == 0 || preferred.is_empty() {
            return false;
        }
        // Reset every structural to its nonbasic snap.
        for j in 0..self.model.num_vars() {
            let (lb, ub) = (self.model.lb[j], self.model.ub[j]);
            self.col_status[j] = if lb.is_finite() {
                VarStatus::AtLower
            } else if ub.is_finite() {
                VarStatus::AtUpper
            } else {
                VarStatus::FreeZero
            };
        }
        // Greedy seat assignment: each preferred variable takes the
        // untaken row where its coefficient is largest.
        let mut taken = vec![false; m];
        let mut seated = vec![false; self.model.num_vars()];
        let mut seats: Vec<(usize, VarId)> = Vec::new();
        for &j in preferred {
            if j >= seated.len() || seated[j] || seats.len() == m {
                continue;
            }
            let col = &self.model.cols[j];
            let mut best: Option<(usize, f64)> = None;
            for (&r, &val) in col.rows.iter().zip(&col.vals) {
                if !taken[r] && best.map_or(true, |(_, a)| val.abs() > a) {
                    best = Some((r, val.abs()));
                }
            }
            if let Some((r, a)) = best {
                if a > 1e-9 {
                    taken[r] = true;
                    seated[j] = true;
                    seats.push((r, j));
                }
            }
        }
        if seats.is_empty() {
            return false;
        }
        self.basis_vars = (0..m).map(BVar::Log).collect();
        for r in 0..m {
            self.row_status[r] = VarStatus::Basic(r);
        }
        for &(r, j) in &seats {
            self.basis_vars[r] = BVar::Col(j);
            self.col_status[j] = VarStatus::Basic(r);
            let (lo, hi) = (self.model.row_lo[r], self.model.row_hi[r]);
            self.row_status[r] = if lo.is_finite() {
                VarStatus::AtLower
            } else if hi.is_finite() {
                VarStatus::AtUpper
            } else {
                VarStatus::FreeZero
            };
        }
        self.factor = None;
        self.refactorize(); // a singular seating repairs to all-logical
        let (_, j0) = seats[0];
        if !matches!(self.col_status[j0], VarStatus::Basic(_)) {
            return false; // repair_basis reset the seating
        }
        // Pin out-of-bounds basic values at themselves so the pinned
        // problem starts primal feasible without drifting away from the
        // guess (the relaxed bound equals the current value, so the
        // optimizer gains no new room below/above it).
        let mut pinned: Vec<(BVar, f64, f64)> = Vec::new();
        for (pos, &v) in self.basis_vars.clone().iter().enumerate() {
            let (lb, ub) = self.bounds_of(v);
            let x = self.x_basic[pos];
            if x < lb - self.tol.feas {
                pinned.push((v, lb, ub));
                match v {
                    BVar::Col(j) => self.model.lb[j] = x,
                    BVar::Log(r) => self.model.row_lo[r] = x,
                }
            } else if x > ub + self.tol.feas {
                pinned.push((v, lb, ub));
                match v {
                    BVar::Col(j) => self.model.ub[j] = x,
                    BVar::Log(r) => self.model.row_hi[r] = x,
                }
            }
        }
        self.bland = false;
        self.stall = 0;
        let st = self.primal_simplex();
        for &(v, lo, hi) in &pinned {
            // Restoring bounds keeps each nonbasic status on the same
            // side (the value snaps to the restored bound) — the same
            // dual-feasibility-preserving move `set_row_bounds` makes.
            match v {
                BVar::Col(j) => {
                    self.model.lb[j] = lo;
                    self.model.ub[j] = hi;
                }
                BVar::Log(r) => {
                    self.model.row_lo[r] = lo;
                    self.model.row_hi[r] = hi;
                }
            }
        }
        st == Status::Optimal
    }

}
