//! Parametric-cost simplex: the PSM baseline of Table 4.
//!
//! Pang, Liu, Vanderbei & Zhao (NeurIPS 2017) solve the L1-SVM by a
//! *parametric simplex method*: the objective is `c(λ) = c_fix + λ·c_var`
//! (hinge slacks in `c_fix`, the |β| halves in `c_var`), the trivial basis
//! is optimal at `λ = λ_max`, and the method rides the optimal-basis path
//! downward, pivoting at each breakpoint where a reduced cost
//! `d_j(λ) = d_fix_j + λ·d_var_j` changes sign. Crucially it operates on
//! the **full model** (all p columns price at every breakpoint), which is
//! exactly why column generation beats it at large p — the effect Table 4
//! measures.

use super::solver::{BVar, SimplexSolver, Status, VarStatus};
use crate::error::Result;

/// One breakpoint on the optimal-basis path.
#[derive(Clone, Copy, Debug)]
pub struct PathPoint {
    /// Regularization value at this breakpoint.
    pub lambda: f64,
    /// Objective value `c(λ)ᵀx` at the basis.
    pub objective: f64,
    /// Pivots performed so far.
    pub pivots: usize,
}

/// Parametric-cost driver over a [`SimplexSolver`].
pub struct ParametricSimplex {
    /// Underlying solver (model costs are rewritten as λ moves).
    pub solver: SimplexSolver,
    /// λ-independent part of the cost (per structural variable).
    c_fix: Vec<f64>,
    /// λ-multiplied part of the cost.
    c_var: Vec<f64>,
    pivots: usize,
}

impl ParametricSimplex {
    /// Build from a solver plus the cost decomposition
    /// `cost_j(λ) = c_fix[j] + λ·c_var[j]`.
    pub fn new(solver: SimplexSolver, c_fix: Vec<f64>, c_var: Vec<f64>) -> Self {
        assert_eq!(c_fix.len(), solver.model().num_vars());
        assert_eq!(c_var.len(), solver.model().num_vars());
        Self { solver, c_fix, c_var, pivots: 0 }
    }

    fn apply_lambda(&mut self, lambda: f64) {
        for j in 0..self.c_fix.len() {
            self.solver.model.cost[j] = self.c_fix[j] + lambda * self.c_var[j];
        }
    }

    /// Solve to optimality at `lambda_start`, then ride the path down to
    /// `lambda_target`, recording every breakpoint. Returns the path; the
    /// solver is left optimal at `lambda_target`.
    ///
    /// Errors (instead of panicking) when `lambda_target > lambda_start`:
    /// user-supplied grids reach this driver unordered, and the serve
    /// layer's never-panics contract turns that into a typed response.
    pub fn run(
        &mut self,
        lambda_start: f64,
        lambda_target: f64,
        max_breakpoints: usize,
    ) -> Result<(Vec<PathPoint>, Status)> {
        crate::ensure!(
            lambda_target <= lambda_start,
            "parametric path: lambda_target {lambda_target} exceeds lambda_start {lambda_start} \
             (the path rides downward; order the grid high to low)"
        );
        let mut path = Vec::new();
        self.apply_lambda(lambda_start);
        let st = self.solver.solve();
        if st != Status::Optimal {
            return Ok((path, st));
        }
        let mut lambda = lambda_start;
        path.push(PathPoint { lambda, objective: self.solver.objective(), pivots: self.pivots });

        for _ in 0..max_breakpoints {
            if lambda <= lambda_target {
                break;
            }
            // Find the largest λ' < λ where some nonbasic reduced cost
            // crosses zero in the violating direction.
            let next =
                next_cost_breakpoint(&mut self.solver, &self.c_fix, &self.c_var, lambda, lambda_target);

            match next {
                None => {
                    // Basis optimal all the way to the target.
                    lambda = lambda_target;
                    self.apply_lambda(lambda);
                    path.push(PathPoint {
                        lambda,
                        objective: self.solver.objective(),
                        pivots: self.pivots,
                    });
                    break;
                }
                Some(crossing) => {
                    // Move just past the breakpoint and re-optimize with the
                    // (primal-feasible) warm basis.
                    lambda = (crossing - 1e-9).max(lambda_target);
                    self.apply_lambda(lambda);
                    let st = self.solver.solve();
                    self.pivots = self.solver.stats.primal_iters + self.solver.stats.dual_iters;
                    if st != Status::Optimal {
                        return Ok((path, st));
                    }
                    path.push(PathPoint {
                        lambda,
                        objective: self.solver.objective(),
                        pivots: self.pivots,
                    });
                }
            }
        }
        if lambda > lambda_target {
            // Breakpoint budget exhausted: finish with one warm solve.
            self.apply_lambda(lambda_target);
            let st = self.solver.solve();
            path.push(PathPoint {
                lambda: lambda_target,
                objective: self.solver.objective(),
                pivots: self.pivots,
            });
            return Ok((path, st));
        }
        Ok((path, Status::Optimal))
    }

    /// Cost of variable `v` at the λ most recently applied.
    pub fn current_cost(&self, j: usize) -> f64 {
        self.solver.model().cost[j]
    }

    /// Access the cost decomposition (for tests).
    pub fn decomposition(&self) -> (&[f64], &[f64]) {
        (&self.c_fix, &self.c_var)
    }

    /// Internal: cost of a basis variable (structural or logical).
    #[allow(dead_code)]
    fn cost_at(&self, v: BVar, lambda: f64) -> f64 {
        match v {
            BVar::Col(j) => self.c_fix[j] + lambda * self.c_var[j],
            BVar::Log(_) => self.solver.cost_of_pub(v),
        }
    }
}

/// Largest λ' in `[lambda_lo, lambda)` at which some nonbasic reduced
/// cost of the current basis crosses zero in the violating direction,
/// under the cost decomposition `c_j(λ) = c_fix[j] + λ·c_var[j]` over
/// structural variables (logicals are cost-free). `None` means the
/// basis stays cost-optimal all the way down to `lambda_lo`.
///
/// Reduced costs decompose the same way the costs do:
/// `d_j(λ) = d_fix_j + λ·d_var_j` with `d_fix/d_var` from one BTRAN
/// each, so the scan is two dual solves plus one pass over the
/// nonbasic variables. Shared by the full-model PSM baseline above and
/// the restricted exact-path drivers in `coordinator`.
pub(crate) fn next_cost_breakpoint(
    solver: &mut SimplexSolver,
    c_fix: &[f64],
    c_var: &[f64],
    lambda: f64,
    lambda_lo: f64,
) -> Option<f64> {
    let y_fix = solver.duals_for_costs(&|v| match v {
        BVar::Col(j) => c_fix[j],
        BVar::Log(_) => 0.0,
    });
    let y_var = solver.duals_for_costs(&|v| match v {
        BVar::Col(j) => c_var[j],
        BVar::Log(_) => 0.0,
    });
    let mut next: Option<f64> = None;
    for v in solver.nonbasic_vars() {
        let (dfix, dvar) = match v {
            BVar::Col(j) => (
                c_fix[j] - solver.column_dot(v, &y_fix),
                c_var[j] - solver.column_dot(v, &y_var),
            ),
            BVar::Log(r) => (y_fix[r], y_var[r]),
        };
        if dvar.abs() < 1e-12 {
            continue; // reduced cost does not move with λ
        }
        let crossing = -dfix / dvar;
        if crossing >= lambda - 1e-10 || crossing < lambda_lo - 1e-10 {
            continue; // outside (lambda_lo, λ)
        }
        let violating = match solver.status_of_pub(v) {
            VarStatus::AtLower => dvar > 0.0,  // d decreases as λ ↓
            VarStatus::AtUpper => dvar < 0.0,  // d increases as λ ↓
            VarStatus::FreeZero => true,
            VarStatus::Basic(_) => false,
        };
        if !violating {
            continue;
        }
        if next.map_or(true, |l| crossing > l) {
            next = Some(crossing);
        }
    }
    next
}
